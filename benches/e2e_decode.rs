//! End-to-end decode throughput through the full stack: coordinator →
//! quantized weights → PJRT executor. The L3 counterpart of the paper's
//! App. H runtime benchmark, at miniature scale.
//!
//! Run: `cargo bench --bench e2e_decode` (needs `make artifacts`)
//!
//! Reports tokens/sec for FP vs TTQ(r=0) vs TTQ(r=16) serving and the
//! share of time spent on online quantization (must be small — Eq. 3).

use std::time::{Duration, Instant};

use ttq_serve::coordinator::{BatchPolicy, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::{Evaluator, MethodSpec};
use ttq_serve::quant::QuantSpec;
use ttq_serve::runtime::Runtime;

fn main() {
    if !ttq_serve::artifacts_ready() {
        eprintln!("skipping e2e_decode: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&ttq_serve::artifacts_dir()).unwrap();
    let model = "qwen-micro";
    let requests = 48;

    println!("== e2e serving throughput, {model}, {requests} requests ==");
    for (label, rank, bits) in [
        ("TTQ q=4 r=0", 0usize, 4u32),
        ("TTQ q=4 r=16", 16, 4),
        ("TTQ q=2 r=0", 0, 2),
    ] {
        let mut cfg = ServerConfig::new(model).with_method(MethodSpec::ttq(rank));
        cfg.spec = QuantSpec::new(bits, 32);
        cfg.policy = BatchPolicy {
            buckets: vec![1, 4],
            linger: Duration::ZERO,
        };
        let mut server = Server::new(&rt, cfg).unwrap();
        let seq = server.seq();
        let mut s = CorpusStream::new("wt2s", Split::Eval);
        let t0 = Instant::now();
        for _ in 0..requests {
            let mut toks = vec![BOS; seq];
            for t in toks.iter_mut().skip(1) {
                *t = s.next_token();
            }
            server.submit(toks);
            server.step(Instant::now()).unwrap();
        }
        server.drain().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        use std::sync::atomic::Ordering::Relaxed;
        let toks = server.metrics.tokens.load(Relaxed);
        let quant_ms = server.metrics.quant_us.load(Relaxed) as f64 / 1e3;
        println!(
            "{label:<14} wall {wall:>6.2}s  {:>8.0} tok/s  quant {quant_ms:>7.1}ms \
             ({:.1}% of wall)  generations {}",
            toks as f64 / wall,
            100.0 * quant_ms / (wall * 1e3),
            server.weight_generation(),
        );
    }

    // per-batch eval-pipeline throughput (the Table 1-3 workhorse)
    println!("\n== eval pipeline batch throughput ==");
    let mut ev = Evaluator::new(&rt, model).unwrap();
    let seq = ev.weights.manifest.config.seq;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    for (label, method) in [
        ("plain nll b4", None),
        ("TTQ two-pass b4", Some(MethodSpec::ttq(0))),
    ] {
        let iters = 6;
        let t0 = Instant::now();
        let mut total_tokens = 0usize;
        for _ in 0..iters {
            let toks = s.batch(4, seq);
            total_tokens += toks.len();
            if let Some(m) = &method {
                ev.restore();
                let st = ev.collect(&toks, 4, false).unwrap();
                ev.apply_quantization(
                    m,
                    Some(&st),
                    &ttq_serve::eval::EvalConfig::default(),
                )
                .unwrap();
            }
            ev.nll(&toks, 4).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{label:<18} {:>8.0} tok/s ({:.1} ms/batch)",
            total_tokens as f64 / wall,
            wall * 1e3 / iters as f64
        );
    }
}
