//! End-to-end decode throughput through the full stack: coordinator →
//! quantized weights → execution backend. The L3 counterpart of the
//! paper's App. H runtime benchmark, at miniature scale.
//!
//! Run: `cargo bench --bench e2e_decode` — needs **no** artifacts: the
//! native backend serves deterministic synthetic weights, and the
//! packed-W4 execution mode turns "TTQ speedup" into a measured
//! wall-clock number (fp32 dense matmul vs grouped int-matmul over the
//! packed codes). With `make artifacts` the PJRT serving section runs
//! too.

use std::time::{Duration, Instant};

use ttq_serve::backend::{ExecBackend, NativeBackend};
use ttq_serve::coordinator::{BatchPolicy, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::{Evaluator, MethodSpec};
use ttq_serve::quant::QuantSpec;
use ttq_serve::runtime::Runtime;

/// Serve `requests` prompts through the coordinator; print tok/s and
/// the online-quantization share of wall-clock (must be small — Eq. 3).
fn serve_once(backend: &dyn ExecBackend, label: &str, model: &str, requests: usize) {
    let mut cfg = ServerConfig::new(model).with_method(MethodSpec::ttq(0));
    cfg.spec = QuantSpec::new(4, 32);
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::ZERO };
    let mut server = Server::new(backend, cfg).unwrap();
    let seq = server.seq();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let t0 = Instant::now();
    for _ in 0..requests {
        let mut toks = vec![BOS; seq];
        for t in toks.iter_mut().skip(1) {
            *t = s.next_token();
        }
        server.submit(toks);
        server.step(Instant::now()).unwrap();
    }
    server.drain().unwrap();
    let wall = t0.elapsed().as_secs_f64();
    use std::sync::atomic::Ordering::Relaxed;
    let toks = server.metrics.tokens.load(Relaxed);
    let quant_ms = server.metrics.quant_us.load(Relaxed) as f64 / 1e3;
    println!(
        "{label:<22} wall {wall:>6.2}s  {:>8.0} tok/s  quant {quant_ms:>7.1}ms \
         ({:.1}% of wall)  generations {}",
        toks as f64 / wall,
        100.0 * quant_ms / (wall * 1e3),
        server.weight_generation(),
    );
}

fn main() {
    let dir = ttq_serve::artifacts_dir();
    let model = "qwen-micro";
    let requests = 32;

    // -- the acceptance measurement: fp32 vs packed-W4 native decode --
    println!("== native decode wall-clock, {model}, batch 1 ==");
    let fp = NativeBackend::new(&dir);
    let weights = fp.load_model(model).unwrap();
    let seq = weights.manifest.config.seq;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let prompt = s.batch(1, seq);
    let iters = 12;
    let mut baseline = 0.0f64;
    for (label, backend) in [
        ("fp32 dense", NativeBackend::new(&dir)),
        ("W4 packed", NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(4, 32))),
        ("W2 packed", NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(2, 32))),
    ] {
        // warm once (packs the weights outside the timed loop)
        backend.logits(&weights, &prompt, 1).unwrap();
        let t0 = Instant::now();
        for _ in 0..iters {
            backend.logits(&weights, &prompt, 1).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let tps = (iters * seq) as f64 / wall;
        if baseline == 0.0 {
            baseline = wall;
        }
        println!(
            "{label:<12} {:>8.1} ms/decode  {tps:>9.0} tok/s  ({:.2}x vs fp32)",
            wall * 1e3 / iters as f64,
            baseline / wall
        );
    }

    // -- full serving loop on the native backend (always available) --
    println!("\n== e2e serving throughput (native), {model}, {requests} requests ==");
    serve_once(&NativeBackend::new(&dir), "native fp32", model, requests);
    serve_once(
        &NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(4, 32)),
        "native W4 packed",
        model,
        requests,
    );

    // -- PJRT serving + eval pipeline (only with compiled artifacts) --
    if !ttq_serve::artifacts_ready() {
        println!("\n(pjrt sections skipped: run `make artifacts` for the AOT path)");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let pjrt = ttq_serve::backend::PjrtBackend::new(rt);
    println!("\n== e2e serving throughput (pjrt), {model}, {requests} requests ==");
    serve_once(&pjrt, "pjrt TTQ q=4", model, requests);

    // per-batch eval-pipeline throughput (the Table 1-3 workhorse)
    println!("\n== eval pipeline batch throughput (pjrt) ==");
    let mut ev = Evaluator::new(&pjrt, model).unwrap();
    let seq = ev.weights.manifest.config.seq;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    for (label, method) in [
        ("plain nll b4", None),
        ("TTQ two-pass b4", Some(MethodSpec::ttq(0))),
    ] {
        let iters = 6;
        let t0 = Instant::now();
        let mut total_tokens = 0usize;
        for _ in 0..iters {
            let toks = s.batch(4, seq);
            total_tokens += toks.len();
            if let Some(m) = &method {
                ev.restore();
                let st = ev.collect(&toks, 4, false).unwrap();
                ev.apply_quantization(
                    m,
                    Some(&st),
                    &ttq_serve::eval::EvalConfig::default(),
                )
                .unwrap();
            }
            ev.nll(&toks, 4).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{label:<18} {:>8.0} tok/s ({:.1} ms/batch)",
            total_tokens as f64 / wall,
            wall * 1e3 / iters as f64
        );
    }
}
