//! End-to-end decode throughput through the full stack: coordinator →
//! quantized weights → execution backend. The L3 counterpart of the
//! paper's App. H runtime benchmark, at miniature scale.
//!
//! Run: `cargo bench --bench e2e_decode` — needs **no** artifacts: the
//! native backend serves deterministic synthetic weights. Since the
//! decode-engine split this measures what the paper actually claims:
//! **true tokens/sec of autoregressive generation**, cached KV decode
//! vs full-prefix recompute, in fp32 and packed-W4 execution. Results
//! land in `BENCH_decode.json` and the process exits non-zero if cached
//! decode fails to beat full recompute — CI runs this as a perf gate.

use std::time::Instant;

use ttq_serve::backend::{ExecBackend, NativeBackend};
use ttq_serve::coordinator::{BatchPolicy, ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::{Evaluator, MethodSpec};
use ttq_serve::models::ModelWeights;
use ttq_serve::quant::QuantSpec;
use ttq_serve::util::argmax;

/// Greedy generation by re-running the full growing prefix each step —
/// the pre-decode-engine baseline.
fn generate_full_recompute(
    be: &dyn ExecBackend,
    w: &ModelWeights,
    prompt: &[i32],
    new_tokens: usize,
) -> (Vec<i32>, f64) {
    let vocab = w.manifest.config.vocab;
    let mut toks = prompt.to_vec();
    let mut out = Vec::with_capacity(new_tokens);
    let t0 = Instant::now();
    for _ in 0..new_tokens {
        let logits = be.logits(w, &toks, 1).unwrap();
        let tok = argmax(&logits[(toks.len() - 1) * vocab..]) as i32;
        out.push(tok);
        toks.push(tok);
    }
    (out, t0.elapsed().as_secs_f64())
}

/// Greedy generation through the cached prefill/decode split — the
/// very loop the library ships (`Evaluator::generate`), timed.
fn generate_cached(ev: &Evaluator<'_>, prompt: &[i32], new_tokens: usize) -> (Vec<i32>, f64) {
    let t0 = Instant::now();
    let out = ev.generate(prompt, new_tokens, None).unwrap();
    (out, t0.elapsed().as_secs_f64())
}

/// Serve `requests` prompts through the streaming decode engine; print
/// generated-token throughput and the online-quantization share.
fn serve_once(backend: &dyn ExecBackend, label: &str, model: &str, requests: usize) {
    let mut cfg = ServerConfig::new(model).with_method(MethodSpec::ttq(0));
    cfg.spec = QuantSpec::new(4, 32);
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: std::time::Duration::ZERO };
    cfg.max_new_tokens = 8;
    let mut server = Server::new(backend, cfg).unwrap();
    let prompt_len = server.max_seq() / 2;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let t0 = Instant::now();
    let mut done = 0usize;
    let mut streamed = 0usize;
    let mut count = |evs: &[ServeEvent]| {
        for e in evs {
            match e {
                ServeEvent::Token { .. } => streamed += 1,
                ServeEvent::Done { .. } => done += 1,
            }
        }
    };
    for _ in 0..requests {
        let mut toks = vec![BOS; prompt_len];
        for t in toks.iter_mut().skip(1) {
            *t = s.next_token();
        }
        server.submit(toks);
        count(&server.step(Instant::now()).unwrap());
    }
    count(&server.drain().unwrap());
    let wall = t0.elapsed().as_secs_f64();
    use std::sync::atomic::Ordering::Relaxed;
    let quant_ms = server.metrics.quant_us.load(Relaxed) as f64 / 1e3;
    let hwm = server.cache_stats().high_water_tokens;
    println!(
        "{label:<18} {done}/{requests} done  {:>7.0} gen tok/s  decode {:>6.0} tok/s \
         quant {quant_ms:>6.1}ms ({:.1}% of wall)  gens {}  cache_hwm {hwm}",
        streamed as f64 / wall,
        server.metrics.decode_tokens_per_sec(),
        100.0 * quant_ms / (wall * 1e3),
        server.weight_generation(),
    );
}

fn main() {
    let dir = ttq_serve::artifacts_dir();
    let model = "qwen-micro";

    // -- the acceptance measurement: cached vs full-recompute decode --
    let fp = NativeBackend::new(&dir);
    let weights = fp.load_model(model).unwrap();
    let max_seq = weights.manifest.config.max_seq;
    let prompt_len = max_seq / 2;
    let new_tokens = max_seq - prompt_len; // fill the context window
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let mut prompt = vec![BOS; prompt_len];
    for t in prompt.iter_mut().skip(1) {
        *t = s.next_token();
    }

    println!(
        "== true decode tokens/sec, {model}, prompt {prompt_len}, {new_tokens} new tokens =="
    );
    let mut rows = Vec::new();
    let mut gate_ok = true;
    for (mode, backend) in [
        ("fp32", NativeBackend::new(&dir)),
        ("w4", NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(4, 32))),
    ] {
        let ev = Evaluator::new(&backend, model).unwrap();
        // warm once (packs weights / faults pages outside the timing)
        backend.logits(&ev.weights, &prompt, 1).unwrap();
        let (full_toks, full_s) =
            generate_full_recompute(&backend, &ev.weights, &prompt, new_tokens);
        let (cached_toks, cached_s) = generate_cached(&ev, &prompt, new_tokens);
        assert_eq!(
            full_toks, cached_toks,
            "{mode}: cached decode diverged from full recompute"
        );
        let full_tps = new_tokens as f64 / full_s;
        let cached_tps = new_tokens as f64 / cached_s;
        let speedup = cached_tps / full_tps;
        println!(
            "{mode:<6} full-recompute {full_tps:>8.0} tok/s   kv-cache {cached_tps:>8.0} \
             tok/s   speedup {speedup:.2}x"
        );
        if cached_tps <= full_tps {
            gate_ok = false;
        }
        rows.push(format!(
            r#"    {{"mode": "{mode}", "full_recompute_tps": {full_tps:.1}, "kv_cache_tps": {cached_tps:.1}, "speedup": {speedup:.3}}}"#
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"e2e_decode\",\n  \"model\": \"{model}\",\n  \
         \"prompt_len\": {prompt_len},\n  \"new_tokens\": {new_tokens},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_decode.json", &json).expect("write BENCH_decode.json");
    println!("\nwrote BENCH_decode.json");

    // -- full serving loop on the native backend (always available) --
    let requests = 24;
    println!("\n== e2e streaming serving, {model}, {requests} requests ==");
    serve_once(&NativeBackend::new(&dir), "native fp32", model, requests);
    serve_once(
        &NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(4, 32)),
        "native W4 packed",
        model,
        requests,
    );
    if !ttq_serve::artifacts_ready() {
        println!("\n(pjrt section skipped: AOT artifacts have no KV-cache variant;");
        println!(" run `make artifacts` for the full-batch pjrt eval pipeline)");
    }

    if !gate_ok {
        eprintln!("PERF GATE FAILED: cached decode must beat full recompute");
        std::process::exit(1);
    }
}
