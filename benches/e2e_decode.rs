//! End-to-end decode throughput through the full stack: coordinator →
//! quantized weights → execution backend. The L3 counterpart of the
//! paper's App. H runtime benchmark, at miniature scale.
//!
//! Run: `cargo bench --bench e2e_decode` — needs **no** artifacts: the
//! native backend serves deterministic synthetic weights. Since the
//! decode-engine split this measures what the paper actually claims:
//! **true tokens/sec of autoregressive generation**, cached KV decode
//! vs full-prefix recompute, in fp32 and packed-W4 execution, plus the
//! self-speculative row (W4 drafter + fp32 verifier) with its measured
//! draft-acceptance rate. Results land in `BENCH_decode.json` and the
//! process exits non-zero on a gate failure — CI runs this as a perf
//! gate:
//!
//! * cached decode must beat full-prefix recompute (fp32 and W4);
//! * speculative greedy output must be token-identical to plain greedy
//!   output (always asserted — the zero-quality-loss contract);
//! * speculative decode must beat plain cached decode tokens/sec
//!   **when the speculative preconditions hold**: measured acceptance
//!   ≥ 0.6 *and* the W4 drafter actually out-paces the fp32 verifier
//!   (≥1.5× — the memory-bound regime the paper's GPUs live in; on a
//!   flop-bound CPU host where packed execution is not faster, the
//!   assertion reports instead of failing, because no drafter speed
//!   advantage exists for speculation to convert).

use std::time::Instant;

use ttq_serve::backend::{ExecBackend, NativeBackend};
use ttq_serve::coordinator::{BatchPolicy, ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::{Evaluator, MethodSpec, Sampler};
use ttq_serve::models::ModelWeights;
use ttq_serve::quant::QuantSpec;
use ttq_serve::specdec::{SpecConfig, SpecGenerator, SpecModel};
use ttq_serve::util::argmax;

/// Greedy generation by re-running the full growing prefix each step —
/// the pre-decode-engine baseline.
fn generate_full_recompute(
    be: &dyn ExecBackend,
    w: &ModelWeights,
    prompt: &[i32],
    new_tokens: usize,
) -> (Vec<i32>, f64) {
    let vocab = w.manifest.config.vocab;
    let mut toks = prompt.to_vec();
    let mut out = Vec::with_capacity(new_tokens);
    let t0 = Instant::now();
    for _ in 0..new_tokens {
        let logits = be.logits(w, &toks, 1).unwrap();
        let tok = argmax(&logits[(toks.len() - 1) * vocab..]) as i32;
        out.push(tok);
        toks.push(tok);
    }
    (out, t0.elapsed().as_secs_f64())
}

/// Greedy generation through the cached prefill/decode split — the
/// very loop the library ships (`Evaluator::generate`), timed.
fn generate_cached(ev: &Evaluator<'_>, prompt: &[i32], new_tokens: usize) -> (Vec<i32>, f64) {
    let t0 = Instant::now();
    let out = ev.generate(prompt, new_tokens, None).unwrap();
    (out, t0.elapsed().as_secs_f64())
}

/// Serve `requests` prompts through the streaming decode engine; print
/// generated-token throughput and the online-quantization share. With
/// `speculative`, every request decodes through the drafter/verifier
/// round instead of plain quantized decode.
fn serve_once(
    backend: &dyn ExecBackend,
    label: &str,
    model: &str,
    requests: usize,
    speculative: bool,
) {
    let mut cfg = ServerConfig::new(model).with_method(MethodSpec::ttq(0));
    cfg.spec = QuantSpec::new(4, 32);
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: std::time::Duration::ZERO };
    cfg.max_new_tokens = 8;
    let mut server = Server::new(backend, cfg).unwrap();
    let prompt_len = server.max_seq() / 2;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let t0 = Instant::now();
    let mut done = 0usize;
    let mut streamed = 0usize;
    let mut count = |evs: &[ServeEvent]| {
        for e in evs {
            match e {
                ServeEvent::Token { .. } => streamed += 1,
                ServeEvent::Done { .. } => done += 1,
            }
        }
    };
    for _ in 0..requests {
        let mut toks = vec![BOS; prompt_len];
        for t in toks.iter_mut().skip(1) {
            *t = s.next_token();
        }
        if speculative {
            server.submit_speculative(toks);
        } else {
            server.submit(toks);
        }
        count(&server.step().unwrap());
    }
    count(&server.drain().unwrap());
    let wall = t0.elapsed().as_secs_f64();
    use std::sync::atomic::Ordering::Relaxed;
    let quant_ms = server.metrics.quant_us.load(Relaxed) as f64 / 1e3;
    let hwm = server.cache_stats().high_water_tokens;
    let spec_note = if speculative {
        format!(
            "  spec accept={:.2} {:.2} tok/round",
            server.metrics.spec_acceptance(),
            server.metrics.spec_tokens_per_round(),
        )
    } else {
        String::new()
    };
    println!(
        "{label:<18} {done}/{requests} done  {:>7.0} gen tok/s  decode {:>6.0} tok/s \
         quant {quant_ms:>6.1}ms ({:.1}% of wall)  gens {}  cache_hwm {hwm}{spec_note}",
        streamed as f64 / wall,
        server.metrics.decode_tokens_per_sec(),
        100.0 * quant_ms / (wall * 1e3),
        server.weight_generation(),
    );
}

fn main() {
    let dir = ttq_serve::artifacts_dir();
    let model = "qwen-micro";

    // -- the acceptance measurement: cached vs full-recompute decode --
    let fp = NativeBackend::new(&dir);
    let weights = fp.load_model(model).unwrap();
    let max_seq = weights.manifest.config.max_seq;
    let prompt_len = max_seq / 2;
    let new_tokens = max_seq - prompt_len; // fill the context window
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let mut prompt = vec![BOS; prompt_len];
    for t in prompt.iter_mut().skip(1) {
        *t = s.next_token();
    }

    println!(
        "== true decode tokens/sec, {model}, prompt {prompt_len}, {new_tokens} new tokens =="
    );
    let mut rows = Vec::new();
    let mut gate_ok = true;
    // cached tokens/sec (and the greedy token stream) per exec mode, for
    // the speculative comparison below
    let mut cached_by_mode: Vec<(String, Vec<i32>, f64)> = Vec::new();
    for (mode, backend) in [
        ("fp32", NativeBackend::new(&dir)),
        ("w4", NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(4, 32))),
    ] {
        let ev = Evaluator::new(&backend, model).unwrap();
        // warm once (packs weights / faults pages outside the timing)
        backend.logits(&ev.weights, &prompt, 1).unwrap();
        let (full_toks, full_s) =
            generate_full_recompute(&backend, &ev.weights, &prompt, new_tokens);
        let (cached_toks, cached_s) = generate_cached(&ev, &prompt, new_tokens);
        assert_eq!(
            full_toks, cached_toks,
            "{mode}: cached decode diverged from full recompute"
        );
        let full_tps = new_tokens as f64 / full_s;
        let cached_tps = new_tokens as f64 / cached_s;
        let speedup = cached_tps / full_tps;
        println!(
            "{mode:<6} full-recompute {full_tps:>8.0} tok/s   kv-cache {cached_tps:>8.0} \
             tok/s   speedup {speedup:.2}x"
        );
        if cached_tps <= full_tps {
            gate_ok = false;
        }
        cached_by_mode.push((mode.to_string(), cached_toks, cached_tps));
        rows.push(format!(
            r#"    {{"mode": "{mode}", "full_recompute_tps": {full_tps:.1}, "kv_cache_tps": {cached_tps:.1}, "speedup": {speedup:.3}}}"#
        ));
    }

    // -- self-speculative decode: W4 drafter + fp32 verifier ----------
    println!("\n== self-speculative decode, {model}, k=4 adaptive ==");
    let fp32_backend = NativeBackend::new(&dir);
    let w4_backend = NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(4, 32));
    let fp_weights = fp32_backend.load_model(model).unwrap();
    // warm the packed cache outside the timing
    w4_backend.logits(&fp_weights, &prompt, 1).unwrap();
    let drafter = SpecModel { backend: &w4_backend, weights: &fp_weights };
    let verifier = SpecModel { backend: &fp32_backend, weights: &fp_weights };
    let mut gen = SpecGenerator::new(drafter, verifier, &SpecConfig::new(4)).unwrap();
    let t0 = Instant::now();
    let (spec_toks, spec_stats) = gen
        .generate(&prompt, new_tokens, None, &mut Sampler::greedy())
        .unwrap();
    let spec_s = t0.elapsed().as_secs_f64();
    let spec_tps = new_tokens as f64 / spec_s;
    let (_, fp32_toks, fp32_tps) = &cached_by_mode[0];
    let (_, _, w4_tps) = &cached_by_mode[1];
    // the zero-quality-loss contract — always asserted
    assert_eq!(
        &spec_toks, fp32_toks,
        "speculative greedy output diverged from plain fp32 greedy output"
    );
    let acceptance = spec_stats.acceptance();
    println!(
        "specdec {spec_tps:>8.0} tok/s   plain fp32 {fp32_tps:>8.0} tok/s   \
         acceptance {acceptance:.2} ({}/{} drafts, {} rounds)",
        spec_stats.accepted,
        spec_stats.drafted,
        spec_stats.rounds,
    );
    // acceptance-gated perf assertion: speculation can only convert a
    // drafter speed advantage; gate when drafts land AND W4 decode
    // actually out-paces fp32 decode on this host (the paper's
    // memory-bound regime)
    let drafter_advantage = w4_tps / fp32_tps;
    let preconditions = acceptance >= 0.6 && drafter_advantage >= 1.5;
    if preconditions && spec_tps <= *fp32_tps {
        eprintln!(
            "PERF GATE FAILED: acceptance {acceptance:.2} ≥ 0.6 and W4 drafter \
             {drafter_advantage:.2}x faster, yet specdec {spec_tps:.0} ≤ plain {fp32_tps:.0} tok/s"
        );
        gate_ok = false;
    } else if !preconditions {
        println!(
            "(spec perf gate informational: acceptance {acceptance:.2}, W4/fp32 decode ratio \
             {drafter_advantage:.2} — gate arms at acceptance ≥ 0.6 and ratio ≥ 1.5)"
        );
    }
    rows.push(format!(
        r#"    {{"mode": "specdec-w4-drafter", "kv_cache_tps": {spec_tps:.1}, "acceptance": {acceptance:.3}, "drafted": {}, "accepted": {}, "rounds": {}, "drafter_advantage": {drafter_advantage:.3}}}"#,
        spec_stats.drafted,
        spec_stats.accepted,
        spec_stats.rounds,
    ));

    let json = format!(
        "{{\n  \"bench\": \"e2e_decode\",\n  \"model\": \"{model}\",\n  \
         \"prompt_len\": {prompt_len},\n  \"new_tokens\": {new_tokens},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_decode.json", &json).expect("write BENCH_decode.json");
    println!("\nwrote BENCH_decode.json");

    // -- full serving loop on the native backend (always available) --
    let requests = 24;
    println!("\n== e2e streaming serving, {model}, {requests} requests ==");
    serve_once(&NativeBackend::new(&dir), "native fp32", model, requests, false);
    serve_once(
        &NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(4, 32)),
        "native W4 packed",
        model,
        requests,
        false,
    );
    serve_once(&NativeBackend::new(&dir), "native specdec", model, requests, true);
    if !ttq_serve::artifacts_ready() {
        println!("\n(pjrt section skipped: AOT artifacts have no KV-cache variant;");
        println!(" run `make artifacts` for the full-batch pjrt eval pipeline)");
    }

    if !gate_ok {
        eprintln!("PERF GATE FAILED: see messages above");
        std::process::exit(1);
    }
}
