//! Kernel roofline profiler bench + perf gates.
//!
//! Run: `cargo bench --bench kernel_profile [-- --fast] [-- --threads N]`
//! — needs **no** artifacts (synthetic models). Measures the host's
//! achievable stream bandwidth and scalar FLOP throughput once
//! (`HostSpec::measured`), drives the serving mix of
//! `bench::throughput::default_scenarios` with the kernel profiler
//! attached, folds the per-scenario reports into one per-site
//! measured-vs-predicted roofline table, writes `BENCH_profile.json`
//! (schema: `docs/BENCHMARKS.md`) and exits non-zero when a gate fails:
//!
//! * **profiler overhead ≤ 2%** — profiler-on short-chat decode
//!   throughput must stay within 2% of profiler-off (best-of-2 on both
//!   sides, same discipline as the trace-recorder gate);
//! * **attribution ≥ 90%** — the named pooled sites must account for at
//!   least 90% of the pool's cumulative kernel wall time (no dark
//!   time). Quant-pack sites are timed serially outside the pool, so
//!   they are excluded from the pooled-coverage numerator.

use ttq_serve::bench::throughput::{default_scenarios, run_scenario, run_scenario_profiled};
use ttq_serve::linalg::pool::WorkerPool;
use ttq_serve::obs::profile::HostSpec;
use ttq_serve::obs::KernelKind;
use ttq_serve::util::cli::Args;

fn main() {
    let a = Args::from_env();
    let fast = a.has("fast");
    let threads = a.get_usize("threads", WorkerPool::default_threads()).max(1);
    let mut gate_ok = true;

    // -- host ceilings (one-shot microbenchmark, cached) ---------------
    let host = HostSpec::measured();
    println!(
        "== host roofline: {:.2} GB/s stream, {:.2} GFLOP/s scalar, balance {:.2} flop/byte ==",
        host.bw_gbps,
        host.gflops,
        host.balance()
    );

    // -- profiler-overhead gate (short-chat) ---------------------------
    // The per-dispatch site recording must be invisible in the serving
    // numbers: profiled short-chat decode throughput may trail the
    // profiler-off baseline by at most 2%. Best-of-2 damps timer noise.
    println!("\n== profiler overhead (short-chat, {threads} pool lanes, fast={fast}) ==");
    let chat = default_scenarios(fast).remove(0);
    let best_off = {
        let mut best: Option<f64> = None;
        for _ in 0..2 {
            let mut spec = chat.clone();
            spec.name = "short-chat-unprofiled".into();
            let r = run_scenario(&spec, threads).expect("unprofiled scenario");
            println!("{}", r.report());
            if best.map_or(true, |b| r.decode_tokens_per_sec > b) {
                best = Some(r.decode_tokens_per_sec);
            }
        }
        best.expect("two runs")
    };
    let best_on = {
        let mut best: Option<f64> = None;
        for _ in 0..2 {
            let mut spec = chat.clone();
            spec.name = "short-chat-profiled".into();
            let (r, _) = run_scenario_profiled(&spec, threads, &host).expect("profiled scenario");
            println!("{}", r.report());
            if best.map_or(true, |b| r.decode_tokens_per_sec > b) {
                best = Some(r.decode_tokens_per_sec);
            }
        }
        best.expect("two runs")
    };
    let overhead_ok = best_on >= 0.98 * best_off;
    println!(
        "profiler overhead: {best_on:.0} tok/s profiled vs {best_off:.0} tok/s unprofiled ({:+.2}%)",
        100.0 * (best_on / best_off - 1.0)
    );
    if !overhead_ok {
        eprintln!(
            "PERF GATE FAILED: kernel profiler costs more than 2% of short-chat decode \
             throughput ({best_on:.0} tok/s profiled < 0.98 × {best_off:.0} tok/s unprofiled)"
        );
        gate_ok = false;
    }

    // -- profiled scenario mix → merged roofline table -----------------
    println!("\n== profiled serving mix ==");
    let mut merged = None;
    for spec in default_scenarios(fast) {
        let (r, rep) = run_scenario_profiled(&spec, threads, &host).expect("scenario");
        println!("{}", r.report());
        match merged.as_mut() {
            None => merged = Some(rep),
            Some(m) => m.merge(&rep),
        }
    }
    let report = merged.expect("at least one scenario");

    println!("\n== per-site roofline (merged across scenarios) ==");
    println!(
        "{:<44} {:>7} {:>9} {:>9} {:>8} {:>8} {:>8} {:<7} {:>7}",
        "site", "calls", "wall_us", "gflops", "gbps", "flop/B", "pred_us", "bound", "ratio"
    );
    for s in &report.sites {
        println!(
            "{:<44} {:>7} {:>9} {:>9.2} {:>8.2} {:>8.3} {:>8.0} {:<7} {:>7.2}",
            s.site.label(),
            s.calls,
            s.measured_us,
            s.gflops,
            s.gbps,
            s.intensity,
            s.predicted_us,
            s.bound.name(),
            s.ratio
        );
    }

    // -- attribution-coverage gate -------------------------------------
    // Quant-pack runs serially outside the pool's kernel clock, so the
    // pooled-coverage numerator excludes it; the raw coverage (which
    // includes it) is reported alongside.
    let pooled_attr: u64 = report
        .sites
        .iter()
        .filter(|s| s.site.kind != KernelKind::QuantPack)
        .map(|s| s.measured_us)
        .sum();
    let pooled_coverage = if report.kernel_us == 0 {
        1.0
    } else {
        pooled_attr as f64 / report.kernel_us as f64
    };
    println!(
        "\nattribution: {pooled_attr} of {} pooled kernel us named ({:.1}%), \
         raw coverage {:.1}%, dropped {}",
        report.kernel_us,
        100.0 * pooled_coverage,
        100.0 * report.coverage(),
        report.dropped
    );
    let coverage_ok = pooled_coverage >= 0.90 && report.dropped == 0;
    if !coverage_ok {
        eprintln!(
            "PERF GATE FAILED: pooled kernel attribution {:.1}% < 90% (or {} dispatches \
             dropped) — a WorkerPool dispatch site is missing its KernelSite",
            100.0 * pooled_coverage,
            report.dropped
        );
        gate_ok = false;
    }

    // -- JSON artifact -------------------------------------------------
    let site_rows: Vec<String> = report
        .sites
        .iter()
        .map(|s| {
            format!(
                r#"    {{"site": "{}", "kind": "{}", "phase": "{}", "calls": {}, "flops": {}, "bytes": {}, "measured_us": {}, "gflops": {:.3}, "gbps": {:.3}, "intensity": {:.4}, "bound": "{}", "predicted_us": {:.2}, "ratio": {:.3}}}"#,
                s.site.label(),
                s.site.kind.name(),
                s.site.phase.name(),
                s.calls,
                s.flops,
                s.bytes,
                s.measured_us,
                s.gflops,
                s.gbps,
                s.intensity,
                s.bound.name(),
                s.predicted_us,
                s.ratio
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"kernel_profile\",\n  \"threads\": {threads},\n  \"fast\": {fast},\n  \
         \"host\": {{\"bw_gbps\": {:.3}, \"gflops\": {:.3}, \"balance\": {:.3}}},\n  \
         \"overhead\": {{\"profiled_tok_s\": {best_on:.1}, \"unprofiled_tok_s\": {best_off:.1}}},\n  \
         \"attribution\": {{\"pool_kernel_us\": {}, \"pooled_attributed_us\": {pooled_attr}, \
         \"pooled_coverage\": {pooled_coverage:.4}, \"raw_coverage\": {:.4}, \"dropped\": {}}},\n  \
         \"gates\": {{\"profiler_overhead_le_2pct\": {overhead_ok}, \"attribution_ge_90pct\": {coverage_ok}}},\n  \
         \"sites\": [\n{}\n  ]\n}}\n",
        host.bw_gbps,
        host.gflops,
        host.balance(),
        report.kernel_us,
        report.coverage(),
        report.dropped,
        site_rows.join(",\n")
    );
    std::fs::write("BENCH_profile.json", &json).expect("write BENCH_profile.json");
    println!("\nwrote BENCH_profile.json ({} sites)", report.sites.len());

    if !gate_ok {
        eprintln!("PERF GATE FAILED: see messages above");
        std::process::exit(1);
    }
}
