//! Quality-vs-speed Pareto bench + quality gates.
//!
//! Run: `cargo bench --bench quality_vs_speed [-- --fast] [-- --threads N]`
//! — needs **no** artifacts (synthetic models). llama.cpp KL
//! methodology: record the pristine fp32 model's logits once per
//! calibration-mismatch scenario, score every method of the ladder
//! (online TTQ, frozen AWQ, RTN, NF) against that recording, join
//! decode tokens/sec per execution format from the throughput harness,
//! write the Pareto table as `BENCH_quality.json`
//! (schema: `docs/BENCHMARKS.md`) and exit non-zero when a gate fails:
//!
//! * **ttq_beats_frozen_awq_under_mismatch** — in every
//!   calibrate-on-A-serve-B scenario, online TTQ's KL against fp32 must
//!   not exceed frozen AWQ's (the paper's test-time claim: online
//!   recalibration erases the calibration-mismatch penalty);
//! * **probe overhead** — short-chat throughput with the online quality
//!   probe firing (`probe_every` as configured below) must stay ≥ 95%
//!   of the unprobed run, best-of-2 per side.

use ttq_serve::bench::quality::{default_mismatch_scenarios, run_quality_scenario};
use ttq_serve::bench::throughput::{default_scenarios, run_scenario, run_scenario_probed};
use ttq_serve::linalg::pool::WorkerPool;
use ttq_serve::util::cli::Args;

/// Probe cadence for the overhead gate: sparse enough that a sampled
/// full-prefix fp32 replay amortizes below the 5% budget, frequent
/// enough to actually fire several times in the gate workload.
const GATE_PROBE_EVERY: usize = 48;

fn main() {
    let a = Args::from_env();
    let fast = a.has("fast");
    let threads = a.get_usize("threads", WorkerPool::default_threads()).max(1);
    let bits: Vec<u32> = if fast { vec![4] } else { vec![3, 4] };
    let mut gate_ok = true;

    // -- speed axis: short-chat decode tok/s per execution format ------
    println!("== quality vs speed, {threads} pool lanes, fast={fast} ==");
    let chat = default_scenarios(fast).remove(0);
    let mut fmt_spec = chat.clone();
    fmt_spec.name = "short-chat-fp32".into();
    fmt_spec.exec_bits = None;
    let fp32_run = run_scenario(&fmt_spec, threads).expect("fp32 format run");
    println!("{}", fp32_run.report());
    let fp32_tps = fp32_run.decode_tokens_per_sec;
    let mut tps_by_bits: Vec<(u32, f64)> = Vec::new();
    for &b in &bits {
        let mut s = chat.clone();
        s.name = format!("short-chat-w{b}");
        s.exec_bits = Some(b);
        let r = run_scenario(&s, threads).expect("packed format run");
        println!("{}", r.report());
        tps_by_bits.push((b, r.decode_tokens_per_sec));
    }

    // -- quality axis: calibration-mismatch scenarios ------------------
    let mut scenarios = Vec::new();
    let mut mismatch_ok = true;
    for spec in default_mismatch_scenarios() {
        let mut sq = run_quality_scenario(&spec, &bits, fast, threads).expect("quality scenario");
        for row in sq.rows.iter_mut() {
            row.tokens_per_sec = if row.bits >= 16 {
                fp32_tps
            } else {
                tps_by_bits
                    .iter()
                    .find(|(b, _)| *b == row.bits)
                    .map_or(0.0, |(_, t)| *t)
            };
        }
        sq.report().print();
        for &b in &bits {
            let (Some(ttq), Some(awq)) = (sq.row("ttq", b), sq.row("awq", b)) else {
                continue;
            };
            println!(
                "{} w{b}: ttq KL {:.4} vs frozen awq KL {:.4} ({})",
                sq.name,
                ttq.kl,
                awq.kl,
                if ttq.kl <= awq.kl { "ok" } else { "FAIL" }
            );
            if ttq.kl > awq.kl {
                eprintln!(
                    "QUALITY GATE FAILED: {} w{b}: online ttq KL {:.4} > frozen awq KL {:.4} \
                     under calibration mismatch",
                    sq.name, ttq.kl, awq.kl
                );
                mismatch_ok = false;
            }
        }
        scenarios.push(sq);
    }
    if !mismatch_ok {
        gate_ok = false;
    }

    // -- probe overhead gate -------------------------------------------
    // A fixed (not fast-shrunk) workload so the cadence math holds: the
    // sampled fp32 replay must cost < 5% of short-chat throughput.
    println!("\n== probe overhead (short-chat, probe_every={GATE_PROBE_EVERY}) ==");
    let mut gate_spec = chat.clone();
    gate_spec.requests = 48;
    gate_spec.max_new_tokens = 12;
    let best = |probed: bool| {
        let mut best_tps = 0.0f64;
        for _ in 0..2 {
            let mut s = gate_spec.clone();
            s.name = if probed { "short-chat-probed" } else { "short-chat-unprobed" }.into();
            let r = if probed {
                run_scenario_probed(&s, threads, GATE_PROBE_EVERY)
            } else {
                run_scenario(&s, threads)
            }
            .expect("overhead scenario");
            println!("{}", r.report());
            best_tps = best_tps.max(r.tokens_per_sec);
        }
        best_tps
    };
    let unprobed_tps = best(false);
    let probed_tps = best(true);
    let probe_ratio = if unprobed_tps > 0.0 {
        probed_tps / unprobed_tps
    } else {
        1.0
    };
    let probe_ok = probe_ratio >= 0.95;
    println!(
        "probe overhead: {probed_tps:.0} tok/s probed vs {unprobed_tps:.0} tok/s unprobed \
         ({:+.2}%)",
        100.0 * (probe_ratio - 1.0)
    );
    if !probe_ok {
        eprintln!(
            "PERF GATE FAILED: quality probe costs more than 5% of short-chat throughput \
             ({probed_tps:.0} tok/s probed < 0.95 × {unprobed_tps:.0} tok/s unprobed)"
        );
        gate_ok = false;
    }

    // -- JSON artifact -------------------------------------------------
    let scenario_json: Vec<String> = scenarios
        .iter()
        .map(|s| format!("    {}", s.to_json()))
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"quality_vs_speed\",\n  \"threads\": {threads},\n  \"fast\": {fast},\n  \
         \"gates\": {{\"ttq_beats_frozen_awq_under_mismatch\": {mismatch_ok}, \
         \"probe_overhead_le_5pct\": {probe_ok}}},\n  \
         \"probe\": {{\"probe_every\": {GATE_PROBE_EVERY}, \"unprobed_tps\": {unprobed_tps:.1}, \
         \"probed_tps\": {probed_tps:.1}, \"ratio\": {probe_ratio:.4}}},\n  \
         \"scenarios\": [\n{}\n  ]\n}}\n",
        scenario_json.join(",\n")
    );
    std::fs::write("BENCH_quality.json", &json).expect("write BENCH_quality.json");
    println!("\nwrote BENCH_quality.json ({} scenarios)", scenarios.len());

    if !gate_ok {
        eprintln!("QUALITY GATE FAILED: see messages above");
        std::process::exit(1);
    }
}
