//! Hot-path microbenchmarks for the quant library (L3).
//!
//! Run: `cargo bench --bench quant_hot_path`
//!
//! Reports element-throughput of the QDQ inner loop, the AWQ scaling
//! path, GPTQ (the O(d³) baseline the paper contrasts), packing, and
//! the fused packed-dequant matmul vs a dense f32 matmul — the CPU
//! stand-in for `marlin_gemm` vs FP16 GEMV.

use ttq_serve::linalg::{Mat, Rng};
use ttq_serve::quant::{
    awq_quantize, diag_from_x, gptq_quantize, lowrank_init, pack,
    packed_matmul, rtn_quantize, rtn_quantize_int, LayerStats, MethodSpec,
    QuantSpec,
};
use ttq_serve::util::benchkit::{black_box, Bencher};

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(1);

    // paper-ish layer dims at our scale: d'=512, d=512
    let (dout, din, t) = (512usize, 512usize, 16usize);
    let w = Mat::randn(dout, din, &mut rng);
    let x = Mat::randn(din, t, &mut rng);
    let n = (dout * din) as f64;

    println!("-- RTN groupwise QDQ (Eq. 1) --");
    for (bits, group) in [(2u32, 32usize), (3, 32), (4, 32), (4, 128), (8, 32)] {
        let spec = QuantSpec::new(bits, group);
        b.run_with_items(
            &format!("rtn_qdq q={bits} g={group} {dout}x{din}"),
            n,
            || rtn_quantize(black_box(&w), &spec),
        );
    }

    println!("-- AWQ scaled QDQ (Eq. 19-20) --");
    let spec = QuantSpec::new(4, 32);
    b.run_with_items(&format!("awq_diag d={din} T={t}"), (din * t) as f64, || {
        diag_from_x(black_box(&x), 2.0, 0.4, 0.5)
    });
    let d = diag_from_x(&x, 2.0, 0.4, 0.5);
    b.run_with_items(&format!("awq_quantize {dout}x{din}"), n, || {
        awq_quantize(black_box(&w), &d, &spec)
    });

    println!("-- dispatch overhead: direct call vs trait object (4-bit RTN) --");
    // The registry redesign must cost nothing on the hot path: one
    // virtual call per *matrix* (256K elements here), not per element.
    let spec4 = QuantSpec::new(4, 32);
    let method = MethodSpec::parse("rtn").expect("registry has rtn");
    let stats = LayerStats::default();
    b.run_with_items(&format!("rtn direct fn {dout}x{din}"), n, || {
        rtn_quantize(black_box(&w), &spec4)
    });
    b.run_with_items(&format!("rtn dyn Quantizer {dout}x{din}"), n, || {
        method
            .quantizer()
            .quantize(black_box(&w), &stats, &spec4)
            .expect("rtn needs no stats")
    });

    println!("-- low-rank init (App. E) --");
    for r in [4usize, 16] {
        b.run(&format!("lowrank_init r={r} {dout}x{din}"), || {
            lowrank_init(black_box(&w), r)
        });
    }

    println!("-- GPTQ baseline (App. C, O(d^3)) --");
    let wg = Mat::randn(128, 128, &mut rng);
    let xg = Mat::randn(128, 256, &mut rng);
    let c = xg.matmul_bt(&xg);
    Bencher::quick().run("gptq 128x128", || {
        gptq_quantize(black_box(&wg), &c, &QuantSpec::new(4, 32), 0.01)
    });

    println!("-- packed int matmul vs dense f32 (marlin analogue) --");
    let xt = Mat::randn(din, 1, &mut rng); // decode: single token
    let dense_flops = (dout * din) as f64;
    b.run_with_items("dense f32 matvec", dense_flops, || {
        black_box(&w).matmul(black_box(&xt))
    });
    for bits in [2u32, 4] {
        let p = pack(&rtn_quantize_int(&w, &QuantSpec::new(bits, 32)));
        b.run_with_items(&format!("packed q={bits} dequant-matvec"), dense_flops, || {
            packed_matmul(black_box(&p), black_box(&xt))
        });
    }
}
