//! Tables 4-8 regeneration: the GPU roofline tables for all five cards
//! plus a CPU-measured cross-check of the traffic mechanism.
//!
//! Run: `cargo bench --bench runtime_tables`
//!
//! The roofline model predicts quantized>FP16 because weight *traffic*
//! shrinks; on CPU the same mechanism appears as the packed matvec
//! touching ~bits/16 of the f32 bytes. We measure that ratio here so
//! the simulated tables rest on an observed mechanism, not just specs.

use ttq_serve::bench::tables_runtime::all_runtime_tables;
use ttq_serve::linalg::{Mat, Rng};
use ttq_serve::quant::{pack, rtn_quantize_int, weight_bytes, QuantSpec};
use ttq_serve::util::benchkit::{black_box, Bencher};

fn main() {
    // 1. the five paper tables from the roofline model
    for t in all_runtime_tables() {
        t.print();
    }

    // 2. observed mechanism at CPU scale: bytes touched per matvec
    println!("\n== CPU traffic cross-check (mechanism validation) ==");
    let mut rng = Rng::new(3);
    let (dout, din) = (2048usize, 1024usize);
    let w = Mat::randn(dout, din, &mut rng);
    let x = Mat::randn(din, 1, &mut rng);
    let f32_bytes = dout * din * 4;
    println!("f32 weight bytes: {f32_bytes}");
    let b = Bencher::default();
    let t_dense = b.run_with_items("dense f32 matvec 2048x1024", (dout * din) as f64, || {
        black_box(&w).matmul(black_box(&x))
    });
    for bits in [2u32, 3, 4, 5] {
        let p = pack(&rtn_quantize_int(&w, &QuantSpec::new(bits, 32)));
        let wb = weight_bytes(&p);
        let t_packed = b.run_with_items(
            &format!("packed q={bits} matvec 2048x1024"),
            (dout * din) as f64,
            || ttq_serve::quant::packed_matmul(black_box(&p), black_box(&x)),
        );
        println!(
            "   q={bits}: weight bytes {wb} ({:.1}% of f32), packed/dense time {:.2}",
            100.0 * wb as f64 / f32_bytes as f64,
            t_packed.median().as_secs_f64() / t_dense.median().as_secs_f64(),
        );
    }
    println!(
        "\nTraffic ratios match the q/32 packing law the roofline tables use\n\
         (on GPU the time ratio tracks the byte ratio because GEMV is\n\
         bandwidth-bound; CPU adds unpack ALU cost, so time > byte ratio)."
    );
}
