//! Multi-scenario serving-throughput bench + perf gates.
//!
//! Run: `cargo bench --bench serve_throughput [-- --fast] [-- --threads N]`
//! — needs **no** artifacts (synthetic models). Drives the coordinator
//! through the workload mix of `bench::throughput::default_scenarios`
//! (short-prompt chat, long-prefill summarization, mixed-domain drift,
//! specdec-heavy, W4-vs-fp32 decode) plus a 1/2/N worker-pool thread
//! sweep, times the pooled kernel against the retained scoped-thread
//! spawn-per-call baseline, writes `BENCH_throughput.json` (schema:
//! `docs/BENCHMARKS.md`) and exits non-zero when a gate fails:
//!
//! * **pooled ≥ scoped** — the persistent pool must not lose to the old
//!   spawn-per-matmul kernel on a decode-shaped call stream (this is the
//!   whole point of the pool);
//! * **W4 decode ≥ fp32 decode at ≥ 2 threads** — packed decode must
//!   out-run dense decode in the memory-bound phase. Measured on the
//!   largest synthetic model so the fp32 weights actually stream from
//!   memory; on a single-lane host the gate has no parallel traffic to
//!   measure and reports informationally instead;
//! * **SIMD ≥ scalar per kernel class** — the runtime-selected vector
//!   microkernels (`linalg::simd`) must not lose to the scalar path on
//!   either the fp32 GEMM or the packed-W4 dequant-dot. Only armed when
//!   a vector ISA is actually selected; under `TTQ_FORCE_SCALAR` or on
//!   hosts with no vector support the rows are informational.

use ttq_serve::bench::throughput::{
    default_scenarios, kernel_baseline, run_scenario, run_scenario_traced, simd_baseline,
};
use ttq_serve::coordinator::DEFAULT_TRACE_CAPACITY;
use ttq_serve::linalg::pool::WorkerPool;
use ttq_serve::linalg::simd::{select, Isa};
use ttq_serve::util::cli::Args;

fn main() {
    let a = Args::from_env();
    let fast = a.has("fast");
    // same sizing policy as every NativeBackend default — one source
    let threads = a.get_usize("threads", WorkerPool::default_threads()).max(1);
    let mut gate_ok = true;

    // -- scenario mix at the full thread count ------------------------
    println!("== serve throughput, {threads} pool lanes, fast={fast} ==");
    let mut results = Vec::new();
    for spec in default_scenarios(fast) {
        let r = run_scenario(&spec, threads).expect("scenario");
        println!("{}", r.report());
        results.push(r);
    }

    // -- worker-pool thread sweep on the chat load --------------------
    println!("\n== thread sweep (short-chat) ==");
    let chat = default_scenarios(fast).remove(0);
    let mut sweep = vec![1usize, 2, threads];
    sweep.sort_unstable();
    sweep.dedup();
    for t in sweep {
        let mut spec = chat.clone();
        spec.name = format!("short-chat@{t}t");
        let r = run_scenario(&spec, t).expect("sweep scenario");
        println!("{}", r.report());
        results.push(r);
    }

    // -- span-recorder overhead gate (short-chat) ---------------------
    // The trace ring must be invisible in the serving numbers: traced
    // short-chat decode throughput may trail the disabled-recorder
    // baseline by at most 2%. Best-of-2 on both sides damps timer noise.
    println!("\n== span-recorder overhead (short-chat) ==");
    let best = |traced: bool| {
        let mut best_r = None;
        for _ in 0..2 {
            let mut spec = chat.clone();
            spec.name = if traced { "short-chat-traced" } else { "short-chat-untraced" }.into();
            let cap = if traced { DEFAULT_TRACE_CAPACITY } else { 0 };
            let r = run_scenario_traced(&spec, threads, cap).expect("overhead scenario");
            let cur = best_r
                .as_ref()
                .map_or(f64::MIN, |b: &ttq_serve::bench::throughput::ScenarioResult| {
                    b.decode_tokens_per_sec
                });
            if r.decode_tokens_per_sec > cur {
                best_r = Some(r);
            }
        }
        best_r.expect("two runs")
    };
    let untraced = best(false);
    let traced = best(true);
    println!("{}", untraced.report());
    println!("{}", traced.report());
    let overhead_ok = traced.decode_tokens_per_sec >= 0.98 * untraced.decode_tokens_per_sec;
    println!(
        "recorder overhead: {:.0} tok/s traced vs {:.0} tok/s untraced ({:+.2}%)",
        traced.decode_tokens_per_sec,
        untraced.decode_tokens_per_sec,
        100.0 * (traced.decode_tokens_per_sec / untraced.decode_tokens_per_sec - 1.0)
    );
    if !overhead_ok {
        eprintln!(
            "PERF GATE FAILED: span recorder costs more than 2% of short-chat decode \
             throughput ({:.0} tok/s traced < 0.98 × {:.0} tok/s untraced)",
            traced.decode_tokens_per_sec, untraced.decode_tokens_per_sec
        );
        gate_ok = false;
    }
    results.push(untraced);
    results.push(traced);

    // -- pooled vs scoped-thread kernel baseline ----------------------
    println!("\n== pooled vs scoped-thread kernel (decode-shaped stream) ==");
    let base = kernel_baseline(threads, fast);
    println!(
        "pooled {:.2} Gflop/s   scoped {:.2} Gflop/s   speedup {:.2}x",
        base.pooled_gflops, base.scoped_gflops, base.speedup
    );
    // On a single lane both kernels run serial and the comparison is
    // pure timer noise — the gate only arms where the pool's dispatch
    // amortization can actually show up.
    if threads >= 2 && base.pooled_gflops < base.scoped_gflops {
        eprintln!(
            "PERF GATE FAILED: pooled kernel {:.2} Gflop/s < scoped-thread baseline {:.2} Gflop/s",
            base.pooled_gflops, base.scoped_gflops
        );
        gate_ok = false;
    } else if threads < 2 {
        println!("(pooled-vs-scoped gate informational: single-lane host)");
    }

    // -- scalar vs SIMD instruction-level baseline --------------------
    // Single-lane pools on both sides so the comparison isolates the
    // instruction-level dispatch (`linalg::simd`), not pool scheduling.
    println!("\n== scalar vs SIMD inner kernels ({}) ==", select().name());
    let simd_rows = simd_baseline(fast);
    let vector_selected = select() != Isa::Scalar;
    let mut simd_gate: Option<bool> = None;
    for r in &simd_rows {
        println!(
            "{:<10} {:>8.2} Gflop/s ({})   {:>8.2} Gflop/s (scalar)   speedup {:.2}x",
            r.kernel, r.simd_gflops, r.isa, r.scalar_gflops, r.speedup
        );
    }
    if vector_selected {
        let ok = simd_rows.iter().all(|r| r.speedup >= 1.0);
        simd_gate = Some(ok);
        if !ok {
            for r in simd_rows.iter().filter(|r| r.speedup < 1.0) {
                eprintln!(
                    "PERF GATE FAILED: {} {} kernel {:.2} Gflop/s < scalar {:.2} Gflop/s",
                    r.isa, r.kernel, r.simd_gflops, r.scalar_gflops
                );
            }
            gate_ok = false;
        }
    } else {
        println!("(SIMD-vs-scalar gate informational: scalar ISA selected)");
    }

    // -- W4 vs fp32 decode gate ---------------------------------------
    let fp32 = results.iter().find(|r| r.name == "fp32-decode");
    let w4 = results.iter().find(|r| r.name == "w4-decode");
    let mut w4_gate: Option<bool> = None;
    if let (Some(fp32), Some(w4)) = (fp32, w4) {
        println!(
            "\nW4 decode {:.0} tok/s vs fp32 decode {:.0} tok/s at {threads} threads",
            w4.decode_tokens_per_sec, fp32.decode_tokens_per_sec
        );
        if threads >= 2 {
            let ok = w4.decode_tokens_per_sec >= fp32.decode_tokens_per_sec;
            w4_gate = Some(ok);
            if !ok {
                eprintln!(
                    "PERF GATE FAILED: packed-W4 decode {:.0} tok/s < fp32 decode {:.0} tok/s \
                     at {threads} (≥2) threads",
                    w4.decode_tokens_per_sec, fp32.decode_tokens_per_sec
                );
                gate_ok = false;
            }
        } else {
            println!("(W4-vs-fp32 gate informational: single-lane host, no parallel decode traffic)");
        }
    }

    // -- JSON artifact -------------------------------------------------
    let rows: Vec<String> = results.iter().map(|r| format!("    {}", r.to_json())).collect();
    let simd_json: Vec<String> =
        simd_rows.iter().map(|r| format!("    {}", r.to_json())).collect();
    let json = format!(
        "{{\n  \"bench\": \"serve_throughput\",\n  \"threads\": {threads},\n  \"fast\": {fast},\n  \
         \"kernel_baseline\": {{\"threads\": {}, \"pooled_gflops\": {:.3}, \"scoped_gflops\": {:.3}, \"speedup\": {:.3}}},\n  \
         \"simd_baseline\": [\n{}\n  ],\n  \
         \"gates\": {{\"pooled_ge_scoped\": {}, \"w4_ge_fp32_decode\": {}, \"simd_ge_scalar\": {}, \"trace_overhead_le_2pct\": {overhead_ok}}},\n  \
         \"scenarios\": [\n{}\n  ]\n}}\n",
        base.threads,
        base.pooled_gflops,
        base.scoped_gflops,
        base.speedup,
        simd_json.join(",\n"),
        base.pooled_gflops >= base.scoped_gflops,
        w4_gate.map_or("null".to_string(), |b| b.to_string()),
        simd_gate.map_or("null".to_string(), |b| b.to_string()),
        rows.join(",\n")
    );
    std::fs::write("BENCH_throughput.json", &json).expect("write BENCH_throughput.json");
    println!("\nwrote BENCH_throughput.json ({} scenarios)", results.len());

    if !gate_ok {
        eprintln!("PERF GATE FAILED: see messages above");
        std::process::exit(1);
    }
}
