//! Eq. (3) validation: the online-quantization overhead ratio
//! ρ = O[dT + 3d′d] / O[d′dT] must vanish as d′ and T grow.
//!
//! Run: `cargo bench --bench ttq_overhead`
//!
//! We *measure* the overhead on CPU — time(TTQ find_params + quantize)
//! over time(projection) — and print it against the analytic ρ. The
//! shape to reproduce: measured overhead → 0 with d′ and T, and the
//! analytic curve tracks the measurement within a small factor.

use std::time::Instant;

use ttq_serve::linalg::{Mat, Rng};
use ttq_serve::quant::{
    diag_from_x, overhead_ratio, ttq_quantize, QuantSpec, TtqHyper,
};
use ttq_serve::util::benchkit::black_box;

fn time_it<T>(iters: usize, mut f: impl FnMut() -> T) -> f64 {
    // warmup
    black_box(f());
    let t0 = Instant::now();
    for _ in 0..iters {
        black_box(f());
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let spec = QuantSpec::new(4, 32);
    let hp = TtqHyper::default();
    println!(
        "{:>6} {:>6} {:>6} | {:>12} {:>12} {:>10} {:>10}",
        "d'", "d", "T", "t_proj (us)", "t_quant (us)", "measured", "analytic"
    );
    let mut rng = Rng::new(7);
    let mut rows = Vec::new();
    for (dout, din, t) in [
        (64usize, 64usize, 4usize),
        (128, 128, 8),
        (256, 256, 16),
        (512, 512, 32),
        (1024, 512, 64),
        (1024, 1024, 128),
    ] {
        let w = Mat::randn(dout, din, &mut rng);
        let x = Mat::randn(din, t, &mut rng);
        let iters = (64 * 64 * 16 / (dout.min(512) * t)).clamp(2, 32);
        let t_proj = time_it(iters, || w.matmul(&x));
        let t_quant = time_it(iters, || {
            // find_params path: diag + scaled QDQ (no matmul)
            let d = diag_from_x(&x, hp.p, hp.lam, hp.alpha);
            black_box(d.len());
            ttq_quantize(&w, &x, &spec, &hp)
        });
        let measured = t_quant / t_proj;
        let analytic = overhead_ratio(dout, din, t);
        println!(
            "{dout:>6} {din:>6} {t:>6} | {:>12.1} {:>12.1} {measured:>10.3} {analytic:>10.4}",
            t_proj * 1e6,
            t_quant * 1e6
        );
        rows.push((measured, analytic));
    }
    // The reproduction claim: both curves decrease monotonically-ish
    // and the final overhead is small.
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    println!(
        "\noverhead shrank {0:.1}x measured ({1:.3} -> {2:.3}); analytic {3:.1}x",
        first.0 / last.0,
        first.0,
        last.0,
        first.1 / last.1
    );
    assert!(
        last.0 < first.0,
        "Eq. 3 violated: overhead did not shrink with scale"
    );
    println!("Eq. 3 reproduced: online quantization overhead vanishes with d', T.");
}
