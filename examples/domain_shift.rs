//! Domain shift: the experiment motivating the whole paper (Fig. 1).
//!
//! Offline AWQ is calibrated once on domain A; traffic then arrives
//! from domain B. TTQ recalibrates from the live prompt and is immune.
//! This example runs the full 3×3 calibration×eval matrix and prints
//! the diagonal-vs-off-diagonal gap.
//!
//! ```bash
//! cargo run --release --example domain_shift
//! ```
//!
//! Runs on any backend; the diagonal-vs-off-diagonal *gap* is only
//! meaningful with trained artifacts (`make artifacts`).

use anyhow::Result;
use ttq_serve::backend::default_backend;
use ttq_serve::corpus::LM_DOMAINS;
use ttq_serve::eval::{EvalConfig, Evaluator, MethodSpec};
use ttq_serve::quant::QuantSpec;

fn main() -> Result<()> {
    let backend = default_backend()?;
    let model = "qwen-mini";
    let mut ev = Evaluator::new(backend.as_ref(), model)?;
    println!("execution backend: {}", backend.name());
    let cfg = EvalConfig {
        spec: QuantSpec::new(3, 32),
        eval_batches: 6,
        calib_batches: 8,
        ..Default::default()
    };

    println!("AWQ 3-bit perplexity, calibration domain × eval domain ({model}):\n");
    print!("{:>12}", "calib\\eval");
    for d in LM_DOMAINS {
        print!("{d:>10}");
    }
    println!();
    let mut diag = 0.0;
    let mut off = 0.0;
    for calib in LM_DOMAINS {
        print!("{calib:>12}");
        for eval_d in LM_DOMAINS {
            let p = ev.perplexity(&MethodSpec::awq(calib), eval_d, &cfg)?;
            if calib == eval_d {
                diag += p;
            } else {
                off += p / 2.0;
            }
            print!("{p:>10.2}");
        }
        println!();
    }
    print!("{:>12}", "TTQ (r=0)");
    let mut ttq_avg = 0.0;
    for eval_d in LM_DOMAINS {
        let p = ev.perplexity(&MethodSpec::ttq(0), eval_d, &cfg)?;
        ttq_avg += p / 3.0;
        print!("{p:>10.2}");
    }
    println!("   <- zero calibration data");

    println!(
        "\nmatched-calibration AWQ avg : {:.2}\nmismatched AWQ avg          : {:.2}\nTTQ avg (no calibration)    : {:.2}",
        diag / 3.0,
        off / 3.0,
        ttq_avg
    );
    println!("\nThe off-diagonal penalty is the domain-shift risk the paper's");
    println!("Fig. 1(a) describes; TTQ (Fig. 1b) tracks the matched diagonal.");
    Ok(())
}
