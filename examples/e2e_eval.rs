//! End-to-end validation driver (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Proves the layers compose on a real small workload:
//!
//!   1. loads the models (trained AOT weights when `make artifacts` has
//!      run, deterministic synthetic weights otherwise),
//!   2. runs full-precision perplexity on all three LM eval domains,
//!   3. quantizes with the paper's methods — including the fused
//!      single-pass TTQ path — and re-evaluates,
//!   4. serves a batched request stream through the coordinator,
//!   5. prints a scoreboard + the training loss curves recorded at
//!      artifact build time (when available).
//!
//! ```bash
//! cargo run --release --example e2e_eval
//! ```

use std::time::Instant;

use anyhow::Result;
use ttq_serve::backend::default_backend;
use ttq_serve::coordinator::{ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS, LM_DOMAINS};
use ttq_serve::eval::{EvalConfig, Evaluator, MethodSpec};
use ttq_serve::quant::QuantSpec;

fn main() -> Result<()> {
    let t_start = Instant::now();
    let backend = default_backend()?;
    println!("== E2E driver: {} backend ==\n", backend.name());

    // 1. training provenance (loss curves dumped by the build, if any)
    for name in ["opt-micro", "qwen-micro", "gemma-micro"] {
        let p = ttq_serve::artifacts_dir().join(format!("ckpt/{name}.loss.json"));
        if let Ok(s) = std::fs::read_to_string(p) {
            let v = ttq_serve::util::json::Value::parse(&s).unwrap();
            let losses = v.as_arr().unwrap();
            let first = losses.first().and_then(|x| x.as_f64()).unwrap_or(0.0);
            let last = losses.last().and_then(|x| x.as_f64()).unwrap_or(0.0);
            println!(
                "train[{name}]: {} steps, loss {first:.3} -> {last:.3}",
                losses.len()
            );
        }
    }

    // 2+3. quantized perplexity scoreboard on one model
    let model = "qwen-mini";
    let mut ev = Evaluator::new(backend.as_ref(), model)?;
    let cfg = EvalConfig {
        spec: QuantSpec::new(3, 32),
        eval_batches: 6,
        calib_batches: 8,
        ..Default::default()
    };
    println!("\n3-bit perplexity scoreboard, {model}:");
    println!("{:<24} {:>8} {:>8} {:>8}", "method", "wt2s", "ptbs", "c4s");
    for m in [
        MethodSpec::fp(),
        MethodSpec::rtn(),
        MethodSpec::awq("c4s"),
        MethodSpec::gptq("c4s"),
        MethodSpec::nf_auto(), // NF at the scoreboard's 3-bit spec
        MethodSpec::prune(0.5),
        MethodSpec::ttq(0),
        MethodSpec::ttq(16),
    ] {
        print!("{:<24}", m.label());
        for d in LM_DOMAINS {
            let p = ev.perplexity(&m, d, &cfg)?;
            print!(" {p:>8.2}");
        }
        println!();
    }

    // fused single-pass TTQ path (Fig. 1b) vs the two-pass path
    let seq = ev.weights.manifest.config.seq;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let toks = s.batch(4, seq);
    let (fused, c) = ev.nll_fused_ttq(&toks, 4, 3)?;
    println!(
        "\nfused TTQ kernel path (single pass, q=3): per-token nll {:.4}",
        fused / c
    );

    // 4. serve a streamed request batch through the decode engine
    let mut scfg = ServerConfig::new("qwen-micro");
    scfg.max_new_tokens = 4;
    let mut server = Server::new(backend.as_ref(), scfg)?;
    let prompt_len = server.max_seq() / 2;
    let mut stream = CorpusStream::new("wt2s", Split::Eval);
    let mut done = 0usize;
    let mut count = |evs: &[ServeEvent]| {
        done += evs
            .iter()
            .filter(|e| matches!(e, ServeEvent::Done { .. }))
            .count();
    };
    for _ in 0..32 {
        let mut toks = vec![BOS; prompt_len];
        for t in toks.iter_mut().skip(1) {
            *t = stream.next_token();
        }
        server.submit(toks);
        count(&server.step()?);
    }
    count(&server.drain()?);
    println!("\nserved batched stream: {}", server.metrics.summary());
    assert_eq!(done, 32);

    println!(
        "\nE2E complete in {:.1}s on the {} backend — fused TTQ path, \
         model forward, and quant+serve pipeline verified.",
        t_start.elapsed().as_secs_f64(),
        backend.name()
    );
    Ok(())
}
