//! Quickstart: quantize a model three ways and compare perplexity.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the public API end to end: load the PJRT runtime, bind an
//! evaluator to a model's artifacts, and measure RTN vs offline-AWQ vs
//! online-TTQ at 3 bits — the paper's core comparison in ~40 lines.

use anyhow::Result;
use ttq_serve::eval::{EvalConfig, Evaluator, MethodSpec};
use ttq_serve::quant::QuantSpec;
use ttq_serve::runtime::Runtime;

fn main() -> Result<()> {
    if !ttq_serve::artifacts_ready() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&ttq_serve::artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());

    let model = "qwen-micro";
    let mut ev = Evaluator::new(&rt, model)?;
    println!(
        "model {model}: {} params, {} quantizable linears\n",
        ev.weights.param_count(),
        ev.weights.manifest.linears.len()
    );

    let cfg = EvalConfig {
        spec: QuantSpec::new(3, 32), // 3-bit, groupsize 32
        eval_batches: 6,
        calib_batches: 8,
        ..Default::default()
    };

    let methods = [
        MethodSpec::fp(),
        MethodSpec::rtn(),
        MethodSpec::awq("c4s"),
        MethodSpec::ttq(0),
        MethodSpec::ttq(16),
    ];
    println!("3-bit perplexity on the wt2s eval stream:");
    for m in methods {
        let ppl = ev.perplexity(&m, "wt2s", &cfg)?;
        println!("  {:<22} {ppl:8.2}", m.label());
    }
    println!("\nExpected ordering: FP < TTQ(r=16) <= TTQ(r=0) <= AWQ < RTN");
    Ok(())
}
