//! Quickstart: quantize a model three ways and compare perplexity.
//!
//! ```bash
//! cargo run --release --example quickstart        # native backend
//! make artifacts && cargo run --release --example quickstart  # PJRT
//! ```
//!
//! Walks the public API end to end: pick an execution backend, bind an
//! evaluator to a model, and measure RTN vs offline-AWQ vs online-TTQ
//! at 3 bits — the paper's core comparison in ~40 lines. Without
//! `make artifacts` the native backend runs deterministic synthetic
//! (untrained) weights: the pipeline is identical, the absolute
//! perplexities are not paper numbers.

use anyhow::Result;
use ttq_serve::backend::default_backend;
use ttq_serve::eval::{EvalConfig, Evaluator, MethodSpec};
use ttq_serve::quant::QuantSpec;

fn main() -> Result<()> {
    let backend = default_backend()?;
    println!("execution backend: {}", backend.name());

    let model = "qwen-micro";
    let mut ev = Evaluator::new(backend.as_ref(), model)?;
    println!(
        "model {model}: {} params, {} quantizable linears\n",
        ev.weights.param_count(),
        ev.weights.manifest.linears.len()
    );

    let cfg = EvalConfig {
        spec: QuantSpec::new(3, 32), // 3-bit, groupsize 32
        eval_batches: 6,
        calib_batches: 8,
        ..Default::default()
    };

    let methods = [
        MethodSpec::fp(),
        MethodSpec::rtn(),
        MethodSpec::awq("c4s"),
        MethodSpec::ttq(0),
        MethodSpec::ttq(16),
    ];
    println!("3-bit perplexity on the wt2s eval stream:");
    for m in methods {
        let ppl = ev.perplexity(&m, "wt2s", &cfg)?;
        println!("  {:<22} {ppl:8.2}", m.label());
    }
    println!("\nExpected ordering (trained artifacts): FP < TTQ(r=16) <= TTQ(r=0) <= AWQ < RTN");
    if !ttq_serve::artifacts_ready() {
        println!("(synthetic untrained weights — ordering not meaningful, pipeline is)");
    }
    Ok(())
}
