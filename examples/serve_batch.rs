//! Serving demo: continuous-batching decode + online self-calibration
//! under shifting traffic.
//!
//! Drives the decode engine with a bursty two-domain workload and
//! prints the metrics a serving operator would watch: batch fill,
//! prefill/decode throughput, latency, KV-cache occupancy, and how many
//! weight generations the TTQ calibrator created (it should requantize
//! on the traffic shift — possibly mid-generation — then settle).
//!
//! ```bash
//! cargo run --release --example serve_batch
//! ```
//!
//! Works with zero artifacts: the native backend serves deterministic
//! synthetic weights through the very same loop.

use std::time::Duration;

use anyhow::Result;
use ttq_serve::backend::default_backend;
use ttq_serve::coordinator::{BatchPolicy, ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::quant::QuantSpec;

fn main() -> Result<()> {
    let backend = default_backend()?;
    println!("execution backend: {}\n", backend.name());
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.spec = QuantSpec::new(4, 32);
    cfg.policy = BatchPolicy {
        buckets: vec![1, 4],
        linger: Duration::from_millis(1),
    };
    cfg.max_new_tokens = 6;
    let mut server = Server::new(backend.as_ref(), cfg)?;
    let prompt_len = server.max_seq() / 2;

    let phases = [("ptbs", 24usize), ("c4s", 24), ("ptbs", 12)];
    println!("traffic: {phases:?} (requests per phase, prompt_len {prompt_len})\n");
    for (domain, n) in phases {
        let mut stream = CorpusStream::new(domain, Split::Eval);
        let gen_before = server.weight_generation();
        let (mut tokens, mut done) = (0usize, 0usize);
        let mut count = |evs: &[ServeEvent]| {
            for e in evs {
                match e {
                    ServeEvent::Token { .. } => tokens += 1,
                    ServeEvent::Done { .. } => done += 1,
                }
            }
        };
        for i in 0..n {
            let mut toks = vec![BOS; prompt_len];
            for t in toks.iter_mut().skip(1) {
                *t = stream.next_token();
            }
            server.submit(toks);
            // bursty arrivals: drive the engine every few submissions
            if i % 3 == 2 {
                count(&server.step()?);
            }
        }
        count(&server.drain()?);
        println!(
            "phase {domain:>5}: {done}/{n} done, {tokens} streamed tokens, \
             weight generations {} -> {}",
            gen_before,
            server.weight_generation()
        );
    }

    println!("\n{}", server.metrics.summary());
    let cs = server.cache_stats();
    println!(
        "kv cache: {} slots, high-water {}/{} tokens",
        cs.slots, cs.high_water_tokens, cs.capacity_tokens
    );
    println!(
        "\nNote the generation bumps at phase boundaries: the calibrator\n\
         detected the activation-statistics drift and requantized — the\n\
         paper's on-device self-calibration (Fig. 1b), now continuous\n\
         across generated tokens, not just across prompts."
    );
    Ok(())
}
