//! Serving demo: shape-bucketed dynamic batching + online
//! self-calibration under shifting traffic.
//!
//! Drives the coordinator with a bursty two-domain workload and prints
//! the metrics a serving operator would watch: batch fill, throughput,
//! latency, and how many weight generations the TTQ calibrator created
//! (it should requantize on the traffic shift, then settle).
//!
//! ```bash
//! cargo run --release --example serve_batch
//! ```
//!
//! Works with zero artifacts: the native backend serves deterministic
//! synthetic weights through the very same loop.

use std::time::{Duration, Instant};

use anyhow::Result;
use ttq_serve::backend::default_backend;
use ttq_serve::coordinator::{BatchPolicy, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::quant::QuantSpec;

fn main() -> Result<()> {
    let backend = default_backend()?;
    println!("execution backend: {}\n", backend.name());
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.spec = QuantSpec::new(4, 32);
    cfg.policy = BatchPolicy {
        buckets: vec![1, 4],
        linger: Duration::from_millis(1),
    };
    let mut server = Server::new(backend.as_ref(), cfg)?;
    let seq = server.seq();

    let phases = [("ptbs", 24usize), ("c4s", 24), ("ptbs", 12)];
    println!("traffic: {phases:?} (requests per phase)\n");
    for (domain, n) in phases {
        let mut stream = CorpusStream::new(domain, Split::Eval);
        let gen_before = server.weight_generation();
        let mut replies = 0usize;
        for i in 0..n {
            let mut toks = vec![BOS; seq];
            for t in toks.iter_mut().skip(1) {
                *t = stream.next_token();
            }
            server.submit(toks);
            // bursty arrivals: drive the engine every few submissions
            if i % 3 == 2 {
                replies += server.step(Instant::now())?.len();
            }
        }
        replies += server.drain()?.len();
        println!(
            "phase {domain:>5}: {replies}/{n} replies, weight generations {} -> {}",
            gen_before,
            server.weight_generation()
        );
    }

    println!("\n{}", server.metrics.summary());
    println!(
        "\nNote the generation bumps at phase boundaries: the calibrator\n\
         detected the activation-statistics drift and requantized — the\n\
         paper's on-device self-calibration (Fig. 1b) in action."
    );
    Ok(())
}
