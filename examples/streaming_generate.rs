//! Multi-token streaming generation through the decode engine.
//!
//! Submits a handful of prompts — one of them decoded speculatively
//! (quantized drafter + fp32 verifier) — then drives the server step by
//! step, printing each `ServeEvent::Token` as it streams out — the
//! shape of a real serving integration (SSE/websocket handlers consume
//! exactly this event stream). Every `Done` reports *why* generation
//! stopped (`MaxNewTokens` / `Eos` / `ContextFull`). Also shows the
//! same generation through the lower-level `Evaluator::generate`
//! convenience.
//!
//! ```bash
//! cargo run --release --example streaming_generate
//! ```


use anyhow::Result;
use ttq_serve::backend::default_backend;
use ttq_serve::coordinator::{ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::Evaluator;

fn main() -> Result<()> {
    let backend = default_backend()?;
    if backend.name() != "native" {
        println!("(cached decode needs the native backend; artifacts detected —");
        println!(" set TTQ_ARTIFACTS to an empty dir to force native)");
    }
    println!("execution backend: {}\n", backend.name());

    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.max_new_tokens = 10;
    let mut server = Server::new(backend.as_ref(), cfg)?;
    let prompt_len = server.max_seq() / 2;
    let mut stream = CorpusStream::new("wt2s", Split::Eval);

    let mut mk_prompt = |len: usize| {
        let mut toks = vec![BOS; len];
        for t in toks.iter_mut().skip(1) {
            *t = stream.next_token();
        }
        toks
    };
    for _ in 0..2 {
        server.submit(mk_prompt(prompt_len));
    }
    // the third request decodes speculatively: the quantized weights
    // only draft, a full-precision verifier commits every token —
    // stream quality is exactly the fp32 model's
    let spec_id = server.submit_speculative(mk_prompt(prompt_len));
    println!("request {spec_id} decodes speculatively (W4 drafter + fp32 verifier)\n");

    // drive the engine until every request is done, streaming tokens
    while server.pending() > 0 || server.running() > 0 {
        for e in server.step()? {
            match e {
                ServeEvent::Token { id, token, index, weight_generation } => {
                    println!("req {id}: token[{index}] = {token} (weight gen {weight_generation})");
                }
                ServeEvent::Done { id, tokens, prompt_len, stop } => {
                    println!(
                        "req {id}: DONE ({stop:?}) — {} tokens generated after a \
                         {prompt_len}-token prompt: {tokens:?}",
                        tokens.len()
                    );
                }
            }
        }
    }

    println!("\n{}", server.metrics.summary());
    println!(
        "speculative acceptance EWMA {:.2}, final draft depth k={}",
        server.spec_controller().acceptance(),
        server.spec_controller().k()
    );

    // the same thing without a server, for scripts and evals
    let ev = Evaluator::new(backend.as_ref(), "qwen-micro")?;
    let prompt = mk_prompt(prompt_len);
    let generated = ev.generate(&prompt, 10, None)?;
    println!("\nEvaluator::generate: {generated:?}");
    Ok(())
}
