//! Multi-token streaming generation through the decode engine.
//!
//! Submits a handful of prompts, then drives the server step by step,
//! printing each `ServeEvent::Token` as it streams out — the shape of a
//! real serving integration (SSE/websocket handlers consume exactly
//! this event stream). Also shows the same generation through the
//! lower-level `Evaluator::generate` convenience.
//!
//! ```bash
//! cargo run --release --example streaming_generate
//! ```

use std::time::Instant;

use anyhow::Result;
use ttq_serve::backend::default_backend;
use ttq_serve::coordinator::{ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::Evaluator;

fn main() -> Result<()> {
    let backend = default_backend()?;
    if backend.name() != "native" {
        println!("(cached decode needs the native backend; artifacts detected —");
        println!(" set TTQ_ARTIFACTS to an empty dir to force native)");
    }
    println!("execution backend: {}\n", backend.name());

    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.max_new_tokens = 10;
    let mut server = Server::new(backend.as_ref(), cfg)?;
    let prompt_len = server.max_seq() / 2;
    let mut stream = CorpusStream::new("wt2s", Split::Eval);

    for _ in 0..3 {
        let mut toks = vec![BOS; prompt_len];
        for t in toks.iter_mut().skip(1) {
            *t = stream.next_token();
        }
        server.submit(toks);
    }

    // drive the engine until every request is done, streaming tokens
    while server.pending() > 0 || server.running() > 0 {
        for e in server.step(Instant::now())? {
            match e {
                ServeEvent::Token { id, token, index, weight_generation } => {
                    println!("req {id}: token[{index}] = {token} (weight gen {weight_generation})");
                }
                ServeEvent::Done { id, tokens, prompt_len } => {
                    println!(
                        "req {id}: DONE — {} tokens generated after a {prompt_len}-token prompt: {tokens:?}",
                        tokens.len()
                    );
                }
            }
        }
    }

    println!("\n{}", server.metrics.summary());

    // the same thing without a server, for scripts and evals
    let ev = Evaluator::new(backend.as_ref(), "qwen-micro")?;
    let mut prompt = vec![BOS; prompt_len];
    for t in prompt.iter_mut().skip(1) {
        *t = stream.next_token();
    }
    let generated = ev.generate(&prompt, 10, None)?;
    println!("\nEvaluator::generate: {generated:?}");
    Ok(())
}
