//! Serving-path observability end to end: force a mid-stream
//! requantization, introspect *why* it fired, and export a Perfetto
//! trace of the whole session.
//!
//! Traffic starts on one corpus domain and switches to another halfway
//! through, so the online calibrator's drift detector fires while
//! requests are still decoding — the paper's test-time scenario. The
//! example then prints each [`ttq_serve::obs::RequantEvent`] (drift vs
//! threshold, tokens of evidence, quantization wall time) with its
//! top-3 drifted layers, and writes the recorded span ring as Chrome
//! trace-event JSON. Open the file at <https://ui.perfetto.dev>: each
//! request is its own track, with admit/prefill/decode spans nested
//! inside the request span and requants on the engine track.
//!
//! ```bash
//! cargo run --release --example trace_generate
//! ```

use anyhow::Result;
use ttq_serve::backend::NativeBackend;
use ttq_serve::coordinator::{ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::obs::export::{chrome_trace, metrics_json};
use ttq_serve::quant::MethodSpec;

const TRACE_PATH: &str = "trace_generate.json";

fn main() -> Result<()> {
    // Cached decode (and therefore serving) needs the native backend;
    // synthetic models keep this runnable without `make artifacts`.
    let backend = NativeBackend::new(&ttq_serve::artifacts_dir());

    let mut cfg = ServerConfig::new("qwen-micro").with_method(MethodSpec::ttq(0));
    cfg.max_new_tokens = 8;
    // a tighter threshold than the default so the wt2s→c4s shift below
    // reliably trips the drift detector mid-stream
    cfg.calib.drift_threshold = 0.02;
    let mut server = Server::new(&backend, cfg)?;
    let prompt_len = server.max_seq() / 2;

    // first half of the traffic on one domain, second half on another —
    // the domain shift is what accumulates diagonal drift
    let mut submit_from = |domain: &str, n: usize, server: &mut Server| {
        let mut stream = CorpusStream::new(domain, Split::Eval);
        for _ in 0..n {
            let mut toks = vec![BOS; prompt_len];
            for t in toks.iter_mut().skip(1) {
                *t = stream.next_token();
            }
            server.submit(toks);
        }
    };
    submit_from("wt2s", 6, &mut server);
    submit_from("c4s", 6, &mut server);

    let (mut streamed, mut done) = (0usize, 0usize);
    while server.pending() > 0 || server.running() > 0 {
        for e in server.step()? {
            match e {
                ServeEvent::Token { .. } => streamed += 1,
                ServeEvent::Done { .. } => done += 1,
            }
        }
    }
    println!("served {done} requests, {streamed} streamed tokens");
    println!("{}\n", server.metrics.summary());

    // why did the weights requantize mid-stream?
    if server.requant_events().is_empty() {
        println!("no drift requant fired (unexpected for this traffic mix)");
    }
    for ev in server.requant_events() {
        println!("requant: {}", ev.describe());
        println!("  drift exceeded threshold: {}", ev.drift_exceeded());
        for (layer, drift) in ev.top_layers(3) {
            println!("  layer {layer:>3}: drift {drift:.4}");
        }
    }

    // export the span ring for Perfetto / chrome://tracing
    let events = server.trace().snapshot();
    std::fs::write(TRACE_PATH, chrome_trace(&events))?;
    println!(
        "\nwrote {} spans ({} recorded, {} dropped) to {TRACE_PATH}",
        events.len(),
        server.trace().recorded(),
        server.trace().dropped()
    );
    println!("open it at https://ui.perfetto.dev");

    // the machine-readable snapshot the CI artifact job also captures
    let snap = metrics_json(&server.metrics);
    println!("metrics snapshot: {} bytes of JSON", snap.len());
    Ok(())
}
