"""AOT compiler: lower every (model, variant, batch-bucket) to HLO text.

Python's ONLY appearance in the system: `make artifacts` runs this once,
after which the rust binary is self-contained. Outputs under artifacts/:

  ckpt/<name>.npz            — trained checkpoints (cache)
  <name>.weights.bin         — f32 LE tensors concatenated in schema order
  <name>.manifest.json       — config + tensor offsets + linear schema
  <name>_<variant>_b<B>.hlo.txt — HLO text modules (see model.make_entry)
  kernels/ttq_linear.hlo.txt — standalone fused TTQ kernel (microbench)
  golden/quant_golden.json   — ref-oracle vectors for rust cross-checks
  corpus_golden.json         — corpus fixtures shared with rust tests

Interchange is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model, train
from .kernels import ref, ttq as ttq_kernels

VARIANTS = ["nll", "logits", "stats", "corr", "ttq"]
# (variant, batch) buckets to compile. logits b1 drives decode; nll/ttq
# get b1 (serving) + b4 (eval throughput); stats/corr are eval-only.
BUCKETS: dict[str, list[int]] = {
    "nll": [1, 4],
    "logits": [1, 4],
    "stats": [1, 4],
    "corr": [4],
    "ttq": [1, 4],
}
SEQ = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(cfg: model.ModelConfig, variant: str, batch: int) -> str:
    fn = model.make_entry(cfg, variant)
    tok_spec = jax.ShapeDtypeStruct((batch, SEQ), jnp.int32)
    w_specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in model.param_schema(cfg)
    ]
    if variant == "ttq":
        qmax_spec = jax.ShapeDtypeStruct((), jnp.float32)
        lowered = jax.jit(fn).lower(tok_spec, qmax_spec, *w_specs)
    else:
        lowered = jax.jit(fn).lower(tok_spec, *w_specs)
    return to_hlo_text(lowered)


def dump_weights(out_dir: str, cfg: model.ModelConfig, params: dict) -> dict:
    """Write weights.bin + manifest; returns the manifest dict."""
    tensors = []
    offset = 0
    blob = bytearray()
    for name, shape in model.param_schema(cfg):
        arr = np.asarray(params[name], np.float32)
        assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
        raw = arr.tobytes()  # C-order f32 LE
        tensors.append(
            {"name": name, "shape": list(shape), "offset": offset,
             "numel": int(arr.size)}
        )
        blob += raw
        offset += arr.size
    with open(os.path.join(out_dir, f"{cfg.name}.weights.bin"), "wb") as f:
        f.write(bytes(blob))
    manifest = {
        "name": cfg.name,
        "family": cfg.family,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim,
            "d_mlp": cfg.d_mlp, "max_seq": cfg.max_seq, "seq": SEQ,
        },
        "tensors": tensors,
        "linears": model.linear_schema(cfg),
        "norm_ps": list(model.NORM_PS),
        "ttq_defaults": {
            "g": model.TTQ_G, "p": model.TTQ_P, "lam": model.TTQ_LAM,
            "alpha": model.TTQ_ALPHA,
        },
        "buckets": BUCKETS,
    }
    with open(os.path.join(out_dir, f"{cfg.name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def dump_quant_golden(out_dir: str) -> None:
    """Golden vectors from the jnp ref oracle for the rust quant tests."""
    rng = np.random.default_rng(7)
    w = rng.normal(size=(8, 64)).astype(np.float32)
    x = rng.normal(size=(64, 12)).astype(np.float32)
    cases = {}
    for q, g in [(2, 16), (3, 32), (4, 32), (5, 64), (4, 128)]:
        qmax = float(2 ** q - 1)
        key = f"q{q}_g{g}"
        cases[key] = {
            "rtn": np.asarray(ref.rtn_ref(w, qmax, g)).flatten().tolist(),
            "awq": np.asarray(
                ref.awq_ref(x, w, qmax, g, 2.0, 0.4, 0.5)
            ).flatten().tolist(),
        }
    dvec = np.asarray(ref.awq_diag(jnp.asarray(x), 2.0, 0.4, 0.5))
    b, a = ref.lowrank_init_ref(jnp.asarray(w), 4)
    y_ttq = ref.ttq_linear_ref(jnp.asarray(x), jnp.asarray(w), 7.0, 32,
                               b=b, a=a)
    golden = {
        "w": w.flatten().tolist(),
        "w_shape": [8, 64],
        "x": x.flatten().tolist(),
        "x_shape": [64, 12],
        "awq_diag_p2": dvec.tolist(),
        "ba": np.asarray(b @ a).flatten().tolist(),
        "ttq_r4_q3_g32_y": np.asarray(y_ttq).flatten().tolist(),
        "cases": cases,
    }
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)
    with open(os.path.join(out_dir, "golden", "quant_golden.json"), "w") as f:
        json.dump(golden, f)


def dump_kernel_artifact(out_dir: str) -> None:
    """Standalone fused TTQ kernel at serving-ish dims for microbenches."""
    os.makedirs(os.path.join(out_dir, "kernels"), exist_ok=True)
    d, ddash, t = 128, 384, 16

    def fn(x, w, qmax):
        return (ttq_kernels.ttq_linear(x, w, qmax, g=32),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((d, t), jnp.float32),
        jax.ShapeDtypeStruct((ddash, d), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    with open(os.path.join(out_dir, "kernels", "ttq_linear.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))


def dump_corpus_golden(out_dir: str) -> None:
    with open(os.path.join(out_dir, "corpus_golden.json"), "w") as f:
        json.dump(corpus.golden_fixture(), f, indent=0)


def build_all(out_dir: str, models: list[str] | None = None, log=print) -> None:
    os.makedirs(out_dir, exist_ok=True)
    ckpt_dir = os.path.join(out_dir, "ckpt")
    os.makedirs(ckpt_dir, exist_ok=True)

    dump_corpus_golden(out_dir)
    dump_quant_golden(out_dir)
    dump_kernel_artifact(out_dir)

    names = models or list(model.CONFIGS)
    for name in names:
        cfg = model.CONFIGS[name]
        t0 = time.time()
        params = train.train_or_load(cfg, ckpt_dir, train.steps_for(cfg), log=log)
        dump_weights(out_dir, cfg, params)
        for variant in VARIANTS:
            for b in BUCKETS[variant]:
                path = os.path.join(out_dir, f"{name}_{variant}_b{b}.hlo.txt")
                if os.path.exists(path):
                    continue
                text = lower_entry(cfg, variant, b)
                with open(path, "w") as f:
                    f.write(text)
                log(f"  [{name}] {variant}_b{b}: {len(text)//1024}KiB")
        log(f"[{name}] done in {time.time()-t0:.1f}s")
    # Build stamp consumed by the Makefile's up-to-date check.
    with open(os.path.join(out_dir, "BUILD_OK"), "w") as f:
        f.write(str(time.time()))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of model names (default: all)")
    args = ap.parse_args()
    build_all(args.out, args.models)


if __name__ == "__main__":
    main()
