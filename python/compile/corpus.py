"""Synthetic corpus engine — python half.

Stand-in for the paper's WikiText-2 / PTB / C4 / TextVQA / LIBERO data
(DESIGN.md §3). Each *domain* is a seeded stochastic language over a
shared 512-token vocabulary with domain-specific statistics:

  wt2s — wiki-like: mid vocab, moderate predictability, Zipf s=1.1
  ptbs — newswire-like: narrow vocab, highly templated, Zipf s=1.3
  c4s  — web-crawl-like: full vocab, high entropy, Zipf s=0.9
  vqas — VQA-proxy: narrow, predictable (accuracy is measurable)
  acts — action-stream proxy for VLA suites: tiny vocab, near-deterministic

The generator is a counter-based SplitMix64 process with an order-≤2
Markov structure: for each context (prev2, prev1) a deterministic hash
fixes K candidate successors (drawn through the Zipf quantile map), and
a geometric choice + ε-noise picks among them. Low conditional entropy
=> learnable by a tiny LM; distinct hashes/shape per domain => real
domain shift between calibration sets, which is what the paper's AWQ
baseline is sensitive to.

The rust side (`rust/src/corpus/`) implements the *identical* algorithm;
`tests/test_corpus.py` emits and checks the shared golden fixture
`testdata/corpus_golden.json` consumed by the rust tests too.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

M64 = (1 << 64) - 1
VOCAB = 512
BOS = 0

C_DOMAIN = 0x9E3779B97F4A7C15
C_PREV1 = 0xC2B2AE3D27D4EB4F
C_PREV2 = 0x165667B19E3779F9
C_SPLIT = 0x27D4EB2F165667C5


def splitmix64(z: int) -> int:
    z = (z + 0x9E3779B97F4A7C15) & M64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return (z ^ (z >> 31)) & M64


@dataclass(frozen=True)
class DomainSpec:
    name: str
    id: int
    vocab_used: int  # tokens 1..vocab_used are live; 0 is BOS
    k: int  # candidate successors per context
    eps: float  # marginal-noise probability
    q: float  # geometric decay over candidates
    order: int  # markov order (1 or 2)
    zipf: float  # Zipf exponent of the marginal


DOMAINS: dict[str, DomainSpec] = {
    "wt2s": DomainSpec("wt2s", 1, 440, 4, 0.05, 0.55, 2, 1.1),
    "ptbs": DomainSpec("ptbs", 2, 160, 3, 0.02, 0.45, 2, 1.3),
    "c4s": DomainSpec("c4s", 3, 500, 8, 0.15, 0.80, 1, 0.9),
    "vqas": DomainSpec("vqas", 4, 96, 2, 0.03, 0.40, 2, 1.05),
    "acts": DomainSpec("acts", 5, 64, 2, 0.01, 0.35, 2, 1.0),
}

# Splits: 0 = train, 1 = eval, 2 = calibration. Same language (context
# hashes), independent random draws.
TRAIN, EVAL, CALIB = 0, 1, 2

BASE_SEED = 0x7751_2026


def zipf_cdf(spec: DomainSpec) -> np.ndarray:
    w = (np.arange(1, spec.vocab_used + 1, dtype=np.float64)) ** (-spec.zipf)
    c = np.cumsum(w)
    return c / c[-1]


def zipf_quantile(cdf: np.ndarray, u: float) -> int:
    """Rank (0-based) whose CDF bucket contains u ∈ [0,1)."""
    return int(np.searchsorted(cdf, u, side="right"))


class CorpusStream:
    """Deterministic token stream for (domain, split, stream_id)."""

    def __init__(self, domain: str, split: int, stream_id: int = 0):
        self.spec = DOMAINS[domain]
        self.cdf = zipf_cdf(self.spec)
        self.lang_seed = splitmix64(BASE_SEED ^ (self.spec.id * C_DOMAIN & M64))
        self.ctr_seed = splitmix64(
            (self.lang_seed ^ ((split * C_SPLIT) & M64) ^ stream_id) & M64
        )
        self.ctr = 0
        self.prev1 = BOS
        self.prev2 = BOS

    def _rand_u01(self) -> float:
        self.ctr += 1
        v = splitmix64((self.ctr_seed + self.ctr) & M64)
        return (v >> 11) * (1.0 / (1 << 53))

    def _context_hash(self) -> int:
        h = self.lang_seed
        h ^= (self.prev1 * C_PREV1) & M64
        if self.spec.order >= 2:
            h ^= (self.prev2 * C_PREV2) & M64
        return splitmix64(h)

    def next_token(self) -> int:
        spec = self.spec
        u = self._rand_u01()
        if u < spec.eps:
            rank = zipf_quantile(self.cdf, self._rand_u01())
            tok = 1 + rank
        else:
            h = self._context_hash()
            u2 = self._rand_u01()
            # geometric choice among k candidates (truncated, renormalized
            # implicitly by the final clamp)
            j = 0
            acc = 1.0 - spec.q
            p = acc
            while j < spec.k - 1 and u2 >= p:
                acc *= spec.q
                p += acc
                j += 1
            frac = ((h >> (13 * (j % 4))) & 0xFFFF) * (1.0 / 65536.0)
            tok = 1 + zipf_quantile(self.cdf, frac)
        self.prev2 = self.prev1
        self.prev1 = tok
        return tok

    def tokens(self, n: int) -> np.ndarray:
        return np.asarray([self.next_token() for _ in range(n)], np.int32)

    def batches(self, n_batches: int, batch: int, seq: int) -> np.ndarray:
        """(n_batches, batch, seq) int32, each row starts with BOS."""
        out = np.zeros((n_batches, batch, seq), np.int32)
        for i in range(n_batches):
            for b in range(batch):
                out[i, b, 0] = BOS
                out[i, b, 1:] = self.tokens(seq - 1)
        return out


def golden_fixture() -> dict:
    """First tokens of every (domain, split) — shared with the rust tests."""
    out = {}
    for name in DOMAINS:
        for split, sname in [(TRAIN, "train"), (EVAL, "eval"), (CALIB, "calib")]:
            s = CorpusStream(name, split)
            out[f"{name}/{sname}"] = s.tokens(64).tolist()
    return out
