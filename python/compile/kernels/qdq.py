"""L1 Pallas kernel: groupwise round-to-nearest quantize-dequantize (RTN).

The paper's Eq. (1): W_int = round[clamp_q[(W - Z) ⊘ S]], Ŵ = W_int ∘ S + Z
with asymmetric per-group scale/zero (App. B/D). The weight is viewed as
(G, g) groups; the grid tiles G so each program QDQs a block of groups
entirely inside VMEM — one HBM→VMEM round-trip per weight element.

``qmax`` (= 2^q − 1) is a *runtime* scalar input so a single AOT artifact
serves every bit-width q ∈ {2..8}.

Hardware adaptation note (DESIGN.md §6): on a real TPU this block layout
keeps each group's min/max reduction within a VMEM tile (the analogue of
Marlin's SMEM-resident dequant); on CPU we run interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Groups per program instance. 64 groups x g<=512 floats x 4B <= 128KiB,
# comfortably inside a TPU core's ~16MiB VMEM alongside double-buffering.
DEFAULT_BLOCK_GROUPS = 64


def _qdq_kernel(w_ref, qmax_ref, o_ref):
    """QDQ one (BG, g) block of groups."""
    w = w_ref[...]
    qmax = qmax_ref[0, 0]
    wmax = jnp.max(w, axis=1, keepdims=True)
    wmin = jnp.min(w, axis=1, keepdims=True)
    z = wmin
    s = (wmax - wmin) / qmax
    s = jnp.where(s <= 0.0, 1.0, s)
    wint = jnp.clip(jnp.round((w - z) / s), 0.0, qmax)
    o_ref[...] = wint * s + z


@functools.partial(jax.jit, static_argnames=("g", "block_groups"))
def rtn_qdq(
    w: jnp.ndarray,
    qmax: jnp.ndarray,
    g: int = 32,
    block_groups: int = DEFAULT_BLOCK_GROUPS,
) -> jnp.ndarray:
    """Groupwise RTN QDQ of ``w`` (d', d) with flat groupsize ``g``.

    qmax: scalar f32 array (2^q - 1). Requires d'*d % g == 0 and the
    number of groups to be divisible by the block size (pad upstream).
    """
    ddash, d = w.shape
    n = ddash * d
    assert n % g == 0, f"weight numel {n} not divisible by groupsize {g}"
    n_groups = n // g
    bg = min(block_groups, n_groups)
    while n_groups % bg != 0:  # shrink to a divisor (power-of-two sizes)
        bg //= 2
    bg = max(bg, 1)
    wg = w.reshape(n_groups, g)
    qm = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _qdq_kernel,
        out_shape=jax.ShapeDtypeStruct((n_groups, g), w.dtype),
        grid=(n_groups // bg,),
        in_specs=[
            pl.BlockSpec((bg, g), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bg, g), lambda i: (i, 0)),
        interpret=True,
    )(wg, qm)
    return out.reshape(ddash, d)


def _diag_kernel(x_ref, o_ref, *, p: float, lam: float, alpha: float):
    """Activation diagonal D_i = (‖X_i,:‖_p + λ)^α for one block of rows."""
    x = x_ref[...]
    if p == 2.0:
        nrm = jnp.sqrt(jnp.sum(x * x, axis=1))
    elif p == 1.0:
        nrm = jnp.sum(jnp.abs(x), axis=1)
    else:
        nrm = jnp.sum(jnp.abs(x) ** p, axis=1) ** (1.0 / p)
    o_ref[...] = (nrm + lam) ** alpha


@functools.partial(jax.jit, static_argnames=("p", "lam", "alpha", "block_rows"))
def awq_diag(
    x: jnp.ndarray,
    p: float = 2.0,
    lam: float = 0.4,
    alpha: float = 0.5,
    block_rows: int = 128,
) -> jnp.ndarray:
    """Pallas activation-scaling diagonal over X (d, T) → D (d,).

    One pass over X; O[dT] — the dominant term of the paper's overhead
    ratio ρ = O[1/d' + 3/T] (Eq. 3).
    """
    d, t = x.shape
    br = min(block_rows, d)
    while d % br != 0:
        br //= 2
    br = max(br, 1)
    kern = functools.partial(_diag_kernel, p=p, lam=lam, alpha=alpha)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        grid=(d // br,),
        in_specs=[pl.BlockSpec((br, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        interpret=True,
    )(x)
