"""Pure-jnp reference oracles for the TTQ quantization stack.

These are the *correctness ground truth* for every Pallas kernel (L1) and
for the rust quant library (L3, cross-checked through golden vectors
emitted by aot.py). All formulas follow the paper:

  RTN (Eq. 1 / App. B):   Ŵ = G⁻[G[W]] with flat groupwise scale/zero
  AWQ (Eq. 19-20/App. C): D_ii = (‖X_i,:‖_p + λ)^α,  Ŵ = Q[W·D]·D⁻¹
  TTQ (+ low rank, §2):   Ŵ = Q[(W−BA)·D]·D⁻¹ + BA, D from the live X

Shapes follow the paper: W is (d', d), X is (d, T), Y = W @ X is (d', T).
Grouping is over the *flattened* weight (d'*d/g, g), exactly as in the
paper's pseudo-code (a group may span row boundaries).
"""

from __future__ import annotations

import jax.numpy as jnp


def quant_params(wg: jnp.ndarray, qmax: float, nu: float = 1.0):
    """Asymmetric scale/zero for grouped weights ``wg`` of shape (G, g).

    ``qmax`` is 2^q - 1 (kept as a float so a single lowered artifact can
    serve any bit-width). ``nu`` is the range-expansion factor of App. D
    (nu=1.0 is the standard min/max scaling).
    """
    wmax = wg.max(axis=1, keepdims=True)
    wmin = wg.min(axis=1, keepdims=True)
    if nu != 1.0:
        wmax, wmin = (
            0.5 * (1 + nu) * wmax + 0.5 * (1 - nu) * wmin,
            0.5 * (1 - nu) * wmax + 0.5 * (1 + nu) * wmin,
        )
    z = wmin
    s = (wmax - wmin) / qmax
    # Guard all-equal groups: scale 0 -> dequant to the (constant) zero point.
    s = jnp.where(s <= 0.0, 1.0, s)
    return s, z


def quant_params_symmetric(wg: jnp.ndarray, qmax: float):
    """Symmetric format of App. D: S = 2|W|max/qmax, Z = -|W|max."""
    amax = jnp.abs(wg).max(axis=1, keepdims=True)
    s = 2.0 * amax / qmax
    s = jnp.where(s <= 0.0, 1.0, s)
    z = -amax
    return s, z


def rtn_ref(
    w: jnp.ndarray,
    qmax: float,
    g: int,
    nu: float = 1.0,
    symmetric: bool = False,
) -> jnp.ndarray:
    """Groupwise round-to-nearest QDQ (paper Eq. 1, App. B pseudo-code)."""
    ddash, d = w.shape
    assert (ddash * d) % g == 0, f"{ddash}x{d} not divisible by group {g}"
    wg = w.reshape(-1, g)
    if symmetric:
        s, z = quant_params_symmetric(wg, qmax)
    else:
        s, z = quant_params(wg, qmax, nu)
    wint = jnp.clip(jnp.round((wg - z) / s), 0.0, qmax)
    what = wint * s + z
    return what.reshape(ddash, d)


def rtn_int_ref(w: jnp.ndarray, qmax: float, g: int):
    """Integer codes + params, for packing tests. Returns (wint, s, z)."""
    ddash, d = w.shape
    wg = w.reshape(-1, g)
    s, z = quant_params(wg, qmax)
    wint = jnp.clip(jnp.round((wg - z) / s), 0.0, qmax)
    return wint.reshape(ddash, d), s[:, 0], z[:, 0]


def awq_diag(
    x: jnp.ndarray, p: float, lam: float, alpha: float
) -> jnp.ndarray:
    """Diagonal activation scaling D_i = (‖X_i,:‖_p + λ)^α; X is (d, T)."""
    if p == 2.0:
        nrm = jnp.sqrt(jnp.sum(x * x, axis=1))
    elif p == 1.0:
        nrm = jnp.sum(jnp.abs(x), axis=1)
    else:
        nrm = jnp.sum(jnp.abs(x) ** p, axis=1) ** (1.0 / p)
    return (nrm + lam) ** alpha


def awq_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    qmax: float,
    g: int,
    p: float = 2.0,
    lam: float = 0.4,
    alpha: float = 0.5,
) -> jnp.ndarray:
    """Activation-aware scaled QDQ (paper App. C pseudo-code)."""
    dvec = awq_diag(x, p, lam, alpha)
    what = rtn_ref(w * dvec[None, :], qmax, g)
    return what / dvec[None, :]


def awq_ref_with_diag(
    w: jnp.ndarray, dvec: jnp.ndarray, qmax: float, g: int
) -> jnp.ndarray:
    """Scaled QDQ given a precomputed diagonal (offline-AWQ path)."""
    what = rtn_ref(w * dvec[None, :], qmax, g)
    return what / dvec[None, :]


def ttq_linear_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    qmax: float,
    g: int,
    p: float = 2.0,
    lam: float = 0.4,
    alpha: float = 0.5,
    b: jnp.ndarray | None = None,
    a: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Full fused TTQ projection: Y = Q[(W−BA)D]D⁻¹ X + B(AX).

    This is the paper's §2 "TTQ with Low-Rank Decomposition" forward with
    the live activation X supplying D (r = 0 when b/a are None).
    """
    resid = w if b is None else w - b @ a
    dvec = awq_diag(x, p, lam, alpha)
    wq = rtn_ref(resid * dvec[None, :], qmax, g) / dvec[None, :]
    y = wq @ x
    if b is not None:
        y = y + b @ (a @ x)
    return y


def lowrank_init_ref(w: jnp.ndarray, r: int):
    """Top-r principal components init (App. E Eq. 31-33):
    B = U_r Λ_r^{1/2}, A = Λ_r^{1/2} V_r   (so BA = U_r Λ_r V_r)."""
    u, sv, vt = jnp.linalg.svd(w, full_matrices=False)
    sr = jnp.sqrt(sv[:r])
    b = u[:, :r] * sr[None, :]
    a = sr[:, None] * vt[:r, :]
    return b, a


def approx_loss_ref(w, what, x):
    """The activation-aware loss L = ‖(W−Ŵ)X‖² of Eq. 2."""
    e = (w - what) @ x
    return jnp.sum(e * e)
