"""L1 Pallas kernel: fused TTQ linear projection (the paper's hot spot).

Computes, in one kernel pass over W (no intermediate HBO round trip for
the scaled/quantized weight):

    Y = Q[(W − BA)·diag(D)]·diag(D)⁻¹ @ X  (+ B @ (A @ X) when r > 0)

where D is the activation diagonal from the *live* X (computed by the
companion ``awq_diag`` kernel — one O[dT] pass). This is the "prologue
fusion" the paper's App. H calls for: AWQ can fold D into the previous
layer offline, TTQ must fuse it into the int-matmul; here the W tile is
rescaled, QDQ'd and fed to the MXU while still resident in VMEM.

Tiling: grid over d' row-blocks of W. Each program holds one
(BD, d) weight tile + the full (d, T) activation block in VMEM, mirrors
Marlin's SMEM-staged dequant-into-GEMM on the TPU memory hierarchy.
Groupsize g must divide d so that groups never span the K dimension of a
tile (g ≤ d; the flat-grouped reference coincides in that regime).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import qdq


def _qdq_rows(w, qmax, g):
    """Groupwise QDQ of a (BD, d) tile with per-row groups of size g."""
    bd, d = w.shape
    wg = w.reshape(bd * d // g, g)
    wmax = jnp.max(wg, axis=1, keepdims=True)
    wmin = jnp.min(wg, axis=1, keepdims=True)
    s = (wmax - wmin) / qmax
    s = jnp.where(s <= 0.0, 1.0, s)
    wint = jnp.clip(jnp.round((wg - wmin) / s), 0.0, qmax)
    return (wint * s + wmin).reshape(bd, d)


def _ttq_matmul_kernel(x_ref, w_ref, dvec_ref, qmax_ref, o_ref, *, g: int):
    """One (BD, d) tile: prescale -> QDQ -> descale -> matmul."""
    w = w_ref[...]
    dvec = dvec_ref[...]
    qmax = qmax_ref[0, 0]
    ws = w * dvec[None, :]
    wq = _qdq_rows(ws, qmax, g) * (1.0 / dvec)[None, :]
    o_ref[...] = jnp.dot(wq, x_ref[...], preferred_element_type=jnp.float32)


def _ttq_matmul_lr_kernel(
    x_ref, w_ref, dvec_ref, qmax_ref, b_ref, ax_ref, o_ref, *, g: int
):
    """Low-rank variant: residual-quantized matmul + B @ (AX) epilogue."""
    w = w_ref[...]
    dvec = dvec_ref[...]
    qmax = qmax_ref[0, 0]
    ws = w * dvec[None, :]
    wq = _qdq_rows(ws, qmax, g) * (1.0 / dvec)[None, :]
    y = jnp.dot(wq, x_ref[...], preferred_element_type=jnp.float32)
    y = y + jnp.dot(b_ref[...], ax_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = y


def _pick_block(ddash: int, want: int = 128) -> int:
    bd = min(want, ddash)
    while ddash % bd != 0:
        bd //= 2
    return max(bd, 1)


@functools.partial(
    jax.jit, static_argnames=("g", "p", "lam", "alpha", "block_d")
)
def ttq_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    qmax: jnp.ndarray,
    g: int = 32,
    p: float = 2.0,
    lam: float = 0.4,
    alpha: float = 0.5,
    block_d: int = 128,
) -> jnp.ndarray:
    """Fused TTQ projection Y = Q[W·D]D⁻¹ X, rank-0 path. X: (d,T), W: (d',d)."""
    d, t = x.shape
    ddash, d2 = w.shape
    assert d == d2 and d % g == 0, f"g={g} must divide d={d}"
    dvec = qdq.awq_diag(x, p=p, lam=lam, alpha=alpha)
    bd = _pick_block(ddash, block_d)
    qm = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    kern = functools.partial(_ttq_matmul_kernel, g=g)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((ddash, t), jnp.float32),
        grid=(ddash // bd,),
        in_specs=[
            pl.BlockSpec((d, t), lambda i: (0, 0)),
            pl.BlockSpec((bd, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bd, t), lambda i: (i, 0)),
        interpret=True,
    )(x, w, dvec, qm)


@functools.partial(
    jax.jit, static_argnames=("g", "p", "lam", "alpha", "block_d")
)
def ttq_linear_lowrank(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    qmax: jnp.ndarray,
    g: int = 32,
    p: float = 2.0,
    lam: float = 0.4,
    alpha: float = 0.5,
    block_d: int = 128,
) -> jnp.ndarray:
    """TTQ + low-rank: Y = Q[(W−BA)D]D⁻¹ X + B(AX).  b: (d',r), a: (r,d).

    The caller passes the *original* W; the residual W − BA is formed
    tile-by-tile inside the kernel-feeding prescale (here: upfront, since
    BA is rank-r it is cheap at build dims), matching App. E.
    """
    d, t = x.shape
    ddash, _ = w.shape
    r = b.shape[1]
    resid = w - b @ a  # O[r d' d] one-off; dominated by the matmul.
    dvec = qdq.awq_diag(x, p=p, lam=lam, alpha=alpha)
    ax = a @ x  # O[r d T] << O[d' d T]
    bd = _pick_block(ddash, block_d)
    qm = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    kern = functools.partial(_ttq_matmul_lr_kernel, g=g)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((ddash, t), jnp.float32),
        grid=(ddash // bd,),
        in_specs=[
            pl.BlockSpec((d, t), lambda i: (0, 0)),
            pl.BlockSpec((bd, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bd, r), lambda i: (i, 0)),
            pl.BlockSpec((r, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bd, t), lambda i: (i, 0)),
        interpret=True,
    )(x, resid, dvec, qm, b, ax)


def awq_prescaled_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    dvec: jnp.ndarray,
    qmax: jnp.ndarray,
    g: int = 32,
    block_d: int = 128,
) -> jnp.ndarray:
    """Offline-AWQ baseline path: D precomputed from calibration data.

    Same fused kernel, but D arrives as a static input instead of being
    derived from the live X — this is exactly Fig. 1(a) vs (b).
    """
    d, t = x.shape
    ddash, _ = w.shape
    bd = _pick_block(ddash, block_d)
    qm = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    kern = functools.partial(_ttq_matmul_kernel, g=g)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((ddash, t), jnp.float32),
        grid=(ddash // bd,),
        in_specs=[
            pl.BlockSpec((d, t), lambda i: (0, 0)),
            pl.BlockSpec((bd, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bd, t), lambda i: (i, 0)),
        interpret=True,
    )(x, w, dvec, qm)
