"""L2: JAX decoder-only transformer families for the TTQ reproduction.

Three architecture-faithful miniature families (DESIGN.md §3):

  opt   — LayerNorm(+bias), ReLU MLP, learned absolute positions  (OPT)
  qwen  — RMSNorm, SwiGLU, RoPE, GQA, per-head QK-norm            (Qwen3)
  gemma — RMSNorm(1+w), GeGLU, RoPE, MQA(kv=1), wide head_dim,
          sqrt(d)-scaled embedding                                 (Gemma3)

Weights live in a *flat name→array dict* whose canonical ordering is the
interchange contract with the rust runtime (manifest order). All
projection weights are stored paper-style as (d_out, d_in); `y = x @ W.T`.

Forward variants (all lowered to HLO text by aot.py; weights are
*inputs*, so the rust coordinator can substitute quantized weights):

  nll    — sum token NLL + count (perplexity eval)
  logits — full logits (serving / greedy decode)
  stats  — nll + per-linear activation norm sums Σ|x|^p, p∈{½,1,2,4}
  corr   — stats + per-linear input auto-correlation XᵀX (GPTQ, App. C)
  ttq    — every attn/MLP linear routed through the fused L1
           `ttq_linear` Pallas kernel with a *runtime* qmax scalar
           (the paper's Fig. 1(b) single-pass online path)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ttq as ttq_kernels

NORM_PS = (0.5, 1.0, 2.0, 4.0)  # Fig. 2 hyperparameter grid support
TTQ_G = 32  # paper default groupsize
TTQ_P = 2.0
TTQ_LAM = 0.4
TTQ_ALPHA = 0.5


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # opt | qwen | gemma
    vocab: int = 512
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 16
    d_mlp: int = 256
    max_seq: int = 64
    norm_eps: float = 1e-5

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.head_dim


# Scaled-down registry mirroring the paper's Tables 14-16 families.
CONFIGS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        ModelConfig("opt-micro", "opt", d_model=64, n_layers=2, n_heads=4,
                    n_kv_heads=4, head_dim=16, d_mlp=256),
        ModelConfig("opt-mini", "opt", d_model=128, n_layers=4, n_heads=8,
                    n_kv_heads=8, head_dim=16, d_mlp=512),
        ModelConfig("opt-small", "opt", d_model=192, n_layers=6, n_heads=8,
                    n_kv_heads=8, head_dim=24, d_mlp=768),
        ModelConfig("qwen-micro", "qwen", d_model=64, n_layers=2, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_mlp=192),
        ModelConfig("qwen-mini", "qwen", d_model=128, n_layers=4, n_heads=8,
                    n_kv_heads=2, head_dim=16, d_mlp=384),
        ModelConfig("gemma-micro", "gemma", d_model=64, n_layers=2, n_heads=4,
                    n_kv_heads=1, head_dim=32, d_mlp=256),
        ModelConfig("gemma-mini", "gemma", d_model=128, n_layers=4, n_heads=4,
                    n_kv_heads=1, head_dim=32, d_mlp=512),
    ]
}


# ---------------------------------------------------------------------------
# Parameter schema — the canonical tensor ordering (interchange contract).
# ---------------------------------------------------------------------------

def param_schema(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list; rust reads weights.bin in this order."""
    out: list[tuple[str, tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.d_model))]
    if cfg.family == "opt":
        out.append(("pos_embed", (cfg.max_seq, cfg.d_model)))
    for i in range(cfg.n_layers):
        p = f"l{i}."
        out.append((p + "ln1", (cfg.d_model,)))
        if cfg.family == "opt":
            out.append((p + "ln1b", (cfg.d_model,)))
        out.append((p + "wq", (cfg.d_attn, cfg.d_model)))
        out.append((p + "wk", (cfg.d_kv, cfg.d_model)))
        out.append((p + "wv", (cfg.d_kv, cfg.d_model)))
        out.append((p + "wo", (cfg.d_model, cfg.d_attn)))
        if cfg.family == "qwen":
            out.append((p + "qnorm", (cfg.head_dim,)))
            out.append((p + "knorm", (cfg.head_dim,)))
        out.append((p + "ln2", (cfg.d_model,)))
        if cfg.family == "opt":
            out.append((p + "ln2b", (cfg.d_model,)))
        if cfg.family == "opt":
            out.append((p + "up", (cfg.d_mlp, cfg.d_model)))
            out.append((p + "down", (cfg.d_model, cfg.d_mlp)))
        else:
            out.append((p + "gate", (cfg.d_mlp, cfg.d_model)))
            out.append((p + "up", (cfg.d_mlp, cfg.d_model)))
            out.append((p + "down", (cfg.d_model, cfg.d_mlp)))
    out.append(("lnf", (cfg.d_model,)))
    if cfg.family == "opt":
        out.append(("lnfb", (cfg.d_model,)))
    return out


def linear_schema(cfg: ModelConfig) -> list[dict]:
    """Quantizable linears in tap order: the contract for stats outputs."""
    out = []
    for i in range(cfg.n_layers):
        p = f"l{i}."
        out.append({"name": p + "wq", "d_in": cfg.d_model, "d_out": cfg.d_attn})
        out.append({"name": p + "wk", "d_in": cfg.d_model, "d_out": cfg.d_kv})
        out.append({"name": p + "wv", "d_in": cfg.d_model, "d_out": cfg.d_kv})
        out.append({"name": p + "wo", "d_in": cfg.d_attn, "d_out": cfg.d_model})
        if cfg.family != "opt":
            out.append({"name": p + "gate", "d_in": cfg.d_model, "d_out": cfg.d_mlp})
        out.append({"name": p + "up", "d_in": cfg.d_model, "d_out": cfg.d_mlp})
        out.append({"name": p + "down", "d_in": cfg.d_mlp, "d_out": cfg.d_model})
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    params: dict[str, jnp.ndarray] = {}
    for name, shape in param_schema(cfg):
        base = name.split(".")[-1]
        if base in ("ln1", "ln2", "lnf", "qnorm", "knorm"):
            arr = (np.zeros(shape) if cfg.family == "gemma" else np.ones(shape))
        elif base in ("ln1b", "ln2b", "lnfb"):
            arr = np.zeros(shape)
        elif name == "embed":
            arr = rng.normal(0, 0.02, shape)
        elif name == "pos_embed":
            arr = rng.normal(0, 0.01, shape)
        else:  # projection: fan-in scaled
            fan_in = shape[1]
            arr = rng.normal(0, fan_in ** -0.5, shape)
            if base in ("wo", "down"):
                arr = arr / np.sqrt(2.0 * cfg.n_layers)
        params[name] = jnp.asarray(arr, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def _layernorm(x, w, b, eps):
    mu = x.mean(-1, keepdims=True)
    v = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(v + eps) * w + b


def _rmsnorm(x, w, eps, unit_offset=False):
    v = (x * x).mean(-1, keepdims=True)
    xn = x * jax.lax.rsqrt(v + eps)
    return xn * (1.0 + w) if unit_offset else xn * w


def _rope(x, positions, head_dim):
    """x: (B,S,H,hd). Standard rotary embedding, theta=1e4."""
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


LinearFn = Callable[[str, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _make_linear(mode: str, taps: list, qmax) -> LinearFn:
    """Returns the projection op for the chosen forward variant."""

    def plain(name, x, w):
        return x @ w.T

    def tapped(name, x, w):
        x2 = x.reshape(-1, x.shape[-1])
        norms = jnp.stack(
            [jnp.sum(jnp.abs(x2) ** p, axis=0) for p in NORM_PS]
        )  # (4, d_in)
        entry = {"name": name, "norms": norms}
        if mode == "corr":
            entry["corr"] = x2.T @ x2
        taps.append(entry)
        return x @ w.T

    def fused_ttq(name, x, w):
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])  # (N, d_in)
        y = ttq_kernels.ttq_linear(
            x2.T, w, qmax, g=TTQ_G, p=TTQ_P, lam=TTQ_LAM, alpha=TTQ_ALPHA
        ).T  # (N, d_out)
        return y.reshape(*lead, w.shape[0])

    if mode in ("stats", "corr"):
        return tapped
    if mode == "ttq":
        return fused_ttq
    return plain


def forward(
    cfg: ModelConfig,
    params: dict[str, jnp.ndarray],
    tokens: jnp.ndarray,  # (B, S) int32
    mode: str = "plain",
    qmax: jnp.ndarray | None = None,
):
    """Returns (logits, taps). taps is [] unless mode in {stats, corr}."""
    taps: list = []
    lin = _make_linear(mode, taps, qmax)
    eps = cfg.norm_eps
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

    h = params["embed"][tokens]
    if cfg.family == "gemma":
        h = h * jnp.sqrt(jnp.float32(cfg.d_model))
    if cfg.family == "opt":
        h = h + params["pos_embed"][pos]

    def norm1(i, x):
        if cfg.family == "opt":
            return _layernorm(x, params[f"l{i}.ln1"], params[f"l{i}.ln1b"], eps)
        return _rmsnorm(x, params[f"l{i}.ln1"], eps, cfg.family == "gemma")

    def norm2(i, x):
        if cfg.family == "opt":
            return _layernorm(x, params[f"l{i}.ln2"], params[f"l{i}.ln2b"], eps)
        return _rmsnorm(x, params[f"l{i}.ln2"], eps, cfg.family == "gemma")

    mask = jnp.tril(jnp.ones((S, S), jnp.float32))
    neg = jnp.float32(-1e9)

    for i in range(cfg.n_layers):
        p = f"l{i}."
        x = norm1(i, h)
        q = lin(p + "wq", x, params[p + "wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = lin(p + "wk", x, params[p + "wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        v = lin(p + "wv", x, params[p + "wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        if cfg.family == "qwen":
            q = _rmsnorm(q, params[p + "qnorm"], eps)
            k = _rmsnorm(k, params[p + "knorm"], eps)
        if cfg.family in ("qwen", "gemma"):
            q = _rope(q, pos, cfg.head_dim)
            k = _rope(k, pos, cfg.head_dim)
        rep = cfg.n_heads // cfg.n_kv_heads
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        att = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(cfg.head_dim)
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhst,bthd->bshd", att, v).reshape(B, S, cfg.d_attn)
        h = h + lin(p + "wo", o, params[p + "wo"])

        x = norm2(i, h)
        if cfg.family == "opt":
            m = jax.nn.relu(lin(p + "up", x, params[p + "up"]))
        else:
            gate = lin(p + "gate", x, params[p + "gate"])
            up = lin(p + "up", x, params[p + "up"])
            act = jax.nn.silu(gate) if cfg.family == "qwen" else jax.nn.gelu(gate)
            m = act * up
        h = h + lin(p + "down", m, params[p + "down"])

    if cfg.family == "opt":
        h = _layernorm(h, params["lnf"], params["lnfb"], eps)
    else:
        h = _rmsnorm(h, params["lnf"], eps, cfg.family == "gemma")

    logits = h @ params["embed"].T  # tied LM head (never quantized)
    return logits, taps


def nll_from_logits(logits: jnp.ndarray, tokens: jnp.ndarray):
    """Sum next-token NLL and count over (B, S)."""
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll), jnp.float32(nll.size)


# ---------------------------------------------------------------------------
# AOT entry points (weights passed positionally in schema order)
# ---------------------------------------------------------------------------

def _params_from_list(cfg: ModelConfig, weights: tuple) -> dict:
    names = [n for n, _ in param_schema(cfg)]
    assert len(names) == len(weights)
    return dict(zip(names, weights))


def make_entry(cfg: ModelConfig, variant: str):
    """Returns fn(tokens, [qmax,] *weights) -> tuple of outputs."""

    if variant == "nll":
        def fn(tokens, *weights):
            params = _params_from_list(cfg, weights)
            logits, _ = forward(cfg, params, tokens, "plain")
            s, c = nll_from_logits(logits, tokens)
            return (s, c)
        return fn

    if variant == "logits":
        def fn(tokens, *weights):
            params = _params_from_list(cfg, weights)
            logits, _ = forward(cfg, params, tokens, "plain")
            return (logits,)
        return fn

    if variant in ("stats", "corr"):
        def fn(tokens, *weights):
            params = _params_from_list(cfg, weights)
            logits, taps = forward(cfg, params, tokens, variant)
            s, c = nll_from_logits(logits, tokens)
            outs = [s, c]
            for t in taps:
                outs.append(t["norms"])
            if variant == "corr":
                for t in taps:
                    outs.append(t["corr"])
            return tuple(outs)
        return fn

    if variant == "ttq":
        def fn(tokens, qmax, *weights):
            params = _params_from_list(cfg, weights)
            logits, _ = forward(cfg, params, tokens, "ttq", qmax=qmax)
            s, c = nll_from_logits(logits, tokens)
            return (s, c)
        return fn

    raise ValueError(f"unknown variant {variant}")
