"""Build-time trainer for the miniature model zoo.

Trains each registry model (model.CONFIGS) on a domain mixture of the
synthetic corpora (wt2s/ptbs/c4s/vqas/acts) with a hand-rolled Adam +
cosine schedule. Checkpoints are cached under ``artifacts/ckpt/`` so
`make artifacts` only trains once; aot.py consumes the checkpoints.

This is the "fwd/bwd" half of L2: the same `model.forward` graph is
differentiated here with jax.grad. It runs once at build time — never on
the rust request path.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model

# Training mixture: every eval domain participates so each model has
# sane statistics everywhere (the paper's LLMs saw web-scale mixtures).
TRAIN_DOMAINS = ["wt2s", "ptbs", "c4s", "vqas", "acts"]
BATCH = 32
SEQ = 64


def _adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def train_model(
    cfg: model.ModelConfig,
    steps: int = 800,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 100,
    log=print,
) -> tuple[dict, list[float]]:
    """Returns (trained params, loss history)."""
    params = model.init_params(cfg, seed=seed)

    def loss_fn(p, tokens):
        logits, _ = model.forward(cfg, p, tokens, "plain")
        s, c = model.nll_from_logits(logits, tokens)
        return s / c

    @jax.jit
    def step_fn(p, opt_m, opt_v, t, tokens):
        loss, grads = jax.value_and_grad(loss_fn)(p, tokens)
        b1, b2, eps = 0.9, 0.95, 1e-8
        # cosine decay with 5% warmup
        warm = 0.05 * steps
        frac = jnp.minimum(t / warm, 1.0)
        prog = jnp.clip((t - warm) / jnp.maximum(steps - warm, 1.0), 0.0, 1.0)
        cur_lr = lr * frac * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        new_p, new_m, new_v = {}, {}, {}
        for k in p:
            g = grads[k]
            m = b1 * opt_m[k] + (1 - b1) * g
            v = b2 * opt_v[k] + (1 - b2) * g * g
            mh = m / (1 - b1 ** (t + 1))
            vh = v / (1 - b2 ** (t + 1))
            new_p[k] = p[k] - cur_lr * mh / (jnp.sqrt(vh) + eps)
            new_m[k], new_v[k] = m, v
        return loss, new_p, new_m, new_v

    # Pre-generate the training stream (python loops are the slow part).
    streams = {d: corpus.CorpusStream(d, corpus.TRAIN, stream_id=seed) for d in TRAIN_DOMAINS}
    per_dom = steps // len(TRAIN_DOMAINS) + 1
    batches = {d: s.batches(per_dom, BATCH, SEQ) for d, s in streams.items()}

    opt = _adam_init(params)
    m, v = opt["m"], opt["v"]
    hist: list[float] = []
    t0 = time.time()
    for t in range(steps):
        d = TRAIN_DOMAINS[t % len(TRAIN_DOMAINS)]
        tokens = jnp.asarray(batches[d][t // len(TRAIN_DOMAINS)])
        loss, params, m, v = step_fn(params, m, v, jnp.float32(t), tokens)
        hist.append(float(loss))
        if log_every and (t % log_every == 0 or t == steps - 1):
            log(f"  [{cfg.name}] step {t:4d} loss {float(loss):.4f} "
                f"({time.time()-t0:.1f}s)")
    return params, hist


def save_checkpoint(path: str, cfg: model.ModelConfig, params: dict, hist):
    os.makedirs(path, exist_ok=True)
    np.savez(
        os.path.join(path, f"{cfg.name}.npz"),
        **{k: np.asarray(v) for k, v in params.items()},
    )
    with open(os.path.join(path, f"{cfg.name}.loss.json"), "w") as f:
        json.dump(hist, f)


def load_checkpoint(path: str, cfg: model.ModelConfig) -> dict | None:
    fp = os.path.join(path, f"{cfg.name}.npz")
    if not os.path.exists(fp):
        return None
    data = np.load(fp)
    names = [n for n, _ in model.param_schema(cfg)]
    if set(names) != set(data.files):
        return None  # schema changed; retrain
    return {k: jnp.asarray(data[k]) for k in names}


def train_or_load(cfg: model.ModelConfig, ckpt_dir: str, steps: int, log=print):
    params = load_checkpoint(ckpt_dir, cfg)
    if params is not None:
        log(f"  [{cfg.name}] checkpoint cache hit")
        return params
    params, hist = train_model(cfg, steps=steps, log=log)
    save_checkpoint(ckpt_dir, cfg, params, hist)
    return params


def steps_for(cfg: model.ModelConfig) -> int:
    return {2: 500, 4: 700, 6: 800}.get(cfg.n_layers, 700)
