"""AOT path tests: lowering to HLO text, manifest integrity, golden dump."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_lower_nll_produces_hlo_text():
    cfg = model.CONFIGS["opt-micro"]
    text = aot.lower_entry(cfg, "nll", 1)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lower_ttq_contains_runtime_qmax_param():
    cfg = model.CONFIGS["qwen-micro"]
    text = aot.lower_entry(cfg, "ttq", 1)
    assert "HloModule" in text
    # tokens + qmax + all weights
    n_params = len(model.param_schema(cfg)) + 2
    assert f"parameter({n_params - 1})" in text


def test_manifest_offsets_contiguous():
    cfg = model.CONFIGS["opt-micro"]
    params = model.init_params(cfg)
    with tempfile.TemporaryDirectory() as d:
        man = aot.dump_weights(d, cfg, params)
        off = 0
        for t in man["tensors"]:
            assert t["offset"] == off
            off += t["numel"]
        blob = os.path.getsize(os.path.join(d, f"{cfg.name}.weights.bin"))
        assert blob == off * 4


def test_weights_bin_roundtrip():
    cfg = model.CONFIGS["qwen-micro"]
    params = model.init_params(cfg, seed=3)
    with tempfile.TemporaryDirectory() as d:
        man = aot.dump_weights(d, cfg, params)
        raw = np.fromfile(
            os.path.join(d, f"{cfg.name}.weights.bin"), dtype="<f4")
        for t in man["tensors"]:
            got = raw[t["offset"]:t["offset"] + t["numel"]].reshape(t["shape"])
            np.testing.assert_array_equal(got, np.asarray(params[t["name"]]))


def test_quant_golden_dump():
    with tempfile.TemporaryDirectory() as d:
        aot.dump_quant_golden(d)
        with open(os.path.join(d, "golden", "quant_golden.json")) as f:
            g = json.load(f)
        assert len(g["w"]) == 8 * 64
        assert "q3_g32" in g["cases"]
        # rtn of the golden W at q=3 is reproducible here
        from compile.kernels import ref
        w = jnp.asarray(np.asarray(g["w"], np.float32).reshape(8, 64))
        want = np.asarray(ref.rtn_ref(w, 7.0, 32)).flatten()
        np.testing.assert_allclose(g["cases"]["q3_g32"]["rtn"], want,
                                   atol=1e-6)


def test_stats_output_arity():
    """stats HLO must return 2 + n_linears outputs; corr 2 + 2*n_linears."""
    cfg = model.CONFIGS["opt-micro"]
    n_lin = len(model.linear_schema(cfg))
    fn = model.make_entry(cfg, "stats")
    toks = jnp.zeros((1, aot.SEQ), jnp.int32)
    ws = [model.init_params(cfg)[n] for n, _ in model.param_schema(cfg)]
    outs = fn(toks, *ws)
    assert len(outs) == 2 + n_lin
    fn2 = model.make_entry(cfg, "corr")
    outs2 = fn2(toks, *ws)
    assert len(outs2) == 2 + 2 * n_lin


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "BUILD_OK")),
    reason="artifacts not built")
def test_built_artifacts_complete():
    """After `make artifacts` every (model, variant, bucket) file exists."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    for name in model.CONFIGS:
        assert os.path.exists(os.path.join(root, f"{name}.manifest.json"))
        assert os.path.exists(os.path.join(root, f"{name}.weights.bin"))
        for variant, buckets in aot.BUCKETS.items():
            for b in buckets:
                p = os.path.join(root, f"{name}_{variant}_b{b}.hlo.txt")
                assert os.path.exists(p), p
