"""Synthetic corpus engine: determinism, domain statistics, golden fixture."""

import collections
import json
import math
import os

import numpy as np
import pytest

from compile import corpus


def test_determinism():
    a = corpus.CorpusStream("wt2s", corpus.TRAIN).tokens(256)
    b = corpus.CorpusStream("wt2s", corpus.TRAIN).tokens(256)
    np.testing.assert_array_equal(a, b)


def test_splits_differ_but_share_language():
    tr = corpus.CorpusStream("ptbs", corpus.TRAIN).tokens(512)
    ev = corpus.CorpusStream("ptbs", corpus.EVAL).tokens(512)
    assert not np.array_equal(tr, ev)
    # shared language: bigram sets overlap heavily
    big_tr = set(zip(tr[:-1].tolist(), tr[1:].tolist()))
    big_ev = set(zip(ev[:-1].tolist(), ev[1:].tolist()))
    inter = len(big_tr & big_ev) / max(1, min(len(big_tr), len(big_ev)))
    assert inter > 0.3


def test_domains_differ():
    streams = {
        d: corpus.CorpusStream(d, corpus.TRAIN).tokens(2048)
        for d in ("wt2s", "ptbs", "c4s")
    }
    vocabs = {d: len(set(t.tolist())) for d, t in streams.items()}
    assert vocabs["ptbs"] < vocabs["wt2s"] <= vocabs["c4s"]


def _unigram_entropy(toks):
    c = collections.Counter(toks.tolist())
    n = len(toks)
    return -sum((v / n) * math.log(v / n) for v in c.values())


def test_entropy_ordering():
    """c4s (web-like) must be the highest-entropy domain, ptbs lowest."""
    ent = {
        d: _unigram_entropy(corpus.CorpusStream(d, corpus.TRAIN).tokens(4096))
        for d in ("wt2s", "ptbs", "c4s")
    }
    assert ent["ptbs"] < ent["wt2s"] < ent["c4s"]


def test_tokens_in_range():
    for d, spec in corpus.DOMAINS.items():
        t = corpus.CorpusStream(d, corpus.EVAL).tokens(512)
        assert t.min() >= 1
        assert t.max() <= spec.vocab_used


def test_predictability_of_acts():
    """The VLA-proxy domain must be near-deterministic (success-rate
    evaluation needs a learnable ground-truth continuation). acts is an
    order-2 Markov language, so condition on the full (prev2, prev1)
    context when estimating its entropy."""
    s = corpus.CorpusStream("acts", corpus.TRAIN)
    toks = s.tokens(8192).tolist()
    tri = collections.defaultdict(collections.Counter)
    for a, b, c in zip(toks, toks[1:], toks[2:]):
        tri[(a, b)][c] += 1
    h = 0.0
    n = len(toks) - 2
    for ctx, cnt in tri.items():
        tot = sum(cnt.values())
        for v in cnt.values():
            h -= (v / n) * math.log(v / tot)
    assert h < 1.0, h  # strongly predictable given its true context


def test_batches_shape_and_bos():
    b = corpus.CorpusStream("wt2s", corpus.TRAIN).batches(3, 4, 16)
    assert b.shape == (3, 4, 16)
    assert (b[:, :, 0] == corpus.BOS).all()
    assert (b[:, :, 1:] >= 1).all()


def test_zipf_quantile_bounds():
    cdf = corpus.zipf_cdf(corpus.DOMAINS["wt2s"])
    assert corpus.zipf_quantile(cdf, 0.0) == 0
    assert corpus.zipf_quantile(cdf, 0.999999) == len(cdf) - 1


def test_golden_fixture_stable():
    """The fixture consumed by the rust tests must stay frozen; if this
    fails the corpus algorithm changed and rust/src/corpus must follow."""
    fix = corpus.golden_fixture()
    assert set(fix) == {
        f"{d}/{s}" for d in corpus.DOMAINS for s in ("train", "eval", "calib")
    }
    for v in fix.values():
        assert len(v) == 64
    # spot values pinned (regenerate deliberately if the algorithm changes)
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                       "corpus_golden.json")
    if os.path.exists(art):
        with open(art) as f:
            frozen = json.load(f)
        assert frozen == fix
