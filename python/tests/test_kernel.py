"""Pallas kernels (L1) vs the pure-jnp reference — the core correctness
signal of the compile path. Hypothesis sweeps shapes/bits/groups."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qdq, ref, ttq

ATOL = 2e-4


def _rand(shape, seed):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32))


# --------------------------------------------------------------------------
# rtn_qdq kernel
# --------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    ddash=st.sampled_from([8, 16, 32, 96]),
    d=st.sampled_from([32, 64, 128]),
    q=st.integers(2, 8),
    g=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2 ** 16),
)
def test_rtn_qdq_matches_ref(ddash, d, q, g, seed):
    w = _rand((ddash, d), seed)
    qmax = jnp.float32(2.0 ** q - 1)
    got = qdq.rtn_qdq(w, qmax, g=g)
    want = ref.rtn_ref(w, float(qmax), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_rtn_qdq_group_spanning_rows():
    """Flat grouping: g larger than a row still matches the ref."""
    w = _rand((8, 16), 3)
    got = qdq.rtn_qdq(w, jnp.float32(7.0), g=64)
    want = ref.rtn_ref(w, 7.0, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_rtn_qdq_odd_block_shrink():
    """Group count not divisible by the default block: kernel must shrink."""
    w = _rand((6, 32), 4)  # 6 groups of g=32
    got = qdq.rtn_qdq(w, jnp.float32(15.0), g=32, block_groups=64)
    want = ref.rtn_ref(w, 15.0, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_rtn_qdq_runtime_qmax_consistency():
    """One artifact, many bit-widths: qmax is a runtime input."""
    w = _rand((16, 64), 5)
    for q in (2, 3, 4, 5):
        got = qdq.rtn_qdq(w, jnp.float32(2.0 ** q - 1), g=32)
        want = ref.rtn_ref(w, 2.0 ** q - 1, 32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


# --------------------------------------------------------------------------
# awq_diag kernel
# --------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    d=st.sampled_from([32, 64, 128, 192]),
    t=st.sampled_from([1, 7, 16, 64]),
    p=st.sampled_from([0.5, 1.0, 2.0, 4.0]),
    seed=st.integers(0, 2 ** 16),
)
def test_awq_diag_matches_ref(d, t, p, seed):
    x = _rand((d, t), seed)
    got = qdq.awq_diag(x, p=p, lam=0.4, alpha=0.5)
    want = ref.awq_diag(x, p, 0.4, 0.5)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=ATOL, rtol=1e-4)


@pytest.mark.parametrize("alpha", [0.0, 0.25, 0.5, 0.75, 1.0])
@pytest.mark.parametrize("lam", [0.01, 0.4, 1.0])
def test_awq_diag_hyperparams(alpha, lam):
    x = _rand((64, 32), 9)
    got = qdq.awq_diag(x, p=2.0, lam=lam, alpha=alpha)
    want = ref.awq_diag(x, 2.0, lam, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


# --------------------------------------------------------------------------
# fused ttq_linear kernel
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    ddash=st.sampled_from([16, 48, 96, 128]),
    d=st.sampled_from([32, 64, 128]),
    t=st.sampled_from([1, 5, 16]),
    q=st.integers(2, 5),
    seed=st.integers(0, 2 ** 16),
)
def test_ttq_linear_matches_ref(ddash, d, t, q, seed):
    w = _rand((ddash, d), seed)
    x = _rand((d, t), seed + 1)
    qmax = jnp.float32(2.0 ** q - 1)
    got = ttq.ttq_linear(x, w, qmax, g=32)
    want = ref.ttq_linear_ref(x, w, float(qmax), 32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    r=st.sampled_from([1, 4, 16]),
    q=st.integers(2, 5),
    seed=st.integers(0, 2 ** 16),
)
def test_ttq_linear_lowrank_matches_ref(r, q, seed):
    w = _rand((48, 64), seed)
    x = _rand((64, 9), seed + 1)
    b, a = ref.lowrank_init_ref(w, r)
    qmax = jnp.float32(2.0 ** q - 1)
    got = ttq.ttq_linear_lowrank(x, w, b, a, qmax, g=32)
    want = ref.ttq_linear_ref(x, w, float(qmax), 32, b=b, a=a)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3)


def test_ttq_linear_block_partitioning_invariance():
    """Result must not depend on the d' tile size (pure data parallel)."""
    w, x = _rand((128, 64), 11), _rand((64, 8), 12)
    qmax = jnp.float32(7.0)
    outs = [
        np.asarray(ttq.ttq_linear(x, w, qmax, g=32, block_d=bd))
        for bd in (16, 32, 64, 128)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5)


def test_awq_prescaled_matches_ttq_when_same_x():
    """Fig. 1(a) vs (b): identical when calibration X == live X."""
    w, x = _rand((48, 64), 13), _rand((64, 16), 14)
    qmax = jnp.float32(7.0)
    dvec = qdq.awq_diag(x, p=2.0, lam=0.4, alpha=0.5)
    y_awq = ttq.awq_prescaled_linear(x, w, dvec, qmax, g=32)
    y_ttq = ttq.ttq_linear(x, w, qmax, g=32)
    np.testing.assert_allclose(
        np.asarray(y_awq), np.asarray(y_ttq), atol=1e-5)


def test_awq_prescaled_differs_under_domain_shift():
    """Stale calibration produces a *different* (worse) projection — the
    domain-shift mechanism TTQ removes."""
    w = _rand((48, 64), 15)
    x_live = _rand((64, 16), 16)
    rng = np.random.default_rng(17)
    x_stale = jnp.asarray(
        (rng.normal(size=(64, 16)) * rng.lognormal(0, 2, (64, 1))
         ).astype(np.float32))
    qmax = jnp.float32(3.0)
    d_stale = qdq.awq_diag(x_stale, p=2.0, lam=0.4, alpha=0.5)
    y_stale = ttq.awq_prescaled_linear(x_live, w, d_stale, qmax, g=32)
    y_live = ttq.ttq_linear(x_live, w, qmax, g=32)
    y_true = w @ x_live
    e_stale = float(jnp.sum((y_true - y_stale) ** 2))
    e_live = float(jnp.sum((y_true - y_live) ** 2))
    assert e_live < e_stale
