"""L2 model-family tests: shapes, causality, stats taps, TTQ forward."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model

MICROS = ["opt-micro", "qwen-micro", "gemma-micro"]


def _tokens(b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(1, 512, size=(b, s)).astype(np.int32)
    t[:, 0] = corpus.BOS
    return jnp.asarray(t)


@pytest.mark.parametrize("name", list(model.CONFIGS))
def test_schema_consistency(name):
    cfg = model.CONFIGS[name]
    schema = model.param_schema(cfg)
    names = [n for n, _ in schema]
    assert len(names) == len(set(names)), "duplicate tensor names"
    params = model.init_params(cfg)
    assert set(params) == set(names)
    for n, shape in schema:
        assert params[n].shape == shape
    # every quantizable linear is a real 2D tensor with matching dims
    for lin in model.linear_schema(cfg):
        w = params[lin["name"]]
        assert w.shape == (lin["d_out"], lin["d_in"])
        assert lin["d_in"] % 32 == 0, "TTQ groupsize must divide d_in"


@pytest.mark.parametrize("name", MICROS)
def test_forward_shapes(name):
    cfg = model.CONFIGS[name]
    params = model.init_params(cfg)
    toks = _tokens()
    logits, taps = model.forward(cfg, params, toks, "plain")
    assert logits.shape == (2, 32, cfg.vocab)
    assert taps == []
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", MICROS)
def test_causality(name):
    """Changing a future token must not change past logits."""
    cfg = model.CONFIGS[name]
    params = model.init_params(cfg)
    t1 = _tokens(1, 32, 1)
    t2 = t1.at[0, 20].set((int(t1[0, 20]) % 511) + 1)
    l1, _ = model.forward(cfg, params, t1, "plain")
    l2, _ = model.forward(cfg, params, t2, "plain")
    np.testing.assert_allclose(
        np.asarray(l1[0, :20]), np.asarray(l2[0, :20]), atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 20:]), np.asarray(l2[0, 20:]))


@pytest.mark.parametrize("name", MICROS)
def test_stats_taps_order_and_values(name):
    """Tap order must equal linear_schema order; norms must match a
    direct computation from the traced activations."""
    cfg = model.CONFIGS[name]
    params = model.init_params(cfg)
    toks = _tokens()
    _, taps = model.forward(cfg, params, toks, "stats")
    schema = model.linear_schema(cfg)
    assert [t["name"] for t in taps] == [l["name"] for l in schema]
    for t, l in zip(taps, schema):
        assert t["norms"].shape == (len(model.NORM_PS), l["d_in"])
        assert bool(jnp.all(t["norms"] >= 0))


@pytest.mark.parametrize("name", MICROS)
def test_corr_taps_psd(name):
    """XᵀX must be symmetric PSD with trace = Σ|x|² (norms p=2 row)."""
    cfg = model.CONFIGS[name]
    params = model.init_params(cfg)
    _, taps = model.forward(cfg, params, _tokens(), "corr")
    for t in taps:
        c = np.asarray(t["corr"])
        assert np.allclose(c, c.T, atol=1e-3)
        tr = np.trace(c)
        p2 = np.sum(np.asarray(t["norms"])[2])  # NORM_PS[2] == 2.0
        assert np.isclose(tr, p2, rtol=1e-4)
        evals = np.linalg.eigvalsh(c)
        assert evals.min() > -1e-2


@pytest.mark.parametrize("name", MICROS)
def test_ttq_forward_close_to_plain_at_high_bits(name):
    """8-bit online quantization must barely move the NLL."""
    cfg = model.CONFIGS[name]
    params = model.init_params(cfg)
    toks = _tokens()
    lp, _ = model.forward(cfg, params, toks, "plain")
    lq, _ = model.forward(cfg, params, toks, "ttq", qmax=jnp.float32(255.0))
    sp, c = model.nll_from_logits(lp, toks)
    sq, _ = model.nll_from_logits(lq, toks)
    assert abs(float(sp - sq)) / float(c) < 0.05


@pytest.mark.parametrize("name", MICROS)
def test_ttq_forward_degrades_at_2bit(name):
    cfg = model.CONFIGS[name]
    params = model.init_params(cfg)
    toks = _tokens()
    lp, _ = model.forward(cfg, params, toks, "plain")
    lq, _ = model.forward(cfg, params, toks, "ttq", qmax=jnp.float32(3.0))
    sp, _ = model.nll_from_logits(lp, toks)
    sq, _ = model.nll_from_logits(lq, toks)
    assert float(sq) != float(sp)  # quantization visibly acts
    assert bool(jnp.isfinite(sq))


def test_nll_matches_manual():
    cfg = model.CONFIGS["opt-micro"]
    params = model.init_params(cfg)
    toks = _tokens(1, 16)
    logits, _ = model.forward(cfg, params, toks, "plain")
    s, c = model.nll_from_logits(logits, toks)
    lp = np.asarray(jnp.log(jnp.exp(logits[0, :-1]) /
                            jnp.sum(jnp.exp(logits[0, :-1]), -1,
                                    keepdims=True)))
    manual = -sum(lp[i, int(toks[0, i + 1])] for i in range(15))
    assert np.isclose(float(s), manual, rtol=1e-3)
    assert float(c) == 15.0


def test_entry_weight_ordering_respected():
    """make_entry must bind positional weights by schema order."""
    cfg = model.CONFIGS["qwen-micro"]
    params = model.init_params(cfg)
    ws = [params[n] for n, _ in model.param_schema(cfg)]
    fn = model.make_entry(cfg, "nll")
    toks = _tokens()
    s1, c1 = fn(toks, *ws)
    logits, _ = model.forward(cfg, params, toks, "plain")
    s2, c2 = model.nll_from_logits(logits, toks)
    assert np.isclose(float(s1), float(s2), rtol=1e-5)


def test_gqa_families_differ():
    """The three families must produce genuinely different functions."""
    toks = _tokens()
    outs = []
    for name in MICROS:
        cfg = model.CONFIGS[name]
        params = model.init_params(cfg, seed=0)
        logits, _ = model.forward(cfg, params, toks, "plain")
        outs.append(np.asarray(logits))
    assert not np.allclose(outs[0], outs[1])
    assert not np.allclose(outs[1], outs[2])
