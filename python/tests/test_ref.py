"""Mathematical invariants of the pure-jnp reference oracles.

These pin down the *semantics* of the paper's equations before any
kernel or rust code is compared against them.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


def _w(shape=(16, 64), seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32))


def _x(shape=(64, 24), seed=1):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32))


class TestRTN:
    @pytest.mark.parametrize("q", [2, 3, 4, 5, 8])
    @pytest.mark.parametrize("g", [16, 32, 64])
    def test_error_bounded_by_half_step(self, q, g):
        """|W − Ŵ| ≤ S/2 per group: the defining RTN property (Eq. 1)."""
        w = _w()
        qmax = 2.0 ** q - 1
        what = ref.rtn_ref(w, qmax, g)
        wg = np.asarray(w).reshape(-1, g)
        s = (wg.max(1) - wg.min(1)) / qmax
        err = np.abs(np.asarray(what).reshape(-1, g) - wg)
        assert np.all(err <= s[:, None] / 2 + 1e-6)

    def test_idempotent(self):
        """QDQ of an already-quantized weight is a fixed point."""
        w = _w()
        w1 = ref.rtn_ref(w, 15.0, 32)
        w2 = ref.rtn_ref(w1, 15.0, 32)
        assert np.allclose(np.asarray(w1), np.asarray(w2), atol=2e-6)

    def test_levels_count(self):
        """Quantized values take at most 2^q distinct levels per group."""
        w = _w((4, 32))
        what = np.asarray(ref.rtn_ref(w, 3.0, 32))  # q=2
        for row in what.reshape(-1, 32):
            assert len(np.unique(np.round(row, 5))) <= 4

    def test_more_bits_less_error(self):
        w = _w()
        errs = [
            float(jnp.sum((w - ref.rtn_ref(w, 2.0 ** q - 1, 32)) ** 2))
            for q in (2, 3, 4, 5, 8)
        ]
        assert all(a > b for a, b in zip(errs, errs[1:]))

    def test_smaller_groups_less_error(self):
        w = _w()
        errs = [
            float(jnp.sum((w - ref.rtn_ref(w, 7.0, g)) ** 2))
            for g in (8, 32, 128, 512)
        ]
        assert all(a <= b + 1e-9 for a, b in zip(errs, errs[1:]))

    def test_constant_group_exact(self):
        """All-equal group has scale 0 → dequantizes exactly to Z."""
        w = jnp.ones((2, 32)) * 0.37
        what = ref.rtn_ref(w, 7.0, 32)
        assert np.allclose(np.asarray(what), 0.37, atol=1e-7)

    def test_flat_grouping_spans_rows(self):
        """g > d is legal: grouping runs over the flattened weight."""
        w = _w((8, 16))
        what = ref.rtn_ref(w, 7.0, 64)  # 64 > 16
        assert what.shape == (8, 16)

    def test_symmetric_format(self):
        w = _w()
        what = ref.rtn_ref(w, 15.0, 32, symmetric=True)
        # symmetric has fewer degrees of freedom => never better than asym
        e_sym = float(jnp.sum((w - what) ** 2))
        e_asym = float(jnp.sum((w - ref.rtn_ref(w, 15.0, 32)) ** 2))
        assert e_sym >= e_asym - 1e-6

    def test_expansion_factor(self):
        """ν≈0.95 (App. D) changes the result but stays a valid QDQ."""
        w = _w()
        what = ref.rtn_ref(w, 7.0, 32, nu=0.95)
        assert float(jnp.max(jnp.abs(w - what))) < 1.0


class TestAWQ:
    def test_diag_positive(self):
        d = ref.awq_diag(_x(), 2.0, 0.4, 0.5)
        assert np.all(np.asarray(d) > 0)

    def test_alpha_zero_is_rtn(self):
        """α = 0 ⇒ D = 1 ⇒ AWQ degenerates to plain RTN."""
        w, x = _w(), _x()
        awq = ref.awq_ref(x, w, 7.0, 32, 2.0, 0.4, 0.0)
        rtn = ref.rtn_ref(w, 7.0, 32)
        assert np.allclose(np.asarray(awq), np.asarray(rtn), atol=1e-5)

    def test_awq_beats_rtn_on_activation_loss(self):
        """The paper's core claim at the single-layer level (Eq. 2):
        activation-aware scaling reduces ‖(W−Ŵ)X‖² vs plain RTN when
        the activation has non-uniform channel energies."""
        rng = np.random.default_rng(3)
        # strongly non-isotropic activations (outlier channels, as in LLMs)
        scales = rng.lognormal(0.0, 1.5, size=(64, 1)).astype(np.float32)
        x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32) * scales)
        w = _w((32, 64), seed=4)
        l_rtn = float(ref.approx_loss_ref(w, ref.rtn_ref(w, 3.0, 32), x))
        l_awq = float(ref.approx_loss_ref(
            w, ref.awq_ref(x, w, 3.0, 32, 2.0, 0.4, 0.5), x))
        assert l_awq < l_rtn

    @pytest.mark.parametrize("p", [0.5, 1.0, 2.0, 4.0])
    def test_p_norms(self, p):
        d = np.asarray(ref.awq_diag(_x(), p, 0.4, 0.5))
        assert d.shape == (64,) and np.all(np.isfinite(d))

    def test_diag_matches_manual(self):
        x = _x()
        d = np.asarray(ref.awq_diag(x, 2.0, 0.4, 0.5))
        manual = (np.linalg.norm(np.asarray(x), axis=1) + 0.4) ** 0.5
        assert np.allclose(d, manual, atol=1e-5)


class TestTTQLowRank:
    def test_lowrank_init_reconstructs(self):
        """BA equals the top-r SVD truncation (Eq. 31-33)."""
        w = _w((16, 64))
        b, a = ref.lowrank_init_ref(w, 16)
        u, s, vt = np.linalg.svd(np.asarray(w), full_matrices=False)
        w_r = (u[:, :16] * s[:16]) @ vt[:16]
        assert np.allclose(np.asarray(b @ a), w_r, atol=1e-4)

    def test_full_rank_residual_small(self):
        w = _w((16, 64))
        b, a = ref.lowrank_init_ref(w, 16)  # r = d' → exact
        assert float(jnp.max(jnp.abs(w - b @ a))) < 1e-4

    def test_lowrank_reduces_2bit_error(self):
        """TTQ(r>0) ≤ TTQ(r=0) on activation loss — Table 3's trend."""
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
        w = _w((48, 64), seed=6)
        y_true = w @ x
        y0 = ref.ttq_linear_ref(x, w, 3.0, 32)
        b, a = ref.lowrank_init_ref(w, 16)
        y16 = ref.ttq_linear_ref(x, w, 3.0, 32, b=b, a=a)
        e0 = float(jnp.sum((y_true - y0) ** 2))
        e16 = float(jnp.sum((y_true - y16) ** 2))
        assert e16 < e0

    def test_rank0_matches_awq_path(self):
        x, w = _x(), _w()
        y = ref.ttq_linear_ref(x, w, 7.0, 32)
        yq = ref.awq_ref(x, w, 7.0, 32, 2.0, 0.4, 0.5) @ x
        assert np.allclose(np.asarray(y), np.asarray(yq), atol=1e-4)
