"""Trainer smoke tests (fast: tiny step counts)."""

import os
import tempfile

import numpy as np

from compile import model, train


def test_loss_decreases():
    cfg = model.CONFIGS["opt-micro"]
    _, hist = train.train_model(cfg, steps=60, log_every=0)
    assert hist[-1] < hist[0] * 0.8


def test_checkpoint_roundtrip():
    cfg = model.CONFIGS["qwen-micro"]
    params, hist = train.train_model(cfg, steps=5, log_every=0)
    with tempfile.TemporaryDirectory() as d:
        train.save_checkpoint(d, cfg, params, hist)
        loaded = train.load_checkpoint(d, cfg)
        assert loaded is not None
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(params[k]), np.asarray(loaded[k]))


def test_checkpoint_schema_mismatch_returns_none():
    cfg_a = model.CONFIGS["qwen-micro"]
    cfg_b = model.CONFIGS["opt-micro"]
    params, hist = train.train_model(cfg_a, steps=2, log_every=0)
    with tempfile.TemporaryDirectory() as d:
        train.save_checkpoint(d, cfg_a, params, hist)
        os.rename(os.path.join(d, f"{cfg_a.name}.npz"),
                  os.path.join(d, f"{cfg_b.name}.npz"))
        assert train.load_checkpoint(d, cfg_b) is None


def test_steps_for_scales_with_depth():
    assert (train.steps_for(model.CONFIGS["opt-micro"])
            <= train.steps_for(model.CONFIGS["opt-small"]))
