//! Execution backends — where forward passes actually run.
//!
//! The evaluator and the serving coordinator consume four model-level
//! operations (the artifact variants of `python/compile/aot.py`): full
//! `logits`, summed `nll`, the per-linear activation `stats` pass, and
//! the fused single-pass TTQ kernel. [`ExecBackend`] abstracts those
//! four behind one trait with two implementations:
//!
//! * [`PjrtBackend`] — the original path: AOT-compiled HLO-text
//!   artifacts executed through the PJRT CPU client (needs
//!   `make artifacts` and the real `xla` crate).
//! * [`NativeBackend`] — a pure-Rust transformer forward pass over
//!   [`crate::linalg::Mat`], driven directly by the
//!   [`crate::models::Manifest`] contract (opt/qwen/gemma families).
//!   Runs anywhere a Rust toolchain exists — no artifacts, no PJRT —
//!   and additionally offers a packed-W4 *execution* mode in which
//!   every quantizable linear is evaluated by a grouped int-matmul
//!   kernel over [`crate::quant::Packed`] weights.
//!
//! [`testmodel`] generates deterministic seeded synthetic models
//! (manifest + weights) mirroring `python/compile/model.py::CONFIGS`,
//! so the whole eval/serving stack runs end-to-end with zero build
//! artifacts — the integration suite falls back to it automatically.

pub mod native;
pub mod pjrt;
pub mod testmodel;

pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use std::path::Path;

use anyhow::Result;

use crate::linalg::Mat;
use crate::models::ModelWeights;
use crate::quant::ActStats;

/// Result of one activation-statistics pass over a batch.
pub struct BatchStats {
    /// Sum of next-token NLL over the batch (the stats artifact emits
    /// it alongside the taps; callers may ignore it).
    pub nll_sum: f64,
    /// Token count behind `nll_sum` (batch × (seq − 1)).
    pub nll_count: f64,
    /// Per-linear accumulated norm sums, in manifest `linears` order,
    /// each already `accumulate`d with batch × seq tokens.
    pub stats: Vec<ActStats>,
    /// Per-linear input correlations XᵀX; empty unless requested.
    pub corr: Vec<Mat>,
}

/// One execution engine for the three model-level artifact variants.
///
/// All methods take the weights explicitly: quantization state lives in
/// the caller ([`crate::eval::Evaluator`] substitutes quantized linears
/// into its `ModelWeights`), the backend only executes.
pub trait ExecBackend: Send + Sync {
    /// Short identifier for logs/CLI (`"pjrt"` / `"native"`).
    fn name(&self) -> &'static str;

    /// Directory holding `<model>.manifest.json` + `<model>.weights.bin`.
    fn models_dir(&self) -> &Path;

    /// Load a model's weights. The native backend falls back to the
    /// deterministic [`testmodel`] generator when the files are absent.
    fn load_model(&self, model: &str) -> Result<ModelWeights> {
        ModelWeights::load(self.models_dir(), model)
    }

    /// Full logits, flat `(batch × seq × vocab)` row-major.
    fn logits(&self, weights: &ModelWeights, tokens: &[i32], batch: usize) -> Result<Vec<f32>>;

    /// Summed next-token NLL: returns `(nll_sum, token_count)`.
    fn nll(&self, weights: &ModelWeights, tokens: &[i32], batch: usize) -> Result<(f64, f64)>;

    /// Activation-statistics pass: per-linear norm sums (and the full
    /// input correlation when `with_corr`).
    fn stats(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
        with_corr: bool,
    ) -> Result<BatchStats>;

    /// Fused single-pass TTQ forward (Fig. 1b, L1 kernel): every
    /// quantizable linear is re-quantized from the live batch's own
    /// activation diagonal inside the forward. Returns `(nll_sum, count)`.
    fn nll_fused_ttq(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
        bits: u32,
    ) -> Result<(f64, f64)>;
}

/// The backend the CLI/examples/benches pick when not told otherwise:
/// PJRT when `make artifacts` has run, the native path everywhere else.
pub fn default_backend() -> Result<Box<dyn ExecBackend>> {
    if crate::artifacts_ready() {
        let rt = crate::runtime::Runtime::new(&crate::artifacts_dir())?;
        Ok(Box::new(PjrtBackend::new(rt)))
    } else {
        Ok(Box::new(NativeBackend::new(&crate::artifacts_dir())))
    }
}
