//! Execution backends — where forward passes actually run.
//!
//! The evaluator and the serving coordinator consume four model-level
//! operations (the artifact variants of `python/compile/aot.py`): full
//! `logits`, summed `nll`, the per-linear activation `stats` pass, and
//! the fused single-pass TTQ kernel. [`ExecBackend`] abstracts those
//! four behind one trait with two implementations:
//!
//! * [`PjrtBackend`] — the original path: AOT-compiled HLO-text
//!   artifacts executed through the PJRT CPU client (needs
//!   `make artifacts` and the real `xla` crate).
//! * [`NativeBackend`] — a pure-Rust transformer forward pass over
//!   [`crate::linalg::Mat`], driven directly by the
//!   [`crate::models::Manifest`] contract (opt/qwen/gemma families).
//!   Runs anywhere a Rust toolchain exists — no artifacts, no PJRT —
//!   and additionally offers a packed-W4 *execution* mode in which
//!   every quantizable linear is evaluated by a grouped int-matmul
//!   kernel over [`crate::quant::Packed`] weights.
//!
//! [`testmodel`] generates deterministic seeded synthetic models
//! (manifest + weights) mirroring `python/compile/model.py::CONFIGS`,
//! so the whole eval/serving stack runs end-to-end with zero build
//! artifacts — the integration suite falls back to it automatically.
//!
//! Since the decode-engine split, the trait also carries the
//! autoregressive pair [`ExecBackend::prefill`] /
//! [`ExecBackend::decode_step`]: a cached forward over a
//! [`crate::kvcache::KvCache`] whose decode step computes one token per
//! sequence instead of re-running the whole prefix — the memory-bound
//! phase where packed low-bit weights actually buy wall-clock. The
//! speculative-decoding subsystem ([`crate::specdec`]) adds
//! [`ExecBackend::verify_step`]: the same cached forward over a k-row
//! causal window, returning logits at *every* new position so a
//! full-precision verifier can score a quantized drafter's tokens in
//! one pass. Only the native backend implements the cached family
//! (PJRT artifacts are fixed-shape).

#![forbid(unsafe_code)]

pub mod native;
pub mod pjrt;
pub mod testmodel;

pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::kvcache::{KvCache, SeqId};
use crate::linalg::pool::WorkerPool;
use crate::linalg::Mat;
use crate::models::ModelWeights;
use crate::quant::ActStats;

/// Result of one activation-statistics pass over a batch.
pub struct BatchStats {
    /// Sum of next-token NLL over the batch (the stats artifact emits
    /// it alongside the taps; callers may ignore it).
    pub nll_sum: f64,
    /// Token count behind `nll_sum` (batch × (seq − 1)).
    pub nll_count: f64,
    /// Per-linear accumulated norm sums, in manifest `linears` order,
    /// each already `accumulate`d with batch × seq tokens.
    pub stats: Vec<ActStats>,
    /// Per-linear input correlations XᵀX; empty unless requested.
    pub corr: Vec<Mat>,
}

/// Output of one cached-forward step ([`ExecBackend::prefill`] /
/// [`ExecBackend::decode_step`] / [`ExecBackend::verify_step`]).
pub struct StepOut {
    /// Logits, flat row-major. Prefill/decode return the **last**
    /// position only, `(n_seqs × vocab)`; `verify_step` returns every
    /// new position, `(n_seqs × new_len × vocab)`.
    pub logits: Vec<f32>,
    /// Per-linear activation statistics tapped *inside* the step (in
    /// manifest `linears` order), when requested — this is what lets
    /// the online calibrator keep observing during decode, so drift can
    /// trigger requantization mid-generation.
    pub stats: Option<Vec<ActStats>>,
}

/// One execution engine for the three model-level artifact variants.
///
/// All methods take the weights explicitly: quantization state lives in
/// the caller ([`crate::eval::Evaluator`] substitutes quantized linears
/// into its `ModelWeights`), the backend only executes.
pub trait ExecBackend: Send + Sync {
    /// Short identifier for logs/CLI (`"pjrt"` / `"native"`).
    fn name(&self) -> &'static str;

    /// Directory holding `<model>.manifest.json` + `<model>.weights.bin`.
    fn models_dir(&self) -> &Path;

    /// Load a model's weights. The native backend falls back to the
    /// deterministic [`testmodel`] generator when the files are absent.
    fn load_model(&self, model: &str) -> Result<ModelWeights> {
        ModelWeights::load(self.models_dir(), model)
    }

    /// The persistent kernel worker pool this backend executes on, when
    /// it has one (native). Callers use it to share one pool across
    /// cooperating backends (the coordinator's speculative
    /// drafter/verifier) and to read cumulative kernel time
    /// ([`WorkerPool::kernel_us`]) for per-phase accounting. Backends
    /// that replay fixed artifacts (PJRT) return `None`.
    fn worker_pool(&self) -> Option<Arc<WorkerPool>> {
        None
    }

    /// Full logits, flat `(batch × seq × vocab)` row-major.
    fn logits(&self, weights: &ModelWeights, tokens: &[i32], batch: usize) -> Result<Vec<f32>>;

    /// Summed next-token NLL: returns `(nll_sum, token_count)`.
    fn nll(&self, weights: &ModelWeights, tokens: &[i32], batch: usize) -> Result<(f64, f64)>;

    /// Activation-statistics pass: per-linear norm sums (and the full
    /// input correlation when `with_corr`).
    fn stats(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
        with_corr: bool,
    ) -> Result<BatchStats>;

    /// Fused single-pass TTQ forward (Fig. 1b, L1 kernel): every
    /// quantizable linear is re-quantized from the live batch's own
    /// activation diagonal inside the forward. Returns `(nll_sum, count)`.
    fn nll_fused_ttq(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
        bits: u32,
    ) -> Result<(f64, f64)>;

    // -- the prefill/decode split (autoregressive serving) -------------

    /// Prefill: run the prompt(s) through the model once, writing every
    /// layer's K/V into the cache, and return the **last-position**
    /// logits per sequence. `tokens` is `(ids.len() × prompt_len)`
    /// row-major; all sequences in one call share a prompt length (the
    /// scheduler groups by length). With `with_stats`, per-linear
    /// activation norms over all prompt tokens ride along for the
    /// online calibrator.
    ///
    /// Backends without an incremental attention path (PJRT artifacts
    /// are compiled for fixed full-sequence shapes) return a clear
    /// unsupported error.
    fn prefill(
        &self,
        _weights: &ModelWeights,
        _tokens: &[i32],
        _cache: &mut KvCache,
        _ids: &[SeqId],
        _with_stats: bool,
    ) -> Result<StepOut> {
        bail!(
            "backend '{}' does not support cached prefill/decode — use the native backend",
            self.name()
        );
    }

    /// One decode step: advance every sequence by exactly one token
    /// (`last_tokens[i]` appended to sequence `ids[i]`), attending over
    /// the cached K/V, and return next-token logits `(ids.len() ×
    /// vocab)`. Sequences may be at different positions — this is the
    /// continuous-batching hot path.
    fn decode_step(
        &self,
        _weights: &ModelWeights,
        _last_tokens: &[i32],
        _cache: &mut KvCache,
        _ids: &[SeqId],
        _with_stats: bool,
    ) -> Result<StepOut> {
        bail!(
            "backend '{}' does not support cached prefill/decode — use the native backend",
            self.name()
        );
    }

    /// Score several new positions per sequence in **one** cached
    /// forward — the speculative-decoding verifier. `draft_tokens` is
    /// `(ids.len() × new_len)` row-major: each sequence's last committed
    /// token followed by its draft tokens. The k-row causal window
    /// generalizes [`Self::decode_step`]'s one-row attention: position
    /// `p` attends over the cached prefix plus the fresh rows `0..=p`,
    /// and the returned logits cover **every** new position
    /// (`ids.len() × new_len × vocab`), so the caller can accept the
    /// longest matching draft prefix and roll the cache back with
    /// [`KvCache::truncate`]. Per-row computation is identical to
    /// `decode_step`, which makes verification bit-exact against plain
    /// decode. With `with_stats`, per-linear activation norms over the
    /// verified tokens ride along for the online calibrator.
    fn verify_step(
        &self,
        _weights: &ModelWeights,
        _draft_tokens: &[i32],
        _cache: &mut KvCache,
        _ids: &[SeqId],
        _with_stats: bool,
    ) -> Result<StepOut> {
        bail!(
            "backend '{}' does not support speculative verification — use the native backend",
            self.name()
        );
    }
}

/// The backend the CLI/examples/benches pick when not told otherwise:
/// PJRT when `make artifacts` has run, the native path everywhere else.
pub fn default_backend() -> Result<Box<dyn ExecBackend>> {
    if crate::artifacts_ready() {
        let rt = crate::runtime::Runtime::new(&crate::artifacts_dir())?;
        Ok(Box::new(PjrtBackend::new(rt)))
    } else {
        Ok(Box::new(NativeBackend::new(&crate::artifacts_dir())))
    }
}
