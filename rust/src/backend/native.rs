//! Pure-Rust transformer forward pass — the artifact-free backend.
//!
//! Re-implements `python/compile/model.py::forward` on
//! [`crate::linalg::Mat`] for all three miniature families, driven only
//! by the [`Manifest`] contract:
//!
//! * **opt**   — LayerNorm(+bias), ReLU MLP, learned absolute positions
//! * **qwen**  — RMSNorm, SwiGLU, RoPE, GQA, per-head QK-norm
//! * **gemma** — RMSNorm(1+w), GeGLU, RoPE, MQA, √d-scaled embedding
//!
//! Four execution modes mirror the four AOT artifact variants: plain
//! (logits/nll), stats taps (per-linear Σ|x|^p on every
//! [`crate::models::LinearInfo`] input, feeding the online calibrator),
//! fused TTQ (per-linear diagonal from the live batch, quantize inside
//! the forward — the L1 Pallas kernel's semantics), and **packed W4**
//! (every quantizable linear executed by a grouped int-matmul directly
//! over [`crate::quant::Packed`] codes — dequantized group-by-group in
//! registers, never materializing the f32 weight).
//!
//! Dense projections and the cached-attention inner loops execute on a
//! persistent [`crate::linalg::pool::WorkerPool`] — parked worker
//! threads claim chunked row ranges per kernel call, replacing the
//! scoped-thread spawn/join every matmul used to pay. Every dispatch
//! goes through [`WorkerPool::run_rows_site`] with a
//! [`crate::obs::KernelCall`] describing its kind, shape and analytic
//! FLOP/byte counts (repo-lint R7), so an attached
//! [`crate::obs::Profiler`] can attribute pooled kernel time per site.
//!
//! The *instruction-level* inner loops of the two hot kernels
//! ([`matmul_bt_mt`] fp32 tile dots, [`packed_matmul_nt`] group dequant
//! + dot) dispatch through [`crate::linalg::simd`] on the pool's
//! selected ISA (AVX2 / NEON / scalar, `TTQ_FORCE_SCALAR` to pin):
//! W4 results are bit-exact across ISAs, fp32 within the documented
//! ULP bound — see `docs/ARCHITECTURE.md` § Kernel dispatch & numerics.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, Mutex, OnceLock, PoisonError};

use anyhow::{anyhow, bail, Result};

use super::{BatchStats, ExecBackend, StepOut};
use crate::kvcache::{KvCache, SeqId};
use crate::obs::{Clock, KernelCall};
use crate::linalg::pool::WorkerPool;
use crate::linalg::simd::{self, Isa};
use crate::linalg::Mat;
use crate::models::{Manifest, ModelWeights};
use crate::quant::{
    awq_quantize, diag_from_x, pack, rtn_quantize_int, ActStats, Packed, QuantSpec,
};

/// Norm epsilon shared with `python/compile/model.py::ModelConfig`.
const NORM_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------
// Pooled kernels
// ---------------------------------------------------------------------

/// `d_in` tile width of the cache-blocked fp32 kernels. Per output
/// element, tile-partial sums are accumulated in tile order — a fixed,
/// shape-independent summation order, so every caller (batched rows,
/// decode GEMV, serial fallback, any thread count) produces bit-identical
/// results *on a given ISA*. Across ISAs the per-tile dot re-associates
/// (the `linalg::simd` relaxed fp32 contract): scalar vs vector output
/// agrees within `util::FP32_MAX_ULPS` / `util::FP32_ABS_TOL`, asserted
/// by `rust/tests/simd_kernels.rs`.
const K_TILE: usize = 256;

/// One chunk of `a @ bᵀ` output rows, tiled over `d_in` so the streamed
/// `b` tile stays cache-resident while it is reused across the chunk's
/// rows. Shared by the pooled and serial paths of [`matmul_bt_mt`]; the
/// per-tile dot dispatches on `isa` ([`simd::dot_f32`] — scalar is the
/// historical strictly-sequential loop).
fn bt_rows(isa: Isa, a: &Mat, b: &Mat, r0: usize, orows: &mut [f32]) {
    let (k, n) = (a.cols, b.rows);
    if n == 0 {
        return;
    }
    let rows = orows.len() / n;
    let mut kt = 0;
    while kt < k {
        let ke = (kt + K_TILE).min(k);
        for rr in 0..rows {
            let arow = &a.row(r0 + rr)[kt..ke];
            let orow = &mut orows[rr * n..(rr + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b.row(j)[kt..ke];
                *o += simd::dot_f32(isa, arow, brow);
            }
        }
        kt = ke;
    }
}

/// The `m == 1` twin of [`bt_rows`]: one output row, chunked over the
/// `d_out` columns (`j0..`) instead of over rows — the only axis a
/// decode-time GEMV can fan out on. Identical tile-partial accumulation
/// order, so GEMV results match the batched kernel bit for bit.
fn gemv_cols(isa: Isa, arow: &[f32], b: &Mat, j0: usize, os: &mut [f32]) {
    let k = arow.len();
    let mut kt = 0;
    while kt < k {
        let ke = (kt + K_TILE).min(k);
        let at = &arow[kt..ke];
        for (jj, o) in os.iter_mut().enumerate() {
            let brow = &b.row(j0 + jj)[kt..ke];
            *o += simd::dot_f32(isa, at, brow);
        }
        kt = ke;
    }
}

/// `a @ bᵀ` on the worker pool, cache-blocked over `d_in`.
///
/// Batched calls (`m ≥ 2`, prefill/verify) chunk output *rows* across
/// the pool; a decode-time GEMV (`m == 1`) chunks the single output
/// row's *columns* (`d_out`) instead, so decode fans out too. The
/// serial-vs-parallel decision lives in [`WorkerPool::run_rows`]
/// (one flop-floor check, not one per kernel), and pooled output is
/// bit-identical to single-threaded output for every shape.
pub fn matmul_bt_mt(a: &Mat, b: &Mat, pool: &WorkerPool) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_bt_mt dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut out = Mat::zeros(m, n);
    let isa = pool.isa();
    let call = KernelCall::fp32_gemm(m, n, k).with_isa(isa);
    if m == 1 {
        pool.run_rows_site(&mut out.data, n, 1, k * n, call, |j0, os| {
            gemv_cols(isa, a.row(0), b, j0, os);
        });
    } else {
        pool.run_rows_site(&mut out.data, m, n, m * k * n, call, |r0, orows| {
            bt_rows(isa, a, b, r0, orows);
        });
    }
    out
}

/// Grouped int-matmul over the packed weight: `Y = X Ŵᵀ` with
/// X `(n, d_in)` row-major tokens and Ŵ the `(d_out, d_in)` packed
/// tensor. Each weight group (the `d_in` tile of this kernel) is
/// dequantized once into a stack buffer and streamed across all n token
/// rows — the register-resident dequant of `marlin_gemm`, CPU edition.
/// Output rows are computed transposed so the pool's chunks own disjoint
/// slices; the chunked axis is `d_out`, which keeps a decode-time GEMV
/// (`n == 1`) fanning out across weight rows instead of falling back to
/// serial.
pub fn packed_matmul_nt(p: &Packed, x: &Mat, pool: &WorkerPool) -> Mat {
    assert_eq!(p.cols, x.cols, "packed_matmul_nt dim mismatch");
    let (n, d_in, d_out) = (x.rows, x.cols, p.rows);
    let g = p.group;
    if d_in % g != 0 {
        // flat groups spanning rows: defer to the general kernel
        return crate::quant::packed_matmul(p, &x.transpose()).transpose();
    }
    let groups_per_row = d_in / g;
    let mut yt = Mat::zeros(d_out, n);
    let isa = pool.isa();
    let call = KernelCall::packed_w4(n, d_out, d_in, p.bits, g).with_isa(isa);
    pool.run_rows_site(&mut yt.data, d_out, n, n * d_in * d_out, call, |r0, yrows| {
        let mut wbuf = vec![0.0f32; g];
        let rows = yrows.len() / n;
        for rr in 0..rows {
            let r = r0 + rr;
            let yrow = &mut yrows[rr * n..(rr + 1) * n];
            for bg in 0..groups_per_row {
                let gi = r * groups_per_row + bg;
                let (s, z) = (p.scales[gi], p.zeros[gi]);
                // Dequant + dot both dispatch on the pool's ISA and are
                // bit-exact across ISAs (elementwise dequant rounding
                // and canonical-lane accumulation — the W4 half of the
                // `linalg::simd` numerics contract).
                simd::w4_dequant_group(isa, p, gi * g, s, z, &mut wbuf);
                let xbase = bg * g;
                for (t, y) in yrow.iter_mut().enumerate() {
                    let xrow = &x.row(t)[xbase..xbase + g];
                    *y += simd::w4_dot(isa, &wbuf, xrow);
                }
            }
        }
    });
    yt.transpose()
}

// ---------------------------------------------------------------------
// Forward-pass building blocks
// ---------------------------------------------------------------------

fn layernorm(x: &Mat, w: &[f32], b: &[f32], eps: f32) -> Mat {
    let d = x.cols;
    assert_eq!(w.len(), d);
    assert_eq!(b.len(), d);
    let mut out = Mat::zeros(x.rows, d);
    for r in 0..x.rows {
        let row = x.row(r);
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = out.row_mut(r);
        for i in 0..d {
            orow[i] = (row[i] - mu) * inv * w[i] + b[i];
        }
    }
    out
}

fn rmsnorm(x: &Mat, w: &[f32], eps: f32, unit_offset: bool) -> Mat {
    let d = x.cols;
    assert_eq!(w.len(), d);
    let mut out = Mat::zeros(x.rows, d);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(r);
        for i in 0..d {
            let scale = if unit_offset { 1.0 + w[i] } else { w[i] };
            orow[i] = row[i] * inv * scale;
        }
    }
    out
}

/// Per-head RMS-norm over contiguous `head_dim` slices (Qwen QK-norm).
fn headnorm_inplace(x: &mut Mat, head_dim: usize, w: &[f32], eps: f32) {
    assert_eq!(w.len(), head_dim);
    for r in 0..x.rows {
        let row = x.row_mut(r);
        for head in row.chunks_mut(head_dim) {
            let ms = head.iter().map(|&v| v * v).sum::<f32>() / head_dim as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            for (v, &wi) in head.iter_mut().zip(w) {
                *v *= inv * wi;
            }
        }
    }
}

/// Standard rotary embedding (θ = 10⁴, half-split pairing) applied to
/// every `head_dim` slice; position = row index mod seq. The angle
/// depends only on (position, frequency), so the sin/cos table is built
/// once per call and shared across rows and heads — this sits on the
/// decode hot path the e2e bench times.
fn rope_inplace(x: &mut Mat, seq: usize, head_dim: usize) {
    let half = head_dim / 2;
    let freqs: Vec<f32> = (0..half)
        .map(|i| 1.0 / 10000f32.powf(i as f32 / half as f32))
        .collect();
    let mut trig = Vec::with_capacity(seq * half);
    for pos in 0..seq {
        for &f in &freqs {
            trig.push((pos as f32 * f).sin_cos());
        }
    }
    for r in 0..x.rows {
        let base = (r % seq) * half;
        let row = x.row_mut(r);
        for head in row.chunks_mut(head_dim) {
            for i in 0..half {
                let (sin, cos) = trig[base + i];
                let (x1, x2) = (head[i], head[half + i]);
                head[i] = x1 * cos - x2 * sin;
                head[half + i] = x1 * sin + x2 * cos;
            }
        }
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// tanh-approximate GELU (jax.nn.gelu's default).
fn gelu(v: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
}

fn add_inplace(h: &mut Mat, delta: &Mat) {
    debug_assert_eq!((h.rows, h.cols), (delta.rows, delta.cols));
    for (a, b) in h.data.iter_mut().zip(&delta.data) {
        *a += b;
    }
}

/// Per-channel Σ|x_i|^p over all token rows, for the stats-tap p-grid.
fn norm_sums(x: &Mat, ps: &[f64]) -> Vec<Vec<f64>> {
    let d = x.cols;
    let mut out = vec![vec![0.0f64; d]; ps.len()];
    for r in 0..x.rows {
        let row = x.row(r);
        for (k, &p) in ps.iter().enumerate() {
            let dst = &mut out[k];
            if (p - 2.0).abs() < 1e-9 {
                for (i, &v) in row.iter().enumerate() {
                    dst[i] += (v as f64) * (v as f64);
                }
            } else if (p - 1.0).abs() < 1e-9 {
                for (i, &v) in row.iter().enumerate() {
                    dst[i] += (v as f64).abs();
                }
            } else {
                for (i, &v) in row.iter().enumerate() {
                    dst[i] += (v as f64).abs().powf(p);
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// The forward pass
// ---------------------------------------------------------------------

/// How quantizable linears execute inside one forward.
enum ExecMode<'a> {
    /// Dense f32 (`logits` / `nll` artifacts).
    Plain,
    /// Dense f32 + per-linear activation taps (`stats` / `corr`).
    Stats { with_corr: bool },
    /// Per-linear diagonal from the live batch, quantize-in-forward
    /// (the fused L1 `ttq_linear` kernel).
    FusedTtq { spec: QuantSpec },
    /// Grouped int-matmul over pre-packed weights (name → packed).
    Packed(&'a HashMap<String, Packed>),
}

/// Per-linear `[n_p][d_in]` channel norm sums tapped during a forward.
type TapNorms = Vec<Vec<Vec<f64>>>;

struct Taps {
    norms: TapNorms,
    corr: Vec<Mat>,
}

struct ForwardOut {
    /// `(batch × seq, vocab)` logits.
    logits: Mat,
    taps: Taps,
}

fn need<'a>(w: &'a ModelWeights, name: &str) -> Result<&'a Mat> {
    w.get(name)
        .ok_or_else(|| anyhow!("tensor '{name}' missing from model weights"))
}

/// One quantizable projection `y = x Wᵀ` under the active mode, with
/// the stats tap on the *input* (the contract of the stats artifact).
fn proj(
    weights: &ModelWeights,
    mode: &ExecMode,
    taps: &mut Taps,
    pool: &WorkerPool,
    name: &str,
    x: &Mat,
) -> Result<Mat> {
    if let ExecMode::Stats { with_corr } = mode {
        taps.norms.push(norm_sums(x, &weights.manifest.norm_ps));
        if *with_corr {
            taps.corr.push(x.gram());
        }
    }
    let w = need(weights, name)?;
    match mode {
        ExecMode::Packed(map) => {
            let p = map
                .get(name)
                .ok_or_else(|| anyhow!("linear '{name}' not packed"))?;
            Ok(packed_matmul_nt(p, x, pool))
        }
        ExecMode::FusedTtq { spec } => {
            // D from the live batch via the shared quant-layer formula
            // (diag_from_x wants channels as rows, hence the transpose)
            let td = &weights.manifest.ttq_defaults;
            let d = diag_from_x(&x.transpose(), td.p, td.lam, td.alpha);
            let wq = awq_quantize(w, &d, spec);
            Ok(matmul_bt_mt(x, &wq, pool))
        }
        _ => Ok(matmul_bt_mt(x, w, pool)),
    }
}

fn forward(
    weights: &ModelWeights,
    tokens: &[i32],
    batch: usize,
    mode: ExecMode,
    pool: &WorkerPool,
) -> Result<ForwardOut> {
    let man: &Manifest = &weights.manifest;
    let cfg = &man.config;
    let (d, vocab) = (cfg.d_model, cfg.vocab);
    // The sequence length is derived, not fixed: any 1..=max_seq works
    // (the full-recompute decode baseline re-runs a growing prefix).
    if batch == 0 || tokens.is_empty() || tokens.len() % batch != 0 {
        bail!("token block is {} elements, not divisible into {batch} rows", tokens.len());
    }
    let seq = tokens.len() / batch;
    if seq > cfg.max_seq {
        bail!("sequence length {seq} exceeds model max_seq {}", cfg.max_seq);
    }
    let family = man.family.as_str();
    let (n_heads, n_kv, hd) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
    if n_kv == 0 || n_heads % n_kv != 0 {
        bail!("n_heads {} not divisible by n_kv_heads {}", n_heads, n_kv);
    }
    let d_attn = n_heads * hd;
    let rep = n_heads / n_kv;
    let n = batch * seq;
    let mut taps = Taps { norms: Vec::new(), corr: Vec::new() };

    // embedding (+ family-specific input treatment)
    let embed = need(weights, "embed")?;
    if (embed.rows, embed.cols) != (vocab, d) {
        bail!("embed shape {}x{} vs config {vocab}x{d}", embed.rows, embed.cols);
    }
    let mut h = Mat::zeros(n, d);
    for (r, &t) in tokens.iter().enumerate() {
        let t = t as usize;
        if t >= vocab {
            bail!("token {t} out of vocab range {vocab}");
        }
        h.row_mut(r).copy_from_slice(embed.row(t));
    }
    if family == "gemma" {
        let s = (d as f32).sqrt();
        for v in h.data.iter_mut() {
            *v *= s;
        }
    }
    if family == "opt" {
        let pos_embed = need(weights, "pos_embed")?;
        for r in 0..n {
            let row = h.row_mut(r);
            let prow = pos_embed.row(r % seq);
            for (a, b) in row.iter_mut().zip(prow) {
                *a += b;
            }
        }
    }

    for i in 0..cfg.n_layers {
        let p = format!("l{i}.");
        // -- attention block ------------------------------------------
        let x = match family {
            "opt" => layernorm(
                &h,
                need(weights, &format!("{p}ln1"))?.row(0),
                need(weights, &format!("{p}ln1b"))?.row(0),
                NORM_EPS,
            ),
            _ => rmsnorm(
                &h,
                need(weights, &format!("{p}ln1"))?.row(0),
                NORM_EPS,
                family == "gemma",
            ),
        };
        let mut q = proj(weights, &mode, &mut taps, pool, &format!("{p}wq"), &x)?;
        let mut k = proj(weights, &mode, &mut taps, pool, &format!("{p}wk"), &x)?;
        let v = proj(weights, &mode, &mut taps, pool, &format!("{p}wv"), &x)?;
        if family == "qwen" {
            headnorm_inplace(&mut q, hd, need(weights, &format!("{p}qnorm"))?.row(0), NORM_EPS);
            headnorm_inplace(&mut k, hd, need(weights, &format!("{p}knorm"))?.row(0), NORM_EPS);
        }
        if family != "opt" {
            rope_inplace(&mut q, seq, hd);
            rope_inplace(&mut k, seq, hd);
        }
        // causal GQA attention (kv head = query head / rep)
        let scale = 1.0 / (hd as f32).sqrt();
        let mut o = Mat::zeros(n, d_attn);
        let mut scores = vec![0.0f32; seq];
        for b in 0..batch {
            for head in 0..n_heads {
                let kvh = head / rep;
                for s in 0..seq {
                    let qrow = &q.row(b * seq + s)[head * hd..(head + 1) * hd];
                    let mut mx = f32::NEG_INFINITY;
                    for (t, sc) in scores.iter_mut().enumerate().take(s + 1) {
                        let krow = &k.row(b * seq + t)[kvh * hd..(kvh + 1) * hd];
                        let mut acc = 0.0f32;
                        for j in 0..hd {
                            acc += qrow[j] * krow[j];
                        }
                        *sc = acc * scale;
                        mx = mx.max(*sc);
                    }
                    let mut denom = 0.0f32;
                    for sc in scores.iter_mut().take(s + 1) {
                        *sc = (*sc - mx).exp();
                        denom += *sc;
                    }
                    let inv = 1.0 / denom;
                    let orow = &mut o.row_mut(b * seq + s)[head * hd..(head + 1) * hd];
                    for (t, &sc) in scores.iter().enumerate().take(s + 1) {
                        let wgt = sc * inv;
                        let vrow = &v.row(b * seq + t)[kvh * hd..(kvh + 1) * hd];
                        for j in 0..hd {
                            orow[j] += wgt * vrow[j];
                        }
                    }
                }
            }
        }
        let attn_out = proj(weights, &mode, &mut taps, pool, &format!("{p}wo"), &o)?;
        add_inplace(&mut h, &attn_out);

        // -- MLP block ------------------------------------------------
        let x = match family {
            "opt" => layernorm(
                &h,
                need(weights, &format!("{p}ln2"))?.row(0),
                need(weights, &format!("{p}ln2b"))?.row(0),
                NORM_EPS,
            ),
            _ => rmsnorm(
                &h,
                need(weights, &format!("{p}ln2"))?.row(0),
                NORM_EPS,
                family == "gemma",
            ),
        };
        let m = if family == "opt" {
            let mut up = proj(weights, &mode, &mut taps, pool, &format!("{p}up"), &x)?;
            for v in up.data.iter_mut() {
                *v = v.max(0.0);
            }
            up
        } else {
            let gate = proj(weights, &mode, &mut taps, pool, &format!("{p}gate"), &x)?;
            let up = proj(weights, &mode, &mut taps, pool, &format!("{p}up"), &x)?;
            let mut m = up;
            for (mv, &gv) in m.data.iter_mut().zip(&gate.data) {
                let act = if family == "qwen" { silu(gv) } else { gelu(gv) };
                *mv *= act;
            }
            m
        };
        let mlp_out = proj(weights, &mode, &mut taps, pool, &format!("{p}down"), &m)?;
        add_inplace(&mut h, &mlp_out);
    }

    let hf = match family {
        "opt" => layernorm(
            &h,
            need(weights, "lnf")?.row(0),
            need(weights, "lnfb")?.row(0),
            NORM_EPS,
        ),
        _ => rmsnorm(&h, need(weights, "lnf")?.row(0), NORM_EPS, family == "gemma"),
    };
    // tied LM head (never quantized — not a manifest linear)
    let logits = matmul_bt_mt(&hf, embed, pool);
    Ok(ForwardOut { logits, taps })
}

/// Rotary embedding for one row at an absolute position. The angle is
/// computed once per frequency into `trig` (len ≥ head_dim/2) and
/// shared across heads — this sits on the decode hot path. The trig
/// expression is exactly [`rope_inplace`]'s, so the cached incremental
/// forward stays bit-identical to the full one.
fn rope_row(row: &mut [f32], pos: usize, head_dim: usize, freqs: &[f32], trig: &mut [(f32, f32)]) {
    let half = head_dim / 2;
    for (t, &f) in trig.iter_mut().zip(freqs) {
        *t = (pos as f32 * f).sin_cos();
    }
    for head in row.chunks_mut(head_dim) {
        for i in 0..half {
            let (sin, cos) = trig[i];
            let (x1, x2) = (head[i], head[half + i]);
            head[i] = x1 * cos - x2 * sin;
            head[half + i] = x1 * sin + x2 * cos;
        }
    }
}

/// Projection for the cached forward: optional stats tap on the input
/// (manifest `linears` order — one push per quantizable projection, in
/// call order), then the projection in the active execution mode. The
/// tap is independent of the mode, so the calibrator keeps observing
/// during packed-W4 decode — that is what lets drift-triggered
/// requantization fire mid-generation.
fn cproj(
    weights: &ModelWeights,
    mode: &ExecMode,
    taps: Option<&mut TapNorms>,
    pool: &WorkerPool,
    name: &str,
    x: &Mat,
) -> Result<Mat> {
    if let Some(taps) = taps {
        taps.push(norm_sums(x, &weights.manifest.norm_ps));
    }
    let mut unused = Taps { norms: Vec::new(), corr: Vec::new() };
    proj(weights, mode, &mut unused, pool, name, x)
}

/// Incremental forward over cached K/V — the decode engine's kernel.
///
/// `tokens` is `(ids.len() × new_len)` row-major: `new_len` fresh
/// tokens per sequence (prefill runs the whole prompt, decode exactly
/// one token). Every layer's fresh K/V rows are written into `cache`
/// at the sequence's current length, attention reads the cached prefix
/// plus the fresh rows (causal by construction — position `p` only
/// ever sees rows `0..=p`), and the function returns **last-position**
/// logits `(ids.len(), vocab)`. Sequences may sit at different
/// positions — that is the continuous-batching decode batch.
///
/// Every per-row operation (norms, projections, rotary angles, softmax
/// accumulation order) matches [`forward`] exactly, which makes cached
/// decode bit-identical to re-running the full prefix — pinned by the
/// decode-engine golden tests.
///
/// `all_positions` selects the LM-head policy: `false` projects only
/// each sequence's **last** position (prefill/decode — one vocab GEMV
/// per sequence), `true` projects every fresh position (the
/// speculative verifier needs logits at all `new_len` rows to score the
/// drafts).
///
/// Returns the logits plus the tapped per-linear norm sums (empty
/// unless `with_stats`).
#[allow(clippy::too_many_arguments)]
fn forward_cached(
    weights: &ModelWeights,
    tokens: &[i32],
    cache: &mut KvCache,
    ids: &[SeqId],
    mode: &ExecMode,
    with_stats: bool,
    all_positions: bool,
    pool: &WorkerPool,
) -> Result<(Mat, TapNorms)> {
    let man: &Manifest = &weights.manifest;
    let cfg = &man.config;
    let family = man.family.as_str();
    let (d, vocab) = (cfg.d_model, cfg.vocab);
    let (n_heads, n_kv, hd) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
    if n_kv == 0 || n_heads % n_kv != 0 {
        bail!("n_heads {} not divisible by n_kv_heads {}", n_heads, n_kv);
    }
    let d_attn = n_heads * hd;
    let rep = n_heads / n_kv;
    let n_seqs = ids.len();
    if n_seqs == 0 || tokens.is_empty() || tokens.len() % n_seqs != 0 {
        bail!(
            "token block is {} elements, not divisible into {n_seqs} sequences",
            tokens.len()
        );
    }
    let new_len = tokens.len() / n_seqs;
    let kc_cfg = cache.config();
    if kc_cfg.n_layers != cfg.n_layers || kc_cfg.d_kv != n_kv * hd {
        bail!(
            "cache geometry ({} layers, d_kv {}) does not match model ({} layers, d_kv {})",
            kc_cfg.n_layers,
            kc_cfg.d_kv,
            cfg.n_layers,
            n_kv * hd
        );
    }
    let starts: Vec<usize> = ids.iter().map(|&id| cache.len(id)).collect();
    for (si, &start) in starts.iter().enumerate() {
        if start + new_len > cfg.max_seq {
            bail!(
                "sequence {si} at position {start} + {new_len} new tokens exceeds max_seq {}",
                cfg.max_seq
            );
        }
    }
    let n = n_seqs * new_len;
    // same frequency ladder as `rope_inplace`
    let half = hd / 2;
    let freqs: Vec<f32> = (0..half)
        .map(|i| 1.0 / 10000f32.powf(i as f32 / half as f32))
        .collect();
    let mut trig = vec![(0.0f32, 0.0f32); half];
    let mut taps: TapNorms = Vec::new();
    let cp = |taps: &mut TapNorms, name: &str, x: &Mat| {
        cproj(weights, mode, with_stats.then_some(taps), pool, name, x)
    };

    // embedding (+ family-specific input treatment)
    let embed = need(weights, "embed")?;
    if (embed.rows, embed.cols) != (vocab, d) {
        bail!("embed shape {}x{} vs config {vocab}x{d}", embed.rows, embed.cols);
    }
    let mut h = Mat::zeros(n, d);
    for (r, &t) in tokens.iter().enumerate() {
        let t = t as usize;
        if t >= vocab {
            bail!("token {t} out of vocab range {vocab}");
        }
        h.row_mut(r).copy_from_slice(embed.row(t));
    }
    if family == "gemma" {
        let s = (d as f32).sqrt();
        for v in h.data.iter_mut() {
            *v *= s;
        }
    }
    if family == "opt" {
        let pos_embed = need(weights, "pos_embed")?;
        for r in 0..n {
            let pos = starts[r / new_len] + r % new_len;
            let row = h.row_mut(r);
            let prow = pos_embed.row(pos);
            for (a, b) in row.iter_mut().zip(prow) {
                *a += b;
            }
        }
    }

    for i in 0..cfg.n_layers {
        let p = format!("l{i}.");
        // -- attention block ------------------------------------------
        let x = match family {
            "opt" => layernorm(
                &h,
                need(weights, &format!("{p}ln1"))?.row(0),
                need(weights, &format!("{p}ln1b"))?.row(0),
                NORM_EPS,
            ),
            _ => rmsnorm(
                &h,
                need(weights, &format!("{p}ln1"))?.row(0),
                NORM_EPS,
                family == "gemma",
            ),
        };
        let mut q = cp(&mut taps, &format!("{p}wq"), &x)?;
        let mut k_new = cp(&mut taps, &format!("{p}wk"), &x)?;
        let v_new = cp(&mut taps, &format!("{p}wv"), &x)?;
        if family == "qwen" {
            headnorm_inplace(&mut q, hd, need(weights, &format!("{p}qnorm"))?.row(0), NORM_EPS);
            headnorm_inplace(
                &mut k_new,
                hd,
                need(weights, &format!("{p}knorm"))?.row(0),
                NORM_EPS,
            );
        }
        if family != "opt" {
            for r in 0..n {
                let pos = starts[r / new_len] + r % new_len;
                rope_row(q.row_mut(r), pos, hd, &freqs, &mut trig);
                rope_row(k_new.row_mut(r), pos, hd, &freqs, &mut trig);
            }
        }
        // write the fresh K/V rows, then attend over cache + fresh
        for r in 0..n {
            let (si, j) = (r / new_len, r % new_len);
            cache.append_row(ids[si], i, starts[si] + j, k_new.row(r), v_new.row(r));
        }
        let scale = 1.0 / (hd as f32).sqrt();
        let mut o = Mat::zeros(n, d_attn);
        // Cached attention on the pool: every fresh position's output
        // row is independent (it reads the immutable cached prefix plus
        // the fresh K/V rows written above), so the row axis of `o`
        // chunks across worker lanes — a long prefill splits one
        // sequence's positions, a wide decode batch splits sequences.
        // Per-(seq, head, pos) arithmetic is exactly the serial loop's,
        // so chunking keeps the step bit-identical.
        let cache_ro: &KvCache = cache;
        let ctx_total: usize = starts.iter().map(|&s0| new_len * (s0 + new_len)).sum();
        let att_flops = ctx_total * d_attn * 2;
        let att_call = KernelCall::cached_attention(n, d_attn, ctx_total);
        pool.run_rows_site(&mut o.data, n, d_attn, att_flops, att_call, |r0, orows| {
            let mut scores = vec![0.0f32; cfg.max_seq];
            let rows = orows.len() / d_attn;
            for rr in 0..rows {
                let r = r0 + rr;
                let (si, j) = (r / new_len, r % new_len);
                let (kc, vc) = cache_ro.layer(ids[si], i);
                let pos = starts[si] + j;
                for head in 0..n_heads {
                    let kvh = head / rep;
                    let qrow = &q.row(r)[head * hd..(head + 1) * hd];
                    let mut mx = f32::NEG_INFINITY;
                    for (t, sc) in scores.iter_mut().enumerate().take(pos + 1) {
                        let krow = &kc.row(t)[kvh * hd..(kvh + 1) * hd];
                        let mut acc = 0.0f32;
                        for jj in 0..hd {
                            acc += qrow[jj] * krow[jj];
                        }
                        *sc = acc * scale;
                        mx = mx.max(*sc);
                    }
                    let mut denom = 0.0f32;
                    for sc in scores.iter_mut().take(pos + 1) {
                        *sc = (*sc - mx).exp();
                        denom += *sc;
                    }
                    let inv = 1.0 / denom;
                    let orow = &mut orows[rr * d_attn + head * hd..rr * d_attn + (head + 1) * hd];
                    for (t, &sc) in scores.iter().enumerate().take(pos + 1) {
                        let wgt = sc * inv;
                        let vrow = &vc.row(t)[kvh * hd..(kvh + 1) * hd];
                        for jj in 0..hd {
                            orow[jj] += wgt * vrow[jj];
                        }
                    }
                }
            }
        });
        let attn_out = cp(&mut taps, &format!("{p}wo"), &o)?;
        add_inplace(&mut h, &attn_out);

        // -- MLP block ------------------------------------------------
        let x = match family {
            "opt" => layernorm(
                &h,
                need(weights, &format!("{p}ln2"))?.row(0),
                need(weights, &format!("{p}ln2b"))?.row(0),
                NORM_EPS,
            ),
            _ => rmsnorm(
                &h,
                need(weights, &format!("{p}ln2"))?.row(0),
                NORM_EPS,
                family == "gemma",
            ),
        };
        let m = if family == "opt" {
            let mut up = cp(&mut taps, &format!("{p}up"), &x)?;
            for v in up.data.iter_mut() {
                *v = v.max(0.0);
            }
            up
        } else {
            let gate = cp(&mut taps, &format!("{p}gate"), &x)?;
            let up = cp(&mut taps, &format!("{p}up"), &x)?;
            let mut m = up;
            for (mv, &gv) in m.data.iter_mut().zip(&gate.data) {
                let act = if family == "qwen" { silu(gv) } else { gelu(gv) };
                *mv *= act;
            }
            m
        };
        let mlp_out = cp(&mut taps, &format!("{p}down"), &m)?;
        add_inplace(&mut h, &mlp_out);
    }

    let hf = match family {
        "opt" => layernorm(
            &h,
            need(weights, "lnf")?.row(0),
            need(weights, "lnfb")?.row(0),
            NORM_EPS,
        ),
        _ => rmsnorm(&h, need(weights, "lnf")?.row(0), NORM_EPS, family == "gemma"),
    };
    // commit the fresh positions across all layers
    for &id in ids {
        cache.advance(id, new_len)?;
    }
    if all_positions {
        // verifier path: logits at every fresh position
        return Ok((matmul_bt_mt(&hf, embed, pool), taps));
    }
    // tied LM head over the *last* position of each sequence only —
    // the decode payoff: one vocab GEMV per sequence, not per token
    let mut last = Mat::zeros(n_seqs, d);
    for si in 0..n_seqs {
        last.row_mut(si).copy_from_slice(hf.row((si + 1) * new_len - 1));
    }
    Ok((matmul_bt_mt(&last, embed, pool), taps))
}

/// Sum next-token NLL + count from `(batch × seq, vocab)` logits.
fn nll_from_logits(logits: &Mat, tokens: &[i32], batch: usize, seq: usize) -> (f64, f64) {
    let vocab = logits.cols;
    let mut sum = 0.0f64;
    let mut count = 0.0f64;
    for b in 0..batch {
        for s in 0..seq - 1 {
            let row = logits.row(b * seq + s);
            let tgt = tokens[b * seq + s + 1] as usize;
            debug_assert!(tgt < vocab);
            let lse = crate::util::logsumexp(row);
            sum += lse - row[tgt] as f64;
            count += 1.0;
        }
    }
    (sum, count)
}

// ---------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------

/// One packed-cache entry: (weights version, packed linears by name).
type PackedEntry = (u64, Arc<HashMap<String, Packed>>);

/// Pure-Rust execution backend. Construct with the models directory
/// (missing models fall back to [`super::testmodel`]); call
/// [`NativeBackend::with_exec_quant`] to run every quantizable linear
/// through the packed grouped int-matmul instead of dense f32. All
/// kernels execute on one persistent [`WorkerPool`] — size it with
/// [`NativeBackend::with_threads`] or share another backend's pool via
/// [`NativeBackend::with_pool`] (the coordinator wires its speculative
/// drafter/verifier backends onto the serving pool this way).
pub struct NativeBackend {
    models_dir: PathBuf,
    /// Lazily spawned on first use, so builder chains like
    /// `new().with_threads(t)` never spawn-and-join a pool for nothing.
    pool: OnceLock<Arc<WorkerPool>>,
    exec_spec: Option<QuantSpec>,
    /// Packed-weight cache keyed by model name. Versions are globally
    /// unique (see [`ModelWeights::version`]), so a stale entry can
    /// never alias a requantized generation.
    packed: Mutex<HashMap<String, PackedEntry>>,
    /// Packed-cache rebuilds so far (first pack + every version-miss
    /// repack after a requant) — observability for how often requants
    /// actually force a repack ([`NativeBackend::repacks`]).
    repacks: AtomicU64,
}

impl NativeBackend {
    /// Backend over `models_dir`; the worker pool is hardware-sized
    /// ([`WorkerPool::default_threads`]) unless overridden before first
    /// use, and spawned lazily on the first kernel.
    pub fn new(models_dir: &Path) -> Self {
        NativeBackend {
            models_dir: models_dir.to_path_buf(),
            pool: OnceLock::new(),
            exec_spec: None,
            packed: Mutex::new(HashMap::new()),
            repacks: AtomicU64::new(0),
        }
    }

    /// Packed-weight cache rebuilds so far: the first pack of each model
    /// plus one repack per weight-version miss (i.e. per requant that
    /// actually reached this backend's packed execution path).
    pub fn repacks(&self) -> u64 {
        // Relaxed: monotone metrics counter, never ordered against
        // other shared state.
        self.repacks.load(Ordering::Relaxed)
    }

    /// Execute quantizable linears as packed grouped int-matmuls at the
    /// given bits/groupsize (the measured "TTQ speedup" configuration).
    pub fn with_exec_quant(mut self, spec: QuantSpec) -> Self {
        self.exec_spec = Some(spec);
        self
    }

    /// Use a pool of `threads` lanes (CLI `--threads`; benches use it
    /// for thread sweeps). A no-op when the pool is already that size.
    pub fn with_threads(mut self, threads: usize) -> Self {
        let threads = threads.max(1);
        if self.pool.get().map_or(true, |p| p.threads() != threads) {
            self.pool = OnceLock::from(Arc::new(WorkerPool::new(threads)));
        }
        self
    }

    /// Share an existing pool instead of owning one — every backend on
    /// the same pool draws from one set of threads (prefill, decode,
    /// verify and speculative drafting never oversubscribe the host).
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = OnceLock::from(pool);
        self
    }

    /// The kernel worker pool (thread count, cumulative kernel time),
    /// spawning the hardware-sized default on first use.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        self.pool
            .get_or_init(|| Arc::new(WorkerPool::with_default_threads()))
    }

    /// The packed execution spec, if any.
    pub fn exec_quant(&self) -> Option<&QuantSpec> {
        self.exec_spec.as_ref()
    }

    fn packed_for(
        &self,
        weights: &ModelWeights,
        spec: &QuantSpec,
    ) -> Result<Arc<HashMap<String, Packed>>> {
        // Poison recovery instead of unwrap (serving-path rule R3): a
        // panic on another thread mid-insert leaves at worst a missing
        // cache entry, which the rebuild below repairs.
        let mut cache = self.packed.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some((ver, packed)) = cache.get(&weights.manifest.name) {
            if *ver == weights.version() {
                return Ok(packed.clone());
            }
        }
        let mut map = HashMap::new();
        let profiler = self.pool().profiler().cloned();
        for lin in &weights.manifest.linears {
            let w = need(weights, &lin.name)?;
            if w.data.len() % spec.group != 0 {
                bail!(
                    "linear {} numel {} not divisible by groupsize {}",
                    lin.name,
                    w.data.len(),
                    spec.group
                );
            }
            // Quant-pack is serial (not a pool dispatch), so attribute it
            // directly; a fresh real clock reads 0 at creation (R5 keeps
            // raw `Instant` out of this file).
            let t = Clock::real();
            map.insert(lin.name.clone(), pack(&rtn_quantize_int(w, spec)));
            if let Some(prof) = profiler.as_ref() {
                let call = KernelCall::quant_pack(w.rows, w.cols, spec.bits, spec.group);
                prof.record(&call, t.now_us());
            }
        }
        let arc = Arc::new(map);
        cache.insert(weights.manifest.name.clone(), (weights.version(), arc.clone()));
        // Relaxed: metrics counter (see `repacks`).
        self.repacks.fetch_add(1, Ordering::Relaxed);
        Ok(arc)
    }

    /// Forward in the backend's execution mode (packed when configured).
    fn exec_forward(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
    ) -> Result<ForwardOut> {
        match &self.exec_spec {
            Some(spec) => {
                let packed = self.packed_for(weights, spec)?;
                forward(weights, tokens, batch, ExecMode::Packed(packed.as_ref()), self.pool())
            }
            None => forward(weights, tokens, batch, ExecMode::Plain, self.pool()),
        }
    }

    /// Cached forward in the backend's execution mode, with the tapped
    /// norms folded into per-linear [`ActStats`] when requested. Note
    /// the taps measure activations *as executed* (packed mode taps the
    /// quantized-execution activations) — exactly what the online
    /// calibrator should track for the weights actually being served.
    fn cached_step(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        cache: &mut KvCache,
        ids: &[SeqId],
        with_stats: bool,
        all_positions: bool,
    ) -> Result<StepOut> {
        let (logits, tap_norms) = match &self.exec_spec {
            Some(spec) => {
                let packed = self.packed_for(weights, spec)?;
                let mode = ExecMode::Packed(packed.as_ref());
                forward_cached(
                    weights,
                    tokens,
                    cache,
                    ids,
                    &mode,
                    with_stats,
                    all_positions,
                    self.pool(),
                )?
            }
            None => forward_cached(
                weights,
                tokens,
                cache,
                ids,
                &ExecMode::Plain,
                with_stats,
                all_positions,
                self.pool(),
            )?,
        };
        let stats = if with_stats {
            let linears = &weights.manifest.linears;
            if tap_norms.len() != linears.len() {
                bail!("{} stats taps for {} linears", tap_norms.len(), linears.len());
            }
            let ps = &weights.manifest.norm_ps;
            let n_tokens = tokens.len() as f64;
            Some(
                tap_norms
                    .iter()
                    .zip(linears)
                    .map(|(sums, lin)| {
                        debug_assert_eq!(sums[0].len(), lin.d_in);
                        let mut st = ActStats::new(ps, lin.d_in);
                        st.accumulate(sums, n_tokens);
                        st
                    })
                    .collect(),
            )
        } else {
            None
        };
        Ok(StepOut { logits: logits.data, stats })
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn models_dir(&self) -> &Path {
        &self.models_dir
    }

    fn worker_pool(&self) -> Option<Arc<WorkerPool>> {
        Some(self.pool().clone())
    }

    fn load_model(&self, model: &str) -> Result<ModelWeights> {
        // Fall back to synthetic weights only when no manifest exists at
        // all. A present-but-corrupt artifact must surface as an error —
        // silently substituting untrained weights would let a truncated
        // `make artifacts` masquerade as trained-model numbers.
        let manifest = self.models_dir.join(format!("{model}.manifest.json"));
        if manifest.exists() {
            return ModelWeights::load(&self.models_dir, model);
        }
        super::testmodel::build(model).map_err(|e| {
            anyhow!("no weights at {manifest:?} and no synthetic fallback: {e}")
        })
    }

    fn logits(&self, weights: &ModelWeights, tokens: &[i32], batch: usize) -> Result<Vec<f32>> {
        Ok(self.exec_forward(weights, tokens, batch)?.logits.data)
    }

    fn nll(&self, weights: &ModelWeights, tokens: &[i32], batch: usize) -> Result<(f64, f64)> {
        let out = self.exec_forward(weights, tokens, batch)?;
        Ok(nll_from_logits(&out.logits, tokens, batch, tokens.len() / batch))
    }

    fn stats(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
        with_corr: bool,
    ) -> Result<BatchStats> {
        // stats always run dense f32: the taps measure the model's true
        // activations, exactly like the stats artifact.
        let out = forward(weights, tokens, batch, ExecMode::Stats { with_corr }, self.pool())?;
        let seq = tokens.len() / batch;
        let linears = &weights.manifest.linears;
        if out.taps.norms.len() != linears.len() {
            bail!(
                "{} stats taps for {} linears",
                out.taps.norms.len(),
                linears.len()
            );
        }
        let ps = &weights.manifest.norm_ps;
        let n_tokens = (batch * seq) as f64;
        let mut stats = Vec::with_capacity(linears.len());
        for (sums, lin) in out.taps.norms.iter().zip(linears) {
            debug_assert_eq!(sums[0].len(), lin.d_in);
            let mut st = ActStats::new(ps, lin.d_in);
            st.accumulate(sums, n_tokens);
            stats.push(st);
        }
        let (nll_sum, nll_count) = nll_from_logits(&out.logits, tokens, batch, seq);
        Ok(BatchStats { nll_sum, nll_count, stats, corr: out.taps.corr })
    }

    fn nll_fused_ttq(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
        bits: u32,
    ) -> Result<(f64, f64)> {
        let g = weights.manifest.ttq_defaults.g;
        let out = forward(
            weights,
            tokens,
            batch,
            ExecMode::FusedTtq { spec: QuantSpec::new(bits, g) },
            self.pool(),
        )?;
        Ok(nll_from_logits(&out.logits, tokens, batch, tokens.len() / batch))
    }

    fn prefill(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        cache: &mut KvCache,
        ids: &[SeqId],
        with_stats: bool,
    ) -> Result<StepOut> {
        for &id in ids {
            if cache.len(id) != 0 {
                bail!("prefill into a non-empty sequence (len {})", cache.len(id));
            }
        }
        self.cached_step(weights, tokens, cache, ids, with_stats, false)
    }

    fn decode_step(
        &self,
        weights: &ModelWeights,
        last_tokens: &[i32],
        cache: &mut KvCache,
        ids: &[SeqId],
        with_stats: bool,
    ) -> Result<StepOut> {
        if last_tokens.len() != ids.len() {
            bail!(
                "{} last tokens for {} sequences in decode batch",
                last_tokens.len(),
                ids.len()
            );
        }
        for &id in ids {
            if cache.len(id) == 0 {
                bail!("decode_step on an unprefilled sequence");
            }
        }
        self.cached_step(weights, last_tokens, cache, ids, with_stats, false)
    }

    fn verify_step(
        &self,
        weights: &ModelWeights,
        draft_tokens: &[i32],
        cache: &mut KvCache,
        ids: &[SeqId],
        with_stats: bool,
    ) -> Result<StepOut> {
        if ids.is_empty() || draft_tokens.is_empty() || draft_tokens.len() % ids.len() != 0 {
            bail!(
                "verify_step token block is {} elements, not divisible into {} sequences",
                draft_tokens.len(),
                ids.len()
            );
        }
        for &id in ids {
            if cache.len(id) == 0 {
                bail!("verify_step on an unprefilled sequence");
            }
        }
        self.cached_step(weights, draft_tokens, cache, ids, with_stats, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::rtn_dequantize;

    #[test]
    fn threaded_matmul_matches_single() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(37, 48, &mut rng);
        let b = Mat::randn(29, 48, &mut rng);
        let st = a.matmul_bt(&b);
        for threads in [1usize, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let got = matmul_bt_mt(&a, &b, &pool);
            for (x, y) in got.data.iter().zip(&st.data) {
                assert!((x - y).abs() < 1e-5);
            }
        }
        let big_a = Mat::randn(96, 64, &mut rng);
        let big_b = Mat::randn(80, 64, &mut rng);
        let want = big_a.matmul_bt(&big_b);
        let got = matmul_bt_mt(&big_a, &big_b, &WorkerPool::new(4));
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn pooled_matmul_bit_identical_to_single_threaded() {
        // The pool contract: chunking must never change a single bit,
        // across odd shapes — m = 1 (GEMV), m < threads, non-divisible
        // chunk splits, and d_in crossing the K_TILE boundary.
        let mut rng = Rng::new(11);
        let serial = WorkerPool::new(1);
        for (m, k, n) in [
            (1usize, 64usize, 512usize), // decode GEMV, d_out fan-out
            (1, 300, 700),               // GEMV with k spanning tiles
            (3, 64, 512),                // fewer rows than threads
            (7, 300, 129),               // nothing divides anything
            (64, 257, 96),               // k just past one tile
        ] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(n, k, &mut rng);
            let want = matmul_bt_mt(&a, &b, &serial);
            for threads in [2usize, 4, 5] {
                let pool = WorkerPool::new(threads);
                let got = matmul_bt_mt(&a, &b, &pool);
                assert_eq!(
                    got.data, want.data,
                    "({m},{k},{n}) x {threads} threads: pooled != serial"
                );
            }
        }
    }

    #[test]
    fn pooled_packed_matmul_bit_identical_to_single_threaded() {
        let mut rng = Rng::new(12);
        let serial = WorkerPool::new(1);
        for (n, d_in, d_out) in [(1usize, 128usize, 1024usize), (3, 64, 96), (9, 320, 77)] {
            let w = Mat::randn(d_out, d_in, &mut rng);
            let x = Mat::randn(n, d_in, &mut rng);
            let qi = rtn_quantize_int(&w, &QuantSpec::new(4, 32));
            let p = pack(&qi);
            let want = packed_matmul_nt(&p, &x, &serial);
            for threads in [2usize, 4, 5] {
                let pool = WorkerPool::new(threads);
                let got = packed_matmul_nt(&p, &x, &pool);
                assert_eq!(
                    got.data, want.data,
                    "({n},{d_in},{d_out}) x {threads} threads: pooled != serial"
                );
            }
        }
    }

    #[test]
    fn packed_gemv_fans_out_on_d_out() {
        // the n == 1 decode GEMV must take the pooled path when d_out is
        // large (the old kernel keyed serial fallback on n < 2) — pin
        // the value equivalence at a shape that crosses the flop floor
        let mut rng = Rng::new(13);
        let w = Mat::randn(1024, 96, &mut rng);
        let x = Mat::randn(1, 96, &mut rng);
        let qi = rtn_quantize_int(&w, &QuantSpec::new(4, 32));
        let p = pack(&qi);
        let want = packed_matmul_nt(&p, &x, &WorkerPool::new(1));
        let got = packed_matmul_nt(&p, &x, &WorkerPool::new(4));
        assert_eq!(got.data, want.data, "pooled GEMV != serial GEMV");
    }

    #[test]
    fn packed_matmul_nt_matches_dequant() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(48, 64, &mut rng);
        let x = Mat::randn(33, 64, &mut rng); // (n, d_in)
        let serial = WorkerPool::new(1);
        for bits in [2u32, 4, 8] {
            let qi = rtn_quantize_int(&w, &QuantSpec::new(bits, 32));
            let p = pack(&qi);
            let want = matmul_bt_mt(&x, &rtn_dequantize(&qi), &serial);
            for threads in [1usize, 4] {
                let pool = WorkerPool::new(threads);
                let got = packed_matmul_nt(&p, &x, &pool);
                assert_eq!((got.rows, got.cols), (33, 48));
                for (a, b) in got.data.iter().zip(&want.data) {
                    assert!((a - b).abs() < 1e-3, "bits={bits}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn packed_matmul_nt_flat_group_fallback() {
        // groupsize spanning rows (d_in % g != 0) routes to the general
        // kernel and still matches dequant-then-matmul.
        let mut rng = Rng::new(3);
        let w = Mat::randn(16, 24, &mut rng);
        let x = Mat::randn(5, 24, &mut rng);
        let qi = rtn_quantize_int(&w, &QuantSpec::new(4, 48));
        let p = pack(&qi);
        let got = packed_matmul_nt(&p, &x, &WorkerPool::new(2));
        let want = matmul_bt_mt(&x, &rtn_dequantize(&qi), &WorkerPool::new(1));
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut rng = Rng::new(4);
        let mut x = Mat::randn(1, 16, &mut rng);
        let orig = x.clone();
        rope_inplace(&mut x, 8, 16); // row 0 → position 0 → angle 0
        for (a, b) in x.data.iter().zip(&orig.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn norm_sums_match_manual() {
        let x = Mat::from_vec(2, 2, vec![1.0, -2.0, 3.0, 4.0]);
        let ps = [1.0f64, 2.0];
        let s = norm_sums(&x, &ps);
        assert!((s[0][0] - 4.0).abs() < 1e-9); // |1| + |3|
        assert!((s[0][1] - 6.0).abs() < 1e-9); // |-2| + |4|
        assert!((s[1][0] - 10.0).abs() < 1e-9); // 1 + 9
        assert!((s[1][1] - 20.0).abs() < 1e-9); // 4 + 16
    }

    #[test]
    fn activations_nonlinearities() {
        assert!((silu(0.0)).abs() < 1e-9);
        assert!((gelu(0.0)).abs() < 1e-9);
        // large positive inputs pass through ~identically
        assert!((silu(20.0) - 20.0).abs() < 1e-3);
        assert!((gelu(20.0) - 20.0).abs() < 1e-3);
        // both are negative-saturating
        assert!(silu(-20.0).abs() < 1e-3);
        assert!(gelu(-20.0).abs() < 1e-3);
    }
}
