//! [`ExecBackend`] over the PJRT runtime — the original artifact path.
//!
//! Thin adapter: each trait method picks the matching AOT artifact
//! (`logits` / `nll` / `stats` / `corr` / `ttq`), feeds the weights
//! positionally in manifest order, and parses the returned tuple. The
//! semantics are exactly the pre-trait `Evaluator` code paths.

use std::path::Path;

use anyhow::{anyhow, Result};

use super::{BatchStats, ExecBackend, StepOut};
use crate::kvcache::{KvCache, SeqId};
use crate::linalg::Mat;
use crate::models::ModelWeights;
use crate::quant::ActStats;
use crate::runtime::{
    literal_f32_vec, literal_scalar_f32, model_inputs, ArtifactKey, Runtime,
};

/// AOT-compiled HLO artifacts executed through the PJRT CPU client.
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    /// Wrap a compiled-artifact runtime.
    pub fn new(rt: Runtime) -> Self {
        PjrtBackend { rt }
    }

    /// The wrapped runtime (platform probes, artifact cache stats).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn run_variant(
        &self,
        weights: &ModelWeights,
        variant: &str,
        tokens: &[i32],
        batch: usize,
        qmax: Option<f32>,
    ) -> Result<Vec<xla::Literal>> {
        let key = ArtifactKey::new(&weights.manifest.name, variant, batch);
        let exe = self.rt.load(&key)?;
        let inputs = model_inputs(weights, tokens, batch, qmax)?;
        self.rt.run(&exe, &inputs)
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn models_dir(&self) -> &Path {
        self.rt.artifacts_dir()
    }

    fn logits(&self, weights: &ModelWeights, tokens: &[i32], batch: usize) -> Result<Vec<f32>> {
        let outs = self.run_variant(weights, "logits", tokens, batch, None)?;
        literal_f32_vec(&outs[0])
    }

    fn nll(&self, weights: &ModelWeights, tokens: &[i32], batch: usize) -> Result<(f64, f64)> {
        let outs = self.run_variant(weights, "nll", tokens, batch, None)?;
        Ok((
            literal_scalar_f32(&outs[0])? as f64,
            literal_scalar_f32(&outs[1])? as f64,
        ))
    }

    fn stats(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
        with_corr: bool,
    ) -> Result<BatchStats> {
        let variant = if with_corr { "corr" } else { "stats" };
        let outs = self.run_variant(weights, variant, tokens, batch, None)?;
        let linears = &weights.manifest.linears;
        let ps = &weights.manifest.norm_ps;
        let seq = weights.manifest.config.seq;
        let nll_sum = literal_scalar_f32(&outs[0])? as f64;
        let nll_count = literal_scalar_f32(&outs[1])? as f64;
        let n_tokens = (batch * seq) as f64;
        let mut stats = Vec::with_capacity(linears.len());
        for (i, lin) in linears.iter().enumerate() {
            let raw = literal_f32_vec(&outs[2 + i])?;
            if raw.len() != ps.len() * lin.d_in {
                return Err(anyhow!(
                    "stats shape mismatch for {}: {} vs {}x{}",
                    lin.name,
                    raw.len(),
                    ps.len(),
                    lin.d_in
                ));
            }
            let mut st = ActStats::new(ps, lin.d_in);
            let sums: Vec<Vec<f64>> = raw
                .chunks(lin.d_in)
                .map(|row| row.iter().map(|&v| v as f64).collect())
                .collect();
            st.accumulate(&sums, n_tokens);
            stats.push(st);
        }
        let mut corr = Vec::new();
        if with_corr {
            for (i, lin) in linears.iter().enumerate() {
                let raw = literal_f32_vec(&outs[2 + linears.len() + i])?;
                corr.push(Mat::from_vec(lin.d_in, lin.d_in, raw));
            }
        }
        Ok(BatchStats { nll_sum, nll_count, stats, corr })
    }

    fn nll_fused_ttq(
        &self,
        weights: &ModelWeights,
        tokens: &[i32],
        batch: usize,
        bits: u32,
    ) -> Result<(f64, f64)> {
        let qmax = crate::quant::qmax(bits);
        let outs = self.run_variant(weights, "ttq", tokens, batch, Some(qmax))?;
        Ok((
            literal_scalar_f32(&outs[0])? as f64,
            literal_scalar_f32(&outs[1])? as f64,
        ))
    }

    fn prefill(
        &self,
        _weights: &ModelWeights,
        _tokens: &[i32],
        _cache: &mut KvCache,
        _ids: &[SeqId],
        _with_stats: bool,
    ) -> Result<StepOut> {
        Err(anyhow!(
            "the pjrt backend has no KV-cache artifact variant: AOT executables are \
             compiled for fixed full-sequence shapes — serve with --backend native \
             for cached prefill/decode"
        ))
    }

    fn decode_step(
        &self,
        _weights: &ModelWeights,
        _last_tokens: &[i32],
        _cache: &mut KvCache,
        _ids: &[SeqId],
        _with_stats: bool,
    ) -> Result<StepOut> {
        Err(anyhow!(
            "the pjrt backend has no KV-cache artifact variant: AOT executables are \
             compiled for fixed full-sequence shapes — serve with --backend native \
             for cached prefill/decode"
        ))
    }
}
