//! Deterministic synthetic models — manifest + weights without
//! `make artifacts`.
//!
//! Mirrors `python/compile/model.py::CONFIGS` (dims) and `init_params`
//! (initialization scheme): fan-in-scaled projections, 0.02-σ
//! embeddings, unit (gemma: zero) norm weights. Weights are seeded from
//! the model name through [`crate::linalg::Rng`], so every process —
//! tests, benches, the CLI native backend — sees bit-identical tensors.
//!
//! These models are *architecturally* faithful but untrained: they
//! exercise the full eval/serving pipeline (forward, stats, calibrator,
//! quantization) without making language-quality claims.

use anyhow::{anyhow, Result};

use crate::linalg::{rng::splitmix64, Mat, Rng};
use crate::models::{
    LinearInfo, Manifest, ModelDims, ModelWeights, TensorInfo, TtqDefaults,
};

/// Dimension set for one synthetic model (mirror of python ModelConfig).
#[derive(Clone, Copy, Debug)]
pub struct TestConfig {
    /// Model name (mirrors the python registry).
    pub name: &'static str,
    /// Architecture family (`opt` / `qwen` / `gemma`).
    pub family: &'static str,
    /// Vocabulary size.
    pub vocab: usize,
    /// Residual width d.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Key/value heads.
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// MLP hidden width.
    pub d_mlp: usize,
    /// Maximum context positions.
    pub max_seq: usize,
}

impl TestConfig {
    /// Attention width `n_heads × head_dim`.
    pub fn d_attn(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// K/V width `n_kv_heads × head_dim`.
    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
}

const fn cfg(
    name: &'static str,
    family: &'static str,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    d_mlp: usize,
) -> TestConfig {
    TestConfig {
        name,
        family,
        vocab: 512,
        d_model,
        n_layers,
        n_heads,
        n_kv_heads,
        head_dim,
        d_mlp,
        max_seq: 64,
    }
}

/// The 7-model registry, dimension-identical to the python CONFIGS.
pub const CONFIGS: [TestConfig; 7] = [
    cfg("opt-micro", "opt", 64, 2, 4, 4, 16, 256),
    cfg("opt-mini", "opt", 128, 4, 8, 8, 16, 512),
    cfg("opt-small", "opt", 192, 6, 8, 8, 24, 768),
    cfg("qwen-micro", "qwen", 64, 2, 4, 2, 16, 192),
    cfg("qwen-mini", "qwen", 128, 4, 8, 2, 16, 384),
    cfg("gemma-micro", "gemma", 64, 2, 4, 1, 32, 256),
    cfg("gemma-mini", "gemma", 128, 4, 4, 1, 32, 512),
];

/// Look up a synthetic model's dimension set by name.
pub fn config(name: &str) -> Option<&'static TestConfig> {
    CONFIGS.iter().find(|c| c.name == name)
}

/// Ordered (name, (rows, cols)) tensor schema — the manifest order
/// contract (1-D tensors are (1, n)).
fn param_schema(c: &TestConfig) -> Vec<(String, (usize, usize))> {
    let d = c.d_model;
    let mut out: Vec<(String, (usize, usize))> =
        vec![("embed".into(), (c.vocab, d))];
    if c.family == "opt" {
        out.push(("pos_embed".into(), (c.max_seq, d)));
    }
    for i in 0..c.n_layers {
        let p = format!("l{i}.");
        out.push((format!("{p}ln1"), (1, d)));
        if c.family == "opt" {
            out.push((format!("{p}ln1b"), (1, d)));
        }
        out.push((format!("{p}wq"), (c.d_attn(), d)));
        out.push((format!("{p}wk"), (c.d_kv(), d)));
        out.push((format!("{p}wv"), (c.d_kv(), d)));
        out.push((format!("{p}wo"), (d, c.d_attn())));
        if c.family == "qwen" {
            out.push((format!("{p}qnorm"), (1, c.head_dim)));
            out.push((format!("{p}knorm"), (1, c.head_dim)));
        }
        out.push((format!("{p}ln2"), (1, d)));
        if c.family == "opt" {
            out.push((format!("{p}ln2b"), (1, d)));
        }
        if c.family == "opt" {
            out.push((format!("{p}up"), (c.d_mlp, d)));
            out.push((format!("{p}down"), (d, c.d_mlp)));
        } else {
            out.push((format!("{p}gate"), (c.d_mlp, d)));
            out.push((format!("{p}up"), (c.d_mlp, d)));
            out.push((format!("{p}down"), (d, c.d_mlp)));
        }
    }
    out.push(("lnf".into(), (1, d)));
    if c.family == "opt" {
        out.push(("lnfb".into(), (1, d)));
    }
    out
}

fn linear_schema(c: &TestConfig) -> Vec<LinearInfo> {
    let d = c.d_model;
    let mut out = Vec::new();
    for i in 0..c.n_layers {
        let p = format!("l{i}.");
        out.push(LinearInfo { name: format!("{p}wq"), d_in: d, d_out: c.d_attn() });
        out.push(LinearInfo { name: format!("{p}wk"), d_in: d, d_out: c.d_kv() });
        out.push(LinearInfo { name: format!("{p}wv"), d_in: d, d_out: c.d_kv() });
        out.push(LinearInfo { name: format!("{p}wo"), d_in: c.d_attn(), d_out: d });
        if c.family != "opt" {
            out.push(LinearInfo { name: format!("{p}gate"), d_in: d, d_out: c.d_mlp });
        }
        out.push(LinearInfo { name: format!("{p}up"), d_in: d, d_out: c.d_mlp });
        out.push(LinearInfo { name: format!("{p}down"), d_in: c.d_mlp, d_out: d });
    }
    out
}

/// Manifest for a synthetic model (offsets/numels in schema order).
pub fn manifest(c: &TestConfig) -> Manifest {
    let mut tensors = Vec::new();
    let mut offset = 0usize;
    for (name, (rows, cols)) in param_schema(c) {
        let numel = rows * cols;
        let shape = if rows == 1 { vec![cols] } else { vec![rows, cols] };
        tensors.push(TensorInfo { name, shape, offset, numel });
        offset += numel;
    }
    Manifest {
        name: c.name.to_string(),
        family: c.family.to_string(),
        config: ModelDims {
            vocab: c.vocab,
            d_model: c.d_model,
            n_layers: c.n_layers,
            n_heads: c.n_heads,
            n_kv_heads: c.n_kv_heads,
            head_dim: c.head_dim,
            d_mlp: c.d_mlp,
            max_seq: c.max_seq,
            seq: c.max_seq,
        },
        tensors,
        linears: linear_schema(c),
        norm_ps: vec![0.5, 1.0, 2.0, 4.0],
        ttq_defaults: TtqDefaults { g: 32, p: 2.0, lam: 0.4, alpha: 0.5 },
    }
}

fn name_seed(name: &str) -> u64 {
    let mut h = 0x7751_2026u64;
    for b in name.bytes() {
        h = splitmix64(h ^ b as u64);
    }
    h
}

/// Build a synthetic model entirely in memory (deterministic per name).
pub fn build(name: &str) -> Result<ModelWeights> {
    let c = config(name).ok_or_else(|| {
        anyhow!("no synthetic config for model '{name}' (known: registry names)")
    })?;
    build_config(c)
}

/// Build from an explicit config (custom shapes for tests).
pub fn build_config(c: &TestConfig) -> Result<ModelWeights> {
    let man = manifest(c);
    let mut rng = Rng::new(name_seed(c.name));
    let residual_scale = 1.0 / (2.0 * c.n_layers as f64).sqrt();
    let mut tensors: Vec<(String, Mat)> = Vec::with_capacity(man.tensors.len());
    for (tname, (rows, cols)) in param_schema(c) {
        let base = tname.rsplit('.').next().unwrap_or(&tname);
        let numel = rows * cols;
        let data: Vec<f32> = match base {
            "ln1" | "ln2" | "lnf" | "qnorm" | "knorm" => {
                let v = if c.family == "gemma" { 0.0 } else { 1.0 };
                vec![v; numel]
            }
            "ln1b" | "ln2b" | "lnfb" => vec![0.0; numel],
            "embed" => (0..numel).map(|_| (rng.normal() * 0.02) as f32).collect(),
            "pos_embed" => (0..numel).map(|_| (rng.normal() * 0.01) as f32).collect(),
            _ => {
                // projection: fan-in-scaled normal, residual outputs damped
                let fan_in = cols as f64;
                let mut s = fan_in.powf(-0.5);
                if base == "wo" || base == "down" {
                    s *= residual_scale;
                }
                (0..numel).map(|_| (rng.normal() * s) as f32).collect()
            }
        };
        tensors.push((tname, Mat::from_vec(rows, cols, data)));
    }
    ModelWeights::from_parts(man, tensors)
}

/// Manifest serialized to the on-disk JSON contract (round-trips
/// through [`Manifest::parse`]); exposed for tooling/tests.
pub fn manifest_json(c: &TestConfig) -> String {
    let m = manifest(c);
    let tensors: Vec<String> = m
        .tensors
        .iter()
        .map(|t| {
            let shape: Vec<String> = t.shape.iter().map(|s| s.to_string()).collect();
            format!(
                r#"{{"name": "{}", "shape": [{}], "offset": {}, "numel": {}}}"#,
                t.name,
                shape.join(", "),
                t.offset,
                t.numel
            )
        })
        .collect();
    let linears: Vec<String> = m
        .linears
        .iter()
        .map(|l| {
            format!(
                r#"{{"name": "{}", "d_in": {}, "d_out": {}}}"#,
                l.name, l.d_in, l.d_out
            )
        })
        .collect();
    let cfgv = &m.config;
    format!(
        r#"{{
  "name": "{}", "family": "{}",
  "config": {{"vocab": {}, "d_model": {}, "n_layers": {}, "n_heads": {},
             "n_kv_heads": {}, "head_dim": {}, "d_mlp": {}, "max_seq": {}, "seq": {}}},
  "tensors": [{}],
  "linears": [{}],
  "norm_ps": [0.5, 1, 2, 4],
  "ttq_defaults": {{"g": {}, "p": {}, "lam": {}, "alpha": {}}}
}}"#,
        m.name,
        m.family,
        cfgv.vocab,
        cfgv.d_model,
        cfgv.n_layers,
        cfgv.n_heads,
        cfgv.n_kv_heads,
        cfgv.head_dim,
        cfgv.d_mlp,
        cfgv.max_seq,
        cfgv.seq,
        tensors.join(", "),
        linears.join(", "),
        m.ttq_defaults.g,
        m.ttq_defaults.p,
        m.ttq_defaults.lam,
        m.ttq_defaults.alpha
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_registry_models_build() {
        for c in &CONFIGS {
            let w = build(c.name).unwrap();
            assert_eq!(w.manifest.name, c.name);
            assert!(w.param_count() > 10_000, "{} too small", c.name);
            let expected_linears =
                c.n_layers * if c.family == "opt" { 6 } else { 7 };
            assert_eq!(w.manifest.linears.len(), expected_linears);
            // every linear exists with the declared shape
            for lin in &w.manifest.linears {
                let t = w.get(&lin.name).expect("linear tensor");
                assert_eq!((t.rows, t.cols), (lin.d_out, lin.d_in), "{}", lin.name);
            }
        }
    }

    #[test]
    fn build_is_deterministic() {
        let a = build("qwen-micro").unwrap();
        let b = build("qwen-micro").unwrap();
        for name in a.tensor_names() {
            assert_eq!(a.get(name).unwrap().data, b.get(name).unwrap().data);
        }
    }

    #[test]
    fn models_differ_by_name() {
        let a = build("qwen-micro").unwrap();
        let b = build("gemma-micro").unwrap();
        assert_ne!(a.get("embed").unwrap().data, b.get("embed").unwrap().data);
    }

    #[test]
    fn manifest_json_round_trips() {
        for c in &CONFIGS {
            let parsed = Manifest::parse(&manifest_json(c)).unwrap();
            let m = manifest(c);
            assert_eq!(parsed.name, m.name);
            assert_eq!(parsed.family, m.family);
            assert_eq!(parsed.tensors.len(), m.tensors.len());
            assert_eq!(parsed.linears.len(), m.linears.len());
            assert_eq!(parsed.norm_ps, m.norm_ps);
            assert_eq!(parsed.config.d_mlp, m.config.d_mlp);
            assert_eq!(parsed.ttq_defaults.g, m.ttq_defaults.g);
        }
    }

    #[test]
    fn kv_cache_geometry_derives_from_every_config() {
        // The decode engine sizes its per-layer K/V blocks from the
        // manifest; the width must be the *KV* head count (GQA/MQA),
        // not the query head count, for every registry family.
        use crate::kvcache::KvCacheConfig;
        for c in &CONFIGS {
            let man = manifest(c);
            let kc = KvCacheConfig::from_manifest(&man, 2);
            assert_eq!(kc.d_kv, c.d_kv(), "{}", c.name);
            assert_eq!(kc.n_layers, c.n_layers, "{}", c.name);
            assert_eq!(kc.max_seq, c.max_seq, "{}", c.name);
            assert!(kc.d_kv <= c.d_attn(), "{}: KV wider than attention", c.name);
        }
    }

    #[test]
    fn offsets_are_contiguous() {
        let m = manifest(config("opt-micro").unwrap());
        let mut expect = 0usize;
        for t in &m.tensors {
            assert_eq!(t.offset, expect, "{}", t.name);
            expect += t.numel;
        }
    }
}
