//! Ablation sweeps for the design choices DESIGN.md calls out.
//!
//! * `sweep_formats` — QDQ format variants of App. D (asym / sym /
//!   expanded ν) on weight-only error: the asymmetric format should win,
//!   ν ≈ 0.95 should be the best expansion.
//! * `sweep_lowrank_init` — App. E: plain top-r SVD vs alternating
//!   refinement; the paper found refinement has "almost no gain".
//! * `sweep_nf` — uniform vs NormalFloat codebooks (App. D's NF4).
//! * `sweep_prune` — test-time pruning + TTQ composition (§3 future
//!   work / μ-MoE integration, App. E "Low-Rank Factor Pruning").

use anyhow::Result;

use super::Report;
use crate::linalg::{activation_loss, Mat, Rng};
use crate::quant::{
    alternating_refine, diag_from_x, lowrank_init, nf_quantize, prune,
    prune_then_quantize, rtn_quantize, QdqFormat, QuantSpec, Sparsity,
};

fn test_weight(seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::randn(128, 256, &mut rng)
}

/// Relative weight-only quantization error per format × bits.
pub fn sweep_formats() -> Result<Report> {
    let w = test_weight(41);
    let total = w.frob_sq();
    let mut rep = Report::new(
        "Ablation (App. D): QDQ format variants, relative ‖W−Ŵ‖²",
        &["format", "2 bits", "3 bits", "4 bits", "5 bits"],
    );
    let formats: Vec<(String, QdqFormat)> = vec![
        ("asymmetric".into(), QdqFormat::Asymmetric),
        ("symmetric".into(), QdqFormat::Symmetric),
        ("expanded nu=0.95".into(), QdqFormat::Expanded { nu: 0.95 }),
        ("expanded nu=0.90".into(), QdqFormat::Expanded { nu: 0.90 }),
        ("expanded nu=0.80".into(), QdqFormat::Expanded { nu: 0.80 }),
    ];
    for (name, fmt) in formats {
        let mut cells = vec![name];
        for bits in [2u32, 3, 4, 5] {
            let spec = QuantSpec { bits, group: 32, format: fmt };
            let e = w.sub(&rtn_quantize(&w, &spec)).frob_sq() / total;
            cells.push(format!("{e:.2e}"));
        }
        rep.row(cells);
    }
    Ok(rep)
}

/// Low-rank init strategies at 2-bit: residual error after W_q + BA.
pub fn sweep_lowrank_init() -> Result<Report> {
    let w = test_weight(42);
    let total = w.frob_sq();
    let spec = QuantSpec::new(2, 32);
    let mut rep = Report::new(
        "Ablation (App. E): low-rank init, relative ‖W−(W_q+BA)‖², 2-bit",
        &["init", "r=4", "r=8", "r=16", "r=32"],
    );
    let mut row_svd = vec!["top-r SVD (Eq. 31-33)".to_string()];
    let mut row_alt1 = vec!["alternating, 1 iter".to_string()];
    let mut row_alt3 = vec!["alternating, 3 iters".to_string()];
    for r in [4usize, 8, 16, 32] {
        let lr = lowrank_init(&w, r);
        let wq = rtn_quantize(&w.sub(&lr.product()), &spec);
        let e_svd = w.sub(&wq.add(&lr.product())).frob_sq() / total;
        row_svd.push(format!("{e_svd:.3e}"));
        for (iters, row) in [(1usize, &mut row_alt1), (3, &mut row_alt3)] {
            let (lr2, wq2) = alternating_refine(&w, r, &spec, iters);
            let e = w.sub(&wq2.add(&lr2.product())).frob_sq() / total;
            row.push(format!("{e:.3e}"));
        }
    }
    rep.row(row_svd);
    rep.row(row_alt1);
    rep.row(row_alt3);
    Ok(rep)
}

/// Uniform asymmetric vs NormalFloat codebook on Gaussian weights.
pub fn sweep_nf() -> Result<Report> {
    let w = test_weight(43);
    let total = w.frob_sq();
    let mut rep = Report::new(
        "Ablation (App. D): uniform vs NormalFloat codebook, relative ‖W−Ŵ‖²",
        &["format", "2 bits", "3 bits", "4 bits"],
    );
    // NF's fair baseline is the *symmetric* uniform format: both spend
    // one parameter (absmax) per group. Asymmetric min/max spends two
    // and is shown for context.
    let mut row_s = vec!["uniform symmetric (1 param)".to_string()];
    let mut row_n = vec!["normal-float NFq (1 param)".to_string()];
    let mut row_a = vec!["uniform asymmetric (2 params)".to_string()];
    for bits in [2u32, 3, 4] {
        let spec_s = QuantSpec { bits, group: 64, format: QdqFormat::Symmetric };
        let e_s = w.sub(&rtn_quantize(&w, &spec_s)).frob_sq() / total;
        let e_n = w.sub(&nf_quantize(&w, bits, 64)).frob_sq() / total;
        let e_a = w
            .sub(&rtn_quantize(&w, &QuantSpec::new(bits, 64)))
            .frob_sq()
            / total;
        row_s.push(format!("{e_s:.3e}"));
        row_n.push(format!("{e_n:.3e}"));
        row_a.push(format!("{e_a:.3e}"));
    }
    rep.row(row_s);
    rep.row(row_n);
    rep.row(row_a);
    Ok(rep)
}

/// Test-time pruning (μ-MoE style) composed with TTQ quantization:
/// activation loss of prune-only / quant-only / prune+quant at matched
/// memory budgets.
pub fn sweep_prune() -> Result<Report> {
    let mut rng = Rng::new(44);
    let w = Mat::randn(128, 256, &mut rng);
    // outlier activations (the regime where activation-awareness matters)
    let scales: Vec<f32> = (0..256).map(|_| rng.lognormal(0.0, 1.5) as f32).collect();
    let mut x = Mat::randn(256, 128, &mut rng);
    for i in 0..256 {
        for v in x.row_mut(i) {
            *v *= scales[i];
        }
    }
    let d = diag_from_x(&x, 2.0, 0.4, 0.5);
    let base = w.matmul(&x).frob_sq();
    let rel = |wq: &Mat| activation_loss(&w, wq, &x) / base;

    let mut rep = Report::new(
        "Ablation (§3): test-time prune × quantize, relative ‖(W−Ŵ)X‖²",
        &["configuration", "loss"],
    );
    let spec4 = QuantSpec::new(4, 32);
    let spec3 = QuantSpec::new(3, 32);
    rep.row(vec![
        "prune 50% (act-aware)".into(),
        format!("{:.3e}", rel(&prune(&w, &d, Sparsity::Unstructured { ratio: 0.5 }))),
    ]);
    rep.row(vec![
        "prune 2:4 (act-aware)".into(),
        format!("{:.3e}", rel(&prune(&w, &d, Sparsity::NofM { n: 2, m: 4 }))),
    ]);
    rep.row(vec![
        "quant 4-bit TTQ".into(),
        format!("{:.3e}", rel(&crate::quant::awq_quantize(&w, &d, &spec4))),
    ]);
    rep.row(vec![
        "prune 2:4 + quant 4-bit".into(),
        format!(
            "{:.3e}",
            rel(&prune_then_quantize(&w, &d, Sparsity::NofM { n: 2, m: 4 }, &spec4))
        ),
    ]);
    rep.row(vec![
        "prune 2:4 + quant 3-bit".into(),
        format!(
            "{:.3e}",
            rel(&prune_then_quantize(&w, &d, Sparsity::NofM { n: 2, m: 4 }, &spec3))
        ),
    ]);
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_sweep_shapes() {
        let r = sweep_formats().unwrap();
        assert_eq!(r.rows.len(), 5);
        // asymmetric must beat symmetric at every bit-width
        for c in 1..5 {
            let asym: f64 = r.rows[0][c].parse().unwrap();
            let sym: f64 = r.rows[1][c].parse().unwrap();
            assert!(asym <= sym, "col {c}");
        }
    }

    #[test]
    fn lowrank_error_decreases_with_rank() {
        let r = sweep_lowrank_init().unwrap();
        let svd: Vec<f64> = (1..5).map(|c| r.rows[0][c].parse().unwrap()).collect();
        for pair in svd.windows(2) {
            assert!(pair[0] >= pair[1]);
        }
    }

    #[test]
    fn nf_beats_symmetric_uniform_on_gaussian() {
        // At 2 bits the 4-level normal codebook degenerates (the forced
        // exact-zero breaks symmetry) — NF4's regime is 3+ bits, which
        // is also where the literature deploys it.
        let r = sweep_nf().unwrap();
        for c in 2..4 {
            let sym: f64 = r.rows[0][c].parse().unwrap();
            let nf: f64 = r.rows[1][c].parse().unwrap();
            assert!(nf < sym, "col {c}: nf {nf} vs symmetric uniform {sym}");
        }
    }

    #[test]
    fn prune_sweep_ordering() {
        let r = sweep_prune().unwrap();
        let get = |i: usize| r.rows[i][1].parse::<f64>().unwrap();
        // combined prune+quant loses more than either alone
        assert!(get(3) >= get(1) - 1e-12);
        assert!(get(3) >= get(2) - 1e-12);
        // 3-bit combined worse than 4-bit combined
        assert!(get(4) > get(3));
    }
}
