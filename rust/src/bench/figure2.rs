//! Figure 2 — hyperparameter histogram: grid-search (α, λ, p), keep the
//! top-5 combos per (model, bits), histogram the winners.
//!
//! The paper's App. F conclusions to reproduce: α around 0.5-0.75,
//! λ ≈ 0.4 (much larger than the folklore 0.01), p = 2 good / p = 1
//! terrible. We search on the activation-loss surrogate ‖(W−Ŵ)X‖²
//! summed over the model's linears (cheap, artifact-free) — the same
//! objective (Eq. 15) the paper's selection minimizes.

use std::collections::HashMap;

use anyhow::Result;

use super::Report;
use crate::backend::ExecBackend;
use crate::corpus::{CorpusStream, Split};
use crate::eval::Evaluator;
use crate::quant::{awq_quantize, diag_from_norm_sums, QuantSpec};

/// α grid of the figure's sweep.
pub const ALPHAS: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];
/// λ grid of the figure's sweep.
pub const LAMBDAS: [f64; 4] = [0.01, 0.1, 0.4, 1.0];
/// p grid of the figure's sweep.
pub const PS: [f64; 4] = [0.5, 1.0, 2.0, 4.0];

/// Grid-search one model at one bit-width; returns the 5 best
/// (alpha, lam, p) triples by summed activation loss.
pub fn top5_for(
    backend: &dyn ExecBackend,
    model: &str,
    bits: u32,
    fast: bool,
) -> Result<Vec<(f64, f64, f64)>> {
    let ev = Evaluator::new(backend, model)?;
    // one stats+corr-free pass on eval traffic for the norm sums, plus
    // a synthetic X per linear rebuilt from a fresh eval stream to score
    // the loss. We approximate X's effect through the stats artifact:
    // collect norm sums once, then score L = Σ ‖(W−Ŵ)·diag(n2)‖² where
    // n2 is the per-channel ℓ2 energy — the diagonal surrogate of Eq. 15.
    let mut stream = CorpusStream::new("wt2s", Split::Eval);
    let batches = if fast { 1 } else { 3 };
    let collected = {
        let mut s: Option<crate::eval::CollectedStats> = None;
        for _ in 0..batches {
            let toks = stream.batch(4, ev.weights.manifest.config.seq);
            let got = ev.collect(&toks, 4, false)?;
            match &mut s {
                None => s = Some(got),
                Some(a) => {
                    for (dst, src) in a.stats.iter_mut().zip(&got.stats) {
                        dst.accumulate(&src.norm_sums, src.count);
                    }
                }
            }
        }
        s.unwrap()
    };
    let originals = ev.weights.linear_weights();
    let linears = ev.weights.manifest.linears.clone();
    let spec = QuantSpec::new(bits, 32);

    let mut scored: Vec<((f64, f64, f64), f64)> = Vec::new();
    for &alpha in &ALPHAS {
        for &lam in &LAMBDAS {
            for &p in &PS {
                let mut loss = 0.0f64;
                for (i, lin) in linears.iter().enumerate() {
                    let st = &collected.stats[i];
                    let d = diag_from_norm_sums(st, p, lam, alpha);
                    let w = &originals[&lin.name];
                    let wq = awq_quantize(w, &d, &spec);
                    // exact diagonal-correlation loss (Eq. 15 with the
                    // true diagonal): ‖(W−Ŵ)·diag(‖X_i‖₂)‖²_F
                    let energy = diag_from_norm_sums(st, 2.0, 0.0, 1.0);
                    for r in 0..lin.d_out {
                        let wr = w.row(r);
                        let qr = wq.row(r);
                        for c in 0..lin.d_in {
                            let e = (wr[c] - qr[c]) as f64 * energy[c] as f64;
                            loss += e * e;
                        }
                    }
                }
                scored.push(((alpha, lam, p), loss));
            }
        }
    }
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    Ok(scored.into_iter().take(5).map(|(k, _)| k).collect())
}

/// Full Figure 2: histograms of top-5 winners across models × bits.
pub fn figure2(backend: &dyn ExecBackend, models: &[String], fast: bool) -> Result<Report> {
    let bits_list: Vec<u32> = if fast { vec![2, 4] } else { vec![2, 3, 4, 5] };
    let mut hist_a: HashMap<String, usize> = HashMap::new();
    let mut hist_l: HashMap<String, usize> = HashMap::new();
    let mut hist_p: HashMap<String, usize> = HashMap::new();
    for model in models {
        for &bits in &bits_list {
            for (a, l, p) in top5_for(backend, model, bits, fast)? {
                *hist_a.entry(format!("{a}")).or_default() += 1;
                *hist_l.entry(format!("{l}")).or_default() += 1;
                *hist_p.entry(format!("{p}")).or_default() += 1;
            }
        }
    }
    let mut rep = Report::new(
        "Figure 2: histogram of top-5 hyperparameter selections",
        &["param", "value", "count", "bar"],
    );
    let mut emit = |name: &str, hist: &HashMap<String, usize>, grid: &[f64]| {
        for v in grid {
            let key = format!("{v}");
            let c = hist.get(&key).copied().unwrap_or(0);
            rep.row(vec![
                name.into(),
                key,
                c.to_string(),
                "#".repeat(c),
            ]);
        }
    };
    emit("alpha", &hist_a, &ALPHAS);
    emit("lambda", &hist_l, &LAMBDAS);
    emit("p", &hist_p, &PS);
    Ok(rep)
}
