//! Table/figure regeneration harness — one entry per paper exhibit.
//!
//! `ttq-serve table <n>` / `ttq-serve figure2` print the same rows the
//! paper reports (DESIGN.md §5 maps exhibits → modules). Absolute
//! numbers live on our miniature substrate; the *shape* (ordering,
//! ratios, crossovers) is the reproduction target and is what
//! EXPERIMENTS.md records.

#![forbid(unsafe_code)]

pub mod ablations;
pub mod figure2;
pub mod quality;
pub mod tables_quality;
pub mod tables_runtime;
pub mod throughput;

pub use ablations::{sweep_formats, sweep_lowrank_init, sweep_nf, sweep_prune};
pub use figure2::figure2;
pub use quality::{default_mismatch_scenarios, run_quality_scenario};
pub use tables_quality::{table1, table2, table3, table12, table13};
pub use tables_runtime::runtime_table;
pub use throughput::{default_scenarios, kernel_baseline, run_scenario};

/// Simple fixed-width table printer shared by all exhibits.
pub struct Report {
    /// Heading printed above the table.
    pub title: String,
    /// Column names.
    pub header: Vec<String>,
    /// Table body; every row has `header.len()` cells.
    pub rows: Vec<Vec<String>>,
}

impl Report {
    /// Empty report with the given title and column names.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Report {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render the aligned fixed-width table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a perplexity like the paper (big values in e-notation).
pub fn fmt_ppl(v: f64) -> String {
    if !v.is_finite() {
        "inf".into()
    } else if v >= 10_000.0 {
        format!("{v:.1e}")
    } else if v >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_aligned() {
        let mut r = Report::new("T", &["a", "method"]);
        r.row(vec!["1".into(), "RTN".into()]);
        r.row(vec!["22".into(), "TTQ (r = 16)".into()]);
        let s = r.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("TTQ (r = 16)"));
    }

    #[test]
    fn ppl_formatting() {
        assert_eq!(fmt_ppl(25.731), "25.73");
        assert_eq!(fmt_ppl(381.74), "381.7");
        assert_eq!(fmt_ppl(8.2e6), "8.2e6");
        assert_eq!(fmt_ppl(f64::INFINITY), "inf");
    }
}
