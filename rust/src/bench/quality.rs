//! Offline quality-vs-speed Pareto harness (llama.cpp KL methodology).
//!
//! The reference distribution is recorded **once**: the pristine fp32
//! model's full logits over a fixed eval-token set. Every method is
//! then scored against that recording — full-softmax KL(fp32 ‖ method)
//! per next-token position, perplexity ratio, top-1 and top-k
//! agreement — so all methods face literally the same tokens and the
//! same reference, the way `llama-perplexity --kl-divergence` scores
//! quantizations against a saved fp16 logit file.
//!
//! [`run_quality_scenario`] runs one **calibration-mismatch** scenario
//! (calibrate on domain A, serve domain B — the regime from "On the
//! Impact of Calibration Data"): offline methods freeze their
//! statistics on the calib domain's calib split, while online TTQ
//! recalibrates from each eval batch itself (Fig. 1b). The mismatch is
//! exactly what the paper claims test-time quantization erases;
//! `benches/quality_vs_speed.rs` gates on TTQ beating frozen AWQ's KL
//! in every scenario, joins decode tokens/sec per execution format
//! from the throughput harness ([`super::throughput`]) into each row,
//! and serializes the Pareto table as `BENCH_quality.json`
//! (schema: `docs/BENCHMARKS.md`).
//!
//! The online **sampled** counterpart of this harness — the serving
//! probe that replays live steps through fp32 — lives in
//! [`crate::obs::quality`]; this module is the exhaustive offline
//! side of the same contract.

use anyhow::Result;

use super::Report;
use crate::backend::NativeBackend;
use crate::corpus::{CorpusStream, Split};
use crate::eval::{EvalConfig, Evaluator, MethodSpec};
use crate::obs::quality::kl_divergence;
use crate::quant::QuantSpec;
use crate::util::{argmax, logsumexp};

/// Reference top-k window for the agreement column: the served top-1
/// token must fall inside the fp32 model's `TOPK` most likely tokens.
pub const TOPK: usize = 5;

/// One calibration-mismatch scenario: freeze offline statistics on
/// `calib`, evaluate everyone on `eval`.
#[derive(Clone, Debug)]
pub struct MismatchSpec {
    /// Scenario name (appears in the report and the JSON).
    pub name: String,
    /// Domain offline methods calibrate on (calib split).
    pub calib: String,
    /// Domain every method is evaluated on (eval split).
    pub eval: String,
}

/// The two cross-domain scenarios the quality bench sweeps: the
/// structured-text and web-text synthetic domains, each serving as the
/// other's out-of-distribution traffic.
pub fn default_mismatch_scenarios() -> Vec<MismatchSpec> {
    vec![
        MismatchSpec {
            name: "calib-wt2s-serve-c4s".into(),
            calib: "wt2s".into(),
            eval: "c4s".into(),
        },
        MismatchSpec {
            name: "calib-c4s-serve-wt2s".into(),
            calib: "c4s".into(),
            eval: "wt2s".into(),
        },
    ]
}

/// One Pareto point: a (method, bits) cell scored against the fp32
/// reference recording, plus the decode throughput of its execution
/// format (joined by the bench binary; 0 until then).
#[derive(Clone, Debug)]
pub struct QualityRow {
    /// Method key (`"fp32"`, `"ttq"`, `"awq"`, `"rtn"`, `"nf"`).
    pub method: String,
    /// Quantization bit-width (32 for the fp32 reference row).
    pub bits: u32,
    /// Mean full-softmax KL(fp32 ‖ method) per position, nats.
    pub kl: f64,
    /// `ppl(method) / ppl(fp32)` on the same tokens (1.0 = lossless).
    pub ppl_ratio: f64,
    /// Fraction of positions where both argmax tokens agree.
    pub top1: f64,
    /// Fraction of positions where the served argmax falls inside the
    /// fp32 reference's top-[`TOPK`].
    pub topk: f64,
    /// Decode tokens/sec of this row's execution format, from the
    /// throughput harness (the speed axis of the Pareto table).
    pub tokens_per_sec: f64,
}

impl QualityRow {
    /// One JSON object line for `BENCH_quality.json`.
    pub fn to_json(&self) -> String {
        format!(
            r#"{{"method": "{}", "bits": {}, "kl": {:.6}, "ppl_ratio": {:.4}, "top1": {:.4}, "topk": {:.4}, "tokens_per_sec": {:.1}}}"#,
            self.method,
            self.bits,
            self.kl,
            self.ppl_ratio,
            self.top1,
            self.topk,
            self.tokens_per_sec,
        )
    }
}

/// One scenario's scored Pareto table.
#[derive(Clone, Debug)]
pub struct ScenarioQuality {
    /// Scenario name (from [`MismatchSpec::name`]).
    pub name: String,
    /// The frozen methods' calibration domain.
    pub calib: String,
    /// The evaluation domain everyone is scored on.
    pub eval: String,
    /// Pareto rows: the fp32 reference first, then method × bits.
    pub rows: Vec<QualityRow>,
}

impl ScenarioQuality {
    /// The row for (`method`, `bits`), if scored.
    pub fn row(&self, method: &str, bits: u32) -> Option<&QualityRow> {
        self.rows
            .iter()
            .find(|r| r.method == method && r.bits == bits)
    }

    /// Fixed-width Pareto table for the bench output.
    pub fn report(&self) -> Report {
        let title = format!(
            "quality vs speed — {} (calib {} → serve {})",
            self.name, self.calib, self.eval
        );
        // columns: KL in nats, ppl/fp = perplexity ratio vs fp32
        let mut rep = Report::new(
            &title,
            &["method", "bits", "KL", "ppl/fp", "top1", "top5", "tok/s"],
        );
        for r in &self.rows {
            rep.row(vec![
                r.method.clone(),
                r.bits.to_string(),
                format!("{:.4}", r.kl),
                format!("{:.4}", r.ppl_ratio),
                format!("{:.3}", r.top1),
                format!("{:.3}", r.topk),
                format!("{:.0}", r.tokens_per_sec),
            ]);
        }
        rep
    }

    /// One JSON object for the scenario (rows inline).
    pub fn to_json(&self) -> String {
        let rows = self
            .rows
            .iter()
            .map(|r| format!("      {}", r.to_json()))
            .collect::<Vec<_>>()
            .join(",\n");
        format!(
            "{{\"name\": \"{}\", \"calib\": \"{}\", \"eval\": \"{}\", \"rows\": [\n{}\n    ]}}",
            self.name,
            self.calib,
            self.eval,
            rows
        )
    }
}

/// Accumulated per-position agreement between one reference/served
/// logit recording pair.
#[derive(Default)]
struct ScoreAcc {
    kl: f64,
    top1: u64,
    topk: u64,
    nll: f64,
    n: u64,
}

impl ScoreAcc {
    /// Score every next-token position of one batch: `reference` and
    /// `served` are `(batch·seq) × vocab` logit recordings over the
    /// same `tokens`.
    fn accumulate(
        &mut self,
        reference: &[f32],
        served: &[f32],
        tokens: &[i32],
        batch: usize,
        seq: usize,
        vocab: usize,
    ) {
        for b in 0..batch {
            for s in 0..seq - 1 {
                let off = (b * seq + s) * vocab;
                let r = &reference[off..off + vocab];
                let q = &served[off..off + vocab];
                self.kl += kl_divergence(r, q);
                let qtop = argmax(q);
                if argmax(r) == qtop {
                    self.top1 += 1;
                }
                // served top-1 inside the reference's top-k window:
                // fewer than k reference logits strictly above it
                let above = r.iter().filter(|&&v| v > r[qtop]).count();
                if above < TOPK {
                    self.topk += 1;
                }
                let tgt = tokens[b * seq + s + 1] as usize;
                self.nll += logsumexp(q) - q[tgt] as f64;
                self.n += 1;
            }
        }
    }

    fn mean_kl(&self) -> f64 {
        if self.n > 0 {
            self.kl / self.n as f64
        } else {
            0.0
        }
    }

    fn mean_nll(&self) -> f64 {
        if self.n > 0 {
            self.nll / self.n as f64
        } else {
            0.0
        }
    }
}

/// The method ladder one scenario scores at one bit-width: online TTQ
/// (recalibrates per eval batch), frozen AWQ (calibrated once on the
/// mismatched domain — the gated comparison), and the stats-free RTN /
/// NormalFloat baselines. GPTQ is absent by construction: the serving
/// substrate has no corr artifact.
fn method_ladder(calib: &str, bits: u32) -> Vec<(&'static str, MethodSpec)> {
    vec![
        ("ttq", MethodSpec::ttq(0)),
        ("awq", MethodSpec::awq(calib)),
        ("rtn", MethodSpec::rtn()),
        ("nf", MethodSpec::nf(bits)),
    ]
}

/// Run one calibration-mismatch scenario: record the fp32 reference
/// logits once over a fixed eval-token set, then score every
/// (method, bits) cell of the ladder against that recording. `fast`
/// shrinks batch counts for CI. Rows come back with
/// `tokens_per_sec = 0` — the bench binary joins throughput per
/// execution format.
pub fn run_quality_scenario(
    spec: &MismatchSpec,
    bits_sweep: &[u32],
    fast: bool,
    threads: usize,
) -> Result<ScenarioQuality> {
    let dir = crate::artifacts_dir();
    let backend = NativeBackend::new(&dir).with_threads(threads);
    let mut ev = Evaluator::new(&backend, "qwen-micro")?;
    let seq = ev.weights.manifest.config.seq;
    let vocab = ev.weights.manifest.config.vocab;
    let batch = 2usize;
    let eval_batches = if fast { 3 } else { 6 };
    let calib_batches = if fast { 4 } else { 8 };

    // the fixed eval-token set every method faces
    let mut stream = CorpusStream::new(&spec.eval, Split::Eval);
    let batches: Vec<Vec<i32>> = (0..eval_batches)
        .map(|_| stream.batch(batch, seq))
        .collect();

    // the reference recording: pristine fp32 logits, computed once
    ev.restore();
    let mut reference = Vec::with_capacity(batches.len());
    for toks in &batches {
        reference.push(ev.backend.logits(&ev.weights, toks, batch)?);
    }
    let mut ref_acc = ScoreAcc::default();
    for (bi, toks) in batches.iter().enumerate() {
        let r = &reference[bi];
        ref_acc.accumulate(r, r, toks, batch, seq, vocab);
    }
    let ref_nll = ref_acc.mean_nll();

    let mut rows = vec![QualityRow {
        method: "fp32".into(),
        bits: 32,
        kl: 0.0,
        ppl_ratio: 1.0,
        top1: 1.0,
        topk: 1.0,
        tokens_per_sec: 0.0,
    }];
    for &bits in bits_sweep {
        let cfg = EvalConfig {
            batch,
            eval_batches,
            calib_batches,
            spec: QuantSpec::new(bits, 32),
        };
        for (key, method) in method_ladder(&spec.calib, bits) {
            // frozen methods quantize once, from the *mismatched* calib
            // domain; online methods are handled per batch below
            ev.quantize_static(&method, &cfg)?;
            let mut acc = ScoreAcc::default();
            for (bi, toks) in batches.iter().enumerate() {
                if method.is_online() {
                    // the test-time loop: statistics from the incoming
                    // batch itself, quantize, then serve it
                    ev.restore();
                    let st = ev.collect(toks, batch, method.needs_corr())?;
                    ev.apply_quantization(&method, Some(&st), &cfg)?;
                }
                let served = ev.backend.logits(&ev.weights, toks, batch)?;
                let r = &reference[bi];
                acc.accumulate(r, &served, toks, batch, seq, vocab);
            }
            rows.push(QualityRow {
                method: key.into(),
                bits,
                kl: acc.mean_kl(),
                ppl_ratio: (acc.mean_nll() - ref_nll).exp(),
                top1: acc.top1 as f64 / acc.n.max(1) as f64,
                topk: acc.topk as f64 / acc.n.max(1) as f64,
                tokens_per_sec: 0.0,
            });
        }
    }
    ev.restore();
    Ok(ScenarioQuality {
        name: spec.name.clone(),
        calib: spec.calib.clone(),
        eval: spec.eval.clone(),
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mismatch_scenario_scores_the_ladder() {
        let spec = default_mismatch_scenarios().remove(0);
        let sq = run_quality_scenario(&spec, &[4], true, 2).unwrap();
        // fp32 reference row + the 4-method ladder at one bit-width
        assert_eq!(sq.rows.len(), 5);
        let fp32 = sq.row("fp32", 32).unwrap();
        assert_eq!(fp32.kl, 0.0);
        assert_eq!(fp32.ppl_ratio, 1.0);
        for r in &sq.rows {
            assert!(r.kl >= 0.0, "{}: KL {}", r.method, r.kl);
            assert!(r.ppl_ratio > 0.0, "{}: ppl ratio {}", r.method, r.ppl_ratio);
            assert!((0.0..=1.0).contains(&r.top1), "{}", r.method);
            assert!((0.0..=1.0).contains(&r.topk), "{}", r.method);
            assert!(r.topk >= r.top1, "top-5 window contains top-1 agreement");
        }
        // every quantized method degrades (or at best matches) fp32
        let ttq = sq.row("ttq", 4).unwrap();
        assert!(ttq.kl >= 0.0);
        // rows stay machine-parseable for the JSON artifact
        let v = crate::util::json::Value::parse(&ttq.to_json()).unwrap();
        assert_eq!(v.get("method").and_then(|x| x.as_str()), Some("ttq"));
        assert!(v.get("kl").and_then(|x| x.as_f64()).is_some());
        let sv = crate::util::json::Value::parse(&sq.to_json()).unwrap();
        let arr = sv.get("rows").and_then(|x| x.as_arr());
        assert!(arr.is_some_and(|a| a.len() == 5));
    }

    #[test]
    fn report_renders_all_rows() {
        let sq = ScenarioQuality {
            name: "t".into(),
            calib: "wt2s".into(),
            eval: "c4s".into(),
            rows: vec![QualityRow {
                method: "ttq".into(),
                bits: 4,
                kl: 0.01,
                ppl_ratio: 1.02,
                top1: 0.98,
                topk: 1.0,
                tokens_per_sec: 1234.0,
            }],
        };
        let s = sq.report().render();
        assert!(s.contains("ttq"), "{s}");
        assert!(s.contains("1234"), "{s}");
    }
}
