//! Quality exhibits: Tables 1, 2, 3 (+ full 9-11), 12 (VLM), 13 (VLA).
//!
//! Every table takes its method rows as [`MethodSpec`]s (an empty slice
//! selects the paper's default row set), so any registered method —
//! including `nf:4` and `prune:0.5` — can be swapped in from the CLI:
//! `ttq-serve table 3 --methods rtn awq ttq:r=16 gptq nf:4 prune:0.5`.

use anyhow::Result;

use super::{fmt_ppl, Report};
use crate::backend::ExecBackend;
use crate::corpus::{CorpusStream, Split, LM_DOMAINS, VLA_SUITES};
use crate::eval::{EvalConfig, Evaluator, MethodSpec};
use crate::quant::QuantSpec;
use crate::util::argmax;

/// Scale knob: `fast` shrinks batch counts ~4x for smoke runs.
pub fn cfg(bits: u32, group: usize, fast: bool) -> EvalConfig {
    EvalConfig {
        batch: 4,
        eval_batches: if fast { 3 } else { 12 },
        calib_batches: if fast { 4 } else { 16 },
        spec: QuantSpec::new(bits, group),
    }
}

fn or_default(methods: &[MethodSpec], default: Vec<MethodSpec>) -> Vec<MethodSpec> {
    if methods.is_empty() {
        default
    } else {
        methods.to_vec()
    }
}

/// Table 1 — calibration length impact (3-bit, g=32, opt-mini).
///
/// Paper: AWQ (C4 calib) degrades as calibration tokens shrink; TTQ
/// needs zero calibration and still wins. Our sweep scales 2^11..2^17
/// down to 2^8..2^14 tokens (miniature corpus). Offline methods sweep
/// the calibration length; online methods get a single "0 tokens" row,
/// weight-only methods a "-" row.
pub fn table1(backend: &dyn ExecBackend, fast: bool, methods: &[MethodSpec]) -> Result<Report> {
    let model = "opt-mini";
    let mut ev = Evaluator::new(backend, model)?;
    let base = cfg(3, 32, fast);
    let seq = ev.weights.manifest.config.seq;
    let methods = or_default(
        methods,
        vec![MethodSpec::ttq(0), MethodSpec::ttq(16), MethodSpec::awq("c4s")],
    );
    let mut rep = Report::new(
        &format!("Table 1: calibration length impact, 3-bit g=32, {model}, wt2s ppl"),
        &["method", "calib tokens T", "WT2s ppl"],
    );
    let exps: Vec<u32> = if fast { vec![8, 11, 14] } else { vec![8, 9, 10, 11, 12, 13, 14] };
    for m in &methods {
        if m.is_offline() {
            for &e in &exps {
                let tokens = 1usize << e;
                let batches = (tokens / (base.batch * seq)).max(1);
                let mut c = base.clone();
                c.calib_batches = batches;
                let p = ev.perplexity(m, "wt2s", &c)?;
                rep.row(vec![m.label(), format!("2^{e}"), fmt_ppl(p)]);
            }
        } else {
            let p = ev.perplexity(m, "wt2s", &base)?;
            let t = if m.is_online() { "0" } else { "-" };
            rep.row(vec![m.label(), t.into(), fmt_ppl(p)]);
        }
    }
    Ok(rep)
}

/// Table 2 — groupsize impact (3-bit, qwen-mini, wt2s).
///
/// Paper: micro-scaling helps everyone; RTN collapses at large g; TTQ
/// tolerates ~2x larger groups than AWQ.
pub fn table2(backend: &dyn ExecBackend, fast: bool, methods: &[MethodSpec]) -> Result<Report> {
    let model = "qwen-mini";
    let mut ev = Evaluator::new(backend, model)?;
    let groups: Vec<usize> = if fast {
        vec![16, 64, 256, 1024]
    } else {
        vec![8, 16, 32, 64, 128, 256, 512, 1024]
    };
    let methods = or_default(
        methods,
        vec![MethodSpec::rtn(), MethodSpec::awq("wt2s"), MethodSpec::ttq(16)],
    );
    let mut rep = Report::new(
        &format!("Table 2: groupsize impact on wt2s ppl, 3-bit, {model}"),
        &{
            let mut h = vec!["method"];
            h.extend(groups.iter().map(|_| "g"));
            h
        },
    );
    // header row with actual group values
    {
        let mut cells = vec!["(groupsize)".to_string()];
        cells.extend(groups.iter().map(|g| g.to_string()));
        rep.row(cells);
    }
    for m in &methods {
        let mut cells = vec![m.label()];
        for &g in &groups {
            let c = cfg(3, g, fast);
            let p = ev.perplexity(m, "wt2s", &c)?;
            cells.push(fmt_ppl(p));
        }
        rep.row(cells);
    }
    Ok(rep)
}

/// Tables 3 / 9-11 — the method × bit-width grid, macro-averaged over
/// the three LM domains, for every model in the registry (or a subset).
/// The default row set now includes the NormalFloat codebook and
/// test-time pruning as first-class methods.
pub fn table3(
    backend: &dyn ExecBackend,
    models: &[String],
    fast: bool,
    methods: &[MethodSpec],
) -> Result<Vec<Report>> {
    let bits_list: Vec<u32> = if fast { vec![2, 4] } else { vec![2, 3, 4, 5] };
    let methods = or_default(
        methods,
        vec![
            MethodSpec::rtn(),
            MethodSpec::awq("wt2s"),
            MethodSpec::awq("ptbs"),
            MethodSpec::awq("c4s"),
            // NF follows each column's bit-width (a pinned nf:4 would
            // report 4-bit numbers under the 2/3/5-bit headers)
            MethodSpec::nf_auto(),
            MethodSpec::prune(0.5),
            MethodSpec::ttq(0),
            MethodSpec::ttq(16),
        ],
    );
    let mut reports = Vec::new();
    for model in models {
        let mut ev = Evaluator::new(backend, model)?;
        // un-compressed reference row
        let base = cfg(4, 32, fast);
        let mut ref_ppls = Vec::new();
        for d in LM_DOMAINS {
            ref_ppls.push(ev.perplexity(&MethodSpec::fp(), d, &base)?);
        }
        let ref_avg = ref_ppls.iter().sum::<f64>() / 3.0;
        let title = format!(
            "Table 3: {model} (wt2s {:.1}, ptbs {:.1}, c4s {:.1}, avg {:.1}), macro-avg ppl",
            ref_ppls[0], ref_ppls[1], ref_ppls[2], ref_avg
        );
        let mut header = vec!["method".to_string()];
        header.extend(bits_list.iter().map(|b| format!("{b} bits")));
        let mut rep = Report::new(&title, &header.iter().map(String::as_str).collect::<Vec<_>>());
        for m in &methods {
            let mut cells = vec![m.label()];
            for &bits in &bits_list {
                let c = cfg(bits, 32, fast);
                let mut acc = 0.0;
                for d in LM_DOMAINS {
                    acc += ev.perplexity(m, d, &c)?;
                }
                cells.push(fmt_ppl(acc / 3.0));
            }
            rep.row(cells);
        }
        reports.push(rep);
    }
    Ok(reports)
}

/// Table 12 — VLM proxy: next-token accuracy on the vqas domain under
/// quantization, with AWQ calibrated on four different domains.
pub fn table12(
    backend: &dyn ExecBackend,
    models: &[String],
    fast: bool,
    methods: &[MethodSpec],
) -> Result<Vec<Report>> {
    let bits_list: Vec<u32> = if fast { vec![2, 4] } else { vec![2, 3, 4, 5] };
    let methods = or_default(
        methods,
        vec![
            MethodSpec::rtn(),
            MethodSpec::awq("wt2s"),
            MethodSpec::awq("ptbs"),
            MethodSpec::awq("c4s"),
            MethodSpec::awq("vqas"),
            MethodSpec::ttq(0),
            MethodSpec::ttq(16),
        ],
    );
    let mut out = Vec::new();
    for model in models {
        let mut ev = Evaluator::new(backend, model)?;
        let base = cfg(4, 32, fast);
        let ref_acc = ev.accuracy(&MethodSpec::fp(), "vqas", &base)? * 100.0;
        let mut header = vec!["method".to_string()];
        header.extend(bits_list.iter().map(|b| format!("{b} bits")));
        let mut rep = Report::new(
            &format!("Table 12 (VLM proxy): {model}, vqas acc, FP ref {ref_acc:.2}%"),
            &header.iter().map(String::as_str).collect::<Vec<_>>(),
        );
        for m in &methods {
            let mut cells = vec![m.label()];
            for &bits in &bits_list {
                let c = cfg(bits, 32, fast);
                let a = ev.accuracy(m, "vqas", &c)? * 100.0;
                cells.push(format!("{a:.2}%"));
            }
            rep.row(cells);
        }
        out.push(rep);
    }
    Ok(out)
}

/// Table 13 — VLA proxy: episode success rate over four suites at
/// q=2, g=64. An episode succeeds when `horizon` greedy continuations
/// all match the ground-truth stream (exact match, like LIBERO).
pub fn table13(backend: &dyn ExecBackend, model: &str, fast: bool, methods: &[MethodSpec]) -> Result<Report> {
    let episodes = if fast { 20 } else { 100 };
    let methods = or_default(
        methods,
        vec![
            MethodSpec::fp(),
            MethodSpec::rtn(),
            MethodSpec::awq("wt2s"),
            MethodSpec::awq("c4s"),
            MethodSpec::awq("acts"),
            MethodSpec::ttq(0),
            MethodSpec::ttq(16),
        ],
    );
    let mut header: Vec<String> = vec!["method".into()];
    header.extend(VLA_SUITES.iter().map(|(n, _, _)| n.to_string()));
    header.push("Avg".into());
    let mut rep = Report::new(
        &format!("Table 13 (VLA proxy): {model}, q=2 g=64, success rate over {episodes} episodes"),
        &header.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    let mut ev = Evaluator::new(backend, model)?;
    for m in &methods {
        let mut cells = vec![m.label()];
        let mut acc = 0.0;
        for &(_, stream_id, horizon) in &VLA_SUITES {
            let r = vla_success_rate(&mut ev, m, stream_id, horizon, episodes, fast)?;
            acc += r;
            cells.push(format!("{:.1}%", r * 100.0));
        }
        cells.push(format!("{:.2}%", acc / VLA_SUITES.len() as f64 * 100.0));
        rep.row(cells);
    }
    Ok(rep)
}

/// Success rate: fraction of episodes whose `horizon` greedy decodes
/// all match the corpus ground truth.
fn vla_success_rate(
    ev: &mut Evaluator,
    method: &MethodSpec,
    stream_id: u64,
    horizon: usize,
    episodes: usize,
    fast: bool,
) -> Result<f64> {
    let seq = ev.weights.manifest.config.seq;
    let vocab = ev.weights.manifest.config.vocab;
    let c = EvalConfig {
        spec: QuantSpec::new(2, 64),
        calib_batches: if fast { 4 } else { 16 },
        ..Default::default()
    };
    // Quantize once per (method, suite): offline / no-stats methods via
    // the shared static path, online (test-time) methods from the
    // suite's own live prefix traffic — exactly Fig. 1.
    if method.is_online() {
        ev.restore();
        let mut s = CorpusStream::with_stream("acts", Split::Eval, stream_id);
        let st = ev.collect_stream(&mut s, c.batch, 2, method.needs_corr())?;
        ev.apply_quantization(method, Some(&st), &c)?;
    } else {
        ev.quantize_static(method, &c)?;
    }

    let mut stream = CorpusStream::with_stream("acts", Split::Eval, stream_id);
    let mut successes = 0usize;
    let prefix = seq - horizon - 1;
    for _ in 0..episodes {
        // Episode: BOS + prefix real traffic, then `horizon` steps where
        // the *analytic argmax* of the action language is the correct
        // action (LIBERO-style: the right action is deterministic given
        // state; the sampled stream's ε/geometric noise is environment
        // stochasticity, not ground truth). The model succeeds when its
        // greedy decode reproduces every correct action.
        let mut toks = vec![crate::corpus::BOS; seq];
        for t in toks.iter_mut().take(prefix + 1).skip(1) {
            *t = stream.next_token();
        }
        let mut truth = Vec::with_capacity(horizon);
        for _ in 0..horizon {
            let want = stream.most_likely_next();
            stream.force(want);
            truth.push(want);
        }
        let mut ok = true;
        for (h, &want) in truth.iter().enumerate() {
            let pos = prefix + h; // predict token at pos+1 from prefix..=pos
            let logits = ev.backend.logits(&ev.weights, &toks, 1)?;
            let off = pos * vocab;
            let best = argmax(&logits[off..off + vocab]);
            if best as i32 != want {
                ok = false;
                break;
            }
            toks[pos + 1] = want; // teacher-forced context continues
        }
        if ok {
            successes += 1;
        }
    }
    ev.restore();
    Ok(successes as f64 / episodes as f64)
}
