//! Runtime exhibits — Tables 4-8 (k tokens/sec of the Qwen3 query
//! projection per GPU) via the roofline simulator.
//!
//! The CPU-measured counterpart (criterion) lives in
//! `benches/runtime_tables.rs`; this module produces the table-shaped
//! report with the paper's exact row/column layout. Rows are
//! [`DecodeMode`]s — registry methods paired with a kernel class — so
//! any [`MethodSpec`] can be priced, not just the built-in five.

use super::Report;
use crate::models::QWEN3;
use crate::perfmodel::{gpu, ktokens_per_sec, DecodeMode, DEFAULT_AMORTIZE};
use crate::quant::{MethodSpec, QuantSpec};

/// The paper's five rows: FP16, both AWQ kernels, TTQ r=0 and r=16.
pub fn default_modes() -> Vec<DecodeMode> {
    vec![
        DecodeMode::fp16(),
        DecodeMode::awq_gemm(),
        DecodeMode::awq_marlin(),
        DecodeMode::ttq(0),
        DecodeMode::ttq(16),
    ]
}

/// Tables 4-8: one report per GPU name ("A40", "A100", "L40",
/// "RTX3090", "RTX4090"). 4-bit, g=32 as in the paper's App. H.
pub fn runtime_table(gpu_name: &str) -> Report {
    runtime_table_for(gpu_name, &default_modes())
}

/// Same layout with caller-chosen method rows (e.g. from
/// `--methods nf:4 prune:0.5` via [`DecodeMode::for_method`]).
pub fn runtime_table_for(gpu_name: &str, modes: &[DecodeMode]) -> Report {
    let g = gpu(gpu_name);
    let spec = QuantSpec::new(4, 32);
    let mut header: Vec<String> = vec!["Qwen3".into()];
    header.extend(QWEN3.iter().map(|m| m.name.to_string()));
    let mut rep = Report::new(
        &format!(
            "Tables 4-8: runtime speed (k tokens/sec) of query projection, 4-bit, {gpu_name} (roofline sim)"
        ),
        &header.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for mode in modes {
        let mut cells = vec![mode.label()];
        for m in &QWEN3 {
            let (dout, din) = m.qproj_dims();
            let k = ktokens_per_sec(g, dout, din, &spec, mode, DEFAULT_AMORTIZE);
            cells.push(format!("{k:.2}"));
        }
        rep.row(cells);
    }
    rep
}

/// Turn method specs into table rows on their natural kernels.
pub fn modes_for_methods(methods: &[MethodSpec]) -> Vec<DecodeMode> {
    methods.iter().cloned().map(DecodeMode::for_method).collect()
}

/// All five GPU tables in paper order.
pub fn all_runtime_tables() -> Vec<Report> {
    ["A40", "A100", "L40", "RTX3090", "RTX4090"]
        .iter()
        .map(|g| runtime_table(g))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_tables_six_models() {
        let ts = all_runtime_tables();
        assert_eq!(ts.len(), 5);
        for t in &ts {
            assert_eq!(t.header.len(), 7); // name + 6 models
            assert_eq!(t.rows.len(), 5); // 5 modes
        }
    }

    #[test]
    fn marlin_row_dominates_fp16_row() {
        let t = runtime_table("A100");
        let parse = |r: usize, c: usize| t.rows[r][c].parse::<f64>().unwrap();
        for c in 1..7 {
            let fp16 = parse(0, c);
            let marlin = parse(2, c);
            assert!(marlin > fp16, "col {c}: marlin {marlin} vs fp16 {fp16}");
        }
    }

    #[test]
    fn custom_method_rows_render() {
        let modes = modes_for_methods(&[
            MethodSpec::parse("nf:4").unwrap(),
            MethodSpec::parse("ttq:r=16").unwrap(),
        ]);
        let t = runtime_table_for("RTX3090", &modes);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "NF4 (marlin_gemm)");
        assert_eq!(t.rows[1][0], "TTQ (r = 16)");
    }
}
