//! Multi-scenario serving-throughput harness — the workload-diverse
//! evidence behind the worker-pool hot path.
//!
//! [`run_scenario`] drives [`crate::coordinator::Server`] as a
//! closed-loop load generator: every request is submitted up front and
//! the engine is stepped to completion, measuring streamed tokens/sec,
//! per-token latency percentiles (p50/p95/p99 over per-step latency
//! attributed to the tokens that step emitted, computed on the shared
//! [`crate::obs::Hist`] log-bucketed histogram), requantization count,
//! speculative acceptance and the pool's kernel-time share.
//! [`run_scenario`] runs with the trace recorder disabled (capacity 0);
//! [`run_scenario_traced`] runs the same load with a live trace ring —
//! the pair behind the ≤ 2% recorder-overhead gate in
//! `benches/serve_throughput.rs`. [`run_scenario_profiled`] runs with
//! the kernel profiler attached and additionally returns the per-site
//! roofline [`ProfileReport`] — the measured side of the
//! profiler-overhead and attribution-coverage gates in
//! `benches/kernel_profile.rs`.
//! [`default_scenarios`] describes the serving mix the throughput bench
//! (`benches/serve_throughput.rs`) sweeps:
//!
//! * **short-chat** — many short prompts, decode-dominated (the chat
//!   regime);
//! * **long-prefill** — near-context prompts, few generated tokens (the
//!   summarization regime, compute-bound prefill);
//! * **mixed-domain-drift** — traffic switches corpus domain mid-stream,
//!   forcing the online calibrator's drift-triggered requantization (the
//!   paper's test-time scenario; "On the Impact of Calibration Data…"
//!   motivates why shifting calibration traffic matters);
//! * **specdec-heavy** — every request decodes through the W4 drafter +
//!   fp32 verifier round;
//! * **fp32-decode / w4-decode** — the same load executed dense vs
//!   packed on the largest synthetic model, the pair behind the
//!   W4-vs-fp32 decode perf gate.
//!
//! [`kernel_baseline`] times the pooled kernel against
//! [`scoped_matmul_bt`] — the pre-pool spawn-per-call kernel, retained
//! verbatim as the regression baseline — on a decode-shaped stream of
//! small matmuls, where per-call thread spawn/join is the dominant cost
//! the pool exists to delete.
//!
//! Results serialize into `BENCH_throughput.json`; the schema contract
//! for CI artifact consumers lives in `docs/BENCHMARKS.md`.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::backend::native::{matmul_bt_mt, packed_matmul_nt};
use crate::backend::NativeBackend;
use crate::coordinator::{BatchPolicy, ServeEvent, Server, ServerConfig};
use crate::corpus::{CorpusStream, Split, BOS};
use crate::linalg::pool::{WorkerPool, MT_FLOP_FLOOR};
use crate::linalg::simd::{select, Isa};
use crate::linalg::{Mat, Rng};
use crate::obs::profile::{HostSpec, ProfileReport};
use crate::obs::{Hist, HistBucket};
use crate::quant::{pack, rtn_quantize_int, MethodSpec, QuantSpec};
use crate::specdec::SpecConfig;
use crate::util::benchkit::{black_box, Bencher};

/// One serving workload: what to submit and how to execute it.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    /// Scenario name (appears in the report and the JSON).
    pub name: String,
    /// Model to serve (synthetic fallback — no artifacts needed).
    pub model: String,
    /// Prompt length as a fraction `(num, den)` of the model context.
    pub prompt_frac: (usize, usize),
    /// Generation budget per request.
    pub max_new_tokens: usize,
    /// Requests submitted (all up front — closed loop).
    pub requests: usize,
    /// Corpus domains; the stream switches domain as the request index
    /// advances, so multi-domain specs exercise drift mid-run.
    pub domains: Vec<String>,
    /// Decode every request through the speculative drafter/verifier.
    pub speculative: bool,
    /// Packed execution bit-width (`None` = dense fp32 execution).
    pub exec_bits: Option<u32>,
}

/// Measured outcome of one scenario run.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    /// Scenario name (from [`LoadSpec::name`]).
    pub name: String,
    /// Worker-pool lanes the backend ran with.
    pub threads: usize,
    /// Execution mode label (`"fp32"` or `"w<bits>"`).
    pub exec: String,
    /// Requests completed (always equals the submitted count).
    pub requests: usize,
    /// Tokens streamed to clients.
    pub streamed_tokens: usize,
    /// Wall-clock of the drive loop, seconds.
    pub wall_s: f64,
    /// Streamed tokens per wall-clock second.
    pub tokens_per_sec: f64,
    /// Generated tokens per second of decode executor time (the
    /// memory-bound phase the paper's claims are about).
    pub decode_tokens_per_sec: f64,
    /// Median per-token latency, milliseconds.
    pub p50_token_ms: f64,
    /// 95th-percentile per-token latency, milliseconds.
    pub p95_token_ms: f64,
    /// 99th-percentile per-token latency, milliseconds.
    pub p99_token_ms: f64,
    /// Occupied per-token latency histogram buckets, microseconds
    /// (`[lo, hi]` bounds + count; counts sum to `streamed_tokens`).
    pub token_us_buckets: Vec<HistBucket>,
    /// Mid-run requantizations the drift detector fired.
    pub requants: u64,
    /// Draft-acceptance rate (0 for non-speculative scenarios).
    pub spec_acceptance: f64,
    /// Fraction of executor time spent in pooled kernel dispatches.
    pub kernel_share: f64,
}

impl ScenarioResult {
    /// One JSON object line for `BENCH_throughput.json`
    /// (`docs/BENCHMARKS.md` documents the schema).
    pub fn to_json(&self) -> String {
        let buckets = self
            .token_us_buckets
            .iter()
            .map(|b| format!("[{}, {}, {}]", b.lo, b.hi, b.count))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            r#"{{"name": "{}", "threads": {}, "exec": "{}", "requests": {}, "streamed_tokens": {}, "wall_s": {:.4}, "tokens_per_sec": {:.1}, "decode_tokens_per_sec": {:.1}, "p50_token_ms": {:.4}, "p95_token_ms": {:.4}, "p99_token_ms": {:.4}, "token_us_buckets": [{}], "requants": {}, "spec_acceptance": {:.3}, "kernel_share": {:.3}}}"#,
            self.name,
            self.threads,
            self.exec,
            self.requests,
            self.streamed_tokens,
            self.wall_s,
            self.tokens_per_sec,
            self.decode_tokens_per_sec,
            self.p50_token_ms,
            self.p95_token_ms,
            self.p99_token_ms,
            buckets,
            self.requants,
            self.spec_acceptance,
            self.kernel_share,
        )
    }

    /// Fixed-width report line for the bench output.
    pub fn report(&self) -> String {
        format!(
            "{:<22} {:>2}t {:<5} {:>7.0} tok/s  decode {:>7.0} tok/s  p50 {:>7.3}ms  p95 {:>7.3}ms  p99 {:>7.3}ms  requants {:>2}  kernel {:>3.0}%{}",
            self.name,
            self.threads,
            self.exec,
            self.tokens_per_sec,
            self.decode_tokens_per_sec,
            self.p50_token_ms,
            self.p95_token_ms,
            self.p99_token_ms,
            self.requants,
            100.0 * self.kernel_share,
            if self.spec_acceptance > 0.0 {
                format!("  accept {:.2}", self.spec_acceptance)
            } else {
                String::new()
            }
        )
    }
}

/// Drive one scenario to completion on a fresh backend with `threads`
/// pool lanes. Closed loop: all requests are queued up front, then the
/// engine steps until every generation finishes (admission backpressure
/// paces the queue through the KV slots). Runs with the trace recorder
/// *disabled* — the clean-performance baseline.
pub fn run_scenario(spec: &LoadSpec, threads: usize) -> Result<ScenarioResult> {
    run_scenario_with(spec, threads, 0, 0, None).map(|(r, _)| r)
}

/// [`run_scenario`] with a live trace ring of `trace_capacity` events —
/// the measured side of the recorder-overhead gate.
pub fn run_scenario_traced(
    spec: &LoadSpec,
    threads: usize,
    trace_capacity: usize,
) -> Result<ScenarioResult> {
    run_scenario_with(spec, threads, trace_capacity, 0, None).map(|(r, _)| r)
}

/// [`run_scenario`] with the online quality probe firing every
/// `probe_every` committed plain decode steps (trace ring disabled) —
/// the measured side of the probe-overhead gate in
/// `benches/quality_vs_speed.rs`.
pub fn run_scenario_probed(
    spec: &LoadSpec,
    threads: usize,
    probe_every: usize,
) -> Result<ScenarioResult> {
    run_scenario_with(spec, threads, 0, probe_every, None).map(|(r, _)| r)
}

/// [`run_scenario`] with the kernel profiler attached (trace ring and
/// probes disabled): returns the scenario result plus the per-site
/// roofline [`ProfileReport`] evaluated against `host` — the measured
/// side of the profiler-overhead gate in `benches/kernel_profile.rs`.
pub fn run_scenario_profiled(
    spec: &LoadSpec,
    threads: usize,
    host: &HostSpec,
) -> Result<(ScenarioResult, ProfileReport)> {
    let (r, rep) = run_scenario_with(spec, threads, 0, 0, Some(host))?;
    match rep {
        Some(rep) => Ok((r, rep)),
        None => bail!("scenario {}: backend has no pooled profiler", spec.name),
    }
}

fn run_scenario_with(
    spec: &LoadSpec,
    threads: usize,
    trace_capacity: usize,
    probe_every: usize,
    profile_host: Option<&HostSpec>,
) -> Result<(ScenarioResult, Option<ProfileReport>)> {
    let dir = crate::artifacts_dir();
    let backend = match spec.exec_bits {
        Some(bits) => NativeBackend::new(&dir).with_exec_quant(QuantSpec::new(bits, 32)),
        None => NativeBackend::new(&dir),
    }
    .with_threads(threads);

    let mut cfg = ServerConfig::new(&spec.model)
        .with_method(MethodSpec::ttq(0))
        .with_trace_capacity(trace_capacity)
        .with_probe_every(probe_every)
        .with_profile(profile_host.is_some());
    cfg.spec = QuantSpec::new(spec.exec_bits.unwrap_or(4), 32);
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::ZERO };
    cfg.max_new_tokens = spec.max_new_tokens.max(1);
    cfg.cache_slots = 8;
    cfg.specdec = SpecConfig::new(4);
    let mut server = Server::new(&backend, cfg)?;
    let max_seq = server.max_seq();
    let (num, den) = spec.prompt_frac;
    let prompt_len = (max_seq * num / den.max(1)).clamp(1, max_seq);

    let mut streams: Vec<CorpusStream> = spec
        .domains
        .iter()
        .map(|d| CorpusStream::new(d, Split::Eval))
        .collect();
    if streams.is_empty() {
        bail!("scenario {} has no domains", spec.name);
    }
    for i in 0..spec.requests {
        // the stream hops domains as the run progresses — multi-domain
        // scenarios shift traffic mid-stream and trip the drift detector
        let di = ((i * streams.len()) / spec.requests.max(1)).min(streams.len() - 1);
        let s = &mut streams[di];
        let mut toks = vec![BOS; prompt_len];
        for t in toks.iter_mut().skip(1) {
            *t = s.next_token();
        }
        if spec.speculative {
            server.submit_speculative(toks);
        } else {
            server.submit(toks);
        }
    }

    let t_wall = Instant::now();
    let lat = Hist::new();
    let (mut streamed, mut done) = (0usize, 0usize);
    while server.pending() > 0 || server.running() > 0 {
        let t0 = Instant::now();
        let evs = server.step()?;
        let dt_us = t0.elapsed().as_micros() as u64;
        let toks = evs
            .iter()
            .filter(|e| matches!(e, ServeEvent::Token { .. }))
            .count();
        done += evs
            .iter()
            .filter(|e| matches!(e, ServeEvent::Done { .. }))
            .count();
        if toks > 0 {
            // attribute the step's latency evenly to its tokens, one
            // sample per token so percentiles weight by token count
            let per_us = dt_us / toks as u64;
            for _ in 0..toks {
                lat.record(per_us);
            }
            streamed += toks;
        }
    }
    let wall_s = t_wall.elapsed().as_secs_f64();
    if done != spec.requests {
        bail!("scenario {}: {done} of {} requests completed", spec.name, spec.requests);
    }

    let profile = if let Some(h) = profile_host {
        match server.profile_report(h) {
            Some(rep) => Some(rep),
            None => bail!("scenario {}: backend has no pooled profiler", spec.name),
        }
    } else {
        None
    };

    use std::sync::atomic::Ordering::Relaxed;
    Ok((ScenarioResult {
        name: spec.name.clone(),
        threads,
        exec: spec.exec_bits.map_or_else(|| "fp32".into(), |b| format!("w{b}")),
        requests: done,
        streamed_tokens: streamed,
        wall_s,
        tokens_per_sec: if wall_s > 0.0 { streamed as f64 / wall_s } else { 0.0 },
        decode_tokens_per_sec: server.metrics.decode_tokens_per_sec(),
        p50_token_ms: lat.p50() / 1e3,
        p95_token_ms: lat.p95() / 1e3,
        p99_token_ms: lat.p99() / 1e3,
        token_us_buckets: lat.nonzero_buckets(),
        requants: server.metrics.requants.load(Relaxed),
        spec_acceptance: server.metrics.spec_acceptance(),
        kernel_share: server.metrics.kernel_share(),
    }, profile))
}

/// The serving mix the throughput bench sweeps (see the module docs).
/// `fast` shrinks request counts for CI.
pub fn default_scenarios(fast: bool) -> Vec<LoadSpec> {
    let r = |full: usize| if fast { full / 3 } else { full };
    let d = |names: &[&str]| names.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    vec![
        LoadSpec {
            name: "short-chat".into(),
            model: "qwen-micro".into(),
            prompt_frac: (1, 8),
            max_new_tokens: 10,
            requests: r(36),
            domains: d(&["wt2s"]),
            speculative: false,
            exec_bits: Some(4),
        },
        LoadSpec {
            name: "long-prefill".into(),
            model: "qwen-micro".into(),
            prompt_frac: (7, 8),
            max_new_tokens: 4,
            requests: r(24),
            domains: d(&["c4s"]),
            speculative: false,
            exec_bits: Some(4),
        },
        LoadSpec {
            name: "mixed-domain-drift".into(),
            model: "qwen-micro".into(),
            prompt_frac: (1, 2),
            max_new_tokens: 8,
            requests: r(36),
            domains: d(&["wt2s", "c4s", "ptbs"]),
            speculative: false,
            exec_bits: Some(4),
        },
        LoadSpec {
            name: "specdec-heavy".into(),
            model: "qwen-micro".into(),
            prompt_frac: (1, 2),
            max_new_tokens: 10,
            requests: r(18),
            domains: d(&["wt2s"]),
            speculative: true,
            exec_bits: None,
        },
        LoadSpec {
            name: "fp32-decode".into(),
            model: "opt-small".into(),
            prompt_frac: (1, 4),
            max_new_tokens: 12,
            requests: r(18),
            domains: d(&["wt2s"]),
            speculative: false,
            exec_bits: None,
        },
        LoadSpec {
            name: "w4-decode".into(),
            model: "opt-small".into(),
            prompt_frac: (1, 4),
            max_new_tokens: 12,
            requests: r(18),
            domains: d(&["wt2s"]),
            speculative: false,
            exec_bits: Some(4),
        },
    ]
}

// ---------------------------------------------------------------------
// Pooled-vs-scoped kernel baseline
// ---------------------------------------------------------------------

/// The pre-pool threaded kernel, retained verbatim as the perf-gate
/// baseline: `a @ bᵀ` with output rows split across **freshly spawned**
/// scoped threads — one OS thread creation per chunk *per call*, the
/// cost every matmul paid before [`WorkerPool`] existed.
#[allow(clippy::disallowed_methods)] // retained spawn-per-call baseline (repo-lint R1 allowlist)
pub fn scoped_matmul_bt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.cols, "scoped_matmul_bt dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    if threads <= 1 || m < 2 || m * k * n < MT_FLOP_FLOOR {
        return a.matmul_bt(b);
    }
    let mut out = Mat::zeros(m, n);
    let nthreads = threads.min(m);
    let chunk = m.div_ceil(nthreads);
    std::thread::scope(|s| {
        for (ti, orows) in out.data.chunks_mut(chunk * n).enumerate() {
            s.spawn(move || {
                let r0 = ti * chunk;
                let rows = orows.len() / n;
                for rr in 0..rows {
                    let arow = a.row(r0 + rr);
                    let orow = &mut orows[rr * n..(rr + 1) * n];
                    for (j, o) in orow.iter_mut().enumerate() {
                        let brow = b.row(j);
                        let mut acc = 0.0f32;
                        for p in 0..k {
                            acc += arow[p] * brow[p];
                        }
                        *o = acc;
                    }
                }
            });
        }
    });
    out
}

/// Pooled-vs-scoped kernel throughput on a decode-shaped stream.
#[derive(Clone, Copy, Debug)]
pub struct KernelBaseline {
    /// Pool lanes / scoped threads compared.
    pub threads: usize,
    /// Pooled kernel throughput, Gflop/s (median sample).
    pub pooled_gflops: f64,
    /// Scoped spawn-per-call kernel throughput, Gflop/s.
    pub scoped_gflops: f64,
    /// `pooled / scoped` — the dispatch-amortization win.
    pub speedup: f64,
}

/// Time the pooled kernel against the retained scoped-thread kernel on
/// a stream of decode-shaped matmuls (a small token block against an
/// `opt-small`-sized MLP weight, many calls per sample) — the regime
/// where per-call spawn/join dominates and the persistent pool earns
/// its keep.
pub fn kernel_baseline(threads: usize, fast: bool) -> KernelBaseline {
    let mut rng = Rng::new(42);
    let a = Mat::randn(8, 192, &mut rng); // one small decode batch
    let b = Mat::randn(768, 192, &mut rng); // an opt-small MLP weight
    let calls_per_sample = if fast { 40 } else { 120 };
    let flops = 2.0 * 8.0 * 192.0 * 768.0 * calls_per_sample as f64;
    let bencher = if fast { Bencher::quick() } else { Bencher::default() };

    let pool = WorkerPool::new(threads);
    let pooled = bencher.run_with_items("pooled matmul_bt_mt", flops, || {
        let mut last = 0.0f32;
        for _ in 0..calls_per_sample {
            let y = matmul_bt_mt(&a, &b, &pool);
            last = y.data[0];
        }
        black_box(last)
    });
    let scoped = bencher.run_with_items("scoped-thread baseline", flops, || {
        let mut last = 0.0f32;
        for _ in 0..calls_per_sample {
            let y = scoped_matmul_bt(&a, &b, threads);
            last = y.data[0];
        }
        black_box(last)
    });
    let pooled_gflops = pooled.throughput().unwrap_or(0.0) / 1e9;
    let scoped_gflops = scoped.throughput().unwrap_or(0.0) / 1e9;
    KernelBaseline {
        threads,
        pooled_gflops,
        scoped_gflops,
        speedup: if scoped_gflops > 0.0 { pooled_gflops / scoped_gflops } else { 0.0 },
    }
}

// ---------------------------------------------------------------------
// Scalar-vs-SIMD kernel baseline
// ---------------------------------------------------------------------

/// Selected-ISA vs forced-scalar throughput for one kernel class
/// (`fp32_gemm` via [`matmul_bt_mt`], `packed_w4` via
/// [`packed_matmul_nt`]) — the instruction-level counterpart of
/// [`KernelBaseline`]'s thread-level comparison.
#[derive(Clone, Debug)]
pub struct SimdBaseline {
    /// Kernel class: `"fp32_gemm"` or `"packed_w4"`.
    pub kernel: &'static str,
    /// The selected ISA's name (`"avx2"` / `"neon"` / `"scalar"`).
    pub isa: &'static str,
    /// Selected-ISA throughput, Gflop/s (median sample).
    pub simd_gflops: f64,
    /// Forced-scalar throughput, Gflop/s.
    pub scalar_gflops: f64,
    /// `simd / scalar` — the vectorization win (1.0 ≈ none).
    pub speedup: f64,
}

impl SimdBaseline {
    /// One JSON object for the `simd_baseline` array of
    /// `BENCH_throughput.json` (schema: `docs/BENCHMARKS.md`).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"kernel\": \"{}\", \"isa\": \"{}\", \"simd_gflops\": {:.3}, \
             \"scalar_gflops\": {:.3}, \"speedup\": {:.3}}}",
            self.kernel, self.isa, self.simd_gflops, self.scalar_gflops, self.speedup
        )
    }
}

fn simd_class(
    bencher: &Bencher,
    kernel: &'static str,
    isa: Isa,
    flops: f64,
    mut body: impl FnMut(&WorkerPool) -> f32,
) -> SimdBaseline {
    // Single-lane pools: the comparison isolates the instruction-level
    // dispatch, so thread fan-out (kernel_baseline's subject) stays out.
    let scalar_pool = WorkerPool::new_with_isa(1, Isa::Scalar);
    let simd_pool = WorkerPool::new_with_isa(1, isa);
    let simd = bencher.run_with_items(&format!("{kernel} {}", isa.name()), flops, || {
        black_box(body(&simd_pool))
    });
    let scalar = bencher.run_with_items(&format!("{kernel} scalar"), flops, || {
        black_box(body(&scalar_pool))
    });
    let simd_gflops = simd.throughput().unwrap_or(0.0) / 1e9;
    let scalar_gflops = scalar.throughput().unwrap_or(0.0) / 1e9;
    SimdBaseline {
        kernel,
        isa: isa.name(),
        simd_gflops,
        scalar_gflops,
        speedup: if scalar_gflops > 0.0 { simd_gflops / scalar_gflops } else { 0.0 },
    }
}

/// Time the selected-ISA inner kernels against the forced-scalar path,
/// one row per kernel class, on decode-shaped streams (small token
/// block × `opt-small`-sized MLP weight). On a host where [`select`]
/// returns scalar (no AVX2/NEON, or `TTQ_FORCE_SCALAR`), both sides
/// run the same code and the speedup hovers at 1.0 — the bench gate
/// treats that case as informational, not a failure.
pub fn simd_baseline(fast: bool) -> Vec<SimdBaseline> {
    let isa = select();
    let mut rng = Rng::new(43);
    let bencher = if fast { Bencher::quick() } else { Bencher::default() };
    let calls = if fast { 40 } else { 120 };

    // fp32_gemm: the same decode-shaped stream kernel_baseline uses.
    let a = Mat::randn(8, 192, &mut rng);
    let b = Mat::randn(768, 192, &mut rng);
    let fp32_flops = 2.0 * 8.0 * 192.0 * 768.0 * calls as f64;
    let fp32 = simd_class(&bencher, "fp32_gemm", isa, fp32_flops, |pool| {
        let mut last = 0.0f32;
        for _ in 0..calls {
            last = matmul_bt_mt(&a, &b, pool).data[0];
        }
        last
    });

    // packed_w4: grouped 4-bit weight, single-token decode GEMV.
    let w = Mat::randn(768, 192, &mut rng);
    let p = pack(&rtn_quantize_int(&w, &QuantSpec::new(4, 32)));
    let x = Mat::randn(1, 192, &mut rng);
    let w4_flops = 2.0 * 192.0 * 768.0 * calls as f64;
    let w4 = simd_class(&bencher, "packed_w4", isa, w4_flops, |pool| {
        let mut last = 0.0f32;
        for _ in 0..calls {
            last = packed_matmul_nt(&p, &x, pool).data[0];
        }
        last
    });

    vec![fp32, w4]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_runs_to_completion() {
        let spec = LoadSpec {
            name: "unit".into(),
            model: "qwen-micro".into(),
            prompt_frac: (1, 4),
            max_new_tokens: 3,
            requests: 4,
            domains: vec!["wt2s".into()],
            speculative: false,
            exec_bits: Some(4),
        };
        let r = run_scenario(&spec, 2).unwrap();
        assert_eq!(r.requests, 4);
        assert!(r.streamed_tokens >= 4, "at least one token per request");
        assert!(r.tokens_per_sec > 0.0);
        assert!(r.p95_token_ms >= r.p50_token_ms);
        assert!(r.p99_token_ms >= r.p95_token_ms);
        let bucketed: u64 = r.token_us_buckets.iter().map(|b| b.count).sum();
        assert_eq!(
            bucketed, r.streamed_tokens as u64,
            "bucket counts account for every streamed token"
        );
        // JSON line stays machine-parseable with the new fields
        let v = crate::util::json::Value::parse(&r.to_json()).unwrap();
        assert!(v.get("p99_token_ms").and_then(|x| x.as_f64()).is_some());
        assert!(v.get("token_us_buckets").and_then(|x| x.as_arr()).is_some());
    }

    #[test]
    fn traced_scenario_records_spans() {
        let spec = LoadSpec {
            name: "unit-traced".into(),
            model: "qwen-micro".into(),
            prompt_frac: (1, 4),
            max_new_tokens: 3,
            requests: 2,
            domains: vec!["wt2s".into()],
            speculative: false,
            exec_bits: Some(4),
        };
        let r = run_scenario_traced(&spec, 2, 4096).unwrap();
        assert_eq!(r.requests, 2);
        assert!(r.streamed_tokens >= 2);
    }

    #[test]
    fn profiled_scenario_attributes_kernel_time() {
        let spec = LoadSpec {
            name: "unit-profiled".into(),
            model: "qwen-micro".into(),
            prompt_frac: (1, 4),
            max_new_tokens: 3,
            requests: 2,
            domains: vec!["wt2s".into()],
            speculative: false,
            exec_bits: Some(4),
        };
        let (r, rep) = run_scenario_profiled(&spec, 2, &HostSpec::synthetic(8.0, 40.0)).unwrap();
        assert_eq!(r.requests, 2);
        assert!(!rep.sites.is_empty(), "profiled run names at least one site");
        assert!(rep.attributed_us > 0);
        assert_eq!(rep.dropped, 0);
        // every observed phase is a serving phase the server sets
        for s in &rep.sites {
            let p = s.site.phase.name();
            assert!(p == "prefill" || p == "decode", "{p}");
        }
    }

    #[test]
    fn scoped_baseline_matches_pooled_values() {
        let mut rng = Rng::new(7);
        let a = Mat::randn(8, 64, &mut rng);
        let b = Mat::randn(48, 64, &mut rng);
        let want = scoped_matmul_bt(&a, &b, 2);
        let got = matmul_bt_mt(&a, &b, &WorkerPool::new(2));
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn simd_baseline_reports_both_kernel_classes() {
        let rows = simd_baseline(true);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].kernel, "fp32_gemm");
        assert_eq!(rows[1].kernel, "packed_w4");
        for r in &rows {
            assert_eq!(r.isa, select().name(), "{}: rows carry the selected ISA", r.kernel);
            assert!(r.simd_gflops > 0.0 && r.scalar_gflops > 0.0, "{}", r.kernel);
            assert!(r.speedup > 0.0, "{}", r.kernel);
            let j = r.to_json();
            assert!(j.contains("\"kernel\"") && j.contains("\"speedup\""), "{j}");
        }
    }
}
