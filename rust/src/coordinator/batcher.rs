//! Shape-bucketed dynamic batcher.
//!
//! PJRT executables are compiled for fixed (batch, seq) shapes, so the
//! batcher groups incoming requests into the AOT bucket sizes
//! (`aot.BUCKETS` — {1, 4} per variant). A batch is released when
//! (a) the largest bucket fills, or (b) the oldest queued request has
//! waited past `linger`, in which case the largest bucket that can be
//! *fully or partially* satisfied fires (padding rows repeat the last
//! request — they are masked out of replies).

use std::collections::VecDeque;
use std::time::Duration;

/// Monotonically increasing server-assigned request identifier.
pub type RequestId = u64;

/// One inference request: a token prompt for a model.
#[derive(Clone, Debug)]
pub struct Request {
    /// Server-assigned identifier (echoed in every reply event).
    pub id: RequestId,
    /// BOS-led prompt, `1..=max_seq` tokens (the decode engine admits
    /// variable-length prompts; [`Batch::tokens`] still requires
    /// fixed-`seq` rows for the legacy full-batch executable path).
    pub tokens: Vec<i32>,
    /// Submission time in microseconds on the server's
    /// [`crate::obs::Clock`] (drives linger, latency accounting and
    /// the request's trace span).
    pub arrived_us: u64,
}

impl Request {
    /// Request arriving at `arrived_us` (a [`crate::obs::Clock`]
    /// reading — the serving path never reads wall clocks directly,
    /// repo-lint R6).
    pub fn new(id: RequestId, tokens: Vec<i32>, arrived_us: u64) -> Self {
        Request { id, tokens, arrived_us }
    }
}

/// Released batch: bucket size + member requests (≤ bucket).
#[derive(Debug)]
pub struct Batch {
    /// The shape bucket this batch fired at.
    pub bucket: usize,
    /// Member requests (≤ bucket; the slack is padding headroom).
    pub requests: Vec<Request>,
}

impl Batch {
    /// A released batch must carry at least one real request (padding
    /// rows are synthesized in [`Batch::tokens`], never stored).
    pub fn new(bucket: usize, requests: Vec<Request>) -> Self {
        debug_assert!(!requests.is_empty(), "batch released with zero requests");
        debug_assert!(
            requests.len() <= bucket,
            "{} requests for bucket {bucket}",
            requests.len()
        );
        Batch { bucket, requests }
    }

    /// Flat (bucket × seq) token block; padding rows clone the last
    /// real request so the executable always sees a full batch.
    pub fn tokens(&self, seq: usize) -> Vec<i32> {
        // Defensive: an empty batch would underflow `len() - 1` below.
        assert!(
            !self.requests.is_empty(),
            "Batch::tokens on a batch with zero requests"
        );
        let mut out = Vec::with_capacity(self.bucket * seq);
        for i in 0..self.bucket {
            let r = &self.requests[i.min(self.requests.len() - 1)];
            assert_eq!(r.tokens.len(), seq, "request length != model seq");
            out.extend_from_slice(&r.tokens);
        }
        out
    }

    /// Bucket slack: rows the bucket has over the real request count.
    pub fn padding_rows(&self) -> usize {
        self.bucket - self.requests.len()
    }
}

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Available bucket sizes, ascending (must match compiled shapes).
    pub buckets: Vec<usize>,
    /// Max time the oldest request may wait before a partial batch fires.
    pub linger: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { buckets: vec![1, 4], linger: Duration::from_millis(2) }
    }
}

/// FIFO queue + bucket selection. Single-model (the server holds one
/// per model); synchronization lives in the server loop.
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<Request>,
}

impl Batcher {
    /// Empty queue under the given policy (buckets are sorted).
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(!policy.buckets.is_empty());
        let mut p = policy;
        p.buckets.sort_unstable();
        Batcher { policy: p, queue: VecDeque::new() }
    }

    /// Enqueue an arriving request (FIFO).
    pub fn push(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    /// Put a request back at the *front* of the queue (admission
    /// backpressure: the server re-queues batch members it could not
    /// get a KV-cache slot for, preserving FIFO order).
    pub fn requeue(&mut self, r: Request) {
        self.queue.push_front(r);
    }

    /// Requests queued, not yet released in a batch.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Largest bucket ≤ n (None if even the smallest doesn't fit —
    /// impossible since buckets start at 1 and n ≥ 1).
    fn bucket_for(&self, n: usize) -> usize {
        *self
            .policy
            .buckets
            .iter()
            .filter(|&&b| b <= n)
            .next_back()
            .unwrap_or(&self.policy.buckets[0])
    }

    /// Largest configured bucket. Buckets are non-empty by construction
    /// (asserted in [`Batcher::new`]); the fallback of 1 degrades to
    /// single-request batches instead of panicking (repo-lint R3 bans
    /// `unwrap` on the serving path).
    fn max_bucket(&self) -> usize {
        self.policy.buckets.last().copied().unwrap_or(1)
    }

    /// Smallest bucket ≥ n (for padding partial linger batches).
    fn bucket_covering(&self, n: usize) -> usize {
        self.policy
            .buckets
            .iter()
            .find(|&&b| b >= n)
            .copied()
            .unwrap_or_else(|| self.max_bucket())
    }

    /// Poll for a ready batch at clock reading `now_us` (microseconds
    /// on the same [`crate::obs::Clock`] that stamped the requests).
    pub fn poll(&mut self, now_us: u64) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let max_bucket = self.max_bucket();
        if self.queue.len() >= max_bucket {
            let requests: Vec<Request> =
                self.queue.drain(..max_bucket).collect();
            return Some(Batch::new(max_bucket, requests));
        }
        let oldest = self.queue.front()?.arrived_us;
        if now_us.saturating_sub(oldest) >= self.policy.linger.as_micros() as u64 {
            return Some(self.release_partial());
        }
        None
    }

    /// Release queued requests immediately, ignoring the linger
    /// deadline — the drain/shutdown path. Same bucket selection as a
    /// linger-expired [`Self::poll`], with no fabricated clock.
    pub fn force_flush(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let max_bucket = self.max_bucket();
        if self.queue.len() >= max_bucket {
            let requests: Vec<Request> =
                self.queue.drain(..max_bucket).collect();
            return Some(Batch::new(max_bucket, requests));
        }
        Some(self.release_partial())
    }

    /// Fire a partial batch (queue shorter than the largest bucket).
    /// Exact bucket: take it. Otherwise trade padded rows vs extra
    /// launches: pad up to the covering bucket when the waste is at
    /// most half the bucket (one launch clears the queue); else drain
    /// the largest full bucket and let the remainder fire next poll.
    fn release_partial(&mut self) -> Batch {
        let n = self.queue.len();
        debug_assert!(n > 0);
        let (bucket, take) = if self.policy.buckets.contains(&n) {
            (n, n)
        } else {
            let covering = self.bucket_covering(n);
            if covering >= n && covering - n <= covering / 2 {
                (covering, n)
            } else {
                let b = self.bucket_for(n);
                (b, b.min(n))
            }
        };
        let requests: Vec<Request> = self.queue.drain(..take).collect();
        Batch::new(bucket, requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0; 8], 0)
    }

    fn mk(buckets: Vec<usize>, linger_ms: u64) -> Batcher {
        Batcher::new(BatchPolicy {
            buckets,
            linger: Duration::from_millis(linger_ms),
        })
    }

    #[test]
    fn full_bucket_fires_immediately() {
        let mut b = mk(vec![1, 4], 1000);
        for i in 0..4 {
            b.push(req(i));
        }
        let batch = b.poll(0).expect("full bucket");
        assert_eq!(batch.bucket, 4);
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn no_fire_before_linger() {
        let mut b = mk(vec![1, 4], 1000);
        b.push(req(0));
        assert!(b.poll(0).is_none(), "linger not expired at t=0");
    }

    #[test]
    fn linger_fires_single() {
        let mut b = mk(vec![1, 4], 0);
        b.push(req(0));
        let batch = b.poll(1_000).unwrap();
        assert_eq!(batch.bucket, 1);
        assert_eq!(batch.padding_rows(), 0);
    }

    #[test]
    fn linger_pads_between_buckets() {
        let mut b = mk(vec![1, 4], 0);
        for i in 0..3 {
            b.push(req(i));
        }
        let batch = b.poll(1_000).unwrap();
        // 3 requests, buckets {1,4}: largest full bucket is 1, but the
        // policy prefers covering all 3 with a padded 4-batch over three
        // sequential singles.
        assert_eq!(batch.bucket, 4);
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.padding_rows(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn overfull_queue_drains_in_bucket_chunks() {
        let mut b = mk(vec![1, 4], 1000);
        for i in 0..9 {
            b.push(req(i));
        }
        let b1 = b.poll(0).unwrap();
        let b2 = b.poll(0).unwrap();
        assert_eq!(b1.bucket, 4);
        assert_eq!(b2.bucket, 4);
        assert_eq!(b.pending(), 1);
        // last one waits for linger
        assert!(b.poll(0).is_none(), "linger not expired at t=0");
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = mk(vec![1, 4], 1000);
        for i in 0..4 {
            b.push(req(i));
        }
        let batch = b.poll(0).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "zero requests")]
    fn empty_batch_tokens_panics_descriptively() {
        // Construct the pathological batch directly (poll never emits
        // one): `tokens` must fail loudly, not underflow `len() - 1`.
        let b = Batch { bucket: 4, requests: Vec::new() };
        let _ = b.tokens(8);
    }

    #[test]
    fn force_flush_fires_without_waiting() {
        let mut b = mk(vec![1, 4], 1000);
        b.push(req(0));
        assert!(b.poll(0).is_none(), "linger not expired");
        let batch = b.force_flush().expect("flush ignores linger");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(b.pending(), 0);
        assert!(b.force_flush().is_none(), "empty queue flushes nothing");
    }

    #[test]
    fn force_flush_drains_full_buckets_first() {
        let mut b = mk(vec![1, 4], 1000);
        for i in 0..5 {
            b.push(req(i));
        }
        let b1 = b.force_flush().unwrap();
        assert_eq!(b1.bucket, 4);
        let b2 = b.force_flush().unwrap();
        assert_eq!(b2.requests.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn requeue_restores_fifo_front() {
        let mut b = mk(vec![1, 4], 0);
        for i in 0..3 {
            b.push(req(i));
        }
        let batch = b.force_flush().unwrap();
        // admission failed for the last two: requeue in reverse order
        let mut rs = batch.requests;
        let r2 = rs.pop().unwrap();
        let r1 = rs.pop().unwrap();
        b.requeue(r2);
        b.requeue(r1);
        let again = b.force_flush().unwrap();
        let ids: Vec<u64> = again.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2], "requeued requests keep their order");
    }

    #[test]
    fn tokens_pads_with_last_request() {
        let mut b = mk(vec![4], 0);
        b.push(Request::new(0, vec![1; 8], 0));
        b.push(Request::new(1, vec![2; 8], 0));
        let batch = b.poll(1_000).unwrap();
        let toks = batch.tokens(8);
        assert_eq!(toks.len(), 32);
        assert_eq!(&toks[0..8], &[1; 8]);
        assert_eq!(&toks[8..16], &[2; 8]);
        assert_eq!(&toks[16..24], &[2; 8]); // padding repeats last
    }
}
