//! Online TTQ calibrator — the coordinator's half of Fig. 1(b).
//!
//! Keeps per-linear running activation statistics (norm sums with
//! exponential decay) fed by the stats artifact on prefill batches, and
//! decides *when* requantization is worth it: weights are re-quantized
//! when the accumulated diagonal has drifted past a threshold from the
//! diagonal used for the current weight generation. This implements the
//! paper's "capable of on-device self-calibration at inference time"
//! with the amortization the runtime benches assume (quantize ≈ once
//! per prompt/domain-shift, not per token).

use crate::quant::{diag_from_norm_sums, ActStats, TtqHyper};

/// Calibrator knobs: statistics decay + requant drift threshold.
#[derive(Clone, Debug)]
pub struct CalibratorConfig {
    /// Exponential decay applied to old statistics per update.
    pub decay: f64,
    /// Relative L2 drift of D that triggers requantization.
    pub drift_threshold: f64,
    /// Diagonal hyperparameters (p, λ, α) D is derived with.
    pub hyper: TtqHyper,
}

impl Default for CalibratorConfig {
    fn default() -> Self {
        CalibratorConfig {
            decay: 0.8,
            drift_threshold: 0.05,
            hyper: TtqHyper::default(),
        }
    }
}

impl CalibratorConfig {
    /// This config with the diagonal hyperparameters taken from a
    /// registry method (the serving engine keeps the calibrator's D
    /// consistent with the method that will consume it). Unchanged for
    /// methods without a diagonal.
    pub fn for_method(mut self, method: &crate::quant::MethodSpec) -> Self {
        if let Some(h) = method.quantizer().diag_hyper() {
            self.hyper = h;
        }
        self
    }
}

/// State for one linear layer.
struct LayerState {
    stats: ActStats,
    /// Diagonal used by the *current* quantized weight generation.
    active_diag: Option<Vec<f32>>,
}

/// Running calibration state for one model.
pub struct OnlineCalibrator {
    cfg: CalibratorConfig,
    layers: Vec<LayerState>,
    generation: u64,
    /// Rows (tokens) observed since the last [`Self::commit`] — the
    /// "how much evidence triggered this requant" introspection field
    /// of [`crate::obs::RequantEvent`].
    observed_since_commit: f64,
}

impl OnlineCalibrator {
    /// Fresh state for layers of the given input widths on the p-grid.
    pub fn new(cfg: CalibratorConfig, ps: &[f64], d_ins: &[usize]) -> Self {
        let layers = d_ins
            .iter()
            .map(|&d| LayerState { stats: ActStats::new(ps, d), active_diag: None })
            .collect();
        OnlineCalibrator { cfg, layers, generation: 0, observed_since_commit: 0.0 }
    }

    /// Committed weight generations so far (bumped per requant).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The configured drift threshold (requant fires above it).
    pub fn drift_threshold(&self) -> f64 {
        self.cfg.drift_threshold
    }

    /// Feed fresh per-layer norm sums from a stats pass.
    pub fn observe(&mut self, per_layer: &[ActStats]) {
        assert_eq!(per_layer.len(), self.layers.len());
        self.observed_since_commit += per_layer.first().map_or(0.0, |s| s.count);
        for (layer, fresh) in self.layers.iter_mut().zip(per_layer) {
            layer.stats.decay(self.cfg.decay);
            layer.stats.accumulate(&fresh.norm_sums, fresh.count);
        }
    }

    /// Rows (tokens) observed since the last commit.
    pub fn tokens_since_commit(&self) -> f64 {
        self.observed_since_commit
    }

    /// Current diagonal for a layer.
    pub fn diag(&self, layer: usize) -> Vec<f32> {
        let h = &self.cfg.hyper;
        diag_from_norm_sums(&self.layers[layer].stats, h.p, h.lam, h.alpha)
    }

    /// Relative drift between *scale-normalized* diagonals (∞ if the
    /// layer was never quantized). Normalization matters: the scaled
    /// QDQ of Eq. 20 is invariant to a constant factor on D, so only
    /// directional change in the channel profile warrants requanting —
    /// otherwise statistics accumulation alone would thrash the weights.
    fn drift(&self, layer: usize) -> f64 {
        let new = self.diag(layer);
        match &self.layers[layer].active_diag {
            None => f64::INFINITY,
            Some(act) => {
                let norm = |v: &[f32]| {
                    v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt()
                };
                let (na, nb) = (norm(act).max(1e-30), norm(&new).max(1e-30));
                let mut num = 0.0f64;
                for (a, b) in act.iter().zip(&new) {
                    let d = *a as f64 / na - *b as f64 / nb;
                    num += d * d;
                }
                num.sqrt()
            }
        }
    }

    /// Should the server requantize now? True when any layer drifted.
    pub fn needs_requant(&self) -> bool {
        (0..self.layers.len()).any(|i| self.drift(i) > self.cfg.drift_threshold)
    }

    /// Mark the current statistics as the active weight generation and
    /// return the per-layer diagonals to quantize with.
    pub fn commit(&mut self) -> Vec<Vec<f32>> {
        let diags: Vec<Vec<f32>> =
            (0..self.layers.len()).map(|i| self.diag(i)).collect();
        for (layer, d) in self.layers.iter_mut().zip(&diags) {
            layer.active_diag = Some(d.clone());
        }
        self.generation += 1;
        self.observed_since_commit = 0.0;
        diags
    }

    /// Largest per-layer drift (diagnostics/tests).
    pub fn max_drift(&self) -> f64 {
        (0..self.layers.len())
            .map(|i| self.drift(i))
            .fold(0.0, f64::max)
    }

    /// Per-layer drift scores vs. the active generation, indexed by
    /// layer (∞ for never-quantized layers). Snapshot this *before*
    /// [`Self::commit`] to explain a requant decision
    /// ([`crate::obs::RequantEvent::layer_drifts`]).
    pub fn drifts(&self) -> Vec<f64> {
        (0..self.layers.len()).map(|i| self.drift(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_with(d: usize, val: f64, count: f64) -> ActStats {
        let ps = [2.0f64];
        let mut s = ActStats::new(&ps, d);
        s.accumulate(&[vec![val; d]], count);
        s
    }

    /// Stats with a *shaped* channel profile (drift is profile-based:
    /// uniform rescaling is invariant under Eq. 20).
    fn stats_shaped(d: usize, hot: f64, count: f64) -> ActStats {
        let ps = [2.0f64];
        let mut s = ActStats::new(&ps, d);
        let vals: Vec<f64> = (0..d)
            .map(|i| if i % 2 == 0 { hot } else { 1.0 })
            .collect();
        s.accumulate(&[vals], count);
        s
    }

    fn mk(d: usize) -> OnlineCalibrator {
        OnlineCalibrator::new(CalibratorConfig::default(), &[2.0], &[d, d])
    }

    #[test]
    fn fresh_calibrator_needs_requant() {
        let mut c = mk(8);
        c.observe(&[stats_with(8, 1.0, 4.0), stats_with(8, 1.0, 4.0)]);
        assert!(c.needs_requant());
        assert_eq!(c.generation(), 0);
    }

    #[test]
    fn commit_clears_need() {
        let mut c = mk(8);
        c.observe(&[stats_with(8, 1.0, 4.0), stats_with(8, 1.0, 4.0)]);
        let diags = c.commit();
        assert_eq!(diags.len(), 2);
        assert_eq!(c.generation(), 1);
        assert!(!c.needs_requant(), "no drift right after commit");
    }

    #[test]
    fn same_domain_does_not_retrigger() {
        let mut c = mk(8);
        for _ in 0..5 {
            c.observe(&[stats_with(8, 1.0, 4.0), stats_with(8, 1.0, 4.0)]);
        }
        c.commit();
        c.observe(&[stats_with(8, 1.0, 4.0), stats_with(8, 1.0, 4.0)]);
        assert!(!c.needs_requant(), "drift {}", c.max_drift());
    }

    #[test]
    fn domain_shift_triggers_requant() {
        let mut c = mk(8);
        c.observe(&[stats_with(8, 1.0, 4.0), stats_with(8, 1.0, 4.0)]);
        c.commit();
        // a different channel *profile* arrives (uniform rescaling would
        // be invariant — Eq. 20 — so shift the shape, not the scale)
        for _ in 0..4 {
            c.observe(&[stats_shaped(8, 400.0, 4.0), stats_shaped(8, 400.0, 4.0)]);
        }
        assert!(c.needs_requant(), "drift {}", c.max_drift());
        let g0 = c.generation();
        c.commit();
        assert_eq!(c.generation(), g0 + 1);
    }

    #[test]
    fn drift_introspection_tracks_layers_and_tokens() {
        let mut c = mk(8);
        c.observe(&[stats_with(8, 1.0, 4.0), stats_with(8, 1.0, 4.0)]);
        assert_eq!(c.tokens_since_commit(), 4.0);
        let d = c.drifts();
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.is_infinite()), "never quantized → ∞");
        c.commit();
        assert_eq!(c.tokens_since_commit(), 0.0, "commit resets evidence");
        for _ in 0..4 {
            c.observe(&[stats_shaped(8, 400.0, 4.0), stats_shaped(8, 400.0, 4.0)]);
        }
        assert_eq!(c.tokens_since_commit(), 16.0);
        let d = c.drifts();
        assert!(d.iter().cloned().fold(0.0, f64::max) > c.drift_threshold());
        assert!((d.iter().cloned().fold(0.0, f64::max) - c.max_drift()).abs() < 1e-12);
    }

    #[test]
    fn uniform_rescaling_is_invariant() {
        // Louder traffic with the same channel profile must NOT requant.
        let mut c = mk(8);
        c.observe(&[stats_with(8, 1.0, 4.0), stats_with(8, 1.0, 4.0)]);
        c.commit();
        for _ in 0..4 {
            c.observe(&[stats_with(8, 400.0, 4.0), stats_with(8, 400.0, 4.0)]);
        }
        assert!(!c.needs_requant(), "drift {}", c.max_drift());
    }

    #[test]
    fn decay_forgets_old_domain() {
        let mut c = mk(4);
        c.observe(&[stats_with(4, 1000.0, 4.0), stats_with(4, 1000.0, 4.0)]);
        for _ in 0..40 {
            c.observe(&[stats_with(4, 1.0, 4.0), stats_with(4, 1.0, 4.0)]);
        }
        // old 1000.0 contribution decayed to negligible: diag ~ fresh
        let d = c.diag(0);
        let expect = ((1.0f64 / (1.0 - 0.8)).sqrt() + 0.4).powf(0.5);
        for v in d {
            assert!((v as f64) < expect * 1.5, "diag {v} vs {expect}");
        }
    }
}
