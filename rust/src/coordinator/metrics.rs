//! Lock-free serving metrics (atomics only — no mutex on the hot path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_rows: AtomicU64,
    pub tokens: AtomicU64,
    pub requants: AtomicU64,
    /// Cumulative latency in microseconds (request arrival → reply).
    pub latency_us: AtomicU64,
    /// Cumulative executor time in microseconds.
    pub exec_us: AtomicU64,
    /// Cumulative quantization time in microseconds.
    pub quant_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, requests: usize, padded: usize, tokens: usize, exec: Duration) {
        self.requests.fetch_add(requests as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_rows.fetch_add(padded as u64, Ordering::Relaxed);
        self.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        self.exec_us
            .fetch_add(exec.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        self.latency_us
            .fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn record_requant(&self, d: Duration) {
        self.requants.fetch_add(1, Ordering::Relaxed);
        self.quant_us
            .fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn mean_latency_ms(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_us.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
    }

    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        let req = self.requests.load(Ordering::Relaxed) as f64;
        let pad = self.padded_rows.load(Ordering::Relaxed) as f64;
        req / (req + pad)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let us = self.exec_us.load(Ordering::Relaxed);
        if us == 0 {
            return 0.0;
        }
        self.tokens.load(Ordering::Relaxed) as f64 / (us as f64 / 1e6)
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} fill={:.2} tokens={} tput={:.0} tok/s \
             mean_latency={:.2}ms requants={} quant_time={:.1}ms",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_fill(),
            self.tokens.load(Ordering::Relaxed),
            self.tokens_per_sec(),
            self.mean_latency_ms(),
            self.requants.load(Ordering::Relaxed),
            self.quant_us.load(Ordering::Relaxed) as f64 / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_fill() {
        let m = Metrics::new();
        m.record_batch(3, 1, 256, Duration::from_millis(2));
        assert!((m.mean_batch_fill() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn throughput() {
        let m = Metrics::new();
        m.record_batch(4, 0, 1000, Duration::from_millis(10));
        let t = m.tokens_per_sec();
        assert!((t - 100_000.0).abs() < 1.0, "{t}");
    }

    #[test]
    fn latency_mean() {
        let m = Metrics::new();
        m.record_batch(2, 0, 10, Duration::from_millis(1));
        m.record_latency(Duration::from_millis(4));
        m.record_latency(Duration::from_millis(6));
        assert!((m.mean_latency_ms() - 5.0).abs() < 0.01);
    }

    #[test]
    fn summary_contains_fields() {
        let m = Metrics::new();
        m.record_batch(1, 0, 64, Duration::from_millis(1));
        let s = m.summary();
        assert!(s.contains("requests=1"));
        assert!(s.contains("tok/s"));
    }
}
