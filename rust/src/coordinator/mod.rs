//! L3 coordinator — the serving layer around the quantized runtime.
//!
//! A vLLM-router-shaped stack scaled to this reproduction:
//!
//! * [`batcher`] — shape-bucketed dynamic batching: requests queue per
//!   (model, seq-bucket); a batch fires when a bucket fills or its
//!   oldest request exceeds the linger deadline. Buckets correspond 1:1
//!   to the AOT-compiled batch sizes (no dynamic shapes under PJRT).
//! * [`calibrator`] — the TTQ-specific contribution: per-session online
//!   activation statistics with exponential decay ("on-device
//!   self-calibration", Fig. 1b) deciding when weights are re-quantized.
//! * [`server`] — the decode engine: batched prefill, a continuous-
//!   batching decode scheduler over the [`crate::kvcache::KvCache`],
//!   streaming [`server::ServeEvent`] replies, mid-generation
//!   drift-triggered requantization, and a per-request decode strategy
//!   (plain quantized decode vs. self-speculative decode through
//!   [`crate::specdec`], where the quantized weights draft and a
//!   full-precision verifier commits); owns quantized weight
//!   generations.
//! * [`metrics`] — lock-free counters, split by prefill/decode phase
//!   plus speculative round accounting and the worker pool's kernel
//!   time per phase.

#![forbid(unsafe_code)]

pub mod batcher;
pub mod calibrator;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, BatchPolicy, Batcher, Request, RequestId};
pub use calibrator::{CalibratorConfig, OnlineCalibrator};
pub use metrics::Metrics;
pub use server::{ServeEvent, Server, ServerConfig, StopReason, DEFAULT_TRACE_CAPACITY};

/// Serving-path failures that used to be `expect`s. The serving loop
/// must degrade by surfacing an error on the offending request, never
/// by unwinding mid-batch (repo-lint rule R3 bans `unwrap`/`expect`
/// here); each variant names the internal invariant that broke.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Admission accepted a sequence but the KV cache had no free slot.
    CacheExhausted,
    /// The speculative draft KV cache had no free slot at admission.
    DraftCacheExhausted,
    /// A speculative sequence was scheduled but the shared speculative
    /// state (drafter weights + draft cache) is missing.
    SpecStateMissing,
    /// A sequence flagged speculative carries no per-sequence
    /// speculative bookkeeping.
    SpecSeqMissing,
    /// The batching policy has an empty bucket list.
    NoBuckets,
    /// A quality probe fired but the pristine-fp32 replay state
    /// (weights + dense backend) is missing.
    ProbeStateMissing,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::CacheExhausted => {
                write!(f, "admission exceeded KV cache slots")
            }
            ServeError::DraftCacheExhausted => {
                write!(f, "admission exceeded draft KV cache slots")
            }
            ServeError::SpecStateMissing => {
                write!(f, "speculative state missing for a speculative sequence")
            }
            ServeError::SpecSeqMissing => {
                write!(f, "speculative bookkeeping missing on a speculative sequence")
            }
            ServeError::NoBuckets => {
                write!(f, "batch policy has no buckets configured")
            }
            ServeError::ProbeStateMissing => {
                write!(f, "probe state missing for a fired quality probe")
            }
        }
    }
}

impl std::error::Error for ServeError {}
