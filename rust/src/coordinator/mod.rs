//! L3 coordinator — the serving layer around the quantized runtime.
//!
//! A vLLM-router-shaped stack scaled to this reproduction:
//!
//! * [`batcher`] — shape-bucketed dynamic batching: requests queue per
//!   (model, seq-bucket); a batch fires when a bucket fills or its
//!   oldest request exceeds the linger deadline. Buckets correspond 1:1
//!   to the AOT-compiled batch sizes (no dynamic shapes under PJRT).
//! * [`calibrator`] — the TTQ-specific contribution: per-session online
//!   activation statistics with exponential decay ("on-device
//!   self-calibration", Fig. 1b) deciding when weights are re-quantized.
//! * [`server`] — the decode engine: batched prefill, a continuous-
//!   batching decode scheduler over the [`crate::kvcache::KvCache`],
//!   streaming [`server::ServeEvent`] replies, mid-generation
//!   drift-triggered requantization, and a per-request decode strategy
//!   (plain quantized decode vs. self-speculative decode through
//!   [`crate::specdec`], where the quantized weights draft and a
//!   full-precision verifier commits); owns quantized weight
//!   generations.
//! * [`metrics`] — lock-free counters, split by prefill/decode phase
//!   plus speculative round accounting and the worker pool's kernel
//!   time per phase.

pub mod batcher;
pub mod calibrator;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, BatchPolicy, Batcher, Request, RequestId};
pub use calibrator::{CalibratorConfig, OnlineCalibrator};
pub use metrics::Metrics;
pub use server::{ServeEvent, Server, ServerConfig, StopReason};
