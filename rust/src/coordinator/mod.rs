//! L3 coordinator — the serving layer around the quantized runtime.
//!
//! A vLLM-router-shaped stack scaled to this reproduction:
//!
//! * [`batcher`] — shape-bucketed dynamic batching: requests queue per
//!   (model, seq-bucket); a batch fires when a bucket fills or its
//!   oldest request exceeds the linger deadline. Buckets correspond 1:1
//!   to the AOT-compiled batch sizes (no dynamic shapes under PJRT).
//! * [`calibrator`] — the TTQ-specific contribution: per-session online
//!   activation statistics with exponential decay ("on-device
//!   self-calibration", Fig. 1b) deciding when weights are re-quantized.
//! * [`server`] — the engine loop tying batcher + calibrator + runtime
//!   together; owns quantized weight generations.
//! * [`metrics`] — lock-free counters for the runtime benches.

pub mod batcher;
pub mod calibrator;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, BatchPolicy, Batcher, Request, RequestId};
pub use calibrator::{CalibratorConfig, OnlineCalibrator};
pub use metrics::Metrics;
pub use server::{Server, ServerConfig, ServeReply};
