//! The serving engine: batcher + online calibrator + executor backend.
//!
//! Request lifecycle (one `step`):
//!
//!   submit → [Batcher bucket fires] → stats pass on the batch
//!          → calibrator.observe → (drift? requantize weight generation)
//!          → logits pass with the quantized weights
//!          → greedy next-token reply per request
//!
//! This is the paper's Fig. 1(b) loop made concrete: quantization state
//! is owned by the server, recomputed *from the live traffic* whenever
//! the activation statistics drift — never from offline calibration.
//!
//! The compression method is a [`MethodSpec`] registry handle. Methods
//! that consume the activation diagonal (TTQ, online AWQ, test-time
//! pruning) ride the calibrator's observe→drift→commit loop; weight-only
//! methods (RTN, NF) quantize once at the first batch; correlation
//! methods (GPTQ) are rejected up front — the serving path has no corr
//! artifact.

use std::time::Instant;

use anyhow::{bail, Result};

use super::batcher::{Batch, BatchPolicy, Batcher, Request, RequestId};
use super::calibrator::{CalibratorConfig, OnlineCalibrator};
use super::metrics::Metrics;
use crate::backend::ExecBackend;
use crate::eval::{EvalConfig, Evaluator};
use crate::quant::{MethodSpec, QuantSpec};
use crate::util::argmax;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model: String,
    pub spec: QuantSpec,
    /// Compression method for the serving loop (default: TTQ r=0).
    pub method: MethodSpec,
    pub policy: BatchPolicy,
    /// Calibrator knobs (decay, drift threshold). The diagonal
    /// hyperparameters are re-derived from `method` at [`Server::new`],
    /// so the calibrator's D always matches the method that consumes it.
    pub calib: CalibratorConfig,
}

impl ServerConfig {
    pub fn new(model: &str) -> Self {
        ServerConfig {
            model: model.into(),
            spec: QuantSpec::new(4, 32),
            method: MethodSpec::ttq(0),
            policy: BatchPolicy::default(),
            calib: CalibratorConfig::default(),
        }
    }

    pub fn with_method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self
    }
}

/// Reply for one request: greedy next token after the prompt.
#[derive(Clone, Debug)]
pub struct ServeReply {
    pub id: RequestId,
    pub next_token: i32,
    pub weight_generation: u64,
}

pub struct Server<'b> {
    cfg: ServerConfig,
    ev: Evaluator<'b>,
    batcher: Batcher,
    calibrator: OnlineCalibrator,
    pub metrics: Metrics,
    next_id: RequestId,
    /// Weight-only methods quantize once; set after the first batch.
    static_applied: bool,
}

impl<'b> Server<'b> {
    pub fn new(backend: &'b dyn ExecBackend, cfg: ServerConfig) -> Result<Self> {
        if cfg.method.needs_corr() {
            bail!(
                "method {} needs the full correlation — unsupported by the serving path",
                cfg.method.label()
            );
        }
        if cfg.method.is_offline() {
            bail!(
                "method {} is offline-calibrated; the serving loop self-calibrates online \
                 (drop the calib domain)",
                cfg.method.label()
            );
        }
        let ev = Evaluator::new(backend, &cfg.model)?;
        let man = &ev.weights.manifest;
        let d_ins: Vec<usize> = man.linears.iter().map(|l| l.d_in).collect();
        // Keep the calibrator's diagonal consistent with the method,
        // however cfg.method was set (constructor, builder, or field).
        let calib_cfg = cfg.calib.clone().for_method(&cfg.method);
        let calibrator = OnlineCalibrator::new(calib_cfg, &man.norm_ps, &d_ins);
        let batcher = Batcher::new(cfg.policy.clone());
        Ok(Server {
            cfg,
            ev,
            batcher,
            calibrator,
            metrics: Metrics::new(),
            next_id: 0,
            static_applied: false,
        })
    }

    pub fn seq(&self) -> usize {
        self.ev.weights.manifest.config.seq
    }

    pub fn weight_generation(&self) -> u64 {
        self.calibrator.generation()
    }

    /// Enqueue a prompt (must be exactly `seq` tokens, BOS-led).
    pub fn submit(&mut self, tokens: Vec<i32>) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.push(Request::new(id, tokens));
        id
    }

    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Drive the engine once; returns replies if a batch fired.
    pub fn step(&mut self, now: Instant) -> Result<Vec<ServeReply>> {
        let Some(batch) = self.batcher.poll(now) else {
            return Ok(Vec::new());
        };
        self.run_batch(batch)
    }

    /// Drain everything queued (test/bench convenience).
    pub fn drain(&mut self) -> Result<Vec<ServeReply>> {
        let mut out = Vec::new();
        while self.batcher.pending() > 0 {
            let far = Instant::now() + self.cfg.policy.linger * 2;
            out.extend(self.step(far)?);
        }
        Ok(out)
    }

    fn run_batch(&mut self, batch: Batch) -> Result<Vec<ServeReply>> {
        let seq = self.seq();
        let bucket = batch.bucket;
        let tokens = batch.tokens(seq);

        if self.cfg.method.needs_stats() {
            // 1. stats pass on the live batch (the O[dT] term of Eq. 3)
            let collected = self.ev.collect(&tokens, bucket, false)?;
            self.calibrator.observe(&collected.stats);

            // 2. requantize only when the activation statistics drifted
            if self.calibrator.needs_requant() {
                let t0 = Instant::now();
                let diags = self.calibrator.commit();
                self.ev
                    .apply_diags(&diags, &self.cfg.method, &self.cfg.spec)?;
                self.metrics.record_requant(t0.elapsed());
            }
        } else if !self.static_applied {
            // weight-only method: one quantization pass, ever
            let t0 = Instant::now();
            let cfg = EvalConfig { spec: self.cfg.spec.clone(), ..Default::default() };
            self.ev.apply_quantization(&self.cfg.method, None, &cfg)?;
            self.static_applied = true;
            self.metrics.record_requant(t0.elapsed());
        }

        // 3. forward with the current quantized generation
        let t0 = Instant::now();
        let logits = self
            .ev
            .backend
            .logits(&self.ev.weights, &tokens, bucket)?;
        let exec = t0.elapsed();
        let vocab = self.ev.weights.manifest.config.vocab;

        let n_real = batch.requests.len();
        self.metrics
            .record_batch(n_real, batch.padding_rows(), bucket * seq, exec);
        let mut replies = Vec::with_capacity(n_real);
        for (row, req) in batch.requests.iter().enumerate() {
            let off = (row * seq + (seq - 1)) * vocab;
            let best = argmax(&logits[off..off + vocab]);
            self.metrics.record_latency(req.arrived.elapsed());
            replies.push(ServeReply {
                id: req.id,
                next_token: best as i32,
                weight_generation: self.calibrator.generation(),
            });
        }
        Ok(replies)
    }
}
