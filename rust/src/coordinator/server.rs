//! The serving engine: continuous-batching decode scheduler + online
//! calibrator over the prefill/decode execution split.
//!
//! Request lifecycle:
//!
//!   submit → [Batcher bucket fires, KV slot free] → batched prefill
//!          (stats tapped on *real* rows only) → calibrator.observe
//!          → first token streamed (`ServeEvent::Token`)
//!          → joins the running decode batch
//!   each step: one `decode_step` over every running sequence
//!          → per-step stats → observe → (drift? requantize mid-stream)
//!          → one `ServeEvent::Token` per sequence
//!   stop (max_new_tokens / EOS / context full) → `ServeEvent::Done`,
//!          KV slot recycled
//!
//! This is the paper's Fig. 1(b) loop in its natural habitat: the
//! memory-bound decode phase is where low-bit weights buy wall-clock,
//! and because activation statistics keep accumulating *per generated
//! token*, drift-triggered requantization can fire mid-generation —
//! the weight-generation bump is visible in the subsequent `Token`
//! events. Offline-calibrated methods cannot do this; that is the
//! paper's whole argument.
//!
//! **Observability.** Every phase above is recorded: the server stamps
//! all times from one [`crate::obs::Clock`] (deterministic in tests),
//! writes admit/prefill/decode/spec/requant spans into a lock-free
//! [`crate::obs::TraceBuffer`] (export with
//! [`crate::obs::export::chrome_trace`]), accumulates
//! [`crate::obs::RequantEvent`] introspection records per drift
//! requant, and feeds latency histograms in [`Metrics`]. See
//! `docs/OBSERVABILITY.md`.
//!
//! The compression method is a [`MethodSpec`] registry handle. Methods
//! that consume the activation diagonal (TTQ, online AWQ, test-time
//! pruning) ride the calibrator's observe→drift→commit loop; weight-only
//! methods (RTN, NF) quantize once before the first prefill; correlation
//! methods (GPTQ) are rejected up front — the serving path has no corr
//! artifact.
//!
//! **Per-request decode strategy.** A request enters through
//! [`Server::submit`] (plain: one cached `decode_step` per engine step,
//! served by the quantized weights) or
//! [`Server::submit_speculative`] (self-speculative: the quantized
//! weights only *draft*; a full-precision verifier commits tokens, so
//! the stream is token-identical to the fp32 model). Speculative
//! sequences hold a second KV slot for the drafter, verify all drafts
//! in one [`crate::backend::ExecBackend::verify_step`], and roll both
//! caches back at the first rejection. Verifier-side activation stats
//! keep feeding the calibrator — but only from fully-committed verify
//! windows, so rejected draft rows can never pollute the statistics
//! (the same purity rule that keeps bucket padding out) — and a
//! mid-stream requantization transparently swaps the drafter weights
//! (the packed cache re-keys on
//! [`crate::models::ModelWeights::version`]) and resets the
//! acceptance EWMA that drives the adaptive draft depth. All the
//! speculative machinery (fp32 snapshot, drafter/verifier backends,
//! draft KV slab) materializes lazily on the first speculative submit —
//! plain-only servers pay nothing for it.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use super::batcher::{Batch, BatchPolicy, Batcher, Request, RequestId};
use super::calibrator::{CalibratorConfig, OnlineCalibrator};
use super::metrics::Metrics;
use super::ServeError;
use crate::backend::{ExecBackend, NativeBackend};
use crate::eval::{EvalConfig, Evaluator, Sampler};
use crate::kvcache::{CacheStats, KvCache, KvCacheConfig, SeqId};
use crate::linalg::pool::WorkerPool;
use crate::models::ModelWeights;
use crate::obs::profile::HostSpec;
use crate::obs::quality::{self, QualityProbe};
use crate::obs::{
    Clock, Phase, ProfileReport, Profiler, RequantEvent, SpanKind, TraceBuffer, TraceEvent,
    ENGINE_SEQ,
};
use crate::quant::{MethodSpec, QuantSpec};
use crate::specdec::{spec_round, DraftState, SpecConfig, SpecController, SpecModel};
use crate::util::argmax;

/// Default span-ring capacity (events) for a new server. At 64 bytes
/// per slot this is ~1 MiB; set [`ServerConfig::trace_capacity`] to 0
/// to disable recording entirely.
pub const DEFAULT_TRACE_CAPACITY: usize = 16_384;

/// Serving-engine configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Model to serve.
    pub model: String,
    /// Bits/groupsize the serving weights are quantized at.
    pub spec: QuantSpec,
    /// Compression method for the serving loop (default: TTQ r=0).
    pub method: MethodSpec,
    /// Admission batching policy (buckets, linger).
    pub policy: BatchPolicy,
    /// Calibrator knobs (decay, drift threshold). The diagonal
    /// hyperparameters are re-derived from `method` at [`Server::new`],
    /// so the calibrator's D always matches the method that consumes it.
    pub calib: CalibratorConfig,
    /// Generation budget per request. The effective budget is clamped
    /// to the context room: a full-`max_seq` prompt yields exactly one
    /// token (the pre-decode-engine behavior).
    pub max_new_tokens: usize,
    /// Optional stop token ending a generation early.
    pub eos: Option<i32>,
    /// Concurrently resident sequences in the KV cache (admission
    /// backpressure beyond this: requests stay queued).
    pub cache_slots: usize,
    /// Speculative-decoding policy for requests submitted through
    /// [`Server::submit_speculative`] (draft depth, adaptivity).
    pub specdec: SpecConfig,
    /// The clock every serving-path timestamp is read from: real
    /// monotonic time in production, [`Clock::test`] for deterministic
    /// span trees in tests (repo-lint R6 bans raw `Instant::now` on
    /// the serving path).
    pub clock: Clock,
    /// Span ring capacity in events ([`DEFAULT_TRACE_CAPACITY`]);
    /// 0 disables the recorder (the overhead-gate baseline).
    pub trace_capacity: usize,
    /// Online quality-probe cadence: every `probe_every` committed
    /// plain decode steps, replay one sampled sequence's exact prefix
    /// through the pristine fp32 weights and record KL / top-1
    /// agreement / NLL delta ([`crate::obs::quality`]). 0 (default)
    /// disables probing entirely — no fp32 fork, no cost.
    pub probe_every: usize,
    /// Attach a kernel-level [`Profiler`] to the serving pool: every
    /// pooled dispatch is attributed to a
    /// [`crate::obs::KernelSite`] (kind × phase × shape bucket) with
    /// analytic FLOP/byte counts, read back via
    /// [`Server::profile_report`]. Off by default (the overhead-gate
    /// baseline).
    pub profile: bool,
}

impl ServerConfig {
    /// Defaults: TTQ r=0 at W4 g=32, 16 new tokens, 16 KV slots.
    pub fn new(model: &str) -> Self {
        ServerConfig {
            model: model.into(),
            spec: QuantSpec::new(4, 32),
            method: MethodSpec::ttq(0),
            policy: BatchPolicy::default(),
            calib: CalibratorConfig::default(),
            max_new_tokens: 16,
            eos: None,
            cache_slots: 16,
            specdec: SpecConfig::default(),
            clock: Clock::real(),
            trace_capacity: DEFAULT_TRACE_CAPACITY,
            probe_every: 0,
            profile: false,
        }
    }

    /// Enable per-site kernel profiling (see [`ServerConfig::profile`]).
    pub fn with_profile(mut self, profile: bool) -> Self {
        self.profile = profile;
        self
    }

    /// Drive the engine from this clock (tests pass [`Clock::test`]
    /// for exactly reproducible span trees).
    pub fn with_clock(mut self, clock: Clock) -> Self {
        self.clock = clock;
        self
    }

    /// Set the span-ring capacity in events (0 disables tracing).
    pub fn with_trace_capacity(mut self, events: usize) -> Self {
        self.trace_capacity = events;
        self
    }

    /// Replace the serving compression method.
    pub fn with_method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self
    }

    /// Set the per-request generation budget (≥ 1).
    pub fn with_max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = n.max(1);
        self
    }

    /// Set the speculative-decoding policy.
    pub fn with_specdec(mut self, specdec: SpecConfig) -> Self {
        self.specdec = specdec;
        self
    }

    /// Probe quality vs fp32 every `n` committed plain decode steps
    /// (0 disables — the default).
    pub fn with_probe_every(mut self, n: usize) -> Self {
        self.probe_every = n;
        self
    }
}

/// Why a generation ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The configured `max_new_tokens` budget was exhausted.
    MaxNewTokens,
    /// The configured EOS token was emitted.
    Eos,
    /// The context window filled before the budget did (the effective
    /// budget was clamped to the room left after the prompt).
    ContextFull,
}

/// Streamed serving reply. One `Token` per generated token (in
/// generation order), closed by exactly one `Done` per request.
#[derive(Clone, Debug)]
pub enum ServeEvent {
    /// One generated token of one request.
    Token {
        /// The request this token belongs to.
        id: RequestId,
        /// The generated token id.
        token: i32,
        /// 0-based position in the generated suffix.
        index: usize,
        /// Quantized weight generation that *produced* this token. A
        /// mid-stream requantization shows up as a bump between
        /// consecutive tokens of the same request.
        weight_generation: u64,
    },
    /// A request finished; closes its token stream.
    Done {
        /// The request that finished.
        id: RequestId,
        /// The full generated suffix (prompt not included).
        tokens: Vec<i32>,
        /// Length of the prompt that was prefilled.
        prompt_len: usize,
        /// Why this generation stopped.
        stop: StopReason,
    },
}

impl ServeEvent {
    /// The request this event belongs to.
    pub fn id(&self) -> RequestId {
        match self {
            ServeEvent::Token { id, .. } | ServeEvent::Done { id, .. } => *id,
        }
    }
}

/// One in-flight generation: KV residency + progress + stop condition.
struct SequenceState {
    id: RequestId,
    kv: SeqId,
    prompt_len: usize,
    /// The prompt tokens, retained so the quality probe can replay the
    /// exact prefix (prompt ⧺ generated) through pristine fp32.
    prompt: Vec<i32>,
    /// Most recent token (input to the next decode step).
    last_token: i32,
    generated: Vec<i32>,
    /// Effective budget (config clamped to context room).
    max_new: usize,
    /// Arrival reading of the server clock, microseconds (start of the
    /// request's trace span; drives latency accounting).
    arrived_us: u64,
    /// Speculative sequences carry the drafter's dual-cache state; plain
    /// sequences decode one token per step on the serving weights.
    spec: Option<DraftState>,
}

impl SequenceState {
    fn finished(&self, eos: Option<i32>) -> bool {
        self.generated.len() >= self.max_new
            || eos.is_some_and(|e| self.generated.last() == Some(&e))
    }
}

/// Speculative-decoding machinery, materialized lazily on the first
/// [`Server::submit_speculative`] — a plain-only server never pays the
/// fp32 weight fork or the second KV slab.
struct SpecState {
    /// Full-precision snapshot (pristine linears, fresh version): what
    /// the verifier executes. Requantization never touches it.
    verifier_weights: ModelWeights,
    /// Dense fp32 execution for the verifier (`verify_step` + the
    /// speculative prefill), regardless of the serving backend's mode.
    verifier_backend: NativeBackend,
    /// Packed execution for the drafter at the serving bit-width: runs
    /// the *serving* weights (`ev.weights`), so every requantization —
    /// which bumps [`ModelWeights::version`] — transparently swaps the
    /// drafter through the version-keyed packed cache.
    drafter_backend: NativeBackend,
    /// The drafter's own KV slab (dual-cache, never forked from the
    /// verifier's: the two models disagree about every hidden state).
    draft_cache: KvCache,
}

/// Pristine-fp32 replay machinery for the online quality probe,
/// materialized lazily on the first probed step — unprobed servers
/// never pay the fp32 weight fork.
struct ProbeState {
    /// Full-precision snapshot the probe replays through.
    /// Requantization never touches it.
    weights: ModelWeights,
    /// Dense fp32 execution for the replay: the serving backend may be
    /// in packed exec mode, which would quantize even pristine weights.
    backend: NativeBackend,
}

/// The continuous-batching decode engine (see the module docs).
pub struct Server<'b> {
    cfg: ServerConfig,
    ev: Evaluator<'b>,
    batcher: Batcher,
    calibrator: OnlineCalibrator,
    cache: KvCache,
    running: Vec<SequenceState>,
    /// Cumulative serving counters (read freely; atomics inside).
    pub metrics: Metrics,
    /// The serving clock (every timestamp on this path reads it).
    clock: Clock,
    /// Span recorder; `Arc` because the worker pool shares it for
    /// kernel-dispatch spans.
    trace: Arc<TraceBuffer>,
    /// Drift-requant introspection records, in firing order.
    requant_events: Vec<RequantEvent>,
    next_id: RequestId,
    /// Weight-only methods quantize once; set before the first prefill.
    static_applied: bool,
    // -- speculative decoding ------------------------------------------
    /// Lazily-built drafter/verifier pair + draft KV slab.
    spec_state: Option<SpecState>,
    /// Adaptive draft depth from the acceptance EWMA; reset on requant.
    spec_ctrl: SpecController,
    /// Requests awaiting admission that asked for speculative decode.
    spec_requests: HashSet<RequestId>,
    /// Verifier-side token selection (greedy — the exactness mode).
    sampler: Sampler,
    // -- online quality probe ------------------------------------------
    /// Probe cadence counter ([`ServerConfig::probe_every`]).
    probe: QualityProbe,
    /// Lazily-built pristine-fp32 replay pair (`None` until the first
    /// probe fires).
    probe_state: Option<ProbeState>,
    // -- kernel profiling -----------------------------------------------
    /// Per-site kernel profiler shared with the serving pool
    /// (`None` unless [`ServerConfig::profile`]).
    profiler: Option<Arc<Profiler>>,
    /// Pool `kernel_us` reading at construction, so the profile
    /// report's coverage denominator counts only this server's time
    /// even on a shared pool.
    kernel_base_us: u64,
}

impl<'b> Server<'b> {
    /// Build the engine: load the model, derive the calibrator from the
    /// method, preallocate the KV slab. Rejects correlation-dependent
    /// and offline-calibrated methods (the serving loop is online).
    pub fn new(backend: &'b dyn ExecBackend, cfg: ServerConfig) -> Result<Self> {
        if cfg.method.needs_corr() {
            bail!(
                "method {} needs the full correlation — unsupported by the serving path",
                cfg.method.label()
            );
        }
        if cfg.method.is_offline() {
            bail!(
                "method {} is offline-calibrated; the serving loop self-calibrates online \
                 (drop the calib domain)",
                cfg.method.label()
            );
        }
        let ev = Evaluator::new(backend, &cfg.model)?;
        let man = &ev.weights.manifest;
        let d_ins: Vec<usize> = man.linears.iter().map(|l| l.d_in).collect();
        // Keep the calibrator's diagonal consistent with the method,
        // however cfg.method was set (constructor, builder, or field).
        let calib_cfg = cfg.calib.clone().for_method(&cfg.method);
        let calibrator = OnlineCalibrator::new(calib_cfg, &man.norm_ps, &d_ins);
        let batcher = Batcher::new(cfg.policy.clone());
        let cache = KvCache::new(KvCacheConfig::from_manifest(man, cfg.cache_slots));
        let spec_ctrl = SpecController::new(&cfg.specdec);
        let probe = QualityProbe::new(cfg.probe_every);
        let clock = cfg.clock.clone();
        let trace = Arc::new(TraceBuffer::new(cfg.trace_capacity));
        if trace.enabled() {
            // Kernel-dispatch spans ride the same ring; first attach
            // wins when backends share a pool (the hook is a OnceLock).
            if let Some(pool) = backend.worker_pool() {
                pool.attach_trace(trace.clone(), clock.clone());
            }
        }
        let profiler = if cfg.profile {
            backend.worker_pool().map(|pool| {
                pool.attach_profiler(Arc::new(Profiler::new()));
                // first attach wins on a shared pool — read back
                // whichever profiler is actually installed
                pool.profiler()
                    .cloned()
                    .unwrap_or_else(|| Arc::new(Profiler::new()))
            })
        } else {
            None
        };
        let kernel_base_us = backend.worker_pool().map_or(0, |p| p.kernel_us());
        Ok(Server {
            cfg,
            ev,
            batcher,
            calibrator,
            cache,
            running: Vec::new(),
            metrics: Metrics::new(),
            clock,
            trace,
            requant_events: Vec::new(),
            next_id: 0,
            static_applied: false,
            spec_state: None,
            spec_ctrl,
            spec_requests: HashSet::new(),
            sampler: Sampler::greedy(),
            probe,
            probe_state: None,
            profiler,
            kernel_base_us,
        })
    }

    /// Build the drafter/verifier pair on first speculative demand.
    /// [`Evaluator::pristine_weights`] restores the fp32 linears, so the
    /// snapshot is full-precision even if quantization already ran.
    fn ensure_spec_state(&mut self) {
        if self.spec_state.is_some() {
            return;
        }
        let man = &self.ev.weights.manifest;
        let dir = self.ev.backend.models_dir();
        // Drafter and verifier execute on the *serving* backend's worker
        // pool when it has one: prefill, decode, draft and verify then
        // share one set of threads instead of oversubscribing the host
        // with three pools.
        let pool = self
            .ev
            .backend
            .worker_pool()
            .unwrap_or_else(|| Arc::new(WorkerPool::with_default_threads()));
        self.spec_state = Some(SpecState {
            verifier_weights: self.ev.pristine_weights(),
            verifier_backend: NativeBackend::new(dir).with_pool(pool.clone()),
            drafter_backend: NativeBackend::new(dir)
                .with_pool(pool)
                .with_exec_quant(self.cfg.spec.clone()),
            draft_cache: KvCache::new(KvCacheConfig::from_manifest(man, self.cfg.cache_slots)),
        });
    }

    /// Build the probe's pristine-fp32 replay pair on first demand.
    /// Mirrors [`Self::ensure_spec_state`]: the serving backend may be
    /// in packed exec mode (which would quantize even pristine
    /// weights), so the probe gets its own dense-fp32 backend, sharing
    /// the serving worker pool rather than spawning a second one.
    fn ensure_probe_state(&mut self) {
        if self.probe_state.is_some() {
            return;
        }
        let dir = self.ev.backend.models_dir();
        let pool = self
            .ev
            .backend
            .worker_pool()
            .unwrap_or_else(|| Arc::new(WorkerPool::with_default_threads()));
        self.probe_state = Some(ProbeState {
            weights: self.ev.pristine_weights(),
            backend: NativeBackend::new(dir).with_pool(pool),
        });
    }

    /// Cumulative kernel time of the serving pool, µs (0 without one).
    /// Phase accounting diffs two snapshots around each executor call.
    fn kernel_us(&self) -> u64 {
        self.ev.backend.worker_pool().map_or(0, |p| p.kernel_us())
    }

    /// Tokens resident in the drafter's KV slab (0 when speculative
    /// decoding has never been used).
    fn draft_tokens_used(&self) -> usize {
        self.spec_state.as_ref().map_or(0, |s| s.draft_cache.used_tokens())
    }

    /// The model's full-batch-artifact sequence length.
    pub fn seq(&self) -> usize {
        self.ev.weights.manifest.config.seq
    }

    /// The model's context window (prompt + generated).
    pub fn max_seq(&self) -> usize {
        self.ev.weights.manifest.config.max_seq
    }

    /// Current quantized-weight generation (bumped per requant).
    pub fn weight_generation(&self) -> u64 {
        self.calibrator.generation()
    }

    /// The online calibrator (read access for diagnostics/tests).
    pub fn calibrator(&self) -> &OnlineCalibrator {
        &self.calibrator
    }

    /// KV-cache occupancy snapshot.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The adaptive-k speculative controller (read access for
    /// diagnostics/tests: current depth + acceptance EWMA).
    pub fn spec_controller(&self) -> &SpecController {
        &self.spec_ctrl
    }

    /// The span recorder (snapshot it for export; disabled when
    /// [`ServerConfig::trace_capacity`] is 0).
    pub fn trace(&self) -> &TraceBuffer {
        &self.trace
    }

    /// Drift-triggered requantization introspection events, in firing
    /// order (what drifted, how far past the threshold, what it cost).
    pub fn requant_events(&self) -> &[RequantEvent] {
        &self.requant_events
    }

    /// The kernel profiler attached to the serving pool (`None` unless
    /// [`ServerConfig::profile`]).
    pub fn profiler(&self) -> Option<&Arc<Profiler>> {
        self.profiler.as_ref()
    }

    /// Per-site roofline report over everything this server dispatched:
    /// achieved GFLOP/s / GB/s / intensity per [`crate::obs::KernelSite`]
    /// against `host`, plus predicted-vs-measured drift and the
    /// attribution coverage vs. this server's share of pool kernel time.
    /// `None` unless profiling is on.
    pub fn profile_report(&self, host: &HostSpec) -> Option<ProfileReport> {
        let kern = self.kernel_us().saturating_sub(self.kernel_base_us);
        self.profiler.as_ref().map(|p| p.report(host, kern))
    }

    /// Point the profiler's phase gauge (no-op without a profiler).
    fn set_phase(&self, phase: Phase) {
        if let Some(p) = &self.profiler {
            p.set_phase(phase);
        }
    }

    /// KV-cache occupancy sample: high-water metrics, slab-byte gauges
    /// (occupancy vs. reserved-but-empty waste across serving + draft
    /// caches), plus instant counter events on the engine track.
    fn sample_cache_occupancy(&self) {
        let used = self.cache.used_tokens() + self.draft_tokens_used();
        self.metrics.record_cache_used(used);
        // Slab-byte gauges: a slot reserves max_seq tokens for the whole
        // residency of its sequence, so waste = reserved − written. The
        // draft cache shares the manifest's geometry (same bytes/token).
        let kcfg = self.cache.config();
        let bpt = (kcfg.n_layers * 2 * kcfg.d_kv * 4) as u64;
        let mut reserved = self.cache.stats().active_seqs * kcfg.max_seq;
        if let Some(st) = &self.spec_state {
            reserved += st.draft_cache.stats().active_seqs * kcfg.max_seq;
        }
        let occupancy = used as u64 * bpt;
        let waste = reserved.saturating_sub(used) as u64 * bpt;
        self.metrics.record_kv_bytes(occupancy, waste);
        if self.trace.enabled() {
            let now_us = self.clock.now_us();
            let gen = self.calibrator.generation();
            self.trace.record(&TraceEvent {
                kind: SpanKind::CacheOccupancy,
                seq: ENGINE_SEQ,
                start_us: now_us,
                dur_us: 0,
                weight_version: gen,
                a: used as u64,
                b: self.cache.stats().capacity_tokens as u64,
            });
            self.trace.record(&TraceEvent {
                kind: SpanKind::KvBytes,
                seq: ENGINE_SEQ,
                start_us: now_us,
                dur_us: 0,
                weight_version: gen,
                a: occupancy,
                b: waste,
            });
        }
    }

    /// Enqueue a BOS-led prompt of `1..=max_seq` in-vocabulary tokens.
    pub fn submit(&mut self, tokens: Vec<i32>) -> RequestId {
        self.submit_inner(tokens)
    }

    /// Like [`Self::submit`], but decode this request speculatively:
    /// the quantized serving weights draft, a full-precision verifier
    /// commits — the token stream is exactly what the fp32 model would
    /// emit, and the quantized weights only buy decode speed. Requires
    /// a backend with a cached decode path (native).
    pub fn submit_speculative(&mut self, tokens: Vec<i32>) -> RequestId {
        self.ensure_spec_state();
        let id = self.submit_inner(tokens);
        self.spec_requests.insert(id);
        id
    }

    fn submit_inner(&mut self, tokens: Vec<i32>) -> RequestId {
        assert!(
            !tokens.is_empty() && tokens.len() <= self.max_seq(),
            "prompt must be 1..={} tokens, got {}",
            self.max_seq(),
            tokens.len()
        );
        // reject bad ids at the door: a prefill failure mid-batch is
        // far more disruptive than a submit panic at the call site
        let vocab = self.ev.weights.manifest.config.vocab as i32;
        assert!(
            tokens.iter().all(|&t| (0..vocab).contains(&t)),
            "prompt contains out-of-vocab token (vocab {vocab})"
        );
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.push(Request::new(id, tokens, self.clock.now_us()));
        id
    }

    /// Requests queued, not yet prefilled.
    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Sequences currently in the decode batch.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Drive the engine once: admit newly-fired batches into the decode
    /// batch (prefill), then advance every running sequence by one
    /// token. Returns the events this step produced. Time comes from
    /// the server's own [`Clock`] (configure via
    /// [`ServerConfig::with_clock`]).
    pub fn step(&mut self) -> Result<Vec<ServeEvent>> {
        let mut events = Vec::new();
        while self.cache.free_slots() > 0 {
            let now_us = self.clock.now_us();
            let Some(batch) = self.batcher.poll(now_us) else { break };
            self.admit(batch, &mut events)?;
        }
        self.decode_once(&mut events)?;
        Ok(events)
    }

    /// Run everything queued to completion (test/bench convenience).
    /// Queued arrivals are force-flushed past the linger gate — no
    /// fabricated far-future clock involved.
    pub fn drain(&mut self) -> Result<Vec<ServeEvent>> {
        let mut events = Vec::new();
        while self.batcher.pending() > 0 || !self.running.is_empty() {
            while self.cache.free_slots() > 0 {
                let Some(batch) = self.batcher.force_flush() else { break };
                self.admit(batch, &mut events)?;
            }
            self.decode_once(&mut events)?;
        }
        Ok(events)
    }

    /// Prefill a fired batch and join it into the decode batch.
    ///
    /// Only *real* requests are executed and observed — bucket padding
    /// never reaches the model, so the calibrator sees each request's
    /// activations exactly once (the padded-row double-counting of the
    /// pre-decode-engine loop is structurally impossible).
    fn admit(&mut self, batch: Batch, events: &mut Vec<ServeEvent>) -> Result<()> {
        let bucket_slack = batch.padding_rows();
        let mut requests = batch.requests;
        // admission backpressure: requeue what the cache can't hold
        let free = self.cache.free_slots();
        if requests.len() > free {
            for r in requests.drain(free..).rev() {
                self.batcher.requeue(r);
            }
        }
        if requests.is_empty() {
            return Ok(());
        }
        self.metrics.record_admitted(requests.len(), bucket_slack);
        if self.trace.enabled() {
            // queue-wait spans: arrival → admission, one per request
            let now_us = self.clock.now_us();
            let gen = self.calibrator.generation();
            for r in &requests {
                self.trace.record(&TraceEvent {
                    kind: SpanKind::Admit,
                    seq: r.id,
                    start_us: r.arrived_us,
                    dur_us: now_us.saturating_sub(r.arrived_us),
                    weight_version: gen,
                    a: r.tokens.len() as u64,
                    b: 0,
                });
            }
        }

        // weight-only methods: one quantization pass before any forward
        if !self.cfg.method.needs_stats() && !self.static_applied {
            let t0_us = self.clock.now_us();
            let cfg = EvalConfig { spec: self.cfg.spec.clone(), ..Default::default() };
            self.ev.apply_quantization(&self.cfg.method, None, &cfg)?;
            self.static_applied = true;
            let dur = self.clock.now_us().saturating_sub(t0_us);
            self.metrics.record_requant(Duration::from_micros(dur));
        }

        // one prefill forward per prompt-length group (insertion order)
        let mut groups: Vec<(usize, Vec<Request>)> = Vec::new();
        for r in requests {
            match groups.iter_mut().find(|(l, _)| *l == r.tokens.len()) {
                Some((_, g)) => g.push(r),
                None => groups.push((r.tokens.len(), vec![r])),
            }
        }
        for (prompt_len, group) in groups {
            self.prefill_group(prompt_len, group, events)?;
        }
        Ok(())
    }

    fn prefill_group(
        &mut self,
        prompt_len: usize,
        group: Vec<Request>,
        events: &mut Vec<ServeEvent>,
    ) -> Result<()> {
        // speculative requests prefill on the verifier (their stream is
        // fp32-exact); plain ones on the serving weights
        let (spec, plain): (Vec<Request>, Vec<Request>) = group
            .into_iter()
            .partition(|r| self.spec_requests.contains(&r.id));
        if !plain.is_empty() {
            self.prefill_subset(prompt_len, plain, events, false)?;
        }
        if !spec.is_empty() {
            self.prefill_subset(prompt_len, spec, events, true)?;
        }
        Ok(())
    }

    fn prefill_subset(
        &mut self,
        prompt_len: usize,
        group: Vec<Request>,
        events: &mut Vec<ServeEvent>,
        speculative: bool,
    ) -> Result<()> {
        // the group's strategy is decided; clear the markers up front so
        // a failed prefill cannot leak entries into `spec_requests`
        for r in &group {
            self.spec_requests.remove(&r.id);
        }
        let n = group.len();
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            // admission checked free_slots up front; surface a typed
            // error (not a panic) if that accounting ever goes wrong
            ids.push(self.cache.alloc().ok_or(ServeError::CacheExhausted)?);
        }
        let mut tokens = Vec::with_capacity(n * prompt_len);
        for r in &group {
            tokens.extend_from_slice(&r.tokens);
        }
        let with_stats = self.cfg.method.needs_stats();
        self.set_phase(Phase::Prefill);
        let t0_us = self.clock.now_us();
        let k0 = self.kernel_us();
        let res = if speculative {
            let st = self.spec_state.as_mut().ok_or(ServeError::SpecStateMissing)?;
            st.verifier_backend.prefill(
                &st.verifier_weights,
                &tokens,
                &mut self.cache,
                &ids,
                with_stats,
            )
        } else {
            self.ev
                .backend
                .prefill(&self.ev.weights, &tokens, &mut self.cache, &ids, with_stats)
        };
        let out = match res {
            Ok(out) => out,
            Err(e) => {
                // don't leak the slots of a failed group — the server
                // stays serviceable for subsequent requests
                for id in ids {
                    self.cache.release(id);
                }
                return Err(e);
            }
        };
        let prefill_dur = self.clock.now_us().saturating_sub(t0_us);
        self.metrics
            .record_prefill(tokens.len(), Duration::from_micros(prefill_dur));
        self.metrics
            .record_prefill_kernel(self.kernel_us().saturating_sub(k0));
        if self.trace.enabled() {
            // one prefill span per member request, on its own track
            let gen = self.calibrator.generation();
            for r in &group {
                self.trace.record(&TraceEvent {
                    kind: SpanKind::Prefill,
                    seq: r.id,
                    start_us: t0_us,
                    dur_us: prefill_dur,
                    weight_version: gen,
                    a: tokens.len() as u64,
                    b: n as u64,
                });
            }
        }

        // the drafter builds its own KV state for the prompt (dual
        // cache — drafter and verifier disagree about hidden states)
        let draft_ids = if speculative {
            let k0 = self.kernel_us();
            let st = self.spec_state.as_mut().ok_or(ServeError::SpecStateMissing)?;
            let mut dids = Vec::with_capacity(n);
            for _ in 0..n {
                // the draft slab is sized like the main one and only
                // speculative sequences draw from it
                dids.push(st.draft_cache.alloc().ok_or(ServeError::DraftCacheExhausted)?);
            }
            let t0_us = self.clock.now_us();
            let res = st.drafter_backend.prefill(
                &self.ev.weights,
                &tokens,
                &mut st.draft_cache,
                &dids,
                false,
            );
            if let Err(e) = res {
                for id in ids {
                    self.cache.release(id);
                }
                for id in dids {
                    st.draft_cache.release(id);
                }
                return Err(e);
            }
            let dur = self.clock.now_us().saturating_sub(t0_us);
            self.metrics
                .record_prefill(tokens.len(), Duration::from_micros(dur));
            self.metrics
                .record_prefill_kernel(self.kernel_us().saturating_sub(k0));
            Some(dids)
        } else {
            None
        };
        // sample occupancy *before* any release below — this is the peak
        self.sample_cache_occupancy();

        // the generation that produced these logits (pre-observe)
        let gen = self.calibrator.generation();
        self.observe_and_maybe_requant(out.stats.as_deref())?;

        let vocab = self.ev.weights.manifest.config.vocab;
        let room = self.max_seq() - prompt_len + 1;
        for (row, (req, kv)) in group.into_iter().zip(ids).enumerate() {
            let tok = argmax(&out.logits[row * vocab..(row + 1) * vocab]) as i32;
            let seq = SequenceState {
                id: req.id,
                kv,
                prompt_len,
                prompt: req.tokens,
                last_token: tok,
                generated: vec![tok],
                max_new: self.cfg.max_new_tokens.clamp(1, room),
                arrived_us: req.arrived_us,
                spec: draft_ids
                    .as_ref()
                    .map(|dids| DraftState::new(dids[row], tok)),
            };
            events.push(ServeEvent::Token {
                id: seq.id,
                token: tok,
                index: 0,
                weight_generation: gen,
            });
            if seq.finished(self.cfg.eos) {
                self.finish(seq, events);
            } else {
                self.running.push(seq);
            }
        }
        Ok(())
    }

    /// Advance every running sequence: one batched `decode_step` for the
    /// plain sequences, one draft→verify→rollback round per speculative
    /// sequence (which may commit up to k+1 tokens).
    fn decode_once(&mut self, events: &mut Vec<ServeEvent>) -> Result<()> {
        self.decode_plain_once(events)?;
        self.decode_spec_once(events)?;
        Ok(())
    }

    /// One decode step over the plain (non-speculative) running batch.
    fn decode_plain_once(&mut self, events: &mut Vec<ServeEvent>) -> Result<()> {
        let rows: Vec<usize> = (0..self.running.len())
            .filter(|&i| self.running[i].spec.is_none())
            .collect();
        if rows.is_empty() {
            return Ok(());
        }
        let last: Vec<i32> = rows.iter().map(|&i| self.running[i].last_token).collect();
        let ids: Vec<SeqId> = rows.iter().map(|&i| self.running[i].kv).collect();
        let with_stats = self.cfg.method.needs_stats();
        self.set_phase(Phase::Decode);
        let t0_us = self.clock.now_us();
        let k0 = self.kernel_us();
        let out = self
            .ev
            .backend
            .decode_step(&self.ev.weights, &last, &mut self.cache, &ids, with_stats)?;
        let dur_us = self.clock.now_us().saturating_sub(t0_us);
        let kern = self.kernel_us().saturating_sub(k0);
        self.metrics
            .record_decode(rows.len(), Duration::from_micros(dur_us));
        self.metrics.record_decode_kernel(kern);
        // peak occupancy: every plain sequence just grew by one token
        self.sample_cache_occupancy();

        let gen = self.calibrator.generation();
        if self.trace.enabled() {
            // the step is one batched forward: each participant gets a
            // span with the batch's timing on its own request track
            for &i in &rows {
                self.trace.record(&TraceEvent {
                    kind: SpanKind::DecodeStep,
                    seq: self.running[i].id,
                    start_us: t0_us,
                    dur_us,
                    weight_version: gen,
                    a: kern,
                    b: rows.len() as u64,
                });
            }
        }
        // per-step statistics: this is what makes requantization able
        // to fire *mid-generation* on drifting traffic
        self.observe_and_maybe_requant(out.stats.as_deref())?;

        let vocab = self.ev.weights.manifest.config.vocab;
        // cadence ticks once per committed plain step; a firing samples
        // ONE rotating participant (not the whole batch), so the replay
        // cost stays bounded by prefix_len / (probe_every · batch)
        // relative to decode — the overhead budget the quality bench
        // gates on
        let probe_step = self.probe.tick();
        let probe_row = if probe_step {
            self.probe.steps() as usize % rows.len()
        } else {
            rows.len()
        };
        for (row, &i) in rows.iter().enumerate() {
            let served = &out.logits[row * vocab..(row + 1) * vocab];
            let tok = argmax(served) as i32;
            if row == probe_row {
                self.probe_sequence(i, served, tok as usize)?;
            }
            let seq = &mut self.running[i];
            seq.generated.push(tok);
            seq.last_token = tok;
            events.push(ServeEvent::Token {
                id: seq.id,
                token: tok,
                index: seq.generated.len() - 1,
                weight_generation: gen,
            });
        }
        // retire finished plain sequences, preserving decode-batch order
        let eos = self.cfg.eos;
        let mut still = Vec::with_capacity(self.running.len());
        for seq in std::mem::take(&mut self.running) {
            if seq.spec.is_none() && seq.finished(eos) {
                self.finish(seq, events);
            } else {
                still.push(seq);
            }
        }
        self.running = still;
        Ok(())
    }

    /// Replay one plain sequence's exact pre-commit prefix
    /// (prompt ⧺ generated) through the pristine fp32 weights and score
    /// the served logits against the reference: full-softmax
    /// KL(fp32 ‖ served), top-1 agreement, and the NLL delta on the
    /// token about to be committed ([`crate::obs::quality`]). Records
    /// histograms in [`Metrics`] and a probe span on the request's
    /// track. The replay runs *after* the step's kernel-time diff was
    /// taken and its wall time lands in `probe_us`, never `exec_us`, so
    /// decode attribution and throughput accounting stay honest.
    fn probe_sequence(&mut self, idx: usize, served: &[f32], committed: usize) -> Result<()> {
        self.ensure_probe_state();
        let seq = &self.running[idx];
        let mut prefix = Vec::with_capacity(seq.prompt.len() + seq.generated.len());
        prefix.extend_from_slice(&seq.prompt);
        prefix.extend_from_slice(&seq.generated);
        let st = self.probe_state.as_ref().ok_or(ServeError::ProbeStateMissing)?;
        let t0_us = self.clock.now_us();
        let logits = st.backend.logits(&st.weights, &prefix, 1)?;
        let dur_us = self.clock.now_us().saturating_sub(t0_us);
        let vocab = self.ev.weights.manifest.config.vocab;
        let last = &logits[(prefix.len() - 1) * vocab..prefix.len() * vocab];
        let sample = quality::compare(last, served, committed);
        self.metrics
            .record_probe(&sample, Duration::from_micros(dur_us));
        if self.trace.enabled() {
            self.trace.record(&TraceEvent {
                kind: SpanKind::Probe,
                seq: seq.id,
                start_us: t0_us,
                dur_us,
                weight_version: self.calibrator.generation(),
                a: quality::nanonats(sample.kl),
                b: sample.top1_agree as u64,
            });
        }
        Ok(())
    }

    /// One speculative round per speculative sequence: the quantized
    /// drafter proposes up to `k` tokens (adaptive), the fp32 verifier
    /// scores all of them in a single cached forward, both caches roll
    /// back to the first rejection, and every committed token streams
    /// out as its own `Token` event.
    ///
    /// Indexed iteration is deliberate: on an execution error the whole
    /// sequence table must be restored into `self.running`, which a
    /// holding iterator borrow would forbid.
    #[allow(clippy::needless_range_loop)]
    fn decode_spec_once(&mut self, events: &mut Vec<ServeEvent>) -> Result<()> {
        if !self.running.iter().any(|s| s.spec.is_some()) {
            return Ok(());
        }
        let with_stats = self.cfg.method.needs_stats();
        let clock = self.clock.clone();
        let mut seqs = std::mem::take(&mut self.running);
        for i in 0..seqs.len() {
            if seqs[i].spec.is_none() {
                continue;
            }
            // never commit past the generation budget: a round lands at
            // most k+1 tokens
            let budget = seqs[i].max_new - seqs[i].generated.len();
            let k = self.spec_ctrl.k().min(budget.saturating_sub(1));
            let t0_us = clock.now_us();
            let kern0 = self.kernel_us();
            let round = {
                let seq = &mut seqs[i];
                let ds = seq.spec.as_mut().ok_or(ServeError::SpecSeqMissing)?;
                let st = self.spec_state.as_mut().ok_or(ServeError::SpecStateMissing)?;
                let drafter = SpecModel {
                    backend: &st.drafter_backend,
                    weights: &self.ev.weights,
                };
                let verifier = SpecModel {
                    backend: &st.verifier_backend,
                    weights: &st.verifier_weights,
                };
                spec_round(
                    &drafter,
                    &mut st.draft_cache,
                    ds,
                    &verifier,
                    &mut self.cache,
                    seq.kv,
                    k,
                    &mut self.sampler,
                    with_stats,
                    &clock,
                )
            };
            let r = match round {
                Ok(r) => r,
                Err(e) => {
                    // keep the engine's sequence table intact on failure
                    self.running = seqs;
                    return Err(e);
                }
            };
            // committed tokens after an EOS are discarded, never
            // streamed — account only for what the client will see
            let streamed = match self.cfg.eos {
                Some(e) => r
                    .committed
                    .iter()
                    .position(|&t| t == e)
                    .map_or(r.committed.len(), |p| p + 1),
                None => r.committed.len(),
            };
            let dur_us = clock.now_us().saturating_sub(t0_us);
            self.metrics.record_spec_round(
                streamed,
                r.drafted,
                r.accepted,
                Duration::from_micros(dur_us),
            );
            // split the round's pool kernel time into its two halves:
            // draft as measured inside spec_round, verify as the
            // residual — so the four phase counters sum exactly to
            // total pool kernel time
            let round_kern = self.kernel_us().saturating_sub(kern0);
            let draft_kern = r.draft_kernel_us.min(round_kern);
            self.metrics.record_spec_draft_kernel(draft_kern);
            self.metrics
                .record_spec_verify_kernel(round_kern.saturating_sub(draft_kern));
            self.sample_cache_occupancy();
            self.spec_ctrl.observe(r.accepted, r.drafted);
            // mirror the controller's tuning state into the exporters
            self.metrics.record_spec_tuning(
                self.spec_ctrl.acceptance(),
                self.spec_ctrl.k(),
            );

            let gen = self.calibrator.generation();
            if self.trace.enabled() {
                // round span + draft/verify children, clamped so the
                // children always nest inside the round
                let id = seqs[i].id;
                let draft = r.draft_us.min(dur_us);
                let verify = r.verify_us.min(dur_us.saturating_sub(draft));
                self.trace.record(&TraceEvent {
                    kind: SpanKind::SpecRound,
                    seq: id,
                    start_us: t0_us,
                    dur_us,
                    weight_version: gen,
                    a: r.drafted as u64,
                    b: r.accepted as u64,
                });
                self.trace.record(&TraceEvent {
                    kind: SpanKind::Draft,
                    seq: id,
                    start_us: t0_us,
                    dur_us: draft,
                    weight_version: gen,
                    a: r.drafted as u64,
                    b: 0,
                });
                self.trace.record(&TraceEvent {
                    kind: SpanKind::Verify,
                    seq: id,
                    start_us: t0_us + draft,
                    dur_us: verify,
                    weight_version: gen,
                    a: r.drafted as u64 + 1,
                    b: r.accepted as u64,
                });
            }
            // verifier-side stats (present only for fully-committed
            // windows — see RoundOut) keep feeding the calibrator, so
            // drift can requantize (and swap) the drafter mid-generation
            if let Err(e) = self.observe_and_maybe_requant(r.stats.as_deref()) {
                self.running = seqs;
                return Err(e);
            }

            let seq = &mut seqs[i];
            for &tok in &r.committed[..streamed] {
                seq.generated.push(tok);
                seq.last_token = tok;
                events.push(ServeEvent::Token {
                    id: seq.id,
                    token: tok,
                    index: seq.generated.len() - 1,
                    weight_generation: gen,
                });
            }
        }
        // retire finished speculative sequences, preserving order
        let eos = self.cfg.eos;
        let mut still = Vec::with_capacity(seqs.len());
        for seq in seqs {
            if seq.spec.is_some() && seq.finished(eos) {
                self.finish(seq, events);
            } else {
                still.push(seq);
            }
        }
        self.running = still;
        Ok(())
    }

    fn observe_and_maybe_requant(
        &mut self,
        stats: Option<&[crate::quant::ActStats]>,
    ) -> Result<()> {
        let Some(stats) = stats else { return Ok(()) };
        self.calibrator.observe(stats);
        if self.calibrator.needs_requant() {
            // snapshot the evidence *before* commit resets it — this is
            // the introspection record that explains the decision
            let layer_drifts = self.calibrator.drifts();
            let max_drift = layer_drifts.iter().cloned().fold(0.0, f64::max);
            let threshold = self.calibrator.drift_threshold();
            let tokens_since_last = self.calibrator.tokens_since_commit() as u64;
            let from_version = self.calibrator.generation();
            let t0_us = self.clock.now_us();
            let diags = self.calibrator.commit();
            self.ev
                .apply_diags(&diags, &self.cfg.method, &self.cfg.spec)?;
            let quant_us = self.clock.now_us().saturating_sub(t0_us);
            self.metrics.record_requant(Duration::from_micros(quant_us));
            let to_version = self.calibrator.generation();
            if self.trace.enabled() {
                self.trace.record(&TraceEvent {
                    kind: SpanKind::Requant,
                    seq: ENGINE_SEQ,
                    start_us: t0_us,
                    dur_us: quant_us,
                    weight_version: to_version,
                    a: from_version,
                    // ∞ (never-quantized) saturates to u64::MAX
                    b: (max_drift * 1e6) as u64,
                });
            }
            // score what the requant just produced: activation-weighted
            // reconstruction error per layer, on the same introspection
            // record as the drift that triggered it
            let layer_recon_err = self.ev.reconstruction_errors(&diags);
            self.requant_events.push(RequantEvent {
                at_us: t0_us,
                from_version,
                to_version,
                max_drift,
                threshold,
                tokens_since_last,
                quant_us,
                layer_drifts,
                layer_recon_err,
            });
            // the drafter weights just changed generation (version bump
            // repacks them transparently); the old acceptance history
            // says nothing about the new drafter
            self.spec_ctrl.reset();
        }
        Ok(())
    }

    fn finish(&mut self, seq: SequenceState, events: &mut Vec<ServeEvent>) {
        self.cache.release(seq.kv);
        if let Some(ds) = &seq.spec {
            // `finish` cannot surface a Result; if the spec state is
            // somehow gone the draft slot is gone with it, so skipping
            // the release is the correct degradation (no panic — R3).
            if let Some(st) = self.spec_state.as_mut() {
                st.draft_cache.release(ds.kv);
            }
        }
        let latency_us = self.clock.now_us().saturating_sub(seq.arrived_us);
        self.metrics
            .record_latency(Duration::from_micros(latency_us));
        if self.trace.enabled() {
            // the request's root span: every decode/spec/prefill span
            // of this id falls inside [arrived_us, arrived_us + latency]
            self.trace.record(&TraceEvent {
                kind: SpanKind::Request,
                seq: seq.id,
                start_us: seq.arrived_us,
                dur_us: latency_us,
                weight_version: self.calibrator.generation(),
                a: seq.generated.len() as u64,
                b: seq.prompt_len as u64,
            });
        }
        let stop = if self.cfg.eos.is_some_and(|e| seq.generated.last() == Some(&e)) {
            StopReason::Eos
        } else if seq.max_new < self.cfg.max_new_tokens {
            // the effective budget was the context room, not the config
            StopReason::ContextFull
        } else {
            StopReason::MaxNewTokens
        };
        events.push(ServeEvent::Done {
            id: seq.id,
            tokens: seq.generated,
            prompt_len: seq.prompt_len,
            stop,
        });
    }
}
