//! The serving engine: continuous-batching decode scheduler + online
//! calibrator over the prefill/decode execution split.
//!
//! Request lifecycle:
//!
//!   submit → [Batcher bucket fires, KV slot free] → batched prefill
//!          (stats tapped on *real* rows only) → calibrator.observe
//!          → first token streamed (`ServeEvent::Token`)
//!          → joins the running decode batch
//!   each step: one `decode_step` over every running sequence
//!          → per-step stats → observe → (drift? requantize mid-stream)
//!          → one `ServeEvent::Token` per sequence
//!   stop (max_new_tokens / EOS / context full) → `ServeEvent::Done`,
//!          KV slot recycled
//!
//! This is the paper's Fig. 1(b) loop in its natural habitat: the
//! memory-bound decode phase is where low-bit weights buy wall-clock,
//! and because activation statistics keep accumulating *per generated
//! token*, drift-triggered requantization can fire mid-generation —
//! the weight-generation bump is visible in the subsequent `Token`
//! events. Offline-calibrated methods cannot do this; that is the
//! paper's whole argument.
//!
//! The compression method is a [`MethodSpec`] registry handle. Methods
//! that consume the activation diagonal (TTQ, online AWQ, test-time
//! pruning) ride the calibrator's observe→drift→commit loop; weight-only
//! methods (RTN, NF) quantize once before the first prefill; correlation
//! methods (GPTQ) are rejected up front — the serving path has no corr
//! artifact.

use std::time::Instant;

use anyhow::{bail, Result};

use super::batcher::{Batch, BatchPolicy, Batcher, Request, RequestId};
use super::calibrator::{CalibratorConfig, OnlineCalibrator};
use super::metrics::Metrics;
use crate::backend::ExecBackend;
use crate::eval::{EvalConfig, Evaluator};
use crate::kvcache::{CacheStats, KvCache, KvCacheConfig, SeqId};
use crate::quant::{MethodSpec, QuantSpec};
use crate::util::argmax;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model: String,
    pub spec: QuantSpec,
    /// Compression method for the serving loop (default: TTQ r=0).
    pub method: MethodSpec,
    pub policy: BatchPolicy,
    /// Calibrator knobs (decay, drift threshold). The diagonal
    /// hyperparameters are re-derived from `method` at [`Server::new`],
    /// so the calibrator's D always matches the method that consumes it.
    pub calib: CalibratorConfig,
    /// Generation budget per request. The effective budget is clamped
    /// to the context room: a full-`max_seq` prompt yields exactly one
    /// token (the pre-decode-engine behavior).
    pub max_new_tokens: usize,
    /// Optional stop token ending a generation early.
    pub eos: Option<i32>,
    /// Concurrently resident sequences in the KV cache (admission
    /// backpressure beyond this: requests stay queued).
    pub cache_slots: usize,
}

impl ServerConfig {
    pub fn new(model: &str) -> Self {
        ServerConfig {
            model: model.into(),
            spec: QuantSpec::new(4, 32),
            method: MethodSpec::ttq(0),
            policy: BatchPolicy::default(),
            calib: CalibratorConfig::default(),
            max_new_tokens: 16,
            eos: None,
            cache_slots: 16,
        }
    }

    pub fn with_method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self
    }

    pub fn with_max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = n.max(1);
        self
    }
}

/// Streamed serving reply. One `Token` per generated token (in
/// generation order), closed by exactly one `Done` per request.
#[derive(Clone, Debug)]
pub enum ServeEvent {
    Token {
        id: RequestId,
        token: i32,
        /// 0-based position in the generated suffix.
        index: usize,
        /// Quantized weight generation that *produced* this token. A
        /// mid-stream requantization shows up as a bump between
        /// consecutive tokens of the same request.
        weight_generation: u64,
    },
    Done {
        id: RequestId,
        /// The full generated suffix (prompt not included).
        tokens: Vec<i32>,
        prompt_len: usize,
    },
}

impl ServeEvent {
    pub fn id(&self) -> RequestId {
        match self {
            ServeEvent::Token { id, .. } | ServeEvent::Done { id, .. } => *id,
        }
    }
}

/// One in-flight generation: KV residency + progress + stop condition.
struct SequenceState {
    id: RequestId,
    kv: SeqId,
    prompt_len: usize,
    /// Most recent token (input to the next decode step).
    last_token: i32,
    generated: Vec<i32>,
    /// Effective budget (config clamped to context room).
    max_new: usize,
    arrived: Instant,
}

impl SequenceState {
    fn finished(&self, eos: Option<i32>) -> bool {
        self.generated.len() >= self.max_new
            || eos.is_some_and(|e| self.generated.last() == Some(&e))
    }
}

pub struct Server<'b> {
    cfg: ServerConfig,
    ev: Evaluator<'b>,
    batcher: Batcher,
    calibrator: OnlineCalibrator,
    cache: KvCache,
    running: Vec<SequenceState>,
    pub metrics: Metrics,
    next_id: RequestId,
    /// Weight-only methods quantize once; set before the first prefill.
    static_applied: bool,
}

impl<'b> Server<'b> {
    pub fn new(backend: &'b dyn ExecBackend, cfg: ServerConfig) -> Result<Self> {
        if cfg.method.needs_corr() {
            bail!(
                "method {} needs the full correlation — unsupported by the serving path",
                cfg.method.label()
            );
        }
        if cfg.method.is_offline() {
            bail!(
                "method {} is offline-calibrated; the serving loop self-calibrates online \
                 (drop the calib domain)",
                cfg.method.label()
            );
        }
        let ev = Evaluator::new(backend, &cfg.model)?;
        let man = &ev.weights.manifest;
        let d_ins: Vec<usize> = man.linears.iter().map(|l| l.d_in).collect();
        // Keep the calibrator's diagonal consistent with the method,
        // however cfg.method was set (constructor, builder, or field).
        let calib_cfg = cfg.calib.clone().for_method(&cfg.method);
        let calibrator = OnlineCalibrator::new(calib_cfg, &man.norm_ps, &d_ins);
        let batcher = Batcher::new(cfg.policy.clone());
        let cache = KvCache::new(KvCacheConfig::from_manifest(man, cfg.cache_slots));
        Ok(Server {
            cfg,
            ev,
            batcher,
            calibrator,
            cache,
            running: Vec::new(),
            metrics: Metrics::new(),
            next_id: 0,
            static_applied: false,
        })
    }

    pub fn seq(&self) -> usize {
        self.ev.weights.manifest.config.seq
    }

    pub fn max_seq(&self) -> usize {
        self.ev.weights.manifest.config.max_seq
    }

    pub fn weight_generation(&self) -> u64 {
        self.calibrator.generation()
    }

    /// The online calibrator (read access for diagnostics/tests).
    pub fn calibrator(&self) -> &OnlineCalibrator {
        &self.calibrator
    }

    /// KV-cache occupancy snapshot.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Enqueue a BOS-led prompt of `1..=max_seq` in-vocabulary tokens.
    pub fn submit(&mut self, tokens: Vec<i32>) -> RequestId {
        assert!(
            !tokens.is_empty() && tokens.len() <= self.max_seq(),
            "prompt must be 1..={} tokens, got {}",
            self.max_seq(),
            tokens.len()
        );
        // reject bad ids at the door: a prefill failure mid-batch is
        // far more disruptive than a submit panic at the call site
        let vocab = self.ev.weights.manifest.config.vocab as i32;
        assert!(
            tokens.iter().all(|&t| (0..vocab).contains(&t)),
            "prompt contains out-of-vocab token (vocab {vocab})"
        );
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.push(Request::new(id, tokens));
        id
    }

    /// Requests queued, not yet prefilled.
    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Sequences currently in the decode batch.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Drive the engine once: admit newly-fired batches into the decode
    /// batch (prefill), then advance every running sequence by one
    /// token. Returns the events this step produced.
    pub fn step(&mut self, now: Instant) -> Result<Vec<ServeEvent>> {
        let mut events = Vec::new();
        while self.cache.free_slots() > 0 {
            let Some(batch) = self.batcher.poll(now) else { break };
            self.admit(batch, &mut events)?;
        }
        self.decode_once(&mut events)?;
        Ok(events)
    }

    /// Run everything queued to completion (test/bench convenience).
    /// Queued arrivals are force-flushed past the linger gate — no
    /// fabricated far-future clock involved.
    pub fn drain(&mut self) -> Result<Vec<ServeEvent>> {
        let mut events = Vec::new();
        while self.batcher.pending() > 0 || !self.running.is_empty() {
            while self.cache.free_slots() > 0 {
                let Some(batch) = self.batcher.force_flush() else { break };
                self.admit(batch, &mut events)?;
            }
            self.decode_once(&mut events)?;
        }
        Ok(events)
    }

    /// Prefill a fired batch and join it into the decode batch.
    ///
    /// Only *real* requests are executed and observed — bucket padding
    /// never reaches the model, so the calibrator sees each request's
    /// activations exactly once (the padded-row double-counting of the
    /// pre-decode-engine loop is structurally impossible).
    fn admit(&mut self, batch: Batch, events: &mut Vec<ServeEvent>) -> Result<()> {
        let bucket_slack = batch.padding_rows();
        let mut requests = batch.requests;
        // admission backpressure: requeue what the cache can't hold
        let free = self.cache.free_slots();
        if requests.len() > free {
            for r in requests.drain(free..).rev() {
                self.batcher.requeue(r);
            }
        }
        if requests.is_empty() {
            return Ok(());
        }
        self.metrics.record_admitted(requests.len(), bucket_slack);

        // weight-only methods: one quantization pass before any forward
        if !self.cfg.method.needs_stats() && !self.static_applied {
            let t0 = Instant::now();
            let cfg = EvalConfig { spec: self.cfg.spec.clone(), ..Default::default() };
            self.ev.apply_quantization(&self.cfg.method, None, &cfg)?;
            self.static_applied = true;
            self.metrics.record_requant(t0.elapsed());
        }

        // one prefill forward per prompt-length group (insertion order)
        let mut groups: Vec<(usize, Vec<Request>)> = Vec::new();
        for r in requests {
            match groups.iter_mut().find(|(l, _)| *l == r.tokens.len()) {
                Some((_, g)) => g.push(r),
                None => groups.push((r.tokens.len(), vec![r])),
            }
        }
        for (prompt_len, group) in groups {
            self.prefill_group(prompt_len, group, events)?;
        }
        Ok(())
    }

    fn prefill_group(
        &mut self,
        prompt_len: usize,
        group: Vec<Request>,
        events: &mut Vec<ServeEvent>,
    ) -> Result<()> {
        let n = group.len();
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            // admission checked free_slots up front
            ids.push(self.cache.alloc().expect("admission exceeded cache slots"));
        }
        let mut tokens = Vec::with_capacity(n * prompt_len);
        for r in &group {
            tokens.extend_from_slice(&r.tokens);
        }
        let with_stats = self.cfg.method.needs_stats();
        let t0 = Instant::now();
        let res = self
            .ev
            .backend
            .prefill(&self.ev.weights, &tokens, &mut self.cache, &ids, with_stats);
        let out = match res {
            Ok(out) => out,
            Err(e) => {
                // don't leak the slots of a failed group — the server
                // stays serviceable for subsequent requests
                for id in ids {
                    self.cache.release(id);
                }
                return Err(e);
            }
        };
        self.metrics.record_prefill(tokens.len(), t0.elapsed());
        // sample occupancy *before* any release below — this is the peak
        self.metrics.record_cache_used(self.cache.used_tokens());

        // the generation that produced these logits (pre-observe)
        let gen = self.calibrator.generation();
        self.observe_and_maybe_requant(out.stats.as_deref())?;

        let vocab = self.ev.weights.manifest.config.vocab;
        let room = self.max_seq() - prompt_len + 1;
        for (row, (req, kv)) in group.into_iter().zip(ids).enumerate() {
            let tok = argmax(&out.logits[row * vocab..(row + 1) * vocab]) as i32;
            let seq = SequenceState {
                id: req.id,
                kv,
                prompt_len,
                last_token: tok,
                generated: vec![tok],
                max_new: self.cfg.max_new_tokens.clamp(1, room),
                arrived: req.arrived,
            };
            events.push(ServeEvent::Token {
                id: seq.id,
                token: tok,
                index: 0,
                weight_generation: gen,
            });
            if seq.finished(self.cfg.eos) {
                self.finish(seq, events);
            } else {
                self.running.push(seq);
            }
        }
        Ok(())
    }

    /// One decode step over the whole running batch.
    fn decode_once(&mut self, events: &mut Vec<ServeEvent>) -> Result<()> {
        if self.running.is_empty() {
            return Ok(());
        }
        let last: Vec<i32> = self.running.iter().map(|s| s.last_token).collect();
        let ids: Vec<SeqId> = self.running.iter().map(|s| s.kv).collect();
        let with_stats = self.cfg.method.needs_stats();
        let t0 = Instant::now();
        let out = self
            .ev
            .backend
            .decode_step(&self.ev.weights, &last, &mut self.cache, &ids, with_stats)?;
        self.metrics.record_decode(self.running.len(), t0.elapsed());
        // peak occupancy: every running sequence just grew by one token
        self.metrics.record_cache_used(self.cache.used_tokens());

        let gen = self.calibrator.generation();
        // per-step statistics: this is what makes requantization able
        // to fire *mid-generation* on drifting traffic
        self.observe_and_maybe_requant(out.stats.as_deref())?;

        let vocab = self.ev.weights.manifest.config.vocab;
        for (row, seq) in self.running.iter_mut().enumerate() {
            let tok = argmax(&out.logits[row * vocab..(row + 1) * vocab]) as i32;
            seq.generated.push(tok);
            seq.last_token = tok;
            events.push(ServeEvent::Token {
                id: seq.id,
                token: tok,
                index: seq.generated.len() - 1,
                weight_generation: gen,
            });
        }
        // retire finished sequences, preserving decode-batch order
        let eos = self.cfg.eos;
        let mut still = Vec::with_capacity(self.running.len());
        for seq in std::mem::take(&mut self.running) {
            if seq.finished(eos) {
                self.finish(seq, events);
            } else {
                still.push(seq);
            }
        }
        self.running = still;
        Ok(())
    }

    fn observe_and_maybe_requant(
        &mut self,
        stats: Option<&[crate::quant::ActStats]>,
    ) -> Result<()> {
        let Some(stats) = stats else { return Ok(()) };
        self.calibrator.observe(stats);
        if self.calibrator.needs_requant() {
            let t0 = Instant::now();
            let diags = self.calibrator.commit();
            self.ev
                .apply_diags(&diags, &self.cfg.method, &self.cfg.spec)?;
            self.metrics.record_requant(t0.elapsed());
        }
        Ok(())
    }

    fn finish(&mut self, seq: SequenceState, events: &mut Vec<ServeEvent>) {
        self.cache.release(seq.kv);
        self.metrics.record_latency(seq.arrived.elapsed());
        events.push(ServeEvent::Done {
            id: seq.id,
            tokens: seq.generated,
            prompt_len: seq.prompt_len,
        });
    }
}
