//! Synthetic corpus engine — rust half.
//!
//! **Bit-identical** mirror of `python/compile/corpus.py` (the python
//! side trains the models; this side generates calibration and eval
//! streams at runtime). The shared golden fixture
//! `artifacts/corpus_golden.json` is checked from both languages
//! (`python/tests/test_corpus.py`, `rust/tests/corpus_golden.rs`).
//!
//! Domains stand in for the paper's datasets (DESIGN.md §3):
//! wt2s→WikiText-2, ptbs→PTB, c4s→C4, vqas→TextVQA-proxy,
//! acts→LIBERO-proxy action streams.

#![forbid(unsafe_code)]

use crate::linalg::rng::splitmix64;

/// Shared vocabulary size across every synthetic domain.
pub const VOCAB: usize = 512;
/// Beginning-of-sequence token (row 0 of every batch).
pub const BOS: i32 = 0;

const C_DOMAIN: u64 = 0x9E37_79B9_7F4A_7C15;
const C_PREV1: u64 = 0xC2B2_AE3D_27D4_EB4F;
const C_PREV2: u64 = 0x1656_67B1_9E37_79F9;
const C_SPLIT: u64 = 0x27D4_EB2F_1656_67C5;
const BASE_SEED: u64 = 0x7751_2026;

/// Stream split — same language, independent draws.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// Training draws (the python model-training pipeline).
    Train,
    /// Evaluation draws (perplexity/accuracy tables).
    Eval,
    /// Calibration draws (offline Fig. 1a methods).
    Calib,
}

impl Split {
    fn id(self) -> u64 {
        match self {
            Split::Train => 0,
            Split::Eval => 1,
            Split::Calib => 2,
        }
    }
    /// Lowercase split name (artifact filenames).
    pub fn name(self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::Eval => "eval",
            Split::Calib => "calib",
        }
    }
}

/// Domain statistics spec (mirror of `corpus.DomainSpec`).
#[derive(Clone, Copy, Debug)]
pub struct DomainSpec {
    /// Domain name (`wt2s`, `ptbs`, `c4s`, `vqas`, `acts`).
    pub name: &'static str,
    /// Seed id separating the domains' languages.
    pub id: u64,
    /// Tokens of the shared vocabulary this domain uses.
    pub vocab_used: usize,
    /// Candidate continuations per context.
    pub k: usize,
    /// Unigram-noise mixture weight.
    pub eps: f64,
    /// Geometric decay over ranked continuations.
    pub q: f64,
    /// Context order (1 or 2 previous tokens).
    pub order: u32,
    /// Zipf exponent of the unigram distribution.
    pub zipf: f64,
}

/// The five synthetic domains (proxies for WT2/PTB/C4/TextVQA/LIBERO).
pub const DOMAINS: [DomainSpec; 5] = [
    DomainSpec { name: "wt2s", id: 1, vocab_used: 440, k: 4, eps: 0.05, q: 0.55, order: 2, zipf: 1.1 },
    DomainSpec { name: "ptbs", id: 2, vocab_used: 160, k: 3, eps: 0.02, q: 0.45, order: 2, zipf: 1.3 },
    DomainSpec { name: "c4s", id: 3, vocab_used: 500, k: 8, eps: 0.15, q: 0.80, order: 1, zipf: 0.9 },
    DomainSpec { name: "vqas", id: 4, vocab_used: 96, k: 2, eps: 0.03, q: 0.40, order: 2, zipf: 1.05 },
    DomainSpec { name: "acts", id: 5, vocab_used: 64, k: 2, eps: 0.01, q: 0.35, order: 2, zipf: 1.0 },
];

/// Look up a domain by name (panics on unknown names).
pub fn domain(name: &str) -> &'static DomainSpec {
    DOMAINS
        .iter()
        .find(|d| d.name == name)
        .unwrap_or_else(|| panic!("unknown domain {name}"))
}

/// The three LM perplexity benchmarks of the paper's tables.
pub const LM_DOMAINS: [&str; 3] = ["wt2s", "ptbs", "c4s"];

fn zipf_cdf(spec: &DomainSpec) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=spec.vocab_used)
        .map(|i| (i as f64).powf(-spec.zipf))
        .collect();
    let mut acc = 0.0;
    for v in w.iter_mut() {
        acc += *v;
        *v = acc;
    }
    let total = acc;
    for v in w.iter_mut() {
        *v /= total;
    }
    w
}

/// `searchsorted(cdf, u, side="right")` — first rank with cdf > u.
fn zipf_quantile(cdf: &[f64], u: f64) -> usize {
    cdf.partition_point(|&c| c <= u).min(cdf.len() - 1)
}

/// Deterministic token stream for (domain, split, stream_id).
pub struct CorpusStream {
    spec: &'static DomainSpec,
    cdf: Vec<f64>,
    lang_seed: u64,
    ctr_seed: u64,
    ctr: u64,
    prev1: u64,
    prev2: u64,
}

impl CorpusStream {
    /// Stream 0 of (domain, split).
    pub fn new(domain_name: &str, split: Split) -> Self {
        Self::with_stream(domain_name, split, 0)
    }

    /// An independent stream of the same (domain, split) language.
    pub fn with_stream(domain_name: &str, split: Split, stream_id: u64) -> Self {
        let spec = domain(domain_name);
        let lang_seed = splitmix64(BASE_SEED ^ spec.id.wrapping_mul(C_DOMAIN));
        let ctr_seed =
            splitmix64(lang_seed ^ split.id().wrapping_mul(C_SPLIT) ^ stream_id);
        CorpusStream {
            spec,
            cdf: zipf_cdf(spec),
            lang_seed,
            ctr_seed,
            ctr: 0,
            prev1: BOS as u64,
            prev2: BOS as u64,
        }
    }

    /// The domain this stream draws from.
    pub fn spec(&self) -> &'static DomainSpec {
        self.spec
    }

    #[inline]
    fn rand_u01(&mut self) -> f64 {
        self.ctr += 1;
        let v = splitmix64(self.ctr_seed.wrapping_add(self.ctr));
        (v >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    fn context_hash(&self) -> u64 {
        let mut h = self.lang_seed;
        h ^= self.prev1.wrapping_mul(C_PREV1);
        if self.spec.order >= 2 {
            h ^= self.prev2.wrapping_mul(C_PREV2);
        }
        splitmix64(h)
    }

    /// Draw the next token (never BOS; 1..VOCAB).
    pub fn next_token(&mut self) -> i32 {
        let spec = self.spec;
        let u = self.rand_u01();
        let tok = if u < spec.eps {
            let u2 = self.rand_u01();
            1 + zipf_quantile(&self.cdf, u2) as i32
        } else {
            let h = self.context_hash();
            let u2 = self.rand_u01();
            let mut j = 0usize;
            let mut acc = 1.0 - spec.q;
            let mut p = acc;
            while j < spec.k - 1 && u2 >= p {
                acc *= spec.q;
                p += acc;
                j += 1;
            }
            let frac = ((h >> (13 * (j % 4))) & 0xFFFF) as f64 / 65536.0;
            1 + zipf_quantile(&self.cdf, frac) as i32
        };
        self.prev2 = self.prev1;
        self.prev1 = tok as u64;
        tok
    }

    /// Draw `n` tokens.
    pub fn tokens(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_token()).collect()
    }

    /// One (batch, seq) block, each row starting with BOS — the token
    /// layout every model artifact expects.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = vec![BOS; batch * seq];
        for b in 0..batch {
            for s in 1..seq {
                out[b * seq + s] = self.next_token();
            }
        }
        out
    }

    /// The most likely next token for the *current* context — ground
    /// truth for the accuracy / success-rate proxies (VQA/VLA tables).
    /// It is the argmax of the generative distribution: candidate j=0
    /// of the context hash (prob (1−q)·(1−ε) dominates all others).
    pub fn most_likely_next(&self) -> i32 {
        let h = self.context_hash();
        let frac = (h & 0xFFFF) as f64 / 65536.0;
        1 + zipf_quantile(&self.cdf, frac) as i32
    }

    /// Advance the stream as if `tok` had been emitted (teacher forcing
    /// for episode evaluation).
    pub fn force(&mut self, tok: i32) {
        self.prev2 = self.prev1;
        self.prev1 = tok as u64;
    }
}

/// VLA-proxy suites (Table 13): name, stream id, episode horizon.
/// LIBERO-10 is the long-horizon suite — more compounding steps.
pub const VLA_SUITES: [(&str, u64, usize); 4] = [
    ("Libero Spatial", 10, 4),
    ("Libero Object", 11, 5),
    ("Libero Goal", 12, 6),
    ("Libero 10", 13, 12),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = CorpusStream::new("wt2s", Split::Train).tokens(128);
        let b = CorpusStream::new("wt2s", Split::Train).tokens(128);
        assert_eq!(a, b);
    }

    #[test]
    fn splits_differ() {
        let a = CorpusStream::new("wt2s", Split::Train).tokens(64);
        let b = CorpusStream::new("wt2s", Split::Eval).tokens(64);
        assert_ne!(a, b);
    }

    #[test]
    fn stream_ids_differ() {
        let a = CorpusStream::with_stream("acts", Split::Eval, 10).tokens(64);
        let b = CorpusStream::with_stream("acts", Split::Eval, 11).tokens(64);
        assert_ne!(a, b);
    }

    #[test]
    fn tokens_in_range() {
        for d in &DOMAINS {
            let t = CorpusStream::new(d.name, Split::Eval).tokens(512);
            assert!(t.iter().all(|&v| v >= 1 && v as usize <= d.vocab_used));
        }
    }

    #[test]
    fn vocab_ordering_matches_domain_design() {
        let count_vocab = |name: &str| {
            let t = CorpusStream::new(name, Split::Train).tokens(4096);
            let mut seen = std::collections::HashSet::new();
            seen.extend(t);
            seen.len()
        };
        let (w, p, c) = (count_vocab("wt2s"), count_vocab("ptbs"), count_vocab("c4s"));
        assert!(p < w && w <= c, "ptbs {p} < wt2s {w} <= c4s {c}");
    }

    #[test]
    fn batch_layout() {
        let mut s = CorpusStream::new("ptbs", Split::Eval);
        let b = s.batch(3, 16);
        assert_eq!(b.len(), 48);
        for r in 0..3 {
            assert_eq!(b[r * 16], BOS);
            assert!(b[r * 16 + 1..(r + 1) * 16].iter().all(|&v| v >= 1));
        }
    }

    #[test]
    fn most_likely_next_is_frequent() {
        // Over many contexts, the analytic argmax must agree with the
        // empirically most frequent successor far above chance.
        let mut s = CorpusStream::new("acts", Split::Train);
        let mut hits = 0;
        let n = 2000;
        for _ in 0..n {
            let pred = s.most_likely_next();
            let actual = s.next_token();
            if pred == actual {
                hits += 1;
            }
        }
        let acc = hits as f64 / n as f64;
        assert!(acc > 0.5, "analytic argmax accuracy {acc}");
    }

    #[test]
    fn zipf_quantile_bounds() {
        let cdf = zipf_cdf(domain("wt2s"));
        assert_eq!(zipf_quantile(&cdf, 0.0), 0);
        assert_eq!(zipf_quantile(&cdf, 0.9999999), cdf.len() - 1);
    }

    #[test]
    fn force_changes_context() {
        let a = CorpusStream::new("wt2s", Split::Eval);
        let mut b = CorpusStream::new("wt2s", Split::Eval);
        b.force(7);
        assert_ne!(a.most_likely_next(), {
            // contexts diverge (with overwhelming probability for this seed)
            let _ = &a;
            b.most_likely_next()
        });
    }
}
