//! Experiment pipelines: perplexity, accuracy, and success-rate
//! evaluation of quantized models — the engines behind every table.
//!
//! All methods share one two-pass pipeline (DESIGN.md §7):
//!
//!   pass 1  backend `stats` pass → per-linear activation statistics
//!   rust    quantize each linear with the chosen method
//!   pass 2  backend `nll`/`logits` pass with the substituted weights
//!
//! Execution is backend-agnostic: the [`Evaluator`] drives any
//! [`crate::backend::ExecBackend`] — the PJRT artifact path or the
//! pure-Rust native forward — and owns all quantization state itself.
//!
//! Method dispatch goes through the [`crate::quant::Quantizer`] trait:
//! the evaluator
//! asks [`MethodSpec::requirement`] what pass 1 must collect and whether
//! it runs *offline* on a calibration split (Fig. 1a — AWQ/GPTQ, the
//! path exposed to domain shift) or *online* on the evaluation batch
//! itself (Fig. 1b — that is the definition of test-time quantization),
//! then hands each linear's [`LayerStats`] to the method.

#![forbid(unsafe_code)]

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::backend::ExecBackend;
use crate::corpus::{CorpusStream, Split};
use crate::kvcache::{KvCache, KvCacheConfig};
use crate::linalg::Mat;
use crate::models::ModelWeights;
use crate::quant::{lowrank_init, LayerStats, LowRank, QuantSpec, StatsRequirement};
use crate::util::argmax;

pub mod sampler;

pub use sampler::Sampler;

// The unified method selector lives in the quant layer; re-exported
// here because eval call sites are where methods are most often named.
pub use crate::quant::{ActStats, MethodSpec};

/// Shared experiment knobs. Method-specific hyperparameters (the TTQ
/// diagonal (p, λ, α), GPTQ damping) live on the method itself — see
/// [`crate::quant::MethodRegistry`].
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Rows per forward batch.
    pub batch: usize,
    /// Evaluation batches per metric.
    pub eval_batches: usize,
    /// Calibration batches for offline methods.
    pub calib_batches: usize,
    /// Bits/groupsize/format under test.
    pub spec: QuantSpec,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            batch: 4,
            eval_batches: 12,
            calib_batches: 16,
            spec: QuantSpec::new(3, 32),
        }
    }
}

/// Per-linear activation statistics from one or more stats passes.
pub struct CollectedStats {
    /// Per-linear accumulated norm sums, manifest order.
    pub stats: Vec<ActStats>,
    /// Per-linear input correlations; empty unless collected.
    pub corr: Vec<Mat>,
}

/// Evaluation driver bound to one model on one execution backend.
pub struct Evaluator<'b> {
    /// The execution backend forwards run on.
    pub backend: &'b dyn ExecBackend,
    /// The live (possibly quantized) weights.
    pub weights: ModelWeights,
    /// Pristine copies of the quantizable linears ("the original
    /// full-precision weights *are* recoverable" — paper's point (3)).
    originals: HashMap<String, Mat>,
    /// Cached low-rank factors per (linear, rank) — static per App. E.
    lowrank_cache: HashMap<(String, usize), LowRank>,
}

impl<'b> Evaluator<'b> {
    /// Load `model` through the backend and bind to it.
    pub fn new(backend: &'b dyn ExecBackend, model: &str) -> Result<Self> {
        let weights = backend.load_model(model)?;
        Ok(Self::with_weights(backend, weights))
    }

    /// Bind to already-loaded (e.g. synthetic) weights.
    pub fn with_weights(backend: &'b dyn ExecBackend, weights: ModelWeights) -> Self {
        let originals = weights.linear_weights();
        Evaluator { backend, weights, originals, lowrank_cache: HashMap::new() }
    }

    /// The bound model's name.
    pub fn model_name(&self) -> &str {
        &self.weights.manifest.name
    }

    fn seq(&self) -> usize {
        self.weights.manifest.config.seq
    }

    /// Backend `nll` pass; returns (nll_sum, token_count).
    pub fn nll(&self, tokens: &[i32], batch: usize) -> Result<(f64, f64)> {
        self.backend.nll(&self.weights, tokens, batch)
    }

    /// Fused single-pass TTQ (Fig. 1b, L1 kernel semantics).
    pub fn nll_fused_ttq(&self, tokens: &[i32], batch: usize, bits: u32) -> Result<(f64, f64)> {
        self.backend
            .nll_fused_ttq(&self.weights, tokens, batch, bits)
    }

    /// One stats pass, parsed into per-linear statistics.
    pub fn collect(&self, tokens: &[i32], batch: usize, with_corr: bool) -> Result<CollectedStats> {
        let got = self
            .backend
            .stats(&self.weights, tokens, batch, with_corr)?;
        Ok(CollectedStats { stats: got.stats, corr: got.corr })
    }

    /// Accumulate stats over many batches of a stream.
    pub fn collect_stream(
        &self,
        stream: &mut CorpusStream,
        batch: usize,
        n_batches: usize,
        with_corr: bool,
    ) -> Result<CollectedStats> {
        let mut agg: Option<CollectedStats> = None;
        for _ in 0..n_batches {
            let toks = stream.batch(batch, self.seq());
            let got = self.collect(&toks, batch, with_corr)?;
            match &mut agg {
                None => agg = Some(got),
                Some(a) => {
                    for (dst, src) in a.stats.iter_mut().zip(&got.stats) {
                        dst.accumulate(&src.norm_sums, src.count);
                    }
                    for (dst, src) in a.corr.iter_mut().zip(&got.corr) {
                        *dst = dst.add(src);
                    }
                }
            }
        }
        Ok(agg.expect("n_batches >= 1"))
    }

    /// Greedy autoregressive generation through the backend's cached
    /// prefill/decode path (the current — possibly quantized — weight
    /// substitution applies). Returns the generated suffix; stops at
    /// `max_new_tokens`, `eos`, or a full context window. Errors on
    /// backends without a decode path (PJRT).
    ///
    /// ```
    /// use ttq_serve::backend::NativeBackend;
    /// use ttq_serve::eval::Evaluator;
    ///
    /// // No artifacts needed: the native backend falls back to a
    /// // deterministic synthetic model.
    /// let backend = NativeBackend::new(std::path::Path::new("artifacts"));
    /// let ev = Evaluator::new(&backend, "qwen-micro").unwrap();
    /// let toks = ev.generate(&[0, 7, 9], 4, None).unwrap();
    /// assert_eq!(toks.len(), 4); // budget-bounded greedy suffix
    /// ```
    pub fn generate(
        &self,
        prompt: &[i32],
        max_new_tokens: usize,
        eos: Option<i32>,
    ) -> Result<Vec<i32>> {
        self.generate_with(prompt, max_new_tokens, eos, &mut Sampler::greedy())
    }

    /// [`Self::generate`] with an explicit [`Sampler`] (greedy /
    /// temperature / top-k). Exactly one sampler draw per generated
    /// token, in order — the contract the speculative decoder relies on
    /// to stay token-identical to this loop under any seeded sampler.
    pub fn generate_with(
        &self,
        prompt: &[i32],
        max_new_tokens: usize,
        eos: Option<i32>,
        sampler: &mut Sampler,
    ) -> Result<Vec<i32>> {
        let man = &self.weights.manifest;
        if prompt.is_empty() || prompt.len() > man.config.max_seq {
            return Err(anyhow!(
                "prompt must be 1..={} tokens, got {}",
                man.config.max_seq,
                prompt.len()
            ));
        }
        let mut cache = KvCache::new(KvCacheConfig::from_manifest(man, 1));
        let id = cache.alloc().expect("fresh single-slot cache");
        let step = self
            .backend
            .prefill(&self.weights, prompt, &mut cache, &[id], false)?;
        let mut tok = sampler.sample(&step.logits) as i32;
        let mut out = vec![tok];
        while out.len() < max_new_tokens && Some(tok) != eos && cache.remaining(id) > 0 {
            let step = self
                .backend
                .decode_step(&self.weights, &[tok], &mut cache, &[id], false)?;
            tok = sampler.sample(&step.logits) as i32;
            out.push(tok);
        }
        Ok(out)
    }

    /// Low-rank factors for a linear (cached — static per App. E).
    pub fn lowrank_for(&mut self, name: &str, rank: usize) -> LowRank {
        if let Some(lr) = self.lowrank_cache.get(&(name.to_string(), rank)) {
            return lr.clone();
        }
        let lr = lowrank_init(&self.originals[name], rank);
        self.lowrank_cache
            .insert((name.to_string(), rank), lr.clone());
        lr
    }

    /// Substitute quantized weights for every linear: look up what the
    /// method requires, slice the collected statistics per layer, and
    /// dispatch through [`crate::quant::Quantizer::quantize`].
    pub fn apply_quantization(
        &mut self,
        method: &MethodSpec,
        collected: Option<&CollectedStats>,
        cfg: &EvalConfig,
    ) -> Result<()> {
        let linears = self.weights.manifest.linears.clone();
        let rank = method.quantizer().lowrank_rank();
        for (i, lin) in linears.iter().enumerate() {
            let lowrank = if rank > 0 {
                Some(self.lowrank_for(&lin.name, rank))
            } else {
                None
            };
            let mut stats = LayerStats::default();
            match method.requirement() {
                StatsRequirement::None => {}
                StatsRequirement::DiagonalNorms | StatsRequirement::StreamingActivations => {
                    let c = collected.ok_or_else(|| {
                        anyhow!("{} needs activation stats", method.label())
                    })?;
                    stats.act = Some(&c.stats[i]);
                }
                StatsRequirement::FullCorrelation => {
                    let c = collected.ok_or_else(|| {
                        anyhow!("{} needs the corr artifact", method.label())
                    })?;
                    stats.corr = Some(c.corr.get(i).ok_or_else(|| {
                        anyhow!("{} needs the corr artifact", method.label())
                    })?);
                }
            }
            stats.lowrank = lowrank.as_ref();
            let wq = method
                .quantizer()
                .quantize(&self.originals[&lin.name], &stats, &cfg.spec)?;
            self.weights.set(&lin.name, wq);
        }
        Ok(())
    }

    /// Quantize every linear with externally supplied diagonals (the
    /// serving path: the [`crate::coordinator::OnlineCalibrator`] owns
    /// the statistics and hands committed diagonals down through
    /// [`LayerStats::diag`]).
    pub fn apply_diags(
        &mut self,
        diags: &[Vec<f32>],
        method: &MethodSpec,
        spec: &QuantSpec,
    ) -> Result<()> {
        let linears = self.weights.manifest.linears.clone();
        if diags.len() != linears.len() {
            return Err(anyhow!("{} diags for {} linears", diags.len(), linears.len()));
        }
        let rank = method.quantizer().lowrank_rank();
        for (lin, d) in linears.iter().zip(diags) {
            let lowrank = if rank > 0 {
                Some(self.lowrank_for(&lin.name, rank))
            } else {
                None
            };
            let mut stats = LayerStats::from_diag(d);
            stats.lowrank = lowrank.as_ref();
            let wq = method
                .quantizer()
                .quantize(&self.originals[&lin.name], &stats, spec)?;
            self.weights.set(&lin.name, wq);
        }
        Ok(())
    }

    /// Restore pristine full-precision weights.
    pub fn restore(&mut self) {
        for (name, w) in self.originals.clone() {
            self.weights.set(&name, w);
        }
    }

    /// A deep-copied snapshot with every quantizable linear restored to
    /// its pristine full-precision tensor (fresh content version) —
    /// correct even after quantization has mutated the live weights.
    /// The speculative decoder's verifier is built from this.
    pub fn pristine_weights(&self) -> ModelWeights {
        let mut w = self.weights.fork();
        for (name, orig) in &self.originals {
            w.set(name, orig.clone());
        }
        w
    }

    /// Activation-weighted relative reconstruction error of every
    /// quantizable linear against its pristine tensor, manifest order:
    /// `Σᵢⱼ dⱼ²·(Wᵢⱼ−Ŵᵢⱼ)² / Σᵢⱼ dⱼ²·Wᵢⱼ²` — the squared error the
    /// activation-aware objective actually minimizes, normalized so
    /// layers of different scale compare. `diags[i]` is layer `i`'s
    /// activation diagonal over input columns (the calibrator's
    /// committed diagonals on the serving path); a missing or empty
    /// diagonal falls back to uniform weighting. All-zero layers
    /// report 0. The server attaches this per requant
    /// ([`crate::obs::RequantEvent::layer_recon_err`]).
    pub fn reconstruction_errors(&self, diags: &[Vec<f32>]) -> Vec<f64> {
        let linears = &self.weights.manifest.linears;
        let mut out = Vec::with_capacity(linears.len());
        for (i, lin) in linears.iter().enumerate() {
            let orig = &self.originals[&lin.name];
            let Some(cur) = self.weights.get(&lin.name) else {
                out.push(0.0);
                continue;
            };
            let d = diags.get(i).map(|v| v.as_slice()).unwrap_or(&[]);
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for r in 0..orig.rows {
                let ro = orig.row(r);
                let rc = cur.row(r);
                for c in 0..orig.cols {
                    let wj = if c < d.len() {
                        d[c] as f64 * d[c] as f64
                    } else {
                        1.0
                    };
                    let w = ro[c] as f64;
                    let dw = w - rc[c] as f64;
                    num += wj * dw * dw;
                    den += wj * w * w;
                }
            }
            out.push(if den > 0.0 { num / den } else { 0.0 });
        }
        out
    }

    /// Offline calibration (Fig. 1a) for methods with a calib domain:
    /// collect what the method requires from the domain's calib split
    /// and quantize once. No-stats methods quantize directly; online
    /// methods are left for the per-batch path.
    pub(crate) fn quantize_static(&mut self, method: &MethodSpec, cfg: &EvalConfig) -> Result<()> {
        self.restore();
        if method.is_offline() {
            let domain = method.calib_domain().expect("offline implies calib");
            let mut s = CorpusStream::new(domain, Split::Calib);
            let st =
                self.collect_stream(&mut s, cfg.batch, cfg.calib_batches, method.needs_corr())?;
            self.apply_quantization(method, Some(&st), cfg)?;
        } else if !method.is_online() {
            self.apply_quantization(method, None, cfg)?;
        }
        Ok(())
    }

    /// Online requantization (Fig. 1b): statistics from the incoming
    /// batch itself, then quantize — the test-time path.
    fn requantize_online(
        &mut self,
        method: &MethodSpec,
        tokens: &[i32],
        cfg: &EvalConfig,
    ) -> Result<()> {
        self.restore();
        let st = self.collect(tokens, cfg.batch, method.needs_corr())?;
        self.apply_quantization(method, Some(&st), cfg)
    }

    // ------------------------------------------------------------------
    // Experiment drivers
    // ------------------------------------------------------------------

    /// Perplexity of `method` on `eval_domain` (paper's core metric).
    pub fn perplexity(
        &mut self,
        method: &MethodSpec,
        eval_domain: &str,
        cfg: &EvalConfig,
    ) -> Result<f64> {
        self.quantize_static(method, cfg)?;
        let mut stream = CorpusStream::new(eval_domain, Split::Eval);
        let mut total_nll = 0.0;
        let mut total_cnt = 0.0;
        for _ in 0..cfg.eval_batches {
            let toks = stream.batch(cfg.batch, self.seq());
            if method.is_online() {
                self.requantize_online(method, &toks, cfg)?;
            }
            let (s, c) = self.nll(&toks, cfg.batch)?;
            total_nll += s;
            total_cnt += c;
        }
        self.restore();
        Ok((total_nll / total_cnt).exp())
    }

    /// Next-token top-1 accuracy on a domain (VQA-proxy, Table 12).
    pub fn accuracy(
        &mut self,
        method: &MethodSpec,
        domain: &str,
        cfg: &EvalConfig,
    ) -> Result<f64> {
        let vocab = self.weights.manifest.config.vocab;
        let seq = self.seq();
        self.quantize_static(method, cfg)?;
        let mut stream = CorpusStream::new(domain, Split::Eval);
        let (mut hits, mut total) = (0usize, 0usize);
        for _ in 0..cfg.eval_batches {
            let toks = stream.batch(cfg.batch, seq);
            if method.is_online() {
                self.requantize_online(method, &toks, cfg)?;
            }
            let logits = self.backend.logits(&self.weights, &toks, cfg.batch)?;
            for b in 0..cfg.batch {
                for s in 0..seq - 1 {
                    let off = (b * seq + s) * vocab;
                    let best = argmax(&logits[off..off + vocab]);
                    if best as i32 == toks[b * seq + s + 1] {
                        hits += 1;
                    }
                    total += 1;
                }
            }
        }
        self.restore();
        Ok(hits as f64 / total as f64)
    }
}

/// exp(mean NLL) — shared helper for reporting.
pub fn ppl(nll_sum: f64, count: f64) -> f64 {
    (nll_sum / count).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels_match_table_rows() {
        assert_eq!(MethodSpec::awq("c4s").label(), "AWQ (C4S Calib)");
        assert_eq!(MethodSpec::ttq(16).label(), "TTQ (r = 16)");
        assert_eq!(MethodSpec::rtn().label(), "RTN");
    }

    #[test]
    fn ppl_of_uniform() {
        // uniform over 512 tokens → ppl = 512
        let nll = (512f64).ln() * 100.0;
        assert!((ppl(nll, 100.0) - 512.0).abs() < 1e-9);
    }

    #[test]
    fn default_config_sane() {
        let c = EvalConfig::default();
        assert_eq!(c.spec.group, 32);
        assert!(c.eval_batches > 0 && c.calib_batches > 0);
    }

    #[test]
    fn reconstruction_errors_relative_and_diag_weighted() {
        let backend = crate::backend::NativeBackend::new(std::path::Path::new("artifacts"));
        let mut ev = Evaluator::new(&backend, "qwen-micro").expect("synthetic model");
        let n = ev.weights.manifest.linears.len();
        assert!(n > 0);

        // Pristine weights → exactly zero everywhere.
        let errs = ev.reconstruction_errors(&[]);
        assert_eq!(errs.len(), n);
        assert!(errs.iter().all(|&e| e == 0.0), "{errs:?}");

        // Scaling one linear by 1.1 gives relative error (0.1)² = 0.01
        // regardless of the layer's own scale.
        let name = ev.weights.manifest.linears[0].name.clone();
        let orig = ev.weights.get(&name).expect("linear").clone();
        let mut scaled = orig.clone();
        for v in scaled.data.iter_mut() {
            *v *= 1.1;
        }
        ev.weights.set(&name, scaled);
        let errs = ev.reconstruction_errors(&[]);
        assert!((errs[0] - 0.01).abs() < 1e-4, "{}", errs[0]);
        assert!(errs[1..].iter().all(|&e| e == 0.0));

        // Diagonal weighting: a diag that zeroes every input column but
        // 0 is blind to a perturbation confined to column 1, while the
        // uniform fallback sees it.
        let mut poked = orig.clone();
        for r in 0..poked.rows {
            poked.row_mut(r)[1] += 0.5;
        }
        ev.weights.set(&name, poked);
        let mut diags: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut d0 = vec![0.0f32; orig.cols];
        d0[0] = 1.0;
        diags[0] = d0;
        let errs = ev.reconstruction_errors(&diags);
        assert_eq!(errs[0], 0.0, "column-1 damage invisible to a column-0 diag");
        let uniform = ev.reconstruction_errors(&[]);
        assert!(uniform[0] > 0.0);
    }
}
