//! Experiment pipelines: perplexity, accuracy, and success-rate
//! evaluation of quantized models — the engines behind every table.
//!
//! All methods share one two-pass pipeline (DESIGN.md §7):
//!
//!   pass 1  `stats`/`corr` artifact → per-linear activation statistics
//!   rust    quantize each linear with the chosen method
//!   pass 2  `nll`/`logits` artifact with the substituted weights
//!
//! For **TTQ** pass 1 runs on the *evaluation batch itself* (that is
//! the definition of test-time quantization — Fig. 1b); for **AWQ/GPTQ**
//! pass 1 runs once on a *calibration* stream (Fig. 1a), which is what
//! exposes them to domain shift.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::corpus::{CorpusStream, Split};
use crate::linalg::Mat;
use crate::models::ModelWeights;
use crate::quant::{
    awq_quantize, diag_from_norm_sums, gptq_quantize, lowrank_init,
    rtn_quantize, ActStats, LowRank, QuantSpec, TtqHyper,
};
use crate::runtime::{
    literal_f32_vec, literal_scalar_f32, model_inputs, ArtifactKey, Runtime,
};

/// Method selector for one experiment row.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodSpec {
    /// Un-quantized baseline (the table headers' reference perplexity).
    Fp,
    Rtn,
    /// Offline AWQ calibrated on the named domain's calib split.
    Awq { calib_domain: String },
    /// Online TTQ with rank-r low-rank compensation.
    Ttq { rank: usize },
    /// GPTQ calibrated on the named domain (needs the corr artifact).
    Gptq { calib_domain: String },
}

impl MethodSpec {
    pub fn label(&self) -> String {
        match self {
            MethodSpec::Fp => "FP32".into(),
            MethodSpec::Rtn => "RTN".into(),
            MethodSpec::Awq { calib_domain } => {
                format!("AWQ ({} Calib)", calib_domain.to_uppercase())
            }
            MethodSpec::Ttq { rank } => format!("TTQ (r = {rank})"),
            MethodSpec::Gptq { calib_domain } => {
                format!("GPTQ ({} Calib)", calib_domain.to_uppercase())
            }
        }
    }
}

/// Shared experiment knobs.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub batch: usize,
    pub eval_batches: usize,
    pub calib_batches: usize,
    pub spec: QuantSpec,
    pub hyper: TtqHyper,
    /// GPTQ diagonal damping fraction.
    pub gptq_damp: f64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            batch: 4,
            eval_batches: 12,
            calib_batches: 16,
            spec: QuantSpec::new(3, 32),
            hyper: TtqHyper::default(),
            gptq_damp: 0.01,
        }
    }
}

/// Per-linear activation statistics from one or more stats passes.
pub struct CollectedStats {
    pub stats: Vec<ActStats>,
    pub corr: Vec<Mat>, // empty unless collected via the corr artifact
}

/// Evaluation driver bound to one model's artifacts.
pub struct Evaluator<'rt> {
    pub rt: &'rt Runtime,
    pub weights: ModelWeights,
    /// Pristine copies of the quantizable linears ("the original
    /// full-precision weights *are* recoverable" — paper's point (3)).
    originals: HashMap<String, Mat>,
    /// Cached low-rank factors per (linear, rank) — static per App. E.
    lowrank_cache: HashMap<(String, usize), LowRank>,
}

impl<'rt> Evaluator<'rt> {
    pub fn new(rt: &'rt Runtime, model: &str) -> Result<Self> {
        let weights = ModelWeights::load(rt.artifacts_dir(), model)?;
        let originals = weights.linear_weights();
        Ok(Evaluator { rt, weights, originals, lowrank_cache: HashMap::new() })
    }

    pub fn model_name(&self) -> &str {
        &self.weights.manifest.name
    }

    fn seq(&self) -> usize {
        self.weights.manifest.config.seq
    }

    /// Run the `nll` artifact; returns (nll_sum, token_count).
    pub fn nll(&self, tokens: &[i32], batch: usize) -> Result<(f64, f64)> {
        let key = ArtifactKey::new(self.model_name(), "nll", batch);
        let exe = self.rt.load(&key)?;
        let inputs = model_inputs(&self.weights, tokens, batch, None)?;
        let outs = self.rt.run(&exe, &inputs)?;
        Ok((
            literal_scalar_f32(&outs[0])? as f64,
            literal_scalar_f32(&outs[1])? as f64,
        ))
    }

    /// Run the fused single-pass `ttq` artifact (Fig. 1b, L1 kernel).
    pub fn nll_fused_ttq(&self, tokens: &[i32], batch: usize, bits: u32) -> Result<(f64, f64)> {
        let key = ArtifactKey::new(self.model_name(), "ttq", batch);
        let exe = self.rt.load(&key)?;
        let qmax = ((1u64 << bits) - 1) as f32;
        let inputs = model_inputs(&self.weights, tokens, batch, Some(qmax))?;
        let outs = self.rt.run(&exe, &inputs)?;
        Ok((
            literal_scalar_f32(&outs[0])? as f64,
            literal_scalar_f32(&outs[1])? as f64,
        ))
    }

    /// Run `stats` (or `corr`) and parse per-linear statistics.
    pub fn collect(&self, tokens: &[i32], batch: usize, with_corr: bool) -> Result<CollectedStats> {
        let variant = if with_corr { "corr" } else { "stats" };
        let key = ArtifactKey::new(self.model_name(), variant, batch);
        let exe = self.rt.load(&key)?;
        let inputs = model_inputs(&self.weights, tokens, batch, None)?;
        let outs = self.rt.run(&exe, &inputs)?;
        let linears = &self.weights.manifest.linears;
        let ps = &self.weights.manifest.norm_ps;
        let count = literal_scalar_f32(&outs[1])? as f64;
        let n_tokens = (batch * self.seq()) as f64;
        let mut stats = Vec::with_capacity(linears.len());
        for (i, lin) in linears.iter().enumerate() {
            let raw = literal_f32_vec(&outs[2 + i])?;
            if raw.len() != ps.len() * lin.d_in {
                return Err(anyhow!(
                    "stats shape mismatch for {}: {} vs {}x{}",
                    lin.name, raw.len(), ps.len(), lin.d_in
                ));
            }
            let mut st = ActStats::new(ps, lin.d_in);
            let sums: Vec<Vec<f64>> = raw
                .chunks(lin.d_in)
                .map(|row| row.iter().map(|&v| v as f64).collect())
                .collect();
            st.accumulate(&sums, n_tokens);
            stats.push(st);
        }
        let mut corr = Vec::new();
        if with_corr {
            for (i, lin) in linears.iter().enumerate() {
                let raw = literal_f32_vec(&outs[2 + linears.len() + i])?;
                corr.push(Mat::from_vec(lin.d_in, lin.d_in, raw));
            }
        }
        let _ = count;
        Ok(CollectedStats { stats, corr })
    }

    /// Accumulate stats over many batches of a stream.
    pub fn collect_stream(
        &self,
        stream: &mut CorpusStream,
        batch: usize,
        n_batches: usize,
        with_corr: bool,
    ) -> Result<CollectedStats> {
        let mut agg: Option<CollectedStats> = None;
        for _ in 0..n_batches {
            let toks = stream.batch(batch, self.seq());
            let got = self.collect(&toks, batch, with_corr)?;
            match &mut agg {
                None => agg = Some(got),
                Some(a) => {
                    for (dst, src) in a.stats.iter_mut().zip(&got.stats) {
                        dst.accumulate(&src.norm_sums, src.count);
                    }
                    for (dst, src) in a.corr.iter_mut().zip(&got.corr) {
                        *dst = dst.add(src);
                    }
                }
            }
        }
        Ok(agg.expect("n_batches >= 1"))
    }

    /// Low-rank factors for a linear (cached — static per App. E).
    pub fn lowrank_for(&mut self, name: &str, rank: usize) -> LowRank {
        if let Some(lr) = self.lowrank_cache.get(&(name.to_string(), rank)) {
            return lr.clone();
        }
        let lr = lowrank_init(&self.originals[name], rank);
        self.lowrank_cache
            .insert((name.to_string(), rank), lr.clone());
        lr
    }

    /// Substitute quantized weights for every linear given statistics.
    pub fn apply_quantization(
        &mut self,
        method: &MethodSpec,
        collected: Option<&CollectedStats>,
        cfg: &EvalConfig,
    ) -> Result<()> {
        let linears = self.weights.manifest.linears.clone();
        for (i, lin) in linears.iter().enumerate() {
            let w0 = self.originals[&lin.name].clone();
            let wq = match method {
                MethodSpec::Fp => w0,
                MethodSpec::Rtn => rtn_quantize(&w0, &cfg.spec),
                MethodSpec::Awq { .. } => {
                    let st = &collected.ok_or_else(|| anyhow!("AWQ needs stats"))?.stats[i];
                    let d = diag_from_norm_sums(st, cfg.hyper.p, cfg.hyper.lam, cfg.hyper.alpha);
                    awq_quantize(&w0, &d, &cfg.spec)
                }
                MethodSpec::Ttq { rank } => {
                    let st = &collected.ok_or_else(|| anyhow!("TTQ needs stats"))?.stats[i];
                    let d = diag_from_norm_sums(st, cfg.hyper.p, cfg.hyper.lam, cfg.hyper.alpha);
                    if *rank == 0 {
                        awq_quantize(&w0, &d, &cfg.spec)
                    } else {
                        let lr = self.lowrank_for(&lin.name, *rank);
                        let wq = awq_quantize(&w0.sub(&lr.product()), &d, &cfg.spec);
                        wq.add(&lr.product())
                    }
                }
                MethodSpec::Gptq { .. } => {
                    let c = &collected.ok_or_else(|| anyhow!("GPTQ needs corr"))?.corr[i];
                    gptq_quantize(&w0, c, &cfg.spec, cfg.gptq_damp)
                }
            };
            self.weights.set(&lin.name, wq);
        }
        Ok(())
    }

    /// Quantize every linear with externally supplied diagonals (the
    /// serving path: the [`crate::coordinator::OnlineCalibrator`] owns
    /// the statistics and hands committed diagonals down).
    pub fn apply_diags(
        &mut self,
        diags: &[Vec<f32>],
        rank: usize,
        spec: &QuantSpec,
    ) -> Result<()> {
        let linears = self.weights.manifest.linears.clone();
        if diags.len() != linears.len() {
            return Err(anyhow!("{} diags for {} linears", diags.len(), linears.len()));
        }
        for (lin, d) in linears.iter().zip(diags) {
            let w0 = self.originals[&lin.name].clone();
            let wq = if rank == 0 {
                awq_quantize(&w0, d, spec)
            } else {
                let lr = self.lowrank_for(&lin.name, rank);
                awq_quantize(&w0.sub(&lr.product()), d, spec).add(&lr.product())
            };
            self.weights.set(&lin.name, wq);
        }
        Ok(())
    }

    /// Restore pristine full-precision weights.
    pub fn restore(&mut self) {
        for (name, w) in self.originals.clone() {
            self.weights.set(&name, w);
        }
    }

    // ------------------------------------------------------------------
    // Experiment drivers
    // ------------------------------------------------------------------

    /// Perplexity of `method` on `eval_domain` (paper's core metric).
    pub fn perplexity(
        &mut self,
        method: &MethodSpec,
        eval_domain: &str,
        cfg: &EvalConfig,
    ) -> Result<f64> {
        // Offline calibration pass (AWQ / GPTQ), once.
        let offline = match method {
            MethodSpec::Awq { calib_domain } => {
                self.restore();
                let mut s = CorpusStream::new(calib_domain, Split::Calib);
                Some(self.collect_stream(&mut s, cfg.batch, cfg.calib_batches, false)?)
            }
            MethodSpec::Gptq { calib_domain } => {
                self.restore();
                let mut s = CorpusStream::new(calib_domain, Split::Calib);
                Some(self.collect_stream(&mut s, cfg.batch, cfg.calib_batches, true)?)
            }
            _ => None,
        };
        if let Some(st) = &offline {
            self.apply_quantization(method, Some(st), cfg)?;
        } else if matches!(method, MethodSpec::Fp | MethodSpec::Rtn) {
            self.restore();
            self.apply_quantization(method, None, cfg)?;
        }

        let mut stream = CorpusStream::new(eval_domain, Split::Eval);
        let mut total_nll = 0.0;
        let mut total_cnt = 0.0;
        for _ in 0..cfg.eval_batches {
            let toks = stream.batch(cfg.batch, self.seq());
            if let MethodSpec::Ttq { .. } = method {
                // TTQ: per-prompt online quantization — stats on the
                // *incoming* batch, quantize, then evaluate it.
                self.restore();
                let st = self.collect(&toks, cfg.batch, false)?;
                self.apply_quantization(method, Some(&st), cfg)?;
            }
            let (s, c) = self.nll(&toks, cfg.batch)?;
            total_nll += s;
            total_cnt += c;
        }
        self.restore();
        Ok((total_nll / total_cnt).exp())
    }

    /// Next-token top-1 accuracy on a domain (VQA-proxy, Table 12).
    pub fn accuracy(
        &mut self,
        method: &MethodSpec,
        domain: &str,
        cfg: &EvalConfig,
    ) -> Result<f64> {
        let vocab = self.weights.manifest.config.vocab;
        let seq = self.seq();
        // quantize exactly as in `perplexity`
        match method {
            MethodSpec::Awq { calib_domain } => {
                self.restore();
                let mut s = CorpusStream::new(calib_domain, Split::Calib);
                let st = self.collect_stream(&mut s, cfg.batch, cfg.calib_batches, false)?;
                self.apply_quantization(method, Some(&st), cfg)?;
            }
            MethodSpec::Gptq { calib_domain } => {
                self.restore();
                let mut s = CorpusStream::new(calib_domain, Split::Calib);
                let st = self.collect_stream(&mut s, cfg.batch, cfg.calib_batches, true)?;
                self.apply_quantization(method, Some(&st), cfg)?;
            }
            _ => {
                self.restore();
                if !matches!(method, MethodSpec::Ttq { .. }) {
                    self.apply_quantization(method, None, cfg)?;
                }
            }
        }
        let key = ArtifactKey::new(self.model_name(), "logits", cfg.batch);
        let exe = self.rt.load(&key)?;
        let mut stream = CorpusStream::new(domain, Split::Eval);
        let (mut hits, mut total) = (0usize, 0usize);
        for _ in 0..cfg.eval_batches {
            let toks = stream.batch(cfg.batch, seq);
            if let MethodSpec::Ttq { .. } = method {
                self.restore();
                let st = self.collect(&toks, cfg.batch, false)?;
                self.apply_quantization(method, Some(&st), cfg)?;
            }
            let inputs = model_inputs(&self.weights, &toks, cfg.batch, None)?;
            let outs = self.rt.run(&exe, &inputs)?;
            let logits = literal_f32_vec(&outs[0])?;
            for b in 0..cfg.batch {
                for s in 0..seq - 1 {
                    let off = (b * seq + s) * vocab;
                    let row = &logits[off..off + vocab];
                    let mut best = 0usize;
                    for (v, &x) in row.iter().enumerate() {
                        if x > row[best] {
                            best = v;
                        }
                    }
                    if best as i32 == toks[b * seq + s + 1] {
                        hits += 1;
                    }
                    total += 1;
                }
            }
        }
        self.restore();
        Ok(hits as f64 / total as f64)
    }
}

/// exp(mean NLL) — shared helper for reporting.
pub fn ppl(nll_sum: f64, count: f64) -> f64 {
    (nll_sum / count).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels_match_table_rows() {
        assert_eq!(
            MethodSpec::Awq { calib_domain: "c4s".into() }.label(),
            "AWQ (C4S Calib)"
        );
        assert_eq!(MethodSpec::Ttq { rank: 16 }.label(), "TTQ (r = 16)");
        assert_eq!(MethodSpec::Rtn.label(), "RTN");
    }

    #[test]
    fn ppl_of_uniform() {
        // uniform over 512 tokens → ppl = 512
        let nll = (512f64).ln() * 100.0;
        assert!((ppl(nll, 100.0) - 512.0).abs() < 1e-9);
    }

    #[test]
    fn default_config_sane() {
        let c = EvalConfig::default();
        assert_eq!(c.spec.group, 32);
        assert!(c.eval_batches > 0 && c.calib_batches > 0);
    }
}
