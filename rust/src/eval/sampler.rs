//! Token samplers for autoregressive generation.
//!
//! One small dispatch point shared by every generation path — plain
//! cached decode ([`crate::eval::Evaluator::generate_with`]) and the
//! speculative verifier ([`crate::specdec`]) — so the two paths consume
//! randomness identically: **exactly one draw per committed token, in
//! generation order**. That discipline is what keeps speculative
//! decoding token-identical to plain decoding not just for greedy but
//! for any seeded sampler (the verifier samples from the same logits
//! rows, in the same order, with the same RNG stream).
//!
//! Seeding goes through [`crate::linalg::Rng`] (SplitMix64), the same
//! deterministic core that drives the corpus engine and test matrices.

use crate::linalg::Rng;
use crate::util::argmax;

/// Greedy / temperature / top-k next-token selection.
#[derive(Clone, Debug)]
pub enum Sampler {
    /// Deterministic argmax — the paper's evaluation mode, and the mode
    /// under which speculative verification is exactly lossless.
    Greedy,
    /// Softmax at `temp` over the full vocabulary.
    Temperature { temp: f32, rng: Rng },
    /// Softmax at `temp` restricted to the `k` highest-logit tokens.
    TopK { k: usize, temp: f32, rng: Rng },
}

impl Sampler {
    /// Deterministic argmax selection.
    pub fn greedy() -> Self {
        Sampler::Greedy
    }

    /// Temperature sampling; `temp <= 0` degenerates to greedy.
    pub fn temperature(temp: f32, seed: u64) -> Self {
        Sampler::Temperature { temp, rng: Rng::new(seed) }
    }

    /// Top-k sampling at `temp`; `k == 0` is treated as `k == 1`.
    pub fn top_k(k: usize, temp: f32, seed: u64) -> Self {
        Sampler::TopK { k: k.max(1), temp, rng: Rng::new(seed) }
    }

    /// True for the deterministic argmax mode.
    pub fn is_greedy(&self) -> bool {
        matches!(self, Sampler::Greedy)
    }

    /// Select the next token from one row of logits. Consumes exactly
    /// one RNG draw for the stochastic modes, zero for greedy.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        match self {
            Sampler::Greedy => argmax(logits),
            Sampler::Temperature { temp, rng } => softmax_draw(logits, *temp, usize::MAX, rng),
            Sampler::TopK { k, temp, rng } => softmax_draw(logits, *temp, *k, rng),
        }
    }
}

/// One inverse-CDF draw from softmax(logits / temp) over the top-k
/// tokens. Ties break toward the lower token id, so the ordering is
/// fully deterministic for a given logits row.
fn softmax_draw(logits: &[f32], temp: f32, k: usize, rng: &mut Rng) -> usize {
    assert!(!logits.is_empty(), "sampling from empty logits");
    if temp <= 0.0 {
        return argmax(logits);
    }
    if k >= logits.len() {
        // temperature mode: no ordering needed — one O(V) stable
        // softmax pass, walking the CDF in token-id order
        let mx = logits[argmax(logits)];
        let weights: Vec<f64> = logits
            .iter()
            .map(|&l| (((l - mx) / temp) as f64).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut u = rng.u01() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= w;
        }
        return argmax(logits);
    }
    let mut order: Vec<usize> = (0..logits.len()).collect();
    order.sort_unstable_by(|&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let top = &order[..k];
    // numerically stable softmax at temperature over the kept set
    let mx = logits[top[0]];
    let weights: Vec<f64> = top
        .iter()
        .map(|&i| (((logits[i] - mx) / temp) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.u01() * total;
    for (&i, w) in top.iter().zip(&weights) {
        if u < *w {
            return i;
        }
        u -= w;
    }
    // numerical slack: fall back to the most likely kept token
    top[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = [0.1f32, 2.0, -1.0, 1.9];
        assert_eq!(Sampler::greedy().sample(&logits), 1);
        assert!(Sampler::greedy().is_greedy());
    }

    #[test]
    fn top1_matches_greedy_for_any_seed() {
        let logits = [0.3f32, -0.5, 4.0, 3.9, 0.0];
        for seed in 0..20 {
            let mut s = Sampler::top_k(1, 1.0, seed);
            assert_eq!(s.sample(&logits), 2, "seed {seed}");
        }
    }

    #[test]
    fn near_zero_temperature_concentrates_on_argmax() {
        let logits = [0.0f32, 1.0, 0.5];
        let mut s = Sampler::temperature(1e-4, 7);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
        // temp <= 0 degenerates to greedy outright
        assert_eq!(Sampler::temperature(0.0, 7).sample(&logits), 1);
    }

    #[test]
    fn same_seed_same_stream() {
        let logits = [0.0f32, 0.1, 0.2, 0.3, 0.15];
        let mut a = Sampler::temperature(2.0, 42);
        let mut b = Sampler::temperature(2.0, 42);
        for _ in 0..100 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }

    #[test]
    fn high_temperature_explores_and_topk_restricts() {
        let logits = [1.0f32, 0.9, -50.0, 0.8];
        let mut seen = [0usize; 4];
        let mut s = Sampler::top_k(3, 5.0, 3);
        for _ in 0..300 {
            seen[s.sample(&logits)] += 1;
        }
        assert_eq!(seen[2], 0, "token outside top-3 must never be drawn");
        assert!(seen[0] > 0 && seen[1] > 0 && seen[3] > 0, "high temp explores: {seen:?}");
    }
}
