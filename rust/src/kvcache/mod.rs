//! Slab-allocated per-sequence KV cache — the state behind the
//! prefill/decode split.
//!
//! Decode is the phase where TTQ's low-bit weights actually pay off:
//! each step is a GEMV whose cost is dominated by weight traffic, *if*
//! the attention keys/values of the prefix are cached instead of
//! recomputed. This module owns that cache:
//!
//! * [`KvCache`] — a fixed pool of sequence slots, each preallocated
//!   with per-layer K/V blocks of `(max_seq, d_kv)` sized from the
//!   model [`Manifest`]. Slots are recycled (`alloc`/`free`) without
//!   reallocation — the slab discipline of paged-attention allocators,
//!   at one-block-per-sequence granularity.
//! * [`SeqId`] — an opaque slot handle. The serving layer holds one per
//!   in-flight sequence and passes them to
//!   [`crate::backend::ExecBackend::prefill`] /
//!   [`crate::backend::ExecBackend::decode_step`].
//! * [`CacheStats`] — capacity accounting (slots, live tokens,
//!   high-water mark) surfaced by the coordinator's metrics.
//!
//! The write protocol is two-phase so a multi-layer forward sees a
//! stable sequence length throughout: the backend writes rows for every
//! layer at absolute positions via [`KvCache::append_row`], then bumps
//! the length once with [`KvCache::advance`] after the full forward.
//!
//! Speculative decoding adds the third verb: [`KvCache::truncate`]
//! rolls a sequence back to a shorter length after the verifier rejects
//! draft tokens — the rows beyond the new length become unreachable and
//! are fully overwritten by the next `append_row`/`advance` cycle, so a
//! rollback is bit-identical to never having appended.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::linalg::Mat;
use crate::models::Manifest;

/// Cache geometry, derived from the model manifest.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Transformer layers cached per sequence.
    pub n_layers: usize,
    /// K/V row width: `n_kv_heads × head_dim` (GQA/MQA-aware).
    pub d_kv: usize,
    /// Maximum positions per sequence (prompt + generated).
    pub max_seq: usize,
    /// Number of concurrently resident sequences.
    pub slots: usize,
}

impl KvCacheConfig {
    /// Geometry for `slots` concurrent sequences of a model.
    pub fn from_manifest(man: &Manifest, slots: usize) -> Self {
        let c = &man.config;
        KvCacheConfig {
            n_layers: c.n_layers,
            d_kv: c.n_kv_heads * c.head_dim,
            max_seq: c.max_seq,
            slots: slots.max(1),
        }
    }

    /// Bytes of K/V storage per slot (f32).
    pub fn bytes_per_slot(&self) -> usize {
        self.n_layers * 2 * self.max_seq * self.d_kv * 4
    }
}

/// One layer's cached keys and values: `(max_seq, d_kv)` row-major,
/// rows `0..len` live.
pub struct LayerKv {
    /// Cached keys, `(max_seq, d_kv)`.
    pub k: Mat,
    /// Cached values, `(max_seq, d_kv)`.
    pub v: Mat,
}

struct Slot {
    layers: Vec<LayerKv>,
    len: usize,
    in_use: bool,
}

/// Opaque handle to one allocated sequence slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SeqId(usize);

/// Capacity accounting snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Total sequence slots in the slab.
    pub slots: usize,
    /// Slots currently allocated.
    pub active_seqs: usize,
    /// Token capacity (slots × max_seq).
    pub capacity_tokens: usize,
    /// Live cached positions across active sequences.
    pub used_tokens: usize,
    /// Most tokens ever simultaneously resident.
    pub high_water_tokens: usize,
}

impl CacheStats {
    /// Fraction of the token capacity currently occupied, in `[0, 1]`
    /// (0 for a zero-capacity cache) — the value behind the exported
    /// `kv_cache_tokens` occupancy counter track
    /// (`docs/OBSERVABILITY.md`).
    pub fn utilization(&self) -> f64 {
        if self.capacity_tokens == 0 {
            0.0
        } else {
            self.used_tokens as f64 / self.capacity_tokens as f64
        }
    }
}

/// The slab: `slots` preallocated sequences, recycled across requests.
pub struct KvCache {
    cfg: KvCacheConfig,
    pool: Vec<Slot>,
    free: Vec<usize>,
    high_water: usize,
}

impl KvCache {
    /// Preallocate the whole slab up front — no allocation happens on
    /// the decode hot path afterwards.
    pub fn new(cfg: KvCacheConfig) -> Self {
        let pool: Vec<Slot> = (0..cfg.slots)
            .map(|_| Slot {
                layers: (0..cfg.n_layers)
                    .map(|_| LayerKv {
                        k: Mat::zeros(cfg.max_seq, cfg.d_kv),
                        v: Mat::zeros(cfg.max_seq, cfg.d_kv),
                    })
                    .collect(),
                len: 0,
                in_use: false,
            })
            .collect();
        // pop order: lowest slot index first
        let free: Vec<usize> = (0..cfg.slots).rev().collect();
        KvCache { cfg, pool, free, high_water: 0 }
    }

    /// The slab geometry.
    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Claim a slot for a new sequence, or `None` when the slab is full
    /// — the caller keeps the request queued. The slot is guaranteed
    /// empty: length 0 *and* zeroed K/V blocks, so a recycled slot is
    /// indistinguishable from a fresh one (zeroing happens here, on the
    /// admission path, never on the decode hot path).
    pub fn alloc(&mut self) -> Option<SeqId> {
        let idx = self.free.pop()?;
        let s = &mut self.pool[idx];
        for l in &mut s.layers {
            l.k.data.fill(0.0);
            l.v.data.fill(0.0);
        }
        s.len = 0;
        s.in_use = true;
        Some(SeqId(idx))
    }

    /// Return a slot to the pool. The K/V contents are left in place
    /// (rows beyond `len == 0` are unreachable); [`Self::alloc`] zeroes
    /// them before the slot is handed out again.
    pub fn release(&mut self, id: SeqId) {
        let s = &mut self.pool[id.0];
        assert!(s.in_use, "release of a free slot");
        s.in_use = false;
        s.len = 0;
        self.free.push(id.0);
    }

    /// Slots available for allocation.
    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Live length (cached positions) of a sequence.
    pub fn len(&self, id: SeqId) -> usize {
        debug_assert!(self.pool[id.0].in_use, "len of a free slot");
        self.pool[id.0].len
    }

    /// True when the sequence has no live positions.
    pub fn is_empty(&self, id: SeqId) -> bool {
        self.len(id) == 0
    }

    /// Room left before the sequence hits `max_seq`.
    pub fn remaining(&self, id: SeqId) -> usize {
        self.cfg.max_seq - self.len(id)
    }

    /// A layer's K/V blocks for reading during attention.
    pub fn layer(&self, id: SeqId, layer: usize) -> (&Mat, &Mat) {
        let l = &self.pool[id.0].layers[layer];
        (&l.k, &l.v)
    }

    /// Write one K row + V row at an absolute position (phase 1 of the
    /// write protocol; positions become live only after [`Self::advance`]).
    pub fn append_row(&mut self, id: SeqId, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert!(pos < self.cfg.max_seq, "position {pos} past max_seq");
        debug_assert_eq!(k.len(), self.cfg.d_kv);
        debug_assert_eq!(v.len(), self.cfg.d_kv);
        let l = &mut self.pool[id.0].layers[layer];
        l.k.row_mut(pos).copy_from_slice(k);
        l.v.row_mut(pos).copy_from_slice(v);
    }

    /// Commit `n` freshly written positions (phase 2) across all layers.
    pub fn advance(&mut self, id: SeqId, n: usize) -> Result<()> {
        let len = self.pool[id.0].len;
        if len + n > self.cfg.max_seq {
            bail!(
                "sequence would grow to {} positions, cache max_seq is {}",
                len + n,
                self.cfg.max_seq
            );
        }
        self.pool[id.0].len = len + n;
        let used = self.used_tokens();
        if used > self.high_water {
            self.high_water = used;
        }
        Ok(())
    }

    /// Roll a sequence back to `new_len` live positions — the
    /// speculative-decoding rejection path. Rows beyond `new_len`
    /// become unreachable immediately; the next
    /// [`Self::append_row`]/[`Self::advance`] cycle overwrites them in
    /// full, so truncate-then-reappend is bit-identical to never having
    /// appended. Never grows a sequence (that would expose stale rows).
    pub fn truncate(&mut self, id: SeqId, new_len: usize) -> Result<()> {
        let s = &mut self.pool[id.0];
        assert!(s.in_use, "truncate of a free slot");
        if new_len > s.len {
            bail!(
                "truncate to {new_len} would grow a sequence of length {} (stale rows)",
                s.len
            );
        }
        s.len = new_len;
        Ok(())
    }

    /// Live cached positions across all active sequences.
    pub fn used_tokens(&self) -> usize {
        self.pool.iter().filter(|s| s.in_use).map(|s| s.len).sum()
    }

    /// Occupancy snapshot (slots, tokens, high-water mark).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            slots: self.cfg.slots,
            active_seqs: self.cfg.slots - self.free.len(),
            capacity_tokens: self.cfg.slots * self.cfg.max_seq,
            used_tokens: self.used_tokens(),
            high_water_tokens: self.high_water,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> KvCacheConfig {
        KvCacheConfig { n_layers: 2, d_kv: 8, max_seq: 16, slots: 3 }
    }

    #[test]
    fn alloc_free_recycles_slots() {
        let mut c = KvCache::new(cfg());
        assert_eq!(c.free_slots(), 3);
        let a = c.alloc().unwrap();
        let b = c.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(c.free_slots(), 1);
        c.release(a);
        assert_eq!(c.free_slots(), 2);
        let c2 = c.alloc().unwrap();
        // the released slot is reused with a reset length
        assert_eq!(c2, a);
        assert_eq!(c.len(c2), 0);
        let _ = c.alloc().unwrap();
        assert!(c.alloc().is_none(), "slab over-allocated");
    }

    #[test]
    fn write_protocol_and_capacity_accounting() {
        let mut c = KvCache::new(cfg());
        let id = c.alloc().unwrap();
        let row = vec![1.0f32; 8];
        for layer in 0..2 {
            for pos in 0..4 {
                c.append_row(id, layer, pos, &row, &row);
            }
        }
        assert_eq!(c.len(id), 0, "rows live only after advance");
        c.advance(id, 4).unwrap();
        assert_eq!(c.len(id), 4);
        assert_eq!(c.remaining(id), 12);
        let (k, v) = c.layer(id, 1);
        assert_eq!(k.row(3), &row[..]);
        assert_eq!(v.row(0), &row[..]);
        let st = c.stats();
        assert_eq!(st.active_seqs, 1);
        assert_eq!(st.used_tokens, 4);
        assert_eq!(st.capacity_tokens, 48);
        assert_eq!(st.high_water_tokens, 4);
        assert!((st.utilization() - 4.0 / 48.0).abs() < 1e-12);
        assert_eq!(CacheStats::default().utilization(), 0.0, "0-capacity → 0");
        // high water survives release
        c.release(id);
        assert_eq!(c.stats().used_tokens, 0);
        assert_eq!(c.stats().high_water_tokens, 4);
    }

    #[test]
    fn truncate_rolls_back_and_never_grows() {
        let mut c = KvCache::new(cfg());
        let id = c.alloc().unwrap();
        let row = vec![2.0f32; 8];
        for layer in 0..2 {
            for pos in 0..6 {
                c.append_row(id, layer, pos, &row, &row);
            }
        }
        c.advance(id, 6).unwrap();
        c.truncate(id, 4).unwrap();
        assert_eq!(c.len(id), 4);
        assert_eq!(c.remaining(id), 12);
        assert_eq!(c.stats().used_tokens, 4, "rollback frees capacity accounting");
        // growing via truncate would expose stale rows — refused
        assert!(c.truncate(id, 5).is_err());
        // truncate to the current length is a no-op
        c.truncate(id, 4).unwrap();
        assert_eq!(c.len(id), 4);
        // the rolled-back positions are writable again
        let row2 = vec![-1.0f32; 8];
        c.append_row(id, 0, 4, &row2, &row2);
        c.advance(id, 1).unwrap();
        assert_eq!(c.layer(id, 0).0.row(4), &row2[..]);
    }

    #[test]
    fn recycled_slot_is_guaranteed_empty() {
        // regression: a released slot's K/V contents used to linger
        // until overwritten — alloc must now hand out a zeroed slot so
        // no stale rows from the previous occupant can ever be read.
        let mut c = KvCache::new(cfg());
        let id = c.alloc().unwrap();
        let row = vec![7.0f32; 8];
        for layer in 0..2 {
            for pos in 0..16 {
                c.append_row(id, layer, pos, &row, &row);
            }
        }
        c.advance(id, 16).unwrap();
        c.release(id);
        let id2 = c.alloc().unwrap();
        assert_eq!(id2, id, "free list recycles the same slot");
        assert_eq!(c.len(id2), 0);
        for layer in 0..2 {
            let (k, v) = c.layer(id2, layer);
            assert!(k.data.iter().all(|&x| x == 0.0), "stale K rows survived recycle");
            assert!(v.data.iter().all(|&x| x == 0.0), "stale V rows survived recycle");
        }
    }

    #[test]
    fn advance_past_max_seq_errors() {
        let mut c = KvCache::new(cfg());
        let id = c.alloc().unwrap();
        c.advance(id, 16).unwrap();
        assert!(c.advance(id, 1).is_err());
    }

    #[test]
    fn config_from_manifest_uses_kv_heads() {
        let man = crate::backend::testmodel::manifest(
            crate::backend::testmodel::config("qwen-micro").unwrap(),
        );
        let c = KvCacheConfig::from_manifest(&man, 4);
        assert_eq!(c.n_layers, 2);
        assert_eq!(c.d_kv, 2 * 16, "GQA cache width is n_kv_heads × head_dim");
        assert_eq!(c.max_seq, 64);
        assert_eq!(c.bytes_per_slot(), 2 * 2 * 64 * 32 * 4);
    }
}
