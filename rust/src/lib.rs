//! # ttq-serve — TTQ paper reproduction, Layer-3 coordinator library
//!
//! Reproduction of *"TTQ: Activation-Aware Test-Time Quantization to
//! Accelerate LLM Inference On The Fly"* (Koike-Akino, Liu, Wang; MERL
//! 2026) as a three-layer Rust + JAX + Pallas stack.
//!
//! This crate is the runtime half: python (L2 jax models + L1 Pallas
//! kernels) runs once at `make artifacts` and never again; everything
//! here executes against AOT-compiled HLO-text artifacts through the
//! PJRT CPU client plus a pure-Rust quantization library.
//!
//! Module map (see DESIGN.md §4 for the full inventory):
//!
//! * [`linalg`] — dense matrix substrate: matmul, Cholesky, truncated
//!   SVD, the persistent [`linalg::pool::WorkerPool`] every native
//!   kernel dispatches on, and the runtime-selected SIMD microkernels
//!   ([`linalg::simd`]: AVX2/NEON/scalar, W4 bit-exact across ISAs,
//!   fp32 within a documented ULP bound).
//! * [`quant`] — the paper's algorithms behind one dispatch surface: the
//!   [`quant::Quantizer`] trait + [`quant::MethodRegistry`] (spec strings
//!   like `"ttq:r=16"`, `"nf:4"`, `"prune:0.5"`), over RTN (Eq. 1), AWQ
//!   (Eq. 19-20), TTQ (§2), GPTQ (App. C), NormalFloat and test-time
//!   pruning, plus low-rank decomposition (App. E), QDQ formats (App. D)
//!   and bit-packing with traffic accounting.
//! * [`corpus`] — seeded synthetic corpora standing in for WT2/PTB/C4 and
//!   the VQA/VLA proxies (bit-identical to `python/compile/corpus.py`).
//! * [`models`] — model registry + weight-manifest loader (interchange
//!   contract with `python/compile/aot.py`).
//! * [`backend`] — execution engines behind the [`backend::ExecBackend`]
//!   trait: [`backend::PjrtBackend`] (AOT artifacts) and
//!   [`backend::NativeBackend`] (pure-Rust forward with a packed-W4
//!   execution mode), plus [`backend::testmodel`] synthetic models.
//! * [`runtime`] — PJRT artifact loader / executor (xla crate; an
//!   in-tree stub keeps offline builds green).
//! * [`kvcache`] — slab-allocated per-sequence K/V cache behind the
//!   prefill/decode split (allocate/append/free, capacity accounting).
//! * [`coordinator`] — serving layer: shape-bucketed dynamic batcher,
//!   online calibrator driving any diagonal method, a continuous-
//!   batching decode scheduler streaming [`coordinator::ServeEvent`]s,
//!   metrics.
//! * [`sync`] — synchronization shim: `std::sync` re-exports normally,
//!   the in-tree bounded-exhaustive model checker ([`sync::model`])
//!   under `--cfg loom`; `linalg::pool` and `backend::native` draw
//!   every primitive from here so `rust/tests/loom_pool.rs` can
//!   explore the dispatch protocol's interleavings exhaustively.
//! * [`specdec`] — self-speculative decoding: a quantized drafter
//!   proposes `k` tokens per round, the full-precision verifier scores
//!   all `k+1` positions in one [`backend::ExecBackend::verify_step`],
//!   and both KV caches roll back to the first rejection — greedy
//!   output stays token-identical to the fp32 model while decode rides
//!   the cheap drafter. Adaptive draft depth from an acceptance EWMA.
//! * [`obs`] — serving-path observability: the [`obs::Clock`]
//!   abstraction (real vs. deterministic test clock), a lock-free span
//!   ring buffer recording the request lifecycle, HDR-style latency
//!   histograms (the repo's single percentile implementation), per-
//!   requant drift introspection, and Chrome-trace / Prometheus / JSON
//!   exporters (`docs/OBSERVABILITY.md`).
//! * [`eval`] — perplexity / accuracy / success-rate pipelines; plans
//!   stats collection from [`quant::StatsRequirement`]; token
//!   [`eval::Sampler`]s (greedy / temperature / top-k).
//! * [`perfmodel`] — GPU roofline simulator regenerating Tables 4-8;
//!   rows are registry methods priced through the trait.
//! * [`bench`] — table/figure regeneration harness (`ttq-serve table N`,
//!   method rows swappable via `--methods`), plus the multi-scenario
//!   serving-throughput harness ([`bench::throughput`]) behind
//!   `benches/serve_throughput.rs`.
//!
//! The prose map of how these stack lives in `docs/ARCHITECTURE.md`;
//! API renames across PRs live in `docs/MIGRATION.md`; bench artifact
//! schemas in `docs/BENCHMARKS.md`.

#![warn(missing_docs)]

pub mod backend;
pub mod bench;
pub mod coordinator;
pub mod corpus;
pub mod eval;
pub mod kvcache;
pub mod linalg;
pub mod models;
pub mod obs;
pub mod perfmodel;
pub mod quant;
pub mod runtime;
pub mod specdec;
pub mod sync;
pub mod util;

/// Repo-relative artifacts directory (overridable via `TTQ_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("TTQ_ARTIFACTS") {
        return p.into();
    }
    // Walk up from the current dir until an `artifacts/` is found so that
    // tests, benches and examples work from any working directory.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}

/// True once `make artifacts` has completed (integration tests that need
/// compiled HLO check this and skip gracefully otherwise).
pub fn artifacts_ready() -> bool {
    artifacts_dir().join("BUILD_OK").exists()
}
