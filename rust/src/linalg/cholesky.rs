//! Cholesky factorization + triangular solves (f64 internals).
//!
//! GPTQ (paper App. C) is "optimal brain surgeon with Cholesky": it
//! needs L such that C_λ = L Lᵀ and the inverse Hessian diag. The paper
//! cites this as the O(d³) cost that TTQ avoids — we implement it as the
//! baseline it is.

#![forbid(unsafe_code)]

use super::Mat;

/// Lower-triangular Cholesky factor of a symmetric PSD matrix.
///
/// Returns `None` if the matrix is not positive definite beyond the
/// jitter tolerance (callers add λ-damping per Eq. 13 before calling).
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    let n = a.rows;
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(Mat::from_vec(
        n,
        n,
        l.into_iter().map(|v| v as f32).collect(),
    ))
}

/// Solve L y = b for lower-triangular L.
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l.at(i, k) as f64 * y[k];
        }
        y[i] = sum / l.at(i, i) as f64;
    }
    y.into_iter().map(|v| v as f32).collect()
}

/// Solve Lᵀ x = y for lower-triangular L (i.e. upper solve on Lᵀ).
pub fn solve_upper(l: &Mat, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for k in i + 1..n {
            sum -= l.at(k, i) as f64 * x[k];
        }
        x[i] = sum / l.at(i, i) as f64;
    }
    x.into_iter().map(|v| v as f32).collect()
}

/// Full inverse via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹ (GPTQ's inverse Hessian).
pub fn cholesky_inverse(a: &Mat) -> Option<Mat> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for col in 0..n {
        e[col] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_upper(&l, &y);
        for row in 0..n {
            *inv.at_mut(row, col) = x[row];
        }
        e[col] = 0.0;
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let x = Mat::randn(n + 4, n, &mut rng);
        let mut g = x.gram();
        for i in 0..n {
            *g.at_mut(i, i) += 0.5; // damping, as in Eq. 13
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(8, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul_bt(&l);
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn lower_triangular() {
        let l = cholesky(&spd(6, 2)).unwrap();
        for i in 0..6 {
            for j in i + 1..6 {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn solves_recover_rhs() {
        let a = spd(10, 3);
        let l = cholesky(&a).unwrap();
        let mut rng = Rng::new(4);
        let b: Vec<f32> = (0..10).map(|_| rng.normal() as f32).collect();
        let y = solve_lower(&l, &b);
        let x = solve_upper(&l, &y);
        // check A x == b
        let ax: Vec<f32> = (0..10)
            .map(|i| (0..10).map(|j| a.at(i, j) * x[j]).sum())
            .collect();
        for (got, want) in ax.iter().zip(&b) {
            assert!((got - want).abs() < 1e-2, "{got} vs {want}");
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(7, 5);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        for i in 0..7 {
            for j in 0..7 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-2);
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky(&a).is_none());
    }
}
