//! Dense linear-algebra substrate.
//!
//! The paper's methods need: matmul (everywhere), Cholesky factorization
//! (GPTQ's inverse-Hessian, App. C), and truncated SVD (the low-rank
//! factors of App. E). Nothing external is linked — this is the
//! "implement the substrate" rule of the reproduction.

mod cholesky;
pub mod pool;
pub mod rng;
pub mod simd;
mod svd;

pub use cholesky::{cholesky, cholesky_inverse, solve_lower, solve_upper};
pub use pool::WorkerPool;
pub use rng::Rng;
pub use svd::{truncated_svd, Svd};

/// Row-major f32 matrix. The one dense type used across quant/eval.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns (the row stride of `data`).
    pub cols: usize,
    /// Row-major elements, `rows * cols` long.
    pub data: Vec<f32>,
}

impl Mat {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer (length must match the shape).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build element-wise from `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Standard-normal random matrix (deterministic via [`Rng`]).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal() as f32);
        }
        Mat { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    /// Element at `(r, c)`.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Mutable element at `(r, c)`.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Materialized transpose.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// `self @ other` — cache-friendly ikj loop order.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// `self @ otherᵀ` without materializing the transpose.
    pub fn matmul_bt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_bt dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += arow[p] * brow[p];
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    /// `selfᵀ @ self` (the Gram matrix XᵀX used for correlations).
    pub fn gram(&self) -> Mat {
        let (n, d) = (self.rows, self.cols);
        let mut out = Mat::zeros(d, d);
        for r in 0..n {
            let row = &self.data[r * d..(r + 1) * d];
            for i in 0..d {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * d..(i + 1) * d];
                for j in 0..d {
                    orow[j] += xi * row[j];
                }
            }
        }
        out
    }

    /// Copy with column `i` scaled by `scales[i]`.
    pub fn scale_cols(&self, scales: &[f32]) -> Mat {
        assert_eq!(scales.len(), self.cols);
        let mut out = self.clone();
        for r in 0..self.rows {
            let row = &mut out.data[r * self.cols..(r + 1) * self.cols];
            for (v, s) in row.iter_mut().zip(scales) {
                *v *= s;
            }
        }
        out
    }

    /// Element-wise difference `self − other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// Element-wise sum `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum()
    }

    /// Largest absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

/// Activation-weighted approximation loss ‖(W−Ŵ)X‖² of paper Eq. (2).
pub fn activation_loss(w: &Mat, what: &Mat, x: &Mat) -> f64 {
    w.sub(what).matmul(x).frob_sq()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(5, 7, &mut rng);
        let i = Mat::eye(7);
        assert_eq!(a.matmul(&i).data, a.data);
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(5, 6, &mut rng);
        let got = a.matmul_bt(&b);
        let want = a.matmul(&b.transpose());
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Rng::new(3);
        let x = Mat::randn(20, 6, &mut rng);
        let g = x.gram();
        for i in 0..6 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..6 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(3, 8, &mut rng);
        assert_eq!(a.transpose().transpose().data, a.data);
    }

    #[test]
    fn activation_loss_zero_for_exact() {
        let mut rng = Rng::new(5);
        let w = Mat::randn(4, 4, &mut rng);
        let x = Mat::randn(4, 9, &mut rng);
        assert_eq!(activation_loss(&w, &w, &x), 0.0);
    }

    #[test]
    fn scale_cols_roundtrip() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(4, 5, &mut rng);
        let s: Vec<f32> = (1..=5).map(|v| v as f32).collect();
        let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
        let b = a.scale_cols(&s).scale_cols(&inv);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
