//! Persistent worker pool — the thread substrate of the native hot path.
//!
//! Before this module existed, every threaded kernel call paid a
//! `std::thread::scope` spawn/join: one OS thread creation *per matmul*,
//! thousands of times per generated token. A [`WorkerPool`] amortizes
//! that cost the way deployed inference kernels do — a fixed set of
//! worker threads is spawned once, parked on a condvar, and woken per
//! dispatch to claim chunks of a row range from a shared queue.
//!
//! Design points:
//!
//! * **Chunked row-range queue.** A dispatch splits `rows` output rows
//!   into one contiguous chunk per thread lane; workers (and the
//!   dispatching thread itself, which always participates) claim chunk
//!   indices from an atomic counter. Each chunk owns a disjoint
//!   `&mut [T]` window of the output buffer, so kernels write without
//!   locks.
//! * **Hoisted serial gating.** The threads-vs-serial decision —
//!   previously re-derived inside every kernel against a raw `m·k·n`
//!   product — lives in [`WorkerPool::run_rows`]: callers pass a flop
//!   hint and the pool falls back to a zero-overhead inline call when
//!   the fan-out cannot pay for itself. Decode-time GEMVs hit exactly
//!   one branch, not one per kernel.
//! * **Determinism.** Chunking never changes per-row arithmetic: the
//!   kernel closure receives `(first_row, window)` and computes each row
//!   exactly as the single-chunk (serial) call would, so pooled output
//!   is bit-identical to single-threaded output for any thread count —
//!   asserted by the unit suite and by the throughput bench.
//! * **Panic propagation.** A panicking kernel chunk is caught on the
//!   worker, the remaining chunks still drain (workers never die), and
//!   the payload is re-thrown on the dispatching thread — the scope-API
//!   contract, without the scope.
//! * **Kernel-time accounting.** Every dispatch (serial or pooled) adds
//!   its wall time to a cumulative counter ([`WorkerPool::kernel_us`]),
//!   which the serving metrics split per phase (prefill / decode /
//!   speculative).
//! * **Span recording.** Once a server attaches its trace ring
//!   ([`WorkerPool::attach_trace`]), every pooled dispatch additionally
//!   records a [`crate::obs::SpanKind::Kernel`] span timed on the
//!   server's [`crate::obs::Clock`]; serial fallbacks are never
//!   recorded (they would flood the ring at decode time).
//! * **Per-site attribution.** Once a server attaches a kernel
//!   profiler ([`WorkerPool::attach_profiler`]), every *attributed*
//!   dispatch ([`WorkerPool::run_rows_site`] — the only dispatch
//!   surface `backend::native` is allowed to use, repo-lint R7)
//!   accumulates its wall time plus analytic FLOP/byte counts into the
//!   per-[`crate::obs::KernelSite`] aggregator. Serial fallbacks are
//!   attributed too (decode GEMVs on the miniature models run below
//!   [`MT_FLOP_FLOOR`], and the ≥ 90% attribution-coverage gate counts
//!   them), so site wall time sums to [`WorkerPool::kernel_us`] minus
//!   only unattributed `run_rows` callers (tests, benches).
//!
//! One pool is meant to be shared by everything that executes kernels:
//! [`crate::backend::NativeBackend`] owns an `Arc<WorkerPool>`, and the
//! coordinator wires the speculative drafter/verifier backends onto the
//! *same* pool, so prefill, decode, verify and draft all draw from one
//! set of threads instead of oversubscribing the host.
//!
//! Dispatches are serialized internally (a second concurrent dispatch
//! waits for the first), and a kernel closure must not dispatch onto
//! the pool it is running on.
//!
//! Every synchronization primitive here comes from [`crate::sync`], so
//! building with `RUSTFLAGS="--cfg loom"` swaps in the instrumented
//! model-checker versions: `rust/tests/loom_pool.rs` explores the whole
//! dispatch protocol (chunk claiming, `done` signaling, panic payload
//! routing, drop/join shutdown) under every bounded interleaving. The
//! `SAFETY:` comments below name the invariant the corresponding model
//! checks; `docs/CONCURRENCY.md` is the prose version.
//!
//! ```
//! use ttq_serve::linalg::pool::WorkerPool;
//!
//! let pool = WorkerPool::new(4);
//! let mut data = vec![0u64; 1024];
//! // flop hint above the floor → the 4 lanes each take a 256-row chunk
//! pool.run_rows(&mut data, 1024, 1, 1 << 20, |r0, rows| {
//!     for (i, v) in rows.iter_mut().enumerate() {
//!         *v = (r0 + i) as u64;
//!     }
//! });
//! assert_eq!(data[777], 777);
//! ```

use crate::obs::{Clock, KernelCall, Profiler, SpanKind, TraceBuffer, TraceEvent, ENGINE_SEQ};
use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::thread::JoinHandle;
use crate::sync::{Arc, Condvar, Mutex, OnceLock, PoisonError};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::time::Instant;

/// Below this flop hint (`m·k·n` for a matmul) the wake/park round-trip
/// costs more than the parallelism saves; [`WorkerPool::run_rows`] runs
/// the kernel inline instead. One floor for every kernel — the decision
/// lives here, not in each call site.
pub const MT_FLOP_FLOOR: usize = 1 << 16;

type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// One posted dispatch: a lifetime-erased task plus the chunk counter
/// workers claim from. The task reference is only ever called while the
/// dispatching `run_rows` frame is alive (it does not return until every
/// worker has finished), which is what makes the erasure sound.
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    n_chunks: usize,
    next: AtomicUsize,
    epoch: u64,
}

struct State {
    job: Option<Arc<Job>>,
    /// Bumped once per dispatch; workers track the last epoch they
    /// served so a job is never double-processed.
    epoch: u64,
    /// Workers still to check in on the current epoch.
    active: usize,
    shutdown: bool,
    /// First panic payload caught in any chunk of the current dispatch.
    panic: Option<PanicPayload>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between dispatches.
    work: Condvar,
    /// The dispatcher parks here until `active` drains to zero.
    done: Condvar,
}

/// Send/Sync wrapper for the output base pointer handed to workers.
struct SendPtr<T>(*mut T);

// SAFETY: `SendPtr` is constructed only inside `run_rows`, and every
// consumer derives its `&mut` window from a chunk index claimed
// *exactly once* from the job's atomic counter — windows of distinct
// chunks are disjoint row ranges of one live `&mut [T]`, so no two
// threads ever hold aliasing `&mut` derived from this pointer. The
// exactly-once claim is checked by the `chunks_claimed_exactly_once`
// loom model and the disjoint-cover property test below; `T: Send`
// keeps the element type itself transferable across threads.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: shared references to the wrapper only expose the raw pointer
// value; all dereferencing goes through the disjoint-window derivation
// argued above.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Poison recovery for the pool's internal locks: a panic can never
/// unwind while one of them is held (kernel panics are caught *outside*
/// the state lock; the gate is dropped before re-throwing), so a
/// poisoned lock only means some *other* thread panicked — the
/// protected state is still consistent and the pool must stay
/// serviceable (the survival contract of this module). Under
/// `--cfg loom` the model mutex never poisons and this is a no-op.
fn relock<T>(r: crate::sync::LockResult<T>) -> T {
    r.unwrap_or_else(PoisonError::into_inner)
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = relock(shared.state.lock());
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen_epoch {
                    if let Some(job) = st.job.clone() {
                        break job;
                    }
                }
                st = relock(shared.work.wait(st));
            }
        };
        seen_epoch = job.epoch;
        loop {
            // Ordering::Relaxed is sufficient for the chunk claim: the
            // RMW is atomic on a single location, which alone guarantees
            // every chunk index is handed out exactly once — no cross-
            // location ordering is needed for uniqueness. Visibility of
            // the *job itself* (task pointer, n_chunks) is established
            // by the state-mutex acquire above, not by this counter.
            // Checked by the `chunks_claimed_exactly_once` loom model
            // (the model runs SeqCst — see `sync::model` docs — so the
            // model proves the protocol and this comment carries the
            // Relaxed-downgrade argument: single-location atomicity).
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.n_chunks {
                break;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| (job.task)(i))) {
                let mut st = relock(shared.state.lock());
                if st.panic.is_none() {
                    st.panic = Some(p);
                }
            }
        }
        drop(job);
        let mut st = relock(shared.state.lock());
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// A fixed set of parked OS threads executing chunked row-range kernels.
/// See the module docs for the design; see
/// [`crate::backend::native::matmul_bt_mt`] for the archetypal caller.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    /// Serializes dispatches from concurrent callers — the job slot is
    /// single-occupancy by design.
    dispatch_gate: Mutex<()>,
    kernel_us: AtomicU64,
    /// Pooled (multi-lane) dispatches posted so far.
    dispatches: AtomicU64,
    /// Observability hook: once attached ([`WorkerPool::attach_trace`]),
    /// every pooled dispatch records a [`SpanKind::Kernel`] span on the
    /// server's trace ring, timed on the server's [`Clock`] so kernel
    /// spans nest consistently inside request spans in the exported
    /// Chrome trace. Unset (the default) costs one `OnceLock::get`.
    trace: OnceLock<(Arc<TraceBuffer>, Clock)>,
    /// Observability hook: once attached
    /// ([`WorkerPool::attach_profiler`]), every site-attributed
    /// dispatch ([`WorkerPool::run_rows_site`], serial or pooled)
    /// accumulates wall time + analytic FLOP/byte counts into the
    /// per-site aggregator. Unset (the default) costs one
    /// `OnceLock::get` per dispatch.
    profiler: OnceLock<Arc<Profiler>>,
    /// Instruction-level dispatch, selected once at construction
    /// ([`crate::linalg::simd::select`]): the ISA every kernel running
    /// on this pool uses for its inner loops, and the label stamped on
    /// each [`crate::obs::KernelSite`].
    isa: crate::linalg::simd::Isa,
}

impl WorkerPool {
    /// Pool with `threads` parallel lanes. The calling thread is lane 0
    /// and always participates in dispatches, so `threads − 1` worker
    /// threads are spawned; `threads <= 1` spawns none and every
    /// dispatch runs inline. Instruction-level dispatch is resolved
    /// here too: [`crate::linalg::simd::select`] picks the widest ISA
    /// the host supports (honoring the `TTQ_FORCE_SCALAR` kill-switch)
    /// once per pool.
    pub fn new(threads: usize) -> Self {
        Self::new_with_isa(threads, crate::linalg::simd::select())
    }

    /// Pool with an explicit [`crate::linalg::simd::Isa`] — the
    /// differential test/bench hook (scalar-reference pools next to
    /// vector-selected pools in one process). The requested ISA is
    /// demoted via [`crate::linalg::simd::Isa::effective`] if the host
    /// cannot run it, so kernels may trust [`WorkerPool::isa`]
    /// unconditionally.
    pub fn new_with_isa(threads: usize, isa: crate::linalg::simd::Isa) -> Self {
        let threads = threads.max(1);
        let isa = isa.effective();
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                epoch: 0,
                active: 0,
                shutdown: false,
                panic: None,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let sh = shared.clone();
                crate::sync::thread::spawn_named(&format!("ttq-pool-{i}"), move || {
                    worker_loop(sh)
                })
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            threads,
            dispatch_gate: Mutex::new(()),
            kernel_us: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            trace: OnceLock::new(),
            profiler: OnceLock::new(),
            isa,
        }
    }

    /// The instruction-level dispatch selected for this pool's kernels
    /// — guaranteed runnable on this host (see
    /// [`WorkerPool::new_with_isa`]).
    pub fn isa(&self) -> crate::linalg::simd::Isa {
        self.isa
    }

    /// Attach a span recorder + clock: from now on every *pooled*
    /// dispatch (serial fallbacks are below the floor by definition and
    /// would flood the ring) records a [`SpanKind::Kernel`] span with
    /// `a` = rows and `b` = lanes. First attachment wins; later calls
    /// are ignored so drafter/verifier backends sharing one pool cannot
    /// re-point it mid-serve.
    pub fn attach_trace(&self, trace: Arc<TraceBuffer>, clock: Clock) {
        let _ = self.trace.set((trace, clock));
    }

    /// Attach a kernel profiler: from now on every
    /// [`WorkerPool::run_rows_site`] dispatch — serial fallback *or*
    /// pooled — accumulates its wall time and the call's analytic
    /// FLOP/byte counts into the per-site aggregator, attributed to the
    /// profiler's current serving [`crate::obs::Phase`] gauge. First
    /// attachment wins (same contract as [`WorkerPool::attach_trace`]),
    /// so drafter/verifier backends sharing one pool cannot re-point it
    /// mid-serve.
    pub fn attach_profiler(&self, profiler: Arc<Profiler>) {
        let _ = self.profiler.set(profiler);
    }

    /// The attached kernel profiler, if any — the coordinator and
    /// `specdec` use this to flip the serving-phase gauge, and
    /// `backend::native` to record the (non-pooled) quant-pack site.
    pub fn profiler(&self) -> Option<&Arc<Profiler>> {
        self.profiler.get()
    }

    /// Pooled dispatches posted so far (serial inline calls excluded).
    pub fn dispatch_count(&self) -> u64 {
        // Relaxed: monotone metrics counter, same argument as kernel_us.
        self.dispatches.load(Ordering::Relaxed)
    }

    /// The hardware-sized lane count: `available_parallelism`, capped
    /// at 16 (beyond that the miniature models' rows don't split
    /// usefully). The single sizing policy — benches and backends both
    /// derive their defaults from here.
    pub fn default_threads() -> usize {
        crate::sync::thread::parallelism().min(16)
    }

    /// Pool sized by [`WorkerPool::default_threads`].
    pub fn with_default_threads() -> Self {
        WorkerPool::new(Self::default_threads())
    }

    /// Parallel lanes (including the dispatching thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Cumulative wall time spent inside dispatches (serial and pooled),
    /// microseconds — the "kernel time" the serving metrics split per
    /// phase. Monotone; callers diff two snapshots.
    pub fn kernel_us(&self) -> u64 {
        // Relaxed: pure monotone metrics counter on a single location —
        // readers only diff snapshots, nothing branches on its value
        // relative to other shared state, so no ordering is required.
        // The `kernel_us_accounting_benign` loom model checks the
        // benign-race claim (no deadlock, no lost protocol signal, sum
        // of contributions observed once the dispatch completes).
        self.kernel_us.load(Ordering::Relaxed)
    }

    /// Run `f` over `rows` logical rows of `data` (each `width`
    /// elements), splitting the row range across the pool's lanes.
    ///
    /// `f(first_row, window)` receives a disjoint contiguous window
    /// `&mut data[first_row*width .. last_row*width]` and must compute
    /// rows independently — that independence is what makes the pooled
    /// result bit-identical to the serial one.
    ///
    /// `flops` is the work hint for the serial/parallel decision: below
    /// [`MT_FLOP_FLOOR`], or when `rows < 2`, or on a single-lane pool,
    /// `f(0, data)` runs inline with zero dispatch overhead.
    ///
    /// Panics from `f` (any chunk, any thread) are re-thrown here after
    /// all chunks drain; the pool itself survives and stays usable.
    pub fn run_rows<T: Send>(
        &self,
        data: &mut [T],
        rows: usize,
        width: usize,
        flops: usize,
        f: impl Fn(usize, &mut [T]) + Sync,
    ) {
        self.run_rows_inner(data, rows, width, flops, None, f);
    }

    /// [`WorkerPool::run_rows`] with kernel-site attribution: `call`
    /// names what this dispatch computes (kind + shape) and carries its
    /// analytic FLOP/byte counts. When a profiler is attached
    /// ([`WorkerPool::attach_profiler`]) the dispatch's wall time —
    /// serial fallback or pooled, the same value that feeds
    /// [`WorkerPool::kernel_us`] — is accumulated into the call's
    /// [`crate::obs::KernelSite`] under the profiler's current phase
    /// gauge. This is the only dispatch surface `backend::native` may
    /// use (repo-lint R7: no unattributed kernels on the serving path).
    pub fn run_rows_site<T: Send>(
        &self,
        data: &mut [T],
        rows: usize,
        width: usize,
        flops: usize,
        call: KernelCall,
        f: impl Fn(usize, &mut [T]) + Sync,
    ) {
        self.run_rows_inner(data, rows, width, flops, Some(call), f);
    }

    fn run_rows_inner<T: Send>(
        &self,
        data: &mut [T],
        rows: usize,
        width: usize,
        flops: usize,
        call: Option<KernelCall>,
        f: impl Fn(usize, &mut [T]) + Sync,
    ) {
        // hard assert: this invariant guards the unsafe disjoint-window
        // derivation below — a violation must never reach release builds
        assert_eq!(data.len(), rows * width, "run_rows shape mismatch");
        let t0 = Instant::now();
        let lanes = self.threads.min(rows);
        if lanes <= 1 || flops < MT_FLOP_FLOOR {
            f(0, data);
        } else {
            let chunk = rows.div_ceil(lanes);
            let n_chunks = rows.div_ceil(chunk);
            let base = SendPtr(data.as_mut_ptr());
            let task = |ci: usize| {
                let r0 = ci * chunk;
                let r1 = (r0 + chunk).min(rows);
                // SAFETY: `base` points at element 0 of a live
                // `&mut [T]` of length `rows*width` (asserted on entry),
                // and `r0 < r1 <= rows`, so the window
                // `[r0*width, r1*width)` is in bounds. Distinct chunk
                // indices yield disjoint windows (the partition covers
                // `0..rows` exactly once — propcheck test
                // `windows_partition_rows_exactly_once` below), and each
                // index is claimed by exactly one thread
                // (`chunks_claimed_exactly_once` loom model), so no two
                // `&mut` windows alias. The underlying exclusive borrow
                // of `data` is pinned by this `run_rows` frame, which
                // does not return until the `done` handshake confirms
                // every chunk has drained.
                let window = unsafe {
                    std::slice::from_raw_parts_mut(base.0.add(r0 * width), (r1 - r0) * width)
                };
                f(r0, window);
            };
            let span_t0 = self
                .trace
                .get()
                .filter(|(t, _)| t.enabled())
                .map(|(_, c)| c.now_us());
            self.dispatch(n_chunks, &task);
            // Relaxed: metrics counter; see `kernel_us`.
            self.dispatches.fetch_add(1, Ordering::Relaxed);
            if let (Some(start), Some((trace, clock))) = (span_t0, self.trace.get()) {
                trace.record(&TraceEvent {
                    kind: SpanKind::Kernel,
                    seq: ENGINE_SEQ,
                    start_us: start,
                    dur_us: clock.now_us().saturating_sub(start),
                    weight_version: 0,
                    a: rows as u64,
                    b: lanes as u64,
                });
            }
        }
        let elapsed_us = t0.elapsed().as_micros() as u64;
        // Relaxed: metrics counter; see `kernel_us` for the argument.
        self.kernel_us.fetch_add(elapsed_us, Ordering::Relaxed);
        // Attribution records the *same* elapsed value kernel_us just
        // accumulated, on both the serial and pooled paths, so per-site
        // wall time sums exactly to kernel_us across attributed calls
        // (the ≥ 90% coverage invariant in `obs::profile`).
        if let (Some(call), Some(prof)) = (call.as_ref(), self.profiler.get()) {
            prof.record(call, elapsed_us);
        }
    }

    /// Post a job, work through chunks on the calling thread alongside
    /// the workers, wait for everyone, and re-throw the first panic.
    fn dispatch(&self, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        debug_assert!(n_chunks > 0);
        // SAFETY: the transmute only erases the borrow lifetime to
        // `'static`; the reference never outlives this call. `dispatch`
        // does not return (and the enclosing `run_rows` frame that owns
        // the real closure stays alive) until every worker has
        // decremented `active` to zero *and* the job has been removed
        // from the state slot, with the dispatcher's own local `Arc`
        // dropped before the gate is released — so every use of the
        // erased reference happens-before the end of the true borrow.
        // The `done_signal_not_missed` loom model checks exactly this:
        // on every interleaving, `active == 0` and `job == None` before
        // `dispatch` returns.
        let task: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        let gate = relock(self.dispatch_gate.lock());
        let job = {
            let mut st = relock(self.shared.state.lock());
            st.epoch += 1;
            st.active = self.handles.len();
            let job = Arc::new(Job {
                task,
                n_chunks,
                next: AtomicUsize::new(0),
                epoch: st.epoch,
            });
            st.job = Some(job.clone());
            job
        };
        self.shared.work.notify_all();
        // lane 0 works too — an idle dispatcher would waste a core
        loop {
            // Relaxed chunk claim: same single-location RMW argument as
            // in `worker_loop` (the comment there is the canonical one).
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= n_chunks {
                break;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| (job.task)(i))) {
                let mut st = relock(self.shared.state.lock());
                if st.panic.is_none() {
                    st.panic = Some(p);
                }
            }
        }
        let mut st = relock(self.shared.state.lock());
        while st.active > 0 {
            st = relock(self.shared.done.wait(st));
        }
        st.job = None;
        let p = st.panic.take();
        drop(st);
        drop(job);
        // release the gate *before* re-throwing: unwinding through a
        // held MutexGuard would poison the gate, and although `relock`
        // recovers from poison, the gate must not even appear held
        // while no dispatch is running (the `panic_payload_propagates`
        // loom model and the `two_panicking_workers_do_not_brick_the_pool`
        // stress test cover the survival contract).
        drop(gate);
        if let Some(p) = p {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = relock(self.shared.state.lock());
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Config};

    /// Big-enough hint to force the pooled path.
    const FORCE: usize = MT_FLOP_FLOOR;

    /// Rounds for the stress loops — shrunk under Miri (interpreter)
    /// and under `--cfg ttq_sanitize` (TSan/ASan builds instrument
    /// every access) so the runs finish while still crossing the
    /// dispatch protocol many times.
    const ROUNDS: u64 = if cfg!(any(miri, ttq_sanitize)) { 20 } else { 1000 };

    #[test]
    fn fills_disjoint_chunks() {
        let pool = WorkerPool::new(4);
        for rows in [1usize, 2, 3, 7, 64, 1000] {
            let mut data = vec![0usize; rows * 3];
            pool.run_rows(&mut data, rows, 3, FORCE, |r0, w| {
                for (i, v) in w.iter_mut().enumerate() {
                    *v = r0 * 3 + i;
                }
            });
            let want: Vec<usize> = (0..rows * 3).collect();
            assert_eq!(data, want, "rows={rows}");
        }
    }

    #[test]
    fn serial_below_floor_matches_pooled() {
        let pool = WorkerPool::new(4);
        let mut a = vec![0u64; 128];
        let mut b = vec![0u64; 128];
        let f = |r0: usize, w: &mut [u64]| {
            for (i, v) in w.iter_mut().enumerate() {
                *v = ((r0 + i) as u64).wrapping_mul(2654435761);
            }
        };
        pool.run_rows(&mut a, 128, 1, 0, f); // below floor → serial
        pool.run_rows(&mut b, 128, 1, FORCE, f); // pooled
        assert_eq!(a, b);
    }

    #[test]
    fn more_threads_than_rows() {
        let pool = WorkerPool::new(8);
        let mut data = vec![0usize; 3 * 2];
        pool.run_rows(&mut data, 3, 2, FORCE, |r0, w| {
            for (i, v) in w.iter_mut().enumerate() {
                *v = r0 * 2 + i + 1;
            }
        });
        assert_eq!(data, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn survives_many_dispatches() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 64];
        for round in 0..ROUNDS {
            pool.run_rows(&mut data, 64, 1, FORCE, |r0, w| {
                for (i, v) in w.iter_mut().enumerate() {
                    *v = (r0 + i) as u64 + round;
                }
            });
        }
        assert_eq!(data[10], 10 + ROUNDS - 1);
        assert!(pool.kernel_us() > 0 || data[0] == ROUNDS - 1);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0usize; 256];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_rows(&mut data, 256, 1, FORCE, |r0, _w| {
                if r0 == 0 {
                    panic!("kernel chunk exploded");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must propagate to the dispatcher");
        // the pool is still serviceable after the panic
        let mut after = vec![0usize; 256];
        pool.run_rows(&mut after, 256, 1, FORCE, |r0, w| {
            for (i, v) in w.iter_mut().enumerate() {
                *v = r0 + i;
            }
        });
        assert_eq!(after[200], 200);
    }

    /// Satellite regression: *every* chunk panics, so multiple workers
    /// (and the dispatcher lane) panic concurrently within one
    /// dispatch. The `done` wait must still drain, only one payload is
    /// re-thrown (the rest are dropped), the gate must not stay
    /// poisoned, and the pool must serve later dispatches — repeated to
    /// catch flaky interleavings.
    #[test]
    fn two_panicking_workers_do_not_brick_the_pool() {
        let pool = WorkerPool::new(4);
        for round in 0..if cfg!(any(miri, ttq_sanitize)) { 3u32 } else { 50 } {
            let mut data = vec![0usize; 256];
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
                pool.run_rows(&mut data, 256, 1, FORCE, |r0, _w| {
                    panic!("chunk {r0} exploded (round {round})");
                });
            }));
            assert!(r.is_err(), "round {round}: panic must propagate");
            // pool usable again immediately after
            let mut after = vec![0usize; 64];
            pool.run_rows(&mut after, 64, 1, FORCE, |r0, w| {
                for (i, v) in w.iter_mut().enumerate() {
                    *v = r0 + i;
                }
            });
            assert_eq!(after[63], 63, "round {round}: pool bricked");
        }
    }

    #[test]
    fn attached_trace_records_kernel_spans() {
        let pool = WorkerPool::new(2);
        let trace = Arc::new(TraceBuffer::new(32));
        pool.attach_trace(trace.clone(), Clock::test(7));
        // first attachment wins — this one must be ignored
        pool.attach_trace(Arc::new(TraceBuffer::disabled()), Clock::real());
        assert_eq!(pool.dispatch_count(), 0);
        let mut data = vec![0u64; 64];
        pool.run_rows(&mut data, 64, 1, FORCE, |r0, w| {
            for (i, v) in w.iter_mut().enumerate() {
                *v = (r0 + i) as u64;
            }
        });
        assert_eq!(pool.dispatch_count(), 1);
        let snap = trace.snapshot();
        assert_eq!(snap.len(), 1, "one pooled dispatch → one kernel span");
        let e = &snap[0];
        assert_eq!(e.kind, SpanKind::Kernel);
        assert_eq!(e.seq, ENGINE_SEQ);
        assert_eq!((e.a, e.b), (64, 2), "a = rows, b = lanes");
        assert!(e.dur_us >= 7, "test clock ticks under the span");
        // serial fallback (below the floor) records nothing
        pool.run_rows(&mut data, 64, 1, 0, |_r0, _w| {});
        assert_eq!(pool.dispatch_count(), 1);
        assert_eq!(trace.snapshot().len(), 1);
    }

    #[test]
    fn attached_profiler_attributes_serial_and_pooled() {
        use crate::obs::profile::Phase;
        let pool = WorkerPool::new(2);
        let prof = Arc::new(Profiler::new());
        pool.attach_profiler(prof.clone());
        // first attachment wins — this one must be ignored
        pool.attach_profiler(Arc::new(Profiler::new()));
        assert!(pool.profiler().is_some());
        prof.set_phase(Phase::Decode);
        let k0 = pool.kernel_us();
        let mut data = vec![0.0f32; 64];
        // pooled (hint at the floor) and serial (hint 0) dispatches,
        // both attributed; plus one unattributed run_rows.
        pool.run_rows_site(&mut data, 64, 1, FORCE, KernelCall::fp32_gemm(64, 64, 64), |_r, w| {
            for v in w.iter_mut() {
                *v += 1.0;
            }
        });
        pool.run_rows_site(&mut data, 64, 1, 0, KernelCall::fp32_gemm(1, 64, 64), |_r, w| {
            for v in w.iter_mut() {
                *v += 1.0;
            }
        });
        pool.run_rows(&mut data, 64, 1, 0, |_r, w| {
            for v in w.iter_mut() {
                *v += 1.0;
            }
        });
        assert_eq!(data[0], 3.0);
        let snap = prof.snapshot();
        assert_eq!(snap.len(), 2, "two shapes → two decode sites");
        let calls: u64 = snap.iter().map(|s| s.calls).sum();
        assert_eq!(calls, 2, "unattributed run_rows records no site");
        let attributed: u64 = snap.iter().map(|s| s.wall_us).sum();
        assert!(
            attributed <= pool.kernel_us() - k0,
            "site wall time ({attributed}) cannot exceed kernel_us ({})",
            pool.kernel_us() - k0
        );
        for s in &snap {
            assert_eq!(s.site.phase, Phase::Decode);
        }
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut data = vec![0usize; 8];
        pool.run_rows(&mut data, 8, 1, FORCE, |r0, w| {
            for (i, v) in w.iter_mut().enumerate() {
                *v = r0 + i;
            }
        });
        assert_eq!(data[7], 7);
    }

    #[test]
    fn kernel_time_accumulates() {
        let pool = WorkerPool::new(2);
        let before = pool.kernel_us();
        let mut data = vec![0.0f32; 1 << 12];
        for _ in 0..if cfg!(any(miri, ttq_sanitize)) { 5 } else { 50 } {
            pool.run_rows(&mut data, 1 << 12, 1, FORCE, |_r0, w| {
                for v in w.iter_mut() {
                    *v += 1.0;
                }
            });
        }
        assert!(pool.kernel_us() >= before);
        assert_eq!(data[0], if cfg!(any(miri, ttq_sanitize)) { 5.0 } else { 50.0 });
    }

    /// Satellite: the disjoint-window partition covers `0..rows`
    /// exactly once for adversarial shapes — rows = 0, rows = 1,
    /// rows < threads, non-divisible splits. Runs the real `run_rows`
    /// (not a re-derivation of its math) and counts per-row visits, so
    /// under Miri this also proves the `SendPtr` + `from_raw_parts_mut`
    /// window derivation is UB-free on exactly these shapes.
    #[test]
    fn windows_partition_rows_exactly_once() {
        let cfg = Config {
            cases: if cfg!(any(miri, ttq_sanitize)) { 6 } else { 48 },
            seed: 0x9001,
        };
        check("run_rows partition covers 0..rows exactly once", &cfg, |g| {
            let threads = g.usize_in(1, if cfg!(any(miri, ttq_sanitize)) { 3 } else { 8 });
            let rows = *g.choose(&[0usize, 1, 2, 3, 5, 7, 16, 33, 100]);
            let width = g.usize_in(1, 3);
            let pool = WorkerPool::new(threads);
            let mut data = vec![0u32; rows * width];
            // force the pooled path whenever it is reachable
            pool.run_rows(&mut data, rows, width, FORCE, |_r0, w| {
                for v in w.iter_mut() {
                    *v += 1;
                }
            });
            for (i, v) in data.iter().enumerate() {
                crate::prop_assert!(
                    *v == 1,
                    "cell {i} visited {v} times (rows={rows} width={width} threads={threads})"
                );
            }
            Ok(())
        });
    }

    /// Miri-focused smoke at the smallest multi-chunk shape: 2 lanes,
    /// 2 chunks, width 2 — the minimal case where the `'static`
    /// transmute and both `SendPtr` windows are live on two threads at
    /// once. Miri validates the raw-pointer arithmetic and the absence
    /// of aliasing `&mut` on exactly this path.
    #[test]
    fn miri_minimal_two_lane_dispatch() {
        let pool = WorkerPool::new(2);
        let mut data = vec![0usize; 4 * 2];
        pool.run_rows(&mut data, 4, 2, FORCE, |r0, w| {
            for (i, v) in w.iter_mut().enumerate() {
                *v = (r0 * 2 + i) * 10;
            }
        });
        let want: Vec<usize> = (0..8).map(|i| i * 10).collect();
        assert_eq!(data, want);
    }
}
