//! Deterministic PRNG: SplitMix64 counter mode + Box–Muller normals.
//!
//! The same SplitMix64 core drives the corpus engine (where it must be
//! bit-identical to `python/compile/corpus.py`); here it additionally
//! powers reproducible random matrices for tests and benches.

#![forbid(unsafe_code)]

/// SplitMix64 finalizer — the shared hash with the python corpus engine.
#[inline]
pub fn splitmix64(z: u64) -> u64 {
    let mut z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Counter-based generator: stateless jumps, O(1) seeking.
#[derive(Clone, Debug)]
pub struct Rng {
    seed: u64,
    ctr: u64,
    spare_normal: Option<f64>,
}

impl Rng {
    /// Generator seeded deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        Rng { seed: splitmix64(seed), ctr: 0, spare_normal: None }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.ctr += 1;
        splitmix64(self.seed.wrapping_add(self.ctr))
    }

    /// Uniform in [0, 1) with 53-bit resolution (same mapping as python).
    #[inline]
    pub fn u01(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Simple modulo; bias is negligible for n << 2^64 as used here.
        self.next_u64() % n
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.u01();
            let u2 = self.u01();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Log-normal with the given mu/sigma (activation-outlier modelling).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn u01_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.u01();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn splitmix_known_vector() {
        // First output of the reference SplitMix64 stream from seed 0:
        // matches the widely-published value.
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
    }
}
