//! Runtime-dispatched SIMD microkernels for the two hot inner loops.
//!
//! The worker pool (PR 5) solved *thread-level* dispatch; this module
//! is the *instruction-level* half: the per-element arithmetic of
//! [`crate::backend::native::matmul_bt_mt`] (fp32 tile dots) and
//! [`crate::backend::native::packed_matmul_nt`] (per-group W4 dequant
//! folded into the dot — nibble unpack → widen → scale/zero-point
//! multiply-accumulate, the llama.cpp quantized-dot shape) runs on the
//! widest vector unit the host actually has.
//!
//! ## ISA selection
//!
//! [`select`] picks one [`Isa`] per process section, at
//! [`crate::linalg::pool::WorkerPool`] construction:
//!
//! * `TTQ_FORCE_SCALAR` (any value except `0`/empty) — kill-switch,
//!   always scalar; the CI matrix runs the whole suite under it.
//! * Miri — scalar (vendor intrinsics are Miri-hostile; see
//!   `docs/CONCURRENCY.md`).
//! * x86-64 — AVX2 when `is_x86_feature_detected!` confirms it.
//! * aarch64 — NEON (architecturally mandatory, still detected).
//! * anything else — the scalar fallback, which is also the reference
//!   implementation the differential suite (`rust/tests/simd_kernels.rs`)
//!   compares every vector path against.
//!
//! ## The numerics contract
//!
//! * **W4 is bit-exact across ISAs.** [`w4_dequant_group`] computes
//!   every element as `code as f32 * scale + zero` (exact integer
//!   widening, one elementwise multiply, one elementwise add — the
//!   identical IEEE roundings in scalar and vector form), and
//!   [`w4_dot`] accumulates in a *canonical 8-virtual-lane order*:
//!   lane `l` sums the terms at indices `≡ l (mod 8)` in index order
//!   (one 8-lane register on AVX2, two 4-lane registers on NEON, an
//!   array of 8 accumulators in scalar form), multiply and add kept as
//!   separate rounds (no FMA), tails folded into lane `j mod 8`, and
//!   one fixed reduction tree (`reduce8`). Every ISA therefore
//!   produces the same bits, asserted by the differential suite.
//! * **fp32 is relaxed to a documented ULP bound.** [`dot_f32`]'s
//!   scalar path keeps the historical strictly-sequential accumulation
//!   (so forced-scalar output is byte-identical to every release before
//!   this module existed), while the vector paths accumulate 8 (AVX2)
//!   or 4 (NEON) partials and reduce at the tile end — a different,
//!   usually *more* accurate summation order. Cross-ISA agreement is
//!   bounded by [`crate::util::FP32_MAX_ULPS`] /
//!   [`crate::util::FP32_ABS_TOL`] (one definition, referenced by every
//!   suite that relaxes from bit-identity).
//!
//! `unsafe` lives only here and is confined by repo-lint **R8** (plus
//! the R2 allowlist): every block is a call to a `#[target_feature]`
//! kernel guarded by [`Isa::effective`], which demotes any ISA the
//! running host has not proven to scalar before dispatch.

use crate::quant::{unpack_at, Packed};

/// Instruction-set architecture of the selected microkernel path.
///
/// Carried by [`crate::linalg::pool::WorkerPool::isa`] into every
/// kernel and stamped on each [`crate::obs::KernelSite`] so roofline
/// verdicts distinguish scalar from vector dispatches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Isa {
    /// Portable scalar fallback — also the differential reference.
    Scalar,
    /// x86-64 AVX2: 8 × f32 lanes.
    Avx2,
    /// aarch64 NEON: 4 × f32 lanes (8 virtual lanes for W4 exactness).
    Neon,
}

impl Isa {
    /// Stable lowercase name used in site labels and exporters.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// f32 lanes per vector register (1 for scalar) — the factor the
    /// roofline compute ceiling scales by
    /// ([`crate::perfmodel::vector_ceiling_gflops`]).
    pub fn lanes(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 8,
            Isa::Neon => 4,
        }
    }

    /// Dense encoding for the [`crate::obs::KernelSite`] key (2 bits).
    pub fn index(self) -> u64 {
        match self {
            Isa::Scalar => 0,
            Isa::Avx2 => 1,
            Isa::Neon => 2,
        }
    }

    /// Inverse of [`Isa::index`]; unknown values decode to scalar.
    pub fn from_index(v: u64) -> Isa {
        match v & 0x3 {
            1 => Isa::Avx2,
            2 => Isa::Neon,
            _ => Isa::Scalar,
        }
    }

    /// Whether the running host can execute this path. Always true for
    /// scalar; vector ISAs require the matching architecture, runtime
    /// feature detection, and a non-Miri build.
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            Isa::Avx2 => {
                #[cfg(all(target_arch = "x86_64", not(miri)))]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(all(target_arch = "x86_64", not(miri))))]
                {
                    false
                }
            }
            Isa::Neon => {
                #[cfg(all(target_arch = "aarch64", not(miri)))]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(all(target_arch = "aarch64", not(miri))))]
                {
                    false
                }
            }
        }
    }

    /// Demote to [`Isa::Scalar`] when the host cannot run this path —
    /// the safety gate every kernel dispatch goes through (so a forced
    /// or stale `Isa` value can never reach an unsupported intrinsic).
    pub fn effective(self) -> Isa {
        if self.available() {
            self
        } else {
            Isa::Scalar
        }
    }
}

/// Parse rule for the kill-switch value: engaged unless unset, empty
/// or `0`. Split out so the contract has a direct unit test (tests run
/// concurrently, so mutating the real process env is off-limits).
fn force_scalar_value(v: Option<&str>) -> bool {
    match v {
        Some(v) => !(v.is_empty() || v == "0"),
        None => false,
    }
}

/// True when the `TTQ_FORCE_SCALAR` kill-switch is engaged (set to
/// anything except empty or `0`).
pub fn force_scalar() -> bool {
    force_scalar_value(std::env::var("TTQ_FORCE_SCALAR").ok().as_deref())
}

/// Select the widest available ISA for this host, honoring the
/// `TTQ_FORCE_SCALAR` kill-switch. Called once per
/// [`crate::linalg::pool::WorkerPool`] construction; the result is
/// stored on the pool so every kernel in a serving section dispatches
/// consistently.
pub fn select() -> Isa {
    if force_scalar() {
        return Isa::Scalar;
    }
    if Isa::Avx2.available() {
        return Isa::Avx2;
    }
    if Isa::Neon.available() {
        return Isa::Neon;
    }
    Isa::Scalar
}

/// The fixed horizontal-reduction tree shared by every 8-virtual-lane
/// accumulator (scalar array, AVX2 register extract, NEON pair
/// extract): pairwise over a stride of 4, then 2, then 1. One
/// definition so the W4 bit-exactness contract cannot drift.
#[inline]
fn reduce8(l: [f32; 8]) -> f32 {
    ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
}

// ---------------------------------------------------------------------
// fp32 dot (relaxed contract: cross-ISA within the documented ULP bound)
// ---------------------------------------------------------------------

/// Strictly-sequential scalar dot — byte-identical to the pre-SIMD
/// kernels' inner loop, and the reference side of the differential
/// fp32 suite.
#[inline]
fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (av, bv) in a.iter().zip(b) {
        acc += av * bv;
    }
    acc
}

/// `Σ a[i]·b[i]` over one tile, on the given ISA. Scalar is strictly
/// sequential; vector paths accumulate per-lane partials and reduce at
/// the end — results agree within [`crate::util::FP32_MAX_ULPS`] /
/// [`crate::util::FP32_ABS_TOL`] (the module-level numerics contract).
#[inline]
pub fn dot_f32(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot_f32 length mismatch");
    match isa.effective() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `effective` returns Avx2 only after
        // `is_x86_feature_detected!("avx2")` confirmed the host supports
        // every intrinsic the target_feature kernel uses.
        Isa::Avx2 => unsafe { x86::dot_f32_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `effective` returns Neon only after runtime detection
        // confirmed NEON on this host.
        Isa::Neon => unsafe { arm::dot_f32_neon(a, b) },
        _ => dot_f32_scalar(a, b),
    }
}

// ---------------------------------------------------------------------
// W4 group dequant + dot (exact contract: bit-identical across ISAs)
// ---------------------------------------------------------------------

/// Canonical 8-virtual-lane dot: lane `l` accumulates the terms at
/// indices `≡ l (mod 8)` in index order, multiply and add as separate
/// IEEE roundings, reduced by [`reduce8`]. The scalar realization of
/// the order every vector path reproduces exactly.
#[inline]
fn w4_dot_scalar(w: &[f32], x: &[f32]) -> f32 {
    let mut lanes = [0.0f32; 8];
    for (j, (wv, xv)) in w.iter().zip(x).enumerate() {
        lanes[j & 7] += wv * xv;
    }
    reduce8(lanes)
}

/// Dequantized-weight-group × activation-slice dot product, bit-exact
/// across every ISA (the canonical-lane contract in the module docs).
/// `w` is one dequantized group from [`w4_dequant_group`]; `x` the
/// matching activation slice.
#[inline]
pub fn w4_dot(isa: Isa, w: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len(), "w4_dot length mismatch");
    match isa.effective() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 survives `effective` only on a detected-AVX2 host.
        Isa::Avx2 => unsafe { x86::w4_dot_avx2(w, x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon survives `effective` only on a detected-NEON host.
        Isa::Neon => unsafe { arm::w4_dot_neon(w, x) },
        _ => w4_dot_scalar(w, x),
    }
}

/// Scalar group dequant — the exact per-element expression
/// (`code as f32 * scale + zero`, via [`unpack_at`]) the vector unpack
/// reproduces.
#[inline]
fn w4_dequant_scalar(p: &Packed, base: usize, scale: f32, zero: f32, out: &mut [f32]) {
    for (j, w) in out.iter_mut().enumerate() {
        *w = unpack_at(p, base + j) as f32 * scale + zero;
    }
}

/// Whether the vectorized nibble unpack applies: 4-bit codes, a group
/// starting on a `u32`-word boundary, and a whole number of 8-code
/// words — every `quant::pack` group with `group % 8 == 0` qualifies.
#[inline]
fn w4_unpack_vectorizable(p: &Packed, base: usize, len: usize) -> bool {
    p.bits == 4 && (base * 4) % 32 == 0 && len % 8 == 0
}

/// Dequantize one weight group (`out.len()` codes starting at flat
/// code index `base`) as `code as f32 * scale + zero`.
///
/// Bit-exact across ISAs for every bit width: integer code extraction
/// is exact, and the elementwise multiply/add round identically in
/// scalar and vector registers. The AVX2/NEON paths vectorize the
/// common case (4-bit codes on word-aligned groups — nibble unpack by
/// per-lane shift/mask, then widen); everything else takes the scalar
/// expression, which is the same function of the same inputs.
#[inline]
pub fn w4_dequant_group(isa: Isa, p: &Packed, base: usize, scale: f32, zero: f32, out: &mut [f32]) {
    if !w4_unpack_vectorizable(p, base, out.len()) {
        w4_dequant_scalar(p, base, scale, zero, out);
        return;
    }
    match isa.effective() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 survives `effective` only on a detected-AVX2 host;
        // `w4_unpack_vectorizable` guarantees whole aligned words.
        Isa::Avx2 => unsafe { x86::w4_dequant_avx2(&p.words[(base * 4) / 32..], scale, zero, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon survives `effective` only on a detected-NEON host;
        // alignment guaranteed as above.
        Isa::Neon => unsafe { arm::w4_dequant_neon(&p.words[(base * 4) / 32..], scale, zero, out) },
        _ => w4_dequant_scalar(p, base, scale, zero, out),
    }
}

// ---------------------------------------------------------------------
// AVX2 kernels (x86-64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::reduce8;
    use core::arch::x86_64::*;

    /// 8-lane fp32 dot: vector main loop, sequential scalar tail.
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2 (enforced by
    /// [`super::Isa::effective`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_ps();
        let mut j = 0usize;
        while j + 8 <= n {
            let av = _mm256_loadu_ps(a.as_ptr().add(j));
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            // mul + add kept separate: same per-element roundings as the
            // scalar expression (the W4 contract; harmless here).
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
            j += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut tail = 0.0f32;
        while j < n {
            tail += a[j] * b[j];
            j += 1;
        }
        reduce8(lanes) + tail
    }

    /// Canonical-lane W4 dot — bit-identical to the scalar 8-lane form.
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn w4_dot_avx2(w: &[f32], x: &[f32]) -> f32 {
        let n = w.len().min(x.len());
        let mut acc = _mm256_setzero_ps();
        let mut j = 0usize;
        while j + 8 <= n {
            let wv = _mm256_loadu_ps(w.as_ptr().add(j));
            let xv = _mm256_loadu_ps(x.as_ptr().add(j));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, xv));
            j += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        // Tail terms continue the canonical order: index j lands in
        // lane j mod 8, exactly as the scalar realization does.
        while j < n {
            lanes[j & 7] += w[j] * x[j];
            j += 1;
        }
        reduce8(lanes)
    }

    /// Vectorized 4-bit unpack + dequant over whole aligned words:
    /// each `u32` word holds 8 little-endian nibbles; a per-lane
    /// variable shift + mask extracts them in index order, integer→f32
    /// widening is exact, and `w·scale + zero` rounds per element
    /// exactly like the scalar expression.
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2, and
    /// `out.len() % 8 == 0` with `words.len() >= out.len() / 8`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn w4_dequant_avx2(words: &[u32], scale: f32, zero: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len() % 8, 0);
        debug_assert!(words.len() >= out.len() / 8);
        let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
        let mask = _mm256_set1_epi32(0xF);
        let vs = _mm256_set1_ps(scale);
        let vz = _mm256_set1_ps(zero);
        for (wi, chunk) in out.chunks_exact_mut(8).enumerate() {
            let word = _mm256_set1_epi32(words[wi] as i32);
            let codes = _mm256_and_si256(_mm256_srlv_epi32(word, shifts), mask);
            let wf = _mm256_cvtepi32_ps(codes);
            let dq = _mm256_add_ps(_mm256_mul_ps(wf, vs), vz);
            _mm256_storeu_ps(chunk.as_mut_ptr(), dq);
        }
    }
}

// ---------------------------------------------------------------------
// NEON kernels (aarch64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::reduce8;
    use core::arch::aarch64::*;

    /// 4-lane fp32 dot: vector main loop, sequential scalar tail.
    ///
    /// # Safety
    /// Caller must guarantee the host supports NEON (enforced by
    /// [`super::Isa::effective`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_f32(0.0);
        let mut j = 0usize;
        while j + 4 <= n {
            let av = vld1q_f32(a.as_ptr().add(j));
            let bv = vld1q_f32(b.as_ptr().add(j));
            acc = vaddq_f32(acc, vmulq_f32(av, bv));
            j += 4;
        }
        let mut s = vaddvq_f32(acc);
        while j < n {
            s += a[j] * b[j];
            j += 1;
        }
        s
    }

    /// Canonical-lane W4 dot on two 4-lane registers (virtual lanes
    /// 0–3 and 4–7) — bit-identical to the scalar 8-lane form.
    ///
    /// # Safety
    /// Caller must guarantee the host supports NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn w4_dot_neon(w: &[f32], x: &[f32]) -> f32 {
        let n = w.len().min(x.len());
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut j = 0usize;
        while j + 8 <= n {
            let w0 = vld1q_f32(w.as_ptr().add(j));
            let x0 = vld1q_f32(x.as_ptr().add(j));
            let w1 = vld1q_f32(w.as_ptr().add(j + 4));
            let x1 = vld1q_f32(x.as_ptr().add(j + 4));
            // mul + add as separate roundings — never vfmaq: FMA's
            // single rounding would break cross-ISA bit-exactness.
            lo = vaddq_f32(lo, vmulq_f32(w0, x0));
            hi = vaddq_f32(hi, vmulq_f32(w1, x1));
            j += 8;
        }
        let mut lanes = [0.0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        while j < n {
            lanes[j & 7] += w[j] * x[j];
            j += 1;
        }
        reduce8(lanes)
    }

    /// Vectorized 4-bit unpack + dequant over whole aligned words; see
    /// the AVX2 twin for the exactness argument.
    ///
    /// # Safety
    /// Caller must guarantee the host supports NEON, and
    /// `out.len() % 8 == 0` with `words.len() >= out.len() / 8`.
    #[target_feature(enable = "neon")]
    pub unsafe fn w4_dequant_neon(words: &[u32], scale: f32, zero: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len() % 8, 0);
        debug_assert!(words.len() >= out.len() / 8);
        // Right shifts via vshlq with negative per-lane shift counts.
        let sh_lo = vld1q_s32([0i32, -4, -8, -12].as_ptr());
        let sh_hi = vld1q_s32([-16i32, -20, -24, -28].as_ptr());
        let mask = vdupq_n_u32(0xF);
        let vs = vdupq_n_f32(scale);
        let vz = vdupq_n_f32(zero);
        for (wi, chunk) in out.chunks_exact_mut(8).enumerate() {
            let word = vdupq_n_u32(words[wi]);
            let lo = vandq_u32(vshlq_u32(word, sh_lo), mask);
            let hi = vandq_u32(vshlq_u32(word, sh_hi), mask);
            let dq_lo = vaddq_f32(vmulq_f32(vcvtq_f32_u32(lo), vs), vz);
            let dq_hi = vaddq_f32(vmulq_f32(vcvtq_f32_u32(hi), vs), vz);
            vst1q_f32(chunk.as_mut_ptr(), dq_lo);
            vst1q_f32(chunk.as_mut_ptr().add(4), dq_hi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Mat, Rng};
    use crate::quant::{pack, rtn_quantize_int, QuantSpec};
    use crate::util::{fp32_close, ulp_diff};

    #[test]
    fn isa_names_lanes_and_index_roundtrip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert_eq!(Isa::from_index(isa.index()), isa);
            assert!(!isa.name().is_empty());
            assert!(isa.lanes() >= 1);
        }
        assert_eq!(Isa::Scalar.lanes(), 1);
        assert_eq!(Isa::Avx2.lanes(), 8);
        assert_eq!(Isa::Neon.lanes(), 4);
        // Unknown indices demote to scalar rather than panicking.
        assert_eq!(Isa::from_index(3), Isa::Scalar);
    }

    #[test]
    fn scalar_always_available_and_effective_demotes() {
        assert!(Isa::Scalar.available());
        for isa in [Isa::Avx2, Isa::Neon] {
            let eff = isa.effective();
            assert!(eff == isa || eff == Isa::Scalar);
            assert!(eff.available());
        }
        // select() must return something the host can actually run.
        assert!(select().available());
    }

    #[test]
    fn dot_f32_matches_sequential_reference() {
        let mut rng = Rng::new(7);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 255, 256, 257] {
            let a = Mat::randn(1, n.max(1), &mut rng).data[..n].to_vec();
            let b = Mat::randn(1, n.max(1), &mut rng).data[..n].to_vec();
            let want = dot_f32_scalar(&a, &b);
            assert_eq!(dot_f32(Isa::Scalar, &a, &b), want, "scalar path must be sequential");
            let got = dot_f32(select(), &a, &b);
            assert!(
                fp32_close(got, want),
                "n={n}: vector dot {got} vs scalar {want} ({} ulps)",
                ulp_diff(got, want)
            );
        }
    }

    #[test]
    fn w4_dot_bit_exact_across_selected_isa() {
        let mut rng = Rng::new(8);
        for n in [1usize, 5, 8, 16, 23, 32, 48, 100, 128] {
            let w = Mat::randn(1, n, &mut rng).data;
            let x = Mat::randn(1, n, &mut rng).data;
            let want = w4_dot(Isa::Scalar, &w, &x);
            let got = w4_dot(select(), &w, &x);
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}: W4 dot must be bit-exact");
        }
    }

    #[test]
    fn w4_dequant_group_matches_unpack_at_for_all_widths() {
        let mut rng = Rng::new(9);
        let w = Mat::randn(6, 96, &mut rng);
        for bits in [2u32, 3, 4, 5, 8] {
            for group in [16usize, 32, 48, 96] {
                let qi = rtn_quantize_int(&w, &QuantSpec::new(bits, group));
                let p = pack(&qi);
                if p.cols % p.group != 0 {
                    continue;
                }
                let groups_per_row = p.cols / p.group;
                let mut buf = vec![0.0f32; p.group];
                for gi in 0..p.rows * groups_per_row {
                    let (s, z) = (p.scales[gi], p.zeros[gi]);
                    w4_dequant_group(select(), &p, gi * p.group, s, z, &mut buf);
                    for (j, &got) in buf.iter().enumerate() {
                        let want = unpack_at(&p, gi * p.group + j) as f32 * s + z;
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "bits={bits} group={group} gi={gi} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn force_scalar_parse_rule() {
        assert!(!force_scalar_value(None));
        assert!(!force_scalar_value(Some("")));
        assert!(!force_scalar_value(Some("0")));
        assert!(force_scalar_value(Some("1")));
        assert!(force_scalar_value(Some("yes")));
        // And under the live environment, select() honors the switch.
        if force_scalar() {
            assert_eq!(select(), Isa::Scalar);
        }
    }
}
