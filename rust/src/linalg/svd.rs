//! Truncated SVD via randomized subspace iteration.
//!
//! Powers the low-rank factor initialization of paper App. E
//! (Eq. 31-33): B = U_r Λ_r^{1/2}, A = Λ_r^{1/2} V_r. Subspace iteration
//! with re-orthonormalization converges geometrically in the spectral
//! gap; the paper needs only small r (≤ 32), so this is exact enough —
//! tests compare against loss reduction rather than bit equality.

#![forbid(unsafe_code)]

use super::{Mat, Rng};

/// Truncated factorization W ≈ U diag(s) Vᵀ with r columns.
pub struct Svd {
    /// Left singular vectors, `(m, r)`.
    pub u: Mat,
    /// Singular values, length r.
    pub s: Vec<f32>,
    /// Right singular vectors, `(r, n)`.
    pub vt: Mat,
}

/// Modified Gram–Schmidt orthonormalization of the columns of `q` (in
/// place), with rank detection: a column whose residual after
/// projection is tiny *relative to its original norm* is linearly
/// dependent — normalizing it would amplify f32 noise into a wildly
/// non-orthogonal direction — so it is zeroed instead. Two projection
/// passes ("twice is enough") keep orthogonality at f32 precision.
fn orthonormalize(q: &mut Mat) {
    let (m, r) = (q.rows, q.cols);
    for j in 0..r {
        let mut pre = 0.0f64;
        for i in 0..m {
            pre += (q.at(i, j) as f64).powi(2);
        }
        let pre = pre.sqrt();
        for _pass in 0..2 {
            for k in 0..j {
                let mut dot = 0.0f64;
                for i in 0..m {
                    dot += q.at(i, k) as f64 * q.at(i, j) as f64;
                }
                for i in 0..m {
                    *q.at_mut(i, j) -= (dot as f32) * q.at(i, k);
                }
            }
        }
        let mut nrm = 0.0f64;
        for i in 0..m {
            nrm += (q.at(i, j) as f64).powi(2);
        }
        let nrm = nrm.sqrt();
        if nrm < 1e-5 * pre.max(1e-30) || nrm < 1e-20 {
            for i in 0..m {
                *q.at_mut(i, j) = 0.0;
            }
        } else {
            let inv = (1.0 / nrm) as f32;
            for i in 0..m {
                *q.at_mut(i, j) *= inv;
            }
        }
    }
}

/// Randomized subspace iteration (Halko-style, fixed seed).
pub fn truncated_svd(w: &Mat, r: usize, iters: usize) -> Svd {
    let (m, n) = (w.rows, w.cols);
    let r = r.min(m).min(n);
    let mut rng = Rng::new(0x5EED_57D0);
    // oversample for accuracy, trim at the end
    let k = (r + 8).min(m).min(n);
    let mut q = Mat::randn(m, k, &mut rng);
    orthonormalize(&mut q);
    for _ in 0..iters.max(2) {
        // q <- orth(W Wᵀ q)
        let wtq = w.transpose().matmul(&q); // (n, k)
        let mut wq = w.matmul(&wtq); // (m, k)
        orthonormalize(&mut wq);
        q = wq;
    }
    // small projected problem: Bs = Qᵀ W  (k, n); SVD of Bs via its Gram.
    let bs = q.transpose().matmul(w); // (k, n)
    // eigendecomposition of Bs Bsᵀ (k×k) by Jacobi
    let g = bs.matmul_bt(&bs); // (k, k)
    let (evals, evecs) = jacobi_eigh(&g);
    // sort descending
    let mut idx: Vec<usize> = (0..evals.len()).collect();
    idx.sort_by(|&a, &b| evals[b].partial_cmp(&evals[a]).unwrap());
    let mut u = Mat::zeros(m, r);
    let mut s = vec![0.0f32; r];
    let mut vt = Mat::zeros(r, n);
    for (out_j, &j) in idx.iter().take(r).enumerate() {
        let sv = evals[j].max(0.0).sqrt();
        s[out_j] = sv as f32;
        // u column = Q * evec_j
        for i in 0..m {
            let mut acc = 0.0f64;
            for t in 0..g.rows {
                acc += q.at(i, t) as f64 * evecs.at(t, j) as f64;
            }
            *u.at_mut(i, out_j) = acc as f32;
        }
        // vt row = (uᵀ W) / s
        if sv > 1e-12 {
            for c in 0..n {
                let mut acc = 0.0f64;
                for i in 0..m {
                    acc += u.at(i, out_j) as f64 * w.at(i, c) as f64;
                }
                *vt.at_mut(out_j, c) = (acc / sv) as f32;
            }
        }
    }
    Svd { u, s, vt }
}

/// Cyclic Jacobi eigendecomposition for small symmetric matrices.
/// Returns (eigenvalues, eigenvector columns).
fn jacobi_eigh(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows;
    let mut m: Vec<f64> = a.data.iter().map(|v| *v as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-30 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let evals: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    let evecs = Mat::from_vec(n, n, v.into_iter().map(|x| x as f32).collect());
    (evals, evecs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lowrank_matrix(m: usize, n: usize, true_r: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let b = Mat::randn(m, true_r, &mut rng);
        let a = Mat::randn(true_r, n, &mut rng);
        b.matmul(&a)
    }

    #[test]
    fn recovers_exact_low_rank() {
        let w = lowrank_matrix(24, 40, 3, 1);
        let svd = truncated_svd(&w, 3, 6);
        let rec = svd
            .u
            .scale_cols(&svd.s)
            .matmul(&svd.vt);
        let rel = w.sub(&rec).frob_sq() / w.frob_sq();
        assert!(rel < 1e-6, "relative error {rel}");
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(16, 32, &mut rng);
        let svd = truncated_svd(&w, 8, 8);
        for pair in svd.s.windows(2) {
            assert!(pair[0] >= pair[1] - 1e-5);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(20, 20, &mut rng);
        let svd = truncated_svd(&w, 5, 8);
        for i in 0..5 {
            for j in 0..5 {
                let mut dot = 0.0f64;
                for k in 0..20 {
                    dot += svd.u.at(k, i) as f64 * svd.u.at(k, j) as f64;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-3, "u[{i}]·u[{j}] = {dot}");
            }
        }
    }

    #[test]
    fn truncation_beats_nothing() {
        // rank-4 approx of a full-rank matrix must capture energy
        let mut rng = Rng::new(4);
        let w = Mat::randn(16, 16, &mut rng);
        let svd = truncated_svd(&w, 4, 8);
        let rec = svd.u.scale_cols(&svd.s).matmul(&svd.vt);
        assert!(w.sub(&rec).frob_sq() < w.frob_sq());
    }

    #[test]
    fn jacobi_matches_known_eigenvalues() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (mut evals, _) = jacobi_eigh(&a);
        evals.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((evals[0] - 1.0).abs() < 1e-8);
        assert!((evals[1] - 3.0).abs() < 1e-8);
    }
}
