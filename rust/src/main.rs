//! ttq-serve — CLI for the TTQ reproduction.
//!
//! Subcommands map 1:1 onto the paper's exhibits plus the serving loop.
//! Methods everywhere are registry spec strings (see `ttq-serve help`):
//!
//! ```text
//! ttq-serve eval --model qwen-mini --method ttq:r=16 --bits 3
//! ttq-serve table <1|2|3|4|5|6|7|8|12|13> [--fast] [--models ...]
//!                 [--methods rtn awq ttq:r=16 gptq nf:4 prune:0.5]
//! ttq-serve figure2 [--fast]
//! ttq-serve sweep <formats|lowrank-init|nf|prune>
//! ttq-serve serve --model qwen-micro --requests 64 [--method M] [--bits Q]
//! ttq-serve info
//! ```
//!
//! Every forward-pass command accepts `--backend {pjrt,native}`. The
//! default is `pjrt` when `make artifacts` has been run and `native`
//! otherwise — the native backend executes a pure-Rust forward pass and
//! falls back to deterministic synthetic models, so the whole CLI works
//! on a bare Rust toolchain (untrained weights: pipeline-shape numbers,
//! not paper numbers).

#![forbid(unsafe_code)]

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use ttq_serve::backend::{ExecBackend, NativeBackend, PjrtBackend};
use ttq_serve::bench::{
    figure2, sweep_formats, sweep_lowrank_init, sweep_nf, sweep_prune,
    table1, table12, table13, table2, table3, tables_runtime,
};
use ttq_serve::coordinator::{BatchPolicy, ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split};
use ttq_serve::eval::{EvalConfig, Evaluator};
use ttq_serve::quant::{MethodRegistry, MethodSpec, QuantSpec};
use ttq_serve::runtime::Runtime;
use ttq_serve::util::cli::Args;
use ttq_serve::{artifacts_dir, artifacts_ready};

const USAGE: &str = "\
ttq-serve — TTQ test-time quantization serving stack

USAGE:
  ttq-serve eval [--model M] [--method SPEC] [--bits Q] [--group G]
                 [--rank R] [--domain D] [--calib D] [--fast]
                 [--backend pjrt|native] [--exec-quant Q]
  ttq-serve table <N> [--fast] [--models M1 M2 ...] [--backend B]
                      [--exec-quant Q]
                      [--methods SPEC1 SPEC2 ...]   (N: 1,2,3,4..8,12,13)
  ttq-serve figure2 [--fast] [--models ...] [--backend B] [--exec-quant Q]
  ttq-serve sweep <formats|lowrank-init|nf|prune>
  ttq-serve serve [--model M] [--requests N] [--method SPEC] [--bits Q]
                  [--rank R] [--domains d1,d2] [--backend B] [--exec-quant Q]
                  [--max-new-tokens T] [--prompt-len L] [--cache-slots S]
                  [--speculative] [--spec-k K] [--threads T]
                  [--trace-out FILE] [--metrics-out FILE] [--prom-out FILE]
                  [--trace-capacity N] [--probe-every N] [--profile]
  ttq-serve info

SERVING (decode engine):
  Prompts are prefilled once into the KV cache, then generated token by
  token through the continuous-batching decode scheduler (streaming
  Token/Done events). --prompt-len defaults to half the model context so
  there is room to decode; --max-new-tokens bounds each generation
  (clamped to the context window). Cached decode requires the native
  backend — pjrt artifacts have no KV-cache variant.
  --speculative decodes every request self-speculatively: the quantized
  serving weights draft up to K tokens per round (--spec-k, adaptive by
  default) and a full-precision verifier commits them in one batched
  cached forward — the streamed tokens are exactly the fp32 model's.

OBSERVABILITY (docs/OBSERVABILITY.md):
  --trace-out FILE     write the recorded span trace as Chrome trace-event
                       JSON (open at https://ui.perfetto.dev)
  --metrics-out FILE   write a JSON metrics snapshot (counters + latency
                       histograms with p50/p95/p99 and bucket tables)
  --prom-out FILE      write Prometheus text exposition of the same metrics
  --trace-capacity N   span ring size in events (default 16384; 0 disables
                       recording entirely)
  --probe-every N      online quality probe: every N committed plain decode
                       steps, replay one sampled sequence through pristine
                       fp32 and record KL / top-1 / NLL-delta histograms
                       (0 = off, the default); summaries land in the
                       metrics line and every exporter
  --profile            attach the kernel roofline profiler (native backend):
                       every pooled kernel dispatch is attributed to a
                       kind/phase/shape site; after the run the per-site
                       measured-vs-predicted roofline table is printed, the
                       ttq_kernel_* families are appended to --prom-out and a
                       kernel-profile track is added to --trace-out
  Requant events (drift vs threshold, top drifted layers, per-layer
  reconstruction error, quantization wall time) are printed after the
  run whenever the calibrator fired.

BACKENDS:
  pjrt     AOT HLO artifacts via the PJRT client (needs `make artifacts`)
  native   pure-Rust forward pass; synthetic models when artifacts are
           absent (default when artifacts are missing)
  --exec-quant Q (native only) additionally executes every quantizable
  linear through the packed Q-bit grouped int-matmul — it composes ON TOP
  of the selected --method, so eval/table numbers reflect method + W{Q}
  execution, not the method alone
  --threads T (native only) sizes the persistent kernel worker pool
  (default: available cores, capped at 16); prefill, decode, verify and
  speculative drafting all share the one pool

METHOD SPECS (ttq-serve eval/table/serve --method(s)):";

fn usage() -> String {
    format!("{USAGE}\n{}", MethodRegistry::global().help())
}

/// Build the execution backend from `--backend` (default: pjrt when
/// artifacts exist, native otherwise). `--exec-quant BITS` puts the
/// native backend into packed-int execution at the given bit-width.
fn make_backend(a: &Args) -> Result<Box<dyn ExecBackend>> {
    let default = if artifacts_ready() { "pjrt" } else { "native" };
    match a.get_or("backend", default) {
        "pjrt" => {
            if a.get("exec-quant").is_some() {
                bail!(
                    "--exec-quant is a native-backend execution mode; it would be \
                     silently ignored on pjrt — add --backend native"
                );
            }
            if a.get("threads").is_some() {
                bail!(
                    "--threads sizes the native kernel worker pool; it would be \
                     silently ignored on pjrt — add --backend native"
                );
            }
            if !artifacts_ready() {
                bail!(
                    "--backend pjrt needs compiled artifacts — run `make artifacts` \
                     first ({:?}), or use --backend native",
                    artifacts_dir()
                );
            }
            Ok(Box::new(PjrtBackend::new(Runtime::new(&artifacts_dir())?)))
        }
        "native" => {
            let mut nb = NativeBackend::new(&artifacts_dir());
            if let Some(bits) = a.get("exec-quant") {
                let bits: u32 = bits
                    .parse()
                    .map_err(|_| anyhow!("--exec-quant takes a bit-width (2..=8)"))?;
                if !(2..=8).contains(&bits) {
                    bail!("--exec-quant bit-width must be in 2..=8, got {bits}");
                }
                nb = nb.with_exec_quant(QuantSpec::new(bits, 32));
            }
            if let Some(t) = a.get("threads") {
                let t: usize = t
                    .parse()
                    .map_err(|_| anyhow!("--threads takes a positive integer"))?;
                if t == 0 {
                    bail!("--threads must be ≥ 1");
                }
                nb = nb.with_threads(t);
            }
            Ok(Box::new(nb))
        }
        other => bail!("unknown backend '{other}' (pjrt|native)"),
    }
}

/// Parse a method spec; offline-by-default methods (awq, gptq) given
/// without an inline `calib=` get the CLI's `--calib` domain.
fn parse_method(spec: &str, default_calib: &str) -> Result<MethodSpec> {
    let mut m = MethodSpec::parse(spec)?;
    if m.quantizer().offline_by_default() && m.calib_domain().is_none() {
        m = m.with_calib(default_calib);
    }
    Ok(m)
}

fn parse_methods(a: &Args) -> Result<Vec<MethodSpec>> {
    let calib = a.get_or("calib", "c4s");
    a.get_many("methods")
        .iter()
        .map(|s| parse_method(s, calib))
        .collect()
}

/// Legacy `--rank R` sugar: `--method ttq --rank 16` ≡ `--method ttq:r=16`.
fn method_arg(a: &Args, default: &str) -> String {
    let spec = a.get_or("method", default);
    if spec == "ttq" && a.get("rank").is_some() {
        return format!("ttq:r={}", a.get_usize("rank", 0));
    }
    spec.to_string()
}

fn default_models(models: Vec<String>) -> Vec<String> {
    if models.is_empty() {
        ttq_serve::models::MODEL_NAMES
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        models
    }
}

fn cmd_eval(a: &Args) -> Result<()> {
    let backend = make_backend(a)?;
    let model = a.get_or("model", "qwen-micro").to_string();
    let mut ev = Evaluator::new(backend.as_ref(), &model)?;
    let fast = a.has("fast");
    let m = parse_method(&method_arg(a, "ttq"), a.get_or("calib", "c4s"))?;
    let cfg = EvalConfig {
        spec: QuantSpec::new(a.get_u32("bits", 3), a.get_usize("group", 32)),
        eval_batches: if fast { 3 } else { 12 },
        calib_batches: if fast { 4 } else { 16 },
        ..Default::default()
    };
    let domain = a.get_or("domain", "wt2s");
    let t0 = Instant::now();
    let ppl = ev.perplexity(&m, domain, &cfg)?;
    println!(
        "{model} {} q={} g={} on {domain} [{}]: ppl {ppl:.3} ({:.1}s)",
        m.label(),
        cfg.spec.bits,
        cfg.spec.group,
        backend.name(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_table(a: &Args) -> Result<()> {
    let n: u32 = a
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("table number required\n{}", usage()))?
        .parse()?;
    let fast = a.has("fast");
    let models = a.get_many("models");
    let methods = parse_methods(a)?;
    match n {
        1 => table1(make_backend(a)?.as_ref(), fast, &methods)?.print(),
        2 => table2(make_backend(a)?.as_ref(), fast, &methods)?.print(),
        3 => {
            let backend = make_backend(a)?;
            for r in table3(backend.as_ref(), &default_models(models), fast, &methods)? {
                r.print();
            }
        }
        4..=8 => {
            let name =
                ["A40", "A100", "L40", "RTX3090", "RTX4090"][(n - 4) as usize];
            if methods.is_empty() {
                tables_runtime::runtime_table(name).print();
            } else {
                let modes = tables_runtime::modes_for_methods(&methods);
                tables_runtime::runtime_table_for(name, &modes).print();
            }
        }
        12 => {
            let backend = make_backend(a)?;
            let ms = if models.is_empty() {
                vec!["qwen-micro".into(), "qwen-mini".into()]
            } else {
                models
            };
            for r in table12(backend.as_ref(), &ms, fast, &methods)? {
                r.print();
            }
        }
        13 => {
            let backend = make_backend(a)?;
            let model = models
                .first()
                .cloned()
                .unwrap_or_else(|| "qwen-mini".into());
            table13(backend.as_ref(), &model, fast, &methods)?.print();
        }
        _ => bail!("no table {n} among the paper's exhibits"),
    }
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    let backend = make_backend(a)?;
    let model = a.get_or("model", "qwen-micro");
    // serving methods are online by definition — no calib default
    let method = MethodSpec::parse(&method_arg(a, "ttq"))?;
    let mut cfg = ServerConfig::new(model).with_method(method);
    cfg.spec = QuantSpec::new(a.get_u32("bits", 4), 32);
    cfg.policy = BatchPolicy::default();
    cfg.max_new_tokens = a.get_usize("max-new-tokens", 8).max(1);
    cfg.cache_slots = a.get_usize("cache-slots", 16).max(1);
    cfg.trace_capacity = a.get_usize(
        "trace-capacity",
        ttq_serve::coordinator::DEFAULT_TRACE_CAPACITY,
    );
    cfg.probe_every = a.get_usize("probe-every", 0);
    cfg.profile = a.has("profile");
    let speculative = a.has("speculative");
    cfg.specdec = ttq_serve::specdec::SpecConfig::new(a.get_usize("spec-k", 4));
    let requests = a.get_usize("requests", 64);
    let mut server = Server::new(backend.as_ref(), cfg)?;
    let max_seq = server.max_seq();
    let prompt_len = a
        .get_usize("prompt-len", (max_seq / 2).max(1))
        .clamp(1, max_seq);
    let domains = a.get_or("domains", "wt2s,c4s").to_string();
    let domain_list: Vec<&str> = domains.split(',').collect();
    let mut streams: Vec<CorpusStream> = domain_list
        .iter()
        .map(|d| CorpusStream::new(d, Split::Eval))
        .collect();
    let t0 = Instant::now();
    let (mut tokens_streamed, mut done) = (0usize, 0usize);
    let mut count = |events: &[ServeEvent]| {
        for e in events {
            match e {
                ServeEvent::Token { .. } => tokens_streamed += 1,
                ServeEvent::Done { .. } => done += 1,
            }
        }
    };
    for i in 0..requests {
        // traffic switches domain partway — the domain-shift scenario
        // TTQ self-calibrates through
        let idx = (i * domain_list.len()) / requests.max(1);
        let s = &mut streams[idx.min(domain_list.len() - 1)];
        let mut toks = vec![ttq_serve::corpus::BOS; prompt_len];
        for t in toks.iter_mut().skip(1) {
            *t = s.next_token();
        }
        if speculative {
            server.submit_speculative(toks);
        } else {
            server.submit(toks);
        }
        count(&server.step()?);
    }
    count(&server.drain()?);
    println!(
        "served {done}/{requests} requests ({tokens_streamed} streamed tokens, \
         prompt_len {prompt_len}) in {:.2}s on the {} backend",
        t0.elapsed().as_secs_f64(),
        backend.name()
    );
    println!("{}", server.metrics.summary());
    let cs = server.cache_stats();
    println!(
        "kv cache: {} slots, high-water {}/{} tokens",
        cs.slots, cs.high_water_tokens, cs.capacity_tokens
    );
    println!("weight generations: {}", server.weight_generation());
    if speculative {
        println!(
            "specdec: acceptance EWMA {:.2}, final draft depth k={}",
            server.spec_controller().acceptance(),
            server.spec_controller().k()
        );
    }
    for ev in server.requant_events() {
        println!("requant: {}", ev.describe());
        for (layer, drift) in ev.top_layers(3) {
            println!("  layer {layer}: drift {drift:.4}");
        }
        for (layer, err) in ev.worst_recon_layers(3) {
            println!("  layer {layer}: recon err {err:.2e}");
        }
    }
    // Roofline report: measure the host ceilings once, position every
    // recorded kernel site against them.
    let profile_report = if a.has("profile") {
        let host = ttq_serve::obs::profile::HostSpec::measured();
        server.profile_report(&host)
    } else {
        None
    };
    if let Some(rep) = &profile_report {
        println!(
            "kernel profile: {:.0}% of {} pooled kernel us attributed across {} sites \
             ({} dropped); host {:.1} GB/s, {:.1} GFLOP/s",
            100.0 * rep.coverage(),
            rep.kernel_us,
            rep.sites.len(),
            rep.dropped,
            rep.host.bw_gbps,
            rep.host.gflops
        );
        for s in &rep.sites {
            println!(
                "  {:<44} {:>6} calls {:>8} us  {:>7.2} gflops  {:>6.2} gbps  {:<7} ratio {:.2}",
                s.site.label(),
                s.calls,
                s.measured_us,
                s.gflops,
                s.gbps,
                s.bound.name(),
                s.ratio
            );
        }
    }
    if let Some(path) = a.get("trace-out") {
        let trace = ttq_serve::obs::export::chrome_trace_with_profile(
            &server.trace().snapshot(),
            profile_report.as_ref(),
        );
        std::fs::write(path, trace)?;
        println!(
            "trace: {} events recorded ({} dropped) -> {path}",
            server.trace().recorded(),
            server.trace().dropped()
        );
    }
    if let Some(path) = a.get("metrics-out") {
        std::fs::write(path, ttq_serve::obs::export::metrics_json(&server.metrics))?;
        println!("metrics snapshot -> {path}");
    }
    if let Some(path) = a.get("prom-out") {
        let mut prom = ttq_serve::obs::export::prometheus(&server.metrics);
        if let Some(rep) = &profile_report {
            prom.push_str(&ttq_serve::obs::export::prometheus_profile(rep));
        }
        std::fs::write(path, prom)?;
        println!("prometheus exposition -> {path}");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("artifacts dir: {:?}", artifacts_dir());
    println!("artifacts ready: {}", artifacts_ready());
    println!(
        "default backend: {}",
        if artifacts_ready() { "pjrt" } else { "native (synthetic models)" }
    );
    println!("models: {:?}", ttq_serve::models::MODEL_NAMES);
    println!("methods:\n{}", MethodRegistry::global().help());
    // one backend (and one PJRT client, when artifacts exist) for both
    // the platform line and the per-model listing
    let backend: Box<dyn ExecBackend> = if artifacts_ready() {
        let rt = Runtime::new(&artifacts_dir())?;
        println!("PJRT platform: {}", rt.platform());
        Box::new(PjrtBackend::new(rt))
    } else {
        Box::new(NativeBackend::new(&artifacts_dir()))
    };
    for name in ttq_serve::models::MODEL_NAMES {
        if let Ok(ev) = Evaluator::new(backend.as_ref(), name) {
            println!(
                "  {name}: {} params, {} linears, family {}",
                ev.weights.param_count(),
                ev.weights.manifest.linears.len(),
                ev.weights.manifest.family
            );
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let a = Args::from_env();
    match a.positional.first().map(String::as_str) {
        Some("eval") => cmd_eval(&a),
        Some("table") => cmd_table(&a),
        Some("figure2") => {
            let backend = make_backend(&a)?;
            let ms = {
                let m = a.get_many("models");
                if m.is_empty() {
                    vec![
                        "opt-micro".into(),
                        "opt-mini".into(),
                        "opt-small".into(),
                    ]
                } else {
                    m
                }
            };
            figure2(backend.as_ref(), &ms, a.has("fast"))?.print();
            Ok(())
        }
        Some("sweep") => match a.positional.get(1).map(String::as_str) {
            Some("formats") => {
                sweep_formats()?.print();
                Ok(())
            }
            Some("lowrank-init") => {
                sweep_lowrank_init()?.print();
                Ok(())
            }
            Some("nf") => {
                sweep_nf()?.print();
                Ok(())
            }
            Some("prune") => {
                sweep_prune()?.print();
                Ok(())
            }
            w => bail!("unknown sweep {w:?} (formats|lowrank-init|nf|prune)"),
        },
        Some("serve") => cmd_serve(&a),
        Some("info") => cmd_info(),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    }
}
