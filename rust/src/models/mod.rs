//! Model registry + weight-manifest loader.
//!
//! The interchange contract with `python/compile/aot.py`: a JSON
//! manifest describing tensor order/shapes/offsets plus a raw f32-LE
//! blob. The registry also carries the paper's *full-scale* family
//! tables (Tables 14-16) used by the GPU roofline model — those models
//! are never executed here, only dimension-accounted.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::fs;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::linalg::Mat;
use crate::util::json::Value;

/// One tensor entry of the weights manifest.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    /// Tensor name (e.g. `l0.wq`).
    pub name: String,
    /// Declared shape (rank 1 or 2).
    pub shape: Vec<usize>,
    /// Element offset into the f32 blob.
    pub offset: usize,
    /// Element count.
    pub numel: usize,
}

/// One quantizable linear layer (stats-output ordering contract).
#[derive(Clone, Debug)]
pub struct LinearInfo {
    /// Weight tensor name.
    pub name: String,
    /// Input width (the stats-tap channel count).
    pub d_in: usize,
    /// Output width.
    pub d_out: usize,
}

/// Architecture dimensions of one model.
#[derive(Clone, Debug)]
pub struct ModelDims {
    /// Vocabulary size.
    pub vocab: usize,
    /// Residual width d.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Attention (query) heads.
    pub n_heads: usize,
    /// Key/value heads (GQA/MQA when < n_heads).
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// MLP hidden width.
    pub d_mlp: usize,
    /// Maximum context positions.
    pub max_seq: usize,
    /// Training / full-batch-artifact sequence length.
    pub seq: usize,
}

/// Manifest-carried TTQ defaults (the fused-kernel hyperparameters).
#[derive(Clone, Debug)]
pub struct TtqDefaults {
    /// Quantization groupsize.
    pub g: usize,
    /// Diagonal norm order.
    pub p: f64,
    /// Additive smoothing λ.
    pub lam: f64,
    /// Diagonal exponent α.
    pub alpha: f64,
}

/// Parsed `<name>.manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Model name (artifact file stem).
    pub name: String,
    /// Architecture family (`opt` / `qwen` / `gemma`).
    pub family: String,
    /// Architecture dimensions.
    pub config: ModelDims,
    /// Tensor order/shape/offset table for the weight blob.
    pub tensors: Vec<TensorInfo>,
    /// Quantizable linears, in stats-tap order.
    pub linears: Vec<LinearInfo>,
    /// The p-grid the stats artifact taps Σ|x|^p on.
    pub norm_ps: Vec<f64>,
    /// Fused-kernel TTQ hyperparameters.
    pub ttq_defaults: TtqDefaults,
}

fn as_usize(v: &Value, key: &str) -> Result<usize> {
    v.field(key)
        .map_err(|e| anyhow!("{e}"))?
        .as_usize()
        .ok_or_else(|| anyhow!("field '{key}' is not a number"))
}

fn as_f64(v: &Value, key: &str) -> Result<f64> {
    v.field(key)
        .map_err(|e| anyhow!("{e}"))?
        .as_f64()
        .ok_or_else(|| anyhow!("field '{key}' is not a number"))
}

fn as_str(v: &Value, key: &str) -> Result<String> {
    Ok(v.field(key)
        .map_err(|e| anyhow!("{e}"))?
        .as_str()
        .ok_or_else(|| anyhow!("field '{key}' is not a string"))?
        .to_string())
}

impl Manifest {
    /// Parse a `<name>.manifest.json` document.
    pub fn parse(doc: &str) -> Result<Manifest> {
        let v = Value::parse(doc).map_err(|e| anyhow!("{e}"))?;
        let cfg = v.field("config").map_err(|e| anyhow!("{e}"))?;
        let config = ModelDims {
            vocab: as_usize(cfg, "vocab")?,
            d_model: as_usize(cfg, "d_model")?,
            n_layers: as_usize(cfg, "n_layers")?,
            n_heads: as_usize(cfg, "n_heads")?,
            n_kv_heads: as_usize(cfg, "n_kv_heads")?,
            head_dim: as_usize(cfg, "head_dim")?,
            d_mlp: as_usize(cfg, "d_mlp")?,
            max_seq: as_usize(cfg, "max_seq")?,
            seq: as_usize(cfg, "seq")?,
        };
        let mut tensors = Vec::new();
        for t in v
            .field("tensors")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("tensors not an array"))?
        {
            tensors.push(TensorInfo {
                name: as_str(t, "name")?,
                shape: t
                    .field("shape")
                    .map_err(|e| anyhow!("{e}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("shape not array"))?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                offset: as_usize(t, "offset")?,
                numel: as_usize(t, "numel")?,
            });
        }
        let mut linears = Vec::new();
        for l in v
            .field("linears")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("linears not an array"))?
        {
            linears.push(LinearInfo {
                name: as_str(l, "name")?,
                d_in: as_usize(l, "d_in")?,
                d_out: as_usize(l, "d_out")?,
            });
        }
        let norm_ps = v
            .field("norm_ps")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("norm_ps not an array"))?
            .iter()
            .map(|x| x.as_f64().unwrap_or(0.0))
            .collect();
        let td = v.field("ttq_defaults").map_err(|e| anyhow!("{e}"))?;
        let ttq_defaults = TtqDefaults {
            g: as_usize(td, "g")?,
            p: as_f64(td, "p")?,
            lam: as_f64(td, "lam")?,
            alpha: as_f64(td, "alpha")?,
        };
        Ok(Manifest {
            name: as_str(&v, "name")?,
            family: as_str(&v, "family")?,
            config,
            tensors,
            linears,
            norm_ps,
            ttq_defaults,
        })
    }
}

/// A loaded model: manifest + owned weight tensors (name → Mat; 1-D
/// tensors are stored as (1, n) matrices).
pub struct ModelWeights {
    /// The parsed manifest the tensors were loaded under.
    pub manifest: Manifest,
    tensors: HashMap<String, Mat>,
    order: Vec<String>,
    /// Globally unique content version: refreshed on every [`Self::set`]
    /// so caches of derived representations (packed weights in the
    /// native backend) can detect staleness without hashing tensors.
    version: u64,
}

/// Monotonic version source shared by all `ModelWeights` instances.
fn next_version() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    COUNTER.fetch_add(1, Ordering::Relaxed)
}

impl ModelWeights {
    /// Load `<name>.manifest.json` + `<name>.weights.bin` from a dir.
    pub fn load(artifacts: &Path, name: &str) -> Result<Self> {
        let man_path = artifacts.join(format!("{name}.manifest.json"));
        let manifest = Manifest::parse(
            &fs::read_to_string(&man_path)
                .with_context(|| format!("reading {man_path:?}"))?,
        )?;
        let bin = fs::read(artifacts.join(format!("{name}.weights.bin")))?;
        let floats: Vec<f32> = bin
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut tensors = HashMap::new();
        let mut order = Vec::new();
        for t in &manifest.tensors {
            let data = floats
                .get(t.offset..t.offset + t.numel)
                .ok_or_else(|| anyhow!("tensor {} out of range", t.name))?
                .to_vec();
            let (rows, cols) = match t.shape.as_slice() {
                [n] => (1, *n),
                [r, c] => (*r, *c),
                s => return Err(anyhow!("unsupported rank for {}: {s:?}", t.name)),
            };
            tensors.insert(t.name.clone(), Mat::from_vec(rows, cols, data));
            order.push(t.name.clone());
        }
        Ok(ModelWeights { manifest, tensors, order, version: next_version() })
    }

    /// Assemble a model from already-built tensors (the synthetic
    /// [`crate::backend::testmodel`] path). Tensors must arrive in
    /// manifest order and match the declared shapes.
    pub fn from_parts(manifest: Manifest, parts: Vec<(String, Mat)>) -> Result<Self> {
        if parts.len() != manifest.tensors.len() {
            return Err(anyhow!(
                "{} tensors supplied for a {}-tensor manifest",
                parts.len(),
                manifest.tensors.len()
            ));
        }
        let mut tensors = HashMap::new();
        let mut order = Vec::with_capacity(parts.len());
        for (info, (name, m)) in manifest.tensors.iter().zip(parts) {
            if info.name != name {
                return Err(anyhow!(
                    "tensor order mismatch: got '{name}', manifest says '{}'",
                    info.name
                ));
            }
            let expect = match info.shape.as_slice() {
                [n] => (1usize, *n),
                [r, c] => (*r, *c),
                s => return Err(anyhow!("unsupported rank for {name}: {s:?}")),
            };
            if (m.rows, m.cols) != expect {
                return Err(anyhow!(
                    "tensor '{name}': {}x{} vs manifest shape {:?}",
                    m.rows,
                    m.cols,
                    info.shape
                ));
            }
            order.push(name.clone());
            tensors.insert(name, m);
        }
        Ok(ModelWeights { manifest, tensors, order, version: next_version() })
    }

    /// Content version — changes on every [`Self::set`]; never reused
    /// by another instance.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Deep copy with a **fresh** content version. The speculative
    /// decoder uses this to hold a full-precision verifier snapshot
    /// next to the (mutating) quantized drafter weights; the fresh
    /// version guarantees backend caches never alias the two once
    /// either diverges.
    pub fn fork(&self) -> Self {
        ModelWeights {
            manifest: self.manifest.clone(),
            tensors: self.tensors.clone(),
            order: self.order.clone(),
            version: next_version(),
        }
    }

    /// A tensor by name.
    pub fn get(&self, name: &str) -> Option<&Mat> {
        self.tensors.get(name)
    }

    /// Replace a tensor (same shape required); bumps the version.
    pub fn set(&mut self, name: &str, m: Mat) {
        let old = self.tensors.get(name).expect("unknown tensor");
        assert_eq!((old.rows, old.cols), (m.rows, m.cols), "shape change");
        self.tensors.insert(name.to_string(), m);
        self.version = next_version();
    }

    /// Tensors in manifest order — the positional inputs of every HLO
    /// artifact after the tokens (and qmax, for the ttq variant).
    pub fn ordered(&self) -> Vec<&Mat> {
        self.order.iter().map(|n| &self.tensors[n]).collect()
    }

    /// Tensor names in manifest order.
    pub fn tensor_names(&self) -> &[String] {
        &self.order
    }

    /// Deep copy of the quantizable linear weights (the originals must
    /// stay recoverable — the paper's point (3) against static quant).
    pub fn linear_weights(&self) -> HashMap<String, Mat> {
        self.manifest
            .linears
            .iter()
            .map(|l| (l.name.clone(), self.tensors[&l.name].clone()))
            .collect()
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.manifest.tensors.iter().map(|t| t.numel).sum()
    }
}

/// Miniature registry shipped in artifacts (must match python CONFIGS).
pub const MODEL_NAMES: [&str; 7] = [
    "opt-micro",
    "opt-mini",
    "opt-small",
    "qwen-micro",
    "qwen-mini",
    "gemma-micro",
    "gemma-mini",
];

/// Family grouping for the Table-3 style report layout.
pub fn family_of(name: &str) -> &'static str {
    if name.starts_with("opt") {
        "opt"
    } else if name.starts_with("qwen") {
        "qwen"
    } else {
        "gemma"
    }
}

// ---------------------------------------------------------------------
// Paper-scale dimension tables (Tables 14-16) for the roofline model.
// ---------------------------------------------------------------------

/// Dimensions of one full-scale model (only what the perf model needs).
#[derive(Clone, Copy, Debug)]
pub struct PaperModel {
    /// Published model name (e.g. `Qwen3-32B`).
    pub name: &'static str,
    /// Residual width.
    pub d_model: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
}

impl PaperModel {
    /// Query-projection weight dims (d_out = heads·head_dim, d_in = d):
    /// the module benchmarked in the paper's Tables 4-8.
    pub fn qproj_dims(&self) -> (usize, usize) {
        (self.n_heads * self.head_dim, self.d_model)
    }
}

/// Qwen3 dense family — paper Table 15.
pub const QWEN3: [PaperModel; 6] = [
    PaperModel { name: "0.6B", d_model: 1024, n_heads: 16, head_dim: 128 },
    PaperModel { name: "1.7B", d_model: 2048, n_heads: 16, head_dim: 128 },
    PaperModel { name: "4B", d_model: 2560, n_heads: 32, head_dim: 128 },
    PaperModel { name: "8B", d_model: 4096, n_heads: 32, head_dim: 128 },
    PaperModel { name: "14B", d_model: 5120, n_heads: 40, head_dim: 128 },
    PaperModel { name: "32B", d_model: 5120, n_heads: 64, head_dim: 128 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen32b_query_projection_dims_match_paper() {
        // Paper App. H: "Qwen3-32B model needs to transfer 5,120 × 8,192
        // weights ... for FP16 query projection".
        let m = QWEN3[5];
        let (dout, din) = m.qproj_dims();
        assert_eq!(din, 5120);
        assert_eq!(dout, 8192);
    }

    #[test]
    fn registry_families() {
        assert_eq!(family_of("opt-small"), "opt");
        assert_eq!(family_of("qwen-mini"), "qwen");
        assert_eq!(family_of("gemma-micro"), "gemma");
        assert_eq!(MODEL_NAMES.len(), 7);
    }

    #[test]
    fn manifest_parses_minimal_doc() {
        let doc = r#"{
          "name": "m", "family": "qwen",
          "config": {"vocab": 512, "d_model": 64, "n_layers": 2,
                     "n_heads": 4, "n_kv_heads": 2, "head_dim": 16,
                     "d_mlp": 192, "max_seq": 64, "seq": 64},
          "tensors": [{"name": "embed", "shape": [512, 64],
                       "offset": 0, "numel": 32768}],
          "linears": [{"name": "l0.wq", "d_in": 64, "d_out": 64}],
          "norm_ps": [0.5, 1, 2, 4],
          "ttq_defaults": {"g": 32, "p": 2, "lam": 0.4, "alpha": 0.5}
        }"#;
        let m = Manifest::parse(doc).unwrap();
        assert_eq!(m.name, "m");
        assert_eq!(m.config.d_mlp, 192);
        assert_eq!(m.tensors[0].numel, 32768);
        assert_eq!(m.linears[0].d_in, 64);
        assert_eq!(m.norm_ps, vec![0.5, 1.0, 2.0, 4.0]);
        assert_eq!(m.ttq_defaults.g, 32);
    }

    #[test]
    fn manifest_missing_field_errors() {
        assert!(Manifest::parse(r#"{"name": "m"}"#).is_err());
    }
}
