//! Monotonic clock abstraction for the serving path.
//!
//! Every serving-path timestamp goes through a [`Clock`] (repo-lint
//! R6 bans raw `Instant::now()` there): a [`Clock::real`] clock reads
//! the OS monotonic clock relative to its construction epoch, while a
//! [`Clock::test`] clock is fully deterministic — it auto-advances a
//! fixed tick per reading, so a scripted serve session produces the
//! exact same span tree on every run (asserted in
//! `rust/tests/obs.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Microsecond clock: real monotonic time or a deterministic test
/// clock. Cheap to clone (test state is shared behind an `Arc`; the
/// real clock copies its epoch).
#[derive(Clone, Debug)]
pub struct Clock(Inner);

#[derive(Clone, Debug)]
enum Inner {
    /// OS monotonic clock, reported relative to the construction
    /// epoch so timestamps start near zero and fit comfortably in
    /// `u64` microseconds.
    Real(Instant),
    Test(Arc<TestState>),
}

#[derive(Debug)]
struct TestState {
    now_us: AtomicU64,
    tick_us: u64,
}

impl Clock {
    /// Real monotonic clock; timestamps count microseconds since this
    /// call.
    pub fn real() -> Self {
        Clock(Inner::Real(Instant::now()))
    }

    /// Deterministic test clock starting at 0. Every [`Clock::now_us`]
    /// reading returns the current value and then advances it by
    /// `tick_us`, so consecutive readings are strictly increasing (for
    /// `tick_us > 0`) without any wall-clock dependence.
    pub fn test(tick_us: u64) -> Self {
        Clock(Inner::Test(Arc::new(TestState {
            now_us: AtomicU64::new(0),
            tick_us,
        })))
    }

    /// Current time in microseconds. Test clocks auto-advance by their
    /// tick per reading; clones share the same underlying time.
    pub fn now_us(&self) -> u64 {
        match &self.0 {
            Inner::Real(epoch) => epoch.elapsed().as_micros() as u64,
            Inner::Test(st) => st.now_us.fetch_add(st.tick_us, Ordering::Relaxed),
        }
    }

    /// Manually advance a test clock by `us`; no-op on a real clock.
    pub fn advance_us(&self, us: u64) {
        if let Inner::Test(st) = &self.0 {
            st.now_us.fetch_add(us, Ordering::Relaxed);
        }
    }

    /// True for deterministic test clocks.
    pub fn is_test(&self) -> bool {
        matches!(self.0, Inner::Test(_))
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::real()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_clock_auto_advances() {
        let c = Clock::test(7);
        assert_eq!(c.now_us(), 0);
        assert_eq!(c.now_us(), 7);
        assert_eq!(c.now_us(), 14);
        assert!(c.is_test());
    }

    #[test]
    fn test_clock_clones_share_time() {
        let c = Clock::test(5);
        let d = c.clone();
        assert_eq!(c.now_us(), 0);
        assert_eq!(d.now_us(), 5);
        d.advance_us(100);
        assert_eq!(c.now_us(), 110);
    }

    #[test]
    fn real_clock_is_monotone() {
        let c = Clock::real();
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
        assert!(!c.is_test());
        c.advance_us(1_000_000); // no-op on real clocks
        assert!(c.now_us() < 900_000, "advance_us must not move a real clock");
    }
}
