//! Trace and metrics exporters: Chrome trace-event JSON (loads in
//! Perfetto / `chrome://tracing`), Prometheus-style text exposition,
//! and a machine-readable JSON metrics snapshot.
//!
//! The Chrome format puts each request on its own track (`tid` =
//! request id + 1) with the engine-wide track at `tid` 0, so decode
//! and speculative spans nest visually inside their request span and
//! requants/cache-occupancy show up as engine activity. Open a written
//! file at <https://ui.perfetto.dev> or `chrome://tracing`.
//! Field-by-field reference: `docs/OBSERVABILITY.md`.

use std::collections::BTreeMap;

use crate::coordinator::Metrics;
use crate::obs::hist::Hist;
use crate::obs::trace::{SpanKind, TraceEvent, ENGINE_SEQ};
use crate::util::json::Value;

/// Chrome trace-event `tid` for an event: engine track 0, requests on
/// `seq + 1`.
fn tid_of(ev: &TraceEvent) -> u64 {
    if ev.seq == ENGINE_SEQ {
        0
    } else {
        ev.seq + 1
    }
}

/// Kind-specific argument names for the two payload words, in `(a, b)`
/// order; `None` hides the word in the export.
fn arg_names(kind: SpanKind) -> (Option<&'static str>, Option<&'static str>) {
    match kind {
        SpanKind::Request => (Some("generated_tokens"), Some("prompt_len")),
        SpanKind::Admit => (Some("prompt_len"), None),
        SpanKind::Prefill => (Some("prompt_tokens"), Some("rows")),
        SpanKind::DecodeStep => (Some("kernel_us"), Some("rows")),
        SpanKind::SpecRound => (Some("drafted"), Some("accepted")),
        SpanKind::Draft => (Some("drafted"), None),
        SpanKind::Verify => (Some("rows"), Some("accepted")),
        SpanKind::Requant => (Some("from_version"), Some("max_drift_ppm")),
        SpanKind::CacheOccupancy => (Some("used_tokens"), Some("capacity_tokens")),
        SpanKind::Kernel => (Some("rows"), Some("lanes")),
        SpanKind::Probe => (Some("kl_nanonats"), Some("top1_agree")),
    }
}

fn num(v: u64) -> Value {
    Value::Num(v as f64)
}

/// Render recorded events as Chrome trace-event JSON
/// (`{"traceEvents": [...]}`), directly loadable in Perfetto.
/// Duration spans become `"ph": "X"` complete events; counter kinds
/// ([`SpanKind::is_counter`]) become `"ph": "C"` counter samples.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    let mut out: Vec<Value> = Vec::with_capacity(events.len() + 8);
    let mut meta = |name: &str, tid: u64, arg: &str| {
        let mut args = BTreeMap::new();
        args.insert("name".to_string(), Value::Str(arg.to_string()));
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Value::Str(name.to_string()));
        o.insert("ph".to_string(), Value::Str("M".to_string()));
        o.insert("pid".to_string(), num(1));
        o.insert("tid".to_string(), num(tid));
        o.insert("args".to_string(), Value::Obj(args));
        out.push(Value::Obj(o));
    };
    meta("process_name", 0, "ttq-serve");
    meta("thread_name", 0, "engine");
    let mut seen: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
    for ev in events {
        if ev.seq != ENGINE_SEQ && seen.insert(ev.seq) {
            meta("thread_name", ev.seq + 1, &format!("request {}", ev.seq));
        }
    }
    for ev in events {
        let mut args = BTreeMap::new();
        args.insert("weight_version".to_string(), num(ev.weight_version));
        let (an, bn) = arg_names(ev.kind);
        if let Some(an) = an {
            args.insert(an.to_string(), num(ev.a));
        }
        if let Some(bn) = bn {
            args.insert(bn.to_string(), num(ev.b));
        }
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Value::Str(ev.kind.name().to_string()));
        o.insert("cat".to_string(), Value::Str("serve".to_string()));
        o.insert("pid".to_string(), num(1));
        o.insert("tid".to_string(), num(tid_of(ev)));
        o.insert("ts".to_string(), num(ev.start_us));
        if ev.kind.is_counter() {
            o.insert("ph".to_string(), Value::Str("C".to_string()));
        } else {
            o.insert("ph".to_string(), Value::Str("X".to_string()));
            o.insert("dur".to_string(), num(ev.dur_us));
        }
        o.insert("args".to_string(), Value::Obj(args));
        out.push(Value::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("displayTimeUnit".to_string(), Value::Str("ms".to_string()));
    top.insert("traceEvents".to_string(), Value::Arr(out));
    Value::Obj(top).to_json()
}

/// One Prometheus counter line with a `# TYPE` header.
fn prom_counter(out: &mut String, name: &str, kind: &str, v: u64) {
    out.push_str(&format!("# TYPE {name} {kind}\n{name} {v}\n"));
}

/// One histogram in Prometheus exposition format: cumulative
/// `_bucket{{le=...}}` lines over the non-empty buckets, then
/// `_sum`/`_count`.
fn prom_hist(out: &mut String, name: &str, h: &Hist) {
    out.push_str(&format!("# TYPE {name} histogram\n"));
    let mut cum = 0u64;
    for b in h.nonzero_buckets() {
        cum += b.count;
        out.push_str(&format!("{name}_bucket{{le=\"{}\"}} {cum}\n", b.hi));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum {}\n", h.sum()));
    out.push_str(&format!("{name}_count {}\n", h.count()));
}

/// Prometheus-style text exposition of every metrics family
/// (counters, gauges and the three latency histograms, all in
/// microseconds where time-valued).
pub fn prometheus(m: &Metrics) -> String {
    use std::sync::atomic::Ordering::Relaxed;
    let mut s = String::new();
    let counters: [(&str, u64); 17] = [
        ("ttq_requests_total", m.requests.load(Relaxed)),
        ("ttq_requests_completed_total", m.completed.load(Relaxed)),
        ("ttq_batches_total", m.batches.load(Relaxed)),
        ("ttq_padded_rows_total", m.padded_rows.load(Relaxed)),
        ("ttq_tokens_total", m.tokens.load(Relaxed)),
        ("ttq_prefill_tokens_total", m.prefill_tokens.load(Relaxed)),
        ("ttq_decode_tokens_total", m.decode_tokens.load(Relaxed)),
        ("ttq_decode_steps_total", m.decode_steps.load(Relaxed)),
        ("ttq_requants_total", m.requants.load(Relaxed)),
        ("ttq_quant_us_total", m.quant_us.load(Relaxed)),
        ("ttq_exec_us_total", m.exec_us.load(Relaxed)),
        ("ttq_spec_rounds_total", m.spec_rounds.load(Relaxed)),
        ("ttq_spec_drafted_total", m.spec_drafted.load(Relaxed)),
        ("ttq_spec_accepted_total", m.spec_accepted.load(Relaxed)),
        ("ttq_probe_samples_total", m.probe_samples.load(Relaxed)),
        ("ttq_probe_top1_total", m.probe_top1_agree.load(Relaxed)),
        ("ttq_probe_us_total", m.probe_us.load(Relaxed)),
    ];
    for (name, v) in counters {
        prom_counter(&mut s, name, "counter", v);
    }
    prom_counter(
        &mut s,
        "ttq_kv_cache_high_water_tokens",
        "gauge",
        m.cache_hwm_tokens.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_kernel_us_total",
        "counter",
        m.prefill_kernel_us.load(Relaxed)
            + m.decode_kernel_us.load(Relaxed)
            + m.spec_kernel_us.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_spec_accept_ewma_milli",
        "gauge",
        m.spec_accept_ewma_milli.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_spec_draft_depth",
        "gauge",
        m.spec_draft_depth.load(Relaxed),
    );
    prom_hist(&mut s, "ttq_request_latency_us", &m.latency_hist);
    prom_hist(&mut s, "ttq_decode_step_us", &m.decode_step_hist);
    prom_hist(&mut s, "ttq_spec_round_us", &m.spec_round_hist);
    prom_hist(&mut s, "ttq_probe_kl_nanonats", &m.probe_kl_hist);
    prom_hist(
        &mut s,
        "ttq_probe_nll_delta_nanonats",
        &m.probe_nll_delta_hist,
    );
    s
}

/// A histogram as JSON: count, sum, p50/p95/p99 and the non-empty
/// `[lo, hi, count]` buckets.
fn hist_value(h: &Hist) -> Value {
    let mut o = BTreeMap::new();
    o.insert("count".to_string(), num(h.count()));
    o.insert("sum".to_string(), num(h.sum()));
    o.insert("p50".to_string(), Value::Num(h.p50()));
    o.insert("p95".to_string(), Value::Num(h.p95()));
    o.insert("p99".to_string(), Value::Num(h.p99()));
    o.insert(
        "buckets".to_string(),
        Value::Arr(
            h.nonzero_buckets()
                .iter()
                .map(|b| Value::Arr(vec![num(b.lo), num(b.hi), num(b.count)]))
                .collect(),
        ),
    );
    Value::Obj(o)
}

/// Machine-readable JSON snapshot of every metrics family, including
/// the three latency histograms with their bucket tables.
pub fn metrics_json(m: &Metrics) -> String {
    use std::sync::atomic::Ordering::Relaxed;
    let mut o = BTreeMap::new();
    let mut put = |k: &str, v: u64| {
        o.insert(k.to_string(), num(v));
    };
    put("requests", m.requests.load(Relaxed));
    put("completed", m.completed.load(Relaxed));
    put("batches", m.batches.load(Relaxed));
    put("padded_rows", m.padded_rows.load(Relaxed));
    put("tokens", m.tokens.load(Relaxed));
    put("prefill_tokens", m.prefill_tokens.load(Relaxed));
    put("decode_tokens", m.decode_tokens.load(Relaxed));
    put("decode_steps", m.decode_steps.load(Relaxed));
    put("requants", m.requants.load(Relaxed));
    put("quant_us", m.quant_us.load(Relaxed));
    put("exec_us", m.exec_us.load(Relaxed));
    put("prefill_us", m.prefill_us.load(Relaxed));
    put("decode_us", m.decode_us.load(Relaxed));
    put("spec_us", m.spec_us.load(Relaxed));
    put("spec_rounds", m.spec_rounds.load(Relaxed));
    put("spec_drafted", m.spec_drafted.load(Relaxed));
    put("spec_accepted", m.spec_accepted.load(Relaxed));
    put("spec_draft_depth", m.spec_draft_depth.load(Relaxed));
    put("cache_hwm_tokens", m.cache_hwm_tokens.load(Relaxed));
    put("probe_samples", m.probe_samples.load(Relaxed));
    put("probe_top1_agree", m.probe_top1_agree.load(Relaxed));
    put("probe_us", m.probe_us.load(Relaxed));
    o.insert(
        "mean_latency_ms".to_string(),
        Value::Num(m.mean_latency_ms()),
    );
    o.insert("kernel_share".to_string(), Value::Num(m.kernel_share()));
    o.insert(
        "spec_acceptance".to_string(),
        Value::Num(m.spec_acceptance()),
    );
    o.insert(
        "spec_accept_ewma".to_string(),
        Value::Num(m.spec_accept_ewma()),
    );
    o.insert(
        "probe_top1_rate".to_string(),
        Value::Num(m.probe_top1_rate()),
    );
    o.insert(
        "probe_mean_kl_nats".to_string(),
        Value::Num(m.probe_mean_kl()),
    );
    o.insert(
        "request_latency_us".to_string(),
        hist_value(&m.latency_hist),
    );
    o.insert(
        "decode_step_us".to_string(),
        hist_value(&m.decode_step_hist),
    );
    o.insert("spec_round_us".to_string(), hist_value(&m.spec_round_hist));
    o.insert(
        "probe_kl_nanonats".to_string(),
        hist_value(&m.probe_kl_hist),
    );
    o.insert(
        "probe_nll_delta_nanonats".to_string(),
        hist_value(&m.probe_nll_delta_hist),
    );
    Value::Obj(o).to_json()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn span(kind: SpanKind, seq: u64, start: u64, dur: u64) -> TraceEvent {
        TraceEvent {
            kind,
            seq,
            start_us: start,
            dur_us: dur,
            weight_version: 1,
            a: 7,
            b: 9,
        }
    }

    #[test]
    fn chrome_trace_parses_and_tracks_split() {
        let evs = [
            span(SpanKind::Request, 0, 0, 100),
            span(SpanKind::DecodeStep, 0, 10, 5),
            span(SpanKind::Requant, ENGINE_SEQ, 20, 8),
            span(SpanKind::CacheOccupancy, ENGINE_SEQ, 25, 0),
        ];
        let s = chrome_trace(&evs);
        let v = Value::parse(&s).expect("valid JSON");
        let arr = v.field("traceEvents").unwrap().as_arr().unwrap();
        // 2 process/engine meta + 1 request meta + 4 events
        assert_eq!(arr.len(), 7);
        let phases: Vec<&str> = arr
            .iter()
            .map(|e| e.field("ph").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(phases.iter().filter(|p| **p == "M").count(), 3);
        assert_eq!(phases.iter().filter(|p| **p == "X").count(), 3);
        assert_eq!(phases.iter().filter(|p| **p == "C").count(), 1);
        // Requant rides the engine track, request spans their own.
        for e in arr.iter().filter(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("requant")
        }) {
            assert_eq!(e.field("tid").unwrap().as_f64(), Some(0.0));
        }
        for e in arr.iter().filter(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("decode_step")
        }) {
            assert_eq!(e.field("tid").unwrap().as_f64(), Some(1.0));
        }
    }

    #[test]
    fn prometheus_buckets_are_cumulative() {
        let m = Metrics::new();
        m.record_admitted(3, 0);
        for ms in [1u64, 2, 400] {
            m.record_latency(Duration::from_millis(ms));
        }
        let s = prometheus(&m);
        assert!(s.contains("ttq_requests_total 3"), "{s}");
        assert!(s.contains("ttq_request_latency_us_count 3"), "{s}");
        assert!(s.contains("le=\"+Inf\"} 3"), "{s}");
        // Cumulative bucket counts never decrease.
        let mut last = 0u64;
        for line in s.lines().filter(|l| l.starts_with("ttq_request_latency_us_bucket")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= last, "{line}");
            last = n;
        }
    }

    #[test]
    fn metrics_json_roundtrips() {
        let m = Metrics::new();
        m.record_admitted(1, 0);
        m.record_decode(1, Duration::from_micros(250));
        m.record_latency(Duration::from_millis(3));
        let v = Value::parse(&metrics_json(&m)).expect("valid JSON");
        assert_eq!(v.field("requests").unwrap().as_usize(), Some(1));
        assert_eq!(v.field("completed").unwrap().as_usize(), Some(1));
        let h = v.field("decode_step_us").unwrap();
        assert_eq!(h.field("count").unwrap().as_usize(), Some(1));
        let buckets = h.field("buckets").unwrap().as_arr().unwrap();
        let total: usize = buckets
            .iter()
            .map(|b| b.as_arr().unwrap()[2].as_usize().unwrap())
            .sum();
        assert_eq!(total, 1);
    }
}
