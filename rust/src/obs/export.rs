//! Trace and metrics exporters: Chrome trace-event JSON (loads in
//! Perfetto / `chrome://tracing`), Prometheus-style text exposition,
//! and a machine-readable JSON metrics snapshot.
//!
//! The Chrome format puts each request on its own track (`tid` =
//! request id + 1) with the engine-wide track at `tid` 0, so decode
//! and speculative spans nest visually inside their request span and
//! requants/cache-occupancy show up as engine activity. Open a written
//! file at <https://ui.perfetto.dev> or `chrome://tracing`.
//! Field-by-field reference: `docs/OBSERVABILITY.md`.

use std::collections::BTreeMap;

use crate::coordinator::Metrics;
use crate::obs::hist::Hist;
use crate::obs::profile::{KernelSite, ProfileReport};
use crate::obs::trace::{SpanKind, TraceEvent, ENGINE_SEQ};
use crate::util::json::Value;

/// Chrome trace-event `tid` for an event: engine track 0, requests on
/// `seq + 1`.
fn tid_of(ev: &TraceEvent) -> u64 {
    if ev.seq == ENGINE_SEQ {
        0
    } else {
        ev.seq + 1
    }
}

/// Kind-specific argument names for the two payload words, in `(a, b)`
/// order; `None` hides the word in the export.
fn arg_names(kind: SpanKind) -> (Option<&'static str>, Option<&'static str>) {
    match kind {
        SpanKind::Request => (Some("generated_tokens"), Some("prompt_len")),
        SpanKind::Admit => (Some("prompt_len"), None),
        SpanKind::Prefill => (Some("prompt_tokens"), Some("rows")),
        SpanKind::DecodeStep => (Some("kernel_us"), Some("rows")),
        SpanKind::SpecRound => (Some("drafted"), Some("accepted")),
        SpanKind::Draft => (Some("drafted"), None),
        SpanKind::Verify => (Some("rows"), Some("accepted")),
        SpanKind::Requant => (Some("from_version"), Some("max_drift_ppm")),
        SpanKind::CacheOccupancy => (Some("used_tokens"), Some("capacity_tokens")),
        SpanKind::Kernel => (Some("rows"), Some("lanes")),
        SpanKind::Probe => (Some("kl_nanonats"), Some("top1_agree")),
        SpanKind::KvBytes => (Some("occupancy_bytes"), Some("waste_bytes")),
    }
}

fn num(v: u64) -> Value {
    Value::Num(v as f64)
}

/// Chrome trace-event `tid` for the synthetic kernel-profile track —
/// far above any plausible request id so it never collides with
/// `seq + 1` request tracks.
const PROFILE_TID: u64 = 1_000_000;

/// Render recorded events as Chrome trace-event JSON
/// (`{"traceEvents": [...]}`), directly loadable in Perfetto.
/// Duration spans become `"ph": "X"` complete events; counter kinds
/// ([`SpanKind::is_counter`]) become `"ph": "C"` counter samples.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    chrome_trace_with_profile(events, None)
}

/// [`chrome_trace`], plus an optional kernel-profile track: one `"X"`
/// slice per [`KernelSite`] (laid end to end, width = attributed wall
/// time) on a dedicated `tid`, with the roofline verdict, achieved
/// rates and predicted-vs-measured ratio in the slice args.
pub fn chrome_trace_with_profile(
    events: &[TraceEvent],
    profile: Option<&ProfileReport>,
) -> String {
    let mut out: Vec<Value> = Vec::with_capacity(events.len() + 8);
    let mut meta = |name: &str, tid: u64, arg: &str| {
        let mut args = BTreeMap::new();
        args.insert("name".to_string(), Value::Str(arg.to_string()));
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Value::Str(name.to_string()));
        o.insert("ph".to_string(), Value::Str("M".to_string()));
        o.insert("pid".to_string(), num(1));
        o.insert("tid".to_string(), num(tid));
        o.insert("args".to_string(), Value::Obj(args));
        out.push(Value::Obj(o));
    };
    meta("process_name", 0, "ttq-serve");
    meta("thread_name", 0, "engine");
    let mut seen: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
    for ev in events {
        if ev.seq != ENGINE_SEQ && seen.insert(ev.seq) {
            meta("thread_name", ev.seq + 1, &format!("request {}", ev.seq));
        }
    }
    for ev in events {
        let mut args = BTreeMap::new();
        args.insert("weight_version".to_string(), num(ev.weight_version));
        let (an, bn) = arg_names(ev.kind);
        if let Some(an) = an {
            args.insert(an.to_string(), num(ev.a));
        }
        if let Some(bn) = bn {
            args.insert(bn.to_string(), num(ev.b));
        }
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Value::Str(ev.kind.name().to_string()));
        o.insert("cat".to_string(), Value::Str("serve".to_string()));
        o.insert("pid".to_string(), num(1));
        o.insert("tid".to_string(), num(tid_of(ev)));
        o.insert("ts".to_string(), num(ev.start_us));
        if ev.kind.is_counter() {
            o.insert("ph".to_string(), Value::Str("C".to_string()));
        } else {
            o.insert("ph".to_string(), Value::Str("X".to_string()));
            o.insert("dur".to_string(), num(ev.dur_us));
        }
        o.insert("args".to_string(), Value::Obj(args));
        out.push(Value::Obj(o));
    }
    if let Some(rep) = profile {
        let mut margs = BTreeMap::new();
        margs.insert(
            "name".to_string(),
            Value::Str("kernel profile".to_string()),
        );
        let mut mo = BTreeMap::new();
        mo.insert("name".to_string(), Value::Str("thread_name".to_string()));
        mo.insert("ph".to_string(), Value::Str("M".to_string()));
        mo.insert("pid".to_string(), num(1));
        mo.insert("tid".to_string(), num(PROFILE_TID));
        mo.insert("args".to_string(), Value::Obj(margs));
        out.push(Value::Obj(mo));
        let mut ts = 0u64;
        for r in &rep.sites {
            let mut args = BTreeMap::new();
            args.insert("calls".to_string(), num(r.calls));
            args.insert("flops".to_string(), num(r.flops));
            args.insert("bytes".to_string(), num(r.bytes));
            args.insert("gflops".to_string(), Value::Num(r.gflops));
            args.insert("gbps".to_string(), Value::Num(r.gbps));
            args.insert("intensity".to_string(), Value::Num(r.intensity));
            args.insert(
                "bound".to_string(),
                Value::Str(r.bound.name().to_string()),
            );
            args.insert("predicted_us".to_string(), Value::Num(r.predicted_us));
            args.insert("ratio".to_string(), Value::Num(r.ratio));
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Value::Str(r.site.label()));
            o.insert("cat".to_string(), Value::Str("profile".to_string()));
            o.insert("pid".to_string(), num(1));
            o.insert("tid".to_string(), num(PROFILE_TID));
            o.insert("ts".to_string(), num(ts));
            o.insert("ph".to_string(), Value::Str("X".to_string()));
            o.insert("dur".to_string(), num(r.measured_us.max(1)));
            o.insert("args".to_string(), Value::Obj(args));
            out.push(Value::Obj(o));
            ts += r.measured_us.max(1);
        }
    }
    let mut top = BTreeMap::new();
    top.insert("displayTimeUnit".to_string(), Value::Str("ms".to_string()));
    top.insert("traceEvents".to_string(), Value::Arr(out));
    Value::Obj(top).to_json()
}

/// One Prometheus counter line with a `# TYPE` header.
fn prom_counter(out: &mut String, name: &str, kind: &str, v: u64) {
    out.push_str(&format!("# TYPE {name} {kind}\n{name} {v}\n"));
}

/// One histogram in Prometheus exposition format: cumulative
/// `_bucket{{le=...}}` lines over the non-empty buckets, then
/// `_sum`/`_count`.
fn prom_hist(out: &mut String, name: &str, h: &Hist) {
    out.push_str(&format!("# TYPE {name} histogram\n"));
    let mut cum = 0u64;
    for b in h.nonzero_buckets() {
        cum += b.count;
        out.push_str(&format!("{name}_bucket{{le=\"{}\"}} {cum}\n", b.hi));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum {}\n", h.sum()));
    out.push_str(&format!("{name}_count {}\n", h.count()));
}

/// Prometheus-style text exposition of every metrics family
/// (counters, gauges and the three latency histograms, all in
/// microseconds where time-valued).
pub fn prometheus(m: &Metrics) -> String {
    use std::sync::atomic::Ordering::Relaxed;
    let mut s = String::new();
    let counters: [(&str, u64); 17] = [
        ("ttq_requests_total", m.requests.load(Relaxed)),
        ("ttq_requests_completed_total", m.completed.load(Relaxed)),
        ("ttq_batches_total", m.batches.load(Relaxed)),
        ("ttq_padded_rows_total", m.padded_rows.load(Relaxed)),
        ("ttq_tokens_total", m.tokens.load(Relaxed)),
        ("ttq_prefill_tokens_total", m.prefill_tokens.load(Relaxed)),
        ("ttq_decode_tokens_total", m.decode_tokens.load(Relaxed)),
        ("ttq_decode_steps_total", m.decode_steps.load(Relaxed)),
        ("ttq_requants_total", m.requants.load(Relaxed)),
        ("ttq_quant_us_total", m.quant_us.load(Relaxed)),
        ("ttq_exec_us_total", m.exec_us.load(Relaxed)),
        ("ttq_spec_rounds_total", m.spec_rounds.load(Relaxed)),
        ("ttq_spec_drafted_total", m.spec_drafted.load(Relaxed)),
        ("ttq_spec_accepted_total", m.spec_accepted.load(Relaxed)),
        ("ttq_probe_samples_total", m.probe_samples.load(Relaxed)),
        ("ttq_probe_top1_total", m.probe_top1_agree.load(Relaxed)),
        ("ttq_probe_us_total", m.probe_us.load(Relaxed)),
    ];
    for (name, v) in counters {
        prom_counter(&mut s, name, "counter", v);
    }
    prom_counter(
        &mut s,
        "ttq_kv_cache_high_water_tokens",
        "gauge",
        m.cache_hwm_tokens.load(Relaxed),
    );
    prom_counter(&mut s, "ttq_kernel_us_total", "counter", m.kernel_us_total());
    prom_counter(
        &mut s,
        "ttq_kernel_prefill_us_total",
        "counter",
        m.prefill_kernel_us.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_kernel_decode_us_total",
        "counter",
        m.decode_kernel_us.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_kernel_spec_draft_us_total",
        "counter",
        m.spec_draft_kernel_us.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_kernel_spec_verify_us_total",
        "counter",
        m.spec_verify_kernel_us.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_kv_occupancy_bytes",
        "gauge",
        m.kv_occupancy_bytes.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_kv_waste_bytes",
        "gauge",
        m.kv_waste_bytes.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_spec_accept_ewma_milli",
        "gauge",
        m.spec_accept_ewma_milli.load(Relaxed),
    );
    prom_counter(
        &mut s,
        "ttq_spec_draft_depth",
        "gauge",
        m.spec_draft_depth.load(Relaxed),
    );
    prom_hist(&mut s, "ttq_request_latency_us", &m.latency_hist);
    prom_hist(&mut s, "ttq_decode_step_us", &m.decode_step_hist);
    prom_hist(&mut s, "ttq_spec_round_us", &m.spec_round_hist);
    prom_hist(&mut s, "ttq_probe_kl_nanonats", &m.probe_kl_hist);
    prom_hist(
        &mut s,
        "ttq_probe_nll_delta_nanonats",
        &m.probe_nll_delta_hist,
    );
    s
}

/// The Prometheus label set for one kernel site:
/// `kind="..",phase="..",shape="m{..}xdo{..}xdi{..}",isa=".."`.
fn site_labels(site: &KernelSite) -> String {
    format!(
        "kind=\"{}\",phase=\"{}\",shape=\"m{}xdo{}xdi{}\",isa=\"{}\"",
        site.kind.name(),
        site.phase.name(),
        site.m_bucket,
        site.d_out_bucket,
        site.d_in_bucket,
        site.isa.name()
    )
}

/// Prometheus-style text exposition of a [`ProfileReport`]: host
/// ceilings, attribution coverage, and one labelled sample per kernel
/// site in each `ttq_kernel_*` family (calls, wall time, analytic
/// FLOPs/bytes, achieved rates, roofline verdict and
/// predicted-vs-measured drift). Appended to the [`prometheus`]
/// exposition by the serve CLI when profiling is on.
pub fn prometheus_profile(rep: &ProfileReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# TYPE ttq_kernel_host_bw_gbps gauge\nttq_kernel_host_bw_gbps {:.3}\n",
        rep.host.bw_gbps
    ));
    s.push_str(&format!(
        "# TYPE ttq_kernel_host_gflops gauge\nttq_kernel_host_gflops {:.3}\n",
        rep.host.gflops
    ));
    prom_counter(&mut s, "ttq_kernel_pool_us_total", "counter", rep.kernel_us);
    prom_counter(
        &mut s,
        "ttq_kernel_attributed_us_total",
        "counter",
        rep.attributed_us,
    );
    prom_counter(&mut s, "ttq_kernel_dropped_total", "counter", rep.dropped);
    s.push_str(&format!(
        "# TYPE ttq_kernel_coverage_ratio gauge\nttq_kernel_coverage_ratio {:.4}\n",
        rep.coverage()
    ));
    s.push_str("# TYPE ttq_kernel_calls_total counter\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_calls_total{{{}}} {}\n",
            site_labels(&r.site),
            r.calls
        ));
    }
    s.push_str("# TYPE ttq_kernel_wall_us_total counter\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_wall_us_total{{{}}} {}\n",
            site_labels(&r.site),
            r.measured_us
        ));
    }
    s.push_str("# TYPE ttq_kernel_flops_total counter\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_flops_total{{{}}} {}\n",
            site_labels(&r.site),
            r.flops
        ));
    }
    s.push_str("# TYPE ttq_kernel_bytes_total counter\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_bytes_total{{{}}} {}\n",
            site_labels(&r.site),
            r.bytes
        ));
    }
    s.push_str("# TYPE ttq_kernel_gflops gauge\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_gflops{{{}}} {:.3}\n",
            site_labels(&r.site),
            r.gflops
        ));
    }
    s.push_str("# TYPE ttq_kernel_gbps gauge\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_gbps{{{}}} {:.3}\n",
            site_labels(&r.site),
            r.gbps
        ));
    }
    s.push_str("# TYPE ttq_kernel_intensity gauge\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_intensity{{{}}} {:.4}\n",
            site_labels(&r.site),
            r.intensity
        ));
    }
    s.push_str("# TYPE ttq_kernel_bound gauge\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_bound{{{},bound=\"{}\"}} 1\n",
            site_labels(&r.site),
            r.bound.name()
        ));
    }
    s.push_str("# TYPE ttq_kernel_predicted_us gauge\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_predicted_us{{{}}} {:.2}\n",
            site_labels(&r.site),
            r.predicted_us
        ));
    }
    s.push_str("# TYPE ttq_kernel_ratio gauge\n");
    for r in &rep.sites {
        s.push_str(&format!(
            "ttq_kernel_ratio{{{}}} {:.3}\n",
            site_labels(&r.site),
            r.ratio
        ));
    }
    s
}

/// A histogram as JSON: count, sum, p50/p95/p99 and the non-empty
/// `[lo, hi, count]` buckets.
fn hist_value(h: &Hist) -> Value {
    let mut o = BTreeMap::new();
    o.insert("count".to_string(), num(h.count()));
    o.insert("sum".to_string(), num(h.sum()));
    o.insert("p50".to_string(), Value::Num(h.p50()));
    o.insert("p95".to_string(), Value::Num(h.p95()));
    o.insert("p99".to_string(), Value::Num(h.p99()));
    o.insert(
        "buckets".to_string(),
        Value::Arr(
            h.nonzero_buckets()
                .iter()
                .map(|b| Value::Arr(vec![num(b.lo), num(b.hi), num(b.count)]))
                .collect(),
        ),
    );
    Value::Obj(o)
}

/// Machine-readable JSON snapshot of every metrics family, including
/// the three latency histograms with their bucket tables.
pub fn metrics_json(m: &Metrics) -> String {
    use std::sync::atomic::Ordering::Relaxed;
    let mut o = BTreeMap::new();
    let mut put = |k: &str, v: u64| {
        o.insert(k.to_string(), num(v));
    };
    put("requests", m.requests.load(Relaxed));
    put("completed", m.completed.load(Relaxed));
    put("batches", m.batches.load(Relaxed));
    put("padded_rows", m.padded_rows.load(Relaxed));
    put("tokens", m.tokens.load(Relaxed));
    put("prefill_tokens", m.prefill_tokens.load(Relaxed));
    put("decode_tokens", m.decode_tokens.load(Relaxed));
    put("decode_steps", m.decode_steps.load(Relaxed));
    put("requants", m.requants.load(Relaxed));
    put("quant_us", m.quant_us.load(Relaxed));
    put("exec_us", m.exec_us.load(Relaxed));
    put("prefill_us", m.prefill_us.load(Relaxed));
    put("decode_us", m.decode_us.load(Relaxed));
    put("spec_us", m.spec_us.load(Relaxed));
    put("spec_rounds", m.spec_rounds.load(Relaxed));
    put("spec_drafted", m.spec_drafted.load(Relaxed));
    put("spec_accepted", m.spec_accepted.load(Relaxed));
    put("spec_draft_depth", m.spec_draft_depth.load(Relaxed));
    put("prefill_kernel_us", m.prefill_kernel_us.load(Relaxed));
    put("decode_kernel_us", m.decode_kernel_us.load(Relaxed));
    put("spec_draft_kernel_us", m.spec_draft_kernel_us.load(Relaxed));
    put("spec_verify_kernel_us", m.spec_verify_kernel_us.load(Relaxed));
    put("kernel_us", m.kernel_us_total());
    put("cache_hwm_tokens", m.cache_hwm_tokens.load(Relaxed));
    put("kv_occupancy_bytes", m.kv_occupancy_bytes.load(Relaxed));
    put("kv_waste_bytes", m.kv_waste_bytes.load(Relaxed));
    put("probe_samples", m.probe_samples.load(Relaxed));
    put("probe_top1_agree", m.probe_top1_agree.load(Relaxed));
    put("probe_us", m.probe_us.load(Relaxed));
    o.insert(
        "mean_latency_ms".to_string(),
        Value::Num(m.mean_latency_ms()),
    );
    o.insert("kernel_share".to_string(), Value::Num(m.kernel_share()));
    o.insert(
        "spec_acceptance".to_string(),
        Value::Num(m.spec_acceptance()),
    );
    o.insert(
        "spec_accept_ewma".to_string(),
        Value::Num(m.spec_accept_ewma()),
    );
    o.insert(
        "probe_top1_rate".to_string(),
        Value::Num(m.probe_top1_rate()),
    );
    o.insert(
        "probe_mean_kl_nats".to_string(),
        Value::Num(m.probe_mean_kl()),
    );
    o.insert(
        "request_latency_us".to_string(),
        hist_value(&m.latency_hist),
    );
    o.insert(
        "decode_step_us".to_string(),
        hist_value(&m.decode_step_hist),
    );
    o.insert("spec_round_us".to_string(), hist_value(&m.spec_round_hist));
    o.insert(
        "probe_kl_nanonats".to_string(),
        hist_value(&m.probe_kl_hist),
    );
    o.insert(
        "probe_nll_delta_nanonats".to_string(),
        hist_value(&m.probe_nll_delta_hist),
    );
    Value::Obj(o).to_json()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn span(kind: SpanKind, seq: u64, start: u64, dur: u64) -> TraceEvent {
        TraceEvent {
            kind,
            seq,
            start_us: start,
            dur_us: dur,
            weight_version: 1,
            a: 7,
            b: 9,
        }
    }

    #[test]
    fn chrome_trace_parses_and_tracks_split() {
        let evs = [
            span(SpanKind::Request, 0, 0, 100),
            span(SpanKind::DecodeStep, 0, 10, 5),
            span(SpanKind::Requant, ENGINE_SEQ, 20, 8),
            span(SpanKind::CacheOccupancy, ENGINE_SEQ, 25, 0),
        ];
        let s = chrome_trace(&evs);
        let v = Value::parse(&s).expect("valid JSON");
        let arr = v.field("traceEvents").unwrap().as_arr().unwrap();
        // 2 process/engine meta + 1 request meta + 4 events
        assert_eq!(arr.len(), 7);
        let phases: Vec<&str> = arr
            .iter()
            .map(|e| e.field("ph").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(phases.iter().filter(|p| **p == "M").count(), 3);
        assert_eq!(phases.iter().filter(|p| **p == "X").count(), 3);
        assert_eq!(phases.iter().filter(|p| **p == "C").count(), 1);
        // Requant rides the engine track, request spans their own.
        for e in arr.iter().filter(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("requant")
        }) {
            assert_eq!(e.field("tid").unwrap().as_f64(), Some(0.0));
        }
        for e in arr.iter().filter(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("decode_step")
        }) {
            assert_eq!(e.field("tid").unwrap().as_f64(), Some(1.0));
        }
    }

    #[test]
    fn prometheus_buckets_are_cumulative() {
        let m = Metrics::new();
        m.record_admitted(3, 0);
        for ms in [1u64, 2, 400] {
            m.record_latency(Duration::from_millis(ms));
        }
        let s = prometheus(&m);
        assert!(s.contains("ttq_requests_total 3"), "{s}");
        assert!(s.contains("ttq_request_latency_us_count 3"), "{s}");
        assert!(s.contains("le=\"+Inf\"} 3"), "{s}");
        // Cumulative bucket counts never decrease.
        let mut last = 0u64;
        for line in s.lines().filter(|l| l.starts_with("ttq_request_latency_us_bucket")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= last, "{line}");
            last = n;
        }
    }

    #[test]
    fn prometheus_phase_split_and_kv_gauges() {
        let m = Metrics::new();
        m.record_prefill_kernel(1_000);
        m.record_decode_kernel(2_000);
        m.record_spec_draft_kernel(3_000);
        m.record_spec_verify_kernel(4_000);
        m.record_kv_bytes(4096, 512);
        let s = prometheus(&m);
        assert!(s.contains("ttq_kernel_us_total 10000"), "{s}");
        assert!(s.contains("ttq_kernel_prefill_us_total 1000"), "{s}");
        assert!(s.contains("ttq_kernel_decode_us_total 2000"), "{s}");
        assert!(s.contains("ttq_kernel_spec_draft_us_total 3000"), "{s}");
        assert!(s.contains("ttq_kernel_spec_verify_us_total 4000"), "{s}");
        assert!(s.contains("ttq_kv_occupancy_bytes 4096"), "{s}");
        assert!(s.contains("ttq_kv_waste_bytes 512"), "{s}");
        let v = Value::parse(&metrics_json(&m)).expect("valid JSON");
        assert_eq!(v.field("kernel_us").unwrap().as_usize(), Some(10_000));
        assert_eq!(
            v.field("spec_verify_kernel_us").unwrap().as_usize(),
            Some(4_000)
        );
        assert_eq!(v.field("kv_waste_bytes").unwrap().as_usize(), Some(512));
    }

    fn sample_report() -> ProfileReport {
        use crate::obs::profile::{HostSpec, KernelCall, Phase, Profiler};
        let p = Profiler::new();
        p.set_phase(Phase::Decode);
        p.record(&KernelCall::fp32_gemm(1, 512, 64), 100);
        p.set_phase(Phase::Prefill);
        p.record(&KernelCall::packed_w4(8, 512, 64, 4, 32), 300);
        p.report(&HostSpec::synthetic(10.0, 50.0), 400)
    }

    #[test]
    fn prometheus_profile_labels_every_site() {
        let rep = sample_report();
        let s = prometheus_profile(&rep);
        assert!(s.contains("ttq_kernel_host_bw_gbps 10.000"), "{s}");
        assert!(s.contains("ttq_kernel_pool_us_total 400"), "{s}");
        assert!(s.contains("ttq_kernel_coverage_ratio 1.0000"), "{s}");
        assert!(
            s.contains("kind=\"fp32_gemm\",phase=\"decode\""),
            "{s}"
        );
        assert!(
            s.contains("kind=\"packed_w4\",phase=\"prefill\""),
            "{s}"
        );
        assert!(s.contains("bound=\""), "{s}");
        // Every sample line is `name[{labels}] value`; type lines
        // declare each family exactly once.
        for fam in ["ttq_kernel_calls_total", "ttq_kernel_ratio"] {
            let decls = s
                .lines()
                .filter(|l| *l == format!("# TYPE {fam} counter") || *l == format!("# TYPE {fam} gauge"))
                .count();
            assert_eq!(decls, 1, "{fam}");
            let samples = s
                .lines()
                .filter(|l| l.starts_with(&format!("{fam}{{")))
                .count();
            assert_eq!(samples, 2, "{fam}\n{s}");
        }
    }

    #[test]
    fn chrome_trace_profile_track_parses() {
        let rep = sample_report();
        let evs = [span(SpanKind::Request, 0, 0, 100)];
        let s = chrome_trace_with_profile(&evs, Some(&rep));
        let v = Value::parse(&s).expect("valid JSON");
        let arr = v.field("traceEvents").unwrap().as_arr().unwrap();
        let slices: Vec<_> = arr
            .iter()
            .filter(|e| {
                e.field("tid").unwrap().as_f64() == Some(PROFILE_TID as f64)
                    && e.field("ph").unwrap().as_str() == Some("X")
            })
            .collect();
        assert_eq!(slices.len(), 2, "{s}");
        for e in &slices {
            let name = e.field("name").unwrap().as_str().unwrap();
            assert!(
                name.starts_with("fp32_gemm/") || name.starts_with("packed_w4/"),
                "{name}"
            );
            let bound = e
                .field("args")
                .unwrap()
                .field("bound")
                .unwrap()
                .as_str()
                .unwrap();
            assert!(bound == "memory" || bound == "compute", "{bound}");
        }
        // Plain chrome_trace is unchanged by the profile feature.
        assert!(!chrome_trace(&evs).contains("kernel profile"));
    }

    #[test]
    fn metrics_json_roundtrips() {
        let m = Metrics::new();
        m.record_admitted(1, 0);
        m.record_decode(1, Duration::from_micros(250));
        m.record_latency(Duration::from_millis(3));
        let v = Value::parse(&metrics_json(&m)).expect("valid JSON");
        assert_eq!(v.field("requests").unwrap().as_usize(), Some(1));
        assert_eq!(v.field("completed").unwrap().as_usize(), Some(1));
        let h = v.field("decode_step_us").unwrap();
        assert_eq!(h.field("count").unwrap().as_usize(), Some(1));
        let buckets = h.field("buckets").unwrap().as_arr().unwrap();
        let total: usize = buckets
            .iter()
            .map(|b| b.as_arr().unwrap()[2].as_usize().unwrap())
            .sum();
        assert_eq!(total, 1);
    }
}
