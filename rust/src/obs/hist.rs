//! HDR-style log-bucketed histograms, atomics only.
//!
//! Values (typically microsecond latencies) are binned into
//! logarithmic buckets with [`SUB_BUCKETS`] linear sub-buckets per
//! octave, giving a bounded ≤ ~3% relative quantization error across
//! the full `u64` range with a fixed 1920-bucket table. Recording is a
//! single relaxed `fetch_add` — safe to call concurrently from every
//! serving thread with no locks and no allocation.
//!
//! This is the single percentile implementation in the repo:
//! [`crate::coordinator::Metrics`] holds three of these (request
//! latency, decode-step time, spec-round time) and
//! [`crate::bench::throughput`] reuses it instead of sorting a `Vec`
//! of samples. Bucket scheme reference: `docs/OBSERVABILITY.md`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Linear sub-buckets per octave (`2^SUB_BITS`).
const SUB_BITS: u32 = 5;
/// Number of linear sub-divisions within each power-of-two octave.
pub const SUB_BUCKETS: usize = 1 << SUB_BITS;
/// Total bucket count: one linear octave for values `< 32` plus 59
/// log octaves covering the rest of the `u64` range.
pub const NUM_BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB_BUCKETS;

/// Bucket index for a value: identity below [`SUB_BUCKETS`], then
/// `(octave, top-5-mantissa-bits)`.
fn bucket_index(v: u64) -> usize {
    if v < SUB_BUCKETS as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros();
        let octave = (msb - SUB_BITS + 1) as usize;
        let sub = ((v >> (msb - SUB_BITS)) & (SUB_BUCKETS as u64 - 1)) as usize;
        octave * SUB_BUCKETS + sub
    }
}

/// Smallest value mapping to bucket `idx`.
fn bucket_lo(idx: usize) -> u64 {
    let octave = idx / SUB_BUCKETS;
    let sub = (idx % SUB_BUCKETS) as u64;
    if octave == 0 {
        sub
    } else {
        (SUB_BUCKETS as u64 + sub) << (octave - 1)
    }
}

/// Largest value mapping to bucket `idx`.
fn bucket_hi(idx: usize) -> u64 {
    let octave = idx / SUB_BUCKETS;
    if octave == 0 {
        bucket_lo(idx)
    } else {
        bucket_lo(idx).saturating_add((1u64 << (octave - 1)) - 1)
    }
}

/// One non-empty histogram bucket: the closed value range it covers
/// and how many samples landed in it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistBucket {
    /// Smallest value in the bucket.
    pub lo: u64,
    /// Largest value in the bucket.
    pub hi: u64,
    /// Number of recorded samples in `[lo, hi]`.
    pub count: u64,
}

/// Concurrent log-bucketed histogram over `u64` samples.
pub struct Hist {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl std::fmt::Debug for Hist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Hist")
            .field("count", &self.count())
            .field("sum", &self.sum())
            .finish()
    }
}

impl Hist {
    /// Empty histogram (fixed [`NUM_BUCKETS`]-entry table).
    pub fn new() -> Self {
        let buckets = (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Hist {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one sample. Lock-free; callable from any thread.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (saturating only at `u64` wrap).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean sample value, `0.0` when empty.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum() as f64 / n as f64
    }

    /// Quantile estimate: midpoint of the bucket containing the
    /// `ceil(q·count)`-th smallest sample (`q` clamped to `[0, 1]`).
    /// Monotone in `q` by construction, so `p50 ≤ p95 ≤ p99` always
    /// holds. Returns `0.0` when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            cum += c;
            if cum >= rank {
                return (bucket_lo(i) as f64 + bucket_hi(i) as f64) / 2.0;
            }
        }
        // Unreachable when count() is consistent with the buckets;
        // fall back to the largest representable midpoint.
        (bucket_lo(NUM_BUCKETS - 1) as f64 + bucket_hi(NUM_BUCKETS - 1) as f64) / 2.0
    }

    /// Median estimate (see [`Hist::percentile`]).
    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    /// 95th-percentile estimate (see [`Hist::percentile`]).
    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    /// 99th-percentile estimate (see [`Hist::percentile`]).
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    /// All non-empty buckets in ascending value order. The bucket
    /// counts sum to [`Hist::count`] exactly (asserted in tests and in
    /// the serve observability suite).
    pub fn nonzero_buckets(&self) -> Vec<HistBucket> {
        let mut out = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 {
                out.push(HistBucket {
                    lo: bucket_lo(i),
                    hi: bucket_hi(i),
                    count: c,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_roundtrips() {
        // Every value maps into a bucket whose [lo, hi] contains it,
        // and lo/hi themselves map back to the same bucket.
        for v in (0u64..2048).chain([4095, 4096, 1 << 20, u64::MAX / 3, u64::MAX]) {
            let i = bucket_index(v);
            assert!(i < NUM_BUCKETS, "index {i} out of range for {v}");
            assert!(bucket_lo(i) <= v && v <= bucket_hi(i), "v={v} i={i}");
            assert_eq!(bucket_index(bucket_lo(i)), i);
            assert_eq!(bucket_index(bucket_hi(i)), i);
        }
    }

    #[test]
    fn buckets_are_contiguous() {
        for i in 1..NUM_BUCKETS {
            assert_eq!(
                bucket_hi(i - 1).saturating_add(1).max(bucket_lo(i)),
                bucket_lo(i),
                "gap or overlap between buckets {} and {}",
                i - 1,
                i
            );
        }
    }

    #[test]
    fn exact_below_linear_range() {
        let h = Hist::new();
        for v in 0..SUB_BUCKETS as u64 {
            h.record(v);
        }
        // Below 32 every value has its own bucket: percentiles exact.
        assert_eq!(h.percentile(1.0 / SUB_BUCKETS as f64), 0.0);
        assert_eq!(h.p50(), 15.0);
        assert_eq!(h.percentile(1.0), 31.0);
    }

    #[test]
    fn percentiles_monotone_and_bounded() {
        let h = Hist::new();
        for i in 0..10_000u64 {
            h.record(i * 37 % 100_000);
        }
        let (p50, p95, p99) = (h.p50(), h.p95(), h.p99());
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // ≤ ~3% relative bucket error at these magnitudes.
        assert!((p50 - 50_000.0).abs() / 50_000.0 < 0.05, "p50={p50}");
        assert!((p95 - 95_000.0).abs() / 95_000.0 < 0.05, "p95={p95}");
        let n: u64 = h.nonzero_buckets().iter().map(|b| b.count).sum();
        assert_eq!(n, h.count());
    }

    #[test]
    fn empty_hist_is_zero() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p99(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.nonzero_buckets().is_empty());
    }

    #[test]
    fn mean_matches_sum() {
        let h = Hist::new();
        for v in [1u64, 2, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.mean(), 2.5);
        assert_eq!(h.sum(), 10);
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = std::sync::Arc::new(Hist::new());
        let per = if cfg!(miri) { 50 } else { 5_000 };
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                crate::sync::thread::spawn_named("hist-test", move || {
                    for i in 0..per {
                        h.record((t * per + i) as u64);
                    }
                })
            })
            .collect();
        for j in hs {
            let _ = j.join();
        }
        assert_eq!(h.count(), 4 * per as u64);
        let n: u64 = h.nonzero_buckets().iter().map(|b| b.count).sum();
        assert_eq!(n, h.count());
    }
}
