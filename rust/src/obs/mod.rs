//! Serving-path observability: span tracing, latency histograms,
//! calibration drift introspection and trace/metrics exporters.
//!
//! TTQ's whole pitch is *on-the-fly* adaptation — per-prompt online
//! calibration and drift-triggered requantization — so the serving
//! path must be able to show its work: when a requant fired, what the
//! per-layer drift looked like, how long quantization stalled decode,
//! and where each request spent its wall time — and, since PR 8, *how
//! close* the served distribution stays to pristine fp32 while it
//! adapts. This module is that layer:
//!
//! - [`clock`] — the [`Clock`] abstraction every serving-path
//!   timestamp goes through (repo-lint R6). A real monotonic clock in
//!   production, a deterministic auto-advancing clock in tests, so
//!   span trees are exactly reproducible.
//! - [`trace`] — a lock-free fixed-capacity span ring buffer
//!   ([`TraceBuffer`]) recording the request lifecycle
//!   (`admit → prefill → decode_step* → spec_round* → requant →
//!   done`). Built on [`crate::sync`] atomics only, so the recorder
//!   itself is model-checked (`rust/tests/loom_obs.rs`).
//! - [`hist`] — HDR-style log-bucketed histograms ([`Hist`]) giving
//!   `Metrics` p50/p95/p99 for request latency, decode-step time and
//!   spec-round time; [`crate::bench::throughput`] reuses the same
//!   implementation instead of sorting a `Vec`.
//! - [`quality`] — online quality probing: KL divergence, top-1
//!   agreement and NLL delta of the served (quantized) logits vs the
//!   pristine fp32 weights ([`QualityProbe`], [`quality::compare`]),
//!   sampled every N committed decode steps by the server.
//! - [`profile`] — the kernel-level performance profiler (PR 9):
//!   per-[`KernelSite`] attribution of pooled kernel time with analytic
//!   FLOP/byte counts, a measured host roofline
//!   ([`profile::HostSpec::measure`]) giving each site an achieved
//!   GFLOP/s + GB/s position and a memory/compute-bound verdict, and
//!   the predicted-vs-measured drift report joined with
//!   [`crate::perfmodel`].
//! - [`requant`] + [`export`] — per-requant introspection records
//!   ([`RequantEvent`]) and exporters: Chrome trace-event JSON
//!   (loadable in Perfetto / `chrome://tracing`), Prometheus-style
//!   text exposition, and a machine-readable JSON metrics snapshot.
//!
//! Format and span taxonomy reference: `docs/OBSERVABILITY.md`.

pub mod clock;
pub mod export;
pub mod hist;
pub mod profile;
pub mod quality;
pub mod requant;
pub mod trace;

pub use clock::Clock;
pub use hist::{Hist, HistBucket};
pub use profile::{KernelCall, KernelKind, KernelSite, Phase, ProfileReport, Profiler};
pub use quality::{ProbeSample, QualityProbe};
pub use requant::RequantEvent;
pub use trace::{SpanKind, TraceBuffer, TraceEvent, ENGINE_SEQ};
