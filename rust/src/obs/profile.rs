//! Kernel-level performance profiler: per-site attribution, measured
//! host roofline, and predicted-vs-measured calibration.
//!
//! The paper's speedup claim rests on decode being **memory-bound** —
//! the roofline `t = max(bytes/BW, flops/FLOPS)` that [`crate::perfmodel`]
//! prices with *published GPU specs*. This module is the measured
//! counterpart for the CPU kernels every token actually runs on:
//!
//! * [`KernelCall`] — one dispatch's identity (kernel kind × shape) plus
//!   **analytic** FLOP and bytes-moved counts, computed from the shape by
//!   the constructors so they scale exactly with `m`, `d_out`, `d_in`
//!   (property-tested).
//! * [`Profiler`] — a lock-free per-site aggregator on [`crate::sync`]
//!   atomics (same discipline as [`crate::obs::TraceBuffer`]): a fixed
//!   open-addressed table of [`KernelSite`] slots accumulating calls,
//!   wall-µs, FLOPs and bytes. Writers never block and never allocate;
//!   the serving phase ([`Phase`]) is a gauge the coordinator sets at
//!   phase boundaries so the pool does not need to know it.
//! * [`HostSpec`] — a one-shot microbenchmark of the *actual machine*:
//!   achieved stream bandwidth and scalar FLOP throughput, the two
//!   ceilings of the measured roofline.
//! * [`ProfileReport`] — the join: per site, achieved GFLOP/s, GB/s,
//!   arithmetic intensity, a roofline [`Bound`] verdict (via
//!   [`crate::perfmodel::roofline_us`] — the same equation the GPU
//!   simulator uses), and the predicted-vs-measured drift ratio. The
//!   report also carries the attribution-coverage invariant: the share
//!   of [`crate::linalg::pool::WorkerPool::kernel_us`] accounted for by
//!   named sites (CI gates this at ≥ 90% — no dark time).
//!
//! Exported through all three exporters (`ttq_kernel_*` Prometheus
//! families, the JSON snapshot, a profile track in the Perfetto trace)
//! and through `benches/kernel_profile.rs` → `BENCH_profile.json`
//! (schema: `docs/BENCHMARKS.md`; methodology: `docs/OBSERVABILITY.md`).

#![forbid(unsafe_code)]

use crate::linalg::simd::Isa;
use crate::obs::Clock;
use crate::perfmodel::{roofline_us, vector_ceiling_gflops, Bound};
use crate::sync::atomic::{AtomicU64, Ordering};

/// What the dispatched kernel computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum KernelKind {
    /// Dense fp32 GEMM / GEMV (`matmul_bt_mt`).
    Fp32Gemm = 0,
    /// Grouped packed low-bit matmul with register dequant
    /// (`packed_matmul_nt`).
    PackedW4 = 1,
    /// Incremental attention over cached K/V (`forward_cached`).
    CachedAttention = 2,
    /// Weight quantize + bit-pack when a packed execution cache misses
    /// (`NativeBackend::packed_for`).
    QuantPack = 3,
}

impl KernelKind {
    /// Stable lowercase label used in exports.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Fp32Gemm => "fp32_gemm",
            KernelKind::PackedW4 => "packed_w4",
            KernelKind::CachedAttention => "cached_attention",
            KernelKind::QuantPack => "quant_pack",
        }
    }

    fn from_u64(v: u64) -> KernelKind {
        match v & 0x3 {
            0 => KernelKind::Fp32Gemm,
            1 => KernelKind::PackedW4,
            2 => KernelKind::CachedAttention,
            _ => KernelKind::QuantPack,
        }
    }
}

/// Which serving phase issued the kernel. Set by the coordinator (and
/// by `specdec::spec_round` around its draft/verify halves) on the
/// [`Profiler`]'s phase gauge; the pool never needs to know it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum Phase {
    /// Batched prompt ingestion.
    Prefill = 0,
    /// Plain cached decode steps.
    Decode = 1,
    /// Speculative drafter proposing tokens.
    SpecDraft = 2,
    /// Full-precision verifier scoring a draft window.
    SpecVerify = 3,
}

impl Phase {
    /// Stable lowercase label used in exports.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::SpecDraft => "spec_draft",
            Phase::SpecVerify => "spec_verify",
        }
    }

    fn from_u64(v: u64) -> Phase {
        match v & 0x3 {
            0 => Phase::Prefill,
            1 => Phase::Decode,
            2 => Phase::SpecDraft,
            _ => Phase::SpecVerify,
        }
    }
}

/// One kernel dispatch: kind, shape, and analytic FLOP / bytes-moved
/// counts. Built by the constructors so the counts are a pure function
/// of the shape (MACs count as 2 FLOPs; fp32 elements as 4 bytes).
#[derive(Clone, Copy, Debug)]
pub struct KernelCall {
    /// Kernel kind.
    pub kind: KernelKind,
    /// Activation rows (1 for a decode GEMV; token count for prefill).
    pub m: usize,
    /// Output features (the chunked axis for GEMV fan-out).
    pub d_out: usize,
    /// Input features / reduction depth (mean attended context for
    /// attention).
    pub d_in: usize,
    /// Analytic floating-point operations (2 per multiply-accumulate).
    pub flops: u64,
    /// Analytic bytes moved: weights or cached K/V streamed plus
    /// activations read and written.
    pub bytes: u64,
    /// Instruction-level dispatch the kernel ran on (scalar unless the
    /// caller stamped the pool's selected ISA via
    /// [`KernelCall::with_isa`]).
    pub isa: Isa,
}

impl KernelCall {
    /// Dense fp32 GEMM `x(m,d_in) · Wᵀ(d_in,d_out)`: weights, input and
    /// output all stream as f32.
    pub fn fp32_gemm(m: usize, d_out: usize, d_in: usize) -> KernelCall {
        KernelCall {
            kind: KernelKind::Fp32Gemm,
            m,
            d_out,
            d_in,
            flops: 2 * (m * d_out * d_in) as u64,
            bytes: 4 * (d_out * d_in + m * d_in + m * d_out) as u64,
            isa: Isa::Scalar,
        }
    }

    /// Packed low-bit matmul: weights stream as `bits`-bit codes plus one
    /// f32 scale + zero per `group` columns per row; activations as f32.
    pub fn packed_w4(m: usize, d_out: usize, d_in: usize, bits: u32, group: usize) -> KernelCall {
        let code_bytes = d_out * (d_in * bits as usize).div_ceil(8);
        let meta_bytes = d_out * d_in.div_ceil(group.max(1)) * 8; // f32 scale + f32 zero
        KernelCall {
            kind: KernelKind::PackedW4,
            m,
            d_out,
            d_in,
            flops: 2 * (m * d_out * d_in) as u64,
            bytes: (code_bytes + meta_bytes + 4 * (m * d_in + m * d_out)) as u64,
            isa: Isa::Scalar,
        }
    }

    /// Incremental cached attention: `rows` fresh query positions over
    /// `ctx_total` attended (query, key) pairs of width `d_attn`. QKᵀ
    /// and the V-weighted sum each cost one MAC per attended pair per
    /// channel; K and V rows of the prefix stream from the cache.
    pub fn cached_attention(rows: usize, d_attn: usize, ctx_total: usize) -> KernelCall {
        KernelCall {
            kind: KernelKind::CachedAttention,
            m: rows,
            d_out: d_attn,
            d_in: ctx_total / rows.max(1),
            flops: 4 * (ctx_total * d_attn) as u64,
            bytes: 4 * (2 * ctx_total * d_attn + 2 * rows * d_attn) as u64,
            isa: Isa::Scalar,
        }
    }

    /// Weight quantize + pack on a packed-cache miss: the fp32 weight is
    /// read, quantized (one scale/round/clamp pass) and written back as
    /// codes + group metadata.
    pub fn quant_pack(d_out: usize, d_in: usize, bits: u32, group: usize) -> KernelCall {
        let code_bytes = d_out * (d_in * bits as usize).div_ceil(8);
        let meta_bytes = d_out * d_in.div_ceil(group.max(1)) * 8;
        KernelCall {
            kind: KernelKind::QuantPack,
            m: 1,
            d_out,
            d_in,
            flops: 2 * (d_out * d_in) as u64,
            bytes: (4 * d_out * d_in + code_bytes + meta_bytes) as u64,
            isa: Isa::Scalar,
        }
    }

    /// Stamp the instruction-level dispatch (the pool's selected
    /// [`Isa`]) onto this call — `backend::native` does this for every
    /// kernel whose inner loops went through `linalg::simd`, so
    /// roofline verdicts can tell scalar from vector sites.
    pub fn with_isa(mut self, isa: Isa) -> KernelCall {
        self.isa = isa;
        self
    }
}

/// Power-of-two shape bucket: 0 → 0, else the next power of two ≥ `v`.
/// Keeps the site table small while preserving the decode-vs-prefill
/// shape distinction (m=1 GEMV vs m=512 GEMM land in different sites).
pub fn shape_bucket(v: usize) -> usize {
    if v == 0 {
        0
    } else {
        v.next_power_of_two()
    }
}

fn bucket_log2(v: usize) -> u64 {
    // 0 → 0, else 1 + log2(next_power_of_two(v)) so bucket 1 (v=1) and
    // "no extent" (v=0) stay distinct. Fits in 6 bits for any usize
    // shape this crate can allocate.
    if v == 0 {
        0
    } else {
        1 + shape_bucket(v).trailing_zeros() as u64
    }
}

fn bucket_from_log2(l: u64) -> usize {
    if l == 0 {
        0
    } else {
        1usize << (l - 1)
    }
}

/// A profiler table key: kernel kind × serving phase × shape bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct KernelSite {
    /// Kernel kind.
    pub kind: KernelKind,
    /// Serving phase that issued the dispatch.
    pub phase: Phase,
    /// Power-of-two bucket of the activation-row count `m`.
    pub m_bucket: usize,
    /// Power-of-two bucket of `d_out`.
    pub d_out_bucket: usize,
    /// Power-of-two bucket of `d_in`.
    pub d_in_bucket: usize,
    /// Instruction-level dispatch the site's kernels ran on — scalar
    /// and vector dispatches of the same shape are distinct sites, so
    /// roofline verdicts never average across ISAs.
    pub isa: Isa,
}

impl KernelSite {
    /// Build the site key for a call observed in `phase`.
    pub fn new(call: &KernelCall, phase: Phase) -> KernelSite {
        KernelSite {
            kind: call.kind,
            phase,
            m_bucket: shape_bucket(call.m),
            d_out_bucket: shape_bucket(call.d_out),
            d_in_bucket: shape_bucket(call.d_in),
            isa: call.isa,
        }
    }

    /// Stable label used across every exporter:
    /// `kind/phase/m{mb}xdo{ob}xdi{ib}/{isa}`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/m{}xdo{}xdi{}/{}",
            self.kind.name(),
            self.phase.name(),
            self.m_bucket,
            self.d_out_bucket,
            self.d_in_bucket,
            self.isa.name()
        )
    }

    /// Pack into a non-zero u64 table key (bit 63 set so an empty slot,
    /// key 0, can never collide with a real site).
    fn encode(&self) -> u64 {
        (1u64 << 63)
            | (self.kind as u64)
            | ((self.phase as u64) << 2)
            | (bucket_log2(self.m_bucket) << 4)
            | (bucket_log2(self.d_out_bucket) << 10)
            | (bucket_log2(self.d_in_bucket) << 16)
            | (self.isa.index() << 22)
    }

    fn decode(key: u64) -> KernelSite {
        KernelSite {
            kind: KernelKind::from_u64(key),
            phase: Phase::from_u64(key >> 2),
            m_bucket: bucket_from_log2((key >> 4) & 0x3f),
            d_out_bucket: bucket_from_log2((key >> 10) & 0x3f),
            d_in_bucket: bucket_from_log2((key >> 16) & 0x3f),
            isa: Isa::from_index(key >> 22),
        }
    }
}

/// Open-addressed table size. 4 kinds × 4 phases × a handful of shape
/// buckets per model is far below this; overflow is counted, never
/// blocks.
const SITE_SLOTS: usize = 256;

struct SiteSlot {
    /// 0 = empty; otherwise a [`KernelSite::encode`] key (bit 63 set).
    key: AtomicU64,
    calls: AtomicU64,
    wall_us: AtomicU64,
    flops: AtomicU64,
    bytes: AtomicU64,
}

/// Accumulated raw counters for one site (one aggregator slot).
#[derive(Clone, Copy, Debug)]
pub struct SiteStats {
    /// The site key.
    pub site: KernelSite,
    /// Dispatches recorded.
    pub calls: u64,
    /// Wall time across those dispatches, microseconds.
    pub wall_us: u64,
    /// Analytic floating-point operations.
    pub flops: u64,
    /// Analytic bytes moved.
    pub bytes: u64,
}

/// Lock-free per-site aggregator. Writers CAS-claim a slot on first
/// sight of a site, then only issue `Relaxed` counter adds — the same
/// monotone-counter discipline as [`crate::coordinator::Metrics`], on
/// the [`crate::sync`] atomics so the loom build can instrument it.
pub struct Profiler {
    slots: Vec<SiteSlot>,
    /// Current serving [`Phase`] gauge (set at phase boundaries).
    phase: AtomicU64,
    /// Dispatches dropped because the site table was full (never
    /// expected; exported so silent truncation is impossible).
    dropped: AtomicU64,
}

impl Default for Profiler {
    fn default() -> Self {
        Profiler::new()
    }
}

impl Profiler {
    /// Empty aggregator; phase gauge starts at [`Phase::Prefill`].
    pub fn new() -> Profiler {
        Profiler {
            slots: (0..SITE_SLOTS)
                .map(|_| SiteSlot {
                    key: AtomicU64::new(0),
                    calls: AtomicU64::new(0),
                    wall_us: AtomicU64::new(0),
                    flops: AtomicU64::new(0),
                    bytes: AtomicU64::new(0),
                })
                .collect(),
            phase: AtomicU64::new(Phase::Prefill as u64),
            dropped: AtomicU64::new(0),
        }
    }

    /// Set the serving-phase gauge; every subsequently recorded call is
    /// attributed to `phase` until the next call.
    pub fn set_phase(&self, phase: Phase) {
        self.phase.store(phase as u64, Ordering::Relaxed);
    }

    /// The current serving-phase gauge.
    pub fn phase(&self) -> Phase {
        Phase::from_u64(self.phase.load(Ordering::Relaxed))
    }

    /// Record one dispatch: `call`'s analytic counts plus its measured
    /// wall time, attributed to the current phase gauge. Lock-free:
    /// linear-probes the table, CAS-claims an empty slot on first sight
    /// of a site, then adds with `Relaxed` (monotone counters — readers
    /// only ever see a slight undercount mid-add, never a torn value).
    pub fn record(&self, call: &KernelCall, wall_us: u64) {
        let site = KernelSite::new(call, self.phase());
        let key = site.encode();
        let n = self.slots.len();
        let mut idx = (key as usize).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 48;
        for _ in 0..n {
            let slot = &self.slots[idx % n];
            let cur = slot.key.load(Ordering::Acquire);
            let claimed = if cur == key {
                true
            } else if cur == 0 {
                // Claim the slot; a racing claimer of the *same* key is
                // fine (we land in its slot), of a different key sends
                // us to the next probe.
                match slot.key.compare_exchange(0, key, Ordering::AcqRel, Ordering::Acquire) {
                    Ok(_) => true,
                    Err(actual) => actual == key,
                }
            } else {
                false
            };
            if claimed {
                slot.calls.fetch_add(1, Ordering::Relaxed);
                slot.wall_us.fetch_add(wall_us, Ordering::Relaxed);
                slot.flops.fetch_add(call.flops, Ordering::Relaxed);
                slot.bytes.fetch_add(call.bytes, Ordering::Relaxed);
                return;
            }
            idx += 1;
        }
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Dispatches dropped on a full site table (0 in any sane run).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Copy out all live sites, sorted by wall time (descending), ties
    /// by site key so the order is deterministic.
    pub fn snapshot(&self) -> Vec<SiteStats> {
        let mut out: Vec<SiteStats> = self
            .slots
            .iter()
            .filter(|s| s.key.load(Ordering::Acquire) != 0)
            .map(|s| SiteStats {
                site: KernelSite::decode(s.key.load(Ordering::Acquire)),
                calls: s.calls.load(Ordering::Relaxed),
                wall_us: s.wall_us.load(Ordering::Relaxed),
                flops: s.flops.load(Ordering::Relaxed),
                bytes: s.bytes.load(Ordering::Relaxed),
            })
            .collect();
        out.sort_by(|a, b| b.wall_us.cmp(&a.wall_us).then(a.site.cmp(&b.site)));
        out
    }

    /// Join the aggregated sites with the measured host roofline into a
    /// [`ProfileReport`]. `kernel_us` is the pool's cumulative kernel
    /// wall time (the attribution-coverage denominator).
    pub fn report(&self, host: &HostSpec, kernel_us: u64) -> ProfileReport {
        let sites: Vec<SiteReport> =
            self.snapshot().iter().map(|s| SiteReport::from_stats(s, host)).collect();
        let attributed_us = sites.iter().map(|s| s.measured_us).sum();
        ProfileReport { host: *host, kernel_us, attributed_us, dropped: self.dropped(), sites }
    }
}

/// Measured ceilings of the host machine: the two roofs of the roofline.
#[derive(Clone, Copy, Debug)]
pub struct HostSpec {
    /// Achieved peak stream bandwidth, GB/s (large-buffer scale pass).
    pub bw_gbps: f64,
    /// Achieved scalar f32 FLOP throughput, GFLOP/s (dependent-FMA-free
    /// accumulator loop).
    pub gflops: f64,
}

impl HostSpec {
    /// A fixed synthetic spec for deterministic tests — no measurement,
    /// no wall-clock dependence.
    pub fn synthetic(bw_gbps: f64, gflops: f64) -> HostSpec {
        HostSpec { bw_gbps, gflops }
    }

    /// One-shot microbenchmark of the actual machine: best-of-3 stream
    /// scale pass over a cache-busting f32 buffer for bandwidth, and a
    /// best-of-3 independent-accumulator multiply-add loop for scalar
    /// FLOP throughput. Takes a few tens of milliseconds; callers cache
    /// the result (see [`HostSpec::measured`]).
    pub fn measure() -> HostSpec {
        let clock = Clock::real();
        // -- stream bandwidth: y[i] = a * x[i] over 8M f32 (32 MiB read
        //    + 32 MiB write per pass, far past any L3).
        let n = 8 << 20;
        let x = vec![1.000_1f32; n];
        let mut y = vec![0.0f32; n];
        let mut best_bw = 0.0f64;
        for pass in 0..3 {
            let a = 1.0 + pass as f32 * 1e-6;
            let t0 = clock.now_us();
            for (yi, xi) in y.iter_mut().zip(x.iter()) {
                *yi = a * *xi;
            }
            let dt = clock.now_us().saturating_sub(t0).max(1);
            let bytes = (n * 8) as f64;
            best_bw = best_bw.max(bytes / dt as f64 / 1e3); // bytes/us → GB/s
        }
        // -- scalar FLOP throughput: 8 independent accumulators so the
        //    multiply-add chain is latency-hiding, 2 FLOPs per update.
        let mut acc = [1.0f32, 1.1, 1.2, 1.3, 1.4, 1.5, 1.6, 1.7];
        let iters = 4_000_000usize;
        let mut best_fl = 0.0f64;
        for _ in 0..3 {
            let t0 = clock.now_us();
            for i in 0..iters {
                let c = 1.0 + (i & 7) as f32 * 1e-9;
                for a in acc.iter_mut() {
                    *a = a.mul_add(c, 1e-9);
                }
            }
            let dt = clock.now_us().saturating_sub(t0).max(1);
            let flops = (iters * acc.len() * 2) as f64;
            best_fl = best_fl.max(flops / dt as f64 / 1e3); // flops/us → GFLOP/s
        }
        // Keep the sink live so the FLOP loop cannot be elided.
        let sink: f32 = acc.iter().sum();
        let fuzz = if sink.is_finite() { 0.0 } else { 1e-12 };
        HostSpec { bw_gbps: best_bw.max(1e-3) + fuzz, gflops: best_fl.max(1e-3) }
    }

    /// The machine's measured spec, cached process-wide so the
    /// microbenchmark runs at most once.
    pub fn measured() -> HostSpec {
        static CACHE: crate::sync::OnceLock<HostSpec> = crate::sync::OnceLock::new();
        *CACHE.get_or_init(HostSpec::measure)
    }

    /// Machine balance: FLOPs per byte at the roofline ridge point.
    pub fn balance(&self) -> f64 {
        self.gflops / self.bw_gbps
    }
}

/// One site joined with the measured roofline and the model prediction.
#[derive(Clone, Debug)]
pub struct SiteReport {
    /// The site key.
    pub site: KernelSite,
    /// Dispatches recorded.
    pub calls: u64,
    /// Analytic floating-point operations.
    pub flops: u64,
    /// Analytic bytes moved.
    pub bytes: u64,
    /// Measured wall time across all dispatches, microseconds.
    pub measured_us: u64,
    /// Achieved GFLOP/s (`flops / measured_us / 1e3`).
    pub gflops: f64,
    /// Achieved GB/s (`bytes / measured_us / 1e3`).
    pub gbps: f64,
    /// Arithmetic intensity, FLOPs per byte.
    pub intensity: f64,
    /// Which roof limits this site on the measured host.
    pub bound: Bound,
    /// Roofline-predicted wall time on the measured host, microseconds.
    pub predicted_us: f64,
    /// Calibration drift: `measured_us / predicted_us` (> 1 means the
    /// kernel runs slower than the roofline allows).
    pub ratio: f64,
}

impl SiteReport {
    fn from_stats(s: &SiteStats, host: &HostSpec) -> SiteReport {
        let us = s.wall_us.max(1) as f64;
        let intensity = s.flops as f64 / (s.bytes.max(1)) as f64;
        // The host FLOP ceiling is measured with the scalar probe; a
        // vector site's compute roof is `lanes()`× higher, so scale it
        // per ISA or every AVX2 site would look implausibly fast and
        // the Bound verdict would flip to Compute too early.
        let ceil_gflops = vector_ceiling_gflops(host.gflops, s.site.isa.lanes());
        let predicted_us = roofline_us(host.bw_gbps, ceil_gflops, s.flops as f64, s.bytes as f64);
        // Roofline knee at the ISA-scaled ceiling: flop/byte below
        // `ceil_gflops / bw` streams slower than it computes.
        let bound = if intensity < ceil_gflops / host.bw_gbps {
            Bound::Memory
        } else {
            Bound::Compute
        };
        SiteReport {
            site: s.site,
            calls: s.calls,
            flops: s.flops,
            bytes: s.bytes,
            measured_us: s.wall_us,
            gflops: s.flops as f64 / us / 1e3,
            gbps: s.bytes as f64 / us / 1e3,
            intensity,
            bound,
            predicted_us,
            ratio: s.wall_us as f64 / predicted_us.max(1e-9),
        }
    }
}

/// The full drift report: measured host spec, per-site rows, and the
/// attribution-coverage invariant.
#[derive(Clone, Debug)]
pub struct ProfileReport {
    /// Measured (or synthetic) host ceilings used for every verdict.
    pub host: HostSpec,
    /// The pool's cumulative kernel wall time (coverage denominator).
    pub kernel_us: u64,
    /// Σ site `measured_us` (coverage numerator).
    pub attributed_us: u64,
    /// Dispatches dropped on a full site table (0 in any sane run).
    pub dropped: u64,
    /// Per-site rows, sorted by wall time descending.
    pub sites: Vec<SiteReport>,
}

impl ProfileReport {
    /// Fraction of pooled kernel wall time attributed to named sites,
    /// in `[0, 1]`-ish (timer granularity can push it slightly past 1).
    /// CI gates this at ≥ 0.90 — no dark time.
    pub fn coverage(&self) -> f64 {
        if self.kernel_us == 0 {
            1.0
        } else {
            self.attributed_us as f64 / self.kernel_us as f64
        }
    }

    /// Merge another report's sites into this one (summing counters and
    /// re-deriving rates against this report's host spec) — used by the
    /// bench to fold the per-scenario profilers into one table.
    pub fn merge(&mut self, other: &ProfileReport) {
        self.kernel_us += other.kernel_us;
        self.attributed_us += other.attributed_us;
        self.dropped += other.dropped;
        for o in &other.sites {
            let stats = SiteStats {
                site: o.site,
                calls: o.calls,
                wall_us: o.measured_us,
                flops: o.flops,
                bytes: o.bytes,
            };
            if let Some(mine) = self.sites.iter_mut().find(|s| s.site == o.site) {
                let merged = SiteStats {
                    site: mine.site,
                    calls: mine.calls + stats.calls,
                    wall_us: mine.measured_us + stats.wall_us,
                    flops: mine.flops + stats.flops,
                    bytes: mine.bytes + stats.bytes,
                };
                *mine = SiteReport::from_stats(&merged, &self.host);
            } else {
                self.sites.push(SiteReport::from_stats(&stats, &self.host));
            }
        }
        self.sites.sort_by(|a, b| {
            b.measured_us.cmp(&a.measured_us).then(a.site.cmp(&b.site))
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_counts_follow_shape() {
        let c = KernelCall::fp32_gemm(4, 8, 16);
        assert_eq!(c.flops, 2 * 4 * 8 * 16);
        assert_eq!(c.bytes, 4 * (8 * 16 + 4 * 16 + 4 * 8));
        let p = KernelCall::packed_w4(1, 8, 64, 4, 32);
        assert_eq!(p.flops, 2 * 8 * 64);
        // 4-bit codes: 64*4/8 = 32 B/row; 2 groups × 8 B meta/row.
        assert_eq!(p.bytes, (8 * 32 + 8 * 2 * 8 + 4 * (64 + 8)) as u64);
        let a = KernelCall::cached_attention(2, 16, 20);
        assert_eq!(a.flops, 4 * 20 * 16);
        assert_eq!(a.bytes, 4 * (2 * 20 * 16 + 2 * 2 * 16));
        assert_eq!(a.d_in, 10, "d_in is the mean attended context");
        let q = KernelCall::quant_pack(8, 64, 4, 32);
        assert_eq!(q.flops, 2 * 8 * 64);
        assert_eq!(q.bytes, (4 * 8 * 64 + 8 * 32 + 8 * 2 * 8) as u64);
    }

    #[test]
    fn flop_byte_counts_scale_exactly_with_shape() {
        // Property: doubling m doubles GEMM flops and the activation
        // byte terms exactly; doubling d_in doubles the reduction.
        crate::util::propcheck::check(
            "profile_counts_scale",
            &crate::util::propcheck::Config { cases: 200, seed: 0x9e37 },
            |g| {
                let m = g.usize_in(1, 64);
                let d_out = g.usize_in(1, 256);
                let d_in = g.usize_in(1, 256);
                let c1 = KernelCall::fp32_gemm(m, d_out, d_in);
                let c2m = KernelCall::fp32_gemm(2 * m, d_out, d_in);
                let c2i = KernelCall::fp32_gemm(m, d_out, 2 * d_in);
                let c2o = KernelCall::fp32_gemm(m, 2 * d_out, d_in);
                crate::prop_assert!(c2m.flops == 2 * c1.flops, "flops linear in m");
                crate::prop_assert!(c2i.flops == 2 * c1.flops, "flops linear in d_in");
                crate::prop_assert!(c2o.flops == 2 * c1.flops, "flops linear in d_out");
                let w1 = 4 * (d_out * d_in) as u64;
                let w2 = 4 * (2 * d_out * d_in) as u64;
                crate::prop_assert!(
                    c2o.bytes == w2 + 4 * (m * d_in + m * 2 * d_out) as u64,
                    "weight + activation byte terms follow d_out"
                );
                crate::prop_assert!(
                    c2m.bytes == w1 + 4 * (2 * m * d_in + 2 * m * d_out) as u64,
                    "activation bytes linear in m"
                );
                // packed: flops identical to dense, bytes strictly fewer
                // for 4-bit weights at any shape with d_in ≥ group.
                let p = KernelCall::packed_w4(m, d_out, d_in.max(32), 4, 32);
                let d = KernelCall::fp32_gemm(m, d_out, d_in.max(32));
                crate::prop_assert!(p.flops == d.flops, "packed flops match dense");
                crate::prop_assert!(p.bytes < d.bytes, "packed moves fewer bytes");
                Ok(())
            },
        );
    }

    #[test]
    fn site_key_roundtrips() {
        for kind in [
            KernelKind::Fp32Gemm,
            KernelKind::PackedW4,
            KernelKind::CachedAttention,
            KernelKind::QuantPack,
        ] {
            for phase in [Phase::Prefill, Phase::Decode, Phase::SpecDraft, Phase::SpecVerify] {
                for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
                    for (m, o, i) in [(0, 1, 1), (1, 512, 64), (64, 4096, 4096), (513, 100, 3)] {
                        let s = KernelSite {
                            kind,
                            phase,
                            m_bucket: shape_bucket(m),
                            d_out_bucket: shape_bucket(o),
                            d_in_bucket: shape_bucket(i),
                            isa,
                        };
                        assert_eq!(KernelSite::decode(s.encode()), s, "roundtrip {s:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn aggregator_accumulates_per_site() {
        let p = Profiler::new();
        let gemv = KernelCall::fp32_gemm(1, 512, 64);
        let gemm = KernelCall::fp32_gemm(64, 512, 64);
        p.set_phase(Phase::Prefill);
        p.record(&gemm, 100);
        p.set_phase(Phase::Decode);
        p.record(&gemv, 10);
        p.record(&gemv, 12);
        let snap = p.snapshot();
        assert_eq!(snap.len(), 2);
        // sorted by wall time: the prefill GEMM leads
        assert_eq!(snap[0].site.phase, Phase::Prefill);
        assert_eq!(snap[0].calls, 1);
        assert_eq!(snap[0].wall_us, 100);
        assert_eq!(snap[1].site.phase, Phase::Decode);
        assert_eq!(snap[1].calls, 2);
        assert_eq!(snap[1].wall_us, 22);
        assert_eq!(snap[1].flops, 2 * gemv.flops);
        assert_eq!(snap[1].bytes, 2 * gemv.bytes);
        assert_eq!(p.dropped(), 0);
    }

    #[test]
    fn roofline_verdicts_from_synthetic_host() {
        // Host: 10 GB/s, 100 GFLOP/s → balance 10 FLOP/byte.
        let host = HostSpec::synthetic(10.0, 100.0);
        let p = Profiler::new();
        p.set_phase(Phase::Decode);
        // decode GEMV: intensity ≈ 0.5 FLOP/byte → memory-bound
        p.record(&KernelCall::fp32_gemm(1, 512, 512), 50);
        p.set_phase(Phase::Prefill);
        // big GEMM: intensity ≈ 2·m·o·i / 4(oi+mi+mo) ≈ 170 → compute-bound
        p.record(&KernelCall::fp32_gemm(512, 512, 512), 5000);
        let rep = p.report(&host, 5050);
        assert_eq!(rep.sites.len(), 2);
        let gemv = rep.sites.iter().find(|s| s.site.m_bucket == 1).unwrap();
        let gemm = rep.sites.iter().find(|s| s.site.m_bucket == 512).unwrap();
        assert_eq!(gemv.bound, Bound::Memory, "decode GEMV is memory-bound");
        assert_eq!(gemm.bound, Bound::Compute, "prefill GEMM is compute-bound");
        assert!(gemv.intensity < host.balance() && gemm.intensity > host.balance());
        // predicted: gemv bytes ≈ 4·(512·512 + 512 + 512) ≈ 1.05 MB at
        // 10 GB/s ≈ 105 us (memory roof binds)
        assert!(gemv.predicted_us > 0.0 && gemv.ratio > 0.0);
        assert!((rep.coverage() - 1.0).abs() < 0.02);
    }

    #[test]
    fn report_merge_sums_sites() {
        let host = HostSpec::synthetic(10.0, 100.0);
        let mk = |wall: u64| {
            let p = Profiler::new();
            p.set_phase(Phase::Decode);
            p.record(&KernelCall::fp32_gemm(1, 512, 512), wall);
            p.report(&host, wall)
        };
        let mut a = mk(10);
        let b = mk(30);
        a.merge(&b);
        assert_eq!(a.sites.len(), 1);
        assert_eq!(a.sites[0].calls, 2);
        assert_eq!(a.sites[0].measured_us, 40);
        assert_eq!(a.kernel_us, 40);
        assert!((a.coverage() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_replay_identical_snapshots() {
        let run = || {
            let p = Profiler::new();
            for step in 0..50u64 {
                p.set_phase(if step % 5 == 0 { Phase::Prefill } else { Phase::Decode });
                p.record(&KernelCall::fp32_gemm(1 + (step % 3) as usize, 512, 64), 7);
                p.record(&KernelCall::packed_w4(1, 512, 64, 4, 32), 3);
            }
            p.snapshot()
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.site, y.site);
            assert_eq!((x.calls, x.wall_us, x.flops, x.bytes), (y.calls, y.wall_us, y.flops, y.bytes));
        }
    }

    #[test]
    fn concurrent_records_never_lose_counts() {
        use crate::sync::Arc;
        let p = Arc::new(Profiler::new());
        let threads = 4;
        let per = if cfg!(any(miri, ttq_sanitize)) { 50 } else { 2000 };
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let p = Arc::clone(&p);
                crate::sync::thread::spawn_named(&format!("prof-{t}"), move || {
                    for i in 0..per {
                        p.record(&KernelCall::fp32_gemm(1 + (i % 4), 128, 128), 1);
                    }
                })
            })
            .collect();
        for h in handles {
            let _ = h.join();
        }
        let snap = p.snapshot();
        let total: u64 = snap.iter().map(|s| s.calls).sum();
        assert_eq!(total + p.dropped(), (threads * per) as u64, "no lost dispatches");
        assert_eq!(p.dropped(), 0, "table never fills at 4 shapes");
    }

    #[test]
    fn synthetic_host_balance() {
        let h = HostSpec::synthetic(20.0, 60.0);
        assert!((h.balance() - 3.0).abs() < 1e-12);
    }
}
