//! Online quality probing: how far is the *served* (quantized,
//! possibly mid-requant) model's next-token distribution from the
//! pristine fp32 weights, measured while requests decode.
//!
//! The serving half lives in the coordinator: every N committed decode
//! steps (`ServerConfig::probe_every`, [`QualityProbe`] owns the
//! cadence) the server replays **one** rotating sampled sequence's
//! exact prefix through a plain fp32 backend holding
//! `Evaluator::pristine_weights`, then scores the served logits row
//! against the reference row with [`compare`]:
//!
//! * **KL divergence** `KL(fp32 ‖ served)` over the full softmax — the
//!   llama.cpp-style headline quality number (reference distribution
//!   first, so mass the fp32 model cares about dominates);
//! * **top-1 agreement** — would greedy decoding have picked the same
//!   token;
//! * **NLL delta** — extra nats the served model charges the token it
//!   actually committed, versus what fp32 would have charged.
//!
//! Samples land in [`crate::obs::Hist`]s on the server `Metrics`
//! (KL and NLL-delta in **nanonats** — the histograms count `u64`s, so
//! sub-nat divergences are stored fixed-point via [`nanonats`]) and as
//! `SpanKind::Probe` spans on the trace ring, putting drift, requant
//! and quality recovery on one Perfetto timeline. The offline half —
//! the Pareto harness scoring every method against recorded fp32
//! logits — reuses the same [`kl_divergence`] in
//! [`crate::bench::quality`]. Design notes: `docs/OBSERVABILITY.md`.

#![forbid(unsafe_code)]

use crate::util::{argmax, logsumexp};

/// One scored probe comparison between a served logits row and its
/// fp32 reference row. All divergences are in nats.
#[derive(Clone, Copy, Debug)]
pub struct ProbeSample {
    /// `KL(fp32 ‖ served)` over the full vocabulary softmax. Always
    /// ≥ 0 (clamped against rounding in the last bit).
    pub kl: f64,
    /// True when both rows argmax to the same token — greedy decoding
    /// would have been unaffected by quantization at this step.
    pub top1_agree: bool,
    /// `nll_served(tok) − nll_fp32(tok)` for the committed token: the
    /// extra nats quantization charged the token the server actually
    /// emitted. Positive when the served model is less confident than
    /// fp32 about its own choice; can be (slightly) negative.
    pub nll_delta: f64,
}

/// `KL(reference ‖ served)` in nats between two same-length logit
/// rows, computed over the full softmax with f64 accumulation via the
/// shared [`logsumexp`]. Returns 0 for empty or all-`-inf` rows, and
/// clamps tiny negative rounding residue to exactly 0, so the result
/// is always ≥ 0 for finite inputs (property-tested below).
pub fn kl_divergence(reference: &[f32], served: &[f32]) -> f64 {
    debug_assert_eq!(reference.len(), served.len());
    let lse_p = logsumexp(reference);
    let lse_q = logsumexp(served);
    if !lse_p.is_finite() || !lse_q.is_finite() {
        return 0.0;
    }
    let mut kl = 0.0f64;
    for (&pl, &ql) in reference.iter().zip(served.iter()) {
        let lp = pl as f64 - lse_p; // log p_i
        let p = lp.exp();
        if p > 0.0 {
            let lq = ql as f64 - lse_q; // log q_i
            kl += p * (lp - lq);
        }
    }
    kl.max(0.0)
}

/// Score one served row against its fp32 reference row. `committed` is
/// the token index the server emitted for this step (clamped rows with
/// `committed` out of range yield `nll_delta = 0`).
pub fn compare(reference: &[f32], served: &[f32], committed: usize) -> ProbeSample {
    let kl = kl_divergence(reference, served);
    let top1_agree = !reference.is_empty() && argmax(reference) == argmax(served);
    let nll_delta = if committed < reference.len() && committed < served.len() {
        let nll_served = logsumexp(served) - served[committed] as f64;
        let nll_ref = logsumexp(reference) - reference[committed] as f64;
        nll_served - nll_ref
    } else {
        0.0
    };
    ProbeSample {
        kl,
        top1_agree,
        nll_delta,
    }
}

/// Fixed-point nats → nanonats for the `u64`-valued histograms:
/// `round(max(x, 0) · 1e9)`, saturating at `u64::MAX`. Negative and
/// NaN inputs map to 0 — the histograms track *regressions*, so the
/// occasional sub-zero NLL delta is clamped rather than wrapped (the
/// clamp is part of the export contract, see `docs/OBSERVABILITY.md`).
pub fn nanonats(x: f64) -> u64 {
    // `as` casts saturate (and NaN → 0) since Rust 1.45.
    (x.max(0.0) * 1e9).round() as u64
}

/// Sampling cadence for the online probe: fire on every `every`-th
/// committed decode step (0 disables). Pure counter logic — the server
/// owns the replay machinery; this owns *when*.
#[derive(Clone, Debug)]
pub struct QualityProbe {
    every: usize,
    steps: u64,
}

impl QualityProbe {
    /// Probe every `every` committed decode steps; 0 never fires.
    pub fn new(every: usize) -> Self {
        QualityProbe { every, steps: 0 }
    }

    /// True when this probe can ever fire.
    pub fn enabled(&self) -> bool {
        self.every > 0
    }

    /// The configured cadence (0 = disabled).
    pub fn every(&self) -> usize {
        self.every
    }

    /// Committed decode steps observed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Count one committed decode step; true when this step is a probe
    /// step (the `every`-th, `2·every`-th, … step observed).
    pub fn tick(&mut self) -> bool {
        if self.every == 0 {
            return false;
        }
        self.steps += 1;
        self.steps % self.every as u64 == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck::{check, Config, Gen};

    /// Directly computed discrete KL between two explicit probability
    /// vectors, for goldens.
    fn kl_explicit(p: &[f64], q: &[f64]) -> f64 {
        p.iter().zip(q).map(|(&pi, &qi)| pi * (pi / qi).ln()).sum()
    }

    #[test]
    fn golden_kl_hand_computed() {
        // P = softmax([0, 0]) = [1/2, 1/2];
        // Q = softmax([ln 3, 0]) = [3/4, 1/4].
        // KL(P‖Q) = ½·ln(½ ÷ ¾) + ½·ln(½ ÷ ¼) = ½·ln(4/3)
        //         = 0.14384103622589045…
        let got = kl_divergence(&[0.0, 0.0], &[3.0f32.ln(), 0.0]);
        assert!((got - 0.143_841_036_225_890_45).abs() < 1e-9, "{got}");
        // and it matches the explicit discrete form
        let explicit = kl_explicit(&[0.5, 0.5], &[0.75, 0.25]);
        assert!((got - explicit).abs() < 1e-12);
    }

    #[test]
    fn kl_of_identical_rows_is_zero() {
        let row = [1.5f32, -0.25, 3.0, 0.0, -7.5];
        assert_eq!(kl_divergence(&row, &row), 0.0);
    }

    #[test]
    fn kl_degenerate_rows_are_zero() {
        assert_eq!(kl_divergence(&[], &[]), 0.0);
        let ninf = [f32::NEG_INFINITY; 4];
        assert_eq!(kl_divergence(&ninf, &[0.0; 4]), 0.0);
    }

    #[test]
    fn prop_kl_nonnegative_and_self_zero() {
        check("kl_nonnegative", &Config::default(), |g: &mut Gen| {
            let n = g.usize_in(1, 40);
            let p = g.vec_f32_adversarial(n);
            let q = g.vec_f32_adversarial(n);
            let kl = kl_divergence(&p, &q);
            prop_assert!(kl >= 0.0, "KL(p‖q) = {kl} < 0");
            let self_kl = kl_divergence(&p, &p);
            prop_assert!(self_kl.abs() < 1e-9, "KL(p‖p) = {self_kl} != 0");
            Ok(())
        });
    }

    #[test]
    fn prop_kl_invariant_under_uniform_logit_shift() {
        check("kl_shift_invariant", &Config::default(), |g: &mut Gen| {
            let n = g.usize_in(2, 24);
            let p: Vec<f32> = (0..n).map(|_| g.f32_normal()).collect();
            let q: Vec<f32> = (0..n).map(|_| g.f32_normal()).collect();
            let cp = g.f32_normal() * 10.0;
            let cq = g.f32_normal() * 10.0;
            let base = kl_divergence(&p, &q);
            let ps: Vec<f32> = p.iter().map(|v| v + cp).collect();
            let qs: Vec<f32> = q.iter().map(|v| v + cq).collect();
            let shifted = kl_divergence(&ps, &qs);
            // f32 addition rounds each shifted logit by up to ~1 ulp of
            // the shift magnitude, so allow a matching slack.
            prop_assert!(
                (base - shifted).abs() < 1e-3 * (1.0 + base.abs()),
                "KL changed under uniform shift: {base} vs {shifted}"
            );
            Ok(())
        });
    }

    #[test]
    fn compare_scores_agreement_and_nll_delta() {
        // Served row still argmaxes to token 0 but is less confident.
        let reference = [2.0f32, 0.0, -1.0];
        let served = [1.0f32, 0.0, -1.0];
        let s = compare(&reference, &served, 0);
        assert!(s.top1_agree);
        assert!(s.kl > 0.0);
        // fp32 charges −log p(0), served charges more (less peaked).
        assert!(s.nll_delta > 0.0, "{}", s.nll_delta);

        // Disagreement: served argmaxes elsewhere.
        let served2 = [0.0f32, 2.0, -1.0];
        let s2 = compare(&reference, &served2, 1);
        assert!(!s2.top1_agree);
        // Token 1 is *more* likely under served2 → negative delta.
        assert!(s2.nll_delta < 0.0);

        // Identical rows: everything degenerate-zero.
        let s3 = compare(&reference, &reference, 0);
        assert_eq!(s3.kl, 0.0);
        assert!(s3.top1_agree);
        assert_eq!(s3.nll_delta, 0.0);

        // Out-of-range committed token → nll_delta pinned to 0.
        let s4 = compare(&reference, &served, 99);
        assert_eq!(s4.nll_delta, 0.0);
    }

    #[test]
    fn nanonats_fixed_point() {
        assert_eq!(nanonats(0.0), 0);
        assert_eq!(nanonats(1.5e-3), 1_500_000);
        assert_eq!(nanonats(2.0), 2_000_000_000);
        // regressions-only clamp: negatives and NaN record as 0
        assert_eq!(nanonats(-0.25), 0);
        assert_eq!(nanonats(f64::NAN), 0);
        // saturation, not wraparound
        assert_eq!(nanonats(f64::INFINITY), u64::MAX);
    }

    #[test]
    fn probe_cadence_fires_every_nth_step() {
        let mut p = QualityProbe::new(3);
        assert!(p.enabled());
        let fired: Vec<bool> = (0..9).map(|_| p.tick()).collect();
        assert_eq!(
            fired,
            vec![false, false, true, false, false, true, false, false, true]
        );
        assert_eq!(p.steps(), 9);

        let mut off = QualityProbe::new(0);
        assert!(!off.enabled());
        assert!((0..10).all(|_| !off.tick()));
        assert_eq!(off.steps(), 0);

        let mut every_step = QualityProbe::new(1);
        assert!((0..5).all(|_| every_step.tick()));
    }
}
