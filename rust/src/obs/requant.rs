//! Per-requantization introspection records.
//!
//! Every drift-triggered requant on the serving path produces one
//! [`RequantEvent`] capturing *why* it fired (per-layer drift scores
//! vs. the configured threshold), *what it saw* (tokens observed since
//! the previous requant) and *what it cost* (quantization wall time,
//! old → new weight generation). The server accumulates them
//! (`Server::requant_events`); `examples/trace_generate.rs` prints
//! them and the observability test suite asserts on them.

/// One drift-triggered requantization, as observed by the server.
#[derive(Clone, Debug)]
pub struct RequantEvent {
    /// When the requant started, microseconds on the server clock.
    pub at_us: u64,
    /// Weight generation before the requant.
    pub from_version: u64,
    /// Weight generation after the requant.
    pub to_version: u64,
    /// Maximum per-layer drift score at trigger time (`f64::INFINITY`
    /// for a layer that had never been quantized).
    pub max_drift: f64,
    /// The calibrator's configured drift threshold.
    pub threshold: f64,
    /// Tokens observed by the calibrator since the previous commit.
    pub tokens_since_last: u64,
    /// Wall time spent requantizing and swapping weights,
    /// microseconds.
    pub quant_us: u64,
    /// Drift score per layer at trigger time, indexed by layer.
    pub layer_drifts: Vec<f64>,
    /// Activation-weighted relative reconstruction error per quantized
    /// linear *after* the requant, in the calibrator's layer order:
    /// `Σᵢⱼ dⱼ²·(Wᵢⱼ−Ŵᵢⱼ)² / Σᵢⱼ dⱼ²·Wᵢⱼ²` with `d` the layer's
    /// activation diagonal (uniform when no statistics exist yet).
    /// Correlates the drift that *triggered* the requant with the
    /// quantization quality that came *out* of it on one timeline.
    pub layer_recon_err: Vec<f64>,
}

impl RequantEvent {
    /// True when the trigger drift actually exceeded the threshold
    /// (always the case for requants fired by the drift rule; asserted
    /// by the observability suite).
    pub fn drift_exceeded(&self) -> bool {
        self.max_drift > self.threshold
    }

    /// The `n` most-drifted layers as `(layer index, drift score)`,
    /// most drifted first. Never-quantized layers (infinite drift)
    /// sort first.
    pub fn top_layers(&self, n: usize) -> Vec<(usize, f64)> {
        let mut v: Vec<(usize, f64)> = self.layer_drifts.iter().cloned().enumerate().collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v.truncate(n);
        v
    }

    /// The `n` worst-reconstructed layers as `(layer index, relative
    /// activation-weighted error)`, worst first.
    pub fn worst_recon_layers(&self, n: usize) -> Vec<(usize, f64)> {
        let mut v: Vec<(usize, f64)> = self.layer_recon_err.iter().cloned().enumerate().collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v.truncate(n);
        v
    }

    /// Mean relative reconstruction error across quantized layers
    /// (0 when the requant recorded none).
    pub fn mean_recon_err(&self) -> f64 {
        if self.layer_recon_err.is_empty() {
            0.0
        } else {
            self.layer_recon_err.iter().sum::<f64>() / self.layer_recon_err.len() as f64
        }
    }

    /// One-line human-readable summary (used by the CLI and example).
    pub fn describe(&self) -> String {
        format!(
            "t={:.3}ms v{}→v{} drift={:.4} (threshold {:.4}) tokens_since={} quant={:.2}ms",
            self.at_us as f64 / 1e3,
            self.from_version,
            self.to_version,
            self.max_drift,
            self.threshold,
            self.tokens_since_last,
            self.quant_us as f64 / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event() -> RequantEvent {
        RequantEvent {
            at_us: 1_500,
            from_version: 3,
            to_version: 4,
            max_drift: 0.21,
            threshold: 0.05,
            tokens_since_last: 640,
            quant_us: 2_200,
            layer_drifts: vec![0.01, 0.21, f64::INFINITY, 0.07],
            layer_recon_err: vec![1e-4, 3e-3, 2e-3, 5e-5],
        }
    }

    #[test]
    fn top_layers_sorted_desc_with_infinities_first() {
        let e = event();
        let top = e.top_layers(3);
        assert_eq!(top.len(), 3);
        assert_eq!(top[0].0, 2);
        assert!(top[0].1.is_infinite());
        assert_eq!(top[1], (1, 0.21));
        assert_eq!(top[2], (3, 0.07));
    }

    #[test]
    fn drift_exceeded_compares_against_threshold() {
        let mut e = event();
        assert!(e.drift_exceeded());
        e.max_drift = 0.04;
        assert!(!e.drift_exceeded());
    }

    #[test]
    fn recon_error_queries() {
        let e = event();
        let worst = e.worst_recon_layers(2);
        assert_eq!(worst, vec![(1, 3e-3), (2, 2e-3)]);
        let mean = e.mean_recon_err();
        assert!((mean - (1e-4 + 3e-3 + 2e-3 + 5e-5) / 4.0).abs() < 1e-15);
        let empty = RequantEvent {
            layer_recon_err: Vec::new(),
            ..e
        };
        assert_eq!(empty.mean_recon_err(), 0.0);
        assert!(empty.worst_recon_layers(3).is_empty());
    }

    #[test]
    fn describe_mentions_versions_and_drift() {
        let s = event().describe();
        assert!(s.contains("v3→v4"), "{s}");
        assert!(s.contains("0.2100"), "{s}");
        assert!(s.contains("tokens_since=640"), "{s}");
    }
}
