//! Lock-free fixed-capacity span ring buffer for the serving path.
//!
//! The recorder is a seqlock-style ticket ring: writers claim a
//! monotonically increasing ticket with one `fetch_add`, mark the slot
//! as in-progress (odd sequence word), store the payload, then publish
//! (even sequence word). Readers ([`TraceBuffer::snapshot`]) validate
//! the sequence word before *and* after reading the payload and drop
//! any slot a writer touched in between — a snapshot never blocks a
//! writer and never returns a torn record. On wraparound the oldest
//! records are silently overwritten (dropped), never blocking the
//! serving loop; [`TraceBuffer::dropped`] reports how many.
//!
//! Built exclusively on [`crate::sync`] atomics (`load` / `store` /
//! `fetch_add`, the subset the in-tree model checker instruments), so
//! the whole protocol is explored exhaustively under `--cfg loom` in
//! `rust/tests/loom_obs.rs`. All accesses are `SeqCst`: the model
//! checker is sequentially consistent, and recording is a handful of
//! stores on an already-synchronizing serving path — clarity over
//! nanoseconds.
//!
//! Span taxonomy and payload conventions: `docs/OBSERVABILITY.md`.

use crate::sync::atomic::{AtomicU64, Ordering};

/// Sequence id used for engine-wide spans (requant, cache occupancy,
/// kernels) that do not belong to any single request.
pub const ENGINE_SEQ: u64 = u64::MAX;

/// `u64` words per ring slot: one sequence word + the 7 payload words
/// of a [`TraceEvent`].
const WORDS: usize = 8;

/// What a span or instant event measures. The `a`/`b` payload words of
/// a [`TraceEvent`] are kind-specific (documented per variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SpanKind {
    /// Whole request lifetime, arrival to `Done`. `a` = generated
    /// tokens, `b` = prompt length.
    Request = 0,
    /// Queue wait: arrival to admission. `a` = prompt length.
    Admit = 1,
    /// Prompt prefill forward for one request's batch group.
    /// `a` = total prompt tokens in the group, `b` = group rows.
    Prefill = 2,
    /// One batched decode step, recorded per participating sequence.
    /// `a` = kernel microseconds attributed to the step, `b` = rows.
    DecodeStep = 3,
    /// One speculative draft+verify round. `a` = tokens drafted,
    /// `b` = tokens accepted.
    SpecRound = 4,
    /// Drafter phase of a speculative round. `a` = tokens drafted.
    Draft = 5,
    /// Verifier phase of a speculative round. `a` = rows verified,
    /// `b` = tokens accepted.
    Verify = 6,
    /// Drift-triggered requantization. `weight_version` = new
    /// generation, `a` = old generation, `b` = max drift in parts per
    /// million.
    Requant = 7,
    /// KV-cache occupancy sample (instant). `a` = used tokens,
    /// `b` = capacity tokens.
    CacheOccupancy = 8,
    /// One pooled kernel dispatch on the worker pool. `a` = rows,
    /// `b` = lanes participating.
    Kernel = 9,
    /// One online quality-probe replay (fp32 reference forward for one
    /// sequence at a committed decode step). `a` = KL(fp32 ‖ served)
    /// in nanonats, `b` = 1 when the top-1 tokens agreed, else 0.
    Probe = 10,
    /// KV-cache slab bytes sample (instant). `a` = occupancy bytes
    /// (tokens written × bytes/token), `b` = waste bytes (reserved by
    /// active slots but not yet written).
    KvBytes = 11,
}

impl SpanKind {
    /// Decode a payload word back into a kind; `None` for garbage
    /// (a torn or never-written slot that slipped every other guard).
    pub fn from_u64(v: u64) -> Option<SpanKind> {
        Some(match v {
            0 => SpanKind::Request,
            1 => SpanKind::Admit,
            2 => SpanKind::Prefill,
            3 => SpanKind::DecodeStep,
            4 => SpanKind::SpecRound,
            5 => SpanKind::Draft,
            6 => SpanKind::Verify,
            7 => SpanKind::Requant,
            8 => SpanKind::CacheOccupancy,
            9 => SpanKind::Kernel,
            10 => SpanKind::Probe,
            11 => SpanKind::KvBytes,
            _ => return None,
        })
    }

    /// Stable lowercase name used by the exporters.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Admit => "admit",
            SpanKind::Prefill => "prefill",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::SpecRound => "spec_round",
            SpanKind::Draft => "draft",
            SpanKind::Verify => "verify",
            SpanKind::Requant => "requant",
            SpanKind::CacheOccupancy => "kv_cache_tokens",
            SpanKind::Kernel => "kernel",
            SpanKind::Probe => "probe",
            SpanKind::KvBytes => "kv_cache_bytes",
        }
    }

    /// True for instant counter samples (exported as Chrome `"C"`
    /// events) rather than duration spans.
    pub fn is_counter(self) -> bool {
        matches!(self, SpanKind::CacheOccupancy | SpanKind::KvBytes)
    }
}

/// One recorded span or instant event. All times are microseconds on
/// the owning server's [`crate::obs::Clock`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// What this event measures.
    pub kind: SpanKind,
    /// Owning request id, or [`ENGINE_SEQ`] for engine-wide events.
    pub seq: u64,
    /// Span start, microseconds.
    pub start_us: u64,
    /// Span duration, microseconds (0 for instant events).
    pub dur_us: u64,
    /// Weight generation current when the span was recorded.
    pub weight_version: u64,
    /// Kind-specific payload (see [`SpanKind`]).
    pub a: u64,
    /// Kind-specific payload (see [`SpanKind`]).
    pub b: u64,
}

/// Lock-free bounded span recorder. Capacity 0 disables recording
/// entirely ([`TraceBuffer::record`] becomes a no-op), which is how
/// the ≤ 2% recorder-overhead gate measures its baseline.
pub struct TraceBuffer {
    cap: usize,
    /// Total tickets ever claimed; slot for ticket `t` is `t % cap`.
    head: AtomicU64,
    /// `cap * WORDS` words; word 0 of each slot is the sequence word
    /// (`2t+1` while writing ticket `t`, `2t+2` once published).
    cells: Box<[AtomicU64]>,
}

impl std::fmt::Debug for TraceBuffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceBuffer")
            .field("capacity", &self.cap)
            .field("recorded", &self.recorded())
            .finish()
    }
}

impl TraceBuffer {
    /// Ring holding the most recent `capacity` events (0 = disabled).
    pub fn new(capacity: usize) -> Self {
        let cells = (0..capacity * WORDS).map(|_| AtomicU64::new(0)).collect();
        TraceBuffer {
            cap: capacity,
            head: AtomicU64::new(0),
            cells,
        }
    }

    /// A disabled recorder: every [`TraceBuffer::record`] is a no-op.
    pub fn disabled() -> Self {
        TraceBuffer::new(0)
    }

    /// True when the buffer actually records (capacity > 0).
    pub fn enabled(&self) -> bool {
        self.cap > 0
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        if self.cap == 0 {
            0
        } else {
            self.head.load(Ordering::SeqCst)
        }
    }

    /// Events lost to wraparound so far.
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.cap as u64)
    }

    /// Record one event. Lock-free and wait-free apart from the single
    /// ticket `fetch_add`; on a full ring the oldest event is
    /// overwritten. Never blocks the serving loop.
    pub fn record(&self, ev: &TraceEvent) {
        if self.cap == 0 {
            return;
        }
        // Claim a unique ticket; tickets are never reused, so sequence
        // words are unique across the buffer's lifetime (no ABA).
        let t = self.head.fetch_add(1, Ordering::SeqCst);
        let base = (t as usize % self.cap) * WORDS;
        // Odd = write in progress. Invariant checked by loom model
        // `writers_never_tear` in rust/tests/loom_obs.rs: a reader that
        // sees the same even word before and after its payload reads
        // observed no concurrent writer on the slot.
        self.cells[base].store(t.wrapping_mul(2).wrapping_add(1), Ordering::SeqCst);
        self.cells[base + 1].store(ev.kind as u64, Ordering::SeqCst);
        self.cells[base + 2].store(ev.seq, Ordering::SeqCst);
        self.cells[base + 3].store(ev.start_us, Ordering::SeqCst);
        self.cells[base + 4].store(ev.dur_us, Ordering::SeqCst);
        self.cells[base + 5].store(ev.weight_version, Ordering::SeqCst);
        self.cells[base + 6].store(ev.a, Ordering::SeqCst);
        self.cells[base + 7].store(ev.b, Ordering::SeqCst);
        // Even = published for ticket t.
        self.cells[base].store(t.wrapping_mul(2).wrapping_add(2), Ordering::SeqCst);
    }

    /// Consistent copy of the currently retained events, oldest first.
    /// Slots a concurrent writer is touching are skipped, never read
    /// torn: the sequence word is checked before and after the payload
    /// reads, and any concurrent writer must flip it to its own odd
    /// value first (tickets are unique, so the check cannot be fooled
    /// by a same-slot rewrite).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        if self.cap == 0 {
            return Vec::new();
        }
        let head = self.head.load(Ordering::SeqCst);
        let n = head.min(self.cap as u64);
        let mut out = Vec::with_capacity(n as usize);
        for t in (head - n)..head {
            let base = (t as usize % self.cap) * WORDS;
            let published = t.wrapping_mul(2).wrapping_add(2);
            if self.cells[base].load(Ordering::SeqCst) != published {
                continue; // still being written, or already overwritten
            }
            let kind = SpanKind::from_u64(self.cells[base + 1].load(Ordering::SeqCst));
            let ev = TraceEvent {
                kind: kind.unwrap_or(SpanKind::Request),
                seq: self.cells[base + 2].load(Ordering::SeqCst),
                start_us: self.cells[base + 3].load(Ordering::SeqCst),
                dur_us: self.cells[base + 4].load(Ordering::SeqCst),
                weight_version: self.cells[base + 5].load(Ordering::SeqCst),
                a: self.cells[base + 6].load(Ordering::SeqCst),
                b: self.cells[base + 7].load(Ordering::SeqCst),
            };
            if kind.is_some() && self.cells[base].load(Ordering::SeqCst) == published {
                out.push(ev);
            }
        }
        out
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn ev(seq: u64, start: u64) -> TraceEvent {
        TraceEvent {
            kind: SpanKind::DecodeStep,
            seq,
            start_us: start,
            dur_us: 5,
            weight_version: 1,
            a: 2,
            b: 3,
        }
    }

    #[test]
    fn roundtrip_in_order() {
        let tb = TraceBuffer::new(8);
        for i in 0..5 {
            tb.record(&ev(i, i * 10));
        }
        let snap = tb.snapshot();
        assert_eq!(snap.len(), 5);
        for (i, e) in snap.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
            assert_eq!(e.start_us, i as u64 * 10);
            assert_eq!(e.kind, SpanKind::DecodeStep);
        }
        assert_eq!(tb.dropped(), 0);
    }

    #[test]
    fn wraparound_drops_oldest() {
        let tb = TraceBuffer::new(4);
        for i in 0..10 {
            tb.record(&ev(i, i));
        }
        let snap = tb.snapshot();
        assert_eq!(snap.len(), 4);
        let seqs: Vec<u64> = snap.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "newest 4 retained, oldest dropped");
        assert_eq!(tb.recorded(), 10);
        assert_eq!(tb.dropped(), 6);
    }

    #[test]
    fn disabled_buffer_is_noop() {
        let tb = TraceBuffer::disabled();
        tb.record(&ev(0, 0));
        assert!(!tb.enabled());
        assert!(tb.snapshot().is_empty());
        assert_eq!(tb.recorded(), 0);
        assert_eq!(tb.dropped(), 0);
    }

    #[test]
    fn kind_codes_roundtrip() {
        for k in [
            SpanKind::Request,
            SpanKind::Admit,
            SpanKind::Prefill,
            SpanKind::DecodeStep,
            SpanKind::SpecRound,
            SpanKind::Draft,
            SpanKind::Verify,
            SpanKind::Requant,
            SpanKind::CacheOccupancy,
            SpanKind::Kernel,
            SpanKind::Probe,
            SpanKind::KvBytes,
        ] {
            assert_eq!(SpanKind::from_u64(k as u64), Some(k));
            assert!(!k.name().is_empty());
        }
        assert_eq!(SpanKind::from_u64(250), None);
    }

    #[test]
    fn concurrent_writers_never_tear() {
        // Stress analogue of the loom model: payload invariant b == a ^ M.
        const M: u64 = 0x5bd1_e995_9bd1_e995;
        let tb = std::sync::Arc::new(TraceBuffer::new(16));
        let per = if cfg!(miri) { 40 } else { 20_000 };
        let hs: Vec<_> = (0..4)
            .map(|w| {
                let tb = tb.clone();
                crate::sync::thread::spawn_named("trace-test", move || {
                    for i in 0..per {
                        let a = (w * per + i) as u64;
                        tb.record(&TraceEvent {
                            kind: SpanKind::Kernel,
                            seq: a,
                            start_us: a,
                            dur_us: a,
                            weight_version: a,
                            a,
                            b: a ^ M,
                        });
                        if i % 16 == 0 {
                            for e in tb.snapshot() {
                                assert_eq!(e.b, e.a ^ M, "torn record observed");
                            }
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            let _ = h.join();
        }
        assert_eq!(tb.recorded(), 4 * per as u64);
        for e in tb.snapshot() {
            assert_eq!(e.b, e.a ^ M);
        }
    }
}
