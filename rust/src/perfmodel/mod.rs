//! GPU roofline simulator — regenerates the paper's runtime Tables 4-8.
//!
//! We have no A40/A100/L40/RTX3090/RTX4090 (repro gate); the paper
//! itself attributes the quantization speedup to *weight-traffic
//! reduction* (App. B: "the practical advantage ... comes with the
//! reduction of required memory, which also leads to GPU acceleration
//! due to the reduction of caching bottleneck"). A bandwidth/compute
//! roofline over each card's published specs therefore reproduces the
//! comparison's *shape*: who wins, by what factor, and how the gap
//! grows with model size. Absolute numbers are calibrated only loosely.
//!
//! Modeled decode step (single-token query projection, as in App. H):
//!
//!   t = max(bytes/BW_eff, flops/TFLOPS_eff) + launch_overhead
//!
//! * FP16      — full d′·d·2 bytes every step.
//! * AWQ       — packed q-bit weight + f16 group params; `awq_gemm` and
//!   `marlin_gemm` differ by kernel efficiency.
//! * TTQ(r=0)  — marlin-class traffic + the online `find_params` pass
//!   (reads W in fp16, writes packed W) **amortized over the decode
//!   window**: the coordinator quantizes once per prompt (prefill) and
//!   decodes `amortize` tokens against the packed weight.
//! * TTQ(r=16) — additionally moves B/A (fp16) and computes the
//!   low-rank projection every step.

use crate::quant::QuantSpec;

/// Published card specs (dense FP16 tensor TFLOPs, HBM/GDDR GB/s).
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    pub bw_gbps: f64,
    pub fp16_tflops: f64,
    /// launch + sync overhead per decode step, seconds (CUDA-graph era)
    pub overhead_s: f64,
}

pub const GPUS: [GpuSpec; 5] = [
    GpuSpec { name: "A40", bw_gbps: 696.0, fp16_tflops: 74.8, overhead_s: 6.0e-6 },
    GpuSpec { name: "A100", bw_gbps: 1555.0, fp16_tflops: 312.0, overhead_s: 6.0e-6 },
    GpuSpec { name: "L40", bw_gbps: 864.0, fp16_tflops: 181.0, overhead_s: 4.0e-6 },
    GpuSpec { name: "RTX3090", bw_gbps: 936.0, fp16_tflops: 71.0, overhead_s: 5.0e-6 },
    GpuSpec { name: "RTX4090", bw_gbps: 1008.0, fp16_tflops: 165.0, overhead_s: 3.0e-6 },
];

pub fn gpu(name: &str) -> &'static GpuSpec {
    GPUS.iter().find(|g| g.name == name).expect("unknown GPU")
}

/// Kernel efficiency factors (fraction of peak BW actually achieved by
/// the memory-bound GEMV): calibrated against the paper's FP16 rows.
const EFF_FP16: f64 = 0.62;
const EFF_AWQ_GEMM: f64 = 0.38; // the older vllm awq_gemm kernel
const EFF_MARLIN: f64 = 0.72; // Frantar et al. 2025
const EFF_TTQ_QUANT: f64 = 0.55; // streaming read-modify-write pass

/// Execution mode — one row of Tables 4-8.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mode {
    Fp16,
    AwqGemm,
    AwqMarlin,
    Ttq { rank: usize },
}

impl Mode {
    pub fn label(&self) -> String {
        match self {
            Mode::Fp16 => "FP16".into(),
            Mode::AwqGemm => "AWQ (awq_gemm)".into(),
            Mode::AwqMarlin => "AWQ (marlin_gemm)".into(),
            Mode::Ttq { rank } => format!("TTQ (r = {rank})"),
        }
    }
}

/// How many decode tokens amortize one online quantization pass (the
/// coordinator's per-prompt requantization window).
pub const DEFAULT_AMORTIZE: f64 = 64.0;

/// Predicted decode throughput, thousand tokens/second, for one linear
/// projection of dims (d_out, d_in).
pub fn ktokens_per_sec(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    mode: Mode,
    amortize: f64,
) -> f64 {
    let n = (d_out * d_in) as f64;
    let bw = gpu.bw_gbps * 1e9;
    let flops_cap = gpu.fp16_tflops * 1e12;
    let fp16_bytes = n * 2.0;
    let packed_bytes = n * spec.bytes_per_element();
    let matmul_flops = 2.0 * n; // single-token GEMV

    let t = match mode {
        Mode::Fp16 => {
            let t_mem = fp16_bytes / (bw * EFF_FP16);
            t_mem.max(matmul_flops / flops_cap) + gpu.overhead_s
        }
        Mode::AwqGemm => {
            let t_mem = packed_bytes / (bw * EFF_AWQ_GEMM);
            t_mem.max(matmul_flops / flops_cap) + gpu.overhead_s
        }
        Mode::AwqMarlin => {
            let t_mem = packed_bytes / (bw * EFF_MARLIN);
            t_mem.max(matmul_flops / flops_cap) + gpu.overhead_s
        }
        Mode::Ttq { rank } => {
            // matmul against packed weights (marlin-class kernel w/ the
            // prologue descale fused — slightly below marlin efficiency
            // because D is applied inline, App. H)
            let t_mm = packed_bytes / (bw * (EFF_MARLIN * 0.93));
            // online find_params: read W fp16 + write packed, amortized
            let quant_bytes = fp16_bytes + packed_bytes;
            let t_quant = quant_bytes / (bw * EFF_TTQ_QUANT) / amortize.max(1.0);
            // low-rank epilogue: move B/A fp16 + its flops every step
            let r = rank as f64;
            let lr_bytes = r * (d_out + d_in) as f64 * 2.0;
            let lr_flops = 2.0 * r * (d_out + d_in) as f64;
            let t_lr = if rank > 0 {
                (lr_bytes / (bw * EFF_FP16)).max(lr_flops / flops_cap)
                    + 0.35 * gpu.overhead_s // extra kernel in the graph
            } else {
                0.0
            };
            t_mm.max(matmul_flops / flops_cap) + t_quant + t_lr + gpu.overhead_s
        }
    };
    1.0 / t / 1000.0
}

/// Speedup of a mode over the FP16 baseline.
pub fn speedup(gpu: &GpuSpec, d_out: usize, d_in: usize, spec: &QuantSpec, mode: Mode) -> f64 {
    ktokens_per_sec(gpu, d_out, d_in, spec, mode, DEFAULT_AMORTIZE)
        / ktokens_per_sec(gpu, d_out, d_in, spec, Mode::Fp16, DEFAULT_AMORTIZE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::QWEN3;

    fn spec4() -> QuantSpec {
        QuantSpec::new(4, 32)
    }

    #[test]
    fn quantized_beats_fp16_on_large_models() {
        // Paper: "up to 6.7 folds at 32B on RTX4090" for marlin AWQ.
        let m = QWEN3[5];
        let (dout, din) = m.qproj_dims();
        for g in &GPUS {
            let s = speedup(g, dout, din, &spec4(), Mode::AwqMarlin);
            assert!(s > 2.0, "{}: marlin speedup {s}", g.name);
        }
        let s4090 = speedup(gpu("RTX4090"), dout, din, &spec4(), Mode::AwqMarlin);
        assert!(s4090 > 3.0 && s4090 < 9.0, "4090 marlin speedup {s4090}");
    }

    #[test]
    fn ttq_r0_close_to_marlin() {
        // Paper: "TTQ (r=0) has no significant loss in speed over AWQ".
        let m = QWEN3[4];
        let (dout, din) = m.qproj_dims();
        let g = gpu("A100");
        let marlin = ktokens_per_sec(g, dout, din, &spec4(), Mode::AwqMarlin, 64.0);
        let ttq = ktokens_per_sec(g, dout, din, &spec4(), Mode::Ttq { rank: 0 }, 64.0);
        assert!(ttq > marlin * 0.7, "ttq {ttq} vs marlin {marlin}");
        assert!(ttq <= marlin * 1.02);
    }

    #[test]
    fn ttq_r16_pays_lowrank_tax_but_beats_fp16_when_large() {
        let m = QWEN3[5];
        let (dout, din) = m.qproj_dims();
        let g = gpu("RTX4090");
        let r0 = ktokens_per_sec(g, dout, din, &spec4(), Mode::Ttq { rank: 0 }, 64.0);
        let r16 = ktokens_per_sec(g, dout, din, &spec4(), Mode::Ttq { rank: 16 }, 64.0);
        let fp = ktokens_per_sec(g, dout, din, &spec4(), Mode::Fp16, 64.0);
        assert!(r16 < r0);
        // Paper: "TTQ can still accelerate ... up to 4.9 folds at 32B"
        let s = r16 / fp;
        assert!(s > 2.0, "r16 speedup {s}");
    }

    #[test]
    fn throughput_degrades_with_model_size() {
        // Paper observation #1.
        let g = gpu("A40");
        let mut last = f64::MAX;
        for m in &QWEN3 {
            let (dout, din) = m.qproj_dims();
            let k = ktokens_per_sec(g, dout, din, &spec4(), Mode::Fp16, 64.0);
            assert!(k < last, "{}: {k} !< {last}", m.name);
            last = k;
        }
    }

    #[test]
    fn ttq_advantage_grows_with_size() {
        // Paper observation #5: more advantage on larger LLMs.
        let g = gpu("A40");
        let (d0, i0) = QWEN3[0].qproj_dims();
        let (d5, i5) = QWEN3[5].qproj_dims();
        let s_small = speedup(g, d0, i0, &spec4(), Mode::Ttq { rank: 0 });
        let s_large = speedup(g, d5, i5, &spec4(), Mode::Ttq { rank: 0 });
        assert!(s_large > s_small);
    }

    #[test]
    fn two_bit_packs_faster_than_four_bit() {
        // App. H: custom 2-bit kernels "theoretically doubling" traffic
        // reduction; the roofline must show 2-bit ≥ 4-bit throughput.
        let (dout, din) = QWEN3[5].qproj_dims();
        let g = gpu("A100");
        let k2 = ktokens_per_sec(g, dout, din, &QuantSpec::new(2, 32), Mode::AwqMarlin, 64.0);
        let k4 = ktokens_per_sec(g, dout, din, &QuantSpec::new(4, 32), Mode::AwqMarlin, 64.0);
        assert!(k2 > k4);
    }

    #[test]
    fn absolute_scale_sane() {
        // FP16 0.6B on A40 should land within ~2x of the paper's 57.58
        // k tokens/s (we claim shape, not absolutes — but stay on-scale).
        let (dout, din) = QWEN3[0].qproj_dims();
        let k = ktokens_per_sec(gpu("A40"), dout, din, &spec4(), Mode::Fp16, 64.0);
        assert!(k > 25.0 && k < 120.0, "FP16 0.6B A40: {k}");
    }
}
