//! GPU roofline simulator — regenerates the paper's runtime Tables 4-8.
//!
//! We have no A40/A100/L40/RTX3090/RTX4090 (repro gate); the paper
//! itself attributes the quantization speedup to *weight-traffic
//! reduction* (App. B: "the practical advantage ... comes with the
//! reduction of required memory, which also leads to GPU acceleration
//! due to the reduction of caching bottleneck"). A bandwidth/compute
//! roofline over each card's published specs therefore reproduces the
//! comparison's *shape*: who wins, by what factor, and how the gap
//! grows with model size. Absolute numbers are calibrated only loosely.
//!
//! Modeled decode step (single-token query projection, as in App. H):
//!
//!   t = max(bytes/BW_eff, flops/TFLOPS_eff) + launch_overhead
//!
//! A table row is a [`DecodeMode`]: a [`MethodSpec`] (the same registry
//! handle the eval/bench/serve layers dispatch on) paired with a GEMV
//! [`Kernel`] class. The cost model interrogates the method through the
//! [`crate::quant::Quantizer`] trait — does it pack the weights, does it
//! quantize *online* (the amortized `find_params` pass of Eq. 3), what
//! low-rank epilogue does it carry — instead of matching on a private
//! mode enum.
//!
//! The speculative extension prices the [`crate::specdec`] round:
//! expected committed tokens per round is a closed form of
//! (acceptance, k) ([`expected_tokens_per_round`]), the drafter pays k
//! sequential quantized GEMVs, and the verifier pays one prefill-priced
//! pass over the k+1-token window ([`speculative_ktokens_per_sec`]).
//!
//! **Measured cross-check.** Since PR 9 these predictions are no longer
//! unfalsifiable on the machines we actually serve on: the same
//! `max(bytes/BW, flops/FLOPS)` primitive ([`roofline_us`], with a
//! [`Bound`] verdict at the ridge point) is evaluated against a
//! *measured* host ceiling ([`crate::obs::profile::HostSpec::measure`])
//! and joined with per-kernel-site measured wall time by
//! [`crate::obs::profile::Profiler::report`] into a
//! predicted-vs-measured drift ratio per site
//! (`benches/kernel_profile.rs` → `BENCH_profile.json`). The paper's
//! memory-bound-decode premise is asserted analytically here and
//! verified empirically there.

#![forbid(unsafe_code)]

use crate::quant::{MethodSpec, QuantSpec};

/// Published card specs (dense FP16 tensor TFLOPs, HBM/GDDR GB/s).
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// Card name as the paper's tables print it.
    pub name: &'static str,
    /// Peak memory bandwidth, GB/s.
    pub bw_gbps: f64,
    /// Dense fp16 tensor throughput, TFLOP/s.
    pub fp16_tflops: f64,
    /// launch + sync overhead per decode step, seconds (CUDA-graph era)
    pub overhead_s: f64,
}

/// The five cards of the paper's runtime tables (4-8).
pub const GPUS: [GpuSpec; 5] = [
    GpuSpec { name: "A40", bw_gbps: 696.0, fp16_tflops: 74.8, overhead_s: 6.0e-6 },
    GpuSpec { name: "A100", bw_gbps: 1555.0, fp16_tflops: 312.0, overhead_s: 6.0e-6 },
    GpuSpec { name: "L40", bw_gbps: 864.0, fp16_tflops: 181.0, overhead_s: 4.0e-6 },
    GpuSpec { name: "RTX3090", bw_gbps: 936.0, fp16_tflops: 71.0, overhead_s: 5.0e-6 },
    GpuSpec { name: "RTX4090", bw_gbps: 1008.0, fp16_tflops: 165.0, overhead_s: 3.0e-6 },
];

/// Look up a card by table name (panics on unknown names).
pub fn gpu(name: &str) -> &'static GpuSpec {
    GPUS.iter().find(|g| g.name == name).expect("unknown GPU")
}

/// Which roof limits a kernel at its arithmetic intensity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    /// Below the ridge point: time is `bytes / BW`.
    Memory,
    /// Above the ridge point: time is `flops / FLOPS`.
    Compute,
}

impl Bound {
    /// Stable lowercase label used in exports.
    pub fn name(self) -> &'static str {
        match self {
            Bound::Memory => "memory",
            Bound::Compute => "compute",
        }
    }
}

/// The roofline time primitive in microseconds:
/// `max(bytes/BW, flops/FLOPS)` for a ceiling of `bw_gbps` GB/s and
/// `gflops` GFLOP/s. This is the same equation every GPU row of
/// Tables 4-8 is priced with (there in seconds against published
/// specs); `obs::profile` evaluates it against a **measured** host
/// ceiling to produce the per-site predicted-vs-measured drift report.
pub fn roofline_us(bw_gbps: f64, gflops: f64, flops: f64, bytes: f64) -> f64 {
    let mem_us = bytes / bw_gbps.max(1e-12) / 1e3;
    let cmp_us = flops / gflops.max(1e-12) / 1e3;
    mem_us.max(cmp_us)
}

/// Compute ceiling for a vector kernel: the measured *scalar* FLOP
/// throughput ([`crate::obs::profile::HostSpec`]'s probe) scaled by the ISA's
/// f32 lane count. An idealization — real vector kernels lose some of
/// the `lanes×` to load alignment and horizontal reductions — but the
/// roofline wants the *ceiling*, and without it every AVX2 site would
/// be judged against a roof 8× too low (measured/predicted ratios
/// systematically < 1 and Bound verdicts flipping to Compute far too
/// early). Used by `obs::profile` for per-site verdicts; `lanes == 1`
/// (scalar sites) is the identity, keeping pre-SIMD reports unchanged.
pub fn vector_ceiling_gflops(scalar_gflops: f64, lanes: usize) -> f64 {
    scalar_gflops * lanes.max(1) as f64
}

/// Streaming read-modify-write efficiency of the online `find_params`
/// pass (fraction of peak BW).
const EFF_TTQ_QUANT: f64 = 0.55;

/// GEMV kernel class — which deployed kernel moves the weights.
/// Efficiency factors are the fraction of peak BW the memory-bound GEMV
/// actually achieves, calibrated against the paper's FP16 rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Dense f16 GEMV.
    Fp16Gemv,
    /// The older vllm `awq_gemm` packed kernel.
    AwqGemm,
    /// `marlin_gemm` (Frantar et al. 2025).
    MarlinGemm,
}

impl Kernel {
    /// Kernel name as printed in the tables.
    pub fn label(&self) -> &'static str {
        match self {
            Kernel::Fp16Gemv => "fp16",
            Kernel::AwqGemm => "awq_gemm",
            Kernel::MarlinGemm => "marlin_gemm",
        }
    }

    /// Fraction of peak bandwidth achieved. Online methods fuse the
    /// descale-by-D prologue into the GEMV, costing a little efficiency
    /// (App. H).
    fn eff(&self, online_descale: bool) -> f64 {
        let base = match self {
            Kernel::Fp16Gemv => 0.62,
            Kernel::AwqGemm => 0.38,
            Kernel::MarlinGemm => 0.72,
        };
        if online_descale {
            base * 0.93
        } else {
            base
        }
    }
}

/// One row of Tables 4-8: a registry method executed by a kernel class.
#[derive(Clone, Debug, PartialEq)]
pub struct DecodeMode {
    /// The compression method priced.
    pub method: MethodSpec,
    /// The GEMV kernel class moving its weights.
    pub kernel: Kernel,
}

impl DecodeMode {
    /// FP16 baseline row.
    pub fn fp16() -> Self {
        DecodeMode { method: MethodSpec::fp(), kernel: Kernel::Fp16Gemv }
    }

    /// Offline AWQ on the older `awq_gemm` kernel. The calibration
    /// domain marks the method offline; it does not enter the model.
    pub fn awq_gemm() -> Self {
        DecodeMode { method: MethodSpec::awq("c4s"), kernel: Kernel::AwqGemm }
    }

    /// Offline AWQ on `marlin_gemm`.
    pub fn awq_marlin() -> Self {
        DecodeMode { method: MethodSpec::awq("c4s"), kernel: Kernel::MarlinGemm }
    }

    /// Online TTQ (rank-r) on a marlin-class kernel with the descale
    /// prologue fused.
    pub fn ttq(rank: usize) -> Self {
        DecodeMode { method: MethodSpec::ttq(rank), kernel: Kernel::MarlinGemm }
    }

    /// Any registry method on its natural kernel: un-quantized methods
    /// run the dense f16 GEMV, everything else marlin-class.
    pub fn for_method(method: MethodSpec) -> Self {
        let kernel = if method.quantizer().quantizes() {
            Kernel::MarlinGemm
        } else {
            Kernel::Fp16Gemv
        };
        DecodeMode { method, kernel }
    }

    /// Paper row label: "FP16", "AWQ (awq_gemm)", "TTQ (r = 16)", ...
    pub fn label(&self) -> String {
        if self.method.quantizer().name() == "fp" {
            "FP16".into()
        } else if self.method.is_online() {
            self.method.quantizer().label()
        } else {
            format!("{} ({})", self.method.quantizer().label(), self.kernel.label())
        }
    }
}

/// How many decode tokens amortize one online quantization pass (the
/// coordinator's per-prompt requantization window).
pub const DEFAULT_AMORTIZE: f64 = 64.0;

/// Predicted decode throughput, thousand tokens/second, for one linear
/// projection of dims (d_out, d_in).
pub fn ktokens_per_sec(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    mode: &DecodeMode,
    amortize: f64,
) -> f64 {
    let q = mode.method.quantizer();
    let quantized = q.quantizes();
    let online = quantized && mode.method.is_online();
    let rank = q.lowrank_rank();

    let n = (d_out * d_in) as f64;
    let bw = gpu.bw_gbps * 1e9;
    let flops_cap = gpu.fp16_tflops * 1e12;
    let fp16_bytes = n * 2.0;
    let packed_bytes = n * spec.bytes_per_element();
    let matmul_flops = 2.0 * n; // single-token GEMV

    // matmul: packed or dense traffic through the kernel class
    let bytes = if quantized { packed_bytes } else { fp16_bytes };
    let t_mem = bytes / (bw * mode.kernel.eff(online));
    let mut t = t_mem.max(matmul_flops / flops_cap) + gpu.overhead_s;

    // online find_params: read W fp16 + write packed, amortized over
    // the decode window (Eq. 3's O[dT + 3d'd] term)
    if online {
        t += (fp16_bytes + packed_bytes) / (bw * EFF_TTQ_QUANT) / amortize.max(1.0);
    }

    // low-rank epilogue: move B/A fp16 + its flops every step
    if rank > 0 {
        let r = rank as f64;
        let lr_bytes = r * (d_out + d_in) as f64 * 2.0;
        let lr_flops = 2.0 * r * (d_out + d_in) as f64;
        t += (lr_bytes / (bw * Kernel::Fp16Gemv.eff(false))).max(lr_flops / flops_cap)
            + 0.35 * gpu.overhead_s; // extra kernel in the graph
    }
    1.0 / t / 1000.0
}

/// Predicted wall-clock of one *prefill* pass over `prompt_len` prompt
/// tokens for one linear projection — the compute-bound half of the
/// prefill/decode split. Unlike decode (a GEMV per token, re-moving the
/// weights every step), prefill is a GEMM: the weights cross the memory
/// bus once for the whole prompt while the flop count scales with
/// `prompt_len` — which is why quantization buys far less wall-clock in
/// prefill than in decode.
pub fn prefill_time_s(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    mode: &DecodeMode,
    prompt_len: usize,
) -> f64 {
    let q = mode.method.quantizer();
    let quantized = q.quantizes();
    let online = quantized && mode.method.is_online();
    let rank = q.lowrank_rank();

    let n = (d_out * d_in) as f64;
    let l = prompt_len.max(1) as f64;
    let bw = gpu.bw_gbps * 1e9;
    let flops_cap = gpu.fp16_tflops * 1e12;
    let fp16_bytes = n * 2.0;
    let packed_bytes = n * spec.bytes_per_element();

    // weights move once per prompt; flops scale with prompt length
    let bytes = if quantized { packed_bytes } else { fp16_bytes };
    let flops = 2.0 * n * l;
    let mut t = (bytes / (bw * mode.kernel.eff(online))).max(flops / flops_cap) + gpu.overhead_s;

    // online find_params runs exactly once, on the prompt itself — the
    // un-amortized O[dT + 3d'd] pass of Eq. 3
    if online {
        t += (fp16_bytes + packed_bytes) / (bw * EFF_TTQ_QUANT);
    }

    // low-rank epilogue: factors move once, flops scale with the prompt
    if rank > 0 {
        let r = rank as f64;
        let lr_bytes = r * (d_out + d_in) as f64 * 2.0;
        let lr_flops = 2.0 * r * (d_out + d_in) as f64 * l;
        t += (lr_bytes / (bw * Kernel::Fp16Gemv.eff(false))).max(lr_flops / flops_cap)
            + 0.35 * gpu.overhead_s;
    }
    t
}

/// End-to-end generation wall-clock: one prefill over the prompt plus
/// `new_tokens − 1` decode steps (the first token falls out of the
/// prefill logits). The online quantization cost is charged once, in
/// the prefill term — the decode term runs with an infinite amortization
/// window so it is not double-counted.
pub fn generation_time_s(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    mode: &DecodeMode,
    prompt_len: usize,
    new_tokens: usize,
) -> f64 {
    let prefill = prefill_time_s(gpu, d_out, d_in, spec, mode, prompt_len);
    let steps = new_tokens.saturating_sub(1) as f64;
    let per_step = 1.0 / (ktokens_per_sec(gpu, d_out, d_in, spec, mode, f64::INFINITY) * 1000.0);
    prefill + steps * per_step
}

/// Generated tokens per second over a whole prefill + decode generation.
pub fn generation_tokens_per_sec(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    mode: &DecodeMode,
    prompt_len: usize,
    new_tokens: usize,
) -> f64 {
    new_tokens.max(1) as f64
        / generation_time_s(gpu, d_out, d_in, spec, mode, prompt_len, new_tokens)
}

// ---------------------------------------------------------------------
// Speculative decoding
// ---------------------------------------------------------------------

/// Expected tokens committed per speculative round with i.i.d.
/// per-draft acceptance probability `acceptance` and draft depth `k`:
/// `Σ_{i=0}^{k} αⁱ = (1 − α^{k+1}) / (1 − α)` — the accepted prefix is
/// geometrically distributed and every round commits one verifier
/// token past it (correction or bonus).
pub fn expected_tokens_per_round(acceptance: f64, k: usize) -> f64 {
    let a = acceptance.clamp(0.0, 1.0);
    if (1.0 - a) < 1e-12 {
        return (k + 1) as f64;
    }
    (1.0 - a.powi(k as i32 + 1)) / (1.0 - a)
}

/// Predicted self-speculative decode throughput, thousand tokens/sec:
/// `k` sequential drafter GEMVs plus **one** verifier forward over the
/// `k+1`-token causal window. The verify pass prices like a tiny
/// prefill — the verifier's weights cross the memory bus once for all
/// `k+1` positions, which is exactly why batched verification is cheap
/// on decode-bound hardware. Expected committed tokens per round come
/// from [`expected_tokens_per_round`]; the drafter runs with an
/// infinite amortization window (its quantization cost is charged to
/// the serving loop's calibrator, not to the round).
#[allow(clippy::too_many_arguments)]
pub fn speculative_ktokens_per_sec(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    drafter: &DecodeMode,
    verifier: &DecodeMode,
    acceptance: f64,
    k: usize,
) -> f64 {
    let t_draft = 1.0 / (ktokens_per_sec(gpu, d_out, d_in, spec, drafter, f64::INFINITY) * 1000.0);
    let t_verify = prefill_time_s(gpu, d_out, d_in, spec, verifier, k + 1);
    expected_tokens_per_round(acceptance, k) / (k as f64 * t_draft + t_verify) / 1000.0
}

/// Speedup of speculative decode over plain decode on the *verifier*
/// mode (the quality-equivalent baseline: both emit the verifier's
/// tokens).
#[allow(clippy::too_many_arguments)]
pub fn speculative_speedup(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    drafter: &DecodeMode,
    verifier: &DecodeMode,
    acceptance: f64,
    k: usize,
) -> f64 {
    speculative_ktokens_per_sec(gpu, d_out, d_in, spec, drafter, verifier, acceptance, k)
        / ktokens_per_sec(gpu, d_out, d_in, spec, verifier, f64::INFINITY)
}

/// Draft depth maximizing predicted speculative throughput at a given
/// acceptance rate — the fixed point the adaptive-k controller hunts.
#[allow(clippy::too_many_arguments)]
pub fn optimal_k(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    drafter: &DecodeMode,
    verifier: &DecodeMode,
    acceptance: f64,
    k_max: usize,
) -> usize {
    let tps = |k: usize| {
        speculative_ktokens_per_sec(gpu, d_out, d_in, spec, drafter, verifier, acceptance, k)
    };
    (0..=k_max)
        .max_by(|&a, &b| tps(a).partial_cmp(&tps(b)).unwrap_or(std::cmp::Ordering::Equal))
        .unwrap_or(0)
}

/// Speedup of a mode over the FP16 baseline.
pub fn speedup(
    gpu: &GpuSpec,
    d_out: usize,
    d_in: usize,
    spec: &QuantSpec,
    mode: &DecodeMode,
) -> f64 {
    ktokens_per_sec(gpu, d_out, d_in, spec, mode, DEFAULT_AMORTIZE)
        / ktokens_per_sec(gpu, d_out, d_in, spec, &DecodeMode::fp16(), DEFAULT_AMORTIZE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::QWEN3;

    fn spec4() -> QuantSpec {
        QuantSpec::new(4, 32)
    }

    #[test]
    fn labels_match_paper_rows() {
        assert_eq!(DecodeMode::fp16().label(), "FP16");
        assert_eq!(DecodeMode::awq_gemm().label(), "AWQ (awq_gemm)");
        assert_eq!(DecodeMode::awq_marlin().label(), "AWQ (marlin_gemm)");
        assert_eq!(DecodeMode::ttq(16).label(), "TTQ (r = 16)");
    }

    #[test]
    fn quantized_beats_fp16_on_large_models() {
        // Paper: "up to 6.7 folds at 32B on RTX4090" for marlin AWQ.
        let m = QWEN3[5];
        let (dout, din) = m.qproj_dims();
        for g in &GPUS {
            let s = speedup(g, dout, din, &spec4(), &DecodeMode::awq_marlin());
            assert!(s > 2.0, "{}: marlin speedup {s}", g.name);
        }
        let s4090 = speedup(gpu("RTX4090"), dout, din, &spec4(), &DecodeMode::awq_marlin());
        assert!(s4090 > 3.0 && s4090 < 9.0, "4090 marlin speedup {s4090}");
    }

    #[test]
    fn ttq_r0_close_to_marlin() {
        // Paper: "TTQ (r=0) has no significant loss in speed over AWQ".
        let m = QWEN3[4];
        let (dout, din) = m.qproj_dims();
        let g = gpu("A100");
        let marlin = ktokens_per_sec(g, dout, din, &spec4(), &DecodeMode::awq_marlin(), 64.0);
        let ttq = ktokens_per_sec(g, dout, din, &spec4(), &DecodeMode::ttq(0), 64.0);
        assert!(ttq > marlin * 0.7, "ttq {ttq} vs marlin {marlin}");
        assert!(ttq <= marlin * 1.02);
    }

    #[test]
    fn ttq_r16_pays_lowrank_tax_but_beats_fp16_when_large() {
        let m = QWEN3[5];
        let (dout, din) = m.qproj_dims();
        let g = gpu("RTX4090");
        let r0 = ktokens_per_sec(g, dout, din, &spec4(), &DecodeMode::ttq(0), 64.0);
        let r16 = ktokens_per_sec(g, dout, din, &spec4(), &DecodeMode::ttq(16), 64.0);
        let fp = ktokens_per_sec(g, dout, din, &spec4(), &DecodeMode::fp16(), 64.0);
        assert!(r16 < r0);
        // Paper: "TTQ can still accelerate ... up to 4.9 folds at 32B"
        let s = r16 / fp;
        assert!(s > 2.0, "r16 speedup {s}");
    }

    #[test]
    fn throughput_degrades_with_model_size() {
        // Paper observation #1.
        let g = gpu("A40");
        let mut last = f64::MAX;
        for m in &QWEN3 {
            let (dout, din) = m.qproj_dims();
            let k = ktokens_per_sec(g, dout, din, &spec4(), &DecodeMode::fp16(), 64.0);
            assert!(k < last, "{}: {k} !< {last}", m.name);
            last = k;
        }
    }

    #[test]
    fn ttq_advantage_grows_with_size() {
        // Paper observation #5: more advantage on larger LLMs.
        let g = gpu("A40");
        let (d0, i0) = QWEN3[0].qproj_dims();
        let (d5, i5) = QWEN3[5].qproj_dims();
        let s_small = speedup(g, d0, i0, &spec4(), &DecodeMode::ttq(0));
        let s_large = speedup(g, d5, i5, &spec4(), &DecodeMode::ttq(0));
        assert!(s_large > s_small);
    }

    #[test]
    fn two_bit_packs_faster_than_four_bit() {
        // App. H: custom 2-bit kernels "theoretically doubling" traffic
        // reduction; the roofline must show 2-bit ≥ 4-bit throughput.
        let (dout, din) = QWEN3[5].qproj_dims();
        let g = gpu("A100");
        let k2 =
            ktokens_per_sec(g, dout, din, &QuantSpec::new(2, 32), &DecodeMode::awq_marlin(), 64.0);
        let k4 =
            ktokens_per_sec(g, dout, din, &QuantSpec::new(4, 32), &DecodeMode::awq_marlin(), 64.0);
        assert!(k2 > k4);
    }

    #[test]
    fn absolute_scale_sane() {
        // FP16 0.6B on A40 should land within ~2x of the paper's 57.58
        // k tokens/s (we claim shape, not absolutes — but stay on-scale).
        let (dout, din) = QWEN3[0].qproj_dims();
        let k = ktokens_per_sec(gpu("A40"), dout, din, &spec4(), &DecodeMode::fp16(), 64.0);
        assert!(k > 25.0 && k < 120.0, "FP16 0.6B A40: {k}");
    }

    #[test]
    fn quantization_helps_decode_more_than_prefill() {
        // The whole point of the prefill/decode split: decode is
        // memory-bound (weight traffic per token), prefill is compute-
        // bound at long prompts — so W4 speedup over FP16 must be much
        // larger in decode than in prefill.
        let (dout, din) = QWEN3[5].qproj_dims();
        let g = gpu("A100");
        let s = spec4();
        let awq = DecodeMode::awq_marlin();
        let fp = DecodeMode::fp16();
        let decode_speedup = ktokens_per_sec(g, dout, din, &s, &awq, 64.0)
            / ktokens_per_sec(g, dout, din, &s, &fp, 64.0);
        let prefill_speedup = prefill_time_s(g, dout, din, &s, &fp, 2048)
            / prefill_time_s(g, dout, din, &s, &awq, 2048);
        assert!(decode_speedup > 2.0, "decode speedup {decode_speedup}");
        assert!(
            prefill_speedup < decode_speedup / 1.5,
            "prefill speedup {prefill_speedup} should trail decode {decode_speedup}"
        );
    }

    #[test]
    fn prefill_goes_compute_bound_with_prompt_length() {
        let (dout, din) = QWEN3[3].qproj_dims();
        let g = gpu("A40");
        let s = spec4();
        let short = prefill_time_s(g, dout, din, &s, &DecodeMode::fp16(), 16);
        let long = prefill_time_s(g, dout, din, &s, &DecodeMode::fp16(), 4096);
        assert!(long > short * 2.0, "prefill {short} → {long} must scale with L");
    }

    #[test]
    fn generation_time_is_prefill_plus_decode_steps() {
        let (dout, din) = QWEN3[2].qproj_dims();
        let g = gpu("L40");
        let s = spec4();
        let m = DecodeMode::ttq(0);
        let t1 = generation_time_s(g, dout, din, &s, &m, 256, 1);
        let t65 = generation_time_s(g, dout, din, &s, &m, 256, 65);
        // one generated token = pure prefill cost
        assert!((t1 - prefill_time_s(g, dout, din, &s, &m, 256)).abs() < 1e-12);
        // 64 extra decode steps at the un-amortized per-step rate
        let per_step = (t65 - t1) / 64.0;
        let want = 1.0 / (ktokens_per_sec(g, dout, din, &s, &m, f64::INFINITY) * 1000.0);
        assert!((per_step - want).abs() / want < 1e-9);
        // and quantized long generations out-throughput FP16
        let ttq = generation_tokens_per_sec(g, dout, din, &s, &m, 256, 128);
        let fp = generation_tokens_per_sec(g, dout, din, &s, &DecodeMode::fp16(), 256, 128);
        assert!(ttq > fp, "ttq {ttq} vs fp16 {fp} at 128 generated tokens");
    }

    #[test]
    fn expected_tokens_closed_form() {
        // α = 0: every draft rejected → exactly the 1 verifier token
        assert!((expected_tokens_per_round(0.0, 4) - 1.0).abs() < 1e-12);
        // α = 1: clean sweep → k drafts + the bonus token
        assert!((expected_tokens_per_round(1.0, 4) - 5.0).abs() < 1e-12);
        // α = 0.5, k = 2: 1 + 0.5 + 0.25
        assert!((expected_tokens_per_round(0.5, 2) - 1.75).abs() < 1e-12);
        // monotone in both acceptance and depth
        assert!(expected_tokens_per_round(0.8, 4) > expected_tokens_per_round(0.6, 4));
        assert!(expected_tokens_per_round(0.8, 6) > expected_tokens_per_round(0.8, 4));
    }

    #[test]
    fn speculative_beats_plain_fp16_at_high_acceptance() {
        // The tentpole claim: a W4 drafter (≈3× faster GEMV) + one
        // batched fp16 verify per round out-throughputs plain fp16
        // decode once drafts mostly land — with zero quality loss,
        // since the committed stream is the verifier's.
        let (dout, din) = QWEN3[5].qproj_dims();
        let s = spec4();
        let drafter = DecodeMode::ttq(0);
        let verifier = DecodeMode::fp16();
        for g in &GPUS {
            let sp = speculative_speedup(g, dout, din, &s, &drafter, &verifier, 0.8, 4);
            assert!(sp > 1.3, "{}: speculative speedup {sp} at α=0.8, k=4", g.name);
        }
    }

    #[test]
    fn speculative_degrades_gracefully_at_low_acceptance() {
        // α → 0: every round pays k wasted drafts + the verify pass for
        // one token — strictly worse than plain decode. The adaptive-k
        // controller exists precisely to exit this regime.
        let (dout, din) = QWEN3[4].qproj_dims();
        let g = gpu("A100");
        let s = spec4();
        let sp =
            speculative_speedup(g, dout, din, &s, &DecodeMode::ttq(0), &DecodeMode::fp16(), 0.0, 4);
        assert!(sp < 1.0, "speculation must not pay at α=0: {sp}");
        // and throughput is monotone in acceptance
        let mut last = 0.0;
        for a in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let t = speculative_ktokens_per_sec(
                g,
                dout,
                din,
                &s,
                &DecodeMode::ttq(0),
                &DecodeMode::fp16(),
                a,
                4,
            );
            assert!(t > last, "throughput must grow with acceptance: {t} at α={a}");
            last = t;
        }
    }

    #[test]
    fn optimal_k_grows_with_acceptance() {
        let (dout, din) = QWEN3[5].qproj_dims();
        let g = gpu("RTX4090");
        let s = spec4();
        let d = DecodeMode::ttq(0);
        let v = DecodeMode::fp16();
        let k_low = optimal_k(g, dout, din, &s, &d, &v, 0.2, 16);
        let k_high = optimal_k(g, dout, din, &s, &d, &v, 0.95, 16);
        assert!(k_high > k_low, "k* {k_low} (α=0.2) vs {k_high} (α=0.95)");
        // at α≈1 a deeper window is always better within the cap
        assert!(k_high >= 8, "near-certain acceptance wants a deep window, got {k_high}");
    }

    #[test]
    fn roofline_primitive_picks_the_binding_roof() {
        // 10 GB/s, 100 GFLOP/s → ridge at 10 FLOP/byte.
        // 1e6 bytes at intensity 0.5: memory roof binds, 100 us.
        let t = roofline_us(10.0, 100.0, 5e5, 1e6);
        assert!((t - 100.0).abs() < 1e-9, "memory-bound time {t}");
        // 1e8 flops over 1e6 bytes (intensity 100): compute roof, 1000 us.
        let t = roofline_us(10.0, 100.0, 1e8, 1e6);
        assert!((t - 1000.0).abs() < 1e-9, "compute-bound time {t}");
        assert_eq!(Bound::Memory.name(), "memory");
        assert_eq!(Bound::Compute.name(), "compute");
    }

    #[test]
    fn vector_ceiling_scales_by_lanes() {
        // Scalar sites keep the measured ceiling untouched.
        assert_eq!(vector_ceiling_gflops(12.5, 1), 12.5);
        // AVX2 (8 lanes) / NEON (4 lanes) raise the compute roof only.
        assert_eq!(vector_ceiling_gflops(12.5, 8), 100.0);
        assert_eq!(vector_ceiling_gflops(12.5, 4), 50.0);
        // Degenerate lane counts clamp to the identity, never to zero.
        assert_eq!(vector_ceiling_gflops(12.5, 0), 12.5);
        // A memory-bound shape stays memory-bound under a higher
        // compute roof (raising GFLOP/s can only shrink the compute
        // term of the max).
        let scalar = roofline_us(10.0, 100.0, 5e5, 1e6);
        let vector = roofline_us(10.0, vector_ceiling_gflops(100.0, 8), 5e5, 1e6);
        assert_eq!(scalar, vector, "memory roof unchanged by lanes");
    }

    #[test]
    fn registry_methods_map_to_modes() {
        // any registered method can become a runtime-table row
        let nf = DecodeMode::for_method(MethodSpec::parse("nf:4").unwrap());
        assert_eq!(nf.kernel, Kernel::MarlinGemm);
        let fp = DecodeMode::for_method(MethodSpec::parse("fp").unwrap());
        assert_eq!(fp.kernel, Kernel::Fp16Gemv);
        let (dout, din) = QWEN3[2].qproj_dims();
        let k = ktokens_per_sec(gpu("L40"), dout, din, &spec4(), &nf, 64.0);
        assert!(k.is_finite() && k > 0.0);
    }
}
