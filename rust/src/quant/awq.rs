//! AWQ: activation-aware scaled QDQ — paper Eq. (19-20) / App. C.
//!
//! `D_i = (‖X_i,:‖_p + λ)^α`, `Ŵ = Q[W·D]·D⁻¹`. The diagonal can come
//! from raw activations (the fused test-time path) or from accumulated
//! norm sums Σ|x|^p collected by the `stats` artifact across calibration
//! batches (the offline Fig. 1(a) path). Both are provided here because
//! the coordinator composes them differently for AWQ vs TTQ.

use super::formats::QuantSpec;
use crate::linalg::Mat;

/// Accumulated activation statistics for one linear layer's input.
///
/// `norm_sums[k][i] = Σ_t |x_i(t)|^{p_k}` for the p-grid shared with the
/// L2 stats artifact (`python/compile/model.py::NORM_PS`).
#[derive(Clone, Debug, Default)]
pub struct ActStats {
    /// The p-norm grid the sums are kept for.
    pub ps: Vec<f64>,
    /// Per-p, per-channel accumulated sums, `[n_p][d_in]`.
    pub norm_sums: Vec<Vec<f64>>, // [n_p][d_in]
    /// Tokens accumulated into the sums.
    pub count: f64,
}

impl ActStats {
    /// Zeroed statistics for a `d_in`-channel input on the p-grid.
    pub fn new(ps: &[f64], d_in: usize) -> Self {
        ActStats {
            ps: ps.to_vec(),
            norm_sums: vec![vec![0.0; d_in]; ps.len()],
            count: 0.0,
        }
    }

    /// Merge another batch's sums (used by multi-batch calibration and
    /// by the coordinator's running EMA state).
    pub fn accumulate(&mut self, norms: &[Vec<f64>], count: f64) {
        assert_eq!(norms.len(), self.ps.len());
        for (dst, src) in self.norm_sums.iter_mut().zip(norms) {
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        self.count += count;
    }

    /// Exponential decay toward fresh statistics ("on-device
    /// self-calibration": decode steps refresh prefill stats).
    pub fn decay(&mut self, factor: f64) {
        for row in &mut self.norm_sums {
            for v in row.iter_mut() {
                *v *= factor;
            }
        }
        self.count *= factor;
    }

    /// Input channel count the sums cover.
    pub fn d_in(&self) -> usize {
        self.norm_sums.first().map(|v| v.len()).unwrap_or(0)
    }

    /// Index of p in the grid (exact match).
    fn p_index(&self, p: f64) -> usize {
        self.ps
            .iter()
            .position(|&v| (v - p).abs() < 1e-9)
            .unwrap_or_else(|| panic!("p={p} not in stats grid {:?}", self.ps))
    }
}

/// Diagonal from accumulated norm sums: D_i = ((Σ|x|^p)^{1/p} + λ)^α.
pub fn diag_from_norm_sums(stats: &ActStats, p: f64, lam: f64, alpha: f64) -> Vec<f32> {
    let k = stats.p_index(p);
    stats.norm_sums[k]
        .iter()
        .map(|&s| ((s.powf(1.0 / p) + lam).powf(alpha)) as f32)
        .collect()
}

/// Diagonal straight from an activation matrix X (d, T) — test-time path.
pub fn diag_from_x(x: &Mat, p: f64, lam: f64, alpha: f64) -> Vec<f32> {
    let mut out = Vec::with_capacity(x.rows);
    for i in 0..x.rows {
        let row = x.row(i);
        let nrm = if (p - 2.0).abs() < 1e-9 {
            row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
        } else if (p - 1.0).abs() < 1e-9 {
            row.iter().map(|&v| (v as f64).abs()).sum::<f64>()
        } else {
            row.iter()
                .map(|&v| (v as f64).abs().powf(p))
                .sum::<f64>()
                .powf(1.0 / p)
        };
        out.push(((nrm + lam).powf(alpha)) as f32);
    }
    out
}

/// Scaled QDQ: Ŵ = Q[W·diag(D)]·diag(D)⁻¹ (Eq. 20).
///
/// Perf notes (EXPERIMENTS.md §Perf): fused single memory pass — the
/// naive scale → QDQ → descale walks the weight three times; here each
/// flat group is scaled into an L1-resident scratch, its params derived
/// there, and the dequant-descale written straight back. Column index
/// is tracked incrementally (no per-element modulo).
pub fn awq_quantize(w: &Mat, dvec: &[f32], spec: &QuantSpec) -> Mat {
    assert_eq!(dvec.len(), w.cols, "diagonal length must be d_in");
    let g = spec.group;
    assert_eq!(w.data.len() % g, 0);
    let qmax = spec.qmax();
    let cols = w.cols;
    let mut out = w.clone();
    let mut scaled = vec![0.0f32; g];
    for (gi, grp) in out.data.chunks_mut(g).enumerate() {
        let mut col = (gi * g) % cols;
        // pass 1 (L1 scratch): prescale + the group's min/max
        for (dst, v) in scaled.iter_mut().zip(grp.iter()) {
            *dst = *v * dvec[col];
            col += 1;
            if col == cols {
                col = 0;
            }
        }
        let (s, z) = super::formats::group_params(&scaled, qmax, spec.format);
        let inv_s = 1.0 / s;
        // pass 2: QDQ + descale, written back in the same sweep
        let mut col = (gi * g) % cols;
        for (v, sc) in grp.iter_mut().zip(scaled.iter()) {
            let q = ((*sc - z) * inv_s).clamp(0.0, qmax).round_ties_even();
            *v = (q * s + z) / dvec[col];
            col += 1;
            if col == cols {
                col = 0;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{activation_loss, Mat, Rng};
    use crate::quant::rtn::rtn_quantize;

    fn spec(bits: u32, group: usize) -> QuantSpec {
        QuantSpec::new(bits, group)
    }

    #[test]
    fn alpha_zero_degenerates_to_rtn() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(16, 64, &mut rng);
        let x = Mat::randn(64, 32, &mut rng);
        let d = diag_from_x(&x, 2.0, 0.4, 0.0);
        let a = awq_quantize(&w, &d, &spec(3, 32));
        let r = rtn_quantize(&w, &spec(3, 32));
        for (p, q) in a.data.iter().zip(&r.data) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn beats_rtn_on_outlier_activations() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(32, 64, &mut rng);
        // lognormal channel scales — LLM-style outlier channels
        let scales: Vec<f32> = (0..64).map(|_| rng.lognormal(0.0, 1.5) as f32).collect();
        let mut x = Mat::randn(64, 256, &mut rng);
        for i in 0..64 {
            for v in x.row_mut(i) {
                *v *= scales[i];
            }
        }
        let d = diag_from_x(&x, 2.0, 0.4, 0.5);
        let l_awq = activation_loss(&w, &awq_quantize(&w, &d, &spec(2, 32)), &x);
        let l_rtn = activation_loss(&w, &rtn_quantize(&w, &spec(2, 32)), &x);
        assert!(l_awq < l_rtn, "awq {l_awq} vs rtn {l_rtn}");
    }

    #[test]
    fn diag_from_sums_matches_diag_from_x() {
        let mut rng = Rng::new(3);
        let x = Mat::randn(48, 100, &mut rng);
        let ps = [0.5f64, 1.0, 2.0, 4.0];
        let mut stats = ActStats::new(&ps, 48);
        let sums: Vec<Vec<f64>> = ps
            .iter()
            .map(|&p| {
                (0..48)
                    .map(|i| x.row(i).iter().map(|&v| (v as f64).abs().powf(p)).sum())
                    .collect()
            })
            .collect();
        stats.accumulate(&sums, 100.0);
        for &p in &ps {
            let a = diag_from_norm_sums(&stats, p, 0.4, 0.5);
            let b = diag_from_x(&x, p, 0.4, 0.5);
            for (u, v) in a.iter().zip(&b) {
                assert!((u - v).abs() < 1e-4, "p={p}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn accumulate_is_additive() {
        let ps = [2.0f64];
        let mut a = ActStats::new(&ps, 4);
        a.accumulate(&[vec![1.0, 2.0, 3.0, 4.0]], 10.0);
        a.accumulate(&[vec![1.0, 2.0, 3.0, 4.0]], 10.0);
        assert_eq!(a.norm_sums[0], vec![2.0, 4.0, 6.0, 8.0]);
        assert_eq!(a.count, 20.0);
    }

    #[test]
    fn decay_halves() {
        let mut a = ActStats::new(&[2.0], 2);
        a.accumulate(&[vec![4.0, 8.0]], 2.0);
        a.decay(0.5);
        assert_eq!(a.norm_sums[0], vec![2.0, 4.0]);
        assert_eq!(a.count, 1.0);
    }

    #[test]
    fn quantize_preserves_shape_and_finiteness() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(8, 96, &mut rng);
        let x = Mat::randn(96, 3, &mut rng);
        let d = diag_from_x(&x, 1.0, 0.4, 0.75);
        let q = awq_quantize(&w, &d, &spec(2, 16));
        assert_eq!((q.rows, q.cols), (8, 96));
        assert!(q.data.iter().all(|v| v.is_finite()));
    }
}
