//! QDQ format variants — paper App. D.
//!
//! Asymmetric min/max (Eq. 25-26, the default), symmetric (Eq. 29-30),
//! and the range-expansion factor ν (Eq. 27-28, best ≈ 0.95). The
//! ablation bench `ttq-serve sweep formats` compares them.

/// Scale/zero derivation for a group.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QdqFormat {
    /// S = (Wmax − Wmin)/qmax, Z = Wmin — Eq. (25-26).
    Asymmetric,
    /// S = 2|W|max/qmax, Z = −|W|max — Eq. (29-30); fewer dof, cheaper
    /// memory, generally worse accuracy.
    Symmetric,
    /// Asymmetric with expanded range endpoints W′ (Eq. 27-28).
    Expanded { nu: f32 },
}

/// Full quantizer configuration (bits + groupsize + format).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantSpec {
    /// Code width in bits (2..=8 for the packed path).
    pub bits: u32,
    /// Elements sharing one scale/zero pair (flat grouping).
    pub group: usize,
    /// Scale/zero derivation variant.
    pub format: QdqFormat,
}

impl QuantSpec {
    /// Asymmetric-format spec at the given bits/groupsize.
    pub fn new(bits: u32, group: usize) -> Self {
        QuantSpec { bits, group, format: QdqFormat::Asymmetric }
    }

    /// `2^bits − 1` — delegates to [`crate::quant::qmax`], the single
    /// source of truth for the convention.
    #[inline]
    pub fn qmax(&self) -> f32 {
        super::qmax(self.bits)
    }

    /// Bytes to store one weight element + amortized group params, the
    /// quantity the paper credits for the GPU speedup (App. B: "qd'd
    /// bits for W_int and d'd/g parameters for S and Z").
    pub fn bytes_per_element(&self) -> f64 {
        let params_per_group = match self.format {
            QdqFormat::Symmetric => 1.0, // Z redundant (App. D)
            _ => 2.0,
        };
        self.bits as f64 / 8.0 + params_per_group * 2.0 / self.group as f64
        // group params stored f16 (2 bytes), as deployed kernels do
    }
}

/// 4-lane min/max reduction: breaks the serial minss/maxss dependency
/// chain so the group scan runs at load bandwidth (§Perf).
#[inline]
fn minmax(grp: &[f32]) -> (f32, f32) {
    let mut mn = [f32::MAX; 4];
    let mut mx = [f32::MIN; 4];
    let chunks = grp.chunks_exact(4);
    let rem = chunks.remainder();
    for c in chunks {
        for i in 0..4 {
            mn[i] = mn[i].min(c[i]);
            mx[i] = mx[i].max(c[i]);
        }
    }
    let (mut amn, mut amx) = (
        mn[0].min(mn[1]).min(mn[2].min(mn[3])),
        mx[0].max(mx[1]).max(mx[2].max(mx[3])),
    );
    for &v in rem {
        amn = amn.min(v);
        amx = amx.max(v);
    }
    (amn, amx)
}

/// Per-group (scale, zero) under the chosen format. Zero-width groups
/// degenerate to S = 1 so dequant returns the constant Z exactly.
#[inline]
pub fn group_params(grp: &[f32], qmax: f32, format: QdqFormat) -> (f32, f32) {
    match format {
        QdqFormat::Asymmetric => {
            let (mn, mx) = minmax(grp);
            let s = (mx - mn) / qmax;
            (if s <= 0.0 { 1.0 } else { s }, mn)
        }
        QdqFormat::Symmetric => {
            let mut amax = 0.0f32;
            for &v in grp {
                amax = amax.max(v.abs());
            }
            let s = 2.0 * amax / qmax;
            (if s <= 0.0 { 1.0 } else { s }, -amax)
        }
        QdqFormat::Expanded { nu } => {
            let (mn, mx) = minmax(grp);
            let mx2 = 0.5 * (1.0 + nu) * mx + 0.5 * (1.0 - nu) * mn;
            let mn2 = 0.5 * (1.0 - nu) * mx + 0.5 * (1.0 + nu) * mn;
            let s = (mx2 - mn2) / qmax;
            (if s <= 0.0 { 1.0 } else { s }, mn2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Mat, Rng};
    use crate::quant::rtn::rtn_quantize;

    #[test]
    fn asymmetric_params_match_minmax() {
        let grp = [1.0f32, -3.0, 2.0, 0.5];
        let (s, z) = group_params(&grp, 7.0, QdqFormat::Asymmetric);
        assert!((z + 3.0).abs() < 1e-7);
        assert!((s - 5.0 / 7.0).abs() < 1e-6);
    }

    #[test]
    fn symmetric_params() {
        let grp = [1.0f32, -3.0, 2.0];
        let (s, z) = group_params(&grp, 15.0, QdqFormat::Symmetric);
        assert!((s - 6.0 / 15.0).abs() < 1e-6);
        assert!((z + 3.0).abs() < 1e-7);
    }

    #[test]
    fn expanded_nu1_equals_asymmetric() {
        let grp = [0.2f32, -1.4, 0.9, 2.2];
        let a = group_params(&grp, 7.0, QdqFormat::Asymmetric);
        let e = group_params(&grp, 7.0, QdqFormat::Expanded { nu: 1.0 });
        assert!((a.0 - e.0).abs() < 1e-6 && (a.1 - e.1).abs() < 1e-6);
    }

    #[test]
    fn expanded_shrinks_range() {
        let grp = [0.0f32, 1.0];
        let (s, z) = group_params(&grp, 1.0, QdqFormat::Expanded { nu: 0.9 });
        assert!(s < 1.0 && z > 0.0);
    }

    #[test]
    fn symmetric_never_beats_asymmetric() {
        let mut rng = Rng::new(9);
        let w = Mat::randn(8, 64, &mut rng);
        let e_a = w
            .sub(&rtn_quantize(&w, &QuantSpec::new(4, 32)))
            .frob_sq();
        let mut spec_s = QuantSpec::new(4, 32);
        spec_s.format = QdqFormat::Symmetric;
        let e_s = w.sub(&rtn_quantize(&w, &spec_s)).frob_sq();
        assert!(e_s >= e_a - 1e-9);
    }

    #[test]
    fn bytes_per_element_ordering() {
        // 2-bit must cost half the weight traffic of 4-bit (same group)
        let b2 = QuantSpec::new(2, 32).bytes_per_element();
        let b4 = QuantSpec::new(4, 32).bytes_per_element();
        assert!((b4 - b2 - 0.25).abs() < 1e-9);
        // larger groups amortize S/Z — Table 2's memory argument
        assert!(
            QuantSpec::new(3, 64).bytes_per_element()
                < QuantSpec::new(3, 32).bytes_per_element()
        );
        // symmetric stores one param per group
        let mut sym = QuantSpec::new(3, 32);
        sym.format = QdqFormat::Symmetric;
        assert!(sym.bytes_per_element() < QuantSpec::new(3, 32).bytes_per_element());
    }
}
