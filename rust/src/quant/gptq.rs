//! GPTQ baseline — greedy optimal-brain-surgeon quantization (App. C).
//!
//! The paper positions GPTQ as the accurate-but-expensive comparator:
//! it needs the *full* input correlation C = XXᵀ and a Cholesky-based
//! inverse Hessian, O(d³ + d d′T), versus AWQ/TTQ's diagonal shortcut.
//! We implement the standard column-sequential algorithm with error
//! feedback into the not-yet-quantized columns.
//!
//! Grouping note: GPTQ's natural grouping is per-row along consecutive
//! input columns (params frozen when a column enters a new group) — it
//! cannot use the paper's flat grouping because columns are visited in
//! order with cross-column error propagation.

use super::formats::{group_params, QuantSpec};
use crate::linalg::{cholesky, cholesky_inverse, Mat};

/// Quantize W (d_out, d_in) given the input correlation C (d_in, d_in).
///
/// `damp` is the λ′ damping fraction added to the diagonal (Eq. 17);
/// most literature uses ~1% of the mean diagonal.
pub fn gptq_quantize(w: &Mat, c: &Mat, spec: &QuantSpec, damp: f64) -> Mat {
    let d_in = w.cols;
    assert_eq!(c.rows, d_in);
    assert_eq!(c.cols, d_in);
    // group must tile rows (columns visited sequentially)
    let g = spec.group.min(d_in);
    let qmax = spec.qmax();

    // Damped Hessian H = C + λ′·mean(diag)·I
    let mean_diag: f64 = (0..d_in).map(|i| c.at(i, i) as f64).sum::<f64>() / d_in as f64;
    let lam = (damp * mean_diag).max(1e-8) as f32;
    let mut h = c.clone();
    for i in 0..d_in {
        *h.at_mut(i, i) += lam;
    }

    // Inverse Hessian, then its Cholesky (upper via transpose of lower):
    // the standard GPTQ trick — Hinv's Cholesky gives the per-column
    // denominators and the error-propagation row in one triangular matrix.
    let hinv = match cholesky_inverse(&h) {
        Some(m) => m,
        None => {
            // fall back: heavier damping
            let mut h2 = h.clone();
            for i in 0..d_in {
                *h2.at_mut(i, i) += 10.0 * lam + 1e-3;
            }
            cholesky_inverse(&h2).expect("damped Hessian must be PD")
        }
    };
    let l = cholesky(&hinv).expect("Hinv is PD");
    // upper-triangular U = Lᵀ: U[j, k] for k ≥ j
    let u = l.transpose();

    let mut wq = w.clone();
    let d_out = w.rows;
    // per-(row, group) scale/zero, frozen at group entry
    let n_groups = d_in.div_ceil(g);
    let mut scales = vec![0.0f32; d_out * n_groups];
    let mut zeros = vec![0.0f32; d_out * n_groups];

    for j in 0..d_in {
        let gi = j / g;
        if j % g == 0 {
            // freeze group params from the *current* (error-fed) weights
            let hi = ((gi + 1) * g).min(d_in);
            for r in 0..d_out {
                let row = wq.row(r);
                let (s, z) = group_params(&row[gi * g..hi], qmax, spec.format);
                scales[r * n_groups + gi] = s;
                zeros[r * n_groups + gi] = z;
            }
        }
        let ujj = u.at(j, j).max(1e-12);
        // quantize column j; propagate scaled error to columns k > j
        for r in 0..d_out {
            let s = scales[r * n_groups + gi];
            let z = zeros[r * n_groups + gi];
            let v = wq.at(r, j);
            let q = ((v - z) / s).round().clamp(0.0, qmax) * s + z;
            *wq.at_mut(r, j) = q;
            let err = (v - q) / ujj;
            if err != 0.0 {
                let urow = u.row(j);
                let wrow = wq.row_mut(r);
                for k in j + 1..d_in {
                    wrow[k] -= err * urow[k];
                }
            }
        }
    }
    wq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{activation_loss, Rng};
    use crate::quant::rtn::rtn_quantize;

    fn outlier_x(d: usize, t: usize, rng: &mut Rng) -> Mat {
        let scales: Vec<f32> = (0..d).map(|_| rng.lognormal(0.0, 1.2) as f32).collect();
        let mut x = Mat::randn(d, t, rng);
        for i in 0..d {
            for v in x.row_mut(i) {
                *v *= scales[i];
            }
        }
        x
    }

    #[test]
    fn beats_rtn_on_correlated_activations() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(24, 48, &mut rng);
        let x = outlier_x(48, 256, &mut rng);
        let c = x.matmul_bt(&x); // XXᵀ with X as (d, T): rows are channels
        let spec = QuantSpec::new(2, 32);
        let wq = gptq_quantize(&w, &c, &spec, 0.01);
        let e_gptq = activation_loss(&w, &wq, &x);
        let e_rtn = activation_loss(&w, &rtn_quantize(&w, &spec), &x);
        assert!(e_gptq < e_rtn, "gptq {e_gptq} vs rtn {e_rtn}");
    }

    #[test]
    fn identity_correlation_close_to_rtn_error() {
        // With C = I there is no cross-column structure to exploit;
        // GPTQ should be in the same error ballpark as RTN (weight-only).
        let mut rng = Rng::new(2);
        let w = Mat::randn(16, 32, &mut rng);
        let c = Mat::eye(32);
        let spec = QuantSpec::new(3, 32);
        let wq = gptq_quantize(&w, &c, &spec, 0.01);
        let e_gptq = w.sub(&wq).frob_sq();
        let e_rtn = w.sub(&rtn_quantize(&w, &spec)).frob_sq();
        assert!(e_gptq < e_rtn * 2.0 + 1e-6);
    }

    #[test]
    fn output_is_finite_and_bounded() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(8, 64, &mut rng);
        let x = outlier_x(64, 32, &mut rng);
        let c = x.matmul_bt(&x);
        let wq = gptq_quantize(&w, &c, &QuantSpec::new(2, 16), 0.01);
        assert!(wq.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn high_bits_near_lossless() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(8, 32, &mut rng);
        let x = Mat::randn(32, 64, &mut rng);
        let c = x.matmul_bt(&x);
        let wq = gptq_quantize(&w, &c, &QuantSpec::new(8, 32), 0.01);
        let rel = w.sub(&wq).frob_sq() / w.frob_sq();
        assert!(rel < 1e-3, "rel {rel}");
    }

    #[test]
    fn group_smaller_than_d_in() {
        let mut rng = Rng::new(5);
        let w = Mat::randn(4, 64, &mut rng);
        let x = Mat::randn(64, 32, &mut rng);
        let c = x.matmul_bt(&x);
        // g=16 → 4 groups per row, all frozen progressively
        let wq = gptq_quantize(&w, &c, &QuantSpec::new(3, 16), 0.01);
        assert_eq!((wq.rows, wq.cols), (4, 64));
        assert!(wq.data.iter().all(|v| v.is_finite()));
    }
}
