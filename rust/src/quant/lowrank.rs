//! Low-rank decomposition for TTQ — paper App. E.
//!
//! `Ŵ = W_q + BA` with B = U_r Λ_r^{1/2}, A = Λ_r^{1/2} V_r from the
//! top-r SVD of W (Eq. 31-33). Also ships the alternating refinement of
//! Eq. 34-35 — the paper found it gave "almost no gain", and our
//! ablation bench (`ttq-serve sweep lowrank-init`) reproduces that.

use super::formats::QuantSpec;
use super::rtn::rtn_quantize;
use crate::linalg::{truncated_svd, Mat};

/// Static low-rank factors for one linear layer.
#[derive(Clone, Debug)]
pub struct LowRank {
    /// Left factor, `(d_out, r)`.
    pub b: Mat,
    /// Right factor, `(r, d_in)`.
    pub a: Mat,
}

impl LowRank {
    /// The decomposition rank r.
    pub fn rank(&self) -> usize {
        self.b.cols
    }

    /// The rank-r product BA (d_out, d_in).
    pub fn product(&self) -> Mat {
        self.b.matmul(&self.a)
    }

    /// Project activations: B (A X) — the O[r(d+d')T] fast path.
    pub fn project(&self, x: &Mat) -> Mat {
        self.b.matmul(&self.a.matmul(x))
    }
}

/// Top-r principal-component initialization (Eq. 31-33).
pub fn lowrank_init(w: &Mat, r: usize) -> LowRank {
    let svd = truncated_svd(w, r, 8);
    let r = svd.s.len();
    let mut b = Mat::zeros(w.rows, r);
    let mut a = Mat::zeros(r, w.cols);
    for j in 0..r {
        let sq = svd.s[j].max(0.0).sqrt();
        for i in 0..w.rows {
            *b.at_mut(i, j) = svd.u.at(i, j) * sq;
        }
        for c in 0..w.cols {
            *a.at_mut(j, c) = sq * svd.vt.at(j, c);
        }
    }
    LowRank { b, a }
}

/// Quantization-aware alternating refinement (Eq. 34-35):
///   B⁽ᵏ⁾A⁽ᵏ⁾ = svd_r[W − W_q⁽ᵏ⁾];  W_q⁽ᵏ⁺¹⁾ = Q[W − B⁽ᵏ⁾A⁽ᵏ⁾].
pub fn alternating_refine(
    w: &Mat,
    r: usize,
    spec: &QuantSpec,
    iters: usize,
) -> (LowRank, Mat) {
    let mut lr = lowrank_init(w, r);
    let mut wq = rtn_quantize(&w.sub(&lr.product()), spec);
    for _ in 0..iters {
        let resid = w.sub(&wq);
        lr = lowrank_init(&resid, r);
        wq = rtn_quantize(&w.sub(&lr.product()), spec);
    }
    (lr, wq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    #[test]
    fn init_matches_truncated_energy() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(24, 40, &mut rng);
        let lr = lowrank_init(&w, 8);
        let resid = w.sub(&lr.product());
        // residual energy strictly below total (top-8 captures something)
        assert!(resid.frob_sq() < w.frob_sq() * 0.95);
    }

    #[test]
    fn ba_shapes() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(16, 48, &mut rng);
        let lr = lowrank_init(&w, 4);
        assert_eq!((lr.b.rows, lr.b.cols), (16, 4));
        assert_eq!((lr.a.rows, lr.a.cols), (4, 48));
        assert_eq!(lr.rank(), 4);
    }

    #[test]
    fn project_equals_product_matmul() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(12, 20, &mut rng);
        let x = Mat::randn(20, 7, &mut rng);
        let lr = lowrank_init(&w, 3);
        let fast = lr.project(&x);
        let slow = lr.product().matmul(&x);
        for (a, b) in fast.data.iter().zip(&slow.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn full_rank_init_near_exact() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(10, 10, &mut rng);
        let lr = lowrank_init(&w, 10);
        let rel = w.sub(&lr.product()).frob_sq() / w.frob_sq();
        assert!(rel < 1e-4, "rel {rel}");
    }

    #[test]
    fn refine_does_not_increase_error() {
        let mut rng = Rng::new(5);
        let w = Mat::randn(16, 64, &mut rng);
        let spec = QuantSpec::new(2, 32);
        let lr0 = lowrank_init(&w, 8);
        let wq0 = rtn_quantize(&w.sub(&lr0.product()), &spec);
        let e0 = w.sub(&wq0.add(&lr0.product())).frob_sq();
        let (lr1, wq1) = alternating_refine(&w, 8, &spec, 3);
        let e1 = w.sub(&wq1.add(&lr1.product())).frob_sq();
        // paper: "almost no gain" — allow equality within 5% tolerance,
        // but it must not blow up.
        assert!(e1 <= e0 * 1.05, "refined {e1} vs init {e0}");
    }
}
