//! The paper's quantization algorithms, pure Rust.
//!
//! Everything here operates on [`crate::linalg::Mat`] weights shaped
//! paper-style `(d_out, d_in)` with *flat* grouping over the flattened
//! weight (a group may span row boundaries — exactly the paper's App. B
//! pseudo-code, which `reshape(-1, g)`s the whole matrix).
//!
//! * [`registry`] — the unified method surface: the [`Quantizer`] trait
//!   (plan/execute split via [`StatsRequirement`]), the [`MethodSpec`]
//!   selector, and the [`MethodRegistry`] building methods from spec
//!   strings (`"ttq:r=16"`, `"nf:4"`, ...). Every layer above dispatches
//!   through this.
//! * [`rtn`] — groupwise round-to-nearest QDQ (Eq. 1).
//! * [`awq`] — activation-aware diagonal scaling (Eq. 19-20).
//! * [`ttq`] — the contribution: online per-prompt quantization (§2),
//!   with optional low-rank residual decomposition (App. E).
//! * [`gptq`] — greedy OBS baseline with Cholesky (App. C).
//! * [`nf`] — NormalFloat codebook QDQ (App. D, NF4-style).
//! * [`prune`] — test-time activation-aware pruning (§3, μ-MoE).
//! * [`lowrank`] — truncated-SVD factors + alternating refinement.
//! * [`formats`] — QDQ format variants (App. D): asymmetric/symmetric,
//!   range expansion ν, the G/G′ representations.
//! * [`pack`] — integer bit-packing + the memory-traffic accounting that
//!   feeds the GPU roofline model (Tables 4-8).
//! * [`online_pca`] — Oja streaming subspace tracker (future
//!   [`StatsRequirement::StreamingActivations`] methods).

#![forbid(unsafe_code)]

pub mod awq;
pub mod formats;
pub mod gptq;
pub mod lowrank;
pub mod nf;
pub mod online_pca;
pub mod pack;
pub mod prune;
pub mod registry;
pub mod rtn;
pub mod ttq;

pub use awq::{awq_quantize, diag_from_norm_sums, diag_from_x, ActStats};
pub use formats::{QdqFormat, QuantSpec};
pub use gptq::gptq_quantize;
pub use lowrank::{alternating_refine, lowrank_init, LowRank};
pub use nf::{nf_codebook, nf_quantize, norm_ppf};
pub use online_pca::OjaTracker;
pub use pack::{fp16_bytes, pack, packed_matmul, unpack, unpack_at, weight_bytes, Packed};
pub use prune::{measured_sparsity, prune, prune_then_quantize, Sparsity};
pub use registry::{
    AwqQuantizer, FpQuantizer, GptqQuantizer, LayerStats, MethodEntry, MethodRegistry,
    MethodSpec, NfQuantizer, PruneQuantizer, Quantizer, RtnQuantizer, StatsRequirement,
    TtqQuantizer,
};
pub use rtn::{rtn_dequantize, rtn_quantize, rtn_quantize_int, QuantizedInt};
pub use ttq::{
    overhead_ratio, ttq_quantize, ttq_quantize_from_stats, ttq_quantize_lowrank,
    ttq_quantize_lowrank_from_stats, TtqHyper, TtqQuantized,
};

/// `2^bits − 1` as f32 — the qmax convention shared with the L1 kernels.
/// Single source of truth; [`QuantSpec::qmax`] delegates here.
#[inline]
pub fn qmax(bits: u32) -> f32 {
    ((1u64 << bits) - 1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(2), 3.0);
        assert_eq!(qmax(3), 7.0);
        assert_eq!(qmax(4), 15.0);
        assert_eq!(qmax(5), 31.0);
        assert_eq!(qmax(8), 255.0);
    }

    #[test]
    fn quantspec_qmax_delegates() {
        for bits in [2u32, 3, 4, 5, 8] {
            assert_eq!(QuantSpec::new(bits, 32).qmax(), qmax(bits));
        }
    }
}
