//! The paper's quantization algorithms, pure Rust.
//!
//! Everything here operates on [`crate::linalg::Mat`] weights shaped
//! paper-style `(d_out, d_in)` with *flat* grouping over the flattened
//! weight (a group may span row boundaries — exactly the paper's App. B
//! pseudo-code, which `reshape(-1, g)`s the whole matrix).
//!
//! * [`rtn`] — groupwise round-to-nearest QDQ (Eq. 1).
//! * [`awq`] — activation-aware diagonal scaling (Eq. 19-20).
//! * [`ttq`] — the contribution: online per-prompt quantization (§2),
//!   with optional low-rank residual decomposition (App. E).
//! * [`gptq`] — greedy OBS baseline with Cholesky (App. C).
//! * [`lowrank`] — truncated-SVD factors + alternating refinement.
//! * [`formats`] — QDQ format variants (App. D): asymmetric/symmetric,
//!   range expansion ν, the G/G′ representations.
//! * [`pack`] — integer bit-packing + the memory-traffic accounting that
//!   feeds the GPU roofline model (Tables 4-8).

pub mod awq;
pub mod formats;
pub mod gptq;
pub mod lowrank;
pub mod nf;
pub mod online_pca;
pub mod prune;
pub mod pack;
pub mod rtn;
pub mod ttq;

pub use awq::{awq_quantize, diag_from_norm_sums, diag_from_x, ActStats};
pub use formats::{QdqFormat, QuantSpec};
pub use gptq::gptq_quantize;
pub use lowrank::{alternating_refine, lowrank_init, LowRank};
pub use nf::{nf_codebook, nf_quantize, norm_ppf};
pub use online_pca::OjaTracker;
pub use prune::{measured_sparsity, prune, prune_then_quantize, Sparsity};
pub use pack::{fp16_bytes, pack, packed_matmul, unpack, unpack_at, weight_bytes, Packed};
pub use rtn::{rtn_dequantize, rtn_quantize, rtn_quantize_int, QuantizedInt};
pub use ttq::{
    overhead_ratio, ttq_quantize, ttq_quantize_from_stats, ttq_quantize_lowrank,
    ttq_quantize_lowrank_from_stats, TtqHyper, TtqQuantized,
};

/// Which quantization method to apply — the rows of the paper's tables.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// Plain round-to-nearest (Eq. 1) — the weakest baseline.
    Rtn,
    /// Offline activation-aware (Fig. 1a) with a *fixed* calibration
    /// diagonal; susceptible to domain shift.
    Awq,
    /// Online test-time quantization (Fig. 1b) with rank-r low-rank
    /// compensation (r = 0 disables it).
    Ttq { rank: usize },
    /// Greedy OBS baseline (needs the full correlation; O(d³)).
    Gptq,
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Rtn => "RTN".into(),
            Method::Awq => "AWQ".into(),
            Method::Ttq { rank } => format!("TTQ (r = {rank})"),
            Method::Gptq => "GPTQ".into(),
        }
    }
}

/// `2^bits − 1` as f32 — the qmax convention shared with the L1 kernels.
#[inline]
pub fn qmax(bits: u32) -> f32 {
    ((1u64 << bits) - 1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(2), 3.0);
        assert_eq!(qmax(3), 7.0);
        assert_eq!(qmax(4), 15.0);
        assert_eq!(qmax(5), 31.0);
        assert_eq!(qmax(8), 255.0);
    }

    #[test]
    fn method_labels_match_paper_rows() {
        assert_eq!(Method::Rtn.label(), "RTN");
        assert_eq!(Method::Ttq { rank: 16 }.label(), "TTQ (r = 16)");
    }
}
