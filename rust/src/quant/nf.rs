//! Non-uniform (NormalFloat) quantization — the NF4-style format the
//! paper's App. D points to (Dettmers et al. 2023).
//!
//! Levels are placed at the quantiles of a standard normal so that,
//! for Gaussian-ish weight groups, every code is used equally often.
//! The group is scaled by its absmax, mapped through the codebook by
//! nearest-level search, and dequantized as `code_value * absmax`.

use crate::linalg::Mat;

/// Inverse standard-normal CDF (Acklam's rational approximation —
/// |ε| < 1.15e-9, far below f32 resolution).
pub fn norm_ppf(p: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&p));
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -norm_ppf(1.0 - p)
    }
}

/// NFq codebook: 2^bits levels at normal quantiles, normalized to
/// [-1, 1], symmetric-ish with an exact zero (as NF4 does).
pub fn nf_codebook(bits: u32) -> Vec<f32> {
    let n = 1usize << bits;
    // Quantile positions i/(n-1) mapped through Φ⁻¹ with clamped tails.
    let lo = 1.0 / (2.0 * n as f64);
    let mut levels: Vec<f64> = (0..n)
        .map(|i| {
            let p = lo + (1.0 - 2.0 * lo) * i as f64 / (n - 1) as f64;
            norm_ppf(p)
        })
        .collect();
    let max = levels.iter().cloned().fold(0.0f64, |m, v| m.max(v.abs()));
    for v in levels.iter_mut() {
        *v /= max;
    }
    // force an exact zero at the nearest-to-zero level (NF4 trick)
    let zi = levels
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    levels[zi] = 0.0;
    levels.into_iter().map(|v| v as f32).collect()
}

/// Groupwise NF QDQ of a flat slice (absmax scaling per group).
pub fn nf_quantize_inplace(data: &mut [f32], bits: u32, group: usize) {
    assert_eq!(data.len() % group, 0);
    let cb = nf_codebook(bits);
    for grp in data.chunks_mut(group) {
        let mut absmax = 0.0f32;
        for v in grp.iter() {
            absmax = absmax.max(v.abs());
        }
        if absmax == 0.0 {
            continue;
        }
        let inv = 1.0 / absmax;
        for v in grp.iter_mut() {
            let t = *v * inv;
            // nearest level (codebook is sorted ascending)
            let mut best = 0usize;
            let mut bd = f32::MAX;
            for (i, &c) in cb.iter().enumerate() {
                let d = (t - c).abs();
                if d < bd {
                    bd = d;
                    best = i;
                }
            }
            *v = cb[best] * absmax;
        }
    }
}

/// Matrix wrapper mirroring [`super::rtn::rtn_quantize`].
pub fn nf_quantize(w: &Mat, bits: u32, group: usize) -> Mat {
    let mut out = w.clone();
    nf_quantize_inplace(&mut out.data, bits, group);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::{rtn_quantize, QuantSpec};

    #[test]
    fn ppf_matches_known_quantiles() {
        assert!((norm_ppf(0.5)).abs() < 1e-9);
        assert!((norm_ppf(0.975) - 1.959964).abs() < 1e-4);
        assert!((norm_ppf(0.025) + 1.959964).abs() < 1e-4);
        assert!((norm_ppf(0.8413) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn codebook_properties() {
        for bits in [2u32, 3, 4] {
            let cb = nf_codebook(bits);
            assert_eq!(cb.len(), 1 << bits);
            // sorted ascending, spans [-1, 1], contains exact zero
            for w in cb.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!((cb[0] + 1.0).abs() < 1e-6);
            assert!((cb[cb.len() - 1] - 1.0).abs() < 1e-6);
            assert!(cb.iter().any(|&v| v == 0.0));
        }
    }

    #[test]
    fn nf4_beats_symmetric_uniform_on_gaussian_weights() {
        // The reason NF4 exists: for normal weights it wastes no codes.
        // Fair baseline = symmetric uniform (same 1 param per group).
        let mut rng = Rng::new(1);
        let w = Mat::randn(32, 64, &mut rng);
        let e_nf = w.sub(&nf_quantize(&w, 4, 64)).frob_sq();
        let mut spec = QuantSpec::new(4, 64);
        spec.format = crate::quant::QdqFormat::Symmetric;
        let e_sym = w.sub(&rtn_quantize(&w, &spec)).frob_sq();
        assert!(e_nf < e_sym, "nf4 {e_nf} vs symmetric uniform {e_sym}");
    }

    #[test]
    fn exact_zero_preserved() {
        let mut data = vec![0.0f32; 16];
        data[3] = 1.0; // absmax anchor
        nf_quantize_inplace(&mut data, 4, 16);
        assert_eq!(data[0], 0.0);
        assert_eq!(data[5], 0.0);
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(8, 32, &mut rng);
        let w1 = nf_quantize(&w, 4, 32);
        let w2 = nf_quantize(&w1, 4, 32);
        for (a, b) in w1.data.iter().zip(&w2.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn constant_zero_group_untouched() {
        let mut data = vec![0.0f32; 32];
        nf_quantize_inplace(&mut data, 4, 32);
        assert!(data.iter().all(|&v| v == 0.0));
    }
}
