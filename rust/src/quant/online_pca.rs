//! Online PCA for test-time decomposition — paper App. E's "Test-Time
//! Decomposition" option: dynamically adapt the low-rank factors B, A
//! from streaming activations instead of keeping them static.
//!
//! Implements Oja's rule (Oja 1982) with Gram–Schmidt re-orthogonal-
//! ization — the first of the four algorithm families App. E lists
//! (stochastic gradient / incremental SVD / subspace tracking / online
//! optimization). The tracker maintains an orthonormal basis U (d×r)
//! of the top-r subspace of the streaming covariance; the coordinator
//! can refresh a layer's `LowRank` factors from it between prompts.

use crate::linalg::Mat;

/// Streaming top-r subspace tracker (Oja + deflation via GS).
pub struct OjaTracker {
    /// Current orthonormal basis estimate, (d, r).
    pub basis: Mat,
    lr: f32,
    steps: u64,
}

impl OjaTracker {
    /// Initialize with an arbitrary (e.g. random or SVD-warmstart) basis.
    pub fn new(init: Mat, lr: f32) -> Self {
        let mut t = OjaTracker { basis: init, lr, steps: 0 };
        t.orthonormalize();
        t
    }

    /// Tracked subspace rank r.
    pub fn rank(&self) -> usize {
        self.basis.cols
    }

    /// Ambient dimension d.
    pub fn dim(&self) -> usize {
        self.basis.rows
    }

    /// One Oja update per sample column x (length d):
    /// U ← orth(U + η · x (xᵀU)).
    pub fn update(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.dim());
        let (d, r) = (self.dim(), self.rank());
        // y = xᵀ U  (r,)
        let mut y = vec![0.0f32; r];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = self.basis.row(i);
            for (j, yj) in y.iter_mut().enumerate() {
                *yj += xi * row[j];
            }
        }
        // decayed step size keeps the estimate stable as it converges
        self.steps += 1;
        let eta = self.lr / (1.0 + 0.01 * self.steps as f32);
        for i in 0..d {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.basis.row_mut(i);
            for (j, &yj) in y.iter().enumerate() {
                row[j] += eta * xi * yj;
            }
        }
        self.orthonormalize();
    }

    /// Batch of samples as columns of X (d, T).
    pub fn update_batch(&mut self, x: &Mat) {
        assert_eq!(x.rows, self.dim());
        let mut col = vec![0.0f32; x.rows];
        for t in 0..x.cols {
            for (i, c) in col.iter_mut().enumerate() {
                *c = x.at(i, t);
            }
            self.update(&col);
        }
    }

    /// Energy of a sample captured by the current subspace:
    /// ‖Uᵀx‖² / ‖x‖² ∈ [0, 1].
    pub fn captured_energy(&self, x: &[f32]) -> f64 {
        let r = self.rank();
        let mut proj = vec![0.0f64; r];
        let mut total = 0.0f64;
        for (i, &xi) in x.iter().enumerate() {
            total += (xi as f64).powi(2);
            let row = self.basis.row(i);
            for (j, p) in proj.iter_mut().enumerate() {
                *p += xi as f64 * row[j] as f64;
            }
        }
        if total == 0.0 {
            return 0.0;
        }
        proj.iter().map(|p| p * p).sum::<f64>() / total
    }

    fn orthonormalize(&mut self) {
        let (d, r) = (self.dim(), self.rank());
        for j in 0..r {
            for k in 0..j {
                let mut dot = 0.0f64;
                for i in 0..d {
                    dot += self.basis.at(i, k) as f64 * self.basis.at(i, j) as f64;
                }
                for i in 0..d {
                    *self.basis.at_mut(i, j) -= dot as f32 * self.basis.at(i, k);
                }
            }
            let mut nrm = 0.0f64;
            for i in 0..d {
                nrm += (self.basis.at(i, j) as f64).powi(2);
            }
            let nrm = nrm.sqrt().max(1e-12) as f32;
            for i in 0..d {
                *self.basis.at_mut(i, j) /= nrm;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    /// Samples concentrated in a known low-dim subspace + noise.
    fn sample(planted: &Mat, rng: &mut Rng, noise: f32) -> Vec<f32> {
        let (d, k) = (planted.rows, planted.cols);
        let coeffs: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 3.0).collect();
        (0..d)
            .map(|i| {
                let mut v = rng.normal() as f32 * noise;
                for (j, &c) in coeffs.iter().enumerate() {
                    v += planted.at(i, j) * c;
                }
                v
            })
            .collect()
    }

    #[test]
    fn recovers_planted_subspace() {
        let mut rng = Rng::new(1);
        let d = 32;
        let mut planted = Mat::randn(d, 2, &mut rng);
        // normalize planted columns
        for j in 0..2 {
            let n: f32 = (0..d).map(|i| planted.at(i, j).powi(2)).sum::<f32>().sqrt();
            for i in 0..d {
                *planted.at_mut(i, j) /= n;
            }
        }
        let mut tracker = OjaTracker::new(Mat::randn(d, 2, &mut rng), 0.05);
        for _ in 0..600 {
            let x = sample(&planted, &mut rng, 0.05);
            tracker.update(&x);
        }
        // fresh samples should be ~fully captured
        let mut acc = 0.0;
        for _ in 0..50 {
            let x = sample(&planted, &mut rng, 0.0);
            acc += tracker.captured_energy(&x);
        }
        let mean = acc / 50.0;
        assert!(mean > 0.95, "captured energy {mean}");
    }

    #[test]
    fn basis_stays_orthonormal() {
        let mut rng = Rng::new(2);
        let mut t = OjaTracker::new(Mat::randn(16, 3, &mut rng), 0.1);
        for _ in 0..100 {
            let x: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            t.update(&x);
        }
        for i in 0..3 {
            for j in 0..3 {
                let mut dot = 0.0f64;
                for k in 0..16 {
                    dot += t.basis.at(k, i) as f64 * t.basis.at(k, j) as f64;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "U[{i}]·U[{j}]={dot}");
            }
        }
    }

    #[test]
    fn adapts_after_subspace_shift() {
        let mut rng = Rng::new(3);
        let d = 24;
        let norm_cols = |m: &mut Mat| {
            for j in 0..m.cols {
                let n: f32 =
                    (0..d).map(|i| m.at(i, j).powi(2)).sum::<f32>().sqrt();
                for i in 0..d {
                    *m.at_mut(i, j) /= n;
                }
            }
        };
        let mut p1 = Mat::randn(d, 2, &mut rng);
        let mut p2 = Mat::randn(d, 2, &mut rng);
        norm_cols(&mut p1);
        norm_cols(&mut p2);
        let mut t = OjaTracker::new(Mat::randn(d, 2, &mut rng), 0.08);
        for _ in 0..500 {
            let x = sample(&p1, &mut rng, 0.05);
            t.update(&x);
        }
        let e_before: f64 = (0..20)
            .map(|_| t.captured_energy(&sample(&p2, &mut rng, 0.0)))
            .sum::<f64>()
            / 20.0;
        for _ in 0..1500 {
            let x = sample(&p2, &mut rng, 0.05);
            t.update(&x);
        }
        let e_after: f64 = (0..20)
            .map(|_| t.captured_energy(&sample(&p2, &mut rng, 0.0)))
            .sum::<f64>()
            / 20.0;
        assert!(
            e_after > e_before + 0.1 && e_after > 0.8,
            "no adaptation: {e_before} -> {e_after}"
        );
    }

    #[test]
    fn captured_energy_bounds() {
        let mut rng = Rng::new(4);
        let t = OjaTracker::new(Mat::randn(8, 2, &mut rng), 0.1);
        let x: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        let e = t.captured_energy(&x);
        assert!((0.0..=1.0 + 1e-6).contains(&e));
        assert_eq!(t.captured_energy(&vec![0.0; 8]), 0.0);
    }
}
