//! Integer weight packing + memory-traffic accounting.
//!
//! The paper's speedup mechanism (App. B/H): quantization pays off on
//! GPUs because the *weight traffic* HBM→SMEM shrinks from 16 bits to q
//! bits per element ("Qwen3-32B needs 168MB ... for FP16 query
//! projection"). We pack codes into u32 words exactly like deployed
//! int_matmul kernels, and expose the byte accounting the roofline
//! model (Tables 4-8) consumes. A fused dequant-matmul over the packed
//! format doubles as the CPU stand-in for `marlin_gemm`.

use super::rtn::QuantizedInt;
use crate::linalg::Mat;

/// Bit-packed quantized tensor (row-major element order).
#[derive(Clone, Debug)]
pub struct Packed {
    /// Dense little-endian code words.
    pub words: Vec<u32>,
    /// Code width in bits.
    pub bits: u32,
    /// Element count (codes packed).
    pub n: usize,
    /// Per-group scale S.
    pub scales: Vec<f32>,
    /// Per-group zero Z.
    pub zeros: Vec<f32>,
    /// Elements per scale/zero group.
    pub group: usize,
    /// Weight rows (d_out).
    pub rows: usize,
    /// Weight columns (d_in).
    pub cols: usize,
}

/// Pack ≤8-bit codes, little-endian within each u32 word. Codes may
/// straddle word boundaries (dense packing — 3-bit really is 3 bits).
pub fn pack(q: &QuantizedInt) -> Packed {
    let bits = q.spec.bits;
    let n = q.codes.len();
    let total_bits = n * bits as usize;
    let mut words = vec![0u32; total_bits.div_ceil(32)];
    for (i, &code) in q.codes.iter().enumerate() {
        let bit = i * bits as usize;
        let wi = bit / 32;
        let off = bit % 32;
        words[wi] |= (code as u32) << off;
        if off + bits as usize > 32 {
            words[wi + 1] |= (code as u32) >> (32 - off);
        }
    }
    Packed {
        words,
        bits,
        n,
        scales: q.scales.clone(),
        zeros: q.zeros.clone(),
        group: q.spec.group,
        rows: q.rows,
        cols: q.cols,
    }
}

/// Unpack one element.
#[inline]
pub fn unpack_at(p: &Packed, i: usize) -> u8 {
    let bits = p.bits as usize;
    let bit = i * bits;
    let wi = bit / 32;
    let off = bit % 32;
    let mask = (1u32 << bits) - 1;
    let mut v = p.words[wi] >> off;
    if off + bits > 32 {
        v |= p.words[wi + 1] << (32 - off);
    }
    (v & mask) as u8
}

/// Unpack the whole tensor back to codes (test helper).
pub fn unpack(p: &Packed) -> Vec<u8> {
    (0..p.n).map(|i| unpack_at(p, i)).collect()
}

/// Total bytes moved to read this weight: packed codes + f16 params.
/// This is the traffic term of the roofline model.
pub fn weight_bytes(p: &Packed) -> usize {
    p.words.len() * 4 + (p.scales.len() + p.zeros.len()) * 2
}

/// FP16 baseline bytes for the same tensor.
pub fn fp16_bytes(rows: usize, cols: usize) -> usize {
    rows * cols * 2
}

/// Fused dequantize-and-matmul over the packed weight: `Y = Ŵ X` with
/// X (d_in, T). The CPU analogue of the paper's `marlin_gemm` prologue
/// fusion — dequant happens in registers per group, never materializing
/// the f32 weight. Used by the e2e decode bench.
///
/// Perf notes (EXPERIMENTS.md §Perf): when groups align with rows
/// (d_in % g == 0, the deployed layout) the group scale/zero and the
/// `i/g` division are hoisted out of the element loop, and the decode
/// case T = 1 accumulates into a register instead of a row slice.
pub fn packed_matmul(p: &Packed, x: &Mat) -> Mat {
    assert_eq!(p.cols, x.rows, "dim mismatch");
    let (d_out, d_in, t) = (p.rows, p.cols, x.cols);
    let g = p.group;
    let mut y = Mat::zeros(d_out, t);
    let bits = p.bits as usize;
    let mask = (1u32 << bits) - 1;

    #[inline(always)]
    fn code_at(words: &[u32], bits: usize, mask: u32, i: usize) -> u32 {
        let bit = i * bits;
        let wi = bit / 32;
        let off = bit % 32;
        let mut v = words[wi] >> off;
        if off + bits > 32 {
            v |= words[wi + 1] << (32 - off);
        }
        v & mask
    }

    if d_in % g == 0 {
        let groups_per_row = d_in / g;
        for r in 0..d_out {
            if t == 1 {
                // decode fast path: scalar accumulator, group-hoisted params
                let mut acc = 0.0f32;
                for bg in 0..groups_per_row {
                    let gi = r * groups_per_row + bg;
                    let (s, z) = (p.scales[gi], p.zeros[gi]);
                    let base = gi * g;
                    for j in 0..g {
                        let w = code_at(&p.words, bits, mask, base + j) as f32
                            * s + z;
                        acc += w * x.data[bg * g + j];
                    }
                }
                y.data[r] = acc;
            } else {
                let yrow = &mut y.data[r * t..(r + 1) * t];
                for bg in 0..groups_per_row {
                    let gi = r * groups_per_row + bg;
                    let (s, z) = (p.scales[gi], p.zeros[gi]);
                    let base = gi * g;
                    for j in 0..g {
                        let w = code_at(&p.words, bits, mask, base + j) as f32
                            * s + z;
                        if w == 0.0 {
                            continue;
                        }
                        let c = bg * g + j;
                        let xrow = &x.data[c * t..(c + 1) * t];
                        for (yv, xv) in yrow.iter_mut().zip(xrow) {
                            *yv += w * xv;
                        }
                    }
                }
            }
        }
        return y;
    }

    // general flat-grouped fallback (groups may span rows)
    for r in 0..d_out {
        let yrow = &mut y.data[r * t..(r + 1) * t];
        for c in 0..d_in {
            let i = r * d_in + c;
            let gi = i / g;
            let w =
                code_at(&p.words, bits, mask, i) as f32 * p.scales[gi] + p.zeros[gi];
            if w == 0.0 {
                continue;
            }
            let xrow = &x.data[c * t..(c + 1) * t];
            for (yv, xv) in yrow.iter_mut().zip(xrow) {
                *yv += w * xv;
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::formats::QuantSpec;
    use crate::quant::rtn::{rtn_dequantize, rtn_quantize_int};

    #[test]
    fn pack_unpack_roundtrip_all_bits() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(8, 64, &mut rng);
        for bits in [2u32, 3, 4, 5, 8] {
            let qi = rtn_quantize_int(&w, &QuantSpec::new(bits, 32));
            let p = pack(&qi);
            assert_eq!(unpack(&p), qi.codes, "bits={bits}");
        }
    }

    #[test]
    fn packed_size_is_dense() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(16, 64, &mut rng); // 1024 elements
        let qi = rtn_quantize_int(&w, &QuantSpec::new(3, 32));
        let p = pack(&qi);
        // 1024 * 3 bits = 3072 bits = 96 words
        assert_eq!(p.words.len(), 96);
    }

    #[test]
    fn traffic_ratio_matches_bits() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(64, 128, &mut rng);
        let q4 = pack(&rtn_quantize_int(&w, &QuantSpec::new(4, 32)));
        let q2 = pack(&rtn_quantize_int(&w, &QuantSpec::new(2, 32)));
        let fp = fp16_bytes(64, 128) as f64;
        let r4 = weight_bytes(&q4) as f64 / fp;
        let r2 = weight_bytes(&q2) as f64 / fp;
        // 4-bit ≈ 1/4 of fp16 + param overhead; 2-bit ≈ 1/8 + overhead
        assert!(r4 < 0.35 && r4 > 0.24, "r4 = {r4}");
        assert!(r2 < 0.22 && r2 > 0.12, "r2 = {r2}");
        // paper App. H: 2-bit "theoretically doubling" over 4-bit
        assert!(r2 < r4);
    }

    #[test]
    fn packed_matmul_matches_dequant_matmul() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(24, 32, &mut rng);
        let x = Mat::randn(32, 7, &mut rng);
        for bits in [2u32, 3, 4, 5] {
            let qi = rtn_quantize_int(&w, &QuantSpec::new(bits, 16));
            let p = pack(&qi);
            let got = packed_matmul(&p, &x);
            let want = rtn_dequantize(&qi).matmul(&x);
            for (a, b) in got.data.iter().zip(&want.data) {
                assert!((a - b).abs() < 1e-3, "bits={bits}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn word_straddling_codes_survive() {
        // 3-bit codes cross u32 boundaries at element 10 (bits 30..33):
        // craft codes that exercise the straddle path.
        let qi = QuantizedInt {
            codes: (0..64u8).map(|i| i % 8).collect(),
            scales: vec![1.0; 2],
            zeros: vec![0.0; 2],
            rows: 2,
            cols: 32,
            spec: QuantSpec::new(3, 32),
        };
        let p = pack(&qi);
        assert_eq!(unpack(&p), qi.codes);
    }
}
