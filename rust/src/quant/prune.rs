//! Test-time activation-aware pruning — the μ-MoE companion technique
//! the paper builds on (Koike-Akino et al. 2025b) and plans to
//! integrate ("we plan to integrate test-time pruning and
//! decomposition into TTQ", §3).
//!
//! Importance score is Wanda-style `|W_ij| · D_j` using the *same*
//! diagonal D that TTQ already computes from the live activations —
//! the paper's App. E observation that "both use similar diagonal
//! correlation matrix, we do not need extra computation for D".
//! Supports unstructured and N:M semi-structured sparsity, and the
//! combined prune-then-quantize test-time pipeline.

use super::awq::awq_quantize;
use super::formats::QuantSpec;
use crate::linalg::Mat;

/// Sparsity pattern.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sparsity {
    /// Keep the top-(1−ratio) fraction of entries per row.
    Unstructured { ratio: f64 },
    /// N of every M consecutive entries are kept (hardware friendly).
    NofM { n: usize, m: usize },
}

/// Activation-aware prune: zero the lowest-importance weights.
/// `dvec` is the activation diagonal (length d_in).
pub fn prune(w: &Mat, dvec: &[f32], sparsity: Sparsity) -> Mat {
    assert_eq!(dvec.len(), w.cols);
    let mut out = w.clone();
    match sparsity {
        Sparsity::Unstructured { ratio } => {
            let keep = ((1.0 - ratio) * w.cols as f64).round() as usize;
            let mut idx: Vec<usize> = (0..w.cols).collect();
            for r in 0..w.rows {
                let row = &w.data[r * w.cols..(r + 1) * w.cols];
                idx.sort_unstable_by(|&a, &b| {
                    let sa = row[a].abs() * dvec[a];
                    let sb = row[b].abs() * dvec[b];
                    sb.partial_cmp(&sa).unwrap()
                });
                let orow = &mut out.data[r * w.cols..(r + 1) * w.cols];
                for &i in &idx[keep..] {
                    orow[i] = 0.0;
                }
            }
        }
        Sparsity::NofM { n, m } => {
            assert!(n <= m && m > 0 && w.cols % m == 0);
            let mut order: Vec<usize> = (0..m).collect();
            for r in 0..w.rows {
                for blk in 0..w.cols / m {
                    let base = r * w.cols + blk * m;
                    order.sort_unstable_by(|&a, &b| {
                        let sa = out.data[base + a].abs() * dvec[blk * m + a];
                        let sb = out.data[base + b].abs() * dvec[blk * m + b];
                        sb.partial_cmp(&sa).unwrap()
                    });
                    for &i in &order[n..] {
                        out.data[base + i] = 0.0;
                    }
                }
            }
        }
    }
    out
}

/// Combined test-time prune + quantize: prune on D, then scaled QDQ of
/// the surviving weights with the same D (one stats pass for both).
pub fn prune_then_quantize(
    w: &Mat,
    dvec: &[f32],
    sparsity: Sparsity,
    spec: &QuantSpec,
) -> Mat {
    let pruned = prune(w, dvec, sparsity);
    awq_quantize(&pruned, dvec, spec)
}

/// Fraction of zero entries.
pub fn measured_sparsity(w: &Mat) -> f64 {
    w.data.iter().filter(|v| **v == 0.0).count() as f64 / w.data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{activation_loss, Rng};
    use crate::quant::diag_from_x;

    fn outlier_x(d: usize, t: usize, rng: &mut Rng) -> Mat {
        let scales: Vec<f32> =
            (0..d).map(|_| rng.lognormal(0.0, 1.5) as f32).collect();
        let mut x = Mat::randn(d, t, rng);
        for i in 0..d {
            for v in x.row_mut(i) {
                *v *= scales[i];
            }
        }
        x
    }

    #[test]
    fn unstructured_hits_target_ratio() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(16, 64, &mut rng);
        let d = vec![1.0f32; 64];
        for ratio in [0.25, 0.5, 0.75] {
            let p = prune(&w, &d, Sparsity::Unstructured { ratio });
            assert!((measured_sparsity(&p) - ratio).abs() < 0.02, "{ratio}");
        }
    }

    #[test]
    fn nofm_pattern_exact() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(8, 64, &mut rng);
        let d = vec![1.0f32; 64];
        let p = prune(&w, &d, Sparsity::NofM { n: 2, m: 4 });
        assert!((measured_sparsity(&p) - 0.5).abs() < 1e-9);
        // every 4-block has exactly 2 zeros
        for r in 0..8 {
            for blk in 0..16 {
                let z = (0..4)
                    .filter(|&i| p.at(r, blk * 4 + i) == 0.0)
                    .count();
                assert_eq!(z, 2, "row {r} block {blk}");
            }
        }
    }

    #[test]
    fn activation_aware_beats_magnitude_only() {
        // On outlier activations, |W|·D pruning must lose less output
        // energy than plain |W| pruning — the Wanda/μ-MoE result.
        let mut rng = Rng::new(3);
        let w = Mat::randn(32, 64, &mut rng);
        let x = outlier_x(64, 128, &mut rng);
        let d_aware = diag_from_x(&x, 2.0, 0.0, 1.0);
        let d_blind = vec![1.0f32; 64];
        let s = Sparsity::Unstructured { ratio: 0.5 };
        let e_aware = activation_loss(&w, &prune(&w, &d_aware, s), &x);
        let e_blind = activation_loss(&w, &prune(&w, &d_blind, s), &x);
        assert!(e_aware < e_blind, "aware {e_aware} vs blind {e_blind}");
    }

    #[test]
    fn keeps_largest_importance_entries() {
        let w = Mat::from_vec(1, 4, vec![0.1, -5.0, 0.2, 3.0]);
        let d = vec![1.0f32; 4];
        let p = prune(&w, &d, Sparsity::Unstructured { ratio: 0.5 });
        assert_eq!(p.data, vec![0.0, -5.0, 0.0, 3.0]);
        // now flip importance through D
        let d2 = vec![100.0f32, 0.01, 100.0, 0.01];
        let p2 = prune(&w, &d2, Sparsity::Unstructured { ratio: 0.5 });
        assert_eq!(p2.data, vec![0.1, 0.0, 0.2, 0.0]);
    }

    #[test]
    fn prune_then_quantize_stays_sparse() {
        // QDQ must not resurrect pruned zeros (zero is representable:
        // asymmetric groups containing 0 keep it within half a step).
        let mut rng = Rng::new(4);
        let w = Mat::randn(8, 64, &mut rng);
        let x = Mat::randn(64, 16, &mut rng);
        let d = diag_from_x(&x, 2.0, 0.4, 0.5);
        let s = Sparsity::NofM { n: 2, m: 4 };
        let pq = prune_then_quantize(&w, &d, s, &QuantSpec::new(4, 32));
        // QDQ reproduces zero to within half a quantization step; for
        // N(0,1) groups at 4 bits that is ≈ range/(2·15) ≈ 0.15-0.2.
        let near_zero = pq.data.iter().filter(|v| v.abs() < 0.2).count();
        assert!(
            near_zero as f64 / pq.data.len() as f64 > 0.45,
            "only {near_zero}/{} near-zero",
            pq.data.len()
        );
    }
}
