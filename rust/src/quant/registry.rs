//! The unified method surface: one [`Quantizer`] trait plus a
//! [`MethodRegistry`] that builds methods from spec strings.
//!
//! Every compression method in the paper's tables — RTN (Eq. 1), AWQ
//! (Eq. 19-20), TTQ (§2), GPTQ (App. C), NormalFloat (App. D) and
//! test-time pruning (§3 / μ-MoE) — implements the same two-step
//! contract:
//!
//! 1. **plan**: [`Quantizer::requirement`] declares which activation
//!    statistics the method consumes, so callers collect exactly what is
//!    needed (nothing for RTN/NF, diagonal norm sums for AWQ/TTQ/prune,
//!    the full correlation for GPTQ) instead of hand-threading
//!    `Option<&CollectedStats>` through every layer;
//! 2. **execute**: [`Quantizer::quantize`] maps one weight matrix plus a
//!    [`LayerStats`] view of those statistics to the compressed weight.
//!
//! [`MethodSpec`] wraps a registry handle together with the optional
//! offline calibration domain — the one method selector shared by the
//! eval pipelines, the bench tables, the serving coordinator, the
//! roofline perf model and the CLI. Spec strings look like `"rtn"`,
//! `"awq:calib=wt2s"`, `"ttq:r=16"`, `"gptq:damp=0.01"`, `"nf:4"` and
//! `"prune:0.5"`; [`MethodSpec::spec_string`] round-trips through
//! [`MethodRegistry::parse`].

use std::fmt;
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, bail, Result};

use super::awq::{awq_quantize, diag_from_norm_sums, ActStats};
use super::formats::QuantSpec;
use super::gptq::gptq_quantize;
use super::lowrank::{lowrank_init, LowRank};
use super::nf::nf_quantize;
use super::prune::{prune, prune_then_quantize, Sparsity};
use super::rtn::rtn_quantize;
use super::ttq::TtqHyper;
use crate::linalg::Mat;

/// Which activation statistics a method consumes — the *plan* half of
/// the plan/execute split. Callers query this instead of matching on
/// concrete method types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatsRequirement {
    /// Weight-only (RTN, NF, FP): no activation pass at all.
    None,
    /// Per-channel norm sums Σ|x_i|^p from the `stats` artifact
    /// (AWQ, TTQ, test-time pruning).
    DiagonalNorms,
    /// The full input correlation C = XXᵀ from the `corr` artifact
    /// (GPTQ's inverse-Hessian; O(d²) memory, O(d³) solve).
    FullCorrelation,
    /// Raw activation vectors streamed sample-by-sample (reserved for
    /// [`super::online_pca::OjaTracker`]-style subspace methods).
    StreamingActivations,
}

/// Borrowed per-layer statistics handed to [`Quantizer::quantize`].
///
/// Only the fields named by the method's [`StatsRequirement`] must be
/// populated; `diag` short-circuits the norm-sum reduction when the
/// caller (the serving coordinator) already owns a committed diagonal,
/// and `lowrank` supplies cached static factors so rank-r methods do
/// not recompute the SVD per prompt (App. E).
#[derive(Clone, Copy, Default)]
pub struct LayerStats<'a> {
    /// Accumulated norm sums for the layer input.
    pub act: Option<&'a ActStats>,
    /// Full input correlation C = XXᵀ.
    pub corr: Option<&'a Mat>,
    /// Precomputed activation diagonal D (overrides `act`).
    pub diag: Option<&'a [f32]>,
    /// Cached static low-rank factors for this layer.
    pub lowrank: Option<&'a LowRank>,
}

impl<'a> LayerStats<'a> {
    /// Stats carrying only accumulated norm sums.
    pub fn from_act(act: &'a ActStats) -> Self {
        LayerStats { act: Some(act), ..Default::default() }
    }

    /// Stats carrying a precomputed committed diagonal (serving path).
    pub fn from_diag(diag: &'a [f32]) -> Self {
        LayerStats { diag: Some(diag), ..Default::default() }
    }

    /// The activation diagonal D: the precomputed one if present, else
    /// derived from the norm sums with the method's hyperparameters.
    fn diagonal(&self, hp: &TtqHyper, who: &str) -> Result<Vec<f32>> {
        if let Some(d) = self.diag {
            return Ok(d.to_vec());
        }
        let st = self
            .act
            .ok_or_else(|| anyhow!("{who} needs activation statistics (stats artifact)"))?;
        Ok(diag_from_norm_sums(st, hp.p, hp.lam, hp.alpha))
    }
}

/// One compression method — a row of the paper's tables.
///
/// Implementations are stateless values (hyperparameters only), shared
/// behind `Arc` by [`MethodSpec`] handles.
pub trait Quantizer: Send + Sync {
    /// Registry key, e.g. `"ttq"`.
    fn name(&self) -> &'static str;

    /// Table-row label, e.g. `"TTQ (r = 16)"` (calibration-domain
    /// suffixes are added by [`MethodSpec::label`]).
    fn label(&self) -> String;

    /// Canonical spec string that re-parses to this method, e.g.
    /// `"ttq:r=16"`.
    fn spec_string(&self) -> String;

    /// Which statistics [`Quantizer::quantize`] consumes.
    fn requirement(&self) -> StatsRequirement;

    /// Rank of the static low-rank compensation factors (App. E); 0
    /// when the method has none. Callers use this to supply cached
    /// factors through [`LayerStats::lowrank`].
    fn lowrank_rank(&self) -> usize {
        0
    }

    /// Whether the method emits a packed low-bit representation — this
    /// drives the perf model's weight-traffic accounting. False for the
    /// FP reference row and for prune-only (dense f16 survivors).
    fn quantizes(&self) -> bool {
        true
    }

    /// True when the method conventionally calibrates offline on a
    /// named domain split (AWQ, GPTQ — Fig. 1a); false for test-time
    /// methods that consume the live batch (TTQ, pruning — Fig. 1b).
    fn offline_by_default(&self) -> bool {
        false
    }

    /// The (p, λ, α) diagonal hyperparameters for methods driven by the
    /// activation diagonal of Eq. 19; `None` otherwise.
    fn diag_hyper(&self) -> Option<TtqHyper> {
        None
    }

    /// Compress one weight matrix given the statistics promised by
    /// [`Quantizer::requirement`].
    fn quantize(&self, w: &Mat, stats: &LayerStats, spec: &QuantSpec) -> Result<Mat>;
}

// ---------------------------------------------------------------------
// Method implementations
// ---------------------------------------------------------------------

/// Un-quantized reference (the tables' FP32 header row).
#[derive(Clone, Copy, Debug, Default)]
pub struct FpQuantizer;

impl Quantizer for FpQuantizer {
    fn name(&self) -> &'static str {
        "fp"
    }

    fn label(&self) -> String {
        "FP32".into()
    }

    fn spec_string(&self) -> String {
        "fp".into()
    }

    fn requirement(&self) -> StatsRequirement {
        StatsRequirement::None
    }

    fn quantizes(&self) -> bool {
        false
    }

    fn quantize(&self, w: &Mat, _stats: &LayerStats, _spec: &QuantSpec) -> Result<Mat> {
        Ok(w.clone())
    }
}

/// Plain round-to-nearest groupwise QDQ (Eq. 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct RtnQuantizer;

impl Quantizer for RtnQuantizer {
    fn name(&self) -> &'static str {
        "rtn"
    }

    fn label(&self) -> String {
        "RTN".into()
    }

    fn spec_string(&self) -> String {
        "rtn".into()
    }

    fn requirement(&self) -> StatsRequirement {
        StatsRequirement::None
    }

    fn quantize(&self, w: &Mat, _stats: &LayerStats, spec: &QuantSpec) -> Result<Mat> {
        Ok(rtn_quantize(w, spec))
    }
}

/// Activation-aware scaled QDQ (Eq. 19-20), conventionally calibrated
/// offline on a named domain (Fig. 1a).
#[derive(Clone, Copy, Debug, Default)]
pub struct AwqQuantizer {
    /// Diagonal hyperparameters (p, λ, α).
    pub hyper: TtqHyper,
}

impl Quantizer for AwqQuantizer {
    fn name(&self) -> &'static str {
        "awq"
    }

    fn label(&self) -> String {
        "AWQ".into()
    }

    fn spec_string(&self) -> String {
        spec_join("awq", &hyper_args(&self.hyper))
    }

    fn requirement(&self) -> StatsRequirement {
        StatsRequirement::DiagonalNorms
    }

    fn offline_by_default(&self) -> bool {
        true
    }

    fn diag_hyper(&self) -> Option<TtqHyper> {
        Some(self.hyper)
    }

    fn quantize(&self, w: &Mat, stats: &LayerStats, spec: &QuantSpec) -> Result<Mat> {
        let d = stats.diagonal(&self.hyper, "AWQ")?;
        Ok(awq_quantize(w, &d, spec))
    }
}

/// Online test-time quantization (§2) with optional rank-r low-rank
/// compensation (App. E).
#[derive(Clone, Copy, Debug, Default)]
pub struct TtqQuantizer {
    /// Low-rank compensation rank r (0 = none).
    pub rank: usize,
    /// Diagonal hyperparameters (p, λ, α).
    pub hyper: TtqHyper,
}

impl Quantizer for TtqQuantizer {
    fn name(&self) -> &'static str {
        "ttq"
    }

    fn label(&self) -> String {
        format!("TTQ (r = {})", self.rank)
    }

    fn spec_string(&self) -> String {
        let mut args = vec![format!("r={}", self.rank)];
        args.extend(hyper_args(&self.hyper));
        spec_join("ttq", &args)
    }

    fn requirement(&self) -> StatsRequirement {
        StatsRequirement::DiagonalNorms
    }

    fn lowrank_rank(&self) -> usize {
        self.rank
    }

    fn diag_hyper(&self) -> Option<TtqHyper> {
        Some(self.hyper)
    }

    fn quantize(&self, w: &Mat, stats: &LayerStats, spec: &QuantSpec) -> Result<Mat> {
        let d = stats.diagonal(&self.hyper, "TTQ")?;
        if self.rank == 0 {
            return Ok(awq_quantize(w, &d, spec));
        }
        // Static factors are cached by the caller (App. E: recomputing
        // the SVD per prompt would defeat the negligible-overhead
        // claim); fall back to a fresh SVD for standalone use.
        let owned;
        let lr = match stats.lowrank {
            Some(lr) => lr,
            None => {
                owned = lowrank_init(w, self.rank);
                &owned
            }
        };
        let ba = lr.product();
        let wq = awq_quantize(&w.sub(&ba), &d, spec);
        Ok(wq.add(&ba))
    }
}

/// Greedy OBS baseline (App. C) over the full input correlation.
#[derive(Clone, Copy, Debug)]
pub struct GptqQuantizer {
    /// Hessian dampening fraction.
    pub damp: f64,
}

impl Default for GptqQuantizer {
    fn default() -> Self {
        GptqQuantizer { damp: 0.01 }
    }
}

impl Quantizer for GptqQuantizer {
    fn name(&self) -> &'static str {
        "gptq"
    }

    fn label(&self) -> String {
        "GPTQ".into()
    }

    fn spec_string(&self) -> String {
        if self.damp == Self::default().damp {
            "gptq".into()
        } else {
            format!("gptq:damp={}", self.damp)
        }
    }

    fn requirement(&self) -> StatsRequirement {
        StatsRequirement::FullCorrelation
    }

    fn offline_by_default(&self) -> bool {
        true
    }

    fn quantize(&self, w: &Mat, stats: &LayerStats, spec: &QuantSpec) -> Result<Mat> {
        let c = stats
            .corr
            .ok_or_else(|| anyhow!("GPTQ needs the input correlation (corr artifact)"))?;
        Ok(gptq_quantize(w, c, spec, self.damp))
    }
}

/// NormalFloat codebook QDQ (App. D's NF4, Dettmers et al. 2023).
#[derive(Clone, Copy, Debug, Default)]
pub struct NfQuantizer {
    /// Codebook bit-width override; `None` follows the [`QuantSpec`].
    pub bits: Option<u32>,
}

impl Quantizer for NfQuantizer {
    fn name(&self) -> &'static str {
        "nf"
    }

    fn label(&self) -> String {
        match self.bits {
            Some(b) => format!("NF{b}"),
            None => "NF".into(),
        }
    }

    fn spec_string(&self) -> String {
        match self.bits {
            Some(b) => format!("nf:{b}"),
            None => "nf".into(),
        }
    }

    fn requirement(&self) -> StatsRequirement {
        StatsRequirement::None
    }

    fn quantize(&self, w: &Mat, _stats: &LayerStats, spec: &QuantSpec) -> Result<Mat> {
        Ok(nf_quantize(w, self.bits.unwrap_or(spec.bits), spec.group))
    }
}

/// Test-time activation-aware pruning (§3 / μ-MoE), by default composed
/// with scaled QDQ of the survivors — one stats pass feeds both.
#[derive(Clone, Copy, Debug)]
pub struct PruneQuantizer {
    /// Target sparsity pattern.
    pub sparsity: Sparsity,
    /// Also QDQ the surviving weights (the §3 prune-then-quantize
    /// pipeline). `false` prunes only.
    pub requantize: bool,
    /// Diagonal hyperparameters (p, λ, α) for the saliency scores.
    pub hyper: TtqHyper,
}

impl Quantizer for PruneQuantizer {
    fn name(&self) -> &'static str {
        "prune"
    }

    fn label(&self) -> String {
        let base = match self.sparsity {
            Sparsity::Unstructured { ratio } => format!("Prune ({:.0}%)", ratio * 100.0),
            Sparsity::NofM { n, m } => format!("Prune ({n}:{m})"),
        };
        if self.requantize {
            format!("{base} + Q")
        } else {
            base
        }
    }

    fn spec_string(&self) -> String {
        let mut args = match self.sparsity {
            Sparsity::Unstructured { ratio } => vec![format!("{ratio}")],
            Sparsity::NofM { n, m } => vec![format!("n={n}"), format!("m={m}")],
        };
        if !self.requantize {
            args.push("quant=false".into());
        }
        args.extend(hyper_args(&self.hyper));
        spec_join("prune", &args)
    }

    fn requirement(&self) -> StatsRequirement {
        StatsRequirement::DiagonalNorms
    }

    fn quantizes(&self) -> bool {
        // prune-only leaves the survivors dense f16 — no packed traffic
        self.requantize
    }

    fn diag_hyper(&self) -> Option<TtqHyper> {
        Some(self.hyper)
    }

    fn quantize(&self, w: &Mat, stats: &LayerStats, spec: &QuantSpec) -> Result<Mat> {
        let d = stats.diagonal(&self.hyper, "prune")?;
        Ok(if self.requantize {
            prune_then_quantize(w, &d, self.sparsity, spec)
        } else {
            prune(w, &d, self.sparsity)
        })
    }
}

fn spec_join(name: &str, args: &[String]) -> String {
    if args.is_empty() {
        name.into()
    } else {
        format!("{}:{}", name, args.join(","))
    }
}

/// Non-default (p, λ, α) overrides in canonical key=value form.
fn hyper_args(hp: &TtqHyper) -> Vec<String> {
    let d = TtqHyper::default();
    let mut out = Vec::new();
    if hp.p != d.p {
        out.push(format!("p={}", hp.p));
    }
    if hp.lam != d.lam {
        out.push(format!("lam={}", hp.lam));
    }
    if hp.alpha != d.alpha {
        out.push(format!("alpha={}", hp.alpha));
    }
    out
}

// ---------------------------------------------------------------------
// MethodSpec — the one method selector shared by every layer
// ---------------------------------------------------------------------

/// A registry handle plus the optional offline calibration domain: the
/// single method selector for eval, bench, coordinator, perf model and
/// CLI (replaces the former `quant::Method` / `eval::MethodSpec` twins).
#[derive(Clone)]
pub struct MethodSpec {
    quantizer: Arc<dyn Quantizer>,
    calib_domain: Option<String>,
}

impl MethodSpec {
    /// Wrap an already-built quantizer (no calibration domain).
    pub fn from_quantizer(quantizer: Arc<dyn Quantizer>) -> Self {
        MethodSpec { quantizer, calib_domain: None }
    }

    /// Parse a spec string (`"rtn"`, `"awq:calib=wt2s"`, `"ttq:r=16"`,
    /// `"nf:4"`, `"prune:0.5"`, ...) via the global registry.
    ///
    /// ```
    /// use ttq_serve::quant::MethodSpec;
    ///
    /// let m = MethodSpec::parse("ttq:r=16").unwrap();
    /// assert_eq!(m.label(), "TTQ (r = 16)");
    /// assert!(m.is_online(), "no calib domain => test-time method");
    ///
    /// let m = MethodSpec::parse("awq:calib=c4s").unwrap();
    /// assert!(m.is_offline());
    /// assert_eq!(m.spec_string(), "awq:calib=c4s"); // round-trips
    ///
    /// assert!(MethodSpec::parse("no-such-method").is_err());
    /// ```
    pub fn parse(spec: &str) -> Result<Self> {
        MethodRegistry::global().parse(spec)
    }

    // -- convenience constructors for the built-in methods ------------

    /// The un-quantized FP32 reference row.
    pub fn fp() -> Self {
        Self::from_quantizer(Arc::new(FpQuantizer))
    }

    /// Plain round-to-nearest groupwise QDQ.
    pub fn rtn() -> Self {
        Self::from_quantizer(Arc::new(RtnQuantizer))
    }

    /// Offline AWQ calibrated on `calib_domain`'s calib split.
    pub fn awq(calib_domain: &str) -> Self {
        Self::from_quantizer(Arc::new(AwqQuantizer::default())).with_calib(calib_domain)
    }

    /// Online TTQ with rank-r low-rank compensation (r = 0 disables it).
    pub fn ttq(rank: usize) -> Self {
        Self::from_quantizer(Arc::new(TtqQuantizer { rank, ..Default::default() }))
    }

    /// Offline GPTQ calibrated on `calib_domain` (corr artifact).
    pub fn gptq(calib_domain: &str) -> Self {
        Self::from_quantizer(Arc::new(GptqQuantizer::default())).with_calib(calib_domain)
    }

    /// NormalFloat codebook QDQ at a fixed bit-width.
    pub fn nf(bits: u32) -> Self {
        Self::from_quantizer(Arc::new(NfQuantizer { bits: Some(bits) }))
    }

    /// NormalFloat at the bit-width of the governing [`QuantSpec`] —
    /// the right row for bit-sweep tables.
    pub fn nf_auto() -> Self {
        Self::from_quantizer(Arc::new(NfQuantizer { bits: None }))
    }

    /// Test-time unstructured prune (+ QDQ) at the given sparsity ratio.
    pub fn prune(ratio: f64) -> Self {
        Self::from_quantizer(Arc::new(PruneQuantizer {
            sparsity: Sparsity::Unstructured { ratio },
            requantize: true,
            hyper: TtqHyper::default(),
        }))
    }

    // -- accessors ----------------------------------------------------

    /// Attach an offline calibration domain (Fig. 1a path).
    pub fn with_calib(mut self, domain: &str) -> Self {
        self.calib_domain = Some(domain.to_string());
        self
    }

    /// The underlying method implementation.
    pub fn quantizer(&self) -> &dyn Quantizer {
        self.quantizer.as_ref()
    }

    /// The offline calibration domain, if any.
    pub fn calib_domain(&self) -> Option<&str> {
        self.calib_domain.as_deref()
    }

    /// What pass-1 statistics the method consumes.
    pub fn requirement(&self) -> StatsRequirement {
        self.quantizer.requirement()
    }

    /// Does this method consume activation statistics at all?
    pub fn needs_stats(&self) -> bool {
        self.requirement() != StatsRequirement::None
    }

    /// Does the stats pass need the full correlation (corr artifact)?
    pub fn needs_corr(&self) -> bool {
        self.requirement() == StatsRequirement::FullCorrelation
    }

    /// Offline: statistics come from a named domain's calibration split,
    /// once (Fig. 1a) — the path exposed to domain shift.
    pub fn is_offline(&self) -> bool {
        self.needs_stats() && self.calib_domain.is_some()
    }

    /// Online: statistics come from the live batch itself, per prompt
    /// (Fig. 1b) — the test-time path.
    pub fn is_online(&self) -> bool {
        self.needs_stats() && self.calib_domain.is_none()
    }

    /// Table-row label, e.g. `"AWQ (C4S Calib)"` / `"TTQ (r = 16)"`.
    pub fn label(&self) -> String {
        match &self.calib_domain {
            Some(d) => format!("{} ({} Calib)", self.quantizer.label(), d.to_uppercase()),
            None => self.quantizer.label(),
        }
    }

    /// Canonical spec string; `parse(spec_string())` reproduces `self`.
    pub fn spec_string(&self) -> String {
        let base = self.quantizer.spec_string();
        match &self.calib_domain {
            None => base,
            Some(d) if base.contains(':') => format!("{base},calib={d}"),
            Some(d) => format!("{base}:calib={d}"),
        }
    }
}

impl PartialEq for MethodSpec {
    fn eq(&self, other: &Self) -> bool {
        self.spec_string() == other.spec_string()
    }
}

impl fmt::Debug for MethodSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MethodSpec({})", self.spec_string())
    }
}

impl fmt::Display for MethodSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.spec_string())
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// Parsed `key=value` / positional arguments of a method spec string.
pub struct SpecArgs {
    kv: Vec<(String, String, bool)>,
    pos: Vec<(String, bool)>,
}

impl SpecArgs {
    fn new(s: &str) -> Self {
        let mut kv = Vec::new();
        let mut pos = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            match tok.split_once('=') {
                Some((k, v)) => kv.push((k.trim().to_string(), v.trim().to_string(), false)),
                None => pos.push((tok.to_string(), false)),
            }
        }
        SpecArgs { kv, pos }
    }

    fn take(&mut self, key: &str) -> Option<String> {
        for (k, v, used) in self.kv.iter_mut() {
            if k.as_str() == key && !*used {
                *used = true;
                return Some(v.clone());
            }
        }
        None
    }

    fn take_pos(&mut self) -> Option<String> {
        for (v, used) in self.pos.iter_mut() {
            if !*used {
                *used = true;
                return Some(v.clone());
            }
        }
        None
    }

    /// Consume `key` as an f64 (error when present but unparsable).
    pub fn take_f64(&mut self, key: &str) -> Result<Option<f64>> {
        self.take(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| anyhow!("method arg {key}={v} is not a number"))
            })
            .transpose()
    }

    /// Consume `key` as a usize (error when present but unparsable).
    pub fn take_usize(&mut self, key: &str) -> Result<Option<usize>> {
        self.take(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| anyhow!("method arg {key}={v} is not an integer"))
            })
            .transpose()
    }

    /// Consume `key` as a u32 (error when present but unparsable).
    pub fn take_u32(&mut self, key: &str) -> Result<Option<u32>> {
        self.take(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| anyhow!("method arg {key}={v} is not an integer"))
            })
            .transpose()
    }

    /// Consume `key` as a bool (error when present but unparsable).
    pub fn take_bool(&mut self, key: &str) -> Result<Option<bool>> {
        self.take(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| anyhow!("method arg {key}={v} is not true/false"))
            })
            .transpose()
    }

    /// Error out on arguments no builder consumed (catches typos).
    fn finish(&self, method: &str) -> Result<()> {
        for (k, v, used) in &self.kv {
            if !used {
                bail!("method '{method}': unknown argument {k}={v}");
            }
        }
        for (v, used) in &self.pos {
            if !used {
                bail!("method '{method}': unexpected argument '{v}'");
            }
        }
        Ok(())
    }
}

type Builder = fn(&mut SpecArgs) -> Result<Arc<dyn Quantizer>>;

/// One registered method family.
pub struct MethodEntry {
    /// Registry key (the spec-string prefix).
    pub name: &'static str,
    /// One-line help text.
    pub summary: &'static str,
    /// Canonical example spec (used in help text and round-trip tests).
    pub example: &'static str,
    builder: Builder,
}

/// Name → constructor table for every compression method. New methods
/// register here once and become CLI/table rows everywhere.
pub struct MethodRegistry {
    entries: Vec<MethodEntry>,
}

fn hyper_from_args(args: &mut SpecArgs) -> Result<TtqHyper> {
    let mut hp = TtqHyper::default();
    if let Some(p) = args.take_f64("p")? {
        hp.p = p;
    }
    if let Some(lam) = args.take_f64("lam")? {
        hp.lam = lam;
    }
    if let Some(alpha) = args.take_f64("alpha")? {
        hp.alpha = alpha;
    }
    Ok(hp)
}

impl MethodRegistry {
    /// The process-wide registry of built-in methods.
    pub fn global() -> &'static MethodRegistry {
        static REG: OnceLock<MethodRegistry> = OnceLock::new();
        REG.get_or_init(MethodRegistry::builtin)
    }

    /// All built-in methods (one entry per paper-table method family).
    pub fn builtin() -> Self {
        MethodRegistry {
            entries: vec![
                MethodEntry {
                    name: "fp",
                    summary: "un-quantized FP32 reference",
                    example: "fp",
                    builder: |_| Ok(Arc::new(FpQuantizer)),
                },
                MethodEntry {
                    name: "rtn",
                    summary: "round-to-nearest groupwise QDQ (Eq. 1)",
                    example: "rtn",
                    builder: |_| Ok(Arc::new(RtnQuantizer)),
                },
                MethodEntry {
                    name: "awq",
                    summary: "activation-aware scaled QDQ, offline calib (Eq. 19-20)",
                    example: "awq:calib=wt2s",
                    builder: |args| {
                        Ok(Arc::new(AwqQuantizer { hyper: hyper_from_args(args)? }))
                    },
                },
                MethodEntry {
                    name: "ttq",
                    summary: "online test-time quantization, rank-r compensation (§2)",
                    example: "ttq:r=16",
                    builder: |args| {
                        let rank = match args.take_usize("r")? {
                            Some(r) => r,
                            None => match args.take_pos() {
                                Some(v) => v
                                    .parse()
                                    .map_err(|_| anyhow!("ttq rank '{v}' is not an integer"))?,
                                None => 0,
                            },
                        };
                        Ok(Arc::new(TtqQuantizer { rank, hyper: hyper_from_args(args)? }))
                    },
                },
                MethodEntry {
                    name: "gptq",
                    summary: "greedy OBS baseline over the full correlation (App. C)",
                    example: "gptq",
                    builder: |args| {
                        let damp = args.take_f64("damp")?.unwrap_or(0.01);
                        if damp < 0.0 {
                            bail!("gptq damp must be >= 0, got {damp}");
                        }
                        Ok(Arc::new(GptqQuantizer { damp }))
                    },
                },
                MethodEntry {
                    name: "nf",
                    summary: "NormalFloat codebook QDQ (App. D, NF4-style)",
                    example: "nf:4",
                    builder: |args| {
                        let bits = match args.take_u32("bits")? {
                            Some(b) => Some(b),
                            None => match args.take_pos() {
                                Some(v) => Some(
                                    v.parse()
                                        .map_err(|_| anyhow!("nf bits '{v}' is not an integer"))?,
                                ),
                                None => None,
                            },
                        };
                        if let Some(b) = bits {
                            if !(1..=8).contains(&b) {
                                bail!("nf bits must be in 1..=8, got {b}");
                            }
                        }
                        Ok(Arc::new(NfQuantizer { bits }))
                    },
                },
                MethodEntry {
                    name: "prune",
                    summary: "test-time activation-aware pruning + QDQ (§3, μ-MoE)",
                    example: "prune:0.5",
                    builder: |args| {
                        let hyper = hyper_from_args(args)?;
                        let requantize = args.take_bool("quant")?.unwrap_or(true);
                        let n = args.take_usize("n")?;
                        let m = args.take_usize("m")?;
                        let sparsity = match (n, m) {
                            (Some(n), Some(m)) => {
                                if m == 0 || n > m {
                                    bail!("prune N:M needs 0 < m and n <= m, got {n}:{m}");
                                }
                                Sparsity::NofM { n, m }
                            }
                            (None, None) => {
                                let v = args.take_pos().ok_or_else(|| {
                                    anyhow!("prune needs a ratio (prune:0.5) or n=/m= (prune:n=2,m=4)")
                                })?;
                                let ratio: f64 = v
                                    .parse()
                                    .map_err(|_| anyhow!("prune ratio '{v}' is not a number"))?;
                                if !(0.0..=1.0).contains(&ratio) {
                                    bail!("prune ratio must be in [0, 1], got {ratio}");
                                }
                                Sparsity::Unstructured { ratio }
                            }
                            _ => bail!("prune: n= and m= must be given together"),
                        };
                        Ok(Arc::new(PruneQuantizer { sparsity, requantize, hyper }))
                    },
                },
            ],
        }
    }

    /// All registered method families.
    pub fn entries(&self) -> &[MethodEntry] {
        &self.entries
    }

    /// Registered method names (spec-string prefixes).
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// One help line per method, for CLI usage text.
    pub fn help(&self) -> String {
        self.entries
            .iter()
            .map(|e| format!("  {:<18} {}", e.example, e.summary))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Build a [`MethodSpec`] from `name[:arg,arg=val,...]`. A
    /// `calib=DOMAIN` argument attaches the offline calibration domain
    /// and is accepted by every statistics-consuming method.
    pub fn parse(&self, spec: &str) -> Result<MethodSpec> {
        let spec = spec.trim();
        let (name, rest) = match spec.split_once(':') {
            Some((n, r)) => (n.trim(), r),
            None => (spec, ""),
        };
        let entry = self
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                anyhow!("unknown method '{name}' — known methods: {}", self.names().join(", "))
            })?;
        let mut args = SpecArgs::new(rest);
        let calib = args.take("calib");
        let quantizer = (entry.builder)(&mut args)?;
        args.finish(name)?;
        let mut method = MethodSpec::from_quantizer(quantizer);
        if let Some(c) = calib {
            if method.requirement() == StatsRequirement::None {
                bail!("method '{name}' uses no activation statistics — calib={c} is meaningless");
            }
            method = method.with_calib(&c);
        }
        Ok(method)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_paper_rows() {
        assert_eq!(MethodSpec::rtn().label(), "RTN");
        assert_eq!(MethodSpec::ttq(16).label(), "TTQ (r = 16)");
        assert_eq!(MethodSpec::awq("c4s").label(), "AWQ (C4S Calib)");
        assert_eq!(MethodSpec::gptq("wt2s").label(), "GPTQ (WT2S Calib)");
        assert_eq!(MethodSpec::fp().label(), "FP32");
        assert_eq!(MethodSpec::nf(4).label(), "NF4");
        assert_eq!(MethodSpec::prune(0.5).label(), "Prune (50%) + Q");
    }

    #[test]
    fn parse_matches_constructors() {
        assert_eq!(MethodSpec::parse("fp").unwrap(), MethodSpec::fp());
        assert_eq!(MethodSpec::parse("rtn").unwrap(), MethodSpec::rtn());
        assert_eq!(
            MethodSpec::parse("awq:calib=c4s").unwrap(),
            MethodSpec::awq("c4s")
        );
        assert_eq!(MethodSpec::parse("ttq:r=16").unwrap(), MethodSpec::ttq(16));
        assert_eq!(MethodSpec::parse("ttq:16").unwrap(), MethodSpec::ttq(16));
        assert_eq!(MethodSpec::parse("ttq").unwrap(), MethodSpec::ttq(0));
        assert_eq!(
            MethodSpec::parse("gptq:calib=wt2s").unwrap(),
            MethodSpec::gptq("wt2s")
        );
        assert_eq!(MethodSpec::parse("nf:4").unwrap(), MethodSpec::nf(4));
        assert_eq!(MethodSpec::parse("prune:0.5").unwrap(), MethodSpec::prune(0.5));
    }

    #[test]
    fn spec_string_round_trips() {
        for spec in [
            "fp",
            "rtn",
            "awq:calib=wt2s",
            "awq:alpha=0.75,calib=c4s",
            "ttq:r=0",
            "ttq:r=16",
            "ttq:r=16,lam=0.1",
            "gptq",
            "gptq:damp=0.05,calib=ptbs",
            "nf:4",
            "nf",
            "prune:0.5",
            "prune:n=2,m=4",
            "prune:0.25,quant=false",
        ] {
            let m = MethodSpec::parse(spec).unwrap();
            let canon = m.spec_string();
            let again = MethodSpec::parse(&canon)
                .unwrap_or_else(|e| panic!("'{canon}' (from '{spec}') must re-parse: {e}"));
            assert_eq!(m, again, "round-trip of '{spec}' via '{canon}'");
            assert_eq!(m.label(), again.label());
        }
    }

    #[test]
    fn requirements_drive_planning() {
        assert_eq!(MethodSpec::fp().requirement(), StatsRequirement::None);
        assert_eq!(MethodSpec::rtn().requirement(), StatsRequirement::None);
        assert_eq!(MethodSpec::nf(4).requirement(), StatsRequirement::None);
        assert_eq!(
            MethodSpec::awq("c4s").requirement(),
            StatsRequirement::DiagonalNorms
        );
        assert_eq!(
            MethodSpec::ttq(16).requirement(),
            StatsRequirement::DiagonalNorms
        );
        assert_eq!(
            MethodSpec::prune(0.5).requirement(),
            StatsRequirement::DiagonalNorms
        );
        assert_eq!(
            MethodSpec::gptq("wt2s").requirement(),
            StatsRequirement::FullCorrelation
        );
        assert!(MethodSpec::gptq("wt2s").needs_corr());
        assert!(!MethodSpec::ttq(0).needs_corr());
    }

    #[test]
    fn online_offline_split() {
        assert!(MethodSpec::awq("c4s").is_offline());
        assert!(MethodSpec::ttq(0).is_online());
        // AWQ with no calib domain collects from live traffic — the
        // "online AWQ" degenerate of TTQ r=0.
        let online_awq = MethodSpec::parse("awq").unwrap();
        assert!(online_awq.is_online() && !online_awq.is_offline());
        // no-stats methods are neither
        assert!(!MethodSpec::rtn().is_online() && !MethodSpec::rtn().is_offline());
        assert!(!MethodSpec::fp().quantizer().quantizes());
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(MethodSpec::parse("awqq").is_err());
        assert!(MethodSpec::parse("rtn:calib=c4s").is_err(), "rtn takes no calib");
        assert!(MethodSpec::parse("ttq:rank=16").is_err(), "unknown key");
        assert!(MethodSpec::parse("ttq:r=abc").is_err());
        assert!(MethodSpec::parse("prune").is_err(), "prune needs a ratio");
        assert!(MethodSpec::parse("prune:1.5").is_err());
        assert!(MethodSpec::parse("prune:n=3,m=2").is_err());
        assert!(MethodSpec::parse("nf:9").is_err());
    }

    #[test]
    fn lowrank_rank_exposed() {
        assert_eq!(MethodSpec::ttq(16).quantizer().lowrank_rank(), 16);
        assert_eq!(MethodSpec::ttq(0).quantizer().lowrank_rank(), 0);
        assert_eq!(MethodSpec::awq("c4s").quantizer().lowrank_rank(), 0);
    }

    #[test]
    fn registry_lists_all_builtins() {
        let names = MethodRegistry::global().names();
        for want in ["fp", "rtn", "awq", "ttq", "gptq", "nf", "prune"] {
            assert!(names.contains(&want), "{want} missing from registry");
        }
        assert!(MethodRegistry::global().help().contains("ttq:r=16"));
    }
}
