//! Round-to-nearest groupwise QDQ — paper Eq. (1) / App. B.
//!
//! Hot path of the whole stack: every method (AWQ/TTQ/GPTQ grouping
//! aside) funnels through this. The inner loop is written allocation-
//! free over the flat weight slice; see EXPERIMENTS.md §Perf for the
//! optimization history.

use super::formats::{group_params, QuantSpec};
use crate::linalg::Mat;

/// QDQ in one shot: returns the dequantized weight (same shape).
pub fn rtn_quantize(w: &Mat, spec: &QuantSpec) -> Mat {
    let mut out = w.clone();
    rtn_quantize_inplace(&mut out.data, spec);
    out
}

/// In-place flat QDQ over any f32 slice (numel must divide by group).
///
/// Perf notes (EXPERIMENTS.md §Perf): `round_ties_even` instead of
/// `round` (the latter is a libm call on x86 — round-half-away has no
/// single instruction; ties-even is `roundss` and also matches the
/// jnp reference's banker's rounding), clamp-before-round so the whole
/// body vectorizes, zero allocation.
pub fn rtn_quantize_inplace(data: &mut [f32], spec: &QuantSpec) {
    let g = spec.group;
    assert_eq!(
        data.len() % g,
        0,
        "numel {} not divisible by groupsize {g}",
        data.len()
    );
    let qmax = spec.qmax();
    for grp in data.chunks_mut(g) {
        let (s, z) = group_params(grp, qmax, spec.format);
        let inv_s = 1.0 / s;
        for v in grp.iter_mut() {
            let q = ((*v - z) * inv_s).clamp(0.0, qmax).round_ties_even();
            *v = q * s + z;
        }
    }
}

/// Integer codes + per-group scale/zero — the deployable representation
/// consumed by [`super::pack`] (int_matmul kernels in the paper).
#[derive(Clone, Debug)]
pub struct QuantizedInt {
    /// One code per element (≤ 8 bits each).
    pub codes: Vec<u8>,
    /// Per-group scale S.
    pub scales: Vec<f32>,
    /// Per-group zero Z.
    pub zeros: Vec<f32>,
    /// Weight rows (d_out).
    pub rows: usize,
    /// Weight columns (d_in).
    pub cols: usize,
    /// The spec the codes were produced under.
    pub spec: QuantSpec,
}

/// Quantize to integer codes + group params (no dequantization).
pub fn rtn_quantize_int(w: &Mat, spec: &QuantSpec) -> QuantizedInt {
    let g = spec.group;
    assert!(spec.bits <= 8, "QuantizedInt stores u8 codes");
    assert_eq!(w.data.len() % g, 0);
    let qmax = spec.qmax();
    let n_groups = w.data.len() / g;
    let mut codes = vec![0u8; w.data.len()];
    let mut scales = Vec::with_capacity(n_groups);
    let mut zeros = Vec::with_capacity(n_groups);
    for (gi, grp) in w.data.chunks(g).enumerate() {
        let (s, z) = group_params(grp, qmax, spec.format);
        let inv_s = 1.0 / s;
        for (j, v) in grp.iter().enumerate() {
            codes[gi * g + j] =
                ((*v - z) * inv_s).clamp(0.0, qmax).round_ties_even() as u8;
        }
        scales.push(s);
        zeros.push(z);
    }
    QuantizedInt {
        codes,
        scales,
        zeros,
        rows: w.rows,
        cols: w.cols,
        spec: spec.clone(),
    }
}

/// Dequantize integer codes back to f32 (the G⁻ operator of Eq. 1).
pub fn rtn_dequantize(q: &QuantizedInt) -> Mat {
    let g = q.spec.group;
    let mut data = vec![0.0f32; q.codes.len()];
    for (gi, chunk) in data.chunks_mut(g).enumerate() {
        let s = q.scales[gi];
        let z = q.zeros[gi];
        for (j, v) in chunk.iter_mut().enumerate() {
            *v = q.codes[gi * g + j] as f32 * s + z;
        }
    }
    Mat::from_vec(q.rows, q.cols, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::formats::QdqFormat;

    fn spec(bits: u32, group: usize) -> QuantSpec {
        QuantSpec { bits, group, format: QdqFormat::Asymmetric }
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(16, 64, &mut rng);
        let what = rtn_quantize(&w, &spec(3, 32));
        for (grp_w, grp_q) in w.data.chunks(32).zip(what.data.chunks(32)) {
            let mx = grp_w.iter().cloned().fold(f32::MIN, f32::max);
            let mn = grp_w.iter().cloned().fold(f32::MAX, f32::min);
            let s = (mx - mn) / 7.0;
            for (a, b) in grp_w.iter().zip(grp_q) {
                assert!((a - b).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(8, 64, &mut rng);
        let w1 = rtn_quantize(&w, &spec(4, 32));
        let w2 = rtn_quantize(&w1, &spec(4, 32));
        for (a, b) in w1.data.iter().zip(&w2.data) {
            assert!((a - b).abs() < 2e-6);
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(16, 64, &mut rng);
        let errs: Vec<f64> = [2, 3, 4, 5, 8]
            .iter()
            .map(|&b| w.sub(&rtn_quantize(&w, &spec(b, 32))).frob_sq())
            .collect();
        for pair in errs.windows(2) {
            assert!(pair[0] > pair[1]);
        }
    }

    #[test]
    fn smaller_groups_less_error() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(16, 64, &mut rng);
        let errs: Vec<f64> = [8usize, 32, 128, 512]
            .iter()
            .map(|&g| w.sub(&rtn_quantize(&w, &spec(3, g))).frob_sq())
            .collect();
        for pair in errs.windows(2) {
            assert!(pair[0] <= pair[1] + 1e-9);
        }
    }

    #[test]
    fn constant_group_exact() {
        let w = Mat::from_vec(2, 32, vec![0.37; 64]);
        let what = rtn_quantize(&w, &spec(3, 32));
        for v in &what.data {
            assert!((v - 0.37).abs() < 1e-7);
        }
    }

    #[test]
    fn int_roundtrip_matches_qdq() {
        let mut rng = Rng::new(5);
        let w = Mat::randn(8, 64, &mut rng);
        let s = spec(4, 32);
        let what = rtn_quantize(&w, &s);
        let qi = rtn_quantize_int(&w, &s);
        let deq = rtn_dequantize(&qi);
        for (a, b) in what.data.iter().zip(&deq.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn codes_within_bit_range() {
        let mut rng = Rng::new(6);
        let w = Mat::randn(4, 64, &mut rng);
        for bits in [2u32, 3, 4, 5] {
            let qi = rtn_quantize_int(&w, &spec(bits, 32));
            let top = (1u32 << bits) - 1;
            assert!(qi.codes.iter().all(|&c| (c as u32) <= top));
        }
    }

    #[test]
    fn group_spanning_rows_is_flat() {
        // g = 64 over a (8, 16) weight: groups run across rows.
        let mut rng = Rng::new(7);
        let w = Mat::randn(8, 16, &mut rng);
        let what = rtn_quantize(&w, &spec(3, 64));
        assert_eq!((what.rows, what.cols), (8, 16));
        // flattened QDQ equals a manual per-64-chunk QDQ
        let mut manual = w.data.clone();
        rtn_quantize_inplace(&mut manual, &spec(3, 64));
        assert_eq!(what.data, manual);
    }
}
