//! TTQ — the paper's contribution (§2): online activation-aware
//! quantization at inference time.
//!
//! Given the *live* activations of the incoming prompt (either raw X or
//! the norm sums collected by the stats artifact), compute D on the fly
//! and quantize `Ŵ = Q[(W − BA)·D]·D⁻¹ (+ BA)`. Zero offline
//! calibration; re-runs per prompt, which is affordable because the
//! overhead ratio ρ = O[1/d′ + 3/T] → 0 (Eq. 3) — measured by
//! `benches/ttq_overhead.rs`.

use super::awq::{awq_quantize, diag_from_norm_sums, diag_from_x, ActStats};
use super::formats::QuantSpec;
use super::lowrank::{lowrank_init, LowRank};
use crate::linalg::Mat;

/// The constant hyperparameters (α, λ, p) the paper keeps fixed at test
/// time (App. F: α ≈ 0.5, λ ≈ 0.4, p = 2).
#[derive(Clone, Copy, Debug)]
pub struct TtqHyper {
    /// Norm order of the activation diagonal.
    pub p: f64,
    /// Additive smoothing λ.
    pub lam: f64,
    /// Diagonal exponent α.
    pub alpha: f64,
}

impl Default for TtqHyper {
    fn default() -> Self {
        TtqHyper { p: 2.0, lam: 0.4, alpha: 0.5 }
    }
}

/// Result of a TTQ pass over one linear layer.
#[derive(Clone, Debug)]
pub struct TtqQuantized {
    /// Dequantized effective weight (W_q, or W_q + BA when rank > 0) —
    /// what the plain forward artifact consumes.
    pub weight: Mat,
    /// The low-rank factors, if any (kept for the fast serving path).
    pub lowrank: Option<LowRank>,
}

/// Rank-0 TTQ from live activations X (d_in, T).
pub fn ttq_quantize(w: &Mat, x: &Mat, spec: &QuantSpec, hp: &TtqHyper) -> TtqQuantized {
    let d = diag_from_x(x, hp.p, hp.lam, hp.alpha);
    TtqQuantized { weight: awq_quantize(w, &d, spec), lowrank: None }
}

/// Rank-0 TTQ from accumulated norm sums (the stats-artifact path used
/// by the coordinator: pass 1 collects Σ|x|^p, rust quantizes, pass 2
/// runs the plain artifact with the substituted weights).
pub fn ttq_quantize_from_stats(
    w: &Mat,
    stats: &ActStats,
    spec: &QuantSpec,
    hp: &TtqHyper,
) -> TtqQuantized {
    let d = diag_from_norm_sums(stats, hp.p, hp.lam, hp.alpha);
    TtqQuantized { weight: awq_quantize(w, &d, spec), lowrank: None }
}

/// TTQ with rank-r low-rank compensation (App. E):
/// `Ŵ = Q[(W − BA)·D]·D⁻¹ + BA`, B/A static top-r principal components.
pub fn ttq_quantize_lowrank(
    w: &Mat,
    x: &Mat,
    r: usize,
    spec: &QuantSpec,
    hp: &TtqHyper,
) -> TtqQuantized {
    if r == 0 {
        return ttq_quantize(w, x, spec, hp);
    }
    let lr = lowrank_init(w, r);
    let d = diag_from_x(x, hp.p, hp.lam, hp.alpha);
    let wq = awq_quantize(&w.sub(&lr.product()), &d, spec);
    TtqQuantized { weight: wq.add(&lr.product()), lowrank: Some(lr) }
}

/// Low-rank variant over accumulated stats with *precomputed* factors
/// (the factors are static per App. E — computing the SVD per prompt
/// would defeat the negligible-overhead claim, so the coordinator does
/// it once at model load).
pub fn ttq_quantize_lowrank_from_stats(
    w: &Mat,
    stats: &ActStats,
    lr: &LowRank,
    spec: &QuantSpec,
    hp: &TtqHyper,
) -> TtqQuantized {
    let d = diag_from_norm_sums(stats, hp.p, hp.lam, hp.alpha);
    let wq = awq_quantize(&w.sub(&lr.product()), &d, spec);
    TtqQuantized { weight: wq.add(&lr.product()), lowrank: Some(lr.clone()) }
}

/// The paper's Eq. (3) overhead model: extra flops of online AWQ over
/// the un-quantized projection, as a ratio. Used by the perf model and
/// checked against measurement in `benches/ttq_overhead.rs`.
pub fn overhead_ratio(d_out: usize, d_in: usize, tokens: usize) -> f64 {
    let num = (d_in * tokens + 3 * d_out * d_in) as f64;
    let den = (d_out * d_in * tokens) as f64;
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{activation_loss, Rng};
    use crate::quant::rtn::rtn_quantize;

    fn outlier_x(d: usize, t: usize, rng: &mut Rng) -> Mat {
        let scales: Vec<f32> = (0..d).map(|_| rng.lognormal(0.0, 1.5) as f32).collect();
        let mut x = Mat::randn(d, t, rng);
        for i in 0..d {
            for v in x.row_mut(i) {
                *v *= scales[i];
            }
        }
        x
    }

    #[test]
    fn rank0_equals_awq_on_same_x() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(16, 64, &mut rng);
        let x = Mat::randn(64, 10, &mut rng);
        let spec = QuantSpec::new(3, 32);
        let hp = TtqHyper::default();
        let t = ttq_quantize(&w, &x, &spec, &hp);
        let d = diag_from_x(&x, 2.0, 0.4, 0.5);
        let a = awq_quantize(&w, &d, &spec);
        assert_eq!(t.weight.data, a.data);
        assert!(t.lowrank.is_none());
    }

    #[test]
    fn lowrank_reduces_2bit_activation_loss() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(48, 64, &mut rng);
        let x = outlier_x(64, 128, &mut rng);
        let spec = QuantSpec::new(2, 32);
        let hp = TtqHyper::default();
        let t0 = ttq_quantize(&w, &x, &spec, &hp);
        let t16 = ttq_quantize_lowrank(&w, &x, 16, &spec, &hp);
        let e0 = activation_loss(&w, &t0.weight, &x);
        let e16 = activation_loss(&w, &t16.weight, &x);
        assert!(e16 < e0, "r16 {e16} vs r0 {e0}");
    }

    #[test]
    fn adapts_to_live_domain_better_than_stale_awq() {
        // The domain-shift experiment at unit scale: AWQ calibrated on
        // domain A, evaluated on domain B, loses to TTQ computed on B.
        let mut rng = Rng::new(3);
        let w = Mat::randn(32, 64, &mut rng);
        let x_stale = outlier_x(64, 128, &mut rng);
        let x_live = outlier_x(64, 128, &mut rng); // different outliers
        let spec = QuantSpec::new(2, 32);
        let hp = TtqHyper::default();
        let d_stale = diag_from_x(&x_stale, hp.p, hp.lam, hp.alpha);
        let w_awq = awq_quantize(&w, &d_stale, &spec);
        let w_ttq = ttq_quantize(&w, &x_live, &spec, &hp).weight;
        let e_awq = activation_loss(&w, &w_awq, &x_live);
        let e_ttq = activation_loss(&w, &w_ttq, &x_live);
        assert!(e_ttq < e_awq, "ttq {e_ttq} vs stale awq {e_awq}");
    }

    #[test]
    fn stats_path_matches_x_path() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(16, 48, &mut rng);
        let x = Mat::randn(48, 64, &mut rng);
        let spec = QuantSpec::new(3, 16);
        let hp = TtqHyper::default();
        let via_x = ttq_quantize(&w, &x, &spec, &hp);
        let ps = [0.5f64, 1.0, 2.0, 4.0];
        let mut stats = ActStats::new(&ps, 48);
        let sums: Vec<Vec<f64>> = ps
            .iter()
            .map(|&p| {
                (0..48)
                    .map(|i| {
                        x.row(i).iter().map(|&v| (v as f64).abs().powf(p)).sum()
                    })
                    .collect()
            })
            .collect();
        stats.accumulate(&sums, 64.0);
        let via_stats = ttq_quantize_from_stats(&w, &stats, &spec, &hp);
        for (a, b) in via_x.weight.data.iter().zip(&via_stats.weight.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn ttq_beats_rtn_at_low_bits() {
        let mut rng = Rng::new(5);
        let w = Mat::randn(32, 64, &mut rng);
        let x = outlier_x(64, 256, &mut rng);
        let spec = QuantSpec::new(2, 32);
        let e_rtn = activation_loss(&w, &rtn_quantize(&w, &spec), &x);
        let e_ttq = activation_loss(
            &w,
            &ttq_quantize(&w, &x, &spec, &TtqHyper::default()).weight,
            &x,
        );
        assert!(e_ttq < e_rtn);
    }

    #[test]
    fn overhead_ratio_vanishes() {
        // Eq. 3: ρ → 0 as d', T grow
        let small = overhead_ratio(64, 64, 4);
        let large = overhead_ratio(4096, 4096, 512);
        assert!(large < small);
        assert!(large < 0.01, "ρ = {large}");
        // exact form check
        let rho = overhead_ratio(100, 50, 20);
        let want = (50.0 * 20.0 + 3.0 * 100.0 * 50.0) / (100.0 * 50.0 * 20.0);
        assert!((rho - want).abs() < 1e-12);
    }

    #[test]
    fn precomputed_lowrank_stats_path_consistent() {
        let mut rng = Rng::new(6);
        let w = Mat::randn(24, 32, &mut rng);
        let x = Mat::randn(32, 40, &mut rng);
        let spec = QuantSpec::new(3, 32);
        let hp = TtqHyper::default();
        let direct = ttq_quantize_lowrank(&w, &x, 4, &spec, &hp);
        let lr = lowrank_init(&w, 4);
        let ps = [2.0f64];
        let mut stats = ActStats::new(&ps, 32);
        let sums: Vec<Vec<f64>> = vec![(0..32)
            .map(|i| x.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum())
            .collect()];
        stats.accumulate(&sums, 40.0);
        let via_stats = ttq_quantize_lowrank_from_stats(&w, &stats, &lr, &spec, &hp);
        for (a, b) in direct.weight.data.iter().zip(&via_stats.weight.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}
