//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client). One [`Runtime`] per
//! process; compiled executables are cached per artifact path so the
//! coordinator's shape buckets each compile exactly once.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::linalg::Mat;

/// Model-variant artifact id: `{model}_{variant}_b{batch}.hlo.txt`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// Model name (e.g. `qwen-micro`).
    pub model: String,
    /// Artifact variant (`logits` / `nll` / `stats` / ...).
    pub variant: String,
    /// Compiled batch size (the AOT bucket).
    pub batch: usize,
}

impl ArtifactKey {
    /// Key for one `{model}_{variant}_b{batch}` artifact.
    pub fn new(model: &str, variant: &str, batch: usize) -> Self {
        ArtifactKey { model: model.into(), variant: variant.into(), batch }
    }

    /// The on-disk artifact filename.
    pub fn filename(&self) -> String {
        format!("{}_{}_b{}.hlo.txt", self.model, self.variant, self.batch)
    }
}

/// The PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Build the PJRT CPU client over an artifacts directory.
    pub fn new(artifacts: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime {
            client,
            artifacts: artifacts.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The artifacts directory this runtime loads from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts
    }

    /// Load + compile (cached) an artifact by key.
    pub fn load(&self, key: &ArtifactKey) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        self.load_path_rel(&key.filename())
    }

    /// Load + compile (cached) any HLO-text file relative to artifacts/.
    pub fn load_path_rel(&self, rel: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(rel) {
            return Ok(exe.clone());
        }
        let path = self.artifacts.join(rel);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {rel}: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(rel.to_string(), exe.clone());
        Ok(exe)
    }

    /// Executables compiled so far (cache size).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute with literal inputs; flattens the returned tuple.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let first = bufs
            .first()
            .and_then(|device| device.first())
            .ok_or_else(|| anyhow!("execute returned no output buffers"))?;
        let out = first
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        // All our artifacts lower with return_tuple=True.
        out.to_tuple().map_err(|e| anyhow!("untuple: {e}"))
    }
}

// ---------------------------------------------------------------------
// Literal conversion helpers (Mat / tokens / scalars ↔ xla::Literal)
// ---------------------------------------------------------------------

/// Tokens (batch, seq) → i32 literal.
pub fn tokens_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), batch * seq);
    xla::Literal::vec1(tokens)
        .reshape(&[batch as i64, seq as i64])
        .map_err(|e| anyhow!("tokens reshape: {e}"))
}

/// Mat → f32 literal with its natural (rows, cols) shape; 1-D tensors
/// (stored as (1, n)) are emitted rank-1 when `rank1` is set.
pub fn mat_literal(m: &Mat, rank1: bool) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&m.data);
    if rank1 {
        Ok(lit)
    } else {
        lit.reshape(&[m.rows as i64, m.cols as i64])
            .map_err(|e| anyhow!("mat reshape: {e}"))
    }
}

/// Scalar f32 literal.
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read back a scalar f32 output.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow!("scalar readback: {e}"))?
        .first()
        .copied()
        .ok_or_else(|| anyhow!("empty literal"))
}

/// Read back an f32 tensor of known element count.
pub fn literal_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("f32 readback: {e}"))
}

/// Build the full positional input list for a model artifact:
/// tokens, [qmax], then every weight tensor in manifest order.
pub fn model_inputs(
    weights: &crate::models::ModelWeights,
    tokens: &[i32],
    batch: usize,
    qmax: Option<f32>,
) -> Result<Vec<xla::Literal>> {
    let seq = weights.manifest.config.seq;
    let mut inputs = vec![tokens_literal(tokens, batch, seq)?];
    if let Some(q) = qmax {
        inputs.push(scalar_f32(q));
    }
    let ranks: HashMap<&str, usize> = weights
        .manifest
        .tensors
        .iter()
        .map(|t| (t.name.as_str(), t.shape.len()))
        .collect();
    for (name, m) in weights
        .tensor_names()
        .iter()
        .map(String::as_str)
        .zip(weights.ordered())
    {
        inputs.push(mat_literal(m, ranks[name] == 1)?);
    }
    Ok(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_key_filename() {
        let k = ArtifactKey::new("qwen-mini", "nll", 4);
        assert_eq!(k.filename(), "qwen-mini_nll_b4.hlo.txt");
    }

    #[test]
    fn tokens_literal_shape() {
        let lit = tokens_literal(&[1, 2, 3, 4, 5, 6], 2, 3).unwrap();
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn mat_literal_roundtrip() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let lit = mat_literal(&m, false).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), m.data);
    }
}
