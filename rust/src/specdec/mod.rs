//! Self-speculative decoding: quantized drafter + full-precision
//! verifier with KV rollback.
//!
//! TTQ's core asset — an activation-aware quantized model produced on
//! the fly from online calibration — is exactly the cheap drafter that
//! speculative decoding needs. The same model therefore plays both
//! roles:
//!
//! * **drafter** — the quantized weights (packed W4, or any registry
//!   method) run `k` cheap cached [`ExecBackend::decode_step`]s,
//!   proposing tokens `d₁..d_k`;
//! * **verifier** — the full-precision weights score all `k+1`
//!   positions (`[last, d₁..d_k]`) in **one** batched cached forward
//!   ([`ExecBackend::verify_step`]), accept the longest prefix of
//!   drafts that match what the verifier itself would have emitted, and
//!   always commit one verifier token past it (the correction on a
//!   rejection, the bonus token on a clean sweep);
//! * **rollback** — both KV caches are rolled back to the first
//!   rejection with [`KvCache::truncate`]; the caches are *dual* (one
//!   slot per role, never forked) because drafter and verifier disagree
//!   about every hidden state.
//!
//! Under greedy decoding the committed stream is **token-identical** to
//! plain full-precision generation — acceptance only trades speed. With
//! a seeded stochastic [`Sampler`] the guarantee still holds, because a
//! draft is accepted only when it equals the token the sampler draws
//! from the verifier's own logits (one draw per committed token, in
//! order — the same RNG stream plain generation consumes).
//!
//! The drafting depth adapts: [`SpecController`] tracks a running
//! acceptance-rate EWMA and widens `k` while drafts keep landing,
//! narrowing it when traffic drifts away from the drafter's
//! calibration. That closes the paper's feedback loop — when the online
//! calibrator requantizes the drafter mid-stream, acceptance (and with
//! it the realized speedup) is the observable that says whether the new
//! calibration fits the traffic. The EWMA is reset at every
//! requantization so the signal speaks about the *current* drafter
//! generation.

#![forbid(unsafe_code)]

use anyhow::{anyhow, bail, Result};

use crate::backend::ExecBackend;
use crate::eval::Sampler;
use crate::kvcache::{KvCache, KvCacheConfig, SeqId};
use crate::models::ModelWeights;
use crate::obs::Clock;
use crate::quant::{lowrank_init, LayerStats, MethodSpec, QuantSpec, StatsRequirement};
use crate::util::argmax;

/// Speculative-round invariant violations that used to be `expect`s
/// (repo-lint R3 bans `unwrap`/`expect` in this module — the round
/// must fail as a `Result`, not unwind mid-serve).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpecError {
    /// `spec_round` was entered with no committed token to anchor the
    /// verify window (`pending` empty — the prefill must seed it).
    EmptyPending,
    /// A freshly built single-slot KV cache refused to allocate its
    /// one sequence slot.
    CacheSlotUnavailable,
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::EmptyPending => {
                write!(f, "speculative round with empty pending window")
            }
            SpecError::CacheSlotUnavailable => {
                write!(f, "fresh single-slot KV cache has no free slot")
            }
        }
    }
}

impl std::error::Error for SpecError {}

// ---------------------------------------------------------------------
// Policy
// ---------------------------------------------------------------------

/// Speculative-decoding policy: drafting depth, drafter method, and
/// whether the depth adapts to the observed acceptance rate.
#[derive(Clone, Debug)]
pub struct SpecConfig {
    /// Initial (and, with `adaptive: false`, fixed) draft depth.
    pub k: usize,
    /// Registry method used to quantize a standalone drafter (see
    /// [`drafter_weights`]). The serving loop ignores this field — its
    /// drafter is whatever the online calibrator last committed.
    pub method: MethodSpec,
    /// Adapt `k` from the acceptance EWMA (see [`SpecController`]).
    pub adaptive: bool,
}

impl SpecConfig {
    /// Adaptive policy starting at draft depth `k` (RTN drafter method).
    pub fn new(k: usize) -> Self {
        SpecConfig { k: k.max(1), method: MethodSpec::rtn(), adaptive: true }
    }

    /// Set the standalone-drafter quantization method.
    pub fn with_method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self
    }

    /// Enable/disable acceptance-driven depth adaptation.
    pub fn with_adaptive(mut self, adaptive: bool) -> Self {
        self.adaptive = adaptive;
        self
    }
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig::new(4)
    }
}

/// Exponentially-weighted acceptance rate (per-draft granularity).
#[derive(Clone, Debug)]
pub struct AcceptanceEwma {
    decay: f64,
    rate: f64,
    seen: bool,
}

impl AcceptanceEwma {
    /// `decay` is the weight of history per observation, in `[0, 1)`.
    pub fn new(decay: f64) -> Self {
        AcceptanceEwma { decay: decay.clamp(0.0, 0.999), rate: 0.0, seen: false }
    }

    /// Fold in one round's outcome (`accepted` of `drafted` landed).
    pub fn observe(&mut self, accepted: usize, drafted: usize) {
        if drafted == 0 {
            return;
        }
        let sample = accepted as f64 / drafted as f64;
        self.rate = if self.seen {
            self.decay * self.rate + (1.0 - self.decay) * sample
        } else {
            sample
        };
        self.seen = true;
    }

    /// Current estimate; optimistic 1.0 before any observation (a fresh
    /// drafter gets the benefit of the doubt at full depth).
    pub fn rate(&self) -> f64 {
        if self.seen {
            self.rate
        } else {
            1.0
        }
    }

    /// Forget all history (fresh drafter generation).
    pub fn reset(&mut self) {
        self.rate = 0.0;
        self.seen = false;
    }
}

/// Acceptance EWMA above this widens the draft window…
const K_RAISE_AT: f64 = 0.8;
/// …below this narrows it.
const K_LOWER_AT: f64 = 0.4;
/// History weight of the acceptance EWMA.
const EWMA_DECAY: f64 = 0.8;

/// Adaptive-k controller: one per drafter generation (the serving loop
/// resets it whenever requantization swaps the drafter weights).
#[derive(Clone, Debug)]
pub struct SpecController {
    k: usize,
    k_init: usize,
    k_max: usize,
    adaptive: bool,
    ewma: AcceptanceEwma,
}

impl SpecController {
    /// Controller at the policy's initial depth (cap 2k, floor 1).
    pub fn new(cfg: &SpecConfig) -> Self {
        let k_init = cfg.k.max(1);
        SpecController {
            k: k_init,
            k_init,
            k_max: (2 * k_init).max(2),
            adaptive: cfg.adaptive,
            ewma: AcceptanceEwma::new(EWMA_DECAY),
        }
    }

    /// Draft depth for the next round.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Current acceptance-rate estimate.
    pub fn acceptance(&self) -> f64 {
        self.ewma.rate()
    }

    /// Fold in a round's outcome and (when adaptive) retune `k`:
    /// sustained high acceptance earns a deeper window, sustained
    /// rejection shrinks it toward a plain verified step.
    pub fn observe(&mut self, accepted: usize, drafted: usize) {
        if drafted == 0 {
            return;
        }
        self.ewma.observe(accepted, drafted);
        if !self.adaptive {
            return;
        }
        let r = self.ewma.rate();
        if r >= K_RAISE_AT {
            self.k = (self.k + 1).min(self.k_max);
        } else if r <= K_LOWER_AT {
            self.k = self.k.saturating_sub(1).max(1);
        }
    }

    /// Back to the initial depth with a cleared EWMA — called when the
    /// drafter weights are swapped (requantization): the old acceptance
    /// history says nothing about the new drafter.
    pub fn reset(&mut self) {
        self.k = self.k_init;
        self.ewma.reset();
    }
}

// ---------------------------------------------------------------------
// Per-sequence state
// ---------------------------------------------------------------------

/// One model role (weights + the backend that executes them). The
/// drafter typically pairs quantized weights with a packed-execution
/// backend; the verifier pairs full-precision weights with a dense one.
#[derive(Clone, Copy)]
pub struct SpecModel<'a> {
    /// The backend executing this role's forwards.
    pub backend: &'a dyn ExecBackend,
    /// The role's weights (quantized for the drafter, fp32 for the
    /// verifier).
    pub weights: &'a ModelWeights,
}

/// Fork-free dual-cache state for one speculative sequence: the
/// drafter's own KV slot plus the committed tokens the drafter has not
/// yet consumed (`pending`, oldest first; the last element is always
/// the newest committed token). The verifier's slot is the sequence's
/// ordinary KV slot — the two caches are never copied into each other.
pub struct DraftState {
    /// The drafter's own KV slot.
    pub kv: SeqId,
    pending: Vec<i32>,
}

impl DraftState {
    /// State for a freshly prefetched sequence: the drafter has seen the
    /// prompt (its own prefill), and `first_token` — the verifier's
    /// first committed token — is pending.
    pub fn new(kv: SeqId, first_token: i32) -> Self {
        DraftState { kv, pending: vec![first_token] }
    }

    /// Committed tokens the drafter has not yet consumed.
    pub fn pending(&self) -> &[i32] {
        &self.pending
    }
}

/// Outcome of one draft→verify→rollback round.
pub struct RoundOut {
    /// Tokens committed this round (1..=k+1): the accepted draft prefix
    /// plus one verifier token.
    pub committed: Vec<i32>,
    /// Drafts that matched the verifier.
    pub accepted: usize,
    /// Drafts proposed (`k` after clamping; 0 for a plain verified step).
    pub drafted: usize,
    /// Verifier-side activation stats (when requested) — full-precision
    /// activations for the online calibrator. Only present when every
    /// row of the verify window was a *committed* token (full
    /// acceptance, or a plain `k == 0` verified step): the norm taps
    /// aggregate over all rows, so a partially-rejected window would
    /// leak drafter-hallucinated activations into the calibrator — the
    /// same stats-pollution class the padding-row fix eliminated.
    pub stats: Option<Vec<crate::quant::ActStats>>,
    /// Wall time of the drafting phase (catch-up + proposals),
    /// microseconds on the caller's [`Clock`] — the server turns this
    /// into the round's `draft` trace span.
    pub draft_us: u64,
    /// Wall time of the verify + rollback phase, microseconds.
    pub verify_us: u64,
    /// Pool kernel time spent inside the drafting phase (drafter
    /// backend's [`crate::linalg::pool::WorkerPool::kernel_us`] delta),
    /// feeding the `Metrics` spec-draft kernel counter.
    pub draft_kernel_us: u64,
    /// Pool kernel time spent inside the verify + rollback phase.
    pub verify_kernel_us: u64,
}

// ---------------------------------------------------------------------
// The round
// ---------------------------------------------------------------------

/// One speculative round for one sequence.
///
/// Draft `k` tokens with the drafter (catching up on `pending` first,
/// in a single multi-token cached forward), verify all `k+1` positions
/// with one [`ExecBackend::verify_step`] on the verifier, commit the
/// longest matching prefix plus one verifier token, and roll both
/// caches back to the first rejection.
///
/// `k` is clamped to the verifier's cache room; at `k == 0` the round
/// degenerates to a plain verified decode step (1 committed token).
#[allow(clippy::too_many_arguments)]
pub fn spec_round(
    drafter: &SpecModel,
    dcache: &mut KvCache,
    draft: &mut DraftState,
    verifier: &SpecModel,
    vcache: &mut KvCache,
    vid: SeqId,
    k: usize,
    sampler: &mut Sampler,
    with_stats: bool,
    clock: &Clock,
) -> Result<RoundOut> {
    let vocab = verifier.weights.manifest.config.vocab;
    let room = vcache.remaining(vid);
    if room == 0 {
        bail!("speculative round with no verifier cache room");
    }
    // k+1 rows go into the verifier cache this round
    let k = k.min(room - 1);

    // -- draft: catch up on pending tokens, then propose k tokens -----
    // Kernel-time deltas are read off each role's pool so `Metrics` can
    // split pool time into spec-draft vs spec-verify; the attached
    // profiler (when any) gets the matching phase gauge so per-site
    // attribution lands in the right phase too.
    let dpool = drafter.backend.worker_pool();
    let vpool = verifier.backend.worker_pool();
    if let Some(prof) = dpool.as_ref().and_then(|p| p.profiler()) {
        prof.set_phase(crate::obs::Phase::SpecDraft);
    }
    let t0_us = clock.now_us();
    let dkern0 = dpool.as_ref().map_or(0, |p| p.kernel_us());
    let mut drafts: Vec<i32> = Vec::with_capacity(k);
    if k > 0 {
        debug_assert!(!draft.pending.is_empty(), "speculative sequence with empty pending");
        let p = draft.pending.len();
        let out = drafter
            .backend
            .verify_step(drafter.weights, &draft.pending, dcache, &[draft.kv], false)?;
        let mut tok = argmax(&out.logits[(p - 1) * vocab..p * vocab]) as i32;
        drafts.push(tok);
        for _ in 1..k {
            let out = drafter
                .backend
                .decode_step(drafter.weights, &[tok], dcache, &[draft.kv], false)?;
            tok = argmax(&out.logits) as i32;
            drafts.push(tok);
        }
    }

    let t1_us = clock.now_us();
    let draft_kernel_us =
        dpool.as_ref().map_or(0, |p| p.kernel_us()).saturating_sub(dkern0);
    if let Some(prof) = vpool.as_ref().and_then(|p| p.profiler()) {
        prof.set_phase(crate::obs::Phase::SpecVerify);
    }
    let vkern0 = vpool.as_ref().map_or(0, |p| p.kernel_us());

    // -- verify: one cached forward over [last, d₁..d_k] ---------------
    let mut vtokens = Vec::with_capacity(k + 1);
    vtokens.push(*draft.pending.last().ok_or(SpecError::EmptyPending)?);
    vtokens.extend_from_slice(&drafts);
    let out = verifier
        .backend
        .verify_step(verifier.weights, &vtokens, vcache, &[vid], with_stats)?;

    // -- accept the longest matching prefix ----------------------------
    // Exactly one sampler draw per committed token, in order: a draft
    // is accepted only when it equals the token the sampler picks from
    // the verifier's logits at that position, so the committed stream
    // is what plain generation with this sampler would have produced.
    let mut committed = Vec::with_capacity(k + 1);
    let mut accepted = 0usize;
    for i in 0..=k {
        let tok = sampler.sample(&out.logits[i * vocab..(i + 1) * vocab]) as i32;
        committed.push(tok);
        if i < k && drafts[i] == tok {
            accepted += 1;
        } else {
            break;
        }
    }

    // -- rollback to the first rejection -------------------------------
    let c = committed.len(); // accepted + 1
    let vlen = vcache.len(vid);
    vcache.truncate(vid, vlen - (k + 1) + c)?;
    if k > 0 {
        // the drafter cached [pending…, d₁..d_{k-1}]; keep only the
        // accepted drafts (d_k was proposed but never cached)
        let base = dcache.len(draft.kv) - (k - 1);
        let keep = accepted.min(k - 1);
        dcache.truncate(draft.kv, base + keep)?;
        draft.pending = committed[keep..].to_vec();
    } else {
        // plain verified step: the drafter just falls further behind
        draft.pending.extend_from_slice(&committed);
    }

    // stats purity: the tap aggregated over all k+1 rows, so they are
    // only safe to report when every row was committed (see RoundOut)
    let stats = if accepted == k { out.stats } else { None };
    let t2_us = clock.now_us();
    let verify_kernel_us =
        vpool.as_ref().map_or(0, |p| p.kernel_us()).saturating_sub(vkern0);
    Ok(RoundOut {
        committed,
        accepted,
        drafted: k,
        stats,
        draft_us: t1_us.saturating_sub(t0_us),
        verify_us: t2_us.saturating_sub(t1_us),
        draft_kernel_us,
        verify_kernel_us,
    })
}

// ---------------------------------------------------------------------
// Standalone generator (eval / bench / golden tests)
// ---------------------------------------------------------------------

/// Aggregate speculative statistics over one generation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecStats {
    /// Draft→verify→rollback rounds run.
    pub rounds: usize,
    /// Tokens the drafter proposed.
    pub drafted: usize,
    /// Proposals the verifier accepted.
    pub accepted: usize,
}

impl SpecStats {
    /// Fraction of drafted tokens the verifier accepted.
    pub fn acceptance(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Self-contained drafter/verifier pair for one-shot generations — the
/// serving loop drives [`spec_round`] directly against its own caches
/// instead.
pub struct SpecGenerator<'a> {
    drafter: SpecModel<'a>,
    verifier: SpecModel<'a>,
    ctrl: SpecController,
    clock: Clock,
}

impl<'a> SpecGenerator<'a> {
    /// Pair a drafter with a verifier (their manifests must agree).
    pub fn new(drafter: SpecModel<'a>, verifier: SpecModel<'a>, cfg: &SpecConfig) -> Result<Self> {
        let dm = &drafter.weights.manifest;
        let vm = &verifier.weights.manifest;
        if dm.config.vocab != vm.config.vocab
            || dm.config.n_layers != vm.config.n_layers
            || dm.config.max_seq != vm.config.max_seq
        {
            bail!("drafter and verifier manifests disagree — self-speculation needs one model");
        }
        Ok(SpecGenerator {
            drafter,
            verifier,
            ctrl: SpecController::new(cfg),
            clock: Clock::real(),
        })
    }

    /// The adaptive-k controller (read access for diagnostics/tests).
    pub fn controller(&self) -> &SpecController {
        &self.ctrl
    }

    /// Speculative generation: token-identical to
    /// [`crate::eval::Evaluator::generate_with`] on the verifier
    /// weights, with the drafter only accelerating. Returns the
    /// generated suffix plus acceptance statistics.
    pub fn generate(
        &mut self,
        prompt: &[i32],
        max_new_tokens: usize,
        eos: Option<i32>,
        sampler: &mut Sampler,
    ) -> Result<(Vec<i32>, SpecStats)> {
        let man = &self.verifier.weights.manifest;
        if prompt.is_empty() || prompt.len() > man.config.max_seq {
            return Err(anyhow!(
                "prompt must be 1..={} tokens, got {}",
                man.config.max_seq,
                prompt.len()
            ));
        }
        let mut vcache = KvCache::new(KvCacheConfig::from_manifest(man, 1));
        let vid = vcache.alloc().ok_or(SpecError::CacheSlotUnavailable)?;
        let mut dcache = KvCache::new(KvCacheConfig::from_manifest(man, 1));
        let did = dcache.alloc().ok_or(SpecError::CacheSlotUnavailable)?;

        // dual prefill: each role builds its own KV state for the prompt
        let step = self
            .verifier
            .backend
            .prefill(self.verifier.weights, prompt, &mut vcache, &[vid], false)?;
        self.drafter
            .backend
            .prefill(self.drafter.weights, prompt, &mut dcache, &[did], false)?;

        let first = sampler.sample(&step.logits) as i32;
        let mut out = vec![first];
        let mut draft = DraftState::new(did, first);
        let mut stats = SpecStats::default();
        'outer: while out.len() < max_new_tokens
            && out.last() != eos.as_ref()
            && vcache.remaining(vid) > 0
        {
            // never commit past the generation budget
            let budget = max_new_tokens - out.len();
            let k = self.ctrl.k().min(budget.saturating_sub(1));
            let r = spec_round(
                &self.drafter,
                &mut dcache,
                &mut draft,
                &self.verifier,
                &mut vcache,
                vid,
                k,
                sampler,
                false,
                &self.clock,
            )?;
            self.ctrl.observe(r.accepted, r.drafted);
            stats.rounds += 1;
            stats.drafted += r.drafted;
            stats.accepted += r.accepted;
            for &tok in &r.committed {
                out.push(tok);
                if eos == Some(tok) {
                    break 'outer;
                }
            }
        }
        Ok((out, stats))
    }
}

/// Quantize a standalone drafter copy of `weights` with a registry
/// method — the offline analogue of what the serving loop's calibrator
/// maintains online. Diagonal methods get a uniform activation diagonal
/// (no calibration traffic has been seen yet); correlation methods are
/// rejected (no corr pass on this path).
pub fn drafter_weights(
    weights: &ModelWeights,
    method: &MethodSpec,
    spec: &QuantSpec,
) -> Result<ModelWeights> {
    if method.needs_corr() {
        bail!(
            "method {} needs the full correlation — unsupported as a drafter",
            method.label()
        );
    }
    let mut out = weights.fork();
    let rank = method.quantizer().lowrank_rank();
    for lin in &weights.manifest.linears {
        let w = weights
            .get(&lin.name)
            .ok_or_else(|| anyhow!("linear '{}' missing from weights", lin.name))?;
        let lowrank = (rank > 0).then(|| lowrank_init(w, rank));
        let uniform = vec![1.0f32; lin.d_in];
        let mut stats = match method.requirement() {
            StatsRequirement::None => LayerStats::default(),
            _ => LayerStats::from_diag(&uniform),
        };
        stats.lowrank = lowrank.as_ref();
        let wq = method.quantizer().quantize(w, &stats, spec)?;
        out.set(&lin.name, wq);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_tracks_and_resets() {
        let mut e = AcceptanceEwma::new(0.5);
        assert!((e.rate() - 1.0).abs() < 1e-12, "optimistic before data");
        e.observe(4, 4);
        assert!((e.rate() - 1.0).abs() < 1e-12);
        e.observe(0, 4);
        assert!((e.rate() - 0.5).abs() < 1e-12);
        e.observe(0, 0); // no drafts → no update
        assert!((e.rate() - 0.5).abs() < 1e-12);
        e.reset();
        assert!((e.rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn controller_widens_on_acceptance_and_narrows_on_rejection() {
        let mut c = SpecController::new(&SpecConfig::new(4));
        assert_eq!(c.k(), 4);
        for _ in 0..10 {
            c.observe(4, 4);
        }
        assert_eq!(c.k(), 8, "sustained acceptance must widen k to the cap");
        for _ in 0..20 {
            c.observe(0, 8);
        }
        assert_eq!(c.k(), 1, "sustained rejection must narrow k to the floor");
        c.reset();
        assert_eq!(c.k(), 4);
        assert!((c.acceptance() - 1.0).abs() < 1e-12, "reset clears the EWMA");
    }

    #[test]
    fn fixed_k_ignores_acceptance() {
        let mut c = SpecController::new(&SpecConfig::new(3).with_adaptive(false));
        for _ in 0..10 {
            c.observe(0, 3);
        }
        assert_eq!(c.k(), 3);
        assert!(c.acceptance() < 0.1, "EWMA still tracks under fixed k");
    }

    #[test]
    fn spec_config_defaults() {
        let c = SpecConfig::default();
        assert_eq!(c.k, 4);
        assert!(c.adaptive);
        assert_eq!(c.method.quantizer().name(), "rtn");
        let c = SpecConfig::new(0);
        assert_eq!(c.k, 1, "draft depth floor");
    }
}
