//! Synchronization shim: `std::sync` normally, the in-tree model
//! checker under `--cfg loom`.
//!
//! Concurrency-bearing modules (`linalg::pool`, `backend::native`)
//! import their primitives from here instead of `std::sync` (enforced
//! by `repo-lint` rule R4). A stable build re-exports the `std` types
//! unchanged — zero overhead, identical semantics. Building the crate
//! with `RUSTFLAGS="--cfg loom"` swaps in the instrumented equivalents
//! from [`model`], which lets `rust/tests/loom_pool.rs` explore every
//! bounded interleaving of the pool's dispatch protocol.
//!
//! Notes on coverage:
//!
//! * `Arc` and `OnceLock` are re-exported from `std` in both modes.
//!   `Arc` is pure refcounting (no protocol to model); `OnceLock` is
//!   used only for lazy one-time pool construction in
//!   `backend::native`, which the loom models construct eagerly.
//! * `thread::spawn_named` / `thread::parallelism` wrap the `std`
//!   spawn API so the loom build can substitute scheduler-controlled
//!   model threads.

#![forbid(unsafe_code)]

pub mod model;

#[cfg(not(loom))]
pub use std::sync::{Condvar, LockResult, Mutex, MutexGuard, PoisonError};

#[cfg(loom)]
pub use model::{Condvar, LockResult, Mutex, MutexGuard};

#[cfg(loom)]
pub use std::sync::PoisonError;

pub use std::sync::{Arc, OnceLock};

/// Atomic types (instrumented under `--cfg loom`); `Ordering` is always
/// the `std` enum.
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicU64, AtomicUsize};

    #[cfg(loom)]
    pub use super::model::{AtomicU64, AtomicUsize};
}

/// Thread spawn/join (scheduler-controlled model threads under
/// `--cfg loom`).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::JoinHandle;

    #[cfg(loom)]
    pub use super::model::JoinHandle;

    /// Spawn a named OS thread (the only sanctioned spawn site outside
    /// the retained `bench::throughput` scoped baseline — repo-lint R1).
    #[cfg(not(loom))]
    #[allow(clippy::disallowed_methods)]
    pub fn spawn_named<F, T>(name: &str, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("spawn named thread")
    }

    #[cfg(loom)]
    pub use super::model::spawn_named;

    /// Hardware parallelism (fixed at 4 under `--cfg loom` so model
    /// explorations are machine-independent).
    #[cfg(not(loom))]
    pub fn parallelism() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Hardware parallelism (fixed at 4 under `--cfg loom` so model
    /// explorations are machine-independent).
    #[cfg(loom)]
    pub fn parallelism() -> usize {
        4
    }
}
