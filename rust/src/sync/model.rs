//! Bounded-exhaustive interleaving model checker (in-tree mini-`loom`).
//!
//! The build environment has no network access, so the real `loom`
//! crate cannot be a dependency. This module implements the same idea
//! at the scale the pool protocol needs: every synchronization
//! primitive is instrumented so a controller decides, at each visible
//! operation, which thread runs next; a depth-first search then replays
//! the program under every schedule (up to a preemption bound), and any
//! panic, deadlock or livelock in any schedule is reported together
//! with how many schedules were explored.
//!
//! Scope and fidelity (limits are mirrored in `docs/CONCURRENCY.md`):
//!
//! * **Sequentially consistent memory model.** Instrumented atomics
//!   ignore the requested `Ordering` and execute `SeqCst`; the checker
//!   explores thread interleavings, not weak-memory reorderings. The
//!   `Ordering::Relaxed` arguments in `linalg::pool` are justified by
//!   comments at each site, not by this checker.
//! * **`notify_one` is modeled as `notify_all`.** Condvars permit
//!   spurious wakeups, so waking more waiters than requested is an
//!   over-approximation that every correct caller already tolerates.
//! * **Yield points** sit at every instrumented operation (mutex
//!   acquire, condvar wait/notify, atomic access, join); plain memory
//!   accesses between them run uninstrumented, under the mutual
//!   exclusion the model enforces.
//!
//! The checker is always compiled and self-tested (stable `cargo test`
//! runs the seeded-bug tests below), while `--cfg loom` additionally
//! switches [`crate::sync`] so `linalg::pool` itself runs on these
//! primitives; `rust/tests/loom_pool.rs` holds the pool models.

use std::any::Any;
use std::cell::RefCell;
use std::panic::{catch_unwind, panic_any, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::thread as real_thread;
use std::time::{Duration, Instant};

/// `std`-shaped lock result; the model mutex never actually poisons.
pub type LockResult<T> = std::sync::LockResult<T>;

/// Panic payload used to unwind model threads when an iteration aborts
/// (deadlock / step cap / panic elsewhere). Never observed by user code
/// unless a kernel closure itself performs instrumented operations.
struct AbortToken;

/// Scheduling state of one model thread.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Ts {
    /// Can be chosen by the scheduler.
    Runnable,
    /// Blocked acquiring model mutex `id`.
    Mutex(usize),
    /// Blocked waiting on model condvar `id`.
    Cond(usize),
    /// Blocked joining model thread `tid`.
    Join(usize),
    /// Exited (result stored in its join slot).
    Finished,
}

/// Why an exploration stopped at a failing schedule.
#[derive(Clone, Debug)]
pub enum Failure {
    /// A model thread panicked (message extracted from the payload).
    Panic(String),
    /// No thread was runnable while some were still alive; the string
    /// lists every thread's blocked state.
    Deadlock(String),
    /// One schedule exceeded the per-schedule step cap (livelock guard).
    StepCap,
    /// The wall-clock watchdog fired — a checker or model bug left the
    /// iteration stuck; reported instead of hanging the test harness.
    Watchdog,
}

/// Outcome of [`Model::try_check`].
#[derive(Clone, Debug)]
pub struct Report {
    /// Schedules executed (including the failing one, if any).
    pub schedules: usize,
    /// True when the schedule space was exhausted under the bounds.
    pub complete: bool,
    /// First failing schedule's diagnosis, if one was found.
    pub failure: Option<Failure>,
}

/// Mutable scheduler state, guarded by the controller's one real mutex.
struct Ctl {
    states: Vec<Ts>,
    names: Vec<String>,
    /// Thread currently holding the token (`usize::MAX` = none; set on
    /// completion or abort).
    cur: usize,
    /// Replay prefix: decision indices from a previous run.
    prefix: Vec<usize>,
    /// (chosen candidate index, candidate count) per decision point.
    trace: Vec<(usize, usize)>,
    /// Next decision index (cursor into `prefix` / `trace`).
    step: usize,
    preemptions: usize,
    /// Locked flag per registered model mutex.
    mutexes: Vec<bool>,
    n_condvars: usize,
    abort: bool,
    failure: Option<Failure>,
    /// OS handles of model-spawned threads, joined by the orchestrator.
    real: Vec<real_thread::JoinHandle<()>>,
    /// Model threads not yet finished.
    live: usize,
}

/// One exploration iteration's scheduler: a single mutex + condvar pair
/// implementing cooperative token passing over real OS threads.
struct Controller {
    ctl: StdMutex<Ctl>,
    cv: StdCondvar,
    preemption_bound: usize,
    max_steps: usize,
}

thread_local! {
    /// (controller, thread id) of the model context this OS thread runs
    /// in, if any. Installed by `run_once` / `spawn_named`.
    static CTX: RefCell<Option<(Arc<Controller>, usize)>> = const { RefCell::new(None) };
}

fn ctx() -> (Arc<Controller>, usize) {
    CTX.with(|c| c.borrow().clone())
        .expect("model primitive used outside Model::check")
}

fn in_model() -> bool {
    CTX.try_with(|c| c.borrow().is_some()).unwrap_or(false)
}

/// Silence the default panic-hook backtrace for panics raised on model
/// threads: aborts and seeded-bug panics fire on most explored
/// schedules and would flood stderr. Installed once per process;
/// non-model threads keep the previous hook's behavior.
fn install_quiet_hook() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !in_model() {
                prev(info);
            }
        }));
    });
}

fn payload_msg(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Unwind out of a model thread after an abort. During an active panic
/// a second panic would abort the process, so the caller falls through
/// to a degraded, scheduler-free path instead (everything is unwinding
/// by then; real mutexes still provide mutual exclusion).
fn abort_exit() {
    if !real_thread::panicking() {
        panic_any(AbortToken);
    }
}

impl Controller {
    fn lock_ctl(&self) -> StdMutexGuard<'_, Ctl> {
        self.ctl.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn fail(&self, ctl: &mut Ctl, f: Failure) {
        ctl.abort = true;
        if ctl.failure.is_none() {
            ctl.failure = Some(f);
        }
        ctl.cur = usize::MAX;
    }

    /// Pick the next thread to run. Called with the token effectively
    /// held by `ctl.cur` (which may have just blocked or finished).
    /// Candidate order is deterministic — continue-current first, then
    /// ascending ids — so a recorded decision index replays exactly.
    fn pick_next(&self, ctl: &mut Ctl) {
        let me = ctl.cur;
        let me_runnable = me != usize::MAX && ctl.states[me] == Ts::Runnable;
        let mut cands: Vec<usize> = Vec::new();
        if me_runnable {
            cands.push(me);
        }
        for (i, s) in ctl.states.iter().enumerate() {
            if i != me && *s == Ts::Runnable {
                cands.push(i);
            }
        }
        if cands.is_empty() {
            if ctl.live > 0 {
                let desc = ctl
                    .names
                    .iter()
                    .zip(ctl.states.iter())
                    .map(|(n, s)| format!("{n}={s:?}"))
                    .collect::<Vec<_>>()
                    .join(", ");
                self.fail(ctl, Failure::Deadlock(desc));
            } else {
                ctl.cur = usize::MAX;
            }
            return;
        }
        // CHESS-style preemption bounding: switching away from a still-
        // runnable thread costs budget; once spent, it must continue.
        let n = if me_runnable && ctl.preemptions >= self.preemption_bound {
            1
        } else {
            cands.len()
        };
        let idx = if ctl.step < ctl.prefix.len() {
            ctl.prefix[ctl.step].min(n - 1)
        } else {
            0
        };
        ctl.trace.push((idx, n));
        ctl.step += 1;
        if ctl.step > self.max_steps {
            self.fail(ctl, Failure::StepCap);
            return;
        }
        let chosen = cands[idx];
        if me_runnable && chosen != me {
            ctl.preemptions += 1;
        }
        ctl.cur = chosen;
    }

    /// The single yield/block primitive. Runs `mark` (which may flip
    /// this thread to a blocked state and update shared model state)
    /// atomically with the scheduling decision, then waits until this
    /// thread is runnable and holds the token again. Returns `true` if
    /// the iteration aborted while waiting.
    fn block_on<F: FnOnce(&mut Ctl)>(&self, me: usize, mark: F) -> bool {
        let mut ctl = self.lock_ctl();
        if ctl.abort {
            return true;
        }
        mark(&mut ctl);
        self.pick_next(&mut ctl);
        self.cv.notify_all();
        while !ctl.abort && !(ctl.cur == me && ctl.states[me] == Ts::Runnable) {
            ctl = self.cv.wait(ctl).unwrap_or_else(|e| e.into_inner());
        }
        ctl.abort
    }

    /// Wait for the first token grant (used by freshly spawned threads,
    /// which must not make a scheduling decision of their own).
    fn wait_for_token(&self, me: usize) -> bool {
        let mut ctl = self.lock_ctl();
        while !ctl.abort && !(ctl.cur == me && ctl.states[me] == Ts::Runnable) {
            ctl = self.cv.wait(ctl).unwrap_or_else(|e| e.into_inner());
        }
        ctl.abort
    }

    /// Record a user panic (a real bug found on this schedule) and
    /// abort the iteration.
    fn fail_panic(&self, p: &(dyn Any + Send)) {
        let msg = payload_msg(p);
        let mut ctl = self.lock_ctl();
        self.fail(&mut ctl, Failure::Panic(msg));
        self.cv.notify_all();
    }

    /// Mark thread `tid` finished, wake its joiners, and pass the token
    /// on if it held one.
    fn finish(&self, tid: usize) {
        let mut ctl = self.lock_ctl();
        ctl.states[tid] = Ts::Finished;
        ctl.live -= 1;
        for s in ctl.states.iter_mut() {
            if *s == Ts::Join(tid) {
                *s = Ts::Runnable;
            }
        }
        if !ctl.abort && ctl.cur == tid {
            self.pick_next(&mut ctl);
        }
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Instrumented primitives (API-compatible with the std::sync subset the
// pool uses; swapped in for it by `crate::sync` under `--cfg loom`).
// ---------------------------------------------------------------------------

/// Model mutex: mutual exclusion is enforced by the scheduler (blocked
/// threads are descheduled until unlock); the inner real mutex is never
/// contended and only carries the data + happens-before.
pub struct Mutex<T> {
    ctrl: Arc<Controller>,
    id: usize,
    cell: StdMutex<T>,
}

/// Guard for [`Mutex`]. Dropping it releases the model lock and wakes
/// blocked acquirers; the drop never panics and is not a yield point,
/// so it is safe to run during unwinding.
pub struct MutexGuard<'a, T> {
    mx: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
}

impl<T> Mutex<T> {
    /// New model mutex, registered with the current model iteration.
    /// Panics outside [`Model::check`].
    pub fn new(value: T) -> Self {
        let (ctrl, _me) = ctx();
        let id = {
            let mut ctl = ctrl.lock_ctl();
            ctl.mutexes.push(false);
            ctl.mutexes.len() - 1
        };
        Mutex { ctrl, id, cell: StdMutex::new(value) }
    }

    /// Acquire. Blocking, scheduling-aware; always returns `Ok` (the
    /// model mutex does not poison — `linalg::pool` recovers from
    /// poisoning via `into_inner` anyway, so both modes behave alike).
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        Ok(self.acquire())
    }

    fn acquire(&self) -> MutexGuard<'_, T> {
        let (ctrl, me) = ctx();
        loop {
            // Decision point before the acquire attempt.
            if ctrl.block_on(me, |_| {}) {
                abort_exit();
                break; // degraded: fall through to the real lock
            }
            // Token held: no other thread can run between this check
            // and the block below, so check-then-act is atomic.
            let mut ctl = ctrl.lock_ctl();
            if !ctl.mutexes[self.id] {
                ctl.mutexes[self.id] = true;
                drop(ctl);
                break;
            }
            drop(ctl);
            let aborted = ctrl.block_on(me, |ctl| {
                if ctl.mutexes[self.id] {
                    ctl.states[me] = Ts::Mutex(self.id);
                }
            });
            if aborted {
                abort_exit();
                break;
            }
            // Woken by an unlock: retry (another thread may have barged
            // in first — the DFS explores both winners).
        }
        let inner = self.cell.lock().unwrap_or_else(|e| e.into_inner());
        MutexGuard { mx: self, inner: Some(inner) }
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard holds the lock")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard holds the lock")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the data lock first, then the model lock. No yields,
        // no panics: this must be safe mid-unwind.
        self.inner = None;
        let mut ctl = self.mx.ctrl.lock_ctl();
        ctl.mutexes[self.mx.id] = false;
        let id = self.mx.id;
        for s in ctl.states.iter_mut() {
            if *s == Ts::Mutex(id) {
                *s = Ts::Runnable;
            }
        }
    }
}

/// Model condvar. `wait` atomically releases the mutex and deschedules;
/// `notify_one` wakes all waiters (a legal spurious-wakeup
/// over-approximation — see the module docs).
pub struct Condvar {
    id: usize,
}

impl Condvar {
    /// New model condvar, registered with the current model iteration.
    /// Panics outside [`Model::check`].
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        let (ctrl, _me) = ctx();
        let mut ctl = ctrl.lock_ctl();
        ctl.n_condvars += 1;
        Condvar { id: ctl.n_condvars - 1 }
    }

    /// Release `guard`'s mutex, deschedule until a notify, reacquire.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let (ctrl, me) = ctx();
        let mx: &'a Mutex<T> = guard.mx;
        {
            // Manual release: drop the real guard, skip the model
            // release in Drop (done under the scheduler lock below so
            // release + deschedule are one atomic decision).
            let mut g = guard;
            g.inner = None;
            std::mem::forget(g);
        }
        let aborted = ctrl.block_on(me, |ctl| {
            ctl.mutexes[mx.id] = false;
            let id = mx.id;
            for s in ctl.states.iter_mut() {
                if *s == Ts::Mutex(id) {
                    *s = Ts::Runnable;
                }
            }
            ctl.states[me] = Ts::Cond(self.id);
        });
        if aborted {
            abort_exit();
        }
        Ok(mx.acquire())
    }

    /// Wake every thread waiting on this condvar.
    pub fn notify_all(&self) {
        let (ctrl, me) = ctx();
        if ctrl.block_on(me, |ctl| {
            for s in ctl.states.iter_mut() {
                if *s == Ts::Cond(self.id) {
                    *s = Ts::Runnable;
                }
            }
        }) {
            abort_exit();
        }
    }

    /// Modeled as [`Condvar::notify_all`] (see module docs).
    pub fn notify_one(&self) {
        self.notify_all();
    }
}

macro_rules! model_atomic {
    ($name:ident, $std:ident, $prim:ty) => {
        /// Instrumented atomic: every access is a yield point and runs
        /// `SeqCst` regardless of the ordering argument (the checker
        /// explores interleavings, not weak-memory reorderings).
        pub struct $name {
            cell: std::sync::atomic::$std,
        }

        impl $name {
            /// New atomic with the given initial value.
            pub fn new(v: $prim) -> Self {
                $name { cell: std::sync::atomic::$std::new(v) }
            }

            /// Instrumented load (`_order` ignored; SeqCst).
            pub fn load(&self, _order: Ordering) -> $prim {
                let (ctrl, me) = ctx();
                if ctrl.block_on(me, |_| {}) {
                    abort_exit();
                }
                self.cell.load(Ordering::SeqCst)
            }

            /// Instrumented store (`_order` ignored; SeqCst).
            pub fn store(&self, v: $prim, _order: Ordering) {
                let (ctrl, me) = ctx();
                if ctrl.block_on(me, |_| {}) {
                    abort_exit();
                }
                self.cell.store(v, Ordering::SeqCst)
            }

            /// Instrumented fetch-add (`_order` ignored; SeqCst).
            pub fn fetch_add(&self, v: $prim, _order: Ordering) -> $prim {
                let (ctrl, me) = ctx();
                if ctrl.block_on(me, |_| {}) {
                    abort_exit();
                }
                self.cell.fetch_add(v, Ordering::SeqCst)
            }

            /// Instrumented compare-exchange (orderings ignored; SeqCst).
            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                _success: Ordering,
                _failure: Ordering,
            ) -> Result<$prim, $prim> {
                let (ctrl, me) = ctx();
                if ctrl.block_on(me, |_| {}) {
                    abort_exit();
                }
                self.cell.compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
            }
        }
    };
}

model_atomic!(AtomicUsize, AtomicUsize, usize);
model_atomic!(AtomicU64, AtomicU64, u64);

// ---------------------------------------------------------------------------
// Model threads.
// ---------------------------------------------------------------------------

type ResultSlot<T> = Arc<StdMutex<Option<real_thread::Result<T>>>>;

/// Handle for a model-spawned thread; `join` is scheduling-aware.
pub struct JoinHandle<T> {
    tid: usize,
    slot: ResultSlot<T>,
}

impl<T> JoinHandle<T> {
    /// Deschedule until the target thread finishes, then return its
    /// result (`Err` carries the panic payload, as with `std`).
    pub fn join(self) -> real_thread::Result<T> {
        let (ctrl, me) = ctx();
        loop {
            let aborted = ctrl.block_on(me, |ctl| {
                if ctl.states[self.tid] != Ts::Finished {
                    ctl.states[me] = Ts::Join(self.tid);
                }
            });
            if aborted {
                abort_exit();
                // Degraded: the abort wakes every model thread, so the
                // target's wrapper will fill the slot shortly; poll it.
                loop {
                    if let Some(r) = self.slot.lock().unwrap_or_else(|e| e.into_inner()).take() {
                        return r;
                    }
                    real_thread::yield_now();
                }
            }
            let done = ctrl.lock_ctl().states[self.tid] == Ts::Finished;
            if done {
                break;
            }
        }
        self.slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .expect("finished model thread stored its result")
    }
}

/// Spawn a named model thread. The OS thread is real; its execution is
/// serialized by the controller like every other model thread. Panics
/// outside [`Model::check`].
#[allow(clippy::disallowed_methods)] // the one sanctioned real-spawn site
pub fn spawn_named<F, T>(name: &str, f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let (ctrl, _me) = ctx();
    let tid = {
        let mut ctl = ctrl.lock_ctl();
        ctl.states.push(Ts::Runnable);
        ctl.names.push(name.to_string());
        ctl.live += 1;
        ctl.states.len() - 1
    };
    let slot: ResultSlot<T> = Arc::new(StdMutex::new(None));
    let slot2 = slot.clone();
    let c2 = ctrl.clone();
    let handle = real_thread::Builder::new()
        .name(name.to_string())
        .spawn(move || {
            CTX.with(|c| *c.borrow_mut() = Some((c2.clone(), tid)));
            let aborted = c2.wait_for_token(tid);
            let result: real_thread::Result<T> = if aborted {
                Err(Box::new(AbortToken))
            } else {
                catch_unwind(AssertUnwindSafe(f))
            };
            if let Err(p) = &result {
                if p.downcast_ref::<AbortToken>().is_none() {
                    c2.fail_panic(p.as_ref());
                }
            }
            *slot2.lock().unwrap_or_else(|e| e.into_inner()) = Some(result);
            c2.finish(tid);
        })
        .expect("spawn model thread");
    ctrl.lock_ctl().real.push(handle);
    JoinHandle { tid, slot }
}

// ---------------------------------------------------------------------------
// The exploration driver.
// ---------------------------------------------------------------------------

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Exploration bounds for one model. `Default` reads the
/// `TTQ_LOOM_PREEMPTIONS` / `TTQ_LOOM_MAX_SCHEDULES` /
/// `TTQ_LOOM_MAX_STEPS` environment overrides.
#[derive(Clone, Debug)]
pub struct Model {
    /// CHESS-style preemption budget per schedule (2 finds the vast
    /// majority of real concurrency bugs while keeping the space small).
    pub preemptions: usize,
    /// Cap on explored schedules; hitting it yields `complete: false`.
    pub max_schedules: usize,
    /// Per-schedule decision cap (livelock guard).
    pub max_steps: usize,
}

impl Default for Model {
    fn default() -> Self {
        Model {
            preemptions: env_usize("TTQ_LOOM_PREEMPTIONS", 2),
            max_schedules: env_usize("TTQ_LOOM_MAX_SCHEDULES", 20_000),
            max_steps: env_usize("TTQ_LOOM_MAX_STEPS", 20_000),
        }
    }
}

impl Model {
    /// Explore `f` under every schedule within the bounds; panic with
    /// the diagnosis if any schedule fails. The loom-style entry point.
    pub fn check<F: Fn() + Send + Sync>(&self, f: F) {
        let report = self.try_check(f);
        if let Some(fail) = &report.failure {
            panic!("model failed after {} schedule(s): {:?}", report.schedules, fail);
        }
    }

    /// Like [`Model::check`] but returns the [`Report`] instead of
    /// panicking — the self-tests use this to assert that seeded bugs
    /// ARE found.
    pub fn try_check<F: Fn() + Send + Sync>(&self, f: F) -> Report {
        install_quiet_hook();
        let mut prefix: Vec<usize> = Vec::new();
        let mut schedules = 0usize;
        loop {
            schedules += 1;
            let (trace, failure) = self.run_once(&f, &prefix);
            if failure.is_some() {
                return Report { schedules, complete: false, failure };
            }
            match next_prefix(&trace) {
                Some(p) => prefix = p,
                None => return Report { schedules, complete: true, failure: None },
            }
            if schedules >= self.max_schedules {
                return Report { schedules, complete: false, failure: None };
            }
        }
    }

    /// Run one schedule (replaying `prefix`, then first-choice greedy).
    #[allow(clippy::disallowed_methods)] // orchestrator's sanctioned scope
    fn run_once<F: Fn() + Send + Sync>(
        &self,
        f: &F,
        prefix: &[usize],
    ) -> (Vec<(usize, usize)>, Option<Failure>) {
        let ctrl = Arc::new(Controller {
            ctl: StdMutex::new(Ctl {
                states: vec![Ts::Runnable],
                names: vec!["main".to_string()],
                cur: 0,
                prefix: prefix.to_vec(),
                trace: Vec::new(),
                step: 0,
                preemptions: 0,
                mutexes: Vec::new(),
                n_condvars: 0,
                abort: false,
                failure: None,
                real: Vec::new(),
                live: 1,
            }),
            cv: StdCondvar::new(),
            preemption_bound: self.preemptions,
            max_steps: self.max_steps,
        });
        let watchdog = Duration::from_secs(env_usize("TTQ_LOOM_WATCHDOG_SECS", 60) as u64);
        real_thread::scope(|s| {
            let c2 = ctrl.clone();
            s.spawn(move || {
                CTX.with(|c| *c.borrow_mut() = Some((c2.clone(), 0)));
                // Thread 0 starts holding the token: run f directly.
                let result = catch_unwind(AssertUnwindSafe(f));
                if let Err(p) = &result {
                    if p.downcast_ref::<AbortToken>().is_none() {
                        c2.fail_panic(p.as_ref());
                    }
                }
                c2.finish(0);
                CTX.with(|c| *c.borrow_mut() = None);
            });
            // Orchestrate: wait for every model thread to finish, with
            // a wall-clock watchdog so checker bugs fail instead of
            // hanging the harness; then reap the real OS threads.
            let deadline = Instant::now() + watchdog;
            let mut ctl = ctrl.lock_ctl();
            while ctl.live > 0 {
                let (g, timeout) = ctrl
                    .cv
                    .wait_timeout(ctl, Duration::from_millis(50))
                    .unwrap_or_else(|e| e.into_inner());
                ctl = g;
                if timeout.timed_out() && Instant::now() >= deadline && !ctl.abort {
                    ctrl.fail(&mut ctl, Failure::Watchdog);
                    ctrl.cv.notify_all();
                }
            }
            let handles = std::mem::take(&mut ctl.real);
            drop(ctl);
            for h in handles {
                let _ = h.join();
            }
        });
        let ctl = ctrl.lock_ctl();
        (ctl.trace.clone(), ctl.failure.clone())
    }
}

/// Depth-first successor of a completed schedule: flip the deepest
/// decision that still has an untried alternative.
fn next_prefix(trace: &[(usize, usize)]) -> Option<Vec<usize>> {
    for i in (0..trace.len()).rev() {
        let (chosen, n) = trace[i];
        if chosen + 1 < n {
            let mut p: Vec<usize> = trace[..i].iter().map(|t| t.0).collect();
            p.push(chosen + 1);
            return Some(p);
        }
    }
    None
}

/// Convenience entry point mirroring `loom::model`.
pub fn model<F: Fn() + Send + Sync>(f: F) {
    Model::default().check(f);
}

// The self-test suite seeds known concurrency bugs and asserts the
// checker FINDS them (and that correct protocols explore to
// completion). This is what makes the loom models trustworthy: a
// checker that cannot find a planted race would pass them vacuously.
#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Model {
        Model { preemptions: 2, max_schedules: 5_000, max_steps: 5_000 }
    }

    #[test]
    fn trivial_model_explores_one_schedule() {
        let r = small().try_check(|| {});
        assert!(r.failure.is_none());
        assert!(r.complete);
        assert_eq!(r.schedules, 1);
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns many short-lived threads; slow under miri")]
    fn finds_non_atomic_increment_race() {
        let r = small().try_check(|| {
            let a = Arc::new(AtomicUsize::new(0));
            let mk = |a: Arc<AtomicUsize>| {
                move || {
                    // Seeded bug: load/store instead of fetch_add.
                    let v = a.load(Ordering::SeqCst);
                    a.store(v + 1, Ordering::SeqCst);
                }
            };
            let t1 = spawn_named("inc-1", mk(a.clone()));
            let t2 = spawn_named("inc-2", mk(a.clone()));
            t1.join().expect("inc-1");
            t2.join().expect("inc-2");
            assert_eq!(a.load(Ordering::SeqCst), 2, "lost update");
        });
        match r.failure {
            Some(Failure::Panic(msg)) => {
                assert!(msg.contains("lost update"), "unexpected diagnosis: {msg}")
            }
            other => panic!("checker missed the seeded race: {other:?}"),
        }
        assert!(r.schedules > 1, "race needs schedule exploration to surface");
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns many short-lived threads; slow under miri")]
    fn finds_abba_deadlock() {
        let r = small().try_check(|| {
            let locks = Arc::new((Mutex::new(()), Mutex::new(())));
            let l1 = locks.clone();
            let a = spawn_named("abba-a", move || {
                let _g1 = l1.0.lock();
                let _g2 = l1.1.lock();
            });
            let l2 = locks.clone();
            let b = spawn_named("abba-b", move || {
                let _g2 = l2.1.lock();
                let _g1 = l2.0.lock();
            });
            let _ = a.join();
            let _ = b.join();
        });
        match r.failure {
            Some(Failure::Deadlock(desc)) => {
                assert!(desc.contains("abba-a"), "deadlock report names threads: {desc}")
            }
            other => panic!("checker missed the ABBA deadlock: {other:?}"),
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns many short-lived threads; slow under miri")]
    fn finds_lost_wakeup() {
        let r = small().try_check(|| {
            let flag = Arc::new(AtomicUsize::new(0));
            let pair = Arc::new((Mutex::new(()), Condvar::new()));
            let (f2, p2) = (flag.clone(), pair.clone());
            let waiter = spawn_named("waiter", move || {
                // Seeded bug: the flag check is OUTSIDE the mutex, so
                // the notify can fire between the check and the wait.
                if f2.load(Ordering::SeqCst) == 0 {
                    let g = p2.0.lock().unwrap_or_else(|e| e.into_inner());
                    let _g = p2.1.wait(g).unwrap_or_else(|e| e.into_inner());
                }
            });
            flag.store(1, Ordering::SeqCst);
            pair.1.notify_all();
            let _ = waiter.join();
        });
        match r.failure {
            Some(Failure::Deadlock(desc)) => {
                assert!(desc.contains("waiter"), "deadlock report names waiter: {desc}")
            }
            other => panic!("checker missed the lost wakeup: {other:?}"),
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns many short-lived threads; slow under miri")]
    fn correct_handshake_explores_to_completion() {
        let r = small().try_check(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = pair.clone();
            let waiter = spawn_named("ok-waiter", move || {
                let mut g = p2.0.lock().unwrap_or_else(|e| e.into_inner());
                while !*g {
                    g = p2.1.wait(g).unwrap_or_else(|e| e.into_inner());
                }
            });
            {
                let mut g = pair.0.lock().unwrap_or_else(|e| e.into_inner());
                *g = true;
            }
            pair.1.notify_all();
            waiter.join().expect("waiter completes");
        });
        assert!(r.failure.is_none(), "correct handshake must pass: {:?}", r.failure);
        assert!(r.complete, "schedule space should be exhausted");
        assert!(r.schedules > 1, "handshake has real interleavings");
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns many short-lived threads; slow under miri")]
    fn mutex_serializes_critical_sections() {
        let r = small().try_check(|| {
            let m = Arc::new(Mutex::new(0usize));
            let spin = Arc::new(AtomicUsize::new(0));
            let mk = |m: Arc<Mutex<usize>>, spin: Arc<AtomicUsize>| {
                move || {
                    let mut g = m.lock().unwrap_or_else(|e| e.into_inner());
                    let v = *g;
                    // Yield point mid-critical-section: the lock must
                    // still keep the read-modify-write atomic.
                    spin.fetch_add(1, Ordering::SeqCst);
                    *g = v + 1;
                }
            };
            let t1 = spawn_named("cs-1", mk(m.clone(), spin.clone()));
            let t2 = spawn_named("cs-2", mk(m.clone(), spin.clone()));
            t1.join().expect("cs-1");
            t2.join().expect("cs-2");
            assert_eq!(*m.lock().unwrap_or_else(|e| e.into_inner()), 2);
        });
        assert!(r.failure.is_none(), "mutex must serialize: {:?}", r.failure);
        assert!(r.complete);
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns many short-lived threads; slow under miri")]
    fn join_returns_thread_value() {
        let r = small().try_check(|| {
            let t = spawn_named("value", || 41usize + 1);
            assert_eq!(t.join().expect("no panic"), 42);
        });
        assert!(r.failure.is_none());
        assert!(r.complete);
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns many short-lived threads; slow under miri")]
    #[should_panic(expected = "model failed")]
    fn check_panics_on_seeded_failure() {
        small().check(|| {
            let a = Arc::new(AtomicUsize::new(0));
            let a2 = a.clone();
            let t = spawn_named("bug", move || {
                let v = a2.load(Ordering::SeqCst);
                a2.store(v + 1, Ordering::SeqCst);
            });
            let v = a.load(Ordering::SeqCst);
            a.store(v + 1, Ordering::SeqCst);
            t.join().expect("bug thread");
            assert_eq!(a.load(Ordering::SeqCst), 2);
        });
    }
}
