//! Measurement harness for the `benches/*` targets (offline stand-in
//! for criterion): warmup, wall-clock sampling, median/mean/p95, and a
//! throughput-aware report line. Deterministic iteration counts so CI
//! runs are comparable.

use std::time::{Duration, Instant};

/// Collected samples of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name (printed in the report line).
    pub name: String,
    /// Wall-clock per sample iteration.
    pub samples: Vec<Duration>,
    /// items (e.g. elements, tokens) processed per iteration
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Median sample.
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort_unstable();
        s[s.len() / 2]
    }

    /// Mean sample.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// 95th-percentile sample.
    pub fn p95(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort_unstable();
        let idx = ((s.len() as f64 * 0.95) as usize).min(s.len() - 1);
        s[idx]
    }

    /// Items per second at the median sample (when items were given).
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n / self.median().as_secs_f64())
    }

    /// One aligned report line (median/mean/p95 + throughput).
    pub fn report(&self) -> String {
        let med = self.median();
        let base = format!(
            "{:<44} median {:>10.3?}  mean {:>10.3?}  p95 {:>10.3?}",
            self.name,
            med,
            self.mean(),
            self.p95()
        );
        match self.throughput() {
            Some(t) if t >= 1e9 => format!("{base}  {:>8.2} G/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("{base}  {:>8.2} M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("{base}  {:>8.2} k/s", t / 1e3),
            Some(t) => format!("{base}  {t:>8.2} /s"),
            None => base,
        }
    }
}

/// Benchmark runner: measures `f` (which should perform one logical
/// iteration and return a value that is black-boxed).
pub struct Bencher {
    /// Untimed warmup iterations before sampling.
    pub warmup: usize,
    /// Timed samples collected.
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 3, samples: 15 }
    }
}

/// Opaque value sink preventing the optimizer from deleting the work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    /// Low-sample configuration for fast/CI runs.
    pub fn quick() -> Self {
        Bencher { warmup: 1, samples: 5 }
    }

    /// Measure `f` (one logical iteration per call), printing the report.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        self.run_items(name, None, &mut f)
    }

    /// [`Self::run`] with an items-per-iteration count for throughput.
    pub fn run_with_items<T>(
        &self,
        name: &str,
        items_per_iter: f64,
        mut f: impl FnMut() -> T,
    ) -> BenchResult {
        self.run_items(name, Some(items_per_iter), &mut f)
    }

    fn run_items<T>(
        &self,
        name: &str,
        items_per_iter: Option<f64>,
        f: &mut impl FnMut() -> T,
    ) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        let r = BenchResult { name: name.to_string(), samples, items_per_iter };
        println!("{}", r.report());
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let r = BenchResult {
            name: "t".into(),
            samples: (1..=10).map(Duration::from_millis).collect(),
            items_per_iter: None,
        };
        assert!(r.median() <= r.p95());
        assert_eq!(r.mean(), Duration::from_micros(5500));
    }

    #[test]
    fn throughput_computed() {
        let r = BenchResult {
            name: "t".into(),
            samples: vec![Duration::from_millis(10); 3],
            items_per_iter: Some(1000.0),
        };
        let t = r.throughput().unwrap();
        assert!((t - 100_000.0).abs() < 1.0);
    }

    #[test]
    fn runner_collects_samples() {
        let b = Bencher { warmup: 1, samples: 4 };
        let mut n = 0u64;
        let r = b.run("count", || {
            n += 1;
            n
        });
        assert_eq!(r.samples.len(), 4);
        assert_eq!(n, 5); // warmup + samples
    }
}
