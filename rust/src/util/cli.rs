//! Flag parser for the `ttq-serve` binary (offline stand-in for clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! values (`--models a b c`), and positional arguments.

use std::collections::BTreeMap;

/// Parsed command line: positionals + `--flag` values.
#[derive(Debug, Default)]
pub struct Args {
    /// Non-flag arguments, in order (subcommand first).
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse a raw argument list (without argv[0]).
    pub fn parse(raw: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        let mut current: Option<String> = None;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                    current = None;
                } else {
                    out.flags.entry(name.to_string()).or_default();
                    current = Some(name.to_string());
                }
            } else if let Some(k) = &current {
                out.flags.get_mut(k).unwrap().push(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    /// True when `--name` appeared (with or without a value).
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// First value of `--name`, if any.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .get(name)
            .and_then(|v| v.first())
            .map(String::as_str)
    }

    /// First value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` parsed as usize, or `default` (also on parse failure).
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// `--name` parsed as u32, or `default` (also on parse failure).
    pub fn get_u32(&self, name: &str, default: u32) -> u32 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// All values of a repeated flag (`--models a b c`).
    pub fn get_many(&self, name: &str) -> Vec<String> {
        self.flags.get(name).cloned().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("table 3 --fast --bits 4");
        assert_eq!(a.positional, vec!["table", "3"]);
        assert!(a.has("fast"));
        assert_eq!(a.get_u32("bits", 0), 4);
    }

    #[test]
    fn equals_form() {
        let a = parse("eval --model=qwen-mini --rank=16");
        assert_eq!(a.get("model"), Some("qwen-mini"));
        assert_eq!(a.get_usize("rank", 0), 16);
    }

    #[test]
    fn repeated_values() {
        let a = parse("table 3 --models opt-micro qwen-mini --fast");
        assert_eq!(a.get_many("models"), vec!["opt-micro", "qwen-mini"]);
        assert!(a.has("fast"));
        assert_eq!(a.positional, vec!["table", "3"]);
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.get_or("model", "qwen-micro"), "qwen-micro");
        assert_eq!(a.get_usize("requests", 64), 64);
        assert!(!a.has("fast"));
    }
}
