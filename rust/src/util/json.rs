//! Minimal JSON parser + writer (RFC 8259 subset sufficient for the
//! artifact manifests and golden fixtures: objects, arrays, strings,
//! f64 numbers, bools, null; `\uXXXX` escapes supported for parsing).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (key-sorted).
    Obj(BTreeMap<String, Value>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(s: &str) -> Result<Value, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the path (manifest loading).
    pub fn field(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key).ok_or(JsonError {
            msg: format!("missing field '{key}'"),
            pos: 0,
        })
    }

    /// The number, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    /// The string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize (numbers in shortest-roundtrip f64 form).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"name": "m", "config": {"d_model": 128, "eps": 1e-5},
                      "tensors": [{"name": "w", "shape": [2, 3], "offset": 0}],
                      "ok": true, "none": null}"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.field("name").unwrap().as_str(), Some("m"));
        assert_eq!(
            v.field("config").unwrap().field("d_model").unwrap().as_usize(),
            Some(128)
        );
        let t = &v.field("tensors").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = t
            .field("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![2, 3]);
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = Value::parse("[-1.5, 2e3, -4E-2, 0]").unwrap();
        let nums: Vec<f64> =
            v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(nums, vec![-1.5, 2000.0, -0.04, 0.0]);
    }

    #[test]
    fn string_escapes() {
        let v = Value::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,"x"],"b":{"c":true},"d":null}"#;
        let v = Value::parse(doc).unwrap();
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("[1] x").is_err());
        assert!(Value::parse("'single'").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse(r#""héllo ⊘""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo ⊘"));
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..50 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..50 {
            s.push(']');
        }
        assert!(Value::parse(&s).is_ok());
    }
}
