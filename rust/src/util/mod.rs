//! Dependency-free substrates.
//!
//! The build environment is offline with only the `xla` crate closure
//! vendored, so the reproduction implements its own:
//!
//! * [`json`] — JSON parser/serializer (manifests, golden fixtures).
//! * [`cli`] — flag parser for the `ttq-serve` binary.
//! * [`benchkit`] — measurement harness (warmup, sampling, stats) used
//!   by all `benches/*` targets.
//! * [`propcheck`] — property-based testing: seeded case generation
//!   with failure-case reporting and input shrinking.

#![forbid(unsafe_code)]

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod propcheck;

/// Index of the largest element, first occurrence winning ties (the
/// greedy-decode convention shared by the eval accuracy path and the
/// server's reply loop). Returns 0 for an empty slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[]), 0);
        // ties: first occurrence wins (strict > comparison)
        assert_eq!(argmax(&[2.0, 7.0, 7.0]), 1);
        // NaN never beats an existing max under strict >
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0]), 2);
    }
}
