//! Dependency-free substrates.
//!
//! The build environment is offline with only the `xla` crate closure
//! vendored, so the reproduction implements its own:
//!
//! * [`json`] — JSON parser/serializer (manifests, golden fixtures).
//! * [`cli`] — flag parser for the `ttq-serve` binary.
//! * [`benchkit`] — measurement harness (warmup, sampling, stats) used
//!   by all `benches/*` targets.
//! * [`propcheck`] — property-based testing: seeded case generation
//!   with failure-case reporting and input shrinking.

#![forbid(unsafe_code)]

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod propcheck;

/// Index of the largest element, first occurrence winning ties (the
/// greedy-decode convention shared by the eval accuracy path and the
/// server's reply loop). Returns 0 for an empty slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Numerically stable `ln Σᵢ exp(xᵢ)` over a logit row, accumulated in
/// `f64` after max-shifting — the one implementation shared by the
/// eval perplexity path ([`crate::backend`] NLL), the quality benches
/// and the online KL probe ([`crate::obs::quality`]). Returns
/// `f64::NEG_INFINITY` for an empty row (the sum over zero terms), and
/// stays finite whenever at least one input is finite (all-`-inf` rows
/// come back `-inf` rather than `NaN`).
pub fn logsumexp(row: &[f32]) -> f64 {
    let mut mx = f32::NEG_INFINITY;
    for &v in row {
        mx = mx.max(v);
    }
    if mx == f32::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    let mut z = 0.0f64;
    for &v in row {
        z += ((v - mx) as f64).exp();
    }
    z.ln() + mx as f64
}

/// Maximum cross-ISA divergence, in units-in-the-last-place, accepted
/// for fp32 kernels under the relaxed numerics contract
/// (`docs/ARCHITECTURE.md` § Kernel dispatch & numerics). Vector dots
/// re-associate one `K_TILE = 256`-element tile into 8 lane partials;
/// worst-case reassociation error grows with tile length, and 2·256
/// ULPs bounds it with margin on every shape the suites drive. W4
/// kernels do NOT use this — they are bit-exact across ISAs.
pub const FP32_MAX_ULPS: u32 = 512;

/// Absolute-difference floor paired with [`FP32_MAX_ULPS`]: near zero
/// (catastrophic cancellation) a tiny absolute error can be millions
/// of ULPs, so [`fp32_close`] also accepts `|a − b| ≤ FP32_ABS_TOL`.
pub const FP32_ABS_TOL: f32 = 1e-4;

/// Map an f32's bit pattern onto a signed line where adjacent
/// representable values differ by 1 (negative floats mirror below
/// zero), so ULP distance is an integer subtraction.
fn ulp_index(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        -((b & 0x7fff_ffff) as i64)
    } else {
        b as i64
    }
}

/// Units-in-the-last-place distance between two f32 values: 0 for
/// bitwise-equal values (and `0.0` vs `-0.0`), `u32::MAX` when either
/// is NaN, otherwise the number of representable floats between them
/// (saturating). `ulp_diff(1.0, next_up(1.0)) == 1`.
pub fn ulp_diff(a: f32, b: f32) -> u32 {
    if a == b {
        return 0; // covers +0.0 vs -0.0, which sit 0 apart numerically
    }
    if a.is_nan() || b.is_nan() {
        return u32::MAX;
    }
    let d = (ulp_index(a) - ulp_index(b)).unsigned_abs();
    d.min(u32::MAX as u64) as u32
}

/// Maximum [`ulp_diff`] over paired slices — the statistic the
/// differential SIMD suites report. Panics on length mismatch (a
/// harness bug, not a numerics result). Empty slices are 0 apart.
pub fn max_ulp_diff(a: &[f32], b: &[f32]) -> u32 {
    assert_eq!(a.len(), b.len(), "max_ulp_diff length mismatch");
    a.iter().zip(b).map(|(&x, &y)| ulp_diff(x, y)).max().unwrap_or(0)
}

/// The relaxed fp32 comparison every suite that steps down from
/// bit-identity uses: within [`FP32_MAX_ULPS`] ULPs *or* within
/// [`FP32_ABS_TOL`] absolutely. One definition, so the documented
/// contract and the asserted contract cannot drift apart.
pub fn fp32_close(a: f32, b: f32) -> bool {
    ulp_diff(a, b) <= FP32_MAX_ULPS || (a - b).abs() <= FP32_ABS_TOL
}

/// Assert two fp32 slices agree under [`fp32_close`], reporting the
/// worst offending index, values and ULP distance on failure. `what`
/// names the comparison in the panic message.
pub fn assert_fp32_slices_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            fp32_close(x, y),
            "{what}: index {i}: {x} vs {y} ({} ulps, abs {})",
            ulp_diff(x, y),
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::{argmax, logsumexp};
    use super::{assert_fp32_slices_close, fp32_close, max_ulp_diff, ulp_diff};

    #[test]
    fn ulp_diff_golden_cases() {
        // Hand-computed: 1.0 = 0x3f800000; its upward neighbor is one
        // bit pattern away.
        assert_eq!(ulp_diff(1.0, f32::from_bits(0x3f80_0001)), 1);
        // Equal values, including signed zeros, are 0 apart.
        assert_eq!(ulp_diff(0.0, -0.0), 0);
        assert_eq!(ulp_diff(3.25, 3.25), 0);
        // Doubling crosses one full exponent: 2^23 representable values.
        assert_eq!(ulp_diff(2.0, 1.0), 1 << 23);
        // Straddling zero counts the denormals on both sides: the two
        // smallest-magnitude denormals are 2 apart.
        assert_eq!(ulp_diff(f32::from_bits(0x8000_0001), f32::from_bits(0x0000_0001)), 2);
        // ±0 to the smallest denormal is exactly 1.
        assert_eq!(ulp_diff(0.0, f32::from_bits(0x0000_0001)), 1);
        // NaN never compares close.
        assert_eq!(ulp_diff(f32::NAN, 1.0), u32::MAX);
        assert_eq!(ulp_diff(f32::NAN, f32::NAN), u32::MAX);
        // Opposite-extreme finite inputs: 2 × (0x7f7fffff) counts every
        // representable value from −MAX to +MAX — fits in u32, no
        // saturation needed (hand-computed: 2 × 2139095039).
        assert_eq!(ulp_diff(f32::MAX, f32::MIN), 4_278_190_078);
    }

    #[test]
    fn max_ulp_diff_reports_worst_pair() {
        let a = [1.0f32, 2.0, 0.0];
        let b = [1.0f32, f32::from_bits(2.0f32.to_bits() + 3), -0.0];
        assert_eq!(max_ulp_diff(&a, &b), 3);
        assert_eq!(max_ulp_diff(&[], &[]), 0);
    }

    #[test]
    fn fp32_close_contract() {
        // Within the ULP bound. Base 1024.0 so one ULP (2^-13 ≈ 1.2e-4)
        // already exceeds the absolute floor — the ULP clause alone
        // decides both assertions (at 1.0, 513 ULPs ≈ 6e-5 would slip
        // under FP32_ABS_TOL and mask the boundary).
        let base = 1024.0f32;
        assert!(fp32_close(base, f32::from_bits(base.to_bits() + super::FP32_MAX_ULPS)));
        // Just beyond it (and beyond the absolute floor).
        assert!(!fp32_close(
            base,
            f32::from_bits(base.to_bits() + super::FP32_MAX_ULPS + 1)
        ));
        // Near zero the absolute floor takes over: 1e-5 vs -1e-5 is
        // millions of ULPs but well inside FP32_ABS_TOL.
        assert!(ulp_diff(1e-5, -1e-5) > super::FP32_MAX_ULPS);
        assert!(fp32_close(1e-5, -1e-5));
        assert!(!fp32_close(f32::NAN, f32::NAN));
        assert_fp32_slices_close(&[1.0, 1e-5], &[1.0, -1e-5], "contract demo");
    }

    #[test]
    #[should_panic(expected = "worst case")]
    fn slice_assert_panics_with_context() {
        assert_fp32_slices_close(&[1.0], &[2.0], "worst case");
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[]), 0);
        // ties: first occurrence wins (strict > comparison)
        assert_eq!(argmax(&[2.0, 7.0, 7.0]), 1);
        // NaN never beats an existing max under strict >
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0]), 2);
    }

    #[test]
    fn logsumexp_matches_direct_sum_on_small_logits() {
        let xs = [0.5f32, -1.25, 2.0, 0.0];
        let direct: f64 = xs.iter().map(|&v| (v as f64).exp()).sum::<f64>().ln();
        assert!((logsumexp(&xs) - direct).abs() < 1e-12);
    }

    #[test]
    fn logsumexp_is_shift_invariant_and_overflow_safe() {
        let xs = [1.0f32, 2.0, 3.0];
        let base = logsumexp(&xs);
        let shifted: Vec<f32> = xs.iter().map(|v| v + 500.0).collect();
        // exp(503) overflows naively; the max-shift keeps it finite and
        // exactly `base + 500`.
        let s = logsumexp(&shifted);
        assert!(s.is_finite());
        assert!((s - (base + 500.0)).abs() < 1e-9, "{s} vs {}", base + 500.0);
    }

    #[test]
    fn logsumexp_edge_rows() {
        assert_eq!(logsumexp(&[]), f64::NEG_INFINITY);
        assert_eq!(logsumexp(&[f32::NEG_INFINITY; 3]), f64::NEG_INFINITY);
        // Single element: lse == the element.
        assert!((logsumexp(&[4.25]) - 4.25).abs() < 1e-12);
    }
}
